# NestQuant reproduction — top-level entry points.
#
#   make build        release build of the rust crate
#   make test         tier-1 test suite (cargo test -q)
#   make test-kernels kernel-focused tests re-run once per SIMD dispatch
#                     tier (NESTQUANT_KERNEL=scalar/avx2/neon; tiers the
#                     host lacks fall back to detection with a warning)
#   make clippy       lint gate (cargo clippy -- -D warnings)
#   make bench        full perf suite -> bench_output.txt + BENCH_gemm.json
#                     + BENCH_serve.json + BENCH_plan.json + BENCH_kvmix.json
#   make bench-gemm   hierarchical-LUT vs decode GEMM sweep -> BENCH_gemm.json
#   make bench-serve  multi-session serving sweep only -> BENCH_serve.json
#   make bench-plan   mixed-precision QuantPlan sweep only -> BENCH_plan.json
#   make bench-kvmix  heterogeneous KV-lane sweep only -> BENCH_kvmix.json
#   make soak-faults  fault-injection soak: the deterministic fail-point
#                     scenarios (kvpool alloc, codec decode, prefill,
#                     fused step, worker respawn)
#   make trace-smoke  observability gate: a traced multi-session soak
#                     whose Perfetto/Prometheus exports must shape-validate
#   make ci           fmt-check + clippy + build + test + test-kernels +
#                     soak-faults + trace-smoke + the kvmix, serve and
#                     gemm smoke benches (what a CI job runs)
#   make clean        remove build artifacts
#
# The python layer (training + AOT lowering, `make artifacts`) is only
# needed for the artifact-gated integration tests; the rust suite skips
# those gracefully when artifacts/ is absent.

.PHONY: build test test-kernels clippy bench bench-gemm bench-serve bench-plan bench-kvmix soak-faults trace-smoke fmt-check ci artifacts clean

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

# SIMD dispatch gate: the kernel parity/dispatch tests ("kernel" in the
# name) once per tier, each in its own process with NESTQUANT_KERNEL
# pinned. The dispatch choice is OnceLock-cached, so per-process env is
# the only way to force a tier end to end; requesting a tier the host
# lacks (neon on x86, avx2 on arm) warns and falls back to detection, so
# every leg runs everywhere — the scalar leg is the guaranteed fallback
# coverage.
test-kernels:
	cd rust && NESTQUANT_KERNEL=scalar cargo test -q kernel
	cd rust && NESTQUANT_KERNEL=avx2 cargo test -q kernel
	cd rust && NESTQUANT_KERNEL=neon cargo test -q kernel

clippy:
	cd rust && cargo clippy -- -D warnings

fmt-check:
	cd rust && cargo fmt --check

# fault-injection soak: every test exercising the deterministic
# fail-point sites ("fault"/"failpoint" in the name). Debug build so the
# sites are compiled in (they vanish from release unless the
# `failpoints` feature is on).
soak-faults:
	cd rust && cargo test -q fault && cargo test -q failpoint

# observability gate: the traced multi-session soak (synthetic model, no
# artifacts needed) whose Chrome-trace and Prometheus exports must
# shape-validate — plus the journal/export unit tests riding the same
# name filter
trace-smoke:
	cd rust && cargo test -q trace_smoke

# bench-kvmix, bench-serve and bench-gemm double as the CI smoke runs of
# the mixed-lane serving path, the fused decode-batch scheduler and the
# hierarchical-LUT GEMM backend (seconds each on synthetic inputs)
ci: fmt-check clippy build test test-kernels soak-faults trace-smoke bench-kvmix bench-serve bench-gemm

# no pipefail in POSIX sh: redirect, propagate the bench exit status,
# then show the log — a crashed bench must not leave a "fresh" log
bench:
	cd rust && cargo bench --bench bench_main > ../bench_output.txt 2>&1 || { cat ../bench_output.txt; exit 1; }
	@cat bench_output.txt

bench-gemm:
	cd rust && cargo bench --bench bench_main -- gemm > ../bench_gemm_output.txt 2>&1 || { cat ../bench_gemm_output.txt; exit 1; }
	@cat bench_gemm_output.txt

bench-serve:
	cd rust && cargo bench --bench bench_main -- serve > ../bench_serve_output.txt 2>&1 || { cat ../bench_serve_output.txt; exit 1; }
	@cat bench_serve_output.txt

bench-plan:
	cd rust && cargo bench --bench bench_main -- plan > ../bench_plan_output.txt 2>&1 || { cat ../bench_plan_output.txt; exit 1; }
	@cat bench_plan_output.txt

bench-kvmix:
	cd rust && cargo bench --bench bench_main -- kvmix > ../bench_kvmix_output.txt 2>&1 || { cat ../bench_kvmix_output.txt; exit 1; }
	@cat bench_kvmix_output.txt

artifacts:
	cd python && python -m compile.train && python -m compile.aot

clean:
	cd rust && cargo clean
	rm -f bench_output.txt bench_gemm_output.txt bench_serve_output.txt bench_plan_output.txt bench_kvmix_output.txt
