# NestQuant reproduction — top-level entry points.
#
#   make build        release build of the rust crate
#   make test         tier-1 test suite (cargo test -q)
#   make bench        full perf suite -> bench_output.txt + BENCH_gemm.json
#                     + BENCH_serve.json
#   make bench-serve  multi-session serving sweep only -> BENCH_serve.json
#   make ci           fmt-check + build + test (what a CI job runs)
#   make clean        remove build artifacts
#
# The python layer (training + AOT lowering, `make artifacts`) is only
# needed for the artifact-gated integration tests; the rust suite skips
# those gracefully when artifacts/ is absent.

.PHONY: build test bench bench-serve fmt-check ci artifacts clean

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

fmt-check:
	cd rust && cargo fmt --check

ci: fmt-check build test

# no pipefail in POSIX sh: redirect, propagate the bench exit status,
# then show the log — a crashed bench must not leave a "fresh" log
bench:
	cd rust && cargo bench --bench bench_main > ../bench_output.txt 2>&1 || { cat ../bench_output.txt; exit 1; }
	@cat bench_output.txt

bench-serve:
	cd rust && cargo bench --bench bench_main -- serve > ../bench_serve_output.txt 2>&1 || { cat ../bench_serve_output.txt; exit 1; }
	@cat bench_serve_output.txt

artifacts:
	cd python && python -m compile.train && python -m compile.aot

clean:
	cd rust && cargo clean
	rm -f bench_output.txt bench_serve_output.txt
