"""Pallas kernels (interpret=True) vs the jnp reference oracles."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.e8 import e8_decode, e8_quantize
from compile.kernels.qmatmul import qmatmul, vmem_report


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([4, 14, 16]),
       st.sampled_from([8, 64, 512]))
def test_e8_decode_matches_ref(seed, q, blocks):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, q, size=(blocks, 8)).astype(np.int32))
    fast = np.asarray(e8_decode(codes, q=q))
    slow = np.asarray(ref.voronoi_decode(codes, q, m_variant=True))
    np.testing.assert_allclose(fast, slow, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([8, 14]))
def test_e8_quantize_roundtrip(seed, q):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((64, 8)).astype(np.float32))
    codes, recon = e8_quantize(x, q=q)
    # codes agree with the reference encoder
    cref = np.asarray(ref.voronoi_encode(x, q))
    np.testing.assert_array_equal(np.asarray(codes), cref)
    # recon is exactly the reference M-variant decode of those codes
    rref = np.asarray(ref.voronoi_decode(codes, q, m_variant=True))
    np.testing.assert_allclose(np.asarray(recon), rref, atol=1e-6)
    # and equals the true nearest point except for rare boundary cases
    # (NestQuantM's shaping region differs slightly near ∂(qV) — App. D)
    p = np.asarray(ref.nearest_e8(x))
    frac_exact = (np.abs(np.asarray(recon) - p).max(-1) < 1e-6).mean()
    assert frac_exact > 0.9, frac_exact


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_qmatmul_kernel_matches_ref(seed):
    rng = np.random.default_rng(seed)
    rows, cols, q = 32, 64, 14
    betas = (0.25, 0.32, 0.45, 1.0)
    w = rng.standard_normal((rows, cols)).astype(np.float32)
    codes = np.zeros((rows, cols), np.int32)
    bidx = np.zeros((rows, cols // 8), np.int32)
    scales = np.zeros(rows, np.float32)
    for r in range(rows):
        c, bi, s = ref.nested_quantize(jnp.asarray(w[r]), q, betas, m_variant=True)
        codes[r], bidx[r], scales[r] = np.asarray(c), np.asarray(bi), float(s)
    x = rng.standard_normal(cols).astype(np.float32)
    fast = np.asarray(
        qmatmul(jnp.asarray(codes), jnp.asarray(bidx), jnp.asarray(scales),
                jnp.asarray(x), q=q, betas=betas)
    )
    slow = np.asarray(
        ref.qmatmul_ref(jnp.asarray(codes), jnp.asarray(bidx),
                        jnp.asarray(scales), jnp.asarray(x), q, betas)
    )
    np.testing.assert_allclose(fast, slow, rtol=1e-4, atol=1e-4)


def test_qmatmul_approximates_dense():
    rng = np.random.default_rng(3)
    rows, cols, q = 32, 128, 14
    betas = (0.25, 0.32, 0.45, 1.0)
    w = rng.standard_normal((rows, cols)).astype(np.float32)
    codes = np.zeros((rows, cols), np.int32)
    bidx = np.zeros((rows, cols // 8), np.int32)
    scales = np.zeros(rows, np.float32)
    for r in range(rows):
        c, bi, s = ref.nested_quantize(jnp.asarray(w[r]), q, betas, m_variant=True)
        codes[r], bidx[r], scales[r] = np.asarray(c), np.asarray(bi), float(s)
    x = rng.standard_normal(cols).astype(np.float32)
    y = np.asarray(
        qmatmul(jnp.asarray(codes), jnp.asarray(bidx), jnp.asarray(scales),
                jnp.asarray(x), q=q, betas=betas)
    )
    exact = w @ x
    rel = np.sqrt(np.mean((y - exact) ** 2)) / (np.linalg.norm(exact) / np.sqrt(rows))
    assert rel < 0.15, rel


def test_vmem_report_structure():
    rep = vmem_report(256, 512, 14)
    assert rep["vmem_bytes_per_tile"] < 16 * 2**20, "tile must fit VMEM"
    assert rep["hbm_bits_per_entry"] == 4.25
    assert rep["row_tile"] >= 1
