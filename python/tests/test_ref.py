"""Tests for the pure-jnp reference implementations (kernels/ref.py)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref


def _rand_blocks(n, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, 8)).astype(np.float32) * scale)


def _in_e8(p) -> bool:
    p = np.asarray(p, dtype=np.float64)
    if np.allclose(p, np.round(p)):
        return int(np.round(p).sum()) % 2 == 0
    h = p - 0.5
    if np.allclose(h, np.round(h)):
        return int(np.round(h).sum()) % 2 == 0
    return False


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_nearest_e8_returns_lattice_points(seed):
    x = _rand_blocks(16, seed, 2.0)
    p = np.asarray(ref.nearest_e8(x))
    for row in p:
        assert _in_e8(row), row


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_nearest_e8_beats_random_lattice_neighbors(seed):
    x = _rand_blocks(4, seed, 1.5)
    p = np.asarray(ref.nearest_e8(x))
    xs = np.asarray(x)
    rng = np.random.default_rng(seed)
    # random E8 perturbations of the found point must not be closer
    for _ in range(50):
        d8 = rng.integers(-2, 3, size=8)
        if d8.sum() % 2 != 0:
            d8[0] += 1
        alt = p + d8.astype(np.float64)
        d_found = ((xs - p) ** 2).sum(-1)
        d_alt = ((xs - alt) ** 2).sum(-1)
        assert (d_found <= d_alt + 1e-4).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([8, 14, 16]))
def test_voronoi_roundtrip_no_overload(seed, q):
    # σ=1 ≪ q·inradius(V_E8): decode(encode(x)) == nearest_e8(x).
    # (q=3 would legitimately overload: 3·0.707 < E‖x‖ ≈ 2.8.)
    x = _rand_blocks(32, seed)
    p = np.asarray(ref.nearest_e8(x))
    c = ref.voronoi_encode(x, q)
    r = np.asarray(ref.voronoi_decode(c, q))
    np.testing.assert_allclose(r, p, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([4, 14]))
def test_voronoi_code_roundtrip(seed, q):
    # decode → encode returns the same code
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.integers(0, q, size=(16, 8)).astype(np.int32))
    r = ref.voronoi_decode(c, q)
    c2 = ref.voronoi_encode(r, q)
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(c))


def test_voronoi_decode_m_variant_matches_rust_convention():
    # golden values computed by the rust integer decoder (decode_block_i32)
    # for q=14 — guards the cross-language contract.
    c = jnp.asarray([[6, 0, 9, 6, 8, 11, 7, 6]], dtype=jnp.int32)
    r = np.asarray(ref.voronoi_decode(c, 14, m_variant=True))[0]
    expected = np.array([6, -4, -6, -8, -12, -10, 0, -10], dtype=np.float64) * 0.5
    np.testing.assert_allclose(r, expected)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_nested_quantize_error_small(seed):
    a = jnp.asarray(
        np.random.default_rng(seed).standard_normal(128).astype(np.float32)
    )
    betas = (0.25, 0.32, 0.45, 1.0)
    codes, bidx, s = ref.nested_quantize(a, 14, betas)
    back = ref.nested_dequantize(codes, bidx, s, 14, betas)
    rmse = float(jnp.sqrt(jnp.mean((back - a) ** 2)))
    assert rmse < 0.12, rmse


def test_nested_quantize_zero_vector():
    a = jnp.zeros(64)
    codes, bidx, s = ref.nested_quantize(a, 8, (0.3, 0.6))
    assert float(s) == 0.0
    back = ref.nested_dequantize(codes, bidx, s, 8, (0.3, 0.6))
    np.testing.assert_allclose(np.asarray(back), 0.0)


def test_qmatmul_ref_matches_dense():
    rng = np.random.default_rng(5)
    rows, cols, q = 16, 64, 14
    betas = (0.25, 0.32, 0.45, 1.0)
    w = rng.standard_normal((rows, cols)).astype(np.float32)
    codes = np.zeros((rows, cols), np.int32)
    bidx = np.zeros((rows, cols // 8), np.int32)
    scales = np.zeros(rows, np.float32)
    deq = np.zeros_like(w)
    for r in range(rows):
        c, bi, s = ref.nested_quantize(jnp.asarray(w[r]), q, betas, m_variant=True)
        codes[r], bidx[r], scales[r] = np.asarray(c), np.asarray(bi), float(s)
        deq[r] = np.asarray(
            ref.nested_dequantize(c, bi, s, q, betas, m_variant=True)
        )
    x = rng.standard_normal(cols).astype(np.float32)
    y = np.asarray(
        ref.qmatmul_ref(
            jnp.asarray(codes), jnp.asarray(bidx), jnp.asarray(scales),
            jnp.asarray(x), q, betas,
        )
    )
    np.testing.assert_allclose(y, deq @ x, rtol=1e-4, atol=1e-4)
