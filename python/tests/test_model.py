"""Model (L2) tests: shapes, loss behavior, determinism, serialization."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import corpus
from compile.model import (
    Config,
    count_params,
    flatten_names,
    forward,
    forward_batch,
    init_params,
    loss_fn,
)

CFG = Config(vocab=corpus.VOCAB_SIZE, ctx=32, d_model=64, n_layer=2, n_head=2, d_ff=192)


def _params():
    return init_params(CFG, jax.random.PRNGKey(7))


def test_forward_shapes():
    p = _params()
    toks = jnp.zeros((16,), jnp.int32)
    logits = forward(p, toks, CFG)
    assert logits.shape == (16, CFG.vocab)
    batch = jnp.zeros((3, 32), jnp.int32)
    lb = forward_batch(p, batch, CFG)
    assert lb.shape == (3, 32, CFG.vocab)


def test_initial_loss_near_uniform():
    p = _params()
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, size=(4, 33)).astype(np.int32))
    loss = float(loss_fn(p, toks, CFG))
    assert abs(loss - np.log(CFG.vocab)) < 0.25, loss


def test_causality():
    # changing a future token must not affect earlier logits
    p = _params()
    rng = np.random.default_rng(1)
    toks = rng.integers(0, CFG.vocab, size=32).astype(np.int32)
    l1 = np.asarray(forward(p, jnp.asarray(toks), CFG))
    toks2 = toks.copy()
    toks2[20] = (toks2[20] + 5) % CFG.vocab
    l2 = np.asarray(forward(p, jnp.asarray(toks2), CFG))
    np.testing.assert_allclose(l1[:20], l2[:20], atol=1e-5)
    assert not np.allclose(l1[20:], l2[20:])


def test_loss_decreases_when_overfitting_one_batch():
    p = _params()
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, size=(2, 33)).astype(np.int32))
    grad_fn = jax.jit(jax.value_and_grad(lambda pp: loss_fn(pp, toks, CFG)))
    l0, _ = grad_fn(p)
    for _ in range(30):
        _, g = grad_fn(p)
        p = jax.tree_util.tree_map(lambda a, b: a - 0.05 * b, p, g)
    l1, _ = grad_fn(p)
    assert float(l1) < float(l0) * 0.8, (float(l0), float(l1))


def test_param_count_and_flatten_order():
    p = _params()
    names = [n for n, _ in flatten_names(p, CFG)]
    assert names[0] == "tok_emb"
    assert names[3] == "final_norm"
    assert f"layers.{CFG.n_layer - 1}.w_down" == names[-1]
    total = sum(int(a.size) for _, a in flatten_names(p, CFG))
    assert total == count_params(p)


def test_deterministic_init():
    a = _params()
    b = init_params(CFG, jax.random.PRNGKey(7))
    for (_, x), (_, y) in zip(flatten_names(a, CFG), flatten_names(b, CFG)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_corpus_roundtrip_and_determinism():
    t1 = corpus.generate(2000, seed=3)
    t2 = corpus.generate(2000, seed=3)
    assert t1 == t2
    ids = corpus.encode(t1)
    assert corpus.decode(ids) == t1  # all generated chars are in-vocab
    t3 = corpus.generate(2000, seed=4)
    assert t1 != t3


def test_nqt_python_roundtrip(tmp_path):
    from compile import nqt

    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.arange(5, dtype=np.int32),
        "c": np.arange(7, dtype=np.uint8),
    }
    p = tmp_path / "t.nqt"
    nqt.write(p, tensors)
    back = nqt.read(p)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
