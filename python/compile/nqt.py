"""Python writer/reader for the `.nqt` tensor container (see
rust/src/io/tensorfile.rs for the spec). Little-endian, self-describing."""

import struct

import numpy as np

_MAGIC = b"NQT1"
_DTYPES = {np.dtype(np.float32): 0, np.dtype(np.uint8): 1, np.dtype(np.int32): 2}
_DTYPES_REV = {0: np.float32, 1: np.uint8, 2: np.int32}


def write(path, tensors: dict):
    """tensors: {name: np.ndarray} with dtype f32/u8/i32."""
    with open(path, "wb") as f:
        f.write(_MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _DTYPES:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def read(path) -> dict:
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == _MAGIC, "bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            dtype_id, ndim = struct.unpack("<BB", f.read(2))
            dims = [struct.unpack("<Q", f.read(8))[0] for _ in range(ndim)]
            dt = np.dtype(_DTYPES_REV[dtype_id])
            numel = int(np.prod(dims)) if dims else 1
            data = np.frombuffer(f.read(numel * dt.itemsize), dtype=dt)
            out[name] = data.reshape(dims)
    return out
