"""Layer-1 Pallas kernels: E8 Voronoi encode / decode over blocked inputs.

Kernels are written TPU-shaped — BlockSpec tiles a (blocks, 8) array of
8-vectors into VMEM-sized row tiles — but are always lowered with
``interpret=True``: the CPU PJRT plugin cannot execute Mosaic custom calls
(see /opt/xla-example/README.md), so interpret mode is both the correctness
path and what the AOT artifacts embed.

Correctness is pytest-checked against ``ref.py`` (hypothesis sweeps shapes,
q, and seeds).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

D = 8
# Row tile: 8-vectors per VMEM tile. 512 blocks × 8 lanes × 4 B ≈ 16 KiB in,
# ~3 tiles live (in/codes/out) — well under the ~16 MiB VMEM budget; sized
# so the (blocks/TILE) grid stays coarse enough to amortize dispatch.
TILE = 512


def _decode_halfunits(t, q):
    """Shared integer decode (NestQuantM flip-0 variant), t int32 (..., 8)."""
    m = 2 * q
    r1 = (t + q) // m
    e1 = t - m * r1
    r2 = t // m
    e2 = t - q - m * r2

    def fix(e, r):
        par = jnp.mod(jnp.sum(r, axis=-1, keepdims=True), 2)
        dir_ = jnp.where(e[..., :1] >= 0, 1, -1)
        delta = jnp.concatenate(
            [m * dir_, jnp.zeros_like(e[..., 1:])], axis=-1
        )
        return jnp.where(par == 1, e - delta, e)

    e1 = fix(e1, r1)
    e2 = fix(e2, r2)
    c1 = jnp.sum(e1 * e1, axis=-1, keepdims=True)
    c2 = jnp.sum(e2 * e2, axis=-1, keepdims=True)
    return jnp.where(c1 <= c2, e1, e2)


def _gmul(c):
    """t = G·c for the Appendix-E generator (sparse form), c int32 (..., 8)."""
    c0 = c[..., 0:1]
    s = jnp.sum(c[..., 2:], axis=-1, keepdims=True)
    return jnp.concatenate(
        [
            c0,
            c0 + 2 * c[..., 2:3],
            c0 + 2 * c[..., 4:5],
            c0 + 2 * c[..., 6:7],
            c0 + 4 * c[..., 1:2] + 2 * s,
            c0 + 2 * c[..., 3:4],
            c0 + 2 * c[..., 5:6],
            c0 + 2 * c[..., 7:8],
        ],
        axis=-1,
    )


def _decode_kernel(c_ref, o_ref, *, q):
    c = c_ref[...].astype(jnp.int32)
    e = _decode_halfunits(_gmul(c), q)
    o_ref[...] = e.astype(jnp.float32) * 0.5


@functools.partial(jax.jit, static_argnames=("q",))
def e8_decode(codes, *, q: int):
    """Decode coset codes (blocks, 8) int32 → lattice points (blocks, 8) f32.

    NestQuantM decode oracle (flip position 0, Appendix D) — matches the
    rust `decode_block_i32` exactly.
    """
    blocks = codes.shape[0]
    assert codes.shape[1] == D
    tile = TILE if blocks % TILE == 0 else blocks
    grid = (blocks // tile,)
    return pl.pallas_call(
        functools.partial(_decode_kernel, q=q),
        out_shape=jax.ShapeDtypeStruct((blocks, D), jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((tile, D), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, D), lambda i: (i, 0)),
        interpret=True,
    )(codes)


def _quantize_kernel(x_ref, ginv_ref, codes_ref, recon_ref, *, q):
    """Encode blocks of 8 (already scaled by 1/β) and emit decode(encode)."""
    x = x_ref[...]
    ginv = ginv_ref[...]
    # nearest E8 point: D8 candidate and D8+½ candidate with parity fix.
    # (full oracle: flip at argmax |x−r| — encode side is exact)
    def nearest_d8(y):
        r = jnp.floor(y + 0.5)
        a = jnp.abs(y - r)
        par = jnp.mod(jnp.sum(r, axis=-1, keepdims=True), 2.0)
        pos = jnp.argmax(a, axis=-1)
        onehot = (jax.lax.broadcasted_iota(jnp.int32, y.shape, 1) == pos[..., None])
        ev = jnp.sum(jnp.where(onehot, y - r, 0.0), axis=-1, keepdims=True)
        dir_ = jnp.where(ev >= 0, 1.0, -1.0)
        return jnp.where(par == 1.0, r + onehot * dir_, r)

    c1 = nearest_d8(x)
    c2 = nearest_d8(x - 0.5) + 0.5
    d1 = jnp.sum((x - c1) ** 2, axis=-1, keepdims=True)
    d2 = jnp.sum((x - c2) ** 2, axis=-1, keepdims=True)
    p = jnp.where(d1 <= d2, c1, c2)
    # coset code: v = G⁻¹·(2p) mod q
    t = 2.0 * p
    v = jnp.floor(t @ ginv.T + 0.5)
    codes = jnp.mod(v, float(q))
    codes_ref[...] = codes.astype(jnp.int32)
    # reconstruction via the decode path (overload-aware)
    e = _decode_halfunits(_gmul(codes.astype(jnp.int32)), q)
    recon_ref[...] = e.astype(jnp.float32) * 0.5


@functools.partial(jax.jit, static_argnames=("q",))
def e8_quantize(x, *, q: int):
    """Encode scaled blocks (blocks, 8) f32 → (codes int32, recon f32).

    recon = decode(encode(x)) — equals the nearest lattice point unless the
    encoder is in overload (paper §4.1).
    """
    import numpy as np

    from . import ref

    blocks = x.shape[0]
    assert x.shape[1] == D
    ginv = jnp.asarray(np.asarray(ref.G2E8_INV), dtype=jnp.float32)
    tile = TILE if blocks % TILE == 0 else blocks
    grid = (blocks // tile,)
    return pl.pallas_call(
        functools.partial(_quantize_kernel, q=q),
        out_shape=(
            jax.ShapeDtypeStruct((blocks, D), jnp.int32),
            jax.ShapeDtypeStruct((blocks, D), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, D), lambda i: (i, 0)),
            pl.BlockSpec((D, D), lambda i: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((tile, D), lambda i: (i, 0)),
            pl.BlockSpec((tile, D), lambda i: (i, 0)),
        ),
        interpret=True,
    )(x, ginv)
