"""Pure-jnp oracles for the NestQuant kernels.

Everything here is the *reference* implementation the Pallas kernels (and,
transitively, the rust engine — see the cross-language golden tests) are
checked against:

* ``nearest_e8``      — Conway–Sloane closest point in E8 (paper Alg. 5)
* ``voronoi_encode``  — coset code of the nearest lattice point (Alg. 1)
* ``voronoi_decode``  — min-energy coset representative (Alg. 2), with the
  integer half-unit formulation shared with the rust fast path
* ``nested_quantize`` — multi-β quantization of 8-blocks (Alg. 3)
* ``qmatmul_ref``     — dequantize-then-matmul reference for the fused
  Pallas kernel

Conventions match the rust side exactly: round-half-up tie-breaks and the
Appendix-E generator matrix of 2·E8.
"""

import jax.numpy as jnp
import numpy as np

D = 8

# Appendix-E generator of 2·E8 (row-major; columns are generators).
G2E8 = np.array(
    [
        [1, 0, 0, 0, 0, 0, 0, 0],
        [1, 0, 2, 0, 0, 0, 0, 0],
        [1, 0, 0, 0, 2, 0, 0, 0],
        [1, 0, 0, 0, 0, 0, 2, 0],
        [1, 4, 2, 2, 2, 2, 2, 2],
        [1, 0, 0, 2, 0, 0, 0, 0],
        [1, 0, 0, 0, 0, 2, 0, 0],
        [1, 0, 0, 0, 0, 0, 0, 2],
    ],
    dtype=np.int64,
)
G2E8_INV = np.linalg.inv(G2E8.astype(np.float64))  # exact up to fp (det 256)


def _round_half_up(x):
    return jnp.floor(x + 0.5)


def _nearest_d8(x, force_flip0: bool):
    """Closest point of D8 = {v ∈ Z^8 : Σv even}; x has shape (..., 8)."""
    r = _round_half_up(x)
    parity = jnp.mod(jnp.sum(r, axis=-1), 2.0)  # 0 or 1
    a = jnp.abs(x - r)
    if force_flip0:
        pos = jnp.zeros(x.shape[:-1], dtype=jnp.int32)
    else:
        pos = jnp.argmax(a, axis=-1).astype(jnp.int32)
    dir_ = jnp.where(jnp.take_along_axis(x - r, pos[..., None], -1)[..., 0] >= 0, 1.0, -1.0)
    onehot = jnp.arange(D) == pos[..., None]
    r_flipped = r + onehot * dir_[..., None]
    return jnp.where(parity[..., None] == 1.0, r_flipped, r)


def nearest_e8(x, m_variant: bool = False):
    """Closest point of E8 = D8 ∪ (D8 + ½); x shape (..., 8)."""
    c1 = _nearest_d8(x, m_variant)
    c2 = _nearest_d8(x - 0.5, m_variant) + 0.5
    d1 = jnp.sum((x - c1) ** 2, axis=-1)
    d2 = jnp.sum((x - c2) ** 2, axis=-1)
    return jnp.where((d1 <= d2)[..., None], c1, c2)


def voronoi_encode(x, q: int):
    """Alg. 1: coset code (..., 8) of nearest lattice point; values in [0, q)."""
    p = nearest_e8(x)
    t = (2.0 * p)  # integer vector in 2E8
    v = jnp.einsum("ij,...j->...i", jnp.asarray(G2E8_INV, dtype=x.dtype), t)
    v = _round_half_up(v)
    return jnp.mod(v, q).astype(jnp.int32)


def voronoi_decode(c, q: int, m_variant: bool = False):
    """Alg. 2 via the integer half-unit formulation (matches rust exactly).

    t = G·c ≥ 0; m = 2q; candidates
      e1 = t − m·round_half_up(t/m)   (D8 grid)
      e2 = t − q − m·floor(t/m)       (D8+½ grid)
    with parity flips; result = chosen e / 2.
    """
    t = jnp.einsum("ij,...j->...i", jnp.asarray(G2E8, dtype=jnp.int32), c.astype(jnp.int32))
    m = 2 * q
    r1 = (t + q) // m
    e1 = t - m * r1
    r2 = t // m
    e2 = t - q - m * r2

    def parity_fix(e, r, force0):
        par = jnp.mod(jnp.sum(r, axis=-1), 2)
        if force0:
            pos = jnp.zeros(e.shape[:-1], dtype=jnp.int32)
        else:
            pos = jnp.argmax(jnp.abs(e), axis=-1).astype(jnp.int32)
        ev = jnp.take_along_axis(e, pos[..., None], -1)[..., 0]
        dir_ = jnp.where(ev >= 0, 1, -1)
        onehot = (jnp.arange(D) == pos[..., None]).astype(e.dtype)
        e_f = e - onehot * (m * dir_)[..., None]
        return jnp.where(par[..., None] == 1, e_f, e)

    e1 = parity_fix(e1, r1, m_variant)
    e2 = parity_fix(e2, r2, m_variant)
    c1 = jnp.sum(e1 * e1, axis=-1)
    c2 = jnp.sum(e2 * e2, axis=-1)
    e = jnp.where((c1 <= c2)[..., None], e1, e2)
    return e.astype(jnp.float32) * 0.5


def nested_quantize(a, q: int, betas, m_variant: bool = False):
    """Alg. 3 on a 1-D vector (length divisible by 8).

    Returns (codes (n,), beta_idx (n/8,), scale s). Opt-β strategy.
    """
    n = a.shape[-1]
    assert n % D == 0
    s = jnp.linalg.norm(a)
    scale = jnp.where(s > 0, jnp.sqrt(float(n)) / s, 0.0)
    v = (a * scale).reshape(-1, D)  # (b, 8)
    betas = jnp.asarray(betas, dtype=jnp.float32)
    # quantize each block at every beta, pick the best
    errs, codes, recons = [], [], []
    for bi in range(betas.shape[0]):
        beta = betas[bi]
        c = voronoi_encode(v / beta, q)
        r = voronoi_decode(c, q, m_variant) * beta
        errs.append(jnp.sum((r - v) ** 2, axis=-1))
        codes.append(c)
        recons.append(r)
    errs = jnp.stack(errs)            # (k, b)
    codes = jnp.stack(codes)          # (k, b, 8)
    best = jnp.argmin(errs, axis=0)   # (b,)
    code = jnp.take_along_axis(codes, best[None, :, None], 0)[0]
    return code.reshape(n), best.astype(jnp.int32), s


def nested_dequantize(codes, beta_idx, s, q: int, betas, m_variant: bool = False):
    n = codes.shape[-1]
    betas = jnp.asarray(betas, dtype=jnp.float32)
    c = codes.reshape(-1, D)
    r = voronoi_decode(c, q, m_variant)
    r = r * betas[beta_idx][:, None]
    denorm = jnp.where(s > 0, s / jnp.sqrt(float(n)), 0.0)
    return (r * denorm).reshape(n)


def qmatmul_ref(codes, beta_idx, row_scales, x, q: int, betas, m_variant: bool = True):
    """Reference for the fused decode-matmul kernel: y = W·x.

    codes (rows, cols) int32; beta_idx (rows, cols/8) int32;
    row_scales (rows,) = s_r; x (cols,) f32.
    """
    rows, cols = codes.shape
    betas = jnp.asarray(betas, dtype=jnp.float32)
    c = codes.reshape(rows, cols // D, D)
    dec = voronoi_decode(c, q, m_variant)           # (rows, b, 8)
    dec = dec * betas[beta_idx][..., None]          # apply β per block
    w = dec.reshape(rows, cols) * (row_scales / jnp.sqrt(float(cols)))[:, None]
    return w @ x
