"""Layer-1 Pallas kernel: fused NestQuant decode → GEMV (the paper's
Appendix-E CUDA kernel, re-thought for TPU).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): instead of warp-level
``__dp4a`` tricks, the kernel tiles rows of the packed weight into VMEM
(BlockSpec), decodes each 8-block to a small-integer lattice point in
registers, applies the 2-bit β dictionary, and feeds the dequantized tile
to the vector unit / MXU as a dense dot. Memory traffic from HBM is the
~4.25-bit payload, not f32 weights — the memory-bound GEMV win of Table 4.

Lowered with interpret=True (CPU PJRT cannot run Mosaic custom calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .e8 import _decode_halfunits, _gmul

D = 8
ROW_TILE = 32


def _qmatmul_kernel(codes_ref, beta_idx_ref, scale_ref, x_ref, o_ref, *, q, betas):
    codes = codes_ref[...].astype(jnp.int32)          # (rt, cols)
    rt, cols = codes.shape
    b = cols // D
    blocks = codes.reshape(rt, b, D)
    e = _decode_halfunits(_gmul(blocks), q)           # (rt, b, 8) half-units
    beta_idx = beta_idx_ref[...]                      # (rt, b) int32
    # β dictionary select without capturing an array constant (pallas
    # kernels may only close over scalars); βs are folded with the
    # half-unit 0.5 factor.
    bsel = jnp.zeros(beta_idx.shape, jnp.float32)
    for t, beta in enumerate(betas):
        bsel = jnp.where(beta_idx == t, beta * 0.5, bsel)
    w = e.astype(jnp.float32) * bsel[..., None]
    w = w.reshape(rt, cols)
    x = x_ref[...]                                    # (cols,)
    y = w @ x                                         # dense dot → MXU tile
    o_ref[...] = y * scale_ref[...] / jnp.sqrt(float(cols))


@functools.partial(jax.jit, static_argnames=("q", "betas"))
def qmatmul(codes, beta_idx, row_scales, x, *, q: int, betas: tuple):
    """y = W·x from quantized storage.

    codes (rows, cols) int32 in [0,q); beta_idx (rows, cols/8) int32;
    row_scales (rows,) f32 (s_r = ‖row‖₂); x (cols,) f32.
    """
    rows, cols = codes.shape
    tile = ROW_TILE if rows % ROW_TILE == 0 else rows
    grid = (rows // tile,)
    return pl.pallas_call(
        functools.partial(_qmatmul_kernel, q=q, betas=tuple(betas)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, cols), lambda i: (i, 0)),
            pl.BlockSpec((tile, cols // D), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((cols,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        interpret=True,
    )(codes, beta_idx, row_scales, x)


def vmem_report(rows: int, cols: int, q: int) -> dict:
    """Static VMEM/MXU estimate for DESIGN.md §Perf (interpret mode gives
    no TPU timings — the paper-facing numbers are structural)."""
    tile = ROW_TILE if rows % ROW_TILE == 0 else rows
    codes_b = tile * cols * 4          # int32 in VMEM (packed u4 in HBM)
    beta_b = tile * cols // D * 4
    x_b = cols * 4
    w_b = tile * cols * 4              # decoded tile
    out_b = tile * 4
    vmem = codes_b + beta_b + x_b + w_b + out_b
    payload_bits = cols * (jnp.log2(q).item() if hasattr(jnp.log2(q), "item") else 4) + cols / D * 2
    return {
        "row_tile": tile,
        "vmem_bytes_per_tile": vmem,
        "hbm_bits_per_entry": 4 + 2 / D,  # u4 codes + 2-bit β
        "mxu_tile": (tile, cols),
        "flops_per_tile": 2 * tile * cols,
        "payload_bits_per_row": payload_bits,
    }
