"""Build-time training of the evaluation char-LMs (DESIGN.md §2: stands in
for the Llama checkpoints the paper quantizes).

Trains three sizes (tiny / small / base) on the synthetic corpus with a
hand-rolled AdamW (optax unavailable offline), logs the loss curve to
results/train_loss_<name>.tsv, and saves weights + config + token splits to
artifacts/model_<name>.nqt for the rust engine.

Run once via `make artifacts`; never on the request path.
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, nqt
from .model import Config, count_params, flatten_names, init_params, loss_fn

SIZES = {
    # name: (d_model, n_layer, n_head, d_ff, steps)
    "tiny": (64, 2, 2, 192, 250),
    "small": (96, 3, 4, 256, 300),
    "base": (192, 4, 4, 512, 400),
}
CTX = 128
BATCH = 12
LR_PEAK = 3e-3
LR_FLOOR = 3e-4
WARMUP = 20
WD = 0.01
B1, B2 = 0.9, 0.95
EPS = 1e-8


def batches(tokens: np.ndarray, rng: np.random.Generator):
    """Random (BATCH, CTX+1) windows."""
    starts = rng.integers(0, len(tokens) - CTX - 1, size=BATCH)
    return np.stack([tokens[s : s + CTX + 1] for s in starts]).astype(np.int32)


def lr_at(step: int, total: int) -> float:
    if step < WARMUP:
        return LR_PEAK * (step + 1) / WARMUP
    frac = (step - WARMUP) / max(1, total - WARMUP)
    return LR_FLOOR + 0.5 * (LR_PEAK - LR_FLOOR) * (1 + np.cos(np.pi * frac))


def adamw_update(params, grads, m, v, step, lr):
    def upd(p, g, m_, v_):
        m2 = B1 * m_ + (1 - B1) * g
        v2 = B2 * v_ + (1 - B2) * g * g
        mhat = m2 / (1 - B1 ** (step + 1))
        vhat = v2 / (1 - B2 ** (step + 1))
        p2 = p - lr * (mhat / (jnp.sqrt(vhat) + EPS) + WD * p)
        return p2, m2, v2

    flat_p, tree = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(m)
    flat_v = jax.tree_util.tree_leaves(v)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v)]
    ps, ms, vs = zip(*out)
    return (
        jax.tree_util.tree_unflatten(tree, ps),
        jax.tree_util.tree_unflatten(tree, ms),
        jax.tree_util.tree_unflatten(tree, vs),
    )


def eval_loss(params, cfg, tokens: np.ndarray, n_windows: int = 24) -> float:
    rng = np.random.default_rng(1234)
    total = 0.0
    for _ in range(n_windows):
        b = batches(tokens, rng)
        total += float(loss_fn(params, jnp.asarray(b), cfg))
    return total / n_windows


def train_one(name: str, out_dir: str, results_dir: str, train_tok, val_tok) -> None:
    d, layers, heads, ff, steps = SIZES[name]
    cfg = Config(
        vocab=corpus.VOCAB_SIZE, ctx=CTX, d_model=d, n_layer=layers, n_head=heads, d_ff=ff
    )
    key = jax.random.PRNGKey(42)
    params = init_params(cfg, key)
    print(f"[{name}] {count_params(params):,} params, {steps} steps")

    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    m, v = zeros, jax.tree_util.tree_map(jnp.zeros_like, params)

    grad_fn = jax.jit(jax.value_and_grad(lambda p, t: loss_fn(p, t, cfg)))

    rng = np.random.default_rng(99)
    curve = []
    t0 = time.time()
    train_np = np.asarray(train_tok)
    val_np = np.asarray(val_tok)
    for step in range(steps):
        b = jnp.asarray(batches(train_np, rng))
        loss, grads = grad_fn(params, b)
        lr = lr_at(step, steps)
        params, m, v = adamw_update(params, grads, m, v, step, lr)
        curve.append((step, float(loss)))
        if step % 50 == 0 or step == steps - 1:
            print(f"[{name}] step {step:4d} loss {float(loss):.4f} lr {lr:.2e} "
                  f"({time.time() - t0:.0f}s)")

    val = eval_loss(params, cfg, val_np)
    ppl = float(np.exp(val))
    print(f"[{name}] val loss {val:.4f}  ppl {ppl:.3f}")

    os.makedirs(results_dir, exist_ok=True)
    with open(os.path.join(results_dir, f"train_loss_{name}.tsv"), "w") as f:
        f.write("step\tloss\n")
        for s, l in curve:
            f.write(f"{s}\t{l:.5f}\n")
        f.write(f"# val_loss\t{val:.5f}\n# val_ppl\t{ppl:.5f}\n")

    tensors = {
        "config": np.array(
            [cfg.vocab, cfg.ctx, cfg.d_model, cfg.n_layer, cfg.n_head, cfg.d_ff],
            dtype=np.int32,
        ),
        "tokens/val": val_np.astype(np.int32),
        "tokens/calib": train_np[: 48 * (CTX + 1)].astype(np.int32),
    }
    for pname, arr in flatten_names(params, cfg):
        tensors[f"w/{pname}"] = np.asarray(arr, dtype=np.float32)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"model_{name}.nqt")
    nqt.write(path, tensors)
    print(f"[{name}] wrote {path} ({os.path.getsize(path) / 1e6:.1f} MB)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--results", default="../results")
    ap.add_argument("--models", default="tiny,small,base")
    args = ap.parse_args()

    train_tok, val_tok = corpus.train_val_tokens(600_000, 40_000)
    print(f"corpus: {len(train_tok):,} train / {len(val_tok):,} val tokens, "
          f"vocab {corpus.VOCAB_SIZE}")
    for name in args.models.split(","):
        train_one(name.strip(), args.out_dir, args.results, train_tok, val_tok)


if __name__ == "__main__":
    main()
