"""AOT lowering: JAX (L2) + Pallas (L1) → HLO *text* artifacts the rust
runtime (L3) loads via PJRT.

Interchange is HLO text, not serialized protos: jax ≥ 0.5 emits 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser reassigns
ids (see /opt/xla-example/README.md and gen_hlo.py there).

Artifacts (per trained model size):
  model_fwd_<name>_b<B>.hlo.txt — batched scoring forward:
      (tokens i32[B,S], *flat_params) → (logits f32[B,S,V],)
  qmatmul_demo.hlo.txt          — the L1 fused decode-GEMV Pallas kernel on
      a real quantized matrix (three-layer composition proof; executed by
      examples/quickstart.rs and checked against the rust decoder)
  plus a `aot_manifest.txt` listing arg orders for the rust loader.
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, nqt
from .model import Config, flatten_names, forward_batch


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def load_model(path: str):
    tensors = nqt.read(path)
    vocab, ctx, d_model, n_layer, n_head, d_ff = [int(x) for x in tensors["config"]]
    cfg = Config(vocab=vocab, ctx=ctx, d_model=d_model, n_layer=n_layer,
                 n_head=n_head, d_ff=d_ff)
    params = {
        "tok_emb": jnp.asarray(tensors["w/tok_emb"]),
        "pos_emb": jnp.asarray(tensors["w/pos_emb"]),
        "head": jnp.asarray(tensors["w/head"]),
        "final_norm": jnp.asarray(tensors["w/final_norm"]),
        "layers": [],
    }
    for i in range(cfg.n_layer):
        params["layers"].append(
            {k: jnp.asarray(tensors[f"w/layers.{i}.{k}"])
             for k in ["ln1", "ln2", "wq", "wk", "wv", "wo", "w_up", "w_down"]}
        )
    return cfg, params


def export_model_fwd(name: str, out_dir: str, batch: int) -> str:
    cfg, params = load_model(os.path.join(out_dir, f"model_{name}.nqt"))
    names = [n for n, _ in flatten_names(params, cfg)]

    def fwd(tokens, *flat):
        # rebuild the params pytree from the flat argument list
        p = {
            "tok_emb": flat[0],
            "pos_emb": flat[1],
            "head": flat[2],
            "final_norm": flat[3],
            "layers": [],
        }
        idx = 4
        for _ in range(cfg.n_layer):
            layer = {}
            for key in ["ln1", "ln2", "wq", "wk", "wv", "wo", "w_up", "w_down"]:
                layer[key] = flat[idx]
                idx += 1
            p["layers"].append(layer)
        logits = forward_batch(p, tokens, cfg)
        # flatten: XLA-CPU pads the minor dim of (B,S,V) buffers when V is
        # not register-aligned, which breaks PjRtBuffer→Literal conversion
        # on the rust side; a 1-D result is layout-trivial.
        return (logits.reshape(-1),)

    tok_spec = jax.ShapeDtypeStruct((batch, cfg.ctx), jnp.int32)
    flat_specs = [
        jax.ShapeDtypeStruct(a.shape, a.dtype) for _, a in flatten_names(params, cfg)
    ]
    lowered = jax.jit(fwd).lower(tok_spec, *flat_specs)
    text = to_hlo_text(lowered)
    fname = f"model_fwd_{name}_b{batch}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    print(f"wrote {fname} ({len(text) / 1e6:.2f} MB), args: tokens + {len(names)} params")
    return fname


def export_qmatmul_demo(out_dir: str) -> str:
    """Quantize a small Gaussian matrix with the jnp reference quantizer and
    export the Pallas fused decode-GEMV over it."""
    from .kernels import ref
    from .kernels.qmatmul import qmatmul

    rows, cols, q = 32, 64, 14
    betas = (0.25, 0.32, 0.45, 1.0)
    rng = np.random.default_rng(11)
    w = rng.standard_normal((rows, cols), dtype=np.float32)
    codes = np.zeros((rows, cols), dtype=np.int32)
    beta_idx = np.zeros((rows, cols // 8), dtype=np.int32)
    scales = np.zeros((rows,), dtype=np.float32)
    for r in range(rows):
        c, bi, s = ref.nested_quantize(jnp.asarray(w[r]), q, betas, m_variant=True)
        codes[r] = np.asarray(c)
        beta_idx[r] = np.asarray(bi)
        scales[r] = float(s)

    def fn(codes_, beta_idx_, scales_, x):
        return (qmatmul(codes_, beta_idx_, scales_, x, q=q, betas=betas),)

    specs = [
        jax.ShapeDtypeStruct((rows, cols), jnp.int32),
        jax.ShapeDtypeStruct((rows, cols // 8), jnp.int32),
        jax.ShapeDtypeStruct((rows,), jnp.float32),
        jax.ShapeDtypeStruct((cols,), jnp.float32),
    ]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    fname = "qmatmul_demo.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    # save the quantized matrix so rust can feed identical inputs
    nqt.write(
        os.path.join(out_dir, "qmatmul_demo.nqt"),
        {
            "codes": codes,
            "beta_idx": beta_idx,
            "scales": scales,
            "betas": np.asarray(betas, dtype=np.float32),
            "q": np.asarray([q], dtype=np.int32),
            "w_original": w,
        },
    )
    print(f"wrote {fname} ({len(text) / 1e3:.0f} kB) + qmatmul_demo.nqt")
    return fname


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="tiny,small,base")
    ap.add_argument("--batches", default="1,4")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = ["# artifact -> argument order (rust loader contract)"]
    for name in args.models.split(","):
        cfg, params = load_model(os.path.join(args.out_dir, f"model_{name}.nqt"))
        pnames = ", ".join(n for n, _ in flatten_names(params, cfg))
        for b in [int(x) for x in args.batches.split(",")]:
            fname = export_model_fwd(name.strip(), args.out_dir, b)
            manifest.append(f"{fname}: tokens[i32 {b}x{cfg.ctx}], {pnames}")
    manifest.append("qmatmul_demo.hlo.txt: codes, beta_idx, scales, x")
    export_qmatmul_demo(args.out_dir)
    with open(os.path.join(args.out_dir, "aot_manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    # corpus vocab is part of the contract; stamp it
    print(f"vocab={corpus.VOCAB_SIZE}")


if __name__ == "__main__":
    main()
