"""Deterministic synthetic character-level corpus.

Stands in for wikitext2 (unavailable offline — see DESIGN.md §2): a mixture
of structured pattern families a small LM can learn in a few hundred steps,
with enough entropy that quantization-induced degradation is measurable:

* arithmetic lines       "37+25=62;"
* counting sequences     "7 8 9 10 11."
* PCFG prose             "the red fox chases a small bird."
* bracket nesting        "([{}])" with balanced structure
* key-value records      "k3=v17, k8=v2;"

Everything is generated from a seeded PRNG; train/val splits are disjoint
streams from different seeds.
"""

import random
import string

# Character vocabulary: fixed, independent of the corpus realization.
VOCAB = string.ascii_lowercase + string.digits + " .,;=+-()[]{}<>\n"
VOCAB_SIZE = len(VOCAB)
CHAR_TO_ID = {c: i for i, c in enumerate(VOCAB)}
ID_TO_CHAR = {i: c for i, c in enumerate(VOCAB)}

_NOUNS = "fox bird dog cat tree river stone cloud fish mouse".split()
_ADJS = "red small big old quick dark cold tall wet dry".split()
_VERBS = "chases sees finds follows likes avoids watches guards".split()


def _arith(rng: random.Random) -> str:
    a = rng.randrange(0, 50)
    b = rng.randrange(0, 50)
    return f"{a}+{b}={a + b};"


def _count(rng: random.Random) -> str:
    start = rng.randrange(0, 90)
    k = rng.randrange(3, 7)
    return " ".join(str(start + i) for i in range(k)) + "."


def _prose(rng: random.Random) -> str:
    det1, det2 = rng.choice(["the", "a"]), rng.choice(["the", "a"])
    return (
        f"{det1} {rng.choice(_ADJS)} {rng.choice(_NOUNS)} "
        f"{rng.choice(_VERBS)} {det2} {rng.choice(_ADJS)} {rng.choice(_NOUNS)}."
    )


def _brackets(rng: random.Random, depth: int = 0) -> str:
    if depth > 3 or rng.random() < 0.3:
        return ""
    pairs = [("(", ")"), ("[", "]"), ("{", "}")]
    o, c = rng.choice(pairs)
    inner = _brackets(rng, depth + 1)
    tail = _brackets(rng, depth + 1) if rng.random() < 0.4 else ""
    return o + inner + c + tail


def _record(rng: random.Random) -> str:
    k = rng.randrange(2, 4)
    items = [f"k{rng.randrange(10)}=v{rng.randrange(30)}" for _ in range(k)]
    return ", ".join(items) + ";"


_FAMILIES = [_arith, _count, _prose, _brackets, _record]


def generate(n_chars: int, seed: int) -> str:
    """Generate a corpus of at least n_chars characters."""
    rng = random.Random(seed)
    parts = []
    total = 0
    while total < n_chars:
        fam = rng.choice(_FAMILIES)
        s = fam(rng)
        if not s:
            continue
        s += "\n"
        parts.append(s)
        total += len(s)
    return "".join(parts)[:n_chars]


def encode(text: str) -> list[int]:
    return [CHAR_TO_ID[c] for c in text if c in CHAR_TO_ID]


def decode(ids) -> str:
    return "".join(ID_TO_CHAR[int(i)] for i in ids)


def train_val_tokens(n_train: int, n_val: int, seed: int = 7):
    """Disjoint train/val token streams."""
    train = encode(generate(n_train, seed))
    val = encode(generate(n_val, seed + 1000))
    return train, val


if __name__ == "__main__":
    t, v = train_val_tokens(500, 200)
    print(decode(t[:200]))
    print("---val---")
    print(decode(v[:100]))
