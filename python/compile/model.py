"""Layer-2 JAX model: a small GPT-style causal char-LM.

Pure-functional parameters (nested dicts of jnp arrays) so the same weights
serialize to ``.nqt`` for the rust native engine (which reimplements this
forward bit-for-bit — parity-tested) and AOT-lower to HLO for the PJRT
runtime.

Architecture (mirrored exactly in rust/src/model/forward.rs):
  tok_emb + pos_emb → N × [RMSNorm → MHA (causal) → +res →
                           RMSNorm → MLP (GELU) → +res] → RMSNorm → head

No biases anywhere; untied embedding/head; learned positions.
``forward_qmatmul`` swaps the head matmul for the L1 Pallas kernel to
prove the three layers compose into one HLO artifact.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Config:
    vocab: int = 44
    ctx: int = 128
    d_model: int = 192
    n_layer: int = 4
    n_head: int = 4
    d_ff: int = 512

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head


def init_params(cfg: Config, key):
    """GPT-2-style init: N(0, 0.02), residual projections scaled by 1/√(2L)."""
    keys = jax.random.split(key, 4 + 6 * cfg.n_layer)
    it = iter(range(len(keys)))
    std = 0.02
    resid_std = std / (2.0 * cfg.n_layer) ** 0.5

    def norm(shape, k, s=std):
        return (jax.random.normal(keys[k], shape) * s).astype(jnp.float32)

    p = {
        "tok_emb": norm((cfg.vocab, cfg.d_model), next(it)),
        "pos_emb": norm((cfg.ctx, cfg.d_model), next(it)),
        "head": norm((cfg.vocab, cfg.d_model), next(it)),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": [],
    }
    _ = next(it)
    for _l in range(cfg.n_layer):
        layer = {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            # weights stored (out, in) — rust GEMV convention
            "wq": norm((cfg.d_model, cfg.d_model), next(it)),
            "wk": norm((cfg.d_model, cfg.d_model), next(it)),
            "wv": norm((cfg.d_model, cfg.d_model), next(it)),
            "wo": norm((cfg.d_model, cfg.d_model), next(it), resid_std),
            "w_up": norm((cfg.d_ff, cfg.d_model), next(it)),
            "w_down": norm((cfg.d_model, cfg.d_ff), next(it), resid_std),
        }
        p["layers"].append(layer)
    return p


def rmsnorm(x, g, eps: float = 1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def gelu(x):
    # tanh approximation (matched in rust)
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


def attention(x, layer, cfg: Config):
    """Causal multi-head attention; x (seq, d)."""
    seq = x.shape[0]
    q = x @ layer["wq"].T
    k = x @ layer["wk"].T
    v = x @ layer["wv"].T

    def split(h):
        return h.reshape(seq, cfg.n_head, cfg.d_head).transpose(1, 0, 2)

    qh, kh, vh = split(q), split(k), split(v)  # (heads, seq, dh)
    scores = qh @ kh.transpose(0, 2, 1) / jnp.sqrt(float(cfg.d_head))
    mask = jnp.tril(jnp.ones((seq, seq), bool))
    scores = jnp.where(mask[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = (probs @ vh).transpose(1, 0, 2).reshape(seq, cfg.d_model)
    return out @ layer["wo"].T


def block(x, layer, cfg: Config):
    x = x + attention(rmsnorm(x, layer["ln1"]), layer, cfg)
    h = rmsnorm(x, layer["ln2"])
    h = gelu(h @ layer["w_up"].T) @ layer["w_down"].T
    return x + h


def forward(params, tokens, cfg: Config):
    """tokens (seq,) int32 → logits (seq, vocab)."""
    seq = tokens.shape[0]
    x = params["tok_emb"][tokens] + params["pos_emb"][:seq]
    for layer in params["layers"]:
        x = block(x, layer, cfg)
    x = rmsnorm(x, params["final_norm"])
    return x @ params["head"].T


def forward_batch(params, tokens, cfg: Config):
    """tokens (batch, seq) → logits (batch, seq, vocab)."""
    return jax.vmap(lambda t: forward(params, t, cfg))(tokens)


def loss_fn(params, tokens, cfg: Config):
    """Next-token cross-entropy over a (batch, seq+1) token block."""
    inp = tokens[:, :-1]
    tgt = tokens[:, 1:]
    logits = forward_batch(params, inp, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def forward_qmatmul_head(params_q, tokens, cfg: Config, q: int, betas: tuple):
    """Forward pass whose head matmul runs through the L1 Pallas kernel —
    the three-layer composition demo AOT-exported for the rust runtime.

    params_q: regular params plus quantized head storage
    (head_codes (vocab, d) int32, head_beta (vocab, d/8) int32,
    head_scales (vocab,) f32).
    """
    from .kernels.qmatmul import qmatmul

    seq = tokens.shape[0]
    x = params_q["tok_emb"][tokens] + params_q["pos_emb"][:seq]
    for layer in params_q["layers"]:
        x = block(x, layer, cfg)
    x = rmsnorm(x, params_q["final_norm"])
    logits = jax.vmap(
        lambda xi: qmatmul(
            params_q["head_codes"],
            params_q["head_beta"],
            params_q["head_scales"],
            xi,
            q=q,
            betas=betas,
        )
    )(x)
    return logits


def count_params(params) -> int:
    leaves = jax.tree_util.tree_leaves(params)
    return sum(int(l.size) for l in leaves)


def flatten_names(params, cfg: Config):
    """Deterministic (name, array) list — the .nqt serialization order and
    the argument order of the AOT-exported forward."""
    out = [
        ("tok_emb", params["tok_emb"]),
        ("pos_emb", params["pos_emb"]),
        ("head", params["head"]),
        ("final_norm", params["final_norm"]),
    ]
    for i, layer in enumerate(params["layers"]):
        for key in ["ln1", "ln2", "wq", "wk", "wv", "wo", "w_up", "w_down"]:
            out.append((f"layers.{i}.{key}", layer[key]))
    return out
