//! End-to-end driver (DESIGN.md "e2e"): take the trained char-LM, quantize
//! it with NestQuant at 4 bits in all three regimes, report perplexity
//! against fp32 and the uniform baseline, and validate the serving path.
//!
//! Run: `cargo run --release --example quantize_and_eval [model]`.

use anyhow::Result;
use nestquant::model::engine::{Engine, EngineOptions, Method, Regime};
use nestquant::model::weights::{artifact_path, ModelWeights};
use std::path::PathBuf;

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "base".into());
    let artifacts = PathBuf::from("artifacts");
    let w = ModelWeights::load(&artifact_path(&artifacts, &model))?;
    println!(
        "model '{model}': {} params, ctx {}, vocab {}",
        w.cfg.n_params(),
        w.cfg.ctx,
        w.cfg.vocab
    );

    let fp = nestquant::model::forward::eval_ppl(&w, &w.val_tokens, 8);
    println!("\nfp32 perplexity: {fp:.4}\n");

    println!("{:<46} {:>8} {:>8} {:>10}", "config", "ppl", "Δppl", "bits/entry");
    for (label, method, regime) in [
        ("NestQuant  W      (q=14,k=4)", Method::NestQuant, Regime::W),
        ("NestQuant  W+KV   (q=14,k=4)", Method::NestQuant, Regime::WKv),
        ("NestQuant  W+KV+A (q=14,k=4)", Method::NestQuant, Regime::WKvA),
        ("uniform+rot+LDLQ W+KV+A (4b)", Method::UniformRotLdlq, Regime::WKvA),
        ("RTN        W+KV+A (4b)", Method::Rtn, Regime::WKvA),
    ] {
        let eng = Engine::build(
            &w,
            EngineOptions {
                method,
                regime,
                calib_windows: 2,
                ..Default::default()
            },
        );
        let ppl = eng.eval_ppl(&w.val_tokens, 8);
        println!(
            "{:<46} {:>8.4} {:>+8.4} {:>10.2}",
            label,
            ppl,
            ppl - fp,
            eng.weight_bits_zstd
        );
    }

    // serving sanity: generate with the quantized engine
    let eng = Engine::build(
        &w,
        EngineOptions {
            regime: Regime::WKv,
            calib_windows: 2,
            ..Default::default()
        },
    );
    let mut sess = nestquant::coordinator::GenSession::new(&eng);
    let out = sess.generate(&w.val_tokens[..12].to_vec(), 48);
    const VOCAB: &str = "abcdefghijklmnopqrstuvwxyz0123456789 .,;=+-()[]{}<>\n";
    let text: String = out
        .iter()
        .map(|&t| VOCAB.chars().nth(t as usize).unwrap_or('?'))
        .collect();
    println!("\nsample generation (quantized W+KV): {:?}", text);
    println!(
        "kv cache: {} bytes for {} positions (fp32 would be {})",
        sess.kv_bytes(),
        sess.position(),
        2 * sess.position() * w.cfg.d_model * 4 * w.cfg.n_layer
    );
    Ok(())
}
