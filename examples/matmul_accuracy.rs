//! The paper's core claim on synthetic data (§5.1 / Fig. 3 in miniature):
//! quantized matrix-multiply accuracy of NestQuant vs uniform at equal
//! rate, against the information-theoretic bound Γ(R).
//!
//! Run: `cargo run --release --example matmul_accuracy`.

use nestquant::bounds;
use nestquant::lattice::beta_dp::{default_beta_universe, optimal_betas, BetaTable};
use nestquant::lattice::nested::{NestedLatticeQuantizer, Strategy};
use nestquant::lattice::voronoi::VoronoiCodec;
use nestquant::quant::uniform::UniformQuantizer;
use nestquant::util::{stats, Rng};

fn main() {
    let n = 512;
    let mut rng = Rng::new(7);
    println!("quantized A·Bᵀ accuracy, iid N(0,1) {n}×{n} (paper Fig. 3 point check)\n");

    // DP-optimized βs for q=14, k=4
    let codec = VoronoiCodec::new(14);
    let blocks: Vec<[f32; 8]> = (0..4096)
        .map(|_| {
            let mut b = [0f32; 8];
            rng.fill_gauss(&mut b);
            b
        })
        .collect();
    let table = BetaTable::build(&codec, &blocks, &default_beta_universe(14.0));
    let sel = optimal_betas(&table, 4).expect("beta DP");
    println!("DP-selected βs: {:?} (usage {:?})", sel.betas, sel.usage);
    let nq = NestedLatticeQuantizer::with_codec(codec, sel.betas, Strategy::OptBeta);
    let uq = UniformQuantizer::new(4);

    let a: Vec<Vec<f32>> = (0..n).map(|_| rng.gauss_vec(n)).collect();
    let b: Vec<Vec<f32>> = (0..n).map(|_| rng.gauss_vec(n)).collect();

    let eval = |quant: &dyn Fn(&[f32]) -> Vec<f32>| -> f64 {
        let aq: Vec<Vec<f32>> = a.iter().map(|r| quant(r)).collect();
        let bq: Vec<Vec<f32>> = b.iter().map(|r| quant(r)).collect();
        let mut err = 0f64;
        let mut cnt = 0;
        for i in (0..n).step_by(4) {
            for j in (0..n).step_by(4) {
                let d = stats::dot(&a[i], &b[j]) - stats::dot(&aq[i], &bq[j]);
                err += d * d;
                cnt += 1;
            }
        }
        (err / cnt as f64).sqrt()
    };

    let usage_counts: Vec<u64> = sel.usage.iter().map(|&p| (p * 1e6) as u64).collect();
    let rate_nest = nq.effective_rate(&usage_counts);
    let rmse_nest = eval(&|r| nq.roundtrip(r));
    let rmse_uni = eval(&|r| uq.roundtrip(r));
    let bound = bounds::matmul_rmse_lower_bound(n, 4.0);

    println!("\n{:<34} {:>10} {:>12}", "method", "bits", "RMSE/entry");
    println!("{:<34} {:>10.3} {:>12.4}", "NestQuant q=14 k=4", rate_nest, rmse_nest);
    println!("{:<34} {:>10} {:>12.4}", "uniform 4-bit (cubic shaping)", 4, rmse_uni);
    println!("{:<34} {:>10} {:>12.4}", "Γ(R) lower bound @4b", 4, bound);
    println!(
        "\nNestQuant is {:.2}× above the IT bound; uniform is {:.2}× above.",
        rmse_nest / bound,
        rmse_uni / bound
    );
    assert!(rmse_nest < rmse_uni, "NestQuant must beat uniform at equal rate");
}
