//! Serving demo: the coordinator (router → batcher → continuous-batching
//! generation worker) over the NestQuant W+KV engine, reporting
//! latency/throughput and quantized-KV memory — the paper's serving
//! motivation (§1, goals 2–3). Prints the per-phase latency percentiles
//! (queue wait / TTFT / inter-token / prefill / fused step) and writes
//! the run's trace journal to `serve_demo_trace.json`, loadable in
//! <https://ui.perfetto.dev>.
//!
//! Run: `cargo run --release --example serve_demo [model] [n_requests]`.

use anyhow::Result;
use nestquant::coordinator::{BatchPolicy, Request, Server, ServerConfig};
use nestquant::model::engine::{Engine, EngineOptions, Regime};
use nestquant::model::weights::{artifact_path, ModelWeights};
use std::path::PathBuf;
use std::sync::Arc;

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "tiny".into());
    let n_req: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let artifacts = PathBuf::from("artifacts");
    let w = ModelWeights::load(&artifact_path(&artifacts, &model))?;
    println!("serving '{model}' with NestQuant W+KV (quantized KV cache)");

    let eng = Arc::new(Engine::build(
        &w,
        EngineOptions {
            regime: Regime::WKv,
            calib_windows: 2,
            ..Default::default()
        },
    ));
    let (srv, rx) = Server::start(
        eng,
        ServerConfig {
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(3),
            },
            ..Default::default()
        },
    );

    let t0 = std::time::Instant::now();
    for i in 0..n_req {
        let start = (i * 53) % (w.val_tokens.len() - 64);
        srv.submit(Request::Generate {
            id: i as u64,
            prompt: w.val_tokens[start..start + 16].to_vec(),
            n_new: 32,
        })?;
        // also interleave scoring traffic
        if i % 3 == 0 {
            srv.submit(Request::Score {
                id: 1000 + i as u64,
                window: w.val_tokens[start..start + w.cfg.ctx + 1].to_vec(),
            })?;
        }
    }
    // a deliberately malformed request: a one-token score window has no
    // (context, target) pair. It is rejected with a typed error on its
    // Response — the server keeps serving everyone else.
    srv.submit(Request::Score {
        id: 9999,
        window: w.val_tokens[..1].to_vec(),
    })?;
    let total = n_req + n_req.div_ceil(3) + 1;
    let mut nlls = Vec::new();
    let mut rejected = 0;
    for _ in 0..total {
        let r = rx.recv()?;
        if let Some(e) = &r.error {
            println!("request {} rejected: {e}", r.id);
            rejected += 1;
            continue;
        }
        if let Some(nll) = r.nll {
            nlls.push(nll);
        }
    }
    println!(
        "completed {} requests in {:.2}s ({rejected} rejected up front)",
        total - rejected,
        t0.elapsed().as_secs_f64()
    );
    println!("{}", srv.metrics.report());
    if let Some(p) = srv.metrics.pool_stats() {
        println!(
            "kv pool: {} pages live, {} cached for prefix reuse, hit rate {:.1}%",
            p.pages_in_use,
            p.cached_pages,
            100.0 * p.prefix_hit_rate()
        );
    }
    if !nlls.is_empty() {
        let mean = nlls.iter().sum::<f64>() / nlls.len() as f64;
        println!("scored windows: mean nll {mean:.4} (ppl {:.3})", mean.exp());
    }
    let m = &srv.metrics;
    println!(
        "latency percentiles:\n  queue wait  {}\n  ttft        {}\n  inter-token {}\n  \
         prefill     {}\n  fused step  {}",
        m.queue_wait_summary().render(),
        m.ttft_summary().render(),
        m.inter_token_summary().render(),
        m.prefill_summary().render(),
        m.fused_step_summary().render()
    );
    let trace = srv.trace.clone();
    let report = srv.shutdown();
    if !report.drained {
        println!("shutdown timed out: {} request(s) undrained", report.undrained);
    }
    let json = nestquant::obs::chrome_trace_json(&trace.snapshot());
    std::fs::write("serve_demo_trace.json", json)?;
    println!(
        "trace: serve_demo_trace.json ({} events, {} dropped) — open in ui.perfetto.dev",
        trace.len(),
        trace.dropped()
    );
    Ok(())
}
