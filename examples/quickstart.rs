//! Quickstart: the full three-layer composition in one binary.
//!
//! 1. Quantize a vector / matrix with the rust lattice engine (L3).
//! 2. Load the Pallas fused decode-GEMV HLO artifact (L1, AOT-lowered by
//!    python) through the PJRT runtime and check it against the rust
//!    decoder on identical coded inputs.
//! 3. Load the trained char-LM forward artifact (L2) and check its logits
//!    against the native rust forward.
//! 4. Build a mixed-KV `QuantPlan` (fp32 / uniform / nested lanes per
//!    layer) on a synthetic model and generate through the paged pool —
//!    the public API covers heterogeneous KV serving end-to-end.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use anyhow::{Context, Result};
use nestquant::io::tensorfile::{find, read_tensors};
use nestquant::lattice::nested::NestedLatticeQuantizer;
use nestquant::model::weights::ModelWeights;
use nestquant::quant::matrix::QuantizedMatrix;
use nestquant::runtime::{ModelRunner, Runtime};
use nestquant::util::{stats, Rng};
use std::path::PathBuf;

fn main() -> Result<()> {
    let artifacts = PathBuf::from("artifacts");

    // --- 1. the quantization primitive (pure rust) ---
    println!("== L3: nested-lattice quantization primitive ==");
    let nq = NestedLatticeQuantizer::new_m(14, vec![0.25, 0.32, 0.45, 1.0]);
    let mut rng = Rng::new(1);
    let a = rng.gauss_vec(256);
    let b = rng.gauss_vec(256);
    let qa = nq.quantize(&a);
    let qb = nq.quantize(&b);
    println!(
        "  quantized 256-dim vectors at {:.2} bits/entry (raw rate)",
        nq.raw_rate()
    );
    println!(
        "  inner product: exact {:+.3}, via Algorithm 4 {:+.3}",
        stats::dot(&a, &b),
        nq.dot(&qa, &qb)
    );
    println!(
        "  roundtrip RMSE: {:.4}",
        stats::rmse(&a, &nq.roundtrip(&a))
    );

    // --- 2. the Pallas kernel artifact through PJRT ---
    println!("\n== L1: Pallas fused decode-GEMV via PJRT ==");
    let rt = Runtime::cpu()?;
    println!("  PJRT platform: {}", rt.platform());
    let demo = read_tensors(&artifacts.join("qmatmul_demo.nqt"))
        .context("run `make artifacts` first")?;
    let codes_t = find(&demo, "codes")?;
    let (rows, cols) = (codes_t.dims[0], codes_t.dims[1]);
    let codes: Vec<i32> = match &codes_t.data {
        nestquant::io::tensorfile::TensorData::I32(v) => v.clone(),
        _ => anyhow::bail!("codes dtype"),
    };
    let beta_idx: Vec<i32> = match &find(&demo, "beta_idx")?.data {
        nestquant::io::tensorfile::TensorData::I32(v) => v.clone(),
        _ => anyhow::bail!("beta_idx dtype"),
    };
    let scales = find(&demo, "scales")?.as_f32()?.to_vec();
    let betas = find(&demo, "betas")?.as_f32()?.to_vec();
    let x = Rng::new(2).gauss_vec(cols);

    let exe = rt.load_hlo(&artifacts.join("qmatmul_demo.hlo.txt"))?;
    let lits = vec![
        rt.lit_i32(&codes, &[rows, cols])?,
        rt.lit_i32(&beta_idx, &[rows, cols / 8])?,
        rt.lit_f32(&scales, &[rows])?,
        rt.lit_f32(&x, &[cols])?,
    ];
    let y_pallas = exe.run(&lits)?;

    // rust-side reference: decode the same codes and do the same GEMV
    let nq_demo =
        NestedLatticeQuantizer::new_m(14, betas.clone());
    let qm = QuantizedMatrix {
        rows,
        cols,
        q: 14,
        codes: codes.iter().map(|&c| c as u8).collect(),
        beta_idx: beta_idx.iter().map(|&b| b as u8).collect(),
        scales,
    };
    let y_rust = qm.qgemv(&nq_demo, &x);
    let err = stats::rmse(&y_pallas, &y_rust);
    println!("  pallas-vs-rust GEMV RMSE: {err:.2e} over {rows} outputs");
    anyhow::ensure!(err < 1e-4, "pallas and rust decoders disagree");
    println!("  ✓ L1 kernel (AOT) and L3 decoder agree bit-for-bit");

    // --- 3. the model forward artifact ---
    println!("\n== L2: char-LM forward via PJRT vs native rust ==");
    let w = ModelWeights::load(&artifacts.join("model_tiny.nqt"))?;
    let runner = ModelRunner::load(&artifacts, "tiny", 1, &w)?;
    let toks: Vec<i32> = w.val_tokens[..w.cfg.ctx].to_vec();
    let logits_hlo = runner.forward(&toks)?;
    let logits_native = nestquant::model::forward::forward_window(&w, &toks);
    let err = stats::rmse(&logits_hlo, &logits_native.data);
    println!(
        "  HLO-vs-native logits RMSE: {err:.2e} over {} values",
        logits_hlo.len()
    );
    anyhow::ensure!(err < 1e-3, "HLO and native forward disagree");
    println!("  ✓ L2 artifact and the native engine agree");

    // --- 4. heterogeneous KV lanes: a mixed plan served from one pool ---
    println!("\n== L4: mixed-KV QuantPlan through the paged pool ==");
    use nestquant::coordinator::generator::GenSession;
    use nestquant::kvpool::PoolConfig;
    use nestquant::model::engine::{Engine, EngineOptions, Method, Regime};
    use nestquant::quant::plan::{PolicyPatch, QuantPlan, SiteRole, SiteSelector};
    let synth = ModelWeights::synthetic(
        nestquant::model::ModelConfig {
            vocab: 48,
            ctx: 64,
            d_model: 32,
            n_layer: 3,
            n_head: 2,
            d_ff: 64,
        },
        0x9C0DE,
    );
    // layer 0 keeps fp32 KV, layer 1 uniform 4-bit, layer 2 nested —
    // one plan, one pool, three lane codecs
    let mut plan = QuantPlan::uniform(EngineOptions {
        method: Method::NestQuantM,
        regime: Regime::WKv,
        calib_windows: 1,
        ..Default::default()
    });
    let kv = |lo: usize, hi: usize| SiteSelector {
        layers: Some((lo, hi)),
        role: Some(SiteRole::Kv),
        ..Default::default()
    };
    plan.rules.push((kv(0, 0), PolicyPatch::fp()));
    plan.rules.push((
        kv(1, 1),
        PolicyPatch {
            method: Some(Method::UniformRot),
            ..Default::default()
        },
    ));
    let eng = Engine::build_plan(&synth, plan);
    let pool = eng.kv_pool(PoolConfig::default());
    let mut sess = GenSession::new_in_pool(&eng, &pool);
    let out = sess.generate(&[1, 2, 3, 4, 5, 6, 7, 8], 24);
    anyhow::ensure!(out.len() == 24, "mixed-KV generation fell short");
    let st = pool.stats();
    let [fp_b, uni_b, nest_b] = st.bytes_in_use_split();
    println!(
        "  generated {} tokens; pool: {} pages, {} B (fp {fp_b} / uni {uni_b} / nest {nest_b})",
        out.len(),
        st.pages_in_use,
        st.bytes_in_use
    );
    anyhow::ensure!(
        fp_b > 0 && uni_b > 0 && nest_b > 0,
        "every lane codec should hold bytes in a mixed plan"
    );
    println!("  ✓ L4 mixed-KV plan serves end-to-end through one paged pool");

    println!("\nAll layers compose. Next: examples/quantize_and_eval.rs");
    Ok(())
}
