//! LLM experiments over the trained char-LMs (Fig. 1/8, Tables 1/2/3/6/7/
//! 8/9). Perplexity is evaluated over non-overlapping ctx-length windows
//! of the held-out synthetic-corpus split.

use crate::io::results::{fmt, MdTable, ResultsDoc};
use crate::model::engine::{Engine, EngineOptions, Method, Regime, RotKind};
use crate::model::weights::{artifact_path, ModelWeights};
use anyhow::{Context, Result};
use std::path::Path;

const EVAL_WINDOWS: usize = 8;

fn load(artifacts: &Path, name: &str) -> Result<ModelWeights> {
    ModelWeights::load(&artifact_path(artifacts, name))
        .with_context(|| format!("load model '{name}' — run `make artifacts` first"))
}

fn ppl_of(w: &ModelWeights, opts: EngineOptions) -> (f64, f64, f64) {
    let eng = Engine::build(w, opts);
    (
        eng.eval_ppl(&w.val_tokens, EVAL_WINDOWS),
        eng.weight_bits_zstd,
        eng.weight_bits_packed,
    )
}

/// Fig. 1 + Table 3: ppl and bits/entry vs q ∈ {8,10,12,14} for the three
/// regimes (NestQuant, k=4).
pub fn fig1_tab3_rate_sweep(artifacts: &Path, results: &Path, model: &str) -> Result<()> {
    let w = load(artifacts, model)?;
    let fp = crate::model::forward::eval_ppl(&w, &w.val_tokens, EVAL_WINDOWS);
    let mut doc = ResultsDoc::new(
        results,
        "fig1_tab3",
        &format!("ppl vs rate, 3 regimes (model={model}, k=4)"),
    );
    doc.para(&format!("fp32 perplexity: **{fp:.3}** (paper: 6.139 for Llama-3-8B)"));
    let mut t = MdTable::new(&["q", "Bits (zstd)", "Bits (no zstd)", "W", "W+KV", "W+KV+A"]);
    let mut series = Vec::new();
    for q in [14u32, 12, 10, 8] {
        let mut row = vec![q.to_string()];
        let mut bits_z = 0.0;
        let mut bits_p = 0.0;
        let mut ppls = Vec::new();
        for regime in [Regime::W, Regime::WKv, Regime::WKvA] {
            let (ppl, bz, bp) = ppl_of(
                &w,
                EngineOptions {
                    q,
                    regime,
                    calib_windows: 2,
                    ..Default::default()
                },
            );
            bits_z = bz;
            bits_p = bp;
            ppls.push(ppl);
            println!("  q={q} {}: ppl={ppl:.4}", regime.label());
        }
        row.push(fmt(bits_z));
        row.push(fmt(bits_p));
        for p in &ppls {
            row.push(fmt(*p));
        }
        t.row(&row);
        series.push(vec![bits_z, ppls[0], ppls[1], ppls[2]]);
    }
    doc.table(&t);
    doc.series("fig1", &["bits", "ppl_W", "ppl_WKV", "ppl_WKVA"], &series);
    doc.para(
        "Paper Table 3 shape: monotone ppl increase as q decreases; the \
         W+KV+A column degrades fastest. Paper Fig. 1 shape: three nested \
         curves with W lowest.",
    );
    doc.write()
}

/// Fig. 8: ppl-vs-bitrate scaling for k ∈ {3,4,5,8} (full quantization).
pub fn fig8_k_sweep(artifacts: &Path, results: &Path, model: &str) -> Result<()> {
    let w = load(artifacts, model)?;
    let mut doc = ResultsDoc::new(
        results,
        "fig8",
        &format!("ppl-vs-bitrate for k ∈ {{3,4,5,8}} (model={model}, W+KV+A)"),
    );
    let mut rows = Vec::new();
    for k in [3usize, 4, 5, 8] {
        for q in [8u32, 10, 12, 14] {
            let (ppl, bits, _) = ppl_of(
                &w,
                EngineOptions {
                    q,
                    k,
                    regime: Regime::WKvA,
                    calib_windows: 2,
                    ..Default::default()
                },
            );
            println!("  k={k} q={q}: bits={bits:.3} ppl={ppl:.4}");
            rows.push(vec![k as f64, q as f64, bits, ppl]);
        }
    }
    doc.series("fig8", &["k", "q", "bits", "ppl"], &rows);
    doc.para("Paper Fig. 8 shape: k=3 strictly worse; k ∈ {4,5,8} comparable.");
    doc.write()
}

/// Table 1: 4-bit quantization across regimes + task-suite evals
/// (synthetic stand-ins for the zero-shot benchmarks, DESIGN.md §2).
pub fn tab1_benchmarks(artifacts: &Path, results: &Path, model: &str) -> Result<()> {
    let w = load(artifacts, model)?;
    let mut doc = ResultsDoc::new(
        results,
        "tab1",
        &format!("4-bit quantization of the {model} char-LM (q=14, k=4)"),
    );
    let mut t = MdTable::new(&[
        "Config",
        "Bits",
        "Bits (no zstd)",
        "Arith ↑",
        "Count ↑",
        "Bracket ↑",
        "Avg ↑",
        "ppl ↓",
    ]);

    let run = |label: &str,
               opts: Option<EngineOptions>,
               t: &mut MdTable|
     -> Result<()> {
        let (engine, bits_z, bits_p): (Option<Engine>, f64, f64) = match opts {
            None => (None, 32.0, 32.0),
            Some(o) => {
                let e = Engine::build(&w, o);
                let (z, p) = (e.weight_bits_zstd, e.weight_bits_packed);
                (Some(e), z, p)
            }
        };
        let ppl = match &engine {
            None => crate::model::forward::eval_ppl(&w, &w.val_tokens, EVAL_WINDOWS),
            Some(e) => e.eval_ppl(&w.val_tokens, EVAL_WINDOWS),
        };
        let (a, c, b) = task_suite(&w, engine.as_ref());
        println!("  {label}: ppl={ppl:.4} arith={a:.2} count={c:.2} bracket={b:.2}");
        t.row(&[
            label.into(),
            fmt(bits_z),
            fmt(bits_p),
            fmt(a),
            fmt(c),
            fmt(b),
            fmt((a + c + b) / 3.0),
            fmt(ppl),
        ]);
        Ok(())
    };

    run("Baseline (FP32)", None, &mut t)?;
    for (label, method, regime) in [
        ("SpinQuant-style W", Method::UniformRotLdlq, Regime::W),
        ("NestQuant W", Method::NestQuant, Regime::W),
        ("SpinQuant-style W+KV", Method::UniformRotLdlq, Regime::WKv),
        ("NestQuant W+KV", Method::NestQuant, Regime::WKv),
        ("SpinQuant-style W+KV+A", Method::UniformRotLdlq, Regime::WKvA),
        ("NestQuant W+KV+A", Method::NestQuant, Regime::WKvA),
    ] {
        run(
            label,
            Some(EngineOptions {
                method,
                regime,
                calib_windows: 2,
                ..Default::default()
            }),
            &mut t,
        )?;
    }
    doc.table(&t);
    doc.para(
        "Task suite stands in for ARC/Hellaswag/PIQA/Winogrande (no public \
         benchmarks offline — DESIGN.md §2): Arith = exact-match on 'a+b=' \
         completions; Count = next-number continuation; Bracket = closing \
         bracket validity. Paper Table 1 shape: NestQuant ≥ uniform baselines \
         at every regime, smallest ppl gap to fp.",
    );
    doc.write()
}

/// Greedy-decoding task accuracies on the synthetic-corpus families.
fn task_suite(w: &ModelWeights, eng: Option<&Engine>) -> (f64, f64, f64) {
    use crate::coordinator::generator::GenSession;
    // build a default fp engine if none given (GenSession needs one)
    let fp_holder;
    let eng = match eng {
        Some(e) => e,
        None => {
            fp_holder = Engine::build(
                w,
                EngineOptions {
                    regime: Regime::Fp,
                    ..Default::default()
                },
            );
            &fp_holder
        }
    };
    let encode = |s: &str| -> Vec<i32> {
        const VOCAB: &str = "abcdefghijklmnopqrstuvwxyz0123456789 .,;=+-()[]{}<>\n";
        s.chars()
            .map(|c| VOCAB.find(c).expect("char in vocab") as i32)
            .collect()
    };
    let decode_ch = |t: i32| -> char {
        const VOCAB: &str = "abcdefghijklmnopqrstuvwxyz0123456789 .,;=+-()[]{}<>\n";
        VOCAB.chars().nth(t as usize).unwrap_or('?')
    };
    let mut rng = crate::util::Rng::new(4242);

    // Arithmetic: "a+b=" → must produce the right sum then ';'
    let mut arith_ok = 0;
    let n_arith = 20;
    for _ in 0..n_arith {
        let a = rng.below(50);
        let b = rng.below(50);
        let prompt = format!("\n{a}+{b}=");
        let expect = format!("{}", a + b);
        let mut sess = GenSession::new(eng);
        let out = sess.generate(&encode(&prompt), expect.len() + 1);
        let got: String = out.iter().map(|&t| decode_ch(t)).collect();
        if got.starts_with(&expect) {
            arith_ok += 1;
        }
    }

    // Counting: "7 8 9 " → next number
    let mut count_ok = 0;
    let n_count = 20;
    for _ in 0..n_count {
        let s = rng.below(80);
        let prompt = format!("\n{} {} {} ", s, s + 1, s + 2);
        let expect = format!("{}", s + 3);
        let mut sess = GenSession::new(eng);
        let out = sess.generate(&encode(&prompt), expect.len());
        let got: String = out.iter().map(|&t| decode_ch(t)).collect();
        if got == expect {
            count_ok += 1;
        }
    }

    // Brackets: prompt with open brackets → first generated char closes
    let mut br_ok = 0;
    let cases = ["\n([", "\n{(", "\n[[", "\n((", "\n{["];
    for c in cases {
        let close = match c.chars().last().unwrap() {
            '(' => ')',
            '[' => ']',
            _ => '}',
        };
        let mut sess = GenSession::new(eng);
        let out = sess.generate(&encode(c), 3);
        let got: String = out.iter().map(|&t| decode_ch(t)).collect();
        if got.contains(close) {
            br_ok += 1;
        }
    }
    (
        arith_ok as f64 / n_arith as f64,
        count_ok as f64 / n_count as f64,
        br_ok as f64 / cases.len() as f64,
    )
}

/// Table 2: methods × model sizes (W4 and full W4A4KV4).
pub fn tab2_methods_by_size(artifacts: &Path, results: &Path) -> Result<()> {
    let mut doc = ResultsDoc::new(results, "tab2", "wikitext2-analog ppl by method and size");
    let models = ["tiny", "small", "base"];
    let mut t = MdTable::new(&["Bits (W-A-KV)", "Method", "tiny", "small", "base"]);

    // fp row
    let mut fp_row = vec!["16-16-16".to_string(), "Floating point".to_string()];
    for m in models {
        let w = load(artifacts, m)?;
        fp_row.push(fmt(crate::model::forward::eval_ppl(&w, &w.val_tokens, EVAL_WINDOWS)));
    }
    t.row(&fp_row);

    // the rotating methods, in `Method::ALL` order (the canonical
    // parse/label table) — plain RTN is covered by Table 1
    for (bits, regime) in [("4-16-16", Regime::W), ("4-4-4", Regime::WKvA)] {
        for method in Method::ALL.into_iter().filter(|m| m.rotates()) {
            let mut row = vec![bits.to_string(), method.label().to_string()];
            for m in models {
                let w = load(artifacts, m)?;
                let (ppl, _, _) = ppl_of(
                    &w,
                    EngineOptions {
                        method,
                        regime,
                        calib_windows: 2,
                        ..Default::default()
                    },
                );
                println!("  {bits} {} {m}: {ppl:.4}", method.label());
                row.push(fmt(ppl));
            }
            t.row(&row);
        }
    }
    doc.table(&t);
    doc.para(
        "Paper Table 2 shape: NestQuant lowest in every column; NestQuantM \
         slightly above NestQuant; full quantization (4-4-4) costs more for \
         uniform methods than for NestQuant.",
    );
    doc.write()
}

/// Table 6: LDLQ ablation (q=14, k=4).
pub fn tab6_ldlq_ablation(artifacts: &Path, results: &Path, model: &str) -> Result<()> {
    let w = load(artifacts, model)?;
    let mut doc = ResultsDoc::new(results, "tab6", "LDLQ ablation (q=14, k=4)");
    let mut t = MdTable::new(&["Algorithm", "W", "W+KV", "W+KV+A"]);
    for (label, ldlq) in [("NestQuant", true), ("NestQuant (no LDLQ)", false)] {
        let mut row = vec![label.to_string()];
        for regime in [Regime::W, Regime::WKv, Regime::WKvA] {
            let (ppl, _, _) = ppl_of(
                &w,
                EngineOptions {
                    ldlq,
                    qa_ldlq: ldlq,
                    regime,
                    calib_windows: 2,
                    ..Default::default()
                },
            );
            println!("  {label} {}: {ppl:.4}", regime.label());
            row.push(fmt(ppl));
        }
        t.row(&row);
    }
    doc.table(&t);
    doc.para("Paper Table 6 shape: LDLQ helps in all three regimes.");
    doc.write()
}

/// Table 7: rotation ablation (W+KV+A, q=14, k=4).
pub fn tab7_rotation_ablation(artifacts: &Path, results: &Path, model: &str) -> Result<()> {
    let w = load(artifacts, model)?;
    let mut doc = ResultsDoc::new(results, "tab7", "rotation ablation (W+KV+A, q=14, k=4)");
    let mut t = MdTable::new(&["Rotation", "W+KV+A ppl"]);
    for (label, kind) in [
        ("Fourier", RotKind::Fourier),
        ("S ⊗ H (random orth ⊗ Sylvester)", RotKind::RandOrthKron),
        ("H₁ ⊗ H (Paley ⊗ Sylvester)", RotKind::Hadamard),
    ] {
        let (ppl, _, _) = ppl_of(
            &w,
            EngineOptions {
                rot_kind: kind,
                regime: Regime::WKvA,
                calib_windows: 2,
                ..Default::default()
            },
        );
        println!("  {label}: {ppl:.4}");
        t.row(&[label.into(), fmt(ppl)]);
    }
    doc.table(&t);
    doc.para("Paper Table 7: Hadamard-based rotations edge out Fourier.");
    doc.write()
}

/// Table 8 (App. I): the smaller model's q sweep.
pub fn tab8_small_model_sweep(artifacts: &Path, results: &Path, model: &str) -> Result<()> {
    let w = load(artifacts, model)?;
    let fp = crate::model::forward::eval_ppl(&w, &w.val_tokens, EVAL_WINDOWS);
    let mut doc = ResultsDoc::new(
        results,
        "tab8",
        &format!("rate sweep for the smaller model ({model}; App. I analog)"),
    );
    doc.para(&format!("fp32 ppl: **{fp:.3}** (paper: 9.749 for Llama-3.2-1B)"));
    let mut t = MdTable::new(&["q", "Bits", "Bits (no zstd)", "W", "W+KV", "W+KV+A"]);
    for q in [14u32, 12, 10, 8] {
        let mut row = vec![q.to_string()];
        let mut bits = (0.0, 0.0);
        let mut ppls = Vec::new();
        for regime in [Regime::W, Regime::WKv, Regime::WKvA] {
            let (ppl, bz, bp) = ppl_of(
                &w,
                EngineOptions {
                    q,
                    regime,
                    calib_windows: 2,
                    ..Default::default()
                },
            );
            bits = (bz, bp);
            ppls.push(ppl);
        }
        println!("  q={q}: {:?}", ppls);
        row.push(fmt(bits.0));
        row.push(fmt(bits.1));
        for p in ppls {
            row.push(fmt(p));
        }
        t.row(&row);
    }
    doc.table(&t);
    doc.para("Paper Table 8 shape: smaller models degrade faster at low q.");
    doc.write()
}

/// Appendix J: 3-bit quantization (q=7, k=4).
pub fn tab9_3bit(artifacts: &Path, results: &Path) -> Result<()> {
    let mut doc = ResultsDoc::new(results, "tab9", "3-bit quantization (q=7, k=4; App. J)");
    let mut t = MdTable::new(&["Bits (W-A-KV)", "Method", "tiny", "base"]);
    let mut fp_row = vec!["16-16-16".into(), "Floating point".to_string()];
    let mut r4 = vec!["4-4-16*".into(), "NestQuant q=14".to_string()];
    let mut r3 = vec!["3-3-16*".into(), "NestQuant q=7".to_string()];
    for m in ["tiny", "base"] {
        let w = load(artifacts, m)?;
        fp_row.push(fmt(crate::model::forward::eval_ppl(&w, &w.val_tokens, EVAL_WINDOWS)));
        for (q, row) in [(14u32, &mut r4), (7u32, &mut r3)] {
            let (ppl, _, _) = ppl_of(
                &w,
                EngineOptions {
                    q,
                    regime: Regime::WKvA,
                    calib_windows: 2,
                    ..Default::default()
                },
            );
            println!("  {m} q={q}: {ppl:.4}");
            row.push(fmt(ppl));
        }
    }
    t.row(&fp_row);
    t.row(&r4);
    t.row(&r3);
    doc.table(&t);
    doc.para(
        "*KV also quantized here (our engine couples A and KV in the WKvA \
         regime). Paper App. J shape: 3-bit degrades gracefully for the \
         larger model, severely for the small one.",
    );
    doc.write()
}
