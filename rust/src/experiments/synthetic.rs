//! Synthetic-data experiments (no model needed): Figs. 2/3/5/6/7 and
//! Tables 4/5.

use crate::bounds;
use crate::io::results::{fmt, MdTable, ResultsDoc};
use crate::lattice::beta_dp::{default_beta_universe, optimal_betas, BetaTable};
use crate::lattice::e8::D;
use crate::lattice::hex::shaping_waste_2d;
use crate::lattice::nested::{NestedLatticeQuantizer, Strategy};
use crate::lattice::voronoi::VoronoiCodec;
use crate::quant::qgemm::PackedNestMatrix;
use crate::quant::uniform::{PackedInt4Matrix, UniformQuantizer};
use crate::util::bench::bench;
use crate::util::linalg::Mat;
use crate::util::{stats, Rng};
use anyhow::Result;
use std::path::Path;
use std::time::Duration;

fn gaussian_blocks(n: usize, seed: u64) -> Vec<[f32; D]> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut b = [0f32; D];
            rng.fill_gauss(&mut b);
            b
        })
        .collect()
}

/// Fig. 2: fraction of codebook wasted outside the typical circle —
/// uniform/cubic vs nested hexagonal shaping in 2-D.
pub fn fig2_shaping_2d(results: &Path) -> Result<()> {
    let mut doc = ResultsDoc::new(results, "fig2", "2-D shaping waste (uniform vs nested hex)");
    doc.para(
        "Paper Fig. 2 quotes ~32% (uniform) vs ~15% (hex) wasted bitstrings; \
         with the circumscribing construction used here the asymptotes are \
         1−π/4 ≈ 21.5% vs 1−π/(2√3) ≈ 9.3%. The reproduced quantity is the \
         ~2.2× waste ratio.",
    );
    let mut t = MdTable::new(&["q (rate=log2 q)", "uniform waste", "hex waste", "ratio"]);
    for q in [8u32, 16, 32, 64, 128] {
        let (u, h) = shaping_waste_2d(q);
        t.row(&[q.to_string(), fmt(u), fmt(h), fmt(u / h.max(1e-9))]);
    }
    doc.table(&t);
    doc.write()
}

/// Fig. 3: RMSE of quantized matmul vs bits/entry — NestQuant (β-optimized)
/// vs uniform (cubic shaping) vs the Γ(R) lower bound.
pub fn fig3_matmul_rmse(results: &Path) -> Result<()> {
    let n = 256; // paper: 4096; scaled for 1 vCPU (shape-preserving: RMSE ∝ √n)
    let trials = 4;
    let mut doc = ResultsDoc::new(results, "fig3", "quantized matmul RMSE vs rate");
    doc.para(&format!(
        "iid N(0,1) {n}×{n} matrices (paper uses 4096; per-entry RMSE scales \
         as √(n·Γ(R)) so the curves are shape-identical). NestQuant βs are \
         DP-optimized per q with k=4."
    ));
    let mut rows: Vec<Vec<f64>> = Vec::new();

    // helper: RMSE of A·Bᵀ per entry under a vector-quantizer roundtrip
    let matmul_rmse = |quant: &dyn Fn(&[f32], &mut Rng) -> Vec<f32>, seed: u64| -> f64 {
        let mut rng = Rng::new(seed);
        let mut err = 0f64;
        let mut cnt = 0usize;
        for _ in 0..trials {
            let a: Vec<Vec<f32>> = (0..n).map(|_| rng.gauss_vec(n)).collect();
            let b: Vec<Vec<f32>> = (0..n).map(|_| rng.gauss_vec(n)).collect();
            let aq: Vec<Vec<f32>> = a.iter().map(|r| quant(r, &mut rng)).collect();
            let bq: Vec<Vec<f32>> = b.iter().map(|r| quant(r, &mut rng)).collect();
            // sample a subset of output entries for speed
            for i in (0..n).step_by(8) {
                for j in (0..n).step_by(8) {
                    let exact = stats::dot(&a[i], &b[j]);
                    let approx = stats::dot(&aq[i], &bq[j]);
                    err += (exact - approx) * (exact - approx);
                    cnt += 1;
                }
            }
        }
        (err / cnt as f64).sqrt()
    };

    // NestQuant frontier over q (k=4, DP βs tuned on Gaussian blocks)
    for q in [3u32, 4, 6, 8, 10, 12, 14, 16] {
        let codec = VoronoiCodec::new(q);
        let blocks = gaussian_blocks(4096, 42 + q as u64);
        let table = BetaTable::build(&codec, &blocks, &default_beta_universe(q as f32));
        let sel = optimal_betas(&table, 4).expect("beta selection");
        let nq = NestedLatticeQuantizer::with_codec(
            codec,
            sel.betas.clone(),
            Strategy::OptBeta,
        );
        // effective rate: log2 q + H(β)/8 (entropy-coded side info, §5.1)
        let usage_counts: Vec<u64> = sel
            .usage
            .iter()
            .map(|&p| (p * 1e6) as u64)
            .collect();
        let rate = nq.effective_rate(&usage_counts);
        let rmse = matmul_rmse(&|r, _| nq.roundtrip(r), 1000 + q as u64);
        let bound = bounds::matmul_rmse_lower_bound(n, rate);
        rows.push(vec![rate, rmse, f64::NAN, bound]);
        println!("  nestquant q={q}: rate={rate:.3} rmse={rmse:.4} (bound {bound:.4})");
    }
    // uniform (cubic shaping) frontier
    for bits in [2u32, 3, 4, 5, 6] {
        let uq = UniformQuantizer::new(bits);
        let rmse = matmul_rmse(&|r, _| uq.roundtrip(r), 2000 + bits as u64);
        let bound = bounds::matmul_rmse_lower_bound(n, bits as f64);
        rows.push(vec![bits as f64, f64::NAN, rmse, bound]);
        println!("  uniform {bits}b: rmse={rmse:.4} (bound {bound:.4})");
    }
    doc.series(
        "fig3",
        &["bits_per_entry", "nestquant_rmse", "uniform_rmse", "gamma_bound"],
        &rows,
    );
    doc.para(
        "Shape check (paper Fig. 3): NestQuant tracks the Γ(R) bound within a \
         small factor and clearly beats uniform/cubic at equal rate.",
    );
    doc.write()
}

/// Fig. 5: complement Gaussian mass of cube / E8-Voronoi / ball at equal
/// volume in 8-D.
pub fn fig5_gaussian_mass(results: &Path) -> Result<()> {
    let mut doc = ResultsDoc::new(results, "fig5", "Gaussian mass of shaping bodies (8-D)");
    let mut rows = Vec::new();
    for i in 0..20 {
        let scale = 1.0 + 0.1 * i as f64; // region volume = scale^8
        let r_ball = scale * bounds::r_eff_unit_volume(8);
        let ball = 1.0 - bounds::gaussian_mass_ball(8, r_ball);
        let cube = 1.0 - bounds::gaussian_mass_cube(8, scale * 0.5);
        let voronoi = 1.0 - bounds::gaussian_mass_e8_voronoi(scale, 60_000, 500 + i);
        rows.push(vec![scale, cube, voronoi, ball]);
    }
    doc.series(
        "fig5",
        &["scale", "cube_complement", "e8_voronoi_complement", "ball_complement"],
        &rows,
    );
    doc.para(
        "Paper Fig. 5: μ(rV_E8) hugs μ(rB); the cube needs a much larger \
         volume for the same coverage (the cubic-shaping loss).",
    );
    doc.write()
}

/// Fig. 6: QA-LDLQ tradeoff on a synthetic high-amplification layer.
pub fn fig6_qaldlq_tradeoff(results: &Path) -> Result<()> {
    use crate::quant::qaldlq::*;
    let (w, x) = synthetic_high_amplification_layer(32, 64, 16, 40.0, 600);
    let h = crate::quant::ldlq::hessian_from_activations(&x, 1e-4);
    let base = amplification_ratio(&w, &x, 1);
    let mut doc = ResultsDoc::new(results, "fig6", "QA-LDLQ amplification-ratio tradeoff");
    doc.para(&format!(
        "Synthetic pathological layer (paper: Llama-3-70B block-0 v_proj, \
         ratio ≈157; ours: {base:.1}). Sweeping ε² as in Fig. 6."
    ));
    let mut rows = Vec::new();
    for i in 0..12 {
        let eps2 = 10f32.powf(-5.0 + 0.5 * i as f32);
        let wt = modified_weight(&w, &h, eps2);
        let ratio = amplification_ratio(&wt, &x, 1);
        let r2 = one_minus_r2(&w, &wt, &x);
        rows.push(vec![eps2 as f64, r2, ratio]);
    }
    doc.series("fig6", &["eps2", "one_minus_r2", "amplification_ratio"], &rows);
    doc.para("Paper Fig. 6 shape: a small 1−R² price buys a large ratio drop.");
    doc.write()
}

/// Fig. 7: granular vs overload error vs β at q=16.
pub fn fig7_granular_overload(results: &Path) -> Result<()> {
    let codec = VoronoiCodec::new(16);
    let blocks = gaussian_blocks(20_000, 700);
    let mut doc = ResultsDoc::new(results, "fig7", "granular and overload error vs β (q=16)");
    let mut rows = Vec::new();
    for i in 1..=40 {
        let beta = 0.02 * i as f32;
        let mut granular = stats::Welford::new();
        let mut overload = stats::Welford::new();
        let mut p_overload = 0f64;
        for b in &blocks {
            let mut xs = [0f32; D];
            for j in 0..D {
                xs[j] = b[j] / beta;
            }
            let (r, ov) = codec.encode_decode(&xs);
            let mut err = 0f64;
            for j in 0..D {
                let d = (r[j] * beta - b[j]) as f64;
                err += d * d;
            }
            if ov {
                overload.push(err);
                p_overload += 1.0;
            } else {
                granular.push(err);
            }
        }
        p_overload /= blocks.len() as f64;
        rows.push(vec![
            beta as f64,
            granular.mean(),
            if overload.count() > 0 { overload.mean() } else { f64::NAN },
            p_overload,
        ]);
    }
    doc.series(
        "fig7",
        &["beta", "granular_mse", "overload_mse", "p_overload"],
        &rows,
    );
    doc.para(
        "Paper Fig. 7: granular error grows ∝β², overload error shrinks as β \
         grows — the tension the multi-β union resolves.",
    );
    doc.write()
}

/// Table 5: Opt-β vs First-β RMSE for k ∈ {2,4,6,8,10}, q=16,
/// βs uniform on [0, 10].
pub fn tab5_opt_vs_first_beta(results: &Path) -> Result<()> {
    let blocks = gaussian_blocks(30_000, 800);
    let mut doc = ResultsDoc::new(results, "tab5", "Opt-β vs First-β (q=16)");
    let mut t = MdTable::new(&["k", "Opt-β RMSE", "First-β RMSE"]);
    for k in [2usize, 4, 6, 8, 10] {
        let betas: Vec<f32> = (1..=k).map(|i| 10.0 * i as f32 / k as f32 / 16.0).collect();
        // paper: βs "uniform on [0,10]" in lattice-scaled units (β·q)
        let opt = NestedLatticeQuantizer::with_codec(
            VoronoiCodec::new(16),
            betas.clone(),
            Strategy::OptBeta,
        );
        let first = NestedLatticeQuantizer::with_codec(
            VoronoiCodec::new(16),
            betas,
            Strategy::FirstBeta,
        );
        let eval = |nq: &NestedLatticeQuantizer| -> f64 {
            let mut err = 0f64;
            for b in &blocks {
                let (_, _, recon, _) = nq.quantize_block(b);
                for j in 0..D {
                    err += ((recon[j] - b[j]) as f64).powi(2);
                }
            }
            (err / (blocks.len() * D) as f64).sqrt()
        };
        t.row(&[k.to_string(), fmt(eval(&opt)), fmt(eval(&first))]);
    }
    doc.table(&t);
    doc.para("Paper Table 5: the two strategies are within a few percent (≈0.071 at k=6).");
    doc.write()
}

/// Table 4: GEMV runtime — fp32 vs NestQuantM packed (4.25b) vs int4
/// uniform, on an n×n matrix.
pub fn tab4_gemv_runtime(results: &Path) -> Result<()> {
    let n = 4096; // paper: 8192 on A100; scaled (out-of-cache → memory-bound regime)
    let mut rng = Rng::new(900);
    let w = Mat::from_vec(n, n, rng.gauss_vec(n * n));
    let x = rng.gauss_vec(n);
    let budget = Duration::from_millis(1500);

    let nq = NestedLatticeQuantizer::new_m(14, vec![0.25, 0.32, 0.45, 1.0]);
    let packed = PackedNestMatrix::quantize(&w, &nq);
    let int4 = PackedInt4Matrix::quantize(&w);
    let wt = w.transpose();

    let mut y = vec![0f32; n];
    let r_fp = bench("fp32 GEMV", budget, || {
        // y = W·x with the same row-major access pattern
        for r in 0..n {
            let mut acc = 0f32;
            let row = &w.data[r * n..(r + 1) * n];
            for i in 0..n {
                acc += row[i] * x[i];
            }
            y[r] = acc;
        }
        y[0]
    });
    let _ = &wt;
    let mut y2 = vec![0f32; n];
    let r_nest = bench("NestQuantM GEMV (4.25b packed)", budget, || {
        packed.gemv_into(&x, &mut y2);
        y2[0]
    });
    let mut y3 = vec![0f32; n];
    let r_int4 = bench("int4 uniform GEMV", budget, || {
        // allocation-free comparator (a per-call Vec skews the table)
        int4.gemv_into(&x, &mut y3);
        y3[0]
    });
    // batch-amortized integer GEMM: decode each 8-block once for a
    // 32-column activation panel (single-threaded, per-column time)
    let batch = 32;
    let xt = {
        let mut rng = Rng::new(0x7AB4);
        Mat::from_vec(batch, n, rng.gauss_vec(batch * n))
    };
    let mut yt = Mat::zeros(batch, n);
    let mut scratch = crate::quant::gemm::GemmScratch::new();
    let r_gemm = bench("NestQuantM GEMM b=32 t=1", budget, || {
        packed.gemm_into(&xt, &mut yt, 1, &mut scratch);
        yt.data[0]
    });

    let mut doc = ResultsDoc::new(results, "tab4", "GEMV runtime (n=4096, 1 CPU core)");
    let mut t = MdTable::new(&["Method", "bits/entry", "time (µs)", "payload MiB", "vs fp32"]);
    let fp_us = r_fp.median_us();
    t.row(&[
        "Baseline (fp32)".into(),
        "32".into(),
        fmt(fp_us),
        fmt((n * n * 4) as f64 / (1 << 20) as f64),
        "1.00×".into(),
    ]);
    t.row(&[
        "NestQuantM (ours)".into(),
        fmt(packed.bits_per_entry()),
        fmt(r_nest.median_us()),
        fmt(packed.payload_bytes() as f64 / (1 << 20) as f64),
        format!("{:.2}×", fp_us / r_nest.median_us()),
    ]);
    t.row(&[
        "int4 uniform".into(),
        "4".into(),
        fmt(r_int4.median_us()),
        fmt(int4.payload_bytes() as f64 / (1 << 20) as f64),
        format!("{:.2}×", fp_us / r_int4.median_us()),
    ]);
    let gemm_per_col = r_gemm.median_us() / batch as f64;
    t.row(&[
        "NestQuantM GEMM (per col, b=32)".into(),
        fmt(packed.bits_per_entry()),
        fmt(gemm_per_col),
        fmt(packed.payload_bytes() as f64 / (1 << 20) as f64),
        format!("{:.2}×", fp_us / gemm_per_col),
    ]);
    doc.table(&t);
    doc.para(
        "Paper Table 4 (8192², A100): fp16 97µs / NestQuantM 60µs / int4 31µs. \
         Reproduced quantity: the ordering int4 < NestQuantM < fp and the \
         memory-traffic ratios; absolute µs differ (CPU vs A100). The GEMM \
         row amortizes the 8-block decode over a 32-column activation panel \
         (quant::gemm), the engine's prefill configuration.",
    );
    println!("{}", r_fp.report());
    println!("{}", r_nest.report());
    println!("{}", r_int4.report());
    println!("{}", r_gemm.report());
    doc.write()
}
