//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (see DESIGN.md §4 for the index). Each experiment writes a
//! markdown/TSV artifact to `results/<id>.md`; EXPERIMENTS.md records
//! paper-vs-measured.

pub mod llm;
pub mod synthetic;

use anyhow::Result;
use std::path::Path;

/// Run one experiment by id ("fig3", "tab5", …) or "all".
pub fn run(id: &str, artifacts: &Path, results: &Path) -> Result<()> {
    let all = id == "all";
    let mut ran = false;
    macro_rules! exp {
        ($name:literal, $f:expr) => {
            if all || id == $name {
                println!("=== {} ===", $name);
                $f?;
                ran = true;
            }
        };
    }
    exp!("fig2", synthetic::fig2_shaping_2d(results));
    exp!("fig3", synthetic::fig3_matmul_rmse(results));
    exp!("fig5", synthetic::fig5_gaussian_mass(results));
    exp!("fig6", synthetic::fig6_qaldlq_tradeoff(results));
    exp!("fig7", synthetic::fig7_granular_overload(results));
    exp!("tab5", synthetic::tab5_opt_vs_first_beta(results));
    exp!("tab4", synthetic::tab4_gemv_runtime(results));
    exp!("fig1", llm::fig1_tab3_rate_sweep(artifacts, results, "base"));
    exp!("fig8", llm::fig8_k_sweep(artifacts, results, "small"));
    exp!("tab1", llm::tab1_benchmarks(artifacts, results, "base"));
    exp!("tab2", llm::tab2_methods_by_size(artifacts, results));
    exp!("tab6", llm::tab6_ldlq_ablation(artifacts, results, "base"));
    exp!("tab7", llm::tab7_rotation_ablation(artifacts, results, "base"));
    exp!("tab8", llm::tab8_small_model_sweep(artifacts, results, "tiny"));
    exp!("tab9", llm::tab9_3bit(artifacts, results));
    anyhow::ensure!(ran, "unknown experiment id '{id}'");
    Ok(())
}
