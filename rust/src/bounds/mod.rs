//! Information-theoretic reference curves (paper §2.1).
//!
//! * `d_of_r` — the Gaussian rate–distortion function D(R) = 2^(−2R).
//! * `gamma` — the inner-product (matrix-multiplication) lower bound Γ(R)
//!   of eq. (1)–(2), including the transcendental fixed point R* ≈ 0.906.
//! * Gaussian measures of shaping bodies (Fig. 5): Euclidean ball (χ²
//!   closed form), cube (erf^d), and the E8 Voronoi region (Monte Carlo
//!   against the exact closest-point oracle).

use crate::lattice::e8::{nearest_e8, D as D8};
use crate::util::Rng;

/// Gaussian rate–distortion function D(R) = 2^(−2R) (per dimension).
pub fn d_of_r(r: f64) -> f64 {
    2f64.powf(-2.0 * r)
}

/// The high-rate branch of Γ: g(R) = 2·2^(−2R) − 2^(−4R).
fn gamma_high(r: f64) -> f64 {
    let a = 2f64.powf(-2.0 * r);
    2.0 * a - a * a
}

fn gamma_high_deriv(r: f64) -> f64 {
    // d/dR [2·2^(−2R) − 2^(−4R)] = ln2 · (−4·2^(−2R) + 4·2^(−4R))
    let ln2 = std::f64::consts::LN_2;
    ln2 * (-4.0 * 2f64.powf(-2.0 * r) + 4.0 * 2f64.powf(-4.0 * r))
}

/// R* solves the tangency condition: the chord from (0, 1) to
/// (R*, g(R*)) has slope g'(R*), i.e. (g(R*) − 1)/R* = g'(R*).
pub fn r_star() -> f64 {
    let f = |r: f64| (gamma_high(r) - 1.0) / r - gamma_high_deriv(r);
    // f is continuous on (0, 3); bisect.
    let (mut lo, mut hi) = (0.2f64, 3.0f64);
    assert!(f(lo) * f(hi) < 0.0, "no sign change for R*");
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if f(lo) * f(mid) <= 0.0 {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Γ(R), eq. (2): linear (time-sharing) segment below R*, curve above.
pub fn gamma(r: f64) -> f64 {
    assert!(r >= 0.0);
    let rs = r_star();
    if r < rs {
        1.0 - (1.0 - gamma_high(rs)) * r / rs
    } else {
        gamma_high(r)
    }
}

/// Lower bound on RMSE per entry of an n×n · n×n quantized matrix product
/// with iid N(0,1) entries at rate R (from eq. (1): E(X·Y − est)² ≥ nΓ(R),
/// per-entry RMSE = √(n·Γ(R))).
pub fn matmul_rmse_lower_bound(n: usize, r: f64) -> f64 {
    ((n as f64) * gamma(r)).sqrt()
}

// ---------------------------------------------------------------------------
// Special functions (no external crates available offline).

/// Error function, Abramowitz & Stegun 7.1.26 refinement via the
/// regularized incomplete gamma: erf(x) = P(1/2, x²).
pub fn erf(x: f64) -> f64 {
    if x < 0.0 {
        -erf(-x)
    } else {
        lower_inc_gamma_reg(0.5, x * x)
    }
}

/// Standard normal CDF.
pub fn phi(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Regularized lower incomplete gamma P(a, x) (series for x < a+1,
/// continued fraction otherwise). Standard Numerical-Recipes scheme.
pub fn lower_inc_gamma_reg(a: f64, x: f64) -> f64 {
    assert!(a > 0.0 && x >= 0.0);
    if x == 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        // series
        let mut ap = a;
        let mut sum = 1.0 / a;
        let mut del = sum;
        for _ in 0..500 {
            ap += 1.0;
            del *= x / ap;
            sum += del;
            if del.abs() < sum.abs() * 1e-15 {
                break;
            }
        }
        sum * (-x + a * x.ln() - ln_gamma(a)).exp()
    } else {
        // continued fraction for Q(a,x), P = 1 − Q
        let mut b = x + 1.0 - a;
        let mut c = 1e300;
        let mut d = 1.0 / b;
        let mut h = d;
        for i in 1..500 {
            let an = -(i as f64) * (i as f64 - a);
            b += 2.0;
            d = an * d + b;
            if d.abs() < 1e-300 {
                d = 1e-300;
            }
            c = b + an / c;
            if c.abs() < 1e-300 {
                c = 1e-300;
            }
            d = 1.0 / d;
            let del = d * c;
            h *= del;
            if (del - 1.0).abs() < 1e-15 {
                break;
            }
        }
        1.0 - (-x + a * x.ln() - ln_gamma(a)).exp() * h
    }
}

/// ln Γ(x), Lanczos approximation (g=7, n=9).
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

// ---------------------------------------------------------------------------
// Gaussian masses of shaping regions (Fig. 5).

/// μ(r·B) for the d-dim Euclidean ball of radius r: χ²_d CDF at r².
pub fn gaussian_mass_ball(d: usize, r: f64) -> f64 {
    lower_inc_gamma_reg(d as f64 / 2.0, r * r / 2.0)
}

/// μ(r·CUBE) for the centered cube [−r, r]^d: (2Φ(r) − 1)^d.
pub fn gaussian_mass_cube(d: usize, r: f64) -> f64 {
    (2.0 * phi(r) - 1.0).powi(d as i32)
}

/// Radius of the unit-volume d-ball, r_eff(1).
pub fn r_eff_unit_volume(d: usize) -> f64 {
    // vol = π^{d/2} r^d / Γ(d/2+1) = 1 → r = (Γ(d/2+1))^{1/d} / √π
    (ln_gamma(d as f64 / 2.0 + 1.0) / d as f64).exp() / std::f64::consts::PI.sqrt()
}

/// μ(r·V_E8): Monte-Carlo estimate of the Gaussian mass of the scaled E8
/// Voronoi region (x ∈ rV ⇔ Q_{E8}(x/r) = 0). E8 has unit covolume, so
/// vol(rV_E8) = vol(rB) with B the unit-volume ball — exactly the Fig. 5
/// comparison.
pub fn gaussian_mass_e8_voronoi(r: f64, samples: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut inside = 0usize;
    let mut x = [0f32; D8];
    for _ in 0..samples {
        for v in x.iter_mut() {
            *v = (rng.gauss() / r) as f32;
        }
        if nearest_e8(&x) == [0f32; D8] {
            inside += 1;
        }
    }
    inside as f64 / samples as f64
}

/// Cube side scaled to unit volume in d dims (half-side 0.5) — the cubic
/// shaping comparator at equal volume.
pub fn gaussian_mass_unit_cube_scaled(d: usize, r: f64) -> f64 {
    gaussian_mass_cube(d, r * 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn r_star_matches_paper() {
        let rs = r_star();
        assert!((rs - 0.906).abs() < 0.01, "R*={rs}, paper says ≈0.906");
    }

    #[test]
    fn gamma_properties() {
        // Γ(0) = 1 (no information → error = E(XᵀY)² variance n·1)
        assert!((gamma(0.0) - 1.0).abs() < 1e-12);
        // continuous at R*
        let rs = r_star();
        assert!((gamma(rs - 1e-9) - gamma(rs + 1e-9)).abs() < 1e-6);
        // decreasing
        let mut last = gamma(0.0);
        for i in 1..50 {
            let g = gamma(i as f64 * 0.1);
            assert!(g < last);
            last = g;
        }
        // high-rate: Γ(R) ≈ 2·2^(−2R) = 2·D(R)
        assert!((gamma(6.0) / (2.0 * d_of_r(6.0)) - 1.0).abs() < 0.01);
    }

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1e-15);
        assert!((erf(1.0) - 0.842_700_792_949_714_9).abs() < 1e-10);
        assert!((erf(2.0) - 0.995_322_265_018_952_7).abs() < 1e-10);
        assert!((erf(-1.0) + 0.842_700_792_949_714_9).abs() < 1e-10);
    }

    #[test]
    fn ln_gamma_known_values() {
        assert!((ln_gamma(1.0)).abs() < 1e-12);
        assert!((ln_gamma(2.0)).abs() < 1e-12);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
    }

    #[test]
    fn chi2_cdf_sanity() {
        // χ²_2 CDF(x) = 1 − e^{−x/2}
        for x in [0.5f64, 1.0, 3.0, 7.0] {
            let p = lower_inc_gamma_reg(1.0, x / 2.0);
            let expect = 1.0 - (-x / 2.0).exp();
            assert!((p - expect).abs() < 1e-10, "x={x}: {p} vs {expect}");
        }
    }

    #[test]
    fn ball_mass_dominates_cube_mass_at_equal_volume() {
        // Fig. 5's message: at equal volume the ball captures more
        // Gaussian mass than the cube in d=8.
        let d = 8;
        for scale in [1.5f64, 2.0, 2.5] {
            let r = scale * r_eff_unit_volume(d);
            let ball = gaussian_mass_ball(d, r);
            // cube of the same volume: side = scale (unit-volume cube side 1)
            let cube = gaussian_mass_cube(d, scale * 0.5);
            assert!(
                ball > cube,
                "scale {scale}: ball {ball} ≤ cube {cube}"
            );
        }
    }

    #[test]
    fn e8_voronoi_mass_close_to_ball_mass() {
        // Fig. 5: μ(rV_E8) ≈ μ(rB) (equal volumes, E8 is nearly spherical).
        let d = 8;
        for scale in [1.8f64, 2.2] {
            let r_ball = scale * r_eff_unit_volume(d);
            let ball = gaussian_mass_ball(d, r_ball);
            let voronoi = gaussian_mass_e8_voronoi(scale, 40_000, 801);
            assert!(
                (ball - voronoi).abs() < 0.05,
                "scale {scale}: ball {ball} vs E8 {voronoi}"
            );
            // and both clearly above the cube
            let cube = gaussian_mass_cube(d, scale * 0.5);
            assert!(voronoi > cube);
        }
    }

    #[test]
    fn matmul_bound_scales_with_sqrt_n() {
        let a = matmul_rmse_lower_bound(64, 4.0);
        let b = matmul_rmse_lower_bound(256, 4.0);
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}
