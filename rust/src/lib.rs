//! # NestQuant — nested lattice quantization for matrix products and LLMs
//!
//! Full-system reproduction of *NestQuant: nested lattice quantization for
//! matrix products and LLMs* (Savkin, Porat, Ordentlich, Polyanskiy; ICML
//! 2025).
//!
//! The crate is organised as the Layer-3 (rust) part of a three-layer stack:
//!
//! * [`lattice`] — the Gosset (E8) lattice engine: closest-point oracle
//!   (paper Alg. 5), Voronoi-code encode/decode (Alg. 1/2), the multi-β
//!   union-of-codebooks quantizer (Alg. 3), quantized dot products (Alg. 4)
//!   and the dynamic program for optimal β selection (Alg. 6 / Appendix F).
//! * [`rotation`] — randomized Hadamard / Kronecker rotations (Section 4.3).
//! * [`quant`] — matrix/vector quantization on top of the lattice engine,
//!   quantized GEMV/GEMM, the uniform scalar baseline (SpinQuant-style),
//!   LDLQ and QA-LDLQ weight quantization (Section 4.5 / Appendix B),
//!   and the per-site quantization policy API (`quant::plan`: `SiteId →
//!   SitePolicy` resolution, the `EngineBuilder`, the `.qplan` format).
//! * [`bounds`] — information-theoretic limits: the rate–distortion function
//!   `D(R)` and the matrix-multiplication lower bound `Γ(R)` of eq. (1)-(2).
//! * [`model`] — a small GPT-style transformer (config, tensors, forward
//!   pass) used as the end-to-end evaluation target.
//! * [`kvpool`] — the paged KV pool, the sole KV backend: heterogeneous
//!   per-layer lane codecs (fp32 / uniform / nested), page slab
//!   allocator, per-session page tables with copy-on-write, token-prefix
//!   sharing index, LRU eviction under a byte budget (multi-session
//!   serving). `SessionKv` is the per-session view.
//! * [`runtime`] — PJRT (xla crate) wrapper loading AOT-compiled HLO
//!   artifacts produced by the Layer-2 JAX model. Gated behind the `xla`
//!   cargo feature: the xla crate + PJRT CPU plugin are only present on
//!   hosts provisioned with the AOT toolchain.
//! * [`coordinator`] — serving coordinator: request router, dynamic
//!   batcher, prefill/decode scheduler, metrics.
//! * [`obs`] — observability: bounded ring-buffer request tracing,
//!   HDR-style latency histograms, Chrome-trace (Perfetto) and
//!   Prometheus exporters threaded through the serving path.
//! * [`io`] — tensor file format + zstd/entropy coding of β side-information.
//! * [`util`] — RNG, statistics, a small property-testing and benching
//!   harness (criterion/proptest are unavailable offline).

pub mod bounds;
pub mod coordinator;
pub mod experiments;
pub mod io;
pub mod kvpool;
pub mod lattice;
pub mod model;
pub mod obs;
pub mod quant;
pub mod rotation;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod util;
