//! I/O: the `.nqt` tensor container (python ↔ rust interchange), zstd /
//! entropy coding of β side information (the Tables 1/3 "Bits" columns),
//! and the markdown results writer used by the experiment harness.

pub mod results;
pub mod sideinfo;
pub mod tensorfile;

pub use sideinfo::{beta_bits_entropy, beta_bits_packed, beta_bits_zstd};
pub use tensorfile::{read_tensors, write_tensors, Tensor};
