//! I/O: the `.nqt` tensor container (python ↔ rust interchange), zstd /
//! entropy coding of β side information (the Tables 1/3 "Bits" columns),
//! and the markdown results writer used by the experiment harness.
//!
//! Tensor reads fail with a typed [`TensorFileError`] naming the file
//! and the corrupt field — corrupt artifacts become friendly CLI
//! messages, never panics.

#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod results;
pub mod sideinfo;
pub mod tensorfile;

pub use sideinfo::{beta_bits_entropy, beta_bits_packed, beta_bits_zstd};
pub use tensorfile::{read_tensors, write_tensors, Tensor, TensorFileError};
