//! Markdown results writer: every experiment regenerating a paper
//! table/figure emits its rows to `results/<id>.md` through this module,
//! so `EXPERIMENTS.md` can reference stable artifacts.

use anyhow::Result;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// A markdown table under construction.
pub struct MdTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl MdTable {
    pub fn new(header: &[&str]) -> Self {
        MdTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "| {} |", self.header.join(" | "));
        let _ = writeln!(
            s,
            "|{}|",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }
}

/// A results document (one per experiment id).
pub struct ResultsDoc {
    path: PathBuf,
    body: String,
}

impl ResultsDoc {
    pub fn new(results_dir: &Path, id: &str, title: &str) -> Self {
        let mut body = String::new();
        let _ = writeln!(body, "# {id}: {title}\n");
        ResultsDoc {
            path: results_dir.join(format!("{id}.md")),
            body,
        }
    }

    pub fn para(&mut self, text: &str) -> &mut Self {
        let _ = writeln!(self.body, "{text}\n");
        self
    }

    pub fn table(&mut self, t: &MdTable) -> &mut Self {
        let _ = writeln!(self.body, "{}", t.render());
        self
    }

    /// TSV series block for figure-like outputs (plottable).
    pub fn series(&mut self, name: &str, header: &[&str], rows: &[Vec<f64>]) -> &mut Self {
        let _ = writeln!(self.body, "```tsv {name}");
        let _ = writeln!(self.body, "{}", header.join("\t"));
        for r in rows {
            let cells: Vec<String> = r.iter().map(|v| format!("{v:.6}")).collect();
            let _ = writeln!(self.body, "{}", cells.join("\t"));
        }
        let _ = writeln!(self.body, "```\n");
        self
    }

    pub fn write(&self) -> Result<()> {
        if let Some(parent) = self.path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&self.path, &self.body)?;
        println!("wrote {}", self.path.display());
        Ok(())
    }

    pub fn body(&self) -> &str {
        &self.body
    }
}

/// Format a float with a sensible width for tables.
pub fn fmt(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else if v.abs() >= 1.0 {
        format!("{v:.3}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_markdown() {
        let mut t = MdTable::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("| a | b |"));
        assert!(s.contains("|---|---|"));
        assert!(s.contains("| 1 | 2 |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = MdTable::new(&["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn doc_writes_to_disk() {
        let dir = std::env::temp_dir().join("nqt_results_test");
        let mut doc = ResultsDoc::new(&dir, "test", "Test doc");
        doc.para("hello");
        doc.series("s", &["x", "y"], &[vec![1.0, 2.0]]);
        doc.write().unwrap();
        let back = std::fs::read_to_string(dir.join("test.md")).unwrap();
        assert!(back.contains("hello"));
        assert!(back.contains("1.000000\t2.000000"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fmt_widths() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(1234.5), "1234.5");
        assert_eq!(fmt(3.14159), "3.142");
        assert_eq!(fmt(0.01234), "0.0123");
    }
}
