//! β side-information accounting (paper Tables 1/3): the per-block β
//! indices are compressed three ways —
//!
//! * `beta_bits_packed`  — raw ⌈log2 k⌉-bit packing ("Bits (no zstd)")
//! * `beta_bits_zstd`    — actual zstd-compressed size ("Bits"; the paper
//!   uses zstd/nvcomp for exactly this stream)
//! * `beta_bits_entropy` — the H(β) information-theoretic floor (§5.1)

/// Bits for raw fixed-width packing of β indices (k values).
pub fn beta_bits_packed(beta_idx: &[u8], k: usize) -> f64 {
    let bits = (k as f64).log2().ceil().max(1.0);
    beta_idx.len() as f64 * bits
}

/// Bits after zstd compression of the β index byte stream (level 19 —
/// offline weight compression; decode cost is irrelevant at load time).
pub fn beta_bits_zstd(beta_idx: &[u8]) -> f64 {
    if beta_idx.is_empty() {
        return 0.0;
    }
    // Pack 4 indices/byte first (k ≤ 4): zstd then squeezes the packed
    // stream further, matching the paper's pipeline.
    let mut packed = vec![0u8; beta_idx.len().div_ceil(4)];
    for (i, &b) in beta_idx.iter().enumerate() {
        packed[i / 4] |= (b & 0x3) << (2 * (i % 4));
    }
    // in-memory compression of a buffer we just built: the only failure
    // mode is allocator exhaustion, which is unrecoverable anyway
    let compressed = match zstd::bulk::compress(&packed, 19) {
        Ok(c) => c,
        Err(e) => panic!("zstd compress of in-memory β stream failed: {e}"),
    };
    (compressed.len() as f64 * 8.0).min(beta_idx.len() as f64 * 2.0)
}

/// Empirical-entropy bits of the β index stream.
pub fn beta_bits_entropy(beta_idx: &[u8]) -> f64 {
    if beta_idx.is_empty() {
        return 0.0;
    }
    let mut counts = [0u64; 256];
    for &b in beta_idx {
        counts[b as usize] += 1;
    }
    let h = crate::util::stats::entropy_bits(&counts);
    h * beta_idx.len() as f64
}

/// Effective bits/entry for a quantized matrix: code bits + β bits / 8
/// entries per block (+ per-row scale amortized).
pub fn bits_per_entry(
    q: u32,
    n_entries: usize,
    beta_bits: f64,
    n_scales: usize,
) -> f64 {
    let code_bits = (q as f64).log2() * n_entries as f64;
    (code_bits + beta_bits + 32.0 * n_scales as f64) / n_entries as f64
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn packed_is_2_bits_for_k4() {
        let idx = vec![0u8, 1, 2, 3, 0, 1];
        assert_eq!(beta_bits_packed(&idx, 4), 12.0);
    }

    #[test]
    fn zstd_beats_packed_on_skewed_stream() {
        // Heavily skewed β usage (the real-world case: most blocks use the
        // smallest β) compresses well below 2 bits/block.
        let mut rng = Rng::new(1501);
        let idx: Vec<u8> = (0..20_000)
            .map(|_| {
                let r = rng.f64();
                if r < 0.85 {
                    0
                } else if r < 0.95 {
                    1
                } else if r < 0.99 {
                    2
                } else {
                    3
                }
            })
            .collect();
        let packed = beta_bits_packed(&idx, 4);
        let z = beta_bits_zstd(&idx);
        let ent = beta_bits_entropy(&idx);
        assert!(z < packed, "zstd {z} not below packed {packed}");
        // zstd should approach the entropy floor within ~30%
        assert!(z < ent * 1.4, "zstd {z} too far above entropy {ent}");
        assert!(ent < packed);
    }

    #[test]
    fn zstd_never_reported_above_packed() {
        // Uniform (incompressible) stream: reported bits capped at packed.
        let mut rng = Rng::new(1502);
        let idx: Vec<u8> = (0..4096).map(|_| rng.below(4) as u8).collect();
        let z = beta_bits_zstd(&idx);
        assert!(z <= beta_bits_packed(&idx, 4) + 1e-9);
    }

    #[test]
    fn bits_per_entry_accounting() {
        // q=14, 1024 entries, 128 blocks × 2 bits, 1 scale
        let b = bits_per_entry(14, 1024, 256.0, 1);
        let expect = (14f64.log2() * 1024.0 + 256.0 + 32.0) / 1024.0;
        assert!((b - expect).abs() < 1e-12);
        // ≈ 3.81 + 0.25 + 0.03 ≈ 4.09
        assert!(b > 4.0 && b < 4.2);
    }

    #[test]
    fn empty_streams() {
        assert_eq!(beta_bits_zstd(&[]), 0.0);
        assert_eq!(beta_bits_entropy(&[]), 0.0);
    }
}
