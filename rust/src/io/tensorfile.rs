//! `.nqt` — a minimal self-describing tensor container shared between the
//! python build layer (numpy) and the rust runtime. Little-endian:
//!
//! ```text
//! magic  b"NQT1"
//! u32    tensor count
//! per tensor:
//!   u16      name length, then name bytes (utf-8)
//!   u8       dtype (0 = f32, 1 = u8, 2 = i32)
//!   u8       ndim
//!   u64×ndim dims
//!   bytes    row-major data
//! ```

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    U8(Vec<u8>),
    I32(Vec<i32>),
}

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(name: &str, dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor {
            name: name.to_string(),
            dims,
            data: TensorData::F32(data),
        }
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor {} is not f32", self.name),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match &self.data {
            TensorData::U8(v) => Ok(v),
            _ => bail!("tensor {} is not u8", self.name),
        }
    }
}

pub fn write_tensors(path: &Path, tensors: &[Tensor]) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
    );
    f.write_all(b"NQT1")?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for t in tensors {
        let name = t.name.as_bytes();
        f.write_all(&(name.len() as u16).to_le_bytes())?;
        f.write_all(name)?;
        let (dtype, nbytes) = match &t.data {
            TensorData::F32(v) => (0u8, v.len() * 4),
            TensorData::U8(v) => (1u8, v.len()),
            TensorData::I32(v) => (2u8, v.len() * 4),
        };
        let _ = nbytes;
        f.write_all(&[dtype, t.dims.len() as u8])?;
        for &d in &t.dims {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::U8(v) => f.write_all(v)?,
            TensorData::I32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

pub fn read_tensors(path: &Path) -> Result<Vec<Tensor>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != b"NQT1" {
        bail!("{path:?}: bad magic {magic:?}");
    }
    let mut buf4 = [0u8; 4];
    f.read_exact(&mut buf4)?;
    let count = u32::from_le_bytes(buf4) as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let mut buf2 = [0u8; 2];
        f.read_exact(&mut buf2)?;
        let name_len = u16::from_le_bytes(buf2) as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name not utf-8")?;
        let mut hdr = [0u8; 2];
        f.read_exact(&mut hdr)?;
        let (dtype, ndim) = (hdr[0], hdr[1] as usize);
        let mut dims = Vec::with_capacity(ndim);
        let mut buf8 = [0u8; 8];
        for _ in 0..ndim {
            f.read_exact(&mut buf8)?;
            dims.push(u64::from_le_bytes(buf8) as usize);
        }
        let numel: usize = dims.iter().product();
        let data = match dtype {
            0 => {
                let mut bytes = vec![0u8; numel * 4];
                f.read_exact(&mut bytes)?;
                TensorData::F32(
                    bytes
                        .chunks_exact(4)
                        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect(),
                )
            }
            1 => {
                let mut bytes = vec![0u8; numel];
                f.read_exact(&mut bytes)?;
                TensorData::U8(bytes)
            }
            2 => {
                let mut bytes = vec![0u8; numel * 4];
                f.read_exact(&mut bytes)?;
                TensorData::I32(
                    bytes
                        .chunks_exact(4)
                        .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect(),
                )
            }
            d => bail!("unknown dtype {d}"),
        };
        out.push(Tensor { name, dims, data });
    }
    Ok(out)
}

/// Find a tensor by name.
pub fn find<'a>(tensors: &'a [Tensor], name: &str) -> Result<&'a Tensor> {
    tensors
        .iter()
        .find(|t| t.name == name)
        .with_context(|| format!("tensor '{name}' not found"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_all_dtypes() {
        let mut rng = Rng::new(1401);
        let tensors = vec![
            Tensor::f32("weights/w0", vec![4, 8], rng.gauss_vec(32)),
            Tensor {
                name: "codes".into(),
                dims: vec![16],
                data: TensorData::U8((0..16u8).collect()),
            },
            Tensor {
                name: "meta/config".into(),
                dims: vec![3],
                data: TensorData::I32(vec![-1, 0, 42]),
            },
        ];
        let dir = std::env::temp_dir().join("nqt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.nqt");
        write_tensors(&path, &tensors).unwrap();
        let back = read_tensors(&path).unwrap();
        assert_eq!(tensors, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("nqt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.nqt");
        std::fs::write(&path, b"XXXX\0\0\0\0").unwrap();
        assert!(read_tensors(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn find_by_name() {
        let t = vec![Tensor::f32("a", vec![1], vec![1.0])];
        assert!(find(&t, "a").is_ok());
        assert!(find(&t, "b").is_err());
    }
}
