//! `.nqt` — a minimal self-describing tensor container shared between the
//! python build layer (numpy) and the rust runtime. Little-endian:
//!
//! ```text
//! magic  b"NQT1"
//! u32    tensor count
//! per tensor:
//!   u16      name length, then name bytes (utf-8)
//!   u8       dtype (0 = f32, 1 = u8, 2 = i32)
//!   u8       ndim
//!   u64×ndim dims
//!   bytes    row-major data
//! ```
//!
//! Reads return a typed [`TensorFileError`] instead of a bare panic or
//! opaque string: a truncated or corrupted artifact names the file, the
//! field that failed, and (for headers) what was expected — so the CLI
//! can print a friendly message and exit nonzero instead of unwinding.
//! Header fields are sanity-capped before any allocation sized by them,
//! so a corrupt count/dim can't OOM the process.

use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

/// Caps on header-declared sizes: a well-formed artifact stays far
/// under these; a corrupt header fails fast instead of allocating.
const MAX_TENSORS: usize = 1 << 20;
const MAX_NDIM: usize = 8;
const MAX_NUMEL: usize = 1 << 28;

/// Why a `.nqt` read failed — every variant names the offending file or
/// tensor so callers can surface an actionable message.
#[derive(Debug)]
pub enum TensorFileError {
    /// The underlying filesystem read failed (open error, permission,
    /// or an injected fault in tests).
    Io { path: PathBuf, source: std::io::Error },
    /// The file ended before the named field could be read.
    Truncated { path: PathBuf, what: &'static str },
    /// The first four bytes are not `b"NQT1"`.
    BadMagic { path: PathBuf, magic: [u8; 4] },
    /// A tensor name was not valid utf-8.
    BadName { path: PathBuf },
    /// A tensor declared a dtype tag outside {0, 1, 2}.
    BadDtype { path: PathBuf, name: String, dtype: u8 },
    /// A header-declared size exceeds the sanity caps — the file is
    /// corrupt (or adversarial), not merely large.
    Implausible { path: PathBuf, what: String },
    /// [`find`] did not locate the named tensor.
    NotFound { name: String },
    /// The tensor exists but holds a different dtype than requested.
    WrongDtype { name: String, expected: &'static str },
}

impl std::fmt::Display for TensorFileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TensorFileError::Io { path, source } => {
                write!(f, "{}: read failed: {source}", path.display())
            }
            TensorFileError::Truncated { path, what } => {
                write!(f, "{}: file truncated while reading {what}", path.display())
            }
            TensorFileError::BadMagic { path, magic } => write!(
                f,
                "{}: bad magic {magic:?} (expected b\"NQT1\" — not a .nqt tensor file?)",
                path.display()
            ),
            TensorFileError::BadName { path } => {
                write!(f, "{}: tensor name is not valid utf-8", path.display())
            }
            TensorFileError::BadDtype { path, name, dtype } => write!(
                f,
                "{}: tensor '{name}' has unknown dtype tag {dtype} (known: 0=f32 1=u8 2=i32)",
                path.display()
            ),
            TensorFileError::Implausible { path, what } => write!(
                f,
                "{}: implausible header ({what}) — file is corrupt",
                path.display()
            ),
            TensorFileError::NotFound { name } => write!(f, "tensor '{name}' not found"),
            TensorFileError::WrongDtype { name, expected } => {
                write!(f, "tensor '{name}' is not {expected}")
            }
        }
    }
}

impl std::error::Error for TensorFileError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TensorFileError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    U8(Vec<u8>),
    I32(Vec<i32>),
}

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(name: &str, dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor {
            name: name.to_string(),
            dims,
            data: TensorData::F32(data),
        }
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn as_f32(&self) -> std::result::Result<&[f32], TensorFileError> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(TensorFileError::WrongDtype {
                name: self.name.clone(),
                expected: "f32",
            }),
        }
    }

    pub fn as_u8(&self) -> std::result::Result<&[u8], TensorFileError> {
        match &self.data {
            TensorData::U8(v) => Ok(v),
            _ => Err(TensorFileError::WrongDtype {
                name: self.name.clone(),
                expected: "u8",
            }),
        }
    }
}

pub fn write_tensors(path: &Path, tensors: &[Tensor]) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
    );
    f.write_all(b"NQT1")?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for t in tensors {
        let name = t.name.as_bytes();
        f.write_all(&(name.len() as u16).to_le_bytes())?;
        f.write_all(name)?;
        let dtype = match &t.data {
            TensorData::F32(_) => 0u8,
            TensorData::U8(_) => 1u8,
            TensorData::I32(_) => 2u8,
        };
        f.write_all(&[dtype, t.dims.len() as u8])?;
        for &d in &t.dims {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::U8(v) => f.write_all(v)?,
            TensorData::I32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

/// Read exactly `buf.len()` bytes, mapping an early EOF to
/// [`TensorFileError::Truncated`] naming the field being read.
fn read_exact_or(
    f: &mut impl Read,
    buf: &mut [u8],
    path: &Path,
    what: &'static str,
) -> std::result::Result<(), TensorFileError> {
    f.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            TensorFileError::Truncated {
                path: path.to_path_buf(),
                what,
            }
        } else {
            TensorFileError::Io {
                path: path.to_path_buf(),
                source: e,
            }
        }
    })
}

pub fn read_tensors(path: &Path) -> std::result::Result<Vec<Tensor>, TensorFileError> {
    let file = std::fs::File::open(path).map_err(|e| TensorFileError::Io {
        path: path.to_path_buf(),
        source: e,
    })?;
    // deterministic injected read fault — exercises the typed-error
    // path without a real bad disk
    crate::fail_point!("io/read", {
        return Err(TensorFileError::Io {
            path: path.to_path_buf(),
            source: std::io::Error::new(std::io::ErrorKind::Other, "injected read fault"),
        });
    });
    let mut f = std::io::BufReader::new(file);
    let mut magic = [0u8; 4];
    read_exact_or(&mut f, &mut magic, path, "magic")?;
    if &magic != b"NQT1" {
        return Err(TensorFileError::BadMagic {
            path: path.to_path_buf(),
            magic,
        });
    }
    let mut buf4 = [0u8; 4];
    read_exact_or(&mut f, &mut buf4, path, "tensor count")?;
    let count = u32::from_le_bytes(buf4) as usize;
    if count > MAX_TENSORS {
        return Err(TensorFileError::Implausible {
            path: path.to_path_buf(),
            what: format!("tensor count {count} > {MAX_TENSORS}"),
        });
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let mut buf2 = [0u8; 2];
        read_exact_or(&mut f, &mut buf2, path, "name length")?;
        let name_len = u16::from_le_bytes(buf2) as usize;
        let mut name = vec![0u8; name_len];
        read_exact_or(&mut f, &mut name, path, "tensor name")?;
        let name = String::from_utf8(name).map_err(|_| TensorFileError::BadName {
            path: path.to_path_buf(),
        })?;
        let mut hdr = [0u8; 2];
        read_exact_or(&mut f, &mut hdr, path, "dtype/ndim header")?;
        let (dtype, ndim) = (hdr[0], hdr[1] as usize);
        if ndim > MAX_NDIM {
            return Err(TensorFileError::Implausible {
                path: path.to_path_buf(),
                what: format!("tensor '{name}' ndim {ndim} > {MAX_NDIM}"),
            });
        }
        let mut dims = Vec::with_capacity(ndim);
        let mut buf8 = [0u8; 8];
        for _ in 0..ndim {
            read_exact_or(&mut f, &mut buf8, path, "dims")?;
            dims.push(u64::from_le_bytes(buf8) as usize);
        }
        let mut numel: usize = 1;
        for &d in &dims {
            numel = numel
                .checked_mul(d)
                .filter(|&n| n <= MAX_NUMEL)
                .ok_or_else(|| TensorFileError::Implausible {
                    path: path.to_path_buf(),
                    what: format!("tensor '{name}' element count overflows (dims {dims:?})"),
                })?;
        }
        let data = match dtype {
            0 => {
                let mut bytes = vec![0u8; numel * 4];
                read_exact_or(&mut f, &mut bytes, path, "f32 data")?;
                TensorData::F32(
                    bytes
                        .chunks_exact(4)
                        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect(),
                )
            }
            1 => {
                let mut bytes = vec![0u8; numel];
                read_exact_or(&mut f, &mut bytes, path, "u8 data")?;
                TensorData::U8(bytes)
            }
            2 => {
                let mut bytes = vec![0u8; numel * 4];
                read_exact_or(&mut f, &mut bytes, path, "i32 data")?;
                TensorData::I32(
                    bytes
                        .chunks_exact(4)
                        .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect(),
                )
            }
            d => {
                return Err(TensorFileError::BadDtype {
                    path: path.to_path_buf(),
                    name,
                    dtype: d,
                })
            }
        };
        out.push(Tensor { name, dims, data });
    }
    Ok(out)
}

/// Find a tensor by name.
pub fn find<'a>(
    tensors: &'a [Tensor],
    name: &str,
) -> std::result::Result<&'a Tensor, TensorFileError> {
    tensors
        .iter()
        .find(|t| t.name == name)
        .ok_or_else(|| TensorFileError::NotFound {
            name: name.to_string(),
        })
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("nqt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_all_dtypes() {
        let mut rng = Rng::new(1401);
        let tensors = vec![
            Tensor::f32("weights/w0", vec![4, 8], rng.gauss_vec(32)),
            Tensor {
                name: "codes".into(),
                dims: vec![16],
                data: TensorData::U8((0..16u8).collect()),
            },
            Tensor {
                name: "meta/config".into(),
                dims: vec![3],
                data: TensorData::I32(vec![-1, 0, 42]),
            },
        ];
        let path = tmp("roundtrip.nqt");
        write_tensors(&path, &tensors).unwrap();
        let back = read_tensors(&path).unwrap();
        assert_eq!(tensors, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmp("bad.nqt");
        std::fs::write(&path, b"XXXX\0\0\0\0").unwrap();
        match read_tensors(&path) {
            Err(TensorFileError::BadMagic { magic, .. }) => assert_eq!(&magic, b"XXXX"),
            other => panic!("expected BadMagic, got {other:?}"),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_file_names_the_missing_field() {
        // valid magic + count=1, then EOF: dies reading the name length
        let path = tmp("truncated.nqt");
        let mut bytes = b"NQT1".to_vec();
        bytes.extend_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        match read_tensors(&path) {
            Err(TensorFileError::Truncated { what, .. }) => assert_eq!(what, "name length"),
            other => panic!("expected Truncated, got {other:?}"),
        }
        // cut mid-data: a real tensor header promising more bytes than exist
        let t = vec![Tensor::f32("w", vec![8], vec![1.0; 8])];
        write_tensors(&path, &t).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        match read_tensors(&path) {
            Err(TensorFileError::Truncated { what, .. }) => assert_eq!(what, "f32 data"),
            other => panic!("expected Truncated, got {other:?}"),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn implausible_headers_fail_before_allocating() {
        // count = u32::MAX would reserve gigabytes if trusted
        let path = tmp("implausible.nqt");
        let mut bytes = b"NQT1".to_vec();
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            read_tensors(&path),
            Err(TensorFileError::Implausible { .. })
        ));
        // dim product overflowing usize must be caught, not wrapped
        let mut bytes = b"NQT1".to_vec();
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1u16.to_le_bytes());
        bytes.push(b'w');
        bytes.push(0); // dtype f32
        bytes.push(2); // ndim
        bytes.extend_from_slice(&(u64::MAX / 2).to_le_bytes());
        bytes.extend_from_slice(&4u64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            read_tensors(&path),
            Err(TensorFileError::Implausible { .. })
        ));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn unknown_dtype_names_the_tensor() {
        let path = tmp("baddtype.nqt");
        let mut bytes = b"NQT1".to_vec();
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&3u16.to_le_bytes());
        bytes.extend_from_slice(b"abc");
        bytes.push(7); // unknown dtype tag
        bytes.push(0); // ndim 0
        std::fs::write(&path, &bytes).unwrap();
        match read_tensors(&path) {
            Err(TensorFileError::BadDtype { name, dtype, .. }) => {
                assert_eq!(name, "abc");
                assert_eq!(dtype, 7);
            }
            other => panic!("expected BadDtype, got {other:?}"),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn injected_read_fault_surfaces_as_typed_io_error() {
        use crate::util::failpoint::{scenario, FailSpec};
        let path = tmp("faulted.nqt");
        let t = vec![Tensor::f32("w", vec![2], vec![1.0, 2.0])];
        write_tensors(&path, &t).unwrap();
        let s = scenario();
        s.fail("io/read", FailSpec::Nth(1));
        match read_tensors(&path) {
            Err(TensorFileError::Io { source, .. }) => {
                assert!(source.to_string().contains("injected"));
            }
            other => panic!("expected injected Io error, got {other:?}"),
        }
        // the error arm returns instead of panicking — the next read,
        // past the Nth(1) trigger, succeeds on the same file
        assert_eq!(read_tensors(&path).unwrap(), t);
        drop(s);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn find_by_name() {
        let t = vec![Tensor::f32("a", vec![1], vec![1.0])];
        assert!(find(&t, "a").is_ok());
        assert!(matches!(
            find(&t, "b"),
            Err(TensorFileError::NotFound { .. })
        ));
    }

    #[test]
    fn wrong_dtype_is_typed() {
        let t = Tensor::f32("a", vec![1], vec![1.0]);
        assert!(t.as_f32().is_ok());
        assert!(matches!(
            t.as_u8(),
            Err(TensorFileError::WrongDtype { expected: "u8", .. })
        ));
    }
}
