//! Fast orthogonal transforms: Sylvester/Walsh–Hadamard, Paley Hadamard
//! matrices for non-power-of-two factors, Kronecker compositions, random
//! sign randomization, and the Fourier/S⊗H ablation variants of Table 7.

use crate::util::linalg::Mat;
use crate::util::Rng;

/// In-place fast Walsh–Hadamard transform, orthonormalized (×1/√n).
/// `x.len()` must be a power of two. Involution: applying twice = identity.
pub fn fwht_normalized(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT needs a power-of-two length");
    let mut h = 1;
    while h < n {
        for chunk in x.chunks_exact_mut(2 * h) {
            let (a, b) = chunk.split_at_mut(h);
            for i in 0..h {
                let (u, v) = (a[i], b[i]);
                a[i] = u + v;
                b[i] = u - v;
            }
        }
        h *= 2;
    }
    let s = 1.0 / (n as f32).sqrt();
    for v in x.iter_mut() {
        *v *= s;
    }
}

/// Paley-construction Hadamard matrix of size p+1 for a prime p ≡ 3 mod 4
/// (entries ±1). Supports the paper's "hardcoded" H₁ factors (12, 20, …).
pub fn paley_hadamard(n: usize) -> Mat {
    let p = n - 1;
    assert!(p >= 3 && p % 4 == 3, "Paley I needs prime p ≡ 3 (mod 4)");
    assert!((2..p).all(|d| d * d > p || p % d != 0), "{p} not prime");
    // quadratic residues mod p
    let mut is_qr = vec![false; p];
    for x in 1..p {
        is_qr[(x * x) % p] = true;
    }
    let chi = |x: usize| -> f32 {
        if x == 0 {
            0.0
        } else if is_qr[x] {
            1.0
        } else {
            -1.0
        }
    };
    // H = [[1, 1ᵀ], [-1, Q + I]] variant; build then fix signs so that
    // H·Hᵀ = n·I (standard Paley I: borders of +1, core Q_{ij}=χ(j−i) − I).
    let mut h = Mat::zeros(n, n);
    for j in 0..n {
        h[(0, j)] = 1.0;
    }
    for i in 1..n {
        h[(i, 0)] = -1.0;
        for j in 1..n {
            let q = chi((j + p - i) % p);
            h[(i, j)] = if i == j { 1.0 } else { q };
        }
    }
    h
}

/// A fast orthogonal rotation U applied as x ↦ U x. All variants are exact
/// orthogonal maps (tested: ‖Ux‖ = ‖x‖, U applied twice via transpose =
/// identity).
#[derive(Clone, Debug)]
pub enum Rotation {
    /// Identity (no rotation) — baseline.
    Identity { n: usize },
    /// Randomized Sylvester Hadamard: D then FWHT. n must be 2^k.
    Hadamard { signs: Vec<f32> },
    /// Kronecker M ⊗ H: view x as (m × 2^k), FWHT along rows, M along
    /// columns. Covers the paper's H₁⊗H₂ (M = Paley Hadamard / √m) and the
    /// Table-7 S⊗H (M = random orthogonal).
    Kronecker { m: Mat, signs: Vec<f32> },
    /// Orthogonal real-Fourier rotation (Table 7 "Fourier"): the real DFT
    /// basis (cos/sin pairs), applied densely. O(n²) — ablation only.
    Fourier { f: Mat },
}

impl Rotation {
    pub fn identity(n: usize) -> Self {
        Rotation::Identity { n }
    }

    /// Randomized Hadamard for power-of-two n.
    pub fn random_hadamard(n: usize, rng: &mut Rng) -> Self {
        assert!(n.is_power_of_two());
        Rotation::Hadamard {
            signs: rng.sign_vec(n),
        }
    }

    /// Deterministic Sylvester Hadamard (no sign randomization) — used
    /// when the rotation is folded into weights and must be replayed.
    pub fn plain_hadamard(n: usize) -> Self {
        assert!(n.is_power_of_two());
        Rotation::Hadamard { signs: vec![1.0; n] }
    }

    /// Paper §4.3 general case: n = m·2^k with a (Paley) Hadamard H₁ of
    /// size m; U = (H₁/√m) ⊗ H₂.
    pub fn kron_hadamard(n: usize, m: usize, rng: &mut Rng) -> Self {
        assert_eq!(n % m, 0);
        assert!((n / m).is_power_of_two());
        let mut h1 = paley_hadamard(m);
        h1.scale(1.0 / (m as f32).sqrt());
        Rotation::Kronecker {
            m: h1,
            signs: rng.sign_vec(n),
        }
    }

    /// Table 7 "S ⊗ H": S random orthogonal (QR of Gaussian), H Sylvester.
    pub fn random_orth_kron(n: usize, m: usize, rng: &mut Rng) -> Self {
        assert_eq!(n % m, 0);
        assert!((n / m).is_power_of_two());
        Rotation::Kronecker {
            m: random_orthogonal(m, rng),
            signs: rng.sign_vec(n),
        }
    }

    /// Table 7 "Fourier": orthogonal real DFT basis.
    pub fn fourier(n: usize) -> Self {
        let mut f = Mat::zeros(n, n);
        let norm = (2.0 / n as f64).sqrt();
        for k in 0..n {
            for t in 0..n {
                let ang = std::f64::consts::TAU * (k * t) as f64 / n as f64;
                f[(k, t)] = if k == 0 {
                    (1.0 / n as f64).sqrt() as f32
                } else if 2 * k < n {
                    (norm * ang.cos()) as f32
                } else if 2 * k == n {
                    ((1.0 / n as f64).sqrt() * if t % 2 == 0 { 1.0 } else { -1.0 }) as f32
                } else {
                    (norm * ang.sin()) as f32
                };
            }
        }
        Rotation::Fourier { f }
    }

    pub fn len(&self) -> usize {
        match self {
            Rotation::Identity { n } => *n,
            Rotation::Hadamard { signs } => signs.len(),
            Rotation::Kronecker { m, signs } => {
                debug_assert_eq!(signs.len() % m.rows, 0);
                signs.len()
            }
            Rotation::Fourier { f } => f.rows,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Apply U in place.
    pub fn apply(&self, x: &mut [f32]) {
        assert_eq!(x.len(), self.len());
        match self {
            Rotation::Identity { .. } => {}
            Rotation::Hadamard { signs } => {
                for (v, s) in x.iter_mut().zip(signs) {
                    *v *= s;
                }
                fwht_normalized(x);
            }
            Rotation::Kronecker { m, signs } => {
                for (v, s) in x.iter_mut().zip(signs) {
                    *v *= s;
                }
                let mm = m.rows;
                let cols = x.len() / mm;
                // FWHT along each contiguous row of the (m × 2^k) view
                for r in 0..mm {
                    fwht_normalized(&mut x[r * cols..(r + 1) * cols]);
                }
                // M along columns
                let mut col = vec![0f32; mm];
                for c in 0..cols {
                    for r in 0..mm {
                        col[r] = x[r * cols + c];
                    }
                    let out = m.matvec(&col);
                    for r in 0..mm {
                        x[r * cols + c] = out[r];
                    }
                }
            }
            Rotation::Fourier { f } => {
                let out = f.matvec(x);
                x.copy_from_slice(&out);
            }
        }
    }

    /// Apply Uᵀ in place (the inverse, since U is orthogonal).
    pub fn apply_t(&self, x: &mut [f32]) {
        assert_eq!(x.len(), self.len());
        match self {
            Rotation::Identity { .. } => {}
            Rotation::Hadamard { signs } => {
                // (DH)ᵀ = Hᵀ D = H D applied in reverse order
                fwht_normalized(x);
                for (v, s) in x.iter_mut().zip(signs) {
                    *v *= s;
                }
            }
            Rotation::Kronecker { m, signs } => {
                let mm = m.rows;
                let cols = x.len() / mm;
                let mt = m.transpose();
                let mut col = vec![0f32; mm];
                for c in 0..cols {
                    for r in 0..mm {
                        col[r] = x[r * cols + c];
                    }
                    let out = mt.matvec(&col);
                    for r in 0..mm {
                        x[r * cols + c] = out[r];
                    }
                }
                for r in 0..mm {
                    fwht_normalized(&mut x[r * cols..(r + 1) * cols]);
                }
                for (v, s) in x.iter_mut().zip(signs) {
                    *v *= s;
                }
            }
            Rotation::Fourier { f } => {
                let out = f.transpose().matvec(x);
                x.copy_from_slice(&out);
            }
        }
    }

    /// Apply U to every row of a row-major matrix (rows of length n).
    pub fn apply_rows(&self, data: &mut [f32]) {
        let n = self.len();
        assert_eq!(data.len() % n, 0);
        for row in data.chunks_exact_mut(n) {
            self.apply(row);
        }
    }

    /// Apply Uᵀ to every row.
    pub fn apply_t_rows(&self, data: &mut [f32]) {
        let n = self.len();
        assert_eq!(data.len() % n, 0);
        for row in data.chunks_exact_mut(n) {
            self.apply_t(row);
        }
    }

    /// Materialize U as a dense matrix (tests / folding into weights).
    pub fn to_mat(&self) -> Mat {
        let n = self.len();
        let mut u = Mat::zeros(n, n);
        let mut e = vec![0f32; n];
        for c in 0..n {
            e.fill(0.0);
            e[c] = 1.0;
            self.apply(&mut e);
            for r in 0..n {
                u[(r, c)] = e[r];
            }
        }
        u
    }
}

/// Random orthogonal matrix via Gram–Schmidt QR of a Gaussian matrix.
pub fn random_orthogonal(n: usize, rng: &mut Rng) -> Mat {
    let mut q = Mat::zeros(n, n);
    for c in 0..n {
        // fresh Gaussian column, orthogonalized against previous columns
        let mut v = rng.gauss_vec(n);
        for prev in 0..c {
            let mut dot = 0f64;
            for r in 0..n {
                dot += q[(r, prev)] as f64 * v[r] as f64;
            }
            for r in 0..n {
                v[r] -= (dot as f32) * q[(r, prev)];
            }
        }
        let norm = crate::util::stats::norm2(&v) as f32;
        assert!(norm > 1e-6, "degenerate Gaussian column");
        for r in 0..n {
            q[(r, c)] = v[r] / norm;
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{propcheck, stats, Rng};

    fn check_orthogonal(rot: &Rotation, seed: u64) {
        let n = rot.len();
        let mut rng = Rng::new(seed);
        // norm preservation
        let x = rng.gauss_vec(n);
        let mut y = x.clone();
        rot.apply(&mut y);
        assert!(
            (stats::norm2(&x) - stats::norm2(&y)).abs() < 1e-3 * stats::norm2(&x),
            "norm not preserved"
        );
        // Uᵀ U = I
        rot.apply_t(&mut y);
        propcheck::assert_close(&x, &y, 1e-4, 1e-4).expect("UᵀU != I");
    }

    #[test]
    fn fwht_is_involution() {
        let mut rng = Rng::new(701);
        let x = rng.gauss_vec(64);
        let mut y = x.clone();
        fwht_normalized(&mut y);
        fwht_normalized(&mut y);
        propcheck::assert_close(&x, &y, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn fwht_matches_dense_hadamard() {
        // n=4 Sylvester: H4 known entries
        let mut x = vec![1.0f32, 0.0, 0.0, 0.0];
        fwht_normalized(&mut x);
        for v in &x {
            assert!((v - 0.5).abs() < 1e-6);
        }
    }

    #[test]
    fn rotations_are_orthogonal() {
        let mut rng = Rng::new(702);
        check_orthogonal(&Rotation::identity(32), 1);
        check_orthogonal(&Rotation::random_hadamard(64, &mut rng), 2);
        check_orthogonal(&Rotation::plain_hadamard(128), 3);
        check_orthogonal(&Rotation::kron_hadamard(96, 12, &mut rng), 4);
        check_orthogonal(&Rotation::random_orth_kron(48, 12, &mut rng), 5);
        check_orthogonal(&Rotation::fourier(48), 6);
    }

    #[test]
    fn paley_hadamard_is_hadamard() {
        for n in [4usize, 12, 20] {
            let h = paley_hadamard(n);
            // entries ±1
            for &v in &h.data {
                assert!(v == 1.0 || v == -1.0, "non ±1 entry {v} in H{n}");
            }
            // H Hᵀ = n I
            let prod = h.matmul(&h.transpose());
            for i in 0..n {
                for j in 0..n {
                    let expect = if i == j { n as f32 } else { 0.0 };
                    assert_eq!(prod[(i, j)], expect, "H{n}·Hᵀ at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn random_orthogonal_is_orthogonal() {
        let mut rng = Rng::new(703);
        let q = random_orthogonal(16, &mut rng);
        let prod = q.transpose().matmul(&q);
        for i in 0..16 {
            for j in 0..16 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((prod[(i, j)] - expect).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn hadamard_gaussianizes_outliers() {
        // A one-hot (max-outlier) vector becomes flat after rotation:
        // kurtosis drops to ~flat, L∞/L2 shrinks by ~√n.
        let n = 256;
        let mut rng = Rng::new(704);
        let rot = Rotation::random_hadamard(n, &mut rng);
        let mut x = vec![0f32; n];
        x[17] = 10.0;
        let before_ratio = 10.0 / stats::norm2(&x) as f32;
        rot.apply(&mut x);
        let linf = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let after_ratio = linf / stats::norm2(&x) as f32;
        assert!(
            after_ratio < before_ratio / ((n as f32).sqrt() * 0.9),
            "rotation did not spread the outlier: {after_ratio} vs {before_ratio}"
        );
    }

    #[test]
    fn rotation_preserves_inner_products() {
        // (Ux)·(Uy) = x·y — the identity that lets rotations be folded
        // into weight/activation pairs without changing layer outputs.
        propcheck::check("rotation-ip", 30, 705, |rng| {
            let n = 64;
            let rot = Rotation::random_hadamard(n, rng);
            let x = rng.gauss_vec(n);
            let y = rng.gauss_vec(n);
            let ip0 = stats::dot(&x, &y);
            let mut xr = x.clone();
            let mut yr = y.clone();
            rot.apply(&mut xr);
            rot.apply(&mut yr);
            let ip1 = stats::dot(&xr, &yr);
            if (ip0 - ip1).abs() < 1e-3 * (1.0 + ip0.abs()) {
                Ok(())
            } else {
                Err(format!("{ip0} vs {ip1}"))
            }
        });
    }

    #[test]
    fn to_mat_matches_apply() {
        let mut rng = Rng::new(706);
        let rot = Rotation::kron_hadamard(24, 12, &mut rng);
        let u = rot.to_mat();
        let x = rng.gauss_vec(24);
        let dense = u.matvec(&x);
        let mut fast = x.clone();
        rot.apply(&mut fast);
        propcheck::assert_close(&dense, &fast, 1e-5, 1e-4).unwrap();
    }
}
