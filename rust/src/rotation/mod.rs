//! Coordinate rotations that "Gaussianize" quantizer inputs (paper §4.3).
//!
//! The workhorse is the randomized Hadamard transform: x ↦ (1/√n)·H·D·x
//! with H a Sylvester Hadamard matrix and D a random ±1 diagonal. For
//! n = 2^k·m the paper composes a hardcoded Hadamard H₁ (size m) with a
//! Sylvester H₂ (size 2^k) via the Kronecker product. Applying H costs
//! O(n log n + n·m) — negligible next to the matmuls it protects.
//!
//! Also provided for the Table 7 ablation: an orthogonal real-Fourier
//! rotation and an S ⊗ H rotation with S a random orthogonal matrix.

pub mod hadamard;

pub use hadamard::{fwht_normalized, paley_hadamard, Rotation};
