//! Monotonic, testable time source for the observability layer.
//!
//! Every trace event and latency sample carries a timestamp in
//! microseconds since the clock's origin. Production uses the wall
//! variant (an [`Instant`] anchor — monotonic by construction); tests
//! use the manual variant, which only moves when [`Clock::advance_us`]
//! is called, so event ordering and histogram contents are exactly
//! reproducible.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Microsecond clock: monotonic wall time or a manually-advanced
/// counter. Shared by reference (`&Clock`) — both variants are `Sync`
/// and interior-mutable where needed.
pub enum Clock {
    /// microseconds since an anchor taken at construction
    Wall { anchor: Instant },
    /// test clock: microseconds advanced explicitly
    Manual { now_us: AtomicU64 },
}

impl Clock {
    pub fn wall() -> Self {
        Clock::Wall {
            anchor: Instant::now(),
        }
    }

    /// A deterministic clock starting at 0 µs; advance it with
    /// [`Self::advance_us`].
    pub fn manual() -> Self {
        Clock::Manual {
            now_us: AtomicU64::new(0),
        }
    }

    /// Microseconds since the clock origin. Monotonic non-decreasing
    /// for both variants.
    pub fn now_us(&self) -> u64 {
        match self {
            Clock::Wall { anchor } => {
                // u64 µs wraps after ~584k years of uptime; saturate
                // instead of truncating just in case
                u64::try_from(anchor.elapsed().as_micros()).unwrap_or(u64::MAX)
            }
            Clock::Manual { now_us } => now_us.load(Ordering::Relaxed),
        }
    }

    /// Advance a manual clock. No-op on the wall variant (wall time
    /// advances itself), so instrumented code paths never need to know
    /// which variant they carry.
    pub fn advance_us(&self, us: u64) {
        if let Clock::Manual { now_us } = self {
            now_us.fetch_add(us, Ordering::Relaxed);
        }
    }
}

impl Default for Clock {
    fn default() -> Self {
        Clock::wall()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_is_deterministic() {
        let c = Clock::manual();
        assert_eq!(c.now_us(), 0);
        c.advance_us(17);
        assert_eq!(c.now_us(), 17);
        c.advance_us(3);
        assert_eq!(c.now_us(), 20);
    }

    #[test]
    fn wall_clock_is_monotonic() {
        let c = Clock::wall();
        let a = c.now_us();
        c.advance_us(1_000_000); // no-op on wall
        let b = c.now_us();
        assert!(b >= a, "wall clock went backwards: {a} -> {b}");
        assert!(b < 1_000_000, "advance_us must not move the wall clock");
    }
}
