//! Trace and metrics exporters: Chrome trace-event JSON (loadable in
//! Perfetto / `chrome://tracing`) and Prometheus text exposition
//! (format 0.0.4), plus shape validators used by `trace-smoke` tests
//! and a std-only TCP listener for scrape-style metric serving.
//!
//! JSON is hand-rolled (the offline vendor set has no serde), mirroring
//! the `util::bench` BENCH_*.json writer. The validators include a
//! minimal recursive-descent JSON well-formedness checker so the smoke
//! test can assert "Perfetto will load this" without a JSON dependency.

use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::histogram::LogHistogram;
use super::trace::{Event, EventKind, REQ_TRACK_BASE, TRACK_ENGINE, TRACK_POOL, TRACK_WORKER};

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn track_name(track: u64) -> String {
    match track {
        TRACK_WORKER => "worker".to_string(),
        TRACK_POOL => "kvpool".to_string(),
        TRACK_ENGINE => "engine".to_string(),
        t if t >= REQ_TRACK_BASE => format!("req-{}", t - REQ_TRACK_BASE),
        t => format!("track-{t}"),
    }
}

fn event_args(kind: EventKind) -> String {
    match kind {
        EventKind::Admitted {
            queue_wait_us,
            replayed,
        } => format!("{{\"queue_wait_us\":{queue_wait_us},\"replayed\":{replayed}}}"),
        EventKind::Prefill { tokens } => format!("{{\"tokens\":{tokens}}}"),
        EventKind::DecodeStep { batch } => format!("{{\"batch\":{batch}}}"),
        EventKind::SiteGemm {
            layer,
            site,
            backend,
            kernel,
        } => format!(
            "{{\"layer\":{layer},\"site\":\"{}\",\"backend\":\"{}\",\"kernel\":\"{}\"}}",
            site.name(),
            backend.name(),
            kernel.name()
        ),
        EventKind::Done { tokens } => format!("{{\"tokens\":{tokens}}}"),
        EventKind::ShutdownDrain { undrained } => format!("{{\"undrained\":{undrained}}}"),
        _ => "{}".to_string(),
    }
}

/// Render a journal snapshot as Chrome trace-event JSON: one process
/// (`pid` 1), one thread per track, complete (`"X"`) events for spans
/// and thread-scoped instant (`"i"`) events for the rest. The output
/// loads directly in Perfetto (ui.perfetto.dev) or `chrome://tracing`.
pub fn chrome_trace_json(events: &[Event]) -> String {
    let mut out = String::with_capacity(events.len() * 96 + 256);
    out.push_str("{\"traceEvents\":[");
    let mut first = true;
    let mut push = |out: &mut String, item: String| {
        if !std::mem::take(&mut first) {
            out.push(',');
        }
        out.push_str(&item);
    };

    push(
        &mut out,
        "{\"ph\":\"M\",\"pid\":1,\"name\":\"process_name\",\
         \"args\":{\"name\":\"nestquant-serve\"}}"
            .to_string(),
    );
    // one thread_name metadata record per distinct track, in order of
    // first appearance, so Perfetto rows are labeled
    let mut seen: Vec<u64> = Vec::new();
    for e in events {
        if !seen.contains(&e.track) {
            seen.push(e.track);
            push(
                &mut out,
                format!(
                    "{{\"ph\":\"M\",\"pid\":1,\"tid\":{},\"name\":\"thread_name\",\
                     \"args\":{{\"name\":\"{}\"}}}}",
                    e.track,
                    json_escape(&track_name(e.track))
                ),
            );
        }
    }

    for e in events {
        let (ph, extra) = if e.dur_us > 0 {
            ("X", format!(",\"dur\":{}", e.dur_us))
        } else {
            ("i", ",\"s\":\"t\"".to_string())
        };
        push(
            &mut out,
            format!(
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"{ph}\",\"ts\":{},\
                 \"pid\":1,\"tid\":{}{extra},\"args\":{}}}",
                e.kind.name(),
                e.kind.category(),
                e.ts_us,
                e.track,
                event_args(e.kind)
            ),
        );
    }
    out.push_str("]}");
    out
}

/// Prometheus `le` bucket ladder in microseconds: powers of two from
/// 64 µs to ~67 s. Aligned with [`LogHistogram`] octave boundaries so
/// cumulative counts are bucket-floor-conservative and monotone.
pub const PROM_BOUNDS_US: [u64; 21] = [
    64,
    128,
    256,
    512,
    1 << 10,
    1 << 11,
    1 << 12,
    1 << 13,
    1 << 14,
    1 << 15,
    1 << 16,
    1 << 17,
    1 << 18,
    1 << 19,
    1 << 20,
    1 << 21,
    1 << 22,
    1 << 23,
    1 << 24,
    1 << 25,
    1 << 26,
];

/// Incremental Prometheus text-exposition writer. Durations are
/// exported in **seconds** (Prometheus convention); the histogram
/// method expands a [`LogHistogram`] into the standard
/// `_bucket`/`_sum`/`_count` triple over [`PROM_BOUNDS_US`].
#[derive(Default)]
pub struct PromWriter {
    out: String,
}

impl PromWriter {
    pub fn new() -> Self {
        Self::default()
    }

    fn header(&mut self, name: &str, help: &str, kind: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n"));
        self.out.push_str(&format!("# TYPE {name} {kind}\n"));
    }

    pub fn counter(&mut self, name: &str, help: &str, value: u64) {
        self.header(name, help, "counter");
        self.out.push_str(&format!("{name} {value}\n"));
    }

    pub fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.header(name, help, "gauge");
        self.out.push_str(&format!("{name} {value}\n"));
    }

    /// A gauge family with one `{label_key="label_val"}` sample per
    /// entry.
    pub fn gauge_labeled(
        &mut self,
        name: &str,
        help: &str,
        label_key: &str,
        samples: &[(&str, f64)],
    ) {
        self.header(name, help, "gauge");
        for (label_val, value) in samples {
            self.out
                .push_str(&format!("{name}{{{label_key}=\"{label_val}\"}} {value}\n"));
        }
    }

    pub fn histogram(&mut self, name: &str, help: &str, h: &LogHistogram) {
        self.header(name, help, "histogram");
        for &bound_us in PROM_BOUNDS_US.iter() {
            let le = bound_us as f64 / 1e6;
            self.out.push_str(&format!(
                "{name}_bucket{{le=\"{le}\"}} {}\n",
                h.count_le(bound_us)
            ));
        }
        self.out
            .push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
        self.out
            .push_str(&format!("{name}_sum {}\n", h.sum_us() as f64 / 1e6));
        self.out.push_str(&format!("{name}_count {}\n", h.count()));
    }

    pub fn finish(self) -> String {
        self.out
    }
}

// ---------------------------------------------------------------------
// shape validators (used by the trace-smoke test)
// ---------------------------------------------------------------------

/// Minimal recursive-descent JSON well-formedness check — enough to
/// guarantee a JSON parser (and therefore Perfetto's loader) will accept
/// the document structurally.
pub fn json_well_formed(s: &str) -> Result<(), String> {
    let b = s.as_bytes();
    let mut p = 0usize;
    parse_value(b, &mut p)?;
    skip_ws(b, &mut p);
    if p != b.len() {
        return Err(format!("trailing bytes at offset {p}"));
    }
    Ok(())
}

fn skip_ws(b: &[u8], p: &mut usize) {
    while *p < b.len() && matches!(b[*p], b' ' | b'\t' | b'\n' | b'\r') {
        *p += 1;
    }
}

fn parse_value(b: &[u8], p: &mut usize) -> Result<(), String> {
    skip_ws(b, p);
    match b.get(*p) {
        Some(b'{') => parse_object(b, p),
        Some(b'[') => parse_array(b, p),
        Some(b'"') => parse_string(b, p),
        Some(b't') => parse_lit(b, p, "true"),
        Some(b'f') => parse_lit(b, p, "false"),
        Some(b'n') => parse_lit(b, p, "null"),
        Some(c) if c.is_ascii_digit() || *c == b'-' => parse_number(b, p),
        Some(c) => Err(format!("unexpected byte {:?} at offset {p}", *c as char)),
        None => Err("unexpected end of input".to_string()),
    }
}

fn parse_lit(b: &[u8], p: &mut usize, lit: &str) -> Result<(), String> {
    if b[*p..].starts_with(lit.as_bytes()) {
        *p += lit.len();
        Ok(())
    } else {
        Err(format!("bad literal at offset {p}"))
    }
}

fn parse_number(b: &[u8], p: &mut usize) -> Result<(), String> {
    let start = *p;
    while *p < b.len() && matches!(b[*p], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
        *p += 1;
    }
    let text = std::str::from_utf8(&b[start..*p]).map_err(|e| e.to_string())?;
    text.parse::<f64>()
        .map(|_| ())
        .map_err(|_| format!("bad number {text:?} at offset {start}"))
}

fn parse_string(b: &[u8], p: &mut usize) -> Result<(), String> {
    debug_assert_eq!(b.get(*p), Some(&b'"'));
    *p += 1;
    while *p < b.len() {
        match b[*p] {
            b'"' => {
                *p += 1;
                return Ok(());
            }
            b'\\' => {
                *p += 1;
                match b.get(*p) {
                    Some(b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't') => *p += 1,
                    Some(b'u') => {
                        if b.len() < *p + 5
                            || !b[*p + 1..*p + 5].iter().all(u8::is_ascii_hexdigit)
                        {
                            return Err(format!("bad \\u escape at offset {p}"));
                        }
                        *p += 5;
                    }
                    _ => return Err(format!("bad escape at offset {p}")),
                }
            }
            _ => *p += 1,
        }
    }
    Err("unterminated string".to_string())
}

fn parse_object(b: &[u8], p: &mut usize) -> Result<(), String> {
    *p += 1; // '{'
    skip_ws(b, p);
    if b.get(*p) == Some(&b'}') {
        *p += 1;
        return Ok(());
    }
    loop {
        skip_ws(b, p);
        if b.get(*p) != Some(&b'"') {
            return Err(format!("expected object key at offset {p}"));
        }
        parse_string(b, p)?;
        skip_ws(b, p);
        if b.get(*p) != Some(&b':') {
            return Err(format!("expected ':' at offset {p}"));
        }
        *p += 1;
        parse_value(b, p)?;
        skip_ws(b, p);
        match b.get(*p) {
            Some(b',') => *p += 1,
            Some(b'}') => {
                *p += 1;
                return Ok(());
            }
            _ => return Err(format!("expected ',' or '}}' at offset {p}")),
        }
    }
}

fn parse_array(b: &[u8], p: &mut usize) -> Result<(), String> {
    *p += 1; // '['
    skip_ws(b, p);
    if b.get(*p) == Some(&b']') {
        *p += 1;
        return Ok(());
    }
    loop {
        parse_value(b, p)?;
        skip_ws(b, p);
        match b.get(*p) {
            Some(b',') => *p += 1,
            Some(b']') => {
                *p += 1;
                return Ok(());
            }
            _ => return Err(format!("expected ',' or ']' at offset {p}")),
        }
    }
}

/// Validate a Chrome trace document: well-formed JSON with a
/// `traceEvents` array whose records carry `ph`/`ts`/`pid` fields.
pub fn validate_chrome_trace(s: &str) -> Result<(), String> {
    json_well_formed(s)?;
    if !s.contains("\"traceEvents\"") {
        return Err("missing traceEvents key".to_string());
    }
    for field in ["\"ph\"", "\"ts\"", "\"pid\""] {
        if !s.contains(field) {
            return Err(format!("no event carries {field}"));
        }
    }
    Ok(())
}

/// Validate Prometheus text exposition shape: every non-empty line is a
/// `# HELP`/`# TYPE` comment or a `name[{labels}] value` sample whose
/// value parses as a float; every `TYPE histogram` family has
/// `_bucket`, `_sum`, and `_count` samples including `le="+Inf"`.
pub fn validate_prometheus(s: &str) -> Result<(), String> {
    let mut histograms: Vec<String> = Vec::new();
    for (i, line) in s.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            let rest = rest.trim_start();
            if !(rest.starts_with("HELP ") || rest.starts_with("TYPE ")) {
                return Err(format!("line {}: unknown comment {line:?}", i + 1));
            }
            if let Some(t) = rest.strip_prefix("TYPE ") {
                let mut it = t.split_whitespace();
                if let (Some(name), Some("histogram")) = (it.next(), it.next()) {
                    histograms.push(name.to_string());
                }
            }
            continue;
        }
        // sample line: name or name{...}, then a float value
        let (series, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {}: no value in {line:?}", i + 1))?;
        value
            .parse::<f64>()
            .map_err(|_| format!("line {}: bad value {value:?}", i + 1))?;
        let name = series.split('{').next().unwrap_or("");
        let base = name
            .trim_end_matches("_bucket")
            .trim_end_matches("_sum")
            .trim_end_matches("_count");
        if base.is_empty()
            || !base
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
            || base.starts_with(|c: char| c.is_ascii_digit())
        {
            return Err(format!("line {}: bad metric name {name:?}", i + 1));
        }
    }
    for h in &histograms {
        for (suffix, probe) in [
            ("_bucket", format!("{h}_bucket{{le=\"+Inf\"}} ")),
            ("_sum", format!("{h}_sum ")),
            ("_count", format!("{h}_count ")),
        ] {
            if !s.contains(&probe) {
                return Err(format!("histogram {h} missing {suffix} sample"));
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// std-only TCP metrics listener
// ---------------------------------------------------------------------

/// A tiny scrape endpoint: serves `render()` as an HTTP 200 text/plain
/// response to every connection. Std-only (no HTTP library); one
/// background thread with a non-blocking accept loop.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and serve `render()` on every
    /// connection until [`Self::stop`] or drop.
    pub fn serve_text<F>(addr: &str, render: F) -> std::io::Result<MetricsServer>
    where
        F: Fn() -> String + Send + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((mut conn, _)) => {
                        let _ = conn.set_nonblocking(false);
                        let _ = conn.set_read_timeout(Some(Duration::from_millis(200)));
                        // drain whatever request bytes arrive; we answer
                        // every connection the same way
                        let mut buf = [0u8; 1024];
                        let _ = conn.read(&mut buf);
                        let body = render();
                        let resp = format!(
                            "HTTP/1.1 200 OK\r\n\
                             Content-Type: text/plain; version=0.0.4\r\n\
                             Content-Length: {}\r\n\
                             Connection: close\r\n\r\n{}",
                            body.len(),
                            body
                        );
                        let _ = conn.write_all(resp.as_bytes());
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(10)),
                }
            }
        });
        Ok(MetricsServer {
            addr: local,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::obs::trace::{req_track, GemmPath, SiteTag, Trace};

    fn demo_trace() -> Trace {
        let t = Trace::manual(256);
        t.instant(req_track(0), EventKind::Queued);
        t.clock().advance_us(40);
        t.instant(
            req_track(0),
            EventKind::Admitted {
                queue_wait_us: 40,
                replayed: false,
            },
        );
        let t0 = t.now();
        t.clock().advance_us(500);
        t.span(req_track(0), EventKind::Prefill { tokens: 9 }, t0);
        let t1 = t.now();
        t.clock().advance_us(120);
        t.span(TRACK_WORKER, EventKind::DecodeStep { batch: 2 }, t1);
        t.span(
            TRACK_ENGINE,
            EventKind::SiteGemm {
                layer: 1,
                site: SiteTag::Up,
                backend: GemmPath::Packed,
                kernel: crate::quant::Kernel::Scalar,
            },
            t1,
        );
        t.instant(TRACK_POOL, EventKind::PageAlloc);
        t.instant(req_track(0), EventKind::Done { tokens: 4 });
        t
    }

    #[test]
    fn chrome_trace_is_well_formed_and_shaped() {
        let json = chrome_trace_json(&demo_trace().snapshot());
        validate_chrome_trace(&json).unwrap();
        // spans carry durations, instants carry scope, metadata labels rows
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"dur\":500"));
        assert!(json.contains("\"ph\":\"i\""));
        assert!(json.contains("\"thread_name\""));
        assert!(json.contains("req-0"));
        assert!(json.contains("\"site\":\"w_up\""));
        assert!(json.contains("\"backend\":\"packed\""));
        assert!(json.contains("\"kernel\":\"scalar\""));
    }

    #[test]
    fn empty_trace_still_exports_valid_json() {
        let json = chrome_trace_json(&[]);
        validate_chrome_trace(&json).unwrap();
    }

    #[test]
    fn prom_writer_output_validates() {
        let mut h = LogHistogram::new();
        for v in [50u64, 120, 900, 15_000, 2_000_000] {
            h.record(v);
        }
        let mut w = PromWriter::new();
        w.counter("nestquant_requests_total", "requests completed", 5);
        w.gauge("nestquant_pool_bytes", "pool bytes in use", 123456.0);
        w.gauge_labeled(
            "nestquant_pool_lane_bytes",
            "per-lane pool bytes",
            "lane",
            &[("fp32", 10.0), ("uniform", 20.0), ("nested", 30.0)],
        );
        w.histogram("nestquant_ttft_seconds", "time to first token", &h);
        let text = w.finish();
        validate_prometheus(&text).unwrap();
        assert!(text.contains("nestquant_ttft_seconds_bucket{le=\"+Inf\"} 5"));
        assert!(text.contains("nestquant_ttft_seconds_count 5"));
        assert!(text.contains("lane=\"nested\""));
        // cumulative bucket counts are monotone non-decreasing
        let counts: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("nestquant_ttft_seconds_bucket"))
            .filter_map(|l| l.rsplit_once(' ').and_then(|(_, v)| v.parse().ok()))
            .collect();
        assert_eq!(counts.len(), PROM_BOUNDS_US.len() + 1);
        assert!(counts.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn json_checker_accepts_valid_and_rejects_broken() {
        for good in [
            "{}",
            "[]",
            "{\"a\":[1,2.5,-3e2,true,false,null,\"x\\n\\u00e9\"]}",
            "  {\"nested\":{\"deep\":[{}]}}  ",
        ] {
            json_well_formed(good).unwrap();
        }
        for bad in [
            "{",
            "{\"a\":}",
            "[1,]",
            "{\"a\" 1}",
            "{\"a\":1} trailing",
            "\"unterminated",
            "{\"bad\\escape\":1}",
            "nope",
        ] {
            assert!(json_well_formed(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn prometheus_validator_rejects_malformed() {
        assert!(validate_prometheus("metric_a 1\n").is_ok());
        assert!(validate_prometheus("bad line without value-number x\n").is_err());
        assert!(validate_prometheus("9leading_digit 1\n").is_err());
        assert!(validate_prometheus("# BOGUS comment\n").is_err());
        // a TYPE histogram with no +Inf bucket is a shape error
        let partial = "# TYPE h histogram\nh_bucket{le=\"0.1\"} 1\nh_sum 0.1\nh_count 1\n";
        assert!(validate_prometheus(partial).is_err());
    }

    #[test]
    fn metrics_listener_serves_rendered_text() {
        use std::net::TcpStream;
        let srv = match MetricsServer::serve_text("127.0.0.1:0", || "m_total 7\n".to_string()) {
            Ok(s) => s,
            // sandboxed environments may forbid binding; the feature is
            // optional, so skip rather than fail
            Err(_) => return,
        };
        let addr = srv.local_addr();
        let mut resp = String::new();
        {
            let mut conn = TcpStream::connect(addr).unwrap();
            conn.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n")
                .unwrap();
            conn.read_to_string(&mut resp).unwrap();
        }
        assert!(resp.starts_with("HTTP/1.1 200 OK"));
        assert!(resp.contains("text/plain"));
        assert!(resp.ends_with("m_total 7\n"));
        srv.stop();
    }
}
