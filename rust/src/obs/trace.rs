//! Bounded, lock-light trace-event journal for the serving stack.
//!
//! A [`Trace`] is a preallocated ring buffer of fixed-size [`Event`]
//! records. Recording an event is one short mutex hold and **zero
//! allocations** — the ring is sized at construction and overwrites its
//! oldest entry when full (the `dropped` counter reports how many were
//! lost). That makes it safe to leave tracing always-on in the fused
//! decode hot loop, which the counting-allocator integration test pins.
//!
//! The journal records three families of activity on separate tracks
//! (Perfetto rows after export):
//! - **request lifecycle** (one track per request id): queued →
//!   validated → admitted → prefill → sampled fused decode steps →
//!   preemption / replay / fault / expiry → done;
//! - **kvpool**: page alloc, copy-on-write, eviction, budget overrun;
//! - **worker**: respawn after a panic, shutdown drain.
//!
//! Per-step and per-site GEMM spans are *sampled* (every Nth fused step,
//! one atomic decision per step) so steady-state decode pays a few ring
//! pushes per sampled step and nothing otherwise. Timestamps come from
//! [`Clock`] — wall-monotonic in production, manually advanced in tests.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::clock::Clock;

/// Track id for the worker / scheduler row.
pub const TRACK_WORKER: u64 = 1;
/// Track id for the KV pool row.
pub const TRACK_POOL: u64 = 2;
/// Track id for the engine (per-site GEMM spans) row.
pub const TRACK_ENGINE: u64 = 3;
/// Requests get their own rows: track = `REQ_TRACK_BASE + request id`.
pub const REQ_TRACK_BASE: u64 = 1000;

/// Track id for a request's lifecycle row.
pub fn req_track(id: u64) -> u64 {
    REQ_TRACK_BASE.saturating_add(id)
}

/// Which weight site a sampled GEMM span covers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum SiteTag {
    Q,
    K,
    V,
    O,
    Up,
    Down,
    Head,
}

impl SiteTag {
    pub fn name(self) -> &'static str {
        match self {
            SiteTag::Q => "wq",
            SiteTag::K => "wk",
            SiteTag::V => "wv",
            SiteTag::O => "wo",
            SiteTag::Up => "w_up",
            SiteTag::Down => "w_down",
            SiteTag::Head => "head",
        }
    }
}

/// Which execution backend served a sampled `SiteGemm` span — the
/// dequantized fp32 matmul, the packed integer-decode GEMM
/// (`quant::qgemm`), or the hierarchical LUT inner-product backend
/// (`quant::lut`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum GemmPath {
    Fp,
    Packed,
    Lut,
}

impl GemmPath {
    pub fn name(self) -> &'static str {
        match self {
            GemmPath::Fp => "fp",
            GemmPath::Packed => "packed",
            GemmPath::Lut => "lut",
        }
    }
}

/// Fixed-size event payloads — every variant is `Copy` so a ring push
/// never allocates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// request entered the inbound queue
    Queued,
    /// request passed admission validation
    Validated,
    /// request failed validation and was rejected
    Rejected,
    /// request admitted to the live set (span duration = 0; the queue
    /// wait is carried in the payload so it survives sampling)
    Admitted { queue_wait_us: u64, replayed: bool },
    /// prefill span over the prompt (or replay after preemption)
    Prefill { tokens: u32 },
    /// one fused decode step over `batch` live sessions (sampled)
    DecodeStep { batch: u32 },
    /// one site's GEMM inside a sampled fused step, attributed to the
    /// backend that served it and the SIMD dispatch tier it ran on
    SiteGemm {
        layer: u16,
        site: SiteTag,
        backend: GemmPath,
        kernel: crate::quant::Kernel,
    },
    /// request preempted under pool pressure (pages released, requeued)
    Preempted,
    /// request deadline expired (shed from queue or mid-generation)
    Expired,
    /// request failed with a contained fault
    Fault,
    /// request completed with `tokens` generated
    Done { tokens: u32 },
    /// kvpool: fresh page allocated
    PageAlloc,
    /// kvpool: shared page copied on write
    PageCow,
    /// kvpool: index-only page evicted for headroom
    PageEvict,
    /// kvpool: allocation forced the pool past its byte budget
    BudgetOverrun,
    /// worker panicked and was respawned by the supervisor
    WorkerRespawn,
    /// shutdown drain finished with `undrained` requests unserved
    ShutdownDrain { undrained: u32 },
}

impl EventKind {
    /// Stable event name (Chrome trace `name` field).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Queued => "queued",
            EventKind::Validated => "validated",
            EventKind::Rejected => "rejected",
            EventKind::Admitted { .. } => "admitted",
            EventKind::Prefill { .. } => "prefill",
            EventKind::DecodeStep { .. } => "decode_step",
            EventKind::SiteGemm { .. } => "site_gemm",
            EventKind::Preempted => "preempted",
            EventKind::Expired => "expired",
            EventKind::Fault => "fault",
            EventKind::Done { .. } => "done",
            EventKind::PageAlloc => "page_alloc",
            EventKind::PageCow => "page_cow",
            EventKind::PageEvict => "page_evict",
            EventKind::BudgetOverrun => "budget_overrun",
            EventKind::WorkerRespawn => "worker_respawn",
            EventKind::ShutdownDrain { .. } => "shutdown_drain",
        }
    }

    /// Chrome trace category for filtering in the Perfetto UI.
    pub fn category(self) -> &'static str {
        match self {
            EventKind::Queued
            | EventKind::Validated
            | EventKind::Rejected
            | EventKind::Admitted { .. }
            | EventKind::Prefill { .. }
            | EventKind::Preempted
            | EventKind::Expired
            | EventKind::Fault
            | EventKind::Done { .. } => "request",
            EventKind::DecodeStep { .. } | EventKind::SiteGemm { .. } => "engine",
            EventKind::PageAlloc
            | EventKind::PageCow
            | EventKind::PageEvict
            | EventKind::BudgetOverrun => "kvpool",
            EventKind::WorkerRespawn | EventKind::ShutdownDrain { .. } => "worker",
        }
    }
}

/// One journal record. `dur_us == 0` renders as an instant event,
/// anything else as a complete span starting at `ts_us`.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub ts_us: u64,
    pub dur_us: u64,
    pub track: u64,
    pub kind: EventKind,
}

struct Ring {
    buf: Vec<Event>,
    /// next write position
    head: usize,
    /// live entries (saturates at capacity)
    len: usize,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, e: Event) {
        let cap = self.buf.len();
        if cap == 0 {
            self.dropped += 1;
            return;
        }
        self.buf[self.head] = e;
        self.head = (self.head + 1) % cap;
        if self.len < cap {
            self.len += 1;
        } else {
            self.dropped += 1;
        }
    }
}

/// Bounded trace journal. Cheap to share behind an `Arc`; all methods
/// take `&self`.
pub struct Trace {
    clock: Clock,
    ring: Mutex<Ring>,
    /// record DecodeStep/SiteGemm spans on every Nth fused step
    sample_every: u64,
    step_counter: AtomicU64,
}

/// Default ring capacity (events). 8192 × 40 B ≈ 320 KiB.
pub const DEFAULT_CAPACITY: usize = 8192;
/// Default decode-step sampling period.
pub const DEFAULT_SAMPLE_EVERY: u64 = 16;

/// Trace sizing carried inside
/// [`ServerConfig`](crate::coordinator::server::ServerConfig): how many
/// events the ring holds and how often fused decode steps are sampled.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// ring capacity in events (0 disables recording; pushes count as
    /// dropped)
    pub capacity: usize,
    /// record DecodeStep/SiteGemm spans on every Nth fused step
    /// (clamped to ≥ 1)
    pub sample_every: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            capacity: DEFAULT_CAPACITY,
            sample_every: DEFAULT_SAMPLE_EVERY,
        }
    }
}

impl TraceConfig {
    /// Build the journal this config describes, stamped by `clock`.
    pub fn build(self, clock: Clock) -> Trace {
        Trace::new(self.capacity, self.sample_every, clock)
    }
}

impl Default for Trace {
    fn default() -> Self {
        Self::new(DEFAULT_CAPACITY, DEFAULT_SAMPLE_EVERY, Clock::wall())
    }
}

impl Trace {
    /// A journal holding at most `capacity` events, sampling decode
    /// steps every `sample_every` (clamped to ≥ 1).
    pub fn new(capacity: usize, sample_every: u64, clock: Clock) -> Self {
        let zero = Event {
            ts_us: 0,
            dur_us: 0,
            track: 0,
            kind: EventKind::Queued,
        };
        Trace {
            clock,
            ring: Mutex::new(Ring {
                buf: vec![zero; capacity],
                head: 0,
                len: 0,
                dropped: 0,
            }),
            sample_every: sample_every.max(1),
            step_counter: AtomicU64::new(0),
        }
    }

    /// A deterministic journal for tests: manual clock, sample every
    /// step.
    pub fn manual(capacity: usize) -> Self {
        Self::new(capacity, 1, Clock::manual())
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Current timestamp; also the way to open a span (`let t0 =
    /// trace.now(); ...; trace.span(track, kind, t0);`).
    pub fn now(&self) -> u64 {
        self.clock.now_us()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Ring> {
        self.ring.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Record an instant event stamped now.
    pub fn instant(&self, track: u64, kind: EventKind) {
        let ts = self.now();
        self.lock().push(Event {
            ts_us: ts,
            dur_us: 0,
            track,
            kind,
        });
    }

    /// Record a complete span that started at `start_us` (from
    /// [`Self::now`]) and ends now.
    pub fn span(&self, track: u64, kind: EventKind, start_us: u64) {
        let end = self.now();
        self.lock().push(Event {
            ts_us: start_us,
            dur_us: end.saturating_sub(start_us),
            track,
            kind,
        });
    }

    /// One sampling decision per fused decode step: true on every Nth
    /// call. A single relaxed atomic — the unsampled path does no other
    /// work.
    pub fn sample_step(&self) -> bool {
        self.step_counter.fetch_add(1, Ordering::Relaxed) % self.sample_every == 0
    }

    /// Events currently held, oldest first.
    pub fn snapshot(&self) -> Vec<Event> {
        let ring = self.lock();
        let cap = ring.buf.len();
        let mut out = Vec::with_capacity(ring.len);
        if cap == 0 {
            return out;
        }
        let start = (ring.head + cap - ring.len) % cap;
        for i in 0..ring.len {
            out.push(ring.buf[(start + i) % cap]);
        }
        out
    }

    /// Live entry count (≤ capacity).
    pub fn len(&self) -> usize {
        self.lock().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events overwritten because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.lock().dropped
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_keeps_newest_in_order() {
        let cap = 64;
        let t = Trace::manual(cap);
        let total = 3 * cap as u64;
        for i in 0..total {
            t.clock().advance_us(1);
            t.instant(TRACK_WORKER, EventKind::Done { tokens: i as u32 });
        }
        assert_eq!(t.len(), cap, "ring must saturate at capacity");
        assert_eq!(t.dropped(), total - cap as u64);
        let snap = t.snapshot();
        assert_eq!(snap.len(), cap);
        // newest `cap` events survive, oldest first, timestamps strictly
        // increasing under the 1 µs-per-event manual clock
        for (j, e) in snap.iter().enumerate() {
            let expect_i = total - cap as u64 + j as u64;
            assert_eq!(e.ts_us, expect_i + 1, "event {j} out of order");
            match e.kind {
                EventKind::Done { tokens } => assert_eq!(tokens as u64, expect_i),
                other => panic!("unexpected kind {other:?}"),
            }
        }
    }

    #[test]
    fn span_records_duration_from_start() {
        let t = Trace::manual(8);
        let t0 = t.now();
        t.clock().advance_us(250);
        t.span(req_track(3), EventKind::Prefill { tokens: 12 }, t0);
        let snap = t.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].ts_us, 0);
        assert_eq!(snap[0].dur_us, 250);
        assert_eq!(snap[0].track, REQ_TRACK_BASE + 3);
    }

    #[test]
    fn sampling_fires_every_nth_step() {
        let t = Trace::new(8, 4, Clock::manual());
        let hits: Vec<bool> = (0..12).map(|_| t.sample_step()).collect();
        assert_eq!(
            hits,
            vec![true, false, false, false, true, false, false, false, true, false, false, false]
        );
        // sample_every is clamped to >= 1
        let every = Trace::new(8, 0, Clock::manual());
        assert!((0..5).all(|_| every.sample_step()));
    }

    #[test]
    fn zero_capacity_drops_everything() {
        let t = Trace::manual(0);
        t.instant(TRACK_POOL, EventKind::PageAlloc);
        t.instant(TRACK_POOL, EventKind::PageEvict);
        assert_eq!(t.len(), 0);
        assert!(t.is_empty());
        assert_eq!(t.dropped(), 2);
        assert!(t.snapshot().is_empty());
    }

    #[test]
    fn kinds_have_stable_names_and_categories() {
        assert_eq!(EventKind::Queued.category(), "request");
        assert_eq!(EventKind::PageCow.category(), "kvpool");
        assert_eq!(EventKind::WorkerRespawn.category(), "worker");
        assert_eq!(
            EventKind::SiteGemm {
                layer: 0,
                site: SiteTag::Q,
                backend: GemmPath::Packed,
                kernel: crate::quant::Kernel::Scalar
            }
            .category(),
            "engine"
        );
        assert_eq!(SiteTag::Down.name(), "w_down");
        assert_eq!(GemmPath::Fp.name(), "fp");
        assert_eq!(GemmPath::Packed.name(), "packed");
        assert_eq!(GemmPath::Lut.name(), "lut");
        assert_eq!(
            EventKind::Admitted {
                queue_wait_us: 1,
                replayed: false
            }
            .name(),
            "admitted"
        );
    }
}
