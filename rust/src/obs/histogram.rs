//! Fixed-size log-bucketed latency histograms (HDR-style).
//!
//! A [`LogHistogram`] stores microsecond samples in a **bounded** bucket
//! array: exact 1 µs buckets below 16 µs, then 16 sub-buckets per
//! power-of-two octave up to `u64::MAX`. Memory is a fixed
//! [`LogHistogram::N_BUCKETS`] counters regardless of how many samples
//! are recorded — this is what replaced the serving coordinator's
//! unbounded `Vec<f64>` of request latencies. The bucketing guarantees a
//! relative quantile error below 1/16 (6.25%): every sample lands in a
//! bucket whose width is less than 1/16 of its lower bound.
//!
//! Histograms are mergeable (element-wise bucket addition — the parallel
//! aggregation property Prometheus and HDR both rely on), and the
//! quantile estimator is rank-exact at the bucket level: the reported
//! value is the containing bucket's upper bound clamped to the true
//! maximum, so `quantile(q)` never under-reports and over-reports by at
//! most one bucket width. `util::propcheck` pins this against exact
//! sorted quantiles.

/// Sub-buckets per octave (and the linear range below the first octave).
const SUBS: usize = 16;
const SUBS_LOG: u32 = 4;

/// Bounded log-bucketed histogram over `u64` microsecond values.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: Vec<u64>,
    count: u64,
    sum_us: u64,
    min_us: u64,
    max_us: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    /// Total bucket count: 16 exact sub-16 µs buckets + 16 sub-buckets
    /// for each of the 60 octaves `[2^4, 2^64)`. Fixed at construction —
    /// the histogram never grows.
    pub const N_BUCKETS: usize = SUBS + (64 - SUBS_LOG as usize) * SUBS;

    pub fn new() -> Self {
        LogHistogram {
            counts: vec![0; Self::N_BUCKETS],
            count: 0,
            sum_us: 0,
            min_us: u64::MAX,
            max_us: 0,
        }
    }

    fn bucket_of(v: u64) -> usize {
        if v < SUBS as u64 {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros(); // >= SUBS_LOG
        let shift = msb - SUBS_LOG;
        SUBS + (shift as usize) * SUBS + ((v >> shift) as usize & (SUBS - 1))
    }

    /// Inclusive `[lower, upper]` value range of bucket `i`.
    fn bucket_bounds(i: usize) -> (u64, u64) {
        if i < SUBS {
            return (i as u64, i as u64);
        }
        let octave = ((i - SUBS) / SUBS) as u32;
        let sub = ((i - SUBS) % SUBS) as u64;
        let lower = (SUBS as u64 + sub) << octave;
        let width = 1u64 << octave;
        (lower, lower + (width - 1))
    }

    /// Record one sample (microseconds).
    pub fn record(&mut self, us: u64) {
        self.counts[Self::bucket_of(us)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
    }

    /// Record a [`std::time::Duration`] sample.
    pub fn record_duration(&mut self, d: std::time::Duration) {
        self.record(u64::try_from(d.as_micros()).unwrap_or(u64::MAX));
    }

    /// Element-wise merge (bucket addition) — order-independent, the
    /// property that makes per-thread or per-shard histograms cheap to
    /// aggregate.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    pub fn max_us(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.max_us
        }
    }

    pub fn min_us(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min_us
        }
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Rank-based quantile estimate in microseconds: the value of rank
    /// `ceil(q·count)` (1-based, nearest-rank definition), reported as
    /// its bucket's upper bound clamped to the recorded maximum. Never
    /// below the exact nearest-rank quantile; above it by at most one
    /// bucket width (< 1/16 relative).
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Self::bucket_bounds(i).1.min(self.max_us);
            }
        }
        self.max_us
    }

    /// Samples with value ≤ `bound_us`, counting whole buckets (exact
    /// whenever `bound_us` is a bucket boundary — in particular at every
    /// power of two ≥ 16, which is what the Prometheus `le` ladder
    /// uses); otherwise a conservative undercount by part of one bucket.
    pub fn count_le(&self, bound_us: u64) -> u64 {
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if Self::bucket_bounds(i).1 <= bound_us {
                cum += c;
            }
        }
        cum
    }

    /// The standard percentile summary in milliseconds.
    pub fn summary_ms(&self) -> HistSummary {
        HistSummary {
            count: self.count,
            p50_ms: self.quantile_us(0.50) as f64 / 1e3,
            p90_ms: self.quantile_us(0.90) as f64 / 1e3,
            p99_ms: self.quantile_us(0.99) as f64 / 1e3,
            max_ms: self.max_us() as f64 / 1e3,
            mean_ms: self.mean_us() / 1e3,
        }
    }
}

/// p50/p90/p99/max/mean snapshot of one histogram, in milliseconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct HistSummary {
    pub count: u64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    pub mean_ms: f64,
}

impl HistSummary {
    /// `p50/p90/p99/max` rendered compactly for the serving report line.
    pub fn render(&self) -> String {
        format!(
            "p50={:.1} p90={:.1} p99={:.1} max={:.1}ms",
            self.p50_ms, self.p90_ms, self.p99_ms, self.max_ms
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    /// Exact nearest-rank quantile of a sorted sample set.
    fn exact_quantile(sorted: &[u64], q: f64) -> u64 {
        let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
        sorted[rank - 1]
    }

    #[test]
    fn bucket_layout_is_contiguous_and_ordered() {
        // every value maps into a bucket whose bounds contain it, bucket
        // ranges tile the u64 line in order, and relative width < 1/16
        let mut prev_upper: Option<u64> = None;
        for i in 0..LogHistogram::N_BUCKETS {
            let (lo, hi) = LogHistogram::bucket_bounds(i);
            assert!(lo <= hi, "bucket {i}: {lo} > {hi}");
            if let Some(p) = prev_upper {
                assert_eq!(lo, p.wrapping_add(1), "gap/overlap at bucket {i}");
            }
            prev_upper = Some(hi);
            if lo >= SUBS as u64 {
                assert!(
                    (hi - lo) as f64 / lo as f64 <= 1.0 / SUBS as f64,
                    "bucket {i} too wide: [{lo}, {hi}]"
                );
            }
        }
        assert_eq!(prev_upper, Some(u64::MAX), "buckets must cover all of u64");
        for v in [0u64, 1, 15, 16, 17, 31, 32, 1000, 1 << 20, u64::MAX] {
            let i = LogHistogram::bucket_of(v);
            let (lo, hi) = LogHistogram::bucket_bounds(i);
            assert!(lo <= v && v <= hi, "value {v} outside its bucket [{lo}, {hi}]");
        }
    }

    #[test]
    fn quantiles_match_exact_within_bucket_error_propcheck() {
        // the satellite acceptance test: histogram quantiles vs exact
        // sorted nearest-rank quantiles, within the bucket error bound
        // (never below; above by at most lower/16 + 1)
        propcheck::check("histogram-quantiles", 24, 0x41570, |rng| {
            let n = 1 + rng.below(3000);
            // mix magnitudes so many octaves are exercised
            let mut xs: Vec<u64> = (0..n)
                .map(|_| {
                    let octave = rng.below(30) as u32;
                    (rng.below(1 << 16) as u64) << octave >> 12
                })
                .collect();
            let mut h = LogHistogram::new();
            for &x in &xs {
                h.record(x);
            }
            xs.sort_unstable();
            for &q in &[0.0, 0.01, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
                let exact = exact_quantile(&xs, q);
                let est = h.quantile_us(q);
                if est < exact {
                    return Err(format!("q={q}: estimate {est} below exact {exact}"));
                }
                let slack = exact / SUBS as u64 + 1;
                if est > exact + slack {
                    return Err(format!(
                        "q={q}: estimate {est} above exact {exact} + slack {slack}"
                    ));
                }
            }
            if h.max_us() != *xs.last().ok_or("empty")? {
                return Err("max is exact by construction".into());
            }
            if h.min_us() != xs[0] {
                return Err("min is exact by construction".into());
            }
            Ok(())
        });
    }

    #[test]
    fn merge_equals_recording_into_one() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut whole = LogHistogram::new();
        for i in 0..500u64 {
            let v = i * i % 7919;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            whole.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.sum_us(), whole.sum_us());
        assert_eq!(a.max_us(), whole.max_us());
        assert_eq!(a.min_us(), whole.min_us());
        for q in [0.1, 0.5, 0.77, 0.99] {
            assert_eq!(a.quantile_us(q), whole.quantile_us(q), "q={q}");
        }
    }

    #[test]
    fn memory_is_bounded_regardless_of_sample_count() {
        let mut h = LogHistogram::new();
        let baseline = h.counts.capacity();
        for i in 0..200_000u64 {
            h.record(i % 100_000);
        }
        assert_eq!(h.count(), 200_000);
        assert_eq!(
            h.counts.capacity(),
            baseline,
            "bucket storage must never grow"
        );
        assert_eq!(baseline, LogHistogram::N_BUCKETS);
    }

    #[test]
    fn count_le_is_exact_at_power_of_two_bounds() {
        let mut h = LogHistogram::new();
        let xs: Vec<u64> = (0..4096).map(|i| (i * 37) % 10_000).collect();
        for &x in &xs {
            h.record(x);
        }
        for bound in [16u64, 64, 256, 1024, 4096, 8192, 16384] {
            let exact = xs.iter().filter(|&&x| x <= bound).count() as u64;
            // power-of-two bounds are bucket boundaries minus one... the
            // ladder uses `le` semantics on bound-1 of the next octave:
            // bucket upper bounds are 2^k - 1, so query at bound-1
            assert_eq!(
                h.count_le(bound - 1),
                xs.iter().filter(|&&x| x < bound).count() as u64,
                "bound {bound}"
            );
            assert!(h.count_le(bound) <= exact, "count_le must never overcount");
        }
        assert_eq!(h.count_le(u64::MAX), h.count());
    }

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_us(0.5), 0);
        assert_eq!(h.max_us(), 0);
        assert_eq!(h.min_us(), 0);
        assert_eq!(h.mean_us(), 0.0);
        let s = h.summary_ms();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99_ms, 0.0);
    }
}
