//! Observability: request-level tracing, bounded latency histograms,
//! and Perfetto/Prometheus export for the serving stack.
//!
//! The serving path needs *attribution* — which phase, which site,
//! which lane burns the time — not just end-of-run counters. This
//! module provides the three pieces and stays strictly bounded in
//! memory so it can run always-on under production traffic:
//!
//! - [`clock::Clock`] — microsecond timestamps, wall-monotonic in
//!   production and manually advanced in tests, so every trace and
//!   histogram assertion is deterministic.
//! - [`trace::Trace`] — a preallocated ring-buffer event journal of
//!   fixed-size [`trace::Event`] records covering the request
//!   lifecycle (queued → admitted → prefill → sampled decode steps →
//!   preempt/fault/expiry → done), kvpool activity (alloc / COW /
//!   evict / budget overrun), and worker supervision (respawn,
//!   shutdown drain). Pushes never allocate; the fused decode hot loop
//!   stays zero-alloc with tracing enabled (pinned by the
//!   counting-allocator integration test).
//! - [`histogram::LogHistogram`] — fixed-size HDR-style log-bucketed
//!   histograms (< 1/16 relative quantile error, mergeable) for queue
//!   wait, TTFT, inter-token latency, prefill and fused-step time.
//!   This type replaced the coordinator's unbounded latency `Vec`.
//! - [`export`] — Chrome trace-event JSON (open in
//!   <https://ui.perfetto.dev>) and Prometheus text exposition,
//!   written via `serve --trace-out/--metrics-out` or served from the
//!   std-only [`export::MetricsServer`]; shape validators back the
//!   `make trace-smoke` gate.

#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod clock;
pub mod export;
pub mod histogram;
pub mod trace;

pub use clock::Clock;
pub use export::{
    chrome_trace_json, validate_chrome_trace, validate_prometheus, MetricsServer, PromWriter,
};
pub use histogram::{HistSummary, LogHistogram};
pub use trace::{Event, EventKind, GemmPath, SiteTag, Trace, TraceConfig};
