//! Matrix/vector quantization built on the lattice engine.
//!
//! * [`matrix`] — NestQuant matrix quantization (§4.2): per-row L2
//!   normalization + blockwise multi-β Voronoi coding.
//! * [`qgemm`] — quantized GEMV/GEMM: decode-on-the-fly dot products,
//!   packed 4-bit storage, and the integer-accumulation path (§3
//!   "Using int8-multipliers", Appendix E).
//! * [`gemm`] — the decode-amortized GEMM kernel core shared by the
//!   packed formats: activation-panel packing, the 8×NC microkernel, and
//!   the row-partitioned `std::thread::scope` driver.
//! * [`kernels`] — runtime-dispatched SIMD tiers (scalar/AVX2/NEON) for
//!   the microkernel, the block decode, and the LUT block dots; picked
//!   once per process, overridable via `NESTQUANT_KERNEL`.
//! * [`lut`] — the LUT inner-product GEMM backend: M-level hierarchical
//!   weight indices + the shared pair LUT (`lattice::hierarchical`), so
//!   C = A·Bᵀ is computed by table lookups with no decoded rows.
//! * [`uniform`] — the uniform scalar baseline with L∞ scaling (cubic
//!   shaping; what SpinQuant/QuaRot use) and packed int4 GEMV.
//! * [`ldlq`] — LDLQ feedback weight quantization (§4.5, Appendix B).
//! * [`qaldlq`] — QA-LDLQ for quantized activations (Lemma 4.2) and the
//!   amplification-ratio diagnostics of Appendix B.
//! * [`plan`] — per-site quantization policy: `SiteId → SitePolicy`
//!   resolution (`QuantPlan`), the fluent `EngineBuilder`, and the
//!   `.qplan` text format for mixed-precision deployments.

pub mod gemm;
pub mod kernels;
pub mod ldlq;
pub mod lut;
pub mod matrix;
pub mod plan;
pub mod qaldlq;
pub mod qgemm;
pub mod uniform;

pub use kernels::Kernel;
pub use lut::{LutScratch, PackedLutMatrix};
pub use matrix::QuantizedMatrix;
pub use plan::{
    EngineBuilder, GemmBackend, PlanFileError, PolicyPatch, QuantPlan, SiteId, SiteKind,
    SitePolicy, SiteRole, SiteSelector,
};
pub use uniform::UniformQuantizer;
