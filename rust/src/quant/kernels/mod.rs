//! Runtime-dispatched SIMD kernels for the decode and LUT GEMM backends.
//!
//! Three tiers serve the same three operations — the 8×PANEL f32
//! microkernel ([`row_times_panels`]), the branch-free NestQuantM block
//! decode ([`decode_block`] / [`decode_nibble_row`]), and the per-block
//! pair-LUT dots ([`lut_block_dots`]):
//!
//! * **scalar** — the portable reference; exactly the loops that served
//!   production before this module existed.
//! * **avx2** — x86_64, gated on runtime `avx2` + `fma` cpuid detection;
//!   8-lane f32/i32 vectors, hardware gathers for the LUT table walk.
//! * **neon** — aarch64 (NEON is baseline there); 4-lane vectors.
//!
//! The tier is picked **once** per process ([`active`], cached in a
//! `OnceLock`): best supported tier by default, overridable with the
//! `NESTQUANT_KERNEL=scalar|avx2|neon` environment knob so every tier is
//! testable on one host (`make test-kernels` runs the suite once per
//! tier). Requesting a tier the host can't run falls back to
//! auto-detection with a one-line warning — a typo in a deployment env
//! file must cost speed, not the server.
//!
//! Parity contract (enforced by the propchecks below and re-proven
//! end-to-end by the gemm≡gemv suites in `quant::{qgemm, lut}`): the f32
//! microkernel is **bitwise identical** across tiers — lane-parallel
//! accumulation preserves the scalar per-column reduction order and no
//! tier uses FMA contraction (single-rounding fused multiply-add would
//! silently diverge from the scalar mul-then-add) — and the integer
//! decode / LUT paths are exact, being i32 arithmetic in the same
//! operation order. So switching tiers never changes a single output
//! bit anywhere in the stack, which is what lets one env knob flip the
//! whole serving path without invalidating any golden output.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub(crate) mod scalar;

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;

use std::sync::OnceLock;

use crate::lattice::e8::D;
use crate::lattice::hierarchical::PairLut;
use crate::quant::qgemm::DecodeConsts;

/// Environment knob forcing a dispatch tier (`scalar|avx2|neon`).
pub const ENV_KERNEL: &str = "NESTQUANT_KERNEL";

/// A dispatch tier. `repr(u8)` indices are stable — they are what the
/// bench sweep records in the BENCH_gemm.json `kernel` column (0 =
/// scalar, 1 = avx2, 2 = neon) and what trace exports name.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Kernel {
    Scalar = 0,
    Avx2 = 1,
    Neon = 2,
}

impl Kernel {
    pub const ALL: [Kernel; 3] = [Kernel::Scalar, Kernel::Avx2, Kernel::Neon];

    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
        }
    }

    /// Stable numeric id for bench/metric columns.
    pub fn index(self) -> u8 {
        self as u8
    }

    pub fn parse(s: &str) -> Option<Kernel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Kernel::Scalar),
            "avx2" => Some(Kernel::Avx2),
            "neon" => Some(Kernel::Neon),
            _ => None,
        }
    }

    /// Whether this tier can run on the current host. Scalar always
    /// can; AVX2 needs runtime `avx2` + `fma` cpuid bits on x86_64;
    /// NEON is architecturally mandatory on aarch64.
    pub fn supported(self) -> bool {
        match self {
            Kernel::Scalar => true,
            Kernel::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                        && std::arch::is_x86_feature_detected!("fma")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            Kernel::Neon => cfg!(target_arch = "aarch64"),
        }
    }
}

/// Best tier the host supports (no env override).
fn detect() -> Kernel {
    if Kernel::Avx2.supported() {
        Kernel::Avx2
    } else if Kernel::Neon.supported() {
        Kernel::Neon
    } else {
        Kernel::Scalar
    }
}

/// Every tier the host supports, scalar first — the iteration set for
/// cross-tier parity tests and the bench sweep's kernel column.
pub fn available() -> Vec<Kernel> {
    Kernel::ALL.iter().copied().filter(|k| k.supported()).collect()
}

/// Resolve the active tier from an (optional) env override — pure so
/// the fallback rules are unit-testable without touching process env.
fn resolve(env: Option<&str>) -> Kernel {
    let auto = detect();
    let Some(v) = env.map(str::trim).filter(|v| !v.is_empty()) else {
        return auto;
    };
    match Kernel::parse(v) {
        Some(k) if k.supported() => k,
        Some(k) => {
            eprintln!(
                "{ENV_KERNEL}={v}: tier '{}' is not supported on this host, \
                 falling back to '{}'",
                k.name(),
                auto.name()
            );
            auto
        }
        None => {
            eprintln!(
                "{ENV_KERNEL}={v}: unknown tier (expected scalar|avx2|neon), \
                 falling back to '{}'",
                auto.name()
            );
            auto
        }
    }
}

/// The process-wide active tier, resolved once: `NESTQUANT_KERNEL` if
/// set and supported, else the best detected tier. Hot paths call this
/// once per GEMM/stream call (a cached atomic load), not per block.
pub fn active() -> Kernel {
    static ACTIVE: OnceLock<Kernel> = OnceLock::new();
    *ACTIVE.get_or_init(|| resolve(std::env::var(ENV_KERNEL).ok().as_deref()))
}

#[inline]
#[track_caller]
fn require(k: Kernel) {
    // Dispatching an unsupported tier would execute illegal instructions
    // (UB), so the explicit-tier entry points are hard-gated. supported()
    // is a cached cpuid read — one atomic load per kernel call.
    assert!(
        k.supported(),
        "kernel tier '{}' is not supported on this host",
        k.name()
    );
}

/// The 8×PANEL microkernel: one decoded weight row times the packed
/// activation panels (see `quant::gemm::pack_panels` for the layout).
/// Output is bitwise identical across tiers.
#[inline]
pub fn row_times_panels(
    k: Kernel,
    ebuf: &[i16],
    bscale: &[f32],
    xp: &[f32],
    batch: usize,
    row_scale: f32,
    out_row: &mut [f32],
) {
    require(k);
    match k {
        Kernel::Scalar => scalar::row_times_panels(ebuf, bscale, xp, batch, row_scale, out_row),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: require(k) verified the avx2+fma cpuid bits
        Kernel::Avx2 => unsafe {
            avx2::row_times_panels(ebuf, bscale, xp, batch, row_scale, out_row)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 (require(k) passed)
        Kernel::Neon => unsafe {
            neon::row_times_panels(ebuf, bscale, xp, batch, row_scale, out_row)
        },
        #[allow(unreachable_patterns)] // cross-arch variants: require() already rejected them
        _ => scalar::row_times_panels(ebuf, bscale, xp, batch, row_scale, out_row),
    }
}

/// Branch-free NestQuantM decode of one coset-code 8-block into
/// half-unit i32 entries — the kvpool streaming-decode kernel. Exact
/// across tiers (integer arithmetic, same operation order).
#[inline]
pub fn decode_block(k: Kernel, consts: DecodeConsts, c: &[u8; D], out: &mut [i32; D]) {
    require(k);
    match k {
        Kernel::Scalar => scalar::decode_block(consts, c, out),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: require(k) verified the avx2+fma cpuid bits
        Kernel::Avx2 => unsafe { avx2::decode_block(consts, c, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 (require(k) passed)
        Kernel::Neon => unsafe { neon::decode_block(consts, c, out) },
        #[allow(unreachable_patterns)]
        _ => scalar::decode_block(consts, c, out),
    }
}

/// Decode a packed-nibble code row (4-bit codes, two per byte) into i16
/// half-unit entries — the per-row decode feeding the GEMM microkernel.
/// Exact across tiers.
#[inline]
pub fn decode_nibble_row(k: Kernel, consts: DecodeConsts, crow: &[u8], ebuf: &mut [i16]) {
    debug_assert_eq!(ebuf.len() % D, 0);
    debug_assert!(crow.len() * 2 >= ebuf.len());
    require(k);
    match k {
        Kernel::Scalar => scalar::decode_nibble_row(consts, crow, ebuf),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: require(k) verified the avx2+fma cpuid bits
        Kernel::Avx2 => unsafe { avx2::decode_nibble_row(consts, crow, ebuf) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 (require(k) passed)
        Kernel::Neon => unsafe { neon::decode_nibble_row(consts, crow, ebuf) },
        #[allow(unreachable_patterns)]
        _ => scalar::decode_nibble_row(consts, crow, ebuf),
    }
}

/// Per-block pair-LUT dots of one weight row against one encoded
/// activation row: `dots[j] = PairLut::block_dot(act_idx[j], widx[j])`,
/// gathered/batched on the SIMD tiers. Exact i32 across tiers inside
/// the `lut_supported` window.
#[inline]
pub fn lut_block_dots(
    k: Kernel,
    lut: &PairLut,
    m: usize,
    act_idx: &[u16],
    widx: &[u16],
    dots: &mut [i32],
) {
    debug_assert_eq!(act_idx.len(), dots.len() * m);
    debug_assert_eq!(widx.len(), dots.len() * m);
    require(k);
    match k {
        Kernel::Scalar => scalar::lut_block_dots(lut, m, act_idx, widx, dots),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: require(k) verified the avx2+fma cpuid bits
        Kernel::Avx2 => unsafe { avx2::lut_block_dots(lut, m, act_idx, widx, dots) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 (require(k) passed)
        Kernel::Neon => unsafe { neon::lut_block_dots(lut, m, act_idx, widx, dots) },
        #[allow(unreachable_patterns)]
        _ => scalar::lut_block_dots(lut, m, act_idx, widx, dots),
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::quant::gemm::{pack_panels, PANEL};
    use crate::util::linalg::Mat;
    use crate::util::{propcheck, Rng};

    #[test]
    fn kernel_names_parse_roundtrip() {
        for k in Kernel::ALL {
            assert_eq!(Kernel::parse(k.name()), Some(k));
            assert_eq!(Kernel::parse(&k.name().to_uppercase()), Some(k));
        }
        assert_eq!(Kernel::parse("sse9"), None);
        assert_eq!(Kernel::parse(""), None);
        assert_eq!(Kernel::Scalar.index(), 0);
        assert_eq!(Kernel::Avx2.index(), 1);
        assert_eq!(Kernel::Neon.index(), 2);
    }

    #[test]
    fn kernel_dispatch_resolution_rules() {
        // no override → best detected tier; that tier must be supported
        let auto = resolve(None);
        assert!(auto.supported());
        assert_eq!(resolve(Some("")), auto);
        assert_eq!(resolve(Some("   ")), auto);
        // unknown names fall back rather than fail
        assert_eq!(resolve(Some("avx512-please")), auto);
        // scalar is always honorable
        assert_eq!(resolve(Some("scalar")), Kernel::Scalar);
        assert_eq!(resolve(Some(" SCALAR ")), Kernel::Scalar);
        // a supported tier is honored, an unsupported one falls back
        for k in [Kernel::Avx2, Kernel::Neon] {
            let want = if k.supported() { k } else { auto };
            assert_eq!(resolve(Some(k.name())), want, "tier {}", k.name());
        }
        // the process-wide choice is one of the host's tiers
        assert!(active().supported());
        assert!(available().contains(&active()));
        assert_eq!(available()[0], Kernel::Scalar);
    }

    #[test]
    fn kernel_row_times_panels_bitwise_parity() {
        // every SIMD tier must match the scalar microkernel bit-for-bit
        // across block counts, ragged batches and scales — the guarantee
        // that lets gemm≡gemv propchecks keep their teeth whatever tier
        // dispatch picks.
        propcheck::check("kernel-rtp-parity", 20, 7101, |rng| {
            for &bpr in &[1usize, 2, 5] {
                for &batch in &[1usize, 7, PANEL, PANEL + 1, 2 * PANEL + 3] {
                    let cols = bpr * D;
                    let ebuf: Vec<i16> =
                        (0..cols).map(|_| rng.below(193) as i16 - 96).collect();
                    let bscale: Vec<f32> =
                        (0..bpr).map(|_| rng.gauss_f32() * 0.3 + 0.5).collect();
                    let row_scale = rng.gauss_f32() * 0.1 + 0.25;
                    let xt = Mat::from_vec(batch, cols, rng.gauss_vec(batch * cols));
                    let mut xp = Vec::new();
                    pack_panels(&xt, &mut xp);
                    let mut want = vec![0f32; batch];
                    row_times_panels(
                        Kernel::Scalar,
                        &ebuf,
                        &bscale,
                        &xp,
                        batch,
                        row_scale,
                        &mut want,
                    );
                    for k in available() {
                        let mut got = vec![0f32; batch];
                        row_times_panels(k, &ebuf, &bscale, &xp, batch, row_scale, &mut got);
                        for c in 0..batch {
                            if got[c].to_bits() != want[c].to_bits() {
                                return Err(format!(
                                    "tier {} bpr={bpr} batch={batch} col {c}: {} vs scalar {}",
                                    k.name(),
                                    got[c],
                                    want[c]
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn kernel_block_decode_exact_parity() {
        // the vectorized branch-free decode is pure integer arithmetic:
        // every tier must equal DecodeConsts::decode exactly, for every
        // q the packed formats serve.
        propcheck::check("kernel-decode-parity", 50, 7102, |rng| {
            for &q in &[2i32, 3, 8, 14, 16] {
                let consts = DecodeConsts::new(q);
                let mut c = [0u8; D];
                for v in c.iter_mut() {
                    *v = rng.below(q as usize) as u8;
                }
                let mut want = [0i32; D];
                consts.decode(&c, &mut want);
                for k in available() {
                    let mut got = [0i32; D];
                    decode_block(k, consts, &c, &mut got);
                    if got != want {
                        return Err(format!(
                            "tier {} q={q} code {c:?}: {got:?} vs scalar {want:?}",
                            k.name()
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn kernel_nibble_row_decode_exact_parity() {
        propcheck::check("kernel-nibble-row-parity", 30, 7103, |rng| {
            for &q in &[3i32, 14, 16] {
                for &bpr in &[1usize, 3, 7] {
                    let consts = DecodeConsts::new(q);
                    let cols = bpr * D;
                    let crow: Vec<u8> = (0..cols / 2)
                        .map(|_| {
                            let lo = rng.below(q as usize) as u8;
                            let hi = rng.below(q as usize) as u8;
                            lo | (hi << 4)
                        })
                        .collect();
                    let mut want = vec![0i16; cols];
                    decode_nibble_row(Kernel::Scalar, consts, &crow, &mut want);
                    for k in available() {
                        let mut got = vec![0i16; cols];
                        decode_nibble_row(k, consts, &crow, &mut got);
                        if got != want {
                            return Err(format!(
                                "tier {} q={q} bpr={bpr}: {got:?} vs scalar {want:?}",
                                k.name()
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn kernel_lut_block_dots_exact_parity() {
        // the gathered LUT path must reproduce PairLut::block_dot i32-
        // exactly for every supported (q, M) shape, including ragged
        // tails around the 4/8-block SIMD groups.
        let mut rng = Rng::new(7104);
        for &(q, m) in &[(2u32, 2usize), (2, 4), (2, 8), (3, 2), (3, 7)] {
            assert!(crate::lattice::hierarchical::lut_supported(q, m as u32));
            let lut = PairLut::shared(q);
            let n = lut.n as u32;
            for &bpr in &[1usize, 4, 8, 9, 17] {
                let act: Vec<u16> =
                    (0..bpr * m).map(|_| rng.below(n as usize) as u16).collect();
                let wid: Vec<u16> =
                    (0..bpr * m).map(|_| rng.below(n as usize) as u16).collect();
                let mut want = vec![0i32; bpr];
                lut_block_dots(Kernel::Scalar, &lut, m, &act, &wid, &mut want);
                for (j, w) in want.iter().enumerate() {
                    assert_eq!(
                        *w,
                        lut.block_dot(&act[j * m..(j + 1) * m], &wid[j * m..(j + 1) * m]),
                        "scalar tier must be block_dot verbatim"
                    );
                }
                for k in available() {
                    let mut got = vec![0i32; bpr];
                    lut_block_dots(k, &lut, m, &act, &wid, &mut got);
                    assert_eq!(
                        got,
                        want,
                        "tier {} q={q} M={m} bpr={bpr}",
                        k.name()
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "not supported on this host")]
    fn kernel_explicit_unsupported_tier_panics() {
        // the explicit-tier API must refuse (not UB) a tier the host
        // can't run; at least one of avx2/neon is always foreign.
        let foreign = if Kernel::Avx2.supported() {
            Kernel::Neon
        } else {
            Kernel::Avx2
        };
        let consts = DecodeConsts::new(4);
        let mut out = [0i32; D];
        decode_block(foreign, consts, &[0u8; D], &mut out);
    }
}
