//! NEON kernel tier (aarch64). NEON is architecturally mandatory on
//! aarch64, so `supported()` is a compile-target check; the functions
//! still carry `#[target_feature(enable = "neon")]` and are only called
//! through the `Kernel::Neon` match arms in `quant::kernels`.
//!
//! Bitwise contract: the f32 microkernel uses `vaddq_f32(…, vmulq_f32)`
//! — explicitly NOT `vfmaq_f32`/`vmlaq_f32`, whose fused single-rounding
//! FMLA would diverge from the scalar tier's mul-then-add double
//! rounding and break the scalar≡SIMD bitwise-parity propchecks. The
//! integer decode and LUT paths are exact i32 arithmetic in the scalar
//! tier's operation order, four lanes per instruction (two vectors per
//! 8-block). There is no gather on NEON; the LUT kernel loads table
//! entries scalar and vectorizes the radix accumulation, which still
//! lets the core issue the four loads of a lane group back-to-back.

use core::arch::aarch64::*;

use crate::lattice::e8::D;
use crate::lattice::hierarchical::PairLut;
use crate::quant::gemm::PANEL;
use crate::quant::qgemm::{gmul, DecodeConsts};

/// The 8×PANEL f32 microkernel, four 128-bit vectors covering the
/// PANEL=16 batch lanes; per-lane op sequence identical to scalar.
///
/// # Safety
/// Requires NEON (aarch64 baseline); same slice contract as scalar.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn row_times_panels(
    ebuf: &[i16],
    bscale: &[f32],
    xp: &[f32],
    batch: usize,
    row_scale: f32,
    out_row: &mut [f32],
) {
    let bpr = bscale.len();
    let n_panels = batch.div_ceil(PANEL);
    for p in 0..n_panels {
        let mut acc = [vdupq_n_f32(0.0); 4];
        for j in 0..bpr {
            let e = &ebuf[j * D..(j + 1) * D];
            let base = (p * bpr + j) * D * PANEL;
            let mut d = [vdupq_n_f32(0.0); 4];
            for (i, &ei) in e.iter().enumerate() {
                let ev = vdupq_n_f32(ei as f32);
                for (k, dk) in d.iter_mut().enumerate() {
                    let x = vld1q_f32(xp.as_ptr().add(base + i * PANEL + 4 * k));
                    // d += e·x as mul-then-add — NOT fused (see module docs)
                    *dk = vaddq_f32(*dk, vmulq_f32(ev, x));
                }
            }
            let b = vdupq_n_f32(bscale[j]);
            for (ak, &dk) in acc.iter_mut().zip(&d) {
                *ak = vaddq_f32(*ak, vmulq_f32(dk, b));
            }
        }
        let rs = vdupq_n_f32(row_scale);
        let mut lanes = [0f32; PANEL];
        for (k, &ak) in acc.iter().enumerate() {
            vst1q_f32(lanes.as_mut_ptr().add(4 * k), vmulq_f32(ak, rs));
        }
        let c0 = p * PANEL;
        let c_lim = (batch - c0).min(PANEL);
        out_row[c0..c0 + c_lim].copy_from_slice(&lanes[..c_lim]);
    }
}

/// floor(x / m) by magic multiply for non-negative lanes — the vector
/// form of `DecodeConsts::div_m`, exact over the decode range.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn div_m(x: int32x4_t, magic: uint32x4_t) -> int32x4_t {
    vreinterpretq_s32_u32(vshrq_n_u32::<21>(vmulq_u32(vreinterpretq_u32_s32(x), magic)))
}

/// Vectorized NestQuantM decode core over one 8-block, split into low
/// (coords 0–3) and high (coords 4–7) halves. Writes the chosen
/// half-unit residual to `out`, lane-exact vs [`DecodeConsts::decode`].
///
/// # Safety
/// Requires NEON.
#[target_feature(enable = "neon")]
unsafe fn decode_core(consts: DecodeConsts, c: &[u8; D], out: &mut [i32; D]) {
    let t_arr = gmul(c);
    let t_lo = vld1q_s32(t_arr.as_ptr());
    let t_hi = vld1q_s32(t_arr.as_ptr().add(4));
    let q = consts.q;
    let m = consts.m;
    let qv = vdupq_n_s32(q);
    let mv = vdupq_n_s32(m);
    let magic = vdupq_n_u32(consts.magic);

    let r1_lo = div_m(vaddq_s32(t_lo, qv), magic);
    let r1_hi = div_m(vaddq_s32(t_hi, qv), magic);
    let mut e1_lo = vsubq_s32(t_lo, vmulq_s32(mv, r1_lo));
    let e1_hi = vsubq_s32(t_hi, vmulq_s32(mv, r1_hi));
    let r2_lo = div_m(t_lo, magic);
    let r2_hi = div_m(t_hi, magic);
    let mut e2_lo = vsubq_s32(vsubq_s32(t_lo, qv), vmulq_s32(mv, r2_lo));
    let e2_hi = vsubq_s32(vsubq_s32(t_hi, qv), vmulq_s32(mv, r2_hi));
    let par1 = vaddvq_s32(r1_lo) + vaddvq_s32(r1_hi);
    let par2 = vaddvq_s32(r2_lo) + vaddvq_s32(r2_hi);

    // parity fix on coordinate 0 (low half, lane 0): e0 −= m·dir·(par&1)
    let fix1 = {
        let dir = 1 | (vgetq_lane_s32::<0>(e1_lo) >> 31);
        m * dir * (par1 & 1)
    };
    e1_lo = vsetq_lane_s32::<0>(vgetq_lane_s32::<0>(e1_lo) - fix1, e1_lo);
    let fix2 = {
        let dir = 1 | (vgetq_lane_s32::<0>(e2_lo) >> 31);
        m * dir * (par2 & 1)
    };
    e2_lo = vsetq_lane_s32::<0>(vgetq_lane_s32::<0>(e2_lo) - fix2, e2_lo);

    let cost1 = vaddvq_s32(vmulq_s32(e1_lo, e1_lo)) + vaddvq_s32(vmulq_s32(e1_hi, e1_hi));
    let cost2 = vaddvq_s32(vmulq_s32(e2_lo, e2_lo)) + vaddvq_s32(vmulq_s32(e2_hi, e2_hi));
    if cost1 <= cost2 {
        vst1q_s32(out.as_mut_ptr(), e1_lo);
        vst1q_s32(out.as_mut_ptr().add(4), e1_hi);
    } else {
        vst1q_s32(out.as_mut_ptr(), e2_lo);
        vst1q_s32(out.as_mut_ptr().add(4), e2_hi);
    }
}

/// Streaming-decode entry point (kvpool): one block, i32 out.
///
/// # Safety
/// Requires NEON.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn decode_block(consts: DecodeConsts, c: &[u8; D], out: &mut [i32; D]) {
    decode_core(consts, c, out);
}

/// Decode a packed-nibble code row into i16 entries: scalar nibble
/// unpack, vector decode core, saturating-narrow store (values bounded
/// by 2m ≪ i16::MAX, saturation never fires).
///
/// # Safety
/// Requires NEON; `crow.len() ≥ ebuf.len()/2` and `ebuf.len() % 8 == 0`.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn decode_nibble_row(consts: DecodeConsts, crow: &[u8], ebuf: &mut [i16]) {
    let bpr = ebuf.len() / D;
    let mut cbuf = [0u8; D];
    let mut e = [0i32; D];
    for j in 0..bpr {
        for b in 0..4 {
            let byte = crow[j * 4 + b];
            cbuf[2 * b] = byte & 0x0F;
            cbuf[2 * b + 1] = byte >> 4;
        }
        decode_core(consts, &cbuf, &mut e);
        let lo = vqmovn_s32(vld1q_s32(e.as_ptr()));
        let hi = vqmovn_s32(vld1q_s32(e.as_ptr().add(4)));
        vst1q_s16(ebuf.as_mut_ptr().add(j * D), vcombine_s16(lo, hi));
    }
}

/// Per-block LUT dots, four blocks per iteration: table entries are
/// loaded scalar (no NEON gather) into a lane group, the q-radix
/// weighting and accumulation run vectorized. Exact i32 per lane vs
/// [`PairLut::block_dot`].
///
/// # Safety
/// Requires NEON.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn lut_block_dots(
    lut: &PairLut,
    m: usize,
    act_idx: &[u16],
    widx: &[u16],
    dots: &mut [i32],
) {
    let bpr = dots.len();
    let n = lut.n;
    let q = lut.q as i32;
    let table = lut.table.as_slice();
    let mut j0 = 0usize;
    while j0 + 4 <= bpr {
        let mut acc = vdupq_n_s32(0);
        let mut wl = 1i32; // q^ℓ
        for l in 0..m {
            let mut rowoff = [0usize; 4];
            for (jj, ro) in rowoff.iter_mut().enumerate() {
                *ro = act_idx[(j0 + jj) * m + l] as usize * n;
            }
            let mut inner = vdupq_n_s32(0);
            let mut wm = 1i32; // q^m
            for mm in 0..m {
                let mut vals = [0i32; 4];
                for (jj, v) in vals.iter_mut().enumerate() {
                    *v = table[rowoff[jj] + widx[(j0 + jj) * m + mm] as usize] as i32;
                }
                let v = vld1q_s32(vals.as_ptr());
                inner = vaddq_s32(inner, vmulq_s32(vdupq_n_s32(wm), v));
                wm *= q;
            }
            acc = vaddq_s32(acc, vmulq_s32(vdupq_n_s32(wl), inner));
            wl *= q;
        }
        vst1q_s32(dots.as_mut_ptr().add(j0), acc);
        j0 += 4;
    }
    for j in j0..bpr {
        dots[j] = lut.block_dot(&act_idx[j * m..(j + 1) * m], &widx[j * m..(j + 1) * m]);
    }
}
