//! Portable scalar kernel tier — the reference implementation every SIMD
//! tier must match bit-for-bit (f32 paths) or exactly (integer paths).
//! These are the loops the crate shipped before the `std::arch` tiers
//! existed, moved here verbatim so the parity propchecks in
//! `quant::kernels::tests` compare SIMD output against the exact code
//! that used to serve production traffic.

use crate::lattice::e8::D;
use crate::lattice::hierarchical::PairLut;
use crate::quant::gemm::PANEL;
use crate::quant::qgemm::DecodeConsts;

/// The 8×NC microkernel: one decoded weight row (`ebuf`, half-unit /
/// integer entries) times the packed `[panel][block][lane][col]`
/// activation panels. Per output column the operation sequence is
/// exactly: for each block, an 8-term sequential multiply-add chain,
/// then one multiply-accumulate by the block scale; finally one multiply
/// by the row scale — the order every SIMD tier preserves lane-by-lane.
pub(crate) fn row_times_panels(
    ebuf: &[i16],
    bscale: &[f32],
    xp: &[f32],
    batch: usize,
    row_scale: f32,
    out_row: &mut [f32],
) {
    let bpr = bscale.len();
    let n_panels = batch.div_ceil(PANEL);
    for p in 0..n_panels {
        let mut acc = [0f32; PANEL];
        for j in 0..bpr {
            let e = &ebuf[j * D..(j + 1) * D];
            let xb = &xp[(p * bpr + j) * D * PANEL..(p * bpr + j + 1) * D * PANEL];
            let mut d = [0f32; PANEL];
            for i in 0..D {
                let ev = e[i] as f32;
                let lane = &xb[i * PANEL..(i + 1) * PANEL];
                for (dc, &xv) in d.iter_mut().zip(lane) {
                    *dc += ev * xv;
                }
            }
            let b = bscale[j];
            for (ac, &dc) in acc.iter_mut().zip(&d) {
                *ac += dc * b;
            }
        }
        let c0 = p * PANEL;
        let c_lim = (batch - c0).min(PANEL);
        for c in 0..c_lim {
            out_row[c0 + c] = acc[c] * row_scale;
        }
    }
}

/// Branch-free NestQuantM decode of one coset-code block into half-unit
/// integers — delegates to [`DecodeConsts::decode`], the all-integer
/// oracle the SIMD tiers replicate operation-for-operation.
#[inline(always)]
pub(crate) fn decode_block(consts: DecodeConsts, c: &[u8; D], out: &mut [i32; D]) {
    consts.decode(c, out);
}

/// Decode a whole packed-nibble code row (4-bit codes, two per byte,
/// `crow.len() = cols/2`) into i16 half-unit entries (`ebuf`, `cols`
/// entries) — the per-row decode feeding the GEMM microkernel.
pub(crate) fn decode_nibble_row(consts: DecodeConsts, crow: &[u8], ebuf: &mut [i16]) {
    let bpr = ebuf.len() / D;
    let mut cbuf = [0u8; D];
    let mut e = [0i32; D];
    for j in 0..bpr {
        for b in 0..4 {
            let byte = crow[j * 4 + b];
            cbuf[2 * b] = byte & 0x0F;
            cbuf[2 * b + 1] = byte >> 4;
        }
        consts.decode(&cbuf, &mut e);
        for i in 0..D {
            ebuf[j * D + i] = e[i] as i16;
        }
    }
}

/// Per-block pair-LUT dots of one weight row against one encoded
/// activation row: `dots[j] = Σ_{ℓ,m} q^{ℓ+m}·T[a_{jℓ}][w_{jm}]`, the
/// exact i32 [`PairLut::block_dot`] per block (`act_idx`/`widx` are
/// `bpr·m` packed digit indices, `[block][level]`).
pub(crate) fn lut_block_dots(
    lut: &PairLut,
    m: usize,
    act_idx: &[u16],
    widx: &[u16],
    dots: &mut [i32],
) {
    for (j, d) in dots.iter_mut().enumerate() {
        *d = lut.block_dot(&act_idx[j * m..(j + 1) * m], &widx[j * m..(j + 1) * m]);
    }
}
