//! AVX2 kernel tier (x86_64). Every function here is marked
//! `#[target_feature(enable = "avx2")]` and must only be called after
//! the dispatcher (`quant::kernels::active`) has verified AVX2 + FMA
//! support — the `Kernel::Avx2` match arms in `quant::kernels` are the
//! only callers.
//!
//! Bitwise contract: the f32 microkernel issues, per output lane, the
//! *same* IEEE operation sequence as the scalar tier — separate multiply
//! then add (`_mm256_mul_ps` + `_mm256_add_ps`), never `_mm256_fmadd_ps`.
//! FMA contraction rounds once where the scalar kernel rounds twice, so
//! using it would silently break the scalar≡SIMD bitwise-parity
//! guarantee the propchecks enforce (the FMA units still help: the
//! detector requires the `fma` cpuid bit so this tier only runs on
//! cores whose vector ALUs handle the mul/add pair at full width). The
//! integer decode and LUT paths are exact by construction — i32
//! arithmetic has no rounding — so they mirror the scalar control flow
//! with 8 lanes per instruction.

use core::arch::x86_64::*;

use crate::lattice::e8::D;
use crate::lattice::hierarchical::PairLut;
use crate::quant::gemm::PANEL;
use crate::quant::qgemm::{gmul, DecodeConsts};

/// Sum the eight i32 lanes. Store-based on purpose: the extract/shuffle
/// reduction ladder saves a couple of cycles but is exactly the kind of
/// lane-order subtlety that breaks exactness reviews; an L1 round-trip
/// is cheap and obviously correct.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum_epi32(v: __m256i) -> i32 {
    let mut lanes = [0i32; 8];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
    lanes.iter().sum()
}

/// The 8×PANEL f32 microkernel, two 256-bit vectors covering the
/// PANEL=16 batch lanes. Per lane the op sequence matches
/// `scalar::row_times_panels` exactly (see module docs).
///
/// # Safety
/// Requires AVX2; `xp` must hold the packed panels for `batch` columns
/// and `out_row` at least `batch` entries (same contract as scalar).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn row_times_panels(
    ebuf: &[i16],
    bscale: &[f32],
    xp: &[f32],
    batch: usize,
    row_scale: f32,
    out_row: &mut [f32],
) {
    let bpr = bscale.len();
    let n_panels = batch.div_ceil(PANEL);
    for p in 0..n_panels {
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        for j in 0..bpr {
            let e = &ebuf[j * D..(j + 1) * D];
            let base = (p * bpr + j) * D * PANEL;
            let mut d0 = _mm256_setzero_ps();
            let mut d1 = _mm256_setzero_ps();
            for (i, &ei) in e.iter().enumerate() {
                let ev = _mm256_set1_ps(ei as f32);
                let x0 = _mm256_loadu_ps(xp.as_ptr().add(base + i * PANEL));
                let x1 = _mm256_loadu_ps(xp.as_ptr().add(base + i * PANEL + 8));
                // d += e·x as mul-then-add — NOT fmadd (see module docs)
                d0 = _mm256_add_ps(d0, _mm256_mul_ps(ev, x0));
                d1 = _mm256_add_ps(d1, _mm256_mul_ps(ev, x1));
            }
            let b = _mm256_set1_ps(bscale[j]);
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(d0, b));
            acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(d1, b));
        }
        let rs = _mm256_set1_ps(row_scale);
        let mut lanes = [0f32; PANEL];
        _mm256_storeu_ps(lanes.as_mut_ptr(), _mm256_mul_ps(acc0, rs));
        _mm256_storeu_ps(lanes.as_mut_ptr().add(8), _mm256_mul_ps(acc1, rs));
        let c0 = p * PANEL;
        let c_lim = (batch - c0).min(PANEL);
        out_row[c0..c0 + c_lim].copy_from_slice(&lanes[..c_lim]);
    }
}

/// Vectorized `DecodeConsts::decode` core: both NestQuantM residual
/// candidates computed across the 8 block coordinates at once, parity
/// fix restricted to lane 0 by mask, minimum-energy pick by (scalar)
/// cost compare. Returns the chosen residual in half-units, identical
/// lane-for-lane to the scalar oracle — every operation is exact i32.
///
/// # Safety
/// Requires AVX2.
#[target_feature(enable = "avx2")]
unsafe fn decode_core(consts: DecodeConsts, c: &[u8; D]) -> __m256i {
    // t = G·c is 8 small integer adds — scalar, the vector win is in the
    // 16 magic divisions + parity/cost work below
    let t_arr = gmul(c);
    let t = _mm256_loadu_si256(t_arr.as_ptr() as *const __m256i);
    let q = consts.q;
    let m = consts.m;
    let qv = _mm256_set1_epi32(q);
    let mv = _mm256_set1_epi32(m);
    let magic = _mm256_set1_epi32(consts.magic as i32);
    // floor(x / m) = (x·magic) >> 21, exact for 0 ≤ x < 4096
    // (`magic_division_exact` pins this); products stay < 2^31 so the
    // signed low-32 mullo equals the u32 wrapping multiply
    let r1 = _mm256_srli_epi32::<21>(_mm256_mullo_epi32(_mm256_add_epi32(t, qv), magic));
    let mut e1 = _mm256_sub_epi32(t, _mm256_mullo_epi32(mv, r1));
    let r2 = _mm256_srli_epi32::<21>(_mm256_mullo_epi32(t, magic));
    let mut e2 = _mm256_sub_epi32(_mm256_sub_epi32(t, qv), _mm256_mullo_epi32(mv, r2));
    let par1 = hsum_epi32(r1);
    let par2 = hsum_epi32(r2);
    // parity fix on coordinate 0 only: e0 −= m·dir·(par&1) with
    // dir = 1 | (e0 >> 31); computed lane-parallel, masked to lane 0
    let lane0 = _mm256_setr_epi32(-1, 0, 0, 0, 0, 0, 0, 0);
    let dir1 = _mm256_or_si256(_mm256_srai_epi32::<31>(e1), _mm256_set1_epi32(1));
    let fix1 = _mm256_mullo_epi32(dir1, _mm256_set1_epi32(m * (par1 & 1)));
    e1 = _mm256_sub_epi32(e1, _mm256_and_si256(fix1, lane0));
    let dir2 = _mm256_or_si256(_mm256_srai_epi32::<31>(e2), _mm256_set1_epi32(1));
    let fix2 = _mm256_mullo_epi32(dir2, _mm256_set1_epi32(m * (par2 & 1)));
    e2 = _mm256_sub_epi32(e2, _mm256_and_si256(fix2, lane0));
    let cost1 = hsum_epi32(_mm256_mullo_epi32(e1, e1));
    let cost2 = hsum_epi32(_mm256_mullo_epi32(e2, e2));
    if cost1 <= cost2 {
        e1
    } else {
        e2
    }
}

/// [`decode_core`] into a caller i32 block — the kvpool streaming-decode
/// entry point.
///
/// # Safety
/// Requires AVX2.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn decode_block(consts: DecodeConsts, c: &[u8; D], out: &mut [i32; D]) {
    let e = decode_core(consts, c);
    _mm256_storeu_si256(out.as_mut_ptr() as *mut __m256i, e);
}

/// Decode a packed-nibble code row into i16 entries: per block, unpack
/// the 8 nibbles (scalar — 4 byte loads), run the vector decode core,
/// and narrow 8×i32 → 8×i16 with one saturating pack (values are
/// bounded by 2m ≪ i16::MAX, so saturation never fires).
///
/// # Safety
/// Requires AVX2; `crow.len() ≥ ebuf.len()/2` and `ebuf.len() % 8 == 0`.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn decode_nibble_row(consts: DecodeConsts, crow: &[u8], ebuf: &mut [i16]) {
    let bpr = ebuf.len() / D;
    let mut cbuf = [0u8; D];
    for j in 0..bpr {
        for b in 0..4 {
            let byte = crow[j * 4 + b];
            cbuf[2 * b] = byte & 0x0F;
            cbuf[2 * b + 1] = byte >> 4;
        }
        let e = decode_core(consts, &cbuf);
        let lo = _mm256_castsi256_si128(e);
        let hi = _mm256_extracti128_si256::<1>(e);
        // packs(lo, hi) lays out lanes [lo0..lo3, hi0..hi3] = e[0..8]
        let narrow = _mm_packs_epi32(lo, hi);
        _mm_storeu_si128(ebuf.as_mut_ptr().add(j * D) as *mut __m128i, narrow);
    }
}

/// Gathered per-block LUT dots: 8 blocks per iteration, one hardware
/// gather per (ℓ, m) level pair resolving all 8 table lookups in
/// flight — the table walk is the cache-miss-bound part of the LUT
/// backend, and overlapping the misses is where the win lives. The
/// i32 radix accumulation (`inner += q^m·T`, `acc += q^ℓ·inner`) is
/// lane-exact vs [`PairLut::block_dot`].
///
/// # Safety
/// Requires AVX2. Gathers load 32 bits per 16-bit entry, so the last
/// table entry's load runs 2 bytes past it — [`PairLut`] pads its table
/// with one trailing element to keep that in-bounds (asserted here).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn lut_block_dots(
    lut: &PairLut,
    m: usize,
    act_idx: &[u16],
    widx: &[u16],
    dots: &mut [i32],
) {
    let bpr = dots.len();
    let n = lut.n as i32;
    let q = lut.q as i32;
    debug_assert!(
        lut.table.len() > lut.n * lut.n,
        "PairLut table must carry the 16-bit gather padding entry"
    );
    let base = lut.table.as_ptr() as *const i32;
    let mut j0 = 0usize;
    while j0 + 8 <= bpr {
        let mut acc = _mm256_setzero_si256();
        let mut wl = 1i32; // q^ℓ
        for l in 0..m {
            let mut rowoff = [0i32; 8];
            for (jj, ro) in rowoff.iter_mut().enumerate() {
                *ro = act_idx[(j0 + jj) * m + l] as i32 * n;
            }
            let mut inner = _mm256_setzero_si256();
            let mut wm = 1i32; // q^m
            for mm in 0..m {
                let mut off = [0i32; 8];
                for (jj, o) in off.iter_mut().enumerate() {
                    *o = rowoff[jj] + widx[(j0 + jj) * m + mm] as i32;
                }
                let offv = _mm256_loadu_si256(off.as_ptr() as *const __m256i);
                // scale=2: offsets index i16 entries; sign-extend the
                // low half of each 32-bit gathered word
                let raw = _mm256_i32gather_epi32::<2>(base, offv);
                let val = _mm256_srai_epi32::<16>(_mm256_slli_epi32::<16>(raw));
                inner =
                    _mm256_add_epi32(inner, _mm256_mullo_epi32(_mm256_set1_epi32(wm), val));
                wm *= q;
            }
            acc = _mm256_add_epi32(acc, _mm256_mullo_epi32(_mm256_set1_epi32(wl), inner));
            wl *= q;
        }
        _mm256_storeu_si256(dots.as_mut_ptr().add(j0) as *mut __m256i, acc);
        j0 += 8;
    }
    // ragged tail: exact scalar
    for j in j0..bpr {
        dots[j] = lut.block_dot(&act_idx[j * m..(j + 1) * m], &widx[j * m..(j + 1) * m]);
    }
}
