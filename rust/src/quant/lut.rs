//! The LUT inner-product GEMM backend (Kaplan & Ordentlich, ISIT 2025):
//! weights stored as M-level hierarchical digit *indices*, activations
//! hierarchically encoded on the fly, and C = A·Bᵀ computed entirely by
//! lookups into the shared [`PairLut`] — M² table reads per 8-block pair,
//! i32 accumulation, **no decode**: unlike the decode-amortized backend
//! (`quant::qgemm::PackedNestMatrix` + `quant::gemm`), no decoded i16 row
//! buffer ever exists. That flips the compute story: the decode backend
//! amortizes per-row decode over the batch (wins at large batch), the
//! LUT backend pays per-activation encode once and then O(M²) integer
//! lookups per block pair (wins at decode-step batch sizes, where the
//! decode backend re-decodes every weight row per token).
//!
//! Scaling chain (mirrors Algorithm 4): digit decodes are in half-units,
//! so a block's LUT dot is 4× the real lattice product; both β
//! dictionaries are stored pre-halved (β/2), making the per-block factor
//! (β_a/2)(β_w/2) = β_a·β_w/4 exact. Per-row f32 accumulation and the
//! final (s_a/√n)(s_w/√n) denormalization match the decode path, so the
//! only error vs a true inner product is the quantization error itself —
//! the two-sided bound documented in `lattice::hierarchical` and pinned
//! by `lut_dot_within_documented_bound` below.
//!
//! Threading reuses the `quant::gemm` driver shape: activations are
//! encoded once per call, weight rows are partitioned across
//! `std::thread::scope` workers writing disjoint chunks of a
//! (rows, batch) staging buffer, transposed into the caller's
//! (batch, rows) output. `threads == 1` with a warm [`LutScratch`] is
//! allocation-free — the fused decode loop's requirement.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::sync::Arc;

use super::gemm::{drive_rows, resolve_threads, transpose_into};
use super::kernels::{self, Kernel};
use super::matrix::QuantizedMatrix;
use crate::lattice::e8::D;
use crate::lattice::hierarchical::{
    lut_supported, pack_index, HierarchicalQuantizer, PairLut, MAX_LEVELS,
};
use crate::util::linalg::Mat;

/// Reusable buffers for [`PackedLutMatrix::gemv_into`]/[`gemm_into`]:
/// the encoded activation indices, per-block activation β multipliers,
/// per-row activation scales, and the (rows, batch) staging output.
///
/// [`gemm_into`]: PackedLutMatrix::gemm_into
#[derive(Default)]
pub struct LutScratch {
    /// batch·(cols/8)·M packed digit indices, `[row][block][level]`
    act_idx: Vec<u16>,
    /// batch·(cols/8) chosen β_t/2 values (dictionary pre-dereferenced)
    act_beta: Vec<f32>,
    /// batch s_a/√n denormalization factors
    act_scale: Vec<f32>,
    /// (rows, batch) staging buffer for the GEMM path
    ytmp: Vec<f32>,
    /// cols/8 per-block LUT dots — the SIMD kernel's i32 staging row
    /// (worker threads use their own; this one serves the alloc-free
    /// `threads == 1` / GEMV paths)
    dots: Vec<i32>,
}

impl LutScratch {
    pub fn new() -> Self {
        LutScratch::default()
    }
}

/// A weight matrix in LUT-ready hierarchical storage: per 8-block, M
/// packed u16 digit indices (coarsest-last), 2-bit β indices, per-row
/// scales, plus the activation-side quantizer that encodes inputs at
/// GEMV time. The shared pair LUT is held by `Arc` — one table per q
/// process-wide.
pub struct PackedLutMatrix {
    pub rows: usize,
    pub cols: usize,
    pub q: u32,
    pub m_levels: usize,
    lut: Arc<PairLut>,
    /// rows·(cols/8)·M digit indices, `[row][block][level]`
    idx: Vec<u16>,
    /// 2-bit weight β indices, four per byte, row-major
    beta_idx: Vec<u8>,
    /// weight β dictionary, pre-halved (β_t/2)
    beta_half: [f32; 4],
    /// per-row s_r/√n
    row_scale: Vec<f32>,
    /// activation-side hierarchical quantizer (same codec, own β ladder)
    act: HierarchicalQuantizer,
    /// activation β dictionary, pre-halved
    act_beta_half: [f32; 4],
}

impl PackedLutMatrix {
    /// Whether a quantizer/shape pair is representable: the (q, M) pair
    /// must be inside the LUT safety window ([`lut_supported`]), β
    /// dictionaries 2-bit packable, columns in whole 8-blocks.
    pub fn supports(hq: &HierarchicalQuantizer, cols: usize) -> bool {
        lut_supported(hq.q(), hq.m_levels() as u32)
            && hq.k() <= 4
            && cols % D == 0
            && cols > 0
    }

    /// Pack an already-quantized M-level matrix (`qm.levels == M`, codes
    /// laid out `[row][block][level][coord]`) without re-quantizing.
    /// `wq` is the quantizer that produced `qm`; `act` encodes
    /// activations at GEMV time (same codec parameters, its own β
    /// dictionary — typically calibrated separately).
    pub fn from_quantized(
        qm: &QuantizedMatrix,
        wq: &HierarchicalQuantizer,
        act: HierarchicalQuantizer,
    ) -> Self {
        let (q, m) = (wq.q(), wq.m_levels());
        assert!(
            lut_supported(q, m as u32),
            "(q={q}, M={m}) outside the LUT safety window"
        );
        assert_eq!(qm.q, q, "carrier matrix quantized at a different q");
        assert_eq!(qm.levels as usize, m, "carrier matrix has a different level count");
        assert_eq!(act.q(), q, "activation quantizer at a different q");
        assert_eq!(act.m_levels(), m, "activation quantizer level mismatch");
        assert!(wq.k() <= 4 && act.k() <= 4, "β dictionaries are 2-bit packed");
        assert_eq!(qm.cols % D, 0, "cols must be divisible by 8");
        assert!(qm.cols > 0, "empty rows are not packable");

        let bpr = qm.cols / D;
        let mut idx = vec![0u16; qm.rows * bpr * m];
        let mut c = [0u8; D];
        for (g, slot) in idx.iter_mut().enumerate() {
            // g = (row·bpr + block)·M + level ↔ codes group g·8
            c.copy_from_slice(&qm.codes[g * D..(g + 1) * D]);
            *slot = pack_index(&c, q);
        }
        let blocks = qm.rows * bpr;
        let mut beta_idx = vec![0u8; blocks.div_ceil(4)];
        for (i, &b) in qm.beta_idx.iter().enumerate() {
            beta_idx[i / 4] |= b << (2 * (i % 4));
        }
        let mut beta_half = [0f32; 4];
        for (t, &b) in wq.betas.iter().enumerate() {
            beta_half[t] = b * 0.5;
        }
        let mut act_beta_half = [0f32; 4];
        for (t, &b) in act.betas.iter().enumerate() {
            act_beta_half[t] = b * 0.5;
        }
        let row_scale = qm
            .scales
            .iter()
            .map(|&s| s / (qm.cols as f32).sqrt())
            .collect();
        PackedLutMatrix {
            rows: qm.rows,
            cols: qm.cols,
            q,
            m_levels: m,
            lut: PairLut::shared(q),
            idx,
            beta_idx,
            beta_half,
            row_scale,
            act,
            act_beta_half,
        }
    }

    /// Hierarchically encode one activation row into caller slices:
    /// `idx_out` gets (cols/8)·M packed indices, `beta_out` the chosen
    /// β_t/2 per block. Returns s_a/√n.
    fn encode_act_row(&self, x: &[f32], idx_out: &mut [u16], beta_out: &mut [f32]) -> f32 {
        debug_assert_eq!(x.len(), self.cols);
        let m = self.m_levels;
        let s = crate::util::stats::norm2(x) as f32;
        if s == 0.0 {
            idx_out.fill(0);
            beta_out.fill(0.0);
            return 0.0;
        }
        let norm = (self.cols as f32).sqrt() / s;
        let mut block = [0f32; D];
        let mut digits = [0u8; MAX_LEVELS * D];
        let mut c = [0u8; D];
        for (j, chunk) in x.chunks_exact(D).enumerate() {
            for i in 0..D {
                block[i] = chunk[i] * norm;
            }
            let (t, _, _) = self.act.quantize_block(&block, &mut digits[..m * D]);
            for l in 0..m {
                c.copy_from_slice(&digits[l * D..(l + 1) * D]);
                idx_out[j * m + l] = pack_index(&c, self.q);
            }
            beta_out[j] = self.act_beta_half[t as usize];
        }
        s / (self.cols as f32).sqrt()
    }

    /// One weight row × one encoded activation row, pure table lookups:
    /// Σ_blocks (Σ_{ℓ,m} q^{ℓ+m}·T)·(β_w/2)(β_a/2). Shared by the GEMV
    /// and GEMM paths so they are bit-for-bit identical. The per-block
    /// i32 dots are staged in `dots` (len cols/8) by the dispatched
    /// [`kernels::lut_block_dots`] — exact integers, so splitting the
    /// lookup stage from the f32 fold changes no output bit: the fold
    /// runs the same f32 operations in the same block order as the old
    /// fused loop.
    #[inline]
    fn accum_row(
        &self,
        kern: Kernel,
        r: usize,
        act_idx: &[u16],
        act_beta: &[f32],
        dots: &mut [i32],
    ) -> f32 {
        let m = self.m_levels;
        let bpr = self.cols / D;
        let widx = &self.idx[r * bpr * m..(r + 1) * bpr * m];
        kernels::lut_block_dots(kern, &self.lut, m, act_idx, widx, dots);
        let mut acc = 0f32;
        for (j, &d) in dots.iter().enumerate() {
            let bidx = r * bpr + j;
            let wb =
                self.beta_half[((self.beta_idx[bidx / 4] >> (2 * (bidx % 4))) & 0x3) as usize];
            acc += d as f32 * (wb * act_beta[j]);
        }
        acc
    }

    /// y = W·x by table lookups (the decode-step hot path). Allocation-
    /// free once `scratch` is warm — no decoded i16 row is ever built.
    pub fn gemv_into(&self, x: &[f32], y: &mut [f32], scratch: &mut LutScratch) {
        self.gemv_into_with(kernels::active(), x, y, scratch)
    }

    /// [`Self::gemv_into`] with an explicit dispatch tier — the direct
    /// entry point tests and benches use to compare tiers in one process.
    pub fn gemv_into_with(&self, kern: Kernel, x: &[f32], y: &mut [f32], scratch: &mut LutScratch) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let m = self.m_levels;
        let bpr = self.cols / D;
        scratch.act_idx.clear();
        scratch.act_idx.resize(bpr * m, 0);
        scratch.act_beta.clear();
        scratch.act_beta.resize(bpr, 0.0);
        scratch.dots.clear();
        scratch.dots.resize(bpr, 0);
        let a_scale = self.encode_act_row(x, &mut scratch.act_idx, &mut scratch.act_beta);
        for r in 0..self.rows {
            y[r] = self.accum_row(kern, r, &scratch.act_idx, &scratch.act_beta, &mut scratch.dots)
                * self.row_scale[r]
                * a_scale;
        }
    }

    /// Allocating convenience wrapper over [`Self::gemv_into`].
    pub fn gemv(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0f32; self.rows];
        self.gemv_into(x, &mut y, &mut LutScratch::new());
        y
    }

    /// Batched GEMM, Y = X·Wᵀ: `xt` is (batch, cols) row-major, `yt`
    /// (batch, rows). Activations are encoded once per call; weight rows
    /// are partitioned across `std::thread::scope` workers (`threads ==
    /// 0` uses all cores) writing disjoint chunks of the staging buffer.
    /// Results are bit-for-bit identical to [`Self::gemv_into`] per
    /// batch row.
    pub fn gemm_into(&self, xt: &Mat, yt: &mut Mat, threads: usize, scratch: &mut LutScratch) {
        self.gemm_into_with(kernels::active(), xt, yt, threads, scratch)
    }

    /// [`Self::gemm_into`] with an explicit dispatch tier (see
    /// [`Self::gemv_into_with`]).
    pub fn gemm_into_with(
        &self,
        kern: Kernel,
        xt: &Mat,
        yt: &mut Mat,
        threads: usize,
        scratch: &mut LutScratch,
    ) {
        assert_eq!(xt.cols, self.cols, "activation panel width mismatch");
        assert_eq!(yt.rows, xt.rows, "output batch mismatch");
        assert_eq!(yt.cols, self.rows, "output width mismatch");
        let batch = xt.rows;
        if batch == 0 || self.rows == 0 {
            return;
        }
        let threads = resolve_threads(threads);
        let m = self.m_levels;
        let bpr = self.cols / D;
        scratch.act_idx.clear();
        scratch.act_idx.resize(batch * bpr * m, 0);
        scratch.act_beta.clear();
        scratch.act_beta.resize(batch * bpr, 0.0);
        scratch.act_scale.clear();
        scratch.act_scale.resize(batch, 0.0);
        for cidx in 0..batch {
            scratch.act_scale[cidx] = self.encode_act_row(
                xt.row(cidx),
                &mut scratch.act_idx[cidx * bpr * m..(cidx + 1) * bpr * m],
                &mut scratch.act_beta[cidx * bpr..(cidx + 1) * bpr],
            );
        }
        scratch.ytmp.clear();
        scratch.ytmp.resize(self.rows * batch, 0.0);
        let LutScratch { act_idx, act_beta, act_scale, ytmp, dots } = scratch;
        let (act_idx, act_beta, act_scale) =
            (act_idx.as_slice(), act_beta.as_slice(), act_scale.as_slice());

        if threads == 1 {
            // allocation-free fast path: the dots staging row lives in
            // the scratch, no range vector, no spawn
            dots.clear();
            dots.resize(bpr, 0);
            for r in 0..self.rows {
                let rs = self.row_scale[r];
                let orow = &mut ytmp[r * batch..(r + 1) * batch];
                for cidx in 0..batch {
                    orow[cidx] = self.accum_row(
                        kern,
                        r,
                        &act_idx[cidx * bpr * m..(cidx + 1) * bpr * m],
                        &act_beta[cidx * bpr..(cidx + 1) * bpr],
                        dots,
                    ) * rs
                        * act_scale[cidx];
                }
            }
        } else {
            drive_rows(self.rows, batch, threads, ytmp, |range, out| {
                let mut dots = vec![0i32; bpr];
                for (k, r) in range.enumerate() {
                    let rs = self.row_scale[r];
                    let orow = &mut out[k * batch..(k + 1) * batch];
                    for cidx in 0..batch {
                        orow[cidx] = self.accum_row(
                            kern,
                            r,
                            &act_idx[cidx * bpr * m..(cidx + 1) * bpr * m],
                            &act_beta[cidx * bpr..(cidx + 1) * bpr],
                            &mut dots,
                        ) * rs
                            * act_scale[cidx];
                    }
                }
            });
        }
        transpose_into(ytmp, self.rows, batch, yt);
    }

    /// Allocating convenience wrapper over [`Self::gemm_into`].
    pub fn gemm(&self, xt: &Mat, threads: usize) -> Mat {
        let mut yt = Mat::zeros(xt.rows, self.rows);
        self.gemm_into(xt, &mut yt, threads, &mut LutScratch::new());
        yt
    }

    /// Stored payload in bytes at the packed rate — M·⌈log2 q⌉ bits per
    /// logical weight + 2-bit β/block + f32 row scales. Identical to the
    /// carrier `QuantizedMatrix::payload_bytes`, so the engine's per-site
    /// accounting is the same number whichever representation it asks.
    /// (The in-memory index array is u16 per digit group for lookup
    /// speed; that is a working-set choice, not the stored rate.)
    pub fn payload_bytes(&self) -> usize {
        let code_bits = (self.q as f64).log2().ceil() as usize;
        (self.rows * self.cols * self.m_levels * code_bits).div_ceil(8)
            + (self.rows * self.cols / D * 2).div_ceil(8)
            + self.row_scale.len() * 4
    }

    /// Bits per logical weight entry of the packed representation.
    pub fn bits_per_entry(&self) -> f64 {
        self.payload_bytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }

    /// The activation-side quantizer (for fake-quant references in tests
    /// and the engine's eval path).
    pub fn act_quantizer(&self) -> &HierarchicalQuantizer {
        &self.act
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::{propcheck, stats, Rng};

    fn quantizers(q: u32, m: usize) -> (HierarchicalQuantizer, HierarchicalQuantizer) {
        // β ladders roughly covering N(0,1) blocks at small q^M volumes
        let wq = HierarchicalQuantizer::new(q, m, vec![0.35, 0.55, 0.85, 1.3]);
        let aq = HierarchicalQuantizer::new(q, m, vec![0.4, 0.6, 0.95, 1.5]);
        (wq, aq)
    }

    fn pack(
        w: &Mat,
        q: u32,
        m: usize,
    ) -> (PackedLutMatrix, QuantizedMatrix, HierarchicalQuantizer) {
        let (wq, aq) = quantizers(q, m);
        let qm = wq.quantize_matrix(w);
        let packed = PackedLutMatrix::from_quantized(&qm, &wq, aq);
        (packed, qm, wq)
    }

    /// Fake-quant an activation row through the packed matrix's own
    /// activation quantizer (the reference the GEMV is exact against).
    fn fake_quant_act(packed: &PackedLutMatrix, x: &[f32]) -> Vec<f32> {
        let aq = packed.act_quantizer();
        let m = Mat::from_vec(1, x.len(), x.to_vec());
        let qm = aq.quantize_matrix(&m);
        aq.dequantize_matrix(&qm).data
    }

    #[test]
    fn supports_window() {
        let (wq, _) = quantizers(2, 4);
        assert!(PackedLutMatrix::supports(&wq, 64));
        assert!(!PackedLutMatrix::supports(&wq, 60), "ragged cols");
        assert!(!PackedLutMatrix::supports(&wq, 0));
        let (wq8, _) = quantizers(4, 2);
        assert!(!PackedLutMatrix::supports(&wq8, 64), "q=4 outside LUT window");
    }

    #[test]
    fn gemv_matches_dequantized_reference() {
        // LUT gemv == ⟨x̂, ŵ⟩ computed the slow way (dequantize both,
        // f64 dot) up to f32 scale-application rounding.
        propcheck::check("lut-gemv-vs-deq", 10, 5101, |rng| {
            for &(q, m) in &[(2u32, 3usize), (3, 2)] {
                let w = Mat::from_vec(8, 64, rng.gauss_vec(512));
                let (packed, qm, wq) = pack(&w, q, m);
                let x = rng.gauss_vec(64);
                let fast = packed.gemv(&x);
                let wdeq = wq.dequantize_matrix(&qm);
                let xdeq = fake_quant_act(&packed, &x);
                for r in 0..8 {
                    let slow: f64 = wdeq
                        .row(r)
                        .iter()
                        .zip(&xdeq)
                        .map(|(&a, &b)| a as f64 * b as f64)
                        .sum();
                    if (fast[r] as f64 - slow).abs() > 1e-4 * (1.0 + slow.abs()) {
                        return Err(format!("q={q} M={m} row {r}: {} vs {slow}", fast[r]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lut_dot_within_documented_bound() {
        // |⟨â,ŵ⟩ − ⟨a,w⟩| ≤ ‖ε_a‖‖w‖ + ‖ε_w‖‖a‖ + ‖ε_a‖‖ε_w‖ — the
        // two-sided bound, checked across random shapes and seeds.
        propcheck::check("lut-dot-bound", 20, 5102, |rng| {
            for &(q, m, rows, cols) in &[(2u32, 4usize, 5usize, 64usize), (2, 3, 3, 32), (3, 2, 4, 48)]
            {
                let w = Mat::from_vec(rows, cols, rng.gauss_vec(rows * cols));
                let (packed, qm, wq) = pack(&w, q, m);
                let x = rng.gauss_vec(cols);
                let y = packed.gemv(&x);
                let wdeq = wq.dequantize_matrix(&qm);
                let xdeq = fake_quant_act(&packed, &x);
                let ea: Vec<f32> = xdeq.iter().zip(&x).map(|(a, b)| a - b).collect();
                let na = stats::norm2(&ea);
                let nx = stats::norm2(&x);
                for r in 0..rows {
                    let ew: Vec<f32> = wdeq
                        .row(r)
                        .iter()
                        .zip(w.row(r))
                        .map(|(a, b)| a - b)
                        .collect();
                    let nw = stats::norm2(&ew);
                    let nwr = stats::norm2(w.row(r));
                    let exact: f64 = w
                        .row(r)
                        .iter()
                        .zip(&x)
                        .map(|(&a, &b)| a as f64 * b as f64)
                        .sum();
                    let bound = na * nwr + nw * nx + na * nw;
                    let slack = 1e-3 * (1.0 + exact.abs() + bound); // f32 rounding
                    if (y[r] as f64 - exact).abs() > bound + slack {
                        return Err(format!(
                            "q={q} M={m} row {r}: |{} − {exact}| > bound {bound}",
                            y[r]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gemm_matches_per_row_gemv_bitexact() {
        propcheck::check("lut-gemm-vs-gemv-bitexact", 4, 5103, |rng| {
            for &(rows, cols) in &[(3usize, 16usize), (8, 64), (17, 40)] {
                let w = Mat::from_vec(rows, cols, rng.gauss_vec(rows * cols));
                let (packed, _, _) = pack(&w, 2, 3);
                for &batch in &[1usize, 5, 16] {
                    let xt = Mat::from_vec(batch, cols, rng.gauss_vec(batch * cols));
                    for &threads in &[1usize, 3] {
                        let yt = packed.gemm(&xt, threads);
                        let mut y = vec![0f32; rows];
                        let mut scratch = LutScratch::new();
                        for c in 0..batch {
                            packed.gemv_into(xt.row(c), &mut y, &mut scratch);
                            for r in 0..rows {
                                if yt[(c, r)].to_bits() != y[r].to_bits() {
                                    return Err(format!(
                                        "({rows}x{cols}) batch={batch} threads={threads} \
                                         col {c} row {r}: gemm {} vs gemv {}",
                                        yt[(c, r)],
                                        y[r]
                                    ));
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gemm_kernel_tiers_bitexact_vs_scalar_gemv() {
        // the gathered SIMD LUT path is exact i32, so every supported
        // tier must reproduce the forced-scalar GEMV bit-for-bit.
        let mut rng = Rng::new(5108);
        for &(q, m) in &[(2u32, 3usize), (3, 2)] {
            let w = Mat::from_vec(9, 72, rng.gauss_vec(9 * 72));
            let (packed, _, _) = pack(&w, q, m);
            let batch = 7;
            let xt = Mat::from_vec(batch, 72, rng.gauss_vec(batch * 72));
            let mut y = vec![0f32; 9];
            let mut vs = LutScratch::new();
            for k in kernels::available() {
                let mut yt = Mat::zeros(batch, 9);
                packed.gemm_into_with(k, &xt, &mut yt, 2, &mut LutScratch::new());
                for c in 0..batch {
                    packed.gemv_into_with(Kernel::Scalar, xt.row(c), &mut y, &mut vs);
                    for r in 0..9 {
                        assert_eq!(
                            yt[(c, r)].to_bits(),
                            y[r].to_bits(),
                            "tier {} q={q} M={m} c={c} r={r}",
                            k.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn gemv_scratch_does_not_reallocate_once_warm() {
        let mut rng = Rng::new(5104);
        let w = Mat::from_vec(6, 64, rng.gauss_vec(384));
        let (packed, _, _) = pack(&w, 2, 4);
        let mut scratch = LutScratch::new();
        let mut y = vec![0f32; 6];
        packed.gemv_into(&rng.gauss_vec(64), &mut y, &mut scratch);
        let caps = (scratch.act_idx.capacity(), scratch.act_beta.capacity());
        for _ in 0..5 {
            packed.gemv_into(&rng.gauss_vec(64), &mut y, &mut scratch);
        }
        assert_eq!(
            (scratch.act_idx.capacity(), scratch.act_beta.capacity()),
            caps,
            "warm gemv must not grow scratch"
        );
    }

    #[test]
    fn payload_matches_carrier_matrix() {
        let mut rng = Rng::new(5105);
        let w = Mat::from_vec(16, 128, rng.gauss_vec(16 * 128));
        for &(q, m) in &[(2u32, 4usize), (3, 2)] {
            let (packed, qm, _) = pack(&w, q, m);
            assert_eq!(packed.payload_bytes(), qm.payload_bytes(), "q={q} M={m}");
            // q=2, M=4: 4 bits/entry codes + 0.25 β + 32/128 scale = 4.5
            if (q, m) == (2, 4) {
                let bits = packed.bits_per_entry();
                assert!((4.4..4.6).contains(&bits), "bits/entry {bits}");
            }
        }
    }

    #[test]
    fn zero_activation_and_empty_batch() {
        let mut rng = Rng::new(5106);
        let w = Mat::from_vec(4, 32, rng.gauss_vec(128));
        let (packed, _, _) = pack(&w, 2, 3);
        let y = packed.gemv(&vec![0.0; 32]);
        assert!(y.iter().all(|&v| v == 0.0));
        let yt = packed.gemm(&Mat::zeros(0, 32), 4);
        assert_eq!(yt.rows, 0);
    }

    #[test]
    fn gemm_scratch_reuse_across_shapes() {
        let mut rng = Rng::new(5107);
        let mut scratch = LutScratch::new();
        for &(rows, cols, batch) in &[(12usize, 64usize, 9usize), (5, 24, 3), (9, 48, 17)] {
            let w = Mat::from_vec(rows, cols, rng.gauss_vec(rows * cols));
            let (packed, _, _) = pack(&w, 2, 2);
            let xt = Mat::from_vec(batch, cols, rng.gauss_vec(batch * cols));
            let mut yt = Mat::zeros(batch, rows);
            packed.gemm_into(&xt, &mut yt, 2, &mut scratch);
            let mut y = vec![0f32; rows];
            let mut vs = LutScratch::new();
            for c in 0..batch {
                packed.gemv_into(xt.row(c), &mut y, &mut vs);
                for r in 0..rows {
                    assert_eq!(
                        yt[(c, r)].to_bits(),
                        y[r].to_bits(),
                        "({rows}x{cols}) b={batch} c={c} r={r}"
                    );
                }
            }
        }
    }
}
