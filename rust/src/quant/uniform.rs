//! Uniform scalar quantization with L∞ scaling — the cubic-shaping
//! baseline used by SpinQuant / QuaRot / LLM.int8-style pipelines (paper
//! §3, Fig. 2/3). Round-to-nearest onto a symmetric 2^R-level grid scaled
//! by the vector's max magnitude. Also provides the packed-int4 GEMV used
//! as the Table 4 runtime comparator.

use super::gemm::{self, GemmScratch};
use super::kernels::{self, Kernel};
use crate::util::linalg::Mat;

/// Symmetric uniform quantizer at `bits` bits per entry.
#[derive(Clone, Copy, Debug)]
pub struct UniformQuantizer {
    pub bits: u32,
}

impl UniformQuantizer {
    pub fn new(bits: u32) -> Self {
        assert!((2..=8).contains(&bits));
        UniformQuantizer { bits }
    }

    #[inline]
    fn levels(&self) -> i32 {
        1 << (self.bits - 1) // codes in [-levels, levels-1]
    }

    /// Quantize a vector: L∞ scale + round-to-nearest. Returns (codes, Δ).
    pub fn quantize(&self, x: &[f32]) -> (Vec<i8>, f32) {
        let mut codes = Vec::new();
        let delta = self.quantize_into(x, &mut codes);
        (codes, delta)
    }

    /// [`Self::quantize`] into a caller-owned code buffer (cleared and
    /// refilled, capacity reused) — the paged-KV append path must not pay
    /// a per-token allocation. Returns Δ.
    pub fn quantize_into(&self, x: &[f32], codes: &mut Vec<i8>) -> f32 {
        codes.clear();
        let maxabs = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
        if maxabs == 0.0 {
            codes.resize(x.len(), 0);
            return 0.0;
        }
        let l = self.levels();
        let delta = maxabs / l as f32;
        codes.extend(
            x.iter()
                .map(|&v| ((v / delta).round() as i32).clamp(-l, l - 1) as i8),
        );
        delta
    }

    pub fn dequantize(&self, codes: &[i8], delta: f32) -> Vec<f32> {
        codes.iter().map(|&c| c as f32 * delta).collect()
    }

    /// Quantize→dequantize ("fake quant").
    pub fn roundtrip(&self, x: &[f32]) -> Vec<f32> {
        let (c, d) = self.quantize(x);
        self.dequantize(&c, d)
    }

    /// Row-wise fake quantization of a matrix (per-row Δ), as used by the
    /// uniform baselines when quantizing weights.
    pub fn roundtrip_rows(&self, m: &Mat) -> Mat {
        let mut out = Mat::zeros(m.rows, m.cols);
        for r in 0..m.rows {
            let rt = self.roundtrip(m.row(r));
            out.row_mut(r).copy_from_slice(&rt);
        }
        out
    }

    /// Effective rate: R bits/entry (+ one f32 scale per vector, reported
    /// separately like NestQuant's s).
    pub fn rate(&self) -> f64 {
        self.bits as f64
    }
}

/// Weights quantized to packed int4 with per-row scales — the Table 4
/// "int4 uniform" GEMV comparator (2 entries per byte).
pub struct PackedInt4Matrix {
    pub rows: usize,
    pub cols: usize,
    /// two 4-bit codes per byte (code = nibble − 8 ∈ [−8, 7])
    pub packed: Vec<u8>,
    pub deltas: Vec<f32>,
}

impl PackedInt4Matrix {
    pub fn quantize(m: &Mat) -> Self {
        assert_eq!(m.cols % 2, 0);
        let uq = UniformQuantizer::new(4);
        let mut packed = vec![0u8; m.rows * m.cols / 2];
        let mut deltas = vec![0f32; m.rows];
        for r in 0..m.rows {
            let (codes, delta) = uq.quantize(m.row(r));
            deltas[r] = delta;
            for (i, pair) in codes.chunks_exact(2).enumerate() {
                let lo = (pair[0] as i32 + 8) as u8;
                let hi = (pair[1] as i32 + 8) as u8;
                packed[r * m.cols / 2 + i] = lo | (hi << 4);
            }
        }
        PackedInt4Matrix {
            rows: m.rows,
            cols: m.cols,
            packed,
            deltas,
        }
    }

    /// y = W·x, unpacking nibbles on the fly (memory-bound fast path).
    pub fn gemv(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0f32; self.rows];
        self.gemv_into(x, &mut y);
        y
    }

    /// [`Self::gemv`] into a caller-provided buffer — the Table 4
    /// comparator must not pay a per-call allocation, or the runtime
    /// comparison against the NestQuant path is skewed.
    pub fn gemv_into(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let half = self.cols / 2;
        for r in 0..self.rows {
            let row = &self.packed[r * half..(r + 1) * half];
            let mut acc = 0f32;
            for (i, &b) in row.iter().enumerate() {
                let lo = (b & 0x0F) as i32 - 8;
                let hi = (b >> 4) as i32 - 8;
                acc += lo as f32 * x[2 * i] + hi as f32 * x[2 * i + 1];
            }
            y[r] = acc * self.deltas[r];
        }
    }

    /// Decode-amortized batched GEMM over the same panel kernel as the
    /// NestQuant path (`quant::gemm`): each weight row's nibbles are
    /// unpacked once and multiplied against the whole activation panel.
    /// `xt` is (batch, cols) row-major, `yt` (batch, rows); requires
    /// cols divisible by 8. `threads == 0` uses all available cores.
    pub fn gemm_into(&self, xt: &Mat, yt: &mut Mat, threads: usize, scratch: &mut GemmScratch) {
        self.gemm_into_with(kernels::active(), xt, yt, threads, scratch)
    }

    /// [`Self::gemm_into`] with an explicit dispatch tier for the shared
    /// panel microkernel (int4 nibble unpack stays scalar — it is not a
    /// lattice decode).
    pub fn gemm_into_with(
        &self,
        kern: Kernel,
        xt: &Mat,
        yt: &mut Mat,
        threads: usize,
        scratch: &mut GemmScratch,
    ) {
        let half = self.cols / 2;
        gemm::gemm_driver(
            self.rows,
            self.cols,
            xt,
            yt,
            threads,
            kern,
            scratch,
            |r, ebuf, bscale| {
                let row = &self.packed[r * half..(r + 1) * half];
                for (i, &b) in row.iter().enumerate() {
                    ebuf[2 * i] = (b & 0x0F) as i16 - 8;
                    ebuf[2 * i + 1] = (b >> 4) as i16 - 8;
                }
                bscale.fill(1.0);
                self.deltas[r]
            },
        );
    }

    /// Allocating convenience wrapper over [`Self::gemm_into`].
    pub fn gemm(&self, xt: &Mat, threads: usize) -> Mat {
        let mut yt = Mat::zeros(xt.rows, self.rows);
        self.gemm_into(xt, &mut yt, threads, &mut GemmScratch::new());
        yt
    }

    pub fn payload_bytes(&self) -> usize {
        self.packed.len() + self.deltas.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{propcheck, stats, Rng};

    #[test]
    fn roundtrip_bounded_error() {
        let mut rng = Rng::new(1001);
        let uq = UniformQuantizer::new(4);
        let x = rng.gauss_vec(256);
        let r = uq.roundtrip(&x);
        let maxabs = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let delta = maxabs / 8.0;
        for (a, b) in x.iter().zip(&r) {
            // δ/2 in the interior; up to δ at +maxabs (symmetric grid has
            // no +2^{R-1} level — the clamp costs one extra half-step).
            assert!((a - b).abs() <= delta + 1e-6);
        }
    }

    #[test]
    fn quantize_into_matches_quantize_and_reuses_capacity() {
        let mut rng = Rng::new(1009);
        let uq = UniformQuantizer::new(4);
        let mut buf = Vec::new();
        for n in [16usize, 64, 16] {
            let x = rng.gauss_vec(n);
            let (codes, delta) = uq.quantize(&x);
            let d2 = uq.quantize_into(&x, &mut buf);
            assert_eq!(buf, codes);
            assert_eq!(d2.to_bits(), delta.to_bits());
        }
        let cap = buf.capacity();
        let x = rng.gauss_vec(32);
        uq.quantize_into(&x, &mut buf);
        assert_eq!(buf.capacity(), cap, "shrinking input must not reallocate");
    }

    #[test]
    fn codes_in_range() {
        let mut rng = Rng::new(1002);
        for bits in [2u32, 3, 4, 8] {
            let uq = UniformQuantizer::new(bits);
            let x = rng.gauss_vec(128);
            let (codes, _) = uq.quantize(&x);
            let l = 1i32 << (bits - 1);
            for &c in &codes {
                assert!((c as i32) >= -l && (c as i32) < l);
            }
        }
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(1003);
        let x = rng.gauss_vec(512);
        let mut last = f64::INFINITY;
        for bits in [2u32, 4, 8] {
            let uq = UniformQuantizer::new(bits);
            let m = stats::mse(&x, &uq.roundtrip(&x));
            assert!(m < last);
            last = m;
        }
    }

    #[test]
    fn nestquant_beats_uniform_at_equal_rate() {
        // The headline shaping-gain claim (Fig. 3) at the vector level:
        // NestQuant q=16 (4 bits + β overhead) vs uniform 4-bit should
        // show materially lower MSE on iid Gaussian input.
        use crate::lattice::nested::NestedLatticeQuantizer;
        let mut rng = Rng::new(1004);
        let nq = NestedLatticeQuantizer::new(16, vec![0.22, 0.28, 0.38, 0.9]);
        let uq = UniformQuantizer::new(4);
        let mut mse_nq = 0.0;
        let mut mse_uq = 0.0;
        for _ in 0..100 {
            let x = rng.gauss_vec(256);
            mse_nq += stats::mse(&x, &nq.roundtrip(&x));
            mse_uq += stats::mse(&x, &uq.roundtrip(&x));
        }
        assert!(
            mse_nq < 0.75 * mse_uq,
            "NestQuant {mse_nq} not clearly better than uniform {mse_uq}"
        );
    }

    #[test]
    fn zero_vector() {
        let uq = UniformQuantizer::new(4);
        let x = vec![0f32; 16];
        assert_eq!(uq.roundtrip(&x), x);
    }

    #[test]
    fn packed_int4_matches_unpacked() {
        propcheck::check("int4-pack", 20, 1005, |rng| {
            let m = crate::util::linalg::Mat::from_vec(4, 32, rng.gauss_vec(128));
            let x = rng.gauss_vec(32);
            let packed = PackedInt4Matrix::quantize(&m);
            let y = packed.gemv(&x);
            // reference: fake-quant rows then dense matvec
            let uq = UniformQuantizer::new(4);
            let deq = uq.roundtrip_rows(&m);
            let expect = deq.matvec(&x);
            propcheck::assert_close(&y, &expect, 1e-4, 1e-3)
        });
    }

    #[test]
    fn int4_gemv_into_matches_gemv() {
        let mut rng = Rng::new(1007);
        let m = crate::util::linalg::Mat::from_vec(6, 40, rng.gauss_vec(240));
        let packed = PackedInt4Matrix::quantize(&m);
        let x = rng.gauss_vec(40);
        let a = packed.gemv(&x);
        let mut b = vec![0f32; 6];
        packed.gemv_into(&x, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn int4_gemm_matches_per_column_gemv() {
        propcheck::check("int4-gemm-vs-gemv", 8, 1008, |rng| {
            let m = crate::util::linalg::Mat::from_vec(9, 48, rng.gauss_vec(9 * 48));
            let packed = PackedInt4Matrix::quantize(&m);
            for &batch in &[1usize, 4, 19] {
                let xt =
                    crate::util::linalg::Mat::from_vec(batch, 48, rng.gauss_vec(batch * 48));
                for &threads in &[1usize, 2] {
                    let yt = packed.gemm(&xt, threads);
                    let mut y = vec![0f32; 9];
                    for c in 0..batch {
                        packed.gemv_into(xt.row(c), &mut y);
                        propcheck::assert_close(yt.row(c), &y, 1e-4, 1e-3)?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn packed_payload_is_half_byte_per_entry() {
        let mut rng = Rng::new(1006);
        let m = crate::util::linalg::Mat::from_vec(8, 64, rng.gauss_vec(512));
        let p = PackedInt4Matrix::quantize(&m);
        assert_eq!(p.payload_bytes(), 8 * 64 / 2 + 8 * 4);
    }
}
