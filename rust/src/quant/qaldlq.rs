//! QA-LDLQ — LDLQ corrected for *quantized activations* (paper §4.5,
//! Lemma 4.2, Appendix B).
//!
//! With activation quantization noise Z (E[Z]=0, J = E[ZZᵀ]) independent of
//! X (H = E[XXᵀ]), the output error δ(U) = WX − U(X+Z) is minimized by
//! running LDLQ on the *modified* weight W̃ = W·H·(H+J)⁻¹ with Hessian
//! H+J. The modification shrinks W along directions where quantization
//! noise would be amplified — the "amplification ratio" diagnostic below
//! (paper Fig. 6).

use crate::lattice::nested::NestedLatticeQuantizer;
use crate::quant::ldlq::ldlq_quantize;
use crate::quant::matrix::QuantizedMatrix;
use crate::util::linalg::{invert_spd, Mat};
use crate::util::Rng;

/// W̃ = W·H·(H+J)⁻¹ with isotropic noise J = ε²·I (Appendix B models the
/// activation-quantizer noise as isotropic at the chosen rate).
pub fn modified_weight(w: &Mat, h: &Mat, eps2: f32) -> Mat {
    assert_eq!(w.cols, h.rows);
    let mut hj = h.clone();
    hj.add_diag(eps2);
    let inv = invert_spd(&hj);
    w.matmul(h).matmul(&inv)
}

/// QA-LDLQ (Lemma 4.2): quantize W̃ with Hessian H + ε²I.
pub fn qa_ldlq_quantize(
    w: &Mat,
    h: &Mat,
    eps2: f32,
    nq: &NestedLatticeQuantizer,
) -> QuantizedMatrix {
    let wt = modified_weight(w, h, eps2);
    let mut hj = h.clone();
    hj.add_diag(eps2);
    ldlq_quantize(&wt, &hj, nq)
}

/// Amplification α(W, X) = E‖WX‖ / E‖X‖ over activation samples (rows of
/// `x`). Appendix B.
pub fn amplification(w: &Mat, x: &Mat) -> f64 {
    assert_eq!(w.cols, x.cols);
    let mut num = 0f64;
    let mut den = 0f64;
    for r in 0..x.rows {
        let y = w.matvec(x.row(r));
        num += crate::util::stats::norm2(&y);
        den += crate::util::stats::norm2(x.row(r));
    }
    num / den.max(1e-30)
}

/// Amplification ratio α(W, Z)/α(W, X) with Z iid Gaussian — how much
/// harder quantization noise hits this layer than its own activations
/// (paper: value projection of Llama-3-70B block 0 reaches ≈157).
pub fn amplification_ratio(w: &Mat, x: &Mat, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut z = Mat::zeros(x.rows.max(64), w.cols);
    rng.fill_gauss(&mut z.data);
    amplification(w, &z) / amplification(w, x)
}

/// The Fig. 6 x-axis: 1 − R² = E‖WX − W̃X‖² / Var(WX).
pub fn one_minus_r2(w: &Mat, wt: &Mat, x: &Mat) -> f64 {
    let mut num = 0f64;
    let mut var = 0f64;
    // mean of WX for variance
    let mut mean = vec![0f64; w.rows];
    let mut outs = Vec::with_capacity(x.rows);
    for r in 0..x.rows {
        let y = w.matvec(x.row(r));
        for (m, &v) in mean.iter_mut().zip(&y) {
            *m += v as f64;
        }
        outs.push(y);
    }
    for m in mean.iter_mut() {
        *m /= x.rows as f64;
    }
    for (r, y) in outs.iter().enumerate() {
        let yt = wt.matvec(x.row(r));
        for i in 0..w.rows {
            num += ((y[i] - yt[i]) as f64).powi(2);
            var += (y[i] as f64 - mean[i]).powi(2);
        }
    }
    num / var.max(1e-30)
}

/// Construct a synthetic "hard" layer with a prescribed amplification
/// ratio: W acts with gain `g_perp` on the orthogonal complement of the
/// activation subspace and gain ~1 on it. Stands in for the Llama-3-70B
/// v_proj pathology (ratio ≈157) that motivates QA-LDLQ.
pub fn synthetic_high_amplification_layer(
    out_dim: usize,
    in_dim: usize,
    act_rank: usize,
    g_perp: f32,
    seed: u64,
) -> (Mat, Mat) {
    assert!(act_rank < in_dim);
    let mut rng = Rng::new(seed);
    let basis = crate::rotation::hadamard::random_orthogonal(in_dim, &mut rng);
    // activations live in the span of the first act_rank basis columns
    let samples = 4 * in_dim;
    let mut x = Mat::zeros(samples, in_dim);
    for r in 0..samples {
        for k in 0..act_rank {
            let c = rng.gauss_f32();
            for i in 0..in_dim {
                x[(r, i)] += c * basis[(i, k)];
            }
        }
    }
    // W = A·P_span + g_perp·B·P_perp  (A, B random row mixers)
    let mut w = Mat::zeros(out_dim, in_dim);
    for r in 0..out_dim {
        for k in 0..in_dim {
            let gain = if k < act_rank { 1.0 } else { g_perp };
            let c = rng.gauss_f32() * gain / (in_dim as f32).sqrt();
            for i in 0..in_dim {
                w[(r, i)] += c * basis[(i, k)];
            }
        }
    }
    (w, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ldlq::hessian_from_activations;

    fn nq() -> NestedLatticeQuantizer {
        NestedLatticeQuantizer::new(14, vec![0.25, 0.32, 0.45, 1.0])
    }

    #[test]
    fn lemma_4_2_identity() {
        // E‖δ(U)‖² = tr[(W̃−U)(H+J)(W̃−U)ᵀ] + C: verify the first term's
        // minimizer property by checking the algebraic identity
        // (W−U)H(W−U)ᵀ + UJUᵀ = (W̃−U)(H+J)(W̃−U)ᵀ + C on traces for
        // random U.
        let mut rng = Rng::new(1301);
        let n = 24;
        let a = 6;
        let w = Mat::from_vec(a, n, rng.gauss_vec(a * n));
        let x = Mat::from_vec(128, n, rng.gauss_vec(128 * n));
        let h = hessian_from_activations(&x, 0.02);
        let eps2 = 0.3f32;
        let wt = modified_weight(&w, &h, eps2);
        let mut hj = h.clone();
        hj.add_diag(eps2);

        // C = W(H − H(H+J)⁻¹H)Wᵀ = W·H·Wᵀ − W̃·(H+J)·W̃ᵀ (trace)
        let tr = |m: &Mat| -> f64 {
            (0..m.rows).map(|i| m[(i, i)] as f64).sum()
        };
        let c = tr(&w.matmul(&h).matmul(&w.transpose()))
            - tr(&wt.matmul(&hj).matmul(&wt.transpose()));

        for trial in 0..5 {
            let u = Mat::from_vec(a, n, rng.gauss_vec(a * n));
            // lhs = tr[(W−U)H(W−U)ᵀ] + tr[U·(ε²I)·Uᵀ]
            let mut wu = w.clone();
            for (p, q) in wu.data.iter_mut().zip(&u.data) {
                *p -= q;
            }
            let lhs = tr(&wu.matmul(&h).matmul(&wu.transpose()))
                + eps2 as f64 * u.data.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
            // rhs = tr[(W̃−U)(H+J)(W̃−U)ᵀ] + C
            let mut wtu = wt.clone();
            for (p, q) in wtu.data.iter_mut().zip(&u.data) {
                *p -= q;
            }
            let rhs = tr(&wtu.matmul(&hj).matmul(&wtu.transpose())) + c;
            assert!(
                (lhs - rhs).abs() < 1e-2 * lhs.abs().max(1.0),
                "trial {trial}: Lemma 4.2 identity violated: {lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn modified_weight_reduces_amplification_ratio() {
        // Fig. 6: increasing ε² decreases the amplification ratio at a
        // small 1−R² cost.
        let (w, x) = synthetic_high_amplification_layer(16, 32, 8, 30.0, 1302);
        let h = hessian_from_activations(&x, 1e-4);
        let base_ratio = amplification_ratio(&w, &x, 1);
        assert!(base_ratio > 5.0, "synthetic layer not pathological: {base_ratio}");

        let mut last_ratio = base_ratio;
        let mut last_r2 = 0.0;
        for eps2 in [1e-3f32, 1e-2, 1e-1] {
            let wt = modified_weight(&w, &h, eps2);
            let ratio = amplification_ratio(&wt, &x, 1);
            let r2 = one_minus_r2(&w, &wt, &x);
            assert!(ratio <= last_ratio * 1.05, "ratio not decreasing at ε²={eps2}");
            assert!(r2 >= last_r2 - 1e-9, "1−R² not increasing at ε²={eps2}");
            last_ratio = ratio;
            last_r2 = r2;
        }
        assert!(
            last_ratio < base_ratio * 0.5,
            "modification too weak: {base_ratio} → {last_ratio}"
        );
    }

    #[test]
    fn qa_ldlq_beats_plain_ldlq_under_activation_noise() {
        // The end-metric: E‖WX − U(X+Z)‖² with Z ~ N(0, ε²I).
        let (w, x) = synthetic_high_amplification_layer(16, 32, 8, 30.0, 1303);
        let h = hessian_from_activations(&x, 1e-4);
        let nq = nq();
        let eps2 = 0.05f32;

        let u_ldlq = crate::quant::ldlq::ldlq_quantize(&w, &h, &nq).dequantize(&nq);
        let u_qa = qa_ldlq_quantize(&w, &h, eps2, &nq).dequantize(&nq);

        let mut rng = Rng::new(1304);
        let mut eval = |u: &Mat| -> f64 {
            let mut total = 0f64;
            for r in 0..x.rows {
                let xr = x.row(r);
                let wx = w.matvec(xr);
                let mut xn: Vec<f32> = xr.to_vec();
                for v in xn.iter_mut() {
                    *v += rng.gauss_f32() * eps2.sqrt();
                }
                let ux = u.matvec(&xn);
                for i in 0..w.rows {
                    total += ((wx[i] - ux[i]) as f64).powi(2);
                }
            }
            total
        };
        let loss_ldlq = eval(&u_ldlq);
        let loss_qa = eval(&u_qa);
        assert!(
            loss_qa < loss_ldlq,
            "QA-LDLQ {loss_qa} not below LDLQ {loss_ldlq}"
        );
    }

    #[test]
    fn eps2_zero_recovers_ldlq() {
        let mut rng = Rng::new(1305);
        let w = Mat::from_vec(4, 32, rng.gauss_vec(128));
        let x = Mat::from_vec(64, 32, rng.gauss_vec(64 * 32));
        let h = hessian_from_activations(&x, 0.02);
        let nq = nq();
        let a = qa_ldlq_quantize(&w, &h, 0.0, &nq);
        let b = crate::quant::ldlq::ldlq_quantize(&w, &h, &nq);
        // W̃ = W·H·H⁻¹ = W numerically (within inversion error): codes match
        assert_eq!(a.codes, b.codes, "ε²=0 should reduce to plain LDLQ");
    }
}
