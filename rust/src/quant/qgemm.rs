//! The performance hot path: packed NestQuant(M) storage and quantized
//! GEMV with decode-on-the-fly, mirroring the paper's CUDA kernel
//! (Appendix E) in CPU-friendly integer arithmetic.
//!
//! Key identity (all-integer decode): with the 2·E8 generator G of
//! Appendix E, t = G·c is an integer vector equal to twice the coset
//! point. Writing m = 2q, the minimum-energy representative works out to
//!
//!   decoded (in half-units)  =  chosen residual e, where
//!   e1_i = t_i − m·round(t_i/m)   (D8 candidate, parity-fixed)
//!   e2_i = t_i − q − m·floor(t_i/m)  (D8+½ candidate, parity-fixed)
//!
//! so the decoded block is *exactly* a small integer vector — the paper's
//! "int8-multipliers" observation (§3). Both GEMV accumulation and
//! quantized·quantized dot products run on i32 integers, with β/scale
//! applied per block/row.
//!
//! The parity-fix position is fixed to coordinate 0 (NestQuantM decode,
//! Appendix D) and matches `lattice::e8::nearest_e8_m` bit-for-bit.

use super::gemm::{self, GemmScratch};
use super::kernels::{self, Kernel};
use super::matrix::QuantizedMatrix;
use crate::lattice::e8::D;
use crate::lattice::nested::{NestedLatticeQuantizer, QuantizedVector};
use crate::util::linalg::Mat;

/// t = G·c for the Appendix-E generator, exploiting its sparsity:
/// t0=c0, t1=c0+2c2, t2=c0+2c4, t3=c0+2c6, t4=c0+4c1+2Σ_{j≥2}c_j,
/// t5=c0+2c3, t6=c0+2c5, t7=c0+2c7.
#[inline(always)]
pub fn gmul(c: &[u8; D]) -> [i32; D] {
    let c0 = c[0] as i32;
    let s = (c[2] as i32 + c[3] as i32 + c[4] as i32 + c[5] as i32)
        + (c[6] as i32 + c[7] as i32);
    [
        c0,
        c0 + 2 * c[2] as i32,
        c0 + 2 * c[4] as i32,
        c0 + 2 * c[6] as i32,
        c0 + 4 * c[1] as i32 + 2 * s,
        c0 + 2 * c[3] as i32,
        c0 + 2 * c[5] as i32,
        c0 + 2 * c[7] as i32,
    ]
}

/// Integer NestQuantM decode: coset code → decoded block in *half units*
/// (decoded value = e/2). Matches `VoronoiCodec::new_m(q).decode` exactly
/// (both call `decode_t_halfunits`); kept as a separate entry point with
/// the sparse `gmul` for the GEMV inner loop.
#[inline(always)]
pub fn decode_block_i32(c: &[u8; D], q: i32) -> [i32; D] {
    let t = gmul(c);
    crate::lattice::voronoi::decode_t_halfunits(&t, q, true)
}

/// Precomputed constants for the branch-free GEMV decode: division by
/// m = 2q is replaced by a magic-number multiply (t < 2048 always holds:
/// t ≤ c0 + 4·15 + 2·6·15 < 256 for q ≤ 16), exact over the full range
/// (verified by `magic_division_exact`).
#[derive(Clone, Copy, Debug)]
pub struct DecodeConsts {
    pub q: i32,
    /// m = 2q (crate-visible so the SIMD tiers in `quant::kernels` can
    /// broadcast it without re-deriving)
    pub(crate) m: i32,
    /// floor(x/m) = (x+BIAS)·magic >> 21 − BIAS/m trick avoided: t ≥ 0 here,
    /// so floor(t/m) = (t·magic) >> 21 with magic = ⌈2^21/m⌉.
    pub(crate) magic: u32,
}

impl DecodeConsts {
    pub fn new(q: i32) -> Self {
        let m = 2 * q;
        DecodeConsts {
            q,
            m,
            magic: (1u32 << 21).div_ceil(m as u32),
        }
    }

    #[inline(always)]
    fn div_m(self, x: i32) -> i32 {
        debug_assert!(x >= 0);
        ((x as u32 * self.magic) >> 21) as i32
    }

    /// Branch-free NestQuantM decode (flip position 0), identical output
    /// to `decode_block_i32` — the GEMV hot path.
    #[inline(always)]
    pub fn decode(self, c: &[u8; D], out: &mut [i32; D]) {
        let t = gmul(c);
        let (q, m) = (self.q, self.m);
        let mut e1 = [0i32; D];
        let mut e2 = [0i32; D];
        let mut par1 = 0i32;
        let mut par2 = 0i32;
        for i in 0..D {
            let r1 = self.div_m(t[i] + q);
            e1[i] = t[i] - m * r1;
            par1 += r1;
            let r2 = self.div_m(t[i]);
            e2[i] = t[i] - q - m * r2;
            par2 += r2;
        }
        // branch-free parity fix on coordinate 0:
        // dir = +1 if e ≥ 0 else −1; e0 −= m·dir·(par&1)
        let dir1 = 1 | (e1[0] >> 31); // sign: e≥0 → +1, e<0 → −1
        e1[0] -= m * dir1 * (par1 & 1);
        let dir2 = 1 | (e2[0] >> 31);
        e2[0] -= m * dir2 * (par2 & 1);
        let mut cost1 = 0i32;
        let mut cost2 = 0i32;
        for i in 0..D {
            cost1 += e1[i] * e1[i];
            cost2 += e2[i] * e2[i];
        }
        let pick1 = cost1 <= cost2;
        for i in 0..D {
            out[i] = if pick1 { e1[i] } else { e2[i] };
        }
    }
}

/// NestQuant(M) matrix in packed storage: 4-bit codes (q ≤ 16), 2-bit β
/// indices (k ≤ 4), per-row f32 scales. This is the Table 4 memory layout:
/// ~4.25 bits/entry.
pub struct PackedNestMatrix {
    pub rows: usize,
    pub cols: usize,
    pub q: i32,
    /// β dictionary (k ≤ 4), pre-halved: beta_half[t] = β_t/2 — folds the
    /// half-unit decode scale into the dictionary.
    pub beta_half: [f32; 4],
    /// 4-bit codes, two per byte, row-major
    pub codes: Vec<u8>,
    /// 2-bit β indices, four per byte, row-major
    pub beta_idx: Vec<u8>,
    /// per-row s_r/√n denormalization factors
    pub row_scale: Vec<f32>,
}

impl PackedNestMatrix {
    /// Whether a quantizer/shape pair is representable in packed 4-bit
    /// storage (the engine's eligibility check for the integer backend).
    pub fn supports(nq: &NestedLatticeQuantizer, cols: usize) -> bool {
        nq.q() <= 16 && nq.k() <= 4 && nq.codec.m_variant && cols % D == 0 && cols > 0
    }

    /// Quantize `m` with the given quantizer (q ≤ 16, k ≤ 4 required).
    pub fn quantize(m: &Mat, nq: &NestedLatticeQuantizer) -> Self {
        let qm = QuantizedMatrix::quantize(m, nq);
        Self::from_quantized(&qm, nq)
    }

    /// Pack an already-quantized matrix without re-quantizing: the
    /// engine's (QA-)LDLQ path chooses the codes, so packing must keep
    /// them bit-for-bit (re-running Algorithm 3 would discard the
    /// feedback corrections).
    pub fn from_quantized(qm: &QuantizedMatrix, nq: &NestedLatticeQuantizer) -> Self {
        assert!(nq.q() <= 16, "packed storage requires q ≤ 16");
        assert!(nq.k() <= 4, "packed storage requires k ≤ 4");
        assert!(
            nq.codec.m_variant,
            "packed GEMV decodes with the NestQuantM oracle; quantize with \
             NestedLatticeQuantizer::new_m so overload checks match"
        );
        assert_eq!(qm.cols % D, 0, "cols must be divisible by 8");
        let mut codes = vec![0u8; qm.rows * qm.cols / 2];
        for (i, pair) in qm.codes.chunks_exact(2).enumerate() {
            codes[i] = pair[0] | (pair[1] << 4);
        }
        let blocks = qm.rows * qm.cols / D;
        let mut beta_idx = vec![0u8; blocks.div_ceil(4)];
        for (i, &b) in qm.beta_idx.iter().enumerate() {
            beta_idx[i / 4] |= b << (2 * (i % 4));
        }
        let mut beta_half = [0f32; 4];
        for (t, &b) in nq.betas.iter().enumerate() {
            beta_half[t] = b * 0.5;
        }
        let row_scale = qm
            .scales
            .iter()
            .map(|&s| s / (qm.cols as f32).sqrt())
            .collect();
        PackedNestMatrix {
            rows: qm.rows,
            cols: qm.cols,
            q: nq.q() as i32,
            beta_half,
            codes,
            beta_idx,
            row_scale,
        }
    }

    /// y = W·x with integer decode-on-the-fly (the Table 4 NestQuantM GEMV).
    pub fn gemv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0f32; self.rows];
        self.gemv_into(x, &mut y);
        y
    }

    /// `gemv` into a caller-provided buffer (allocation-free hot path).
    ///
    /// Perf notes (EXPERIMENTS.md §Perf): division-by-m is strength-
    /// reduced to a magic multiply and the parity fix is branch-free
    /// (`DecodeConsts::decode`) — the two top hotspots of the naive
    /// decode (16 idiv + 2 unpredictable branches per 8-block).
    pub fn gemv_into(&self, x: &[f32], y: &mut [f32]) {
        self.gemv_into_with(kernels::active(), x, y)
    }

    /// [`Self::gemv_into`] with an explicit dispatch tier — the direct
    /// entry point tests and benches use to compare tiers in one process
    /// (the `OnceLock`-cached [`kernels::active`] choice cannot change
    /// after first use).
    pub fn gemv_into_with(&self, kern: Kernel, x: &[f32], y: &mut [f32]) {
        let bpr = self.cols / D; // blocks per row
        let code_bytes_per_row = self.cols / 2;
        let consts = DecodeConsts::new(self.q);
        let mut cbuf = [0u8; D];
        let mut e = [0i32; D];
        for r in 0..self.rows {
            let crow = &self.codes[r * code_bytes_per_row..(r + 1) * code_bytes_per_row];
            let mut acc = 0f32;
            for j in 0..bpr {
                for b in 0..4 {
                    let byte = crow[j * 4 + b];
                    cbuf[2 * b] = byte & 0x0F;
                    cbuf[2 * b + 1] = byte >> 4;
                }
                kernels::decode_block(kern, consts, &cbuf, &mut e);
                let xb = &x[j * D..(j + 1) * D];
                let mut d = 0f32;
                for i in 0..D {
                    d += e[i] as f32 * xb[i];
                }
                let bidx = r * bpr + j;
                let beta = self.beta_half
                    [((self.beta_idx[bidx / 4] >> (2 * (bidx % 4))) & 0x3) as usize];
                acc += d * beta;
            }
            y[r] = acc * self.row_scale[r];
        }
    }

    /// Decode weight row `r` into half-unit integers (`ebuf`, `cols`
    /// entries) and the per-block β_t/2 multipliers (`bscale`, cols/8
    /// entries) — one decode per 8-block, shared by every activation
    /// column of a GEMM panel.
    fn decode_row(
        &self,
        kern: Kernel,
        r: usize,
        consts: DecodeConsts,
        ebuf: &mut [i16],
        bscale: &mut [f32],
    ) {
        let bpr = self.cols / D;
        let code_bytes_per_row = self.cols / 2;
        let crow = &self.codes[r * code_bytes_per_row..(r + 1) * code_bytes_per_row];
        kernels::decode_nibble_row(kern, consts, crow, ebuf);
        for (j, b) in bscale.iter_mut().enumerate() {
            let bidx = r * bpr + j;
            *b = self.beta_half
                [((self.beta_idx[bidx / 4] >> (2 * (bidx % 4))) & 0x3) as usize];
        }
    }

    /// Batched GEMM, Y = X·Wᵀ: `xt` is (batch, cols) row-major — one
    /// activation vector per row, the engine's (seq, d) layout — and `yt`
    /// is (batch, rows). Each packed 8-block is decoded **once** per call
    /// into an i16 row buffer and multiplied against the whole activation
    /// panel (decode-amortized; EXPERIMENTS.md §Perf), with weight rows
    /// partitioned across `std::thread::scope` workers (`threads == 0`
    /// uses all available cores). Results are bit-for-bit identical to
    /// calling [`Self::gemv_into`] once per batch row.
    pub fn gemm_into(&self, xt: &Mat, yt: &mut Mat, threads: usize, scratch: &mut GemmScratch) {
        self.gemm_into_with(kernels::active(), xt, yt, threads, scratch)
    }

    /// [`Self::gemm_into`] with an explicit dispatch tier (see
    /// [`Self::gemv_into_with`]).
    pub fn gemm_into_with(
        &self,
        kern: Kernel,
        xt: &Mat,
        yt: &mut Mat,
        threads: usize,
        scratch: &mut GemmScratch,
    ) {
        let consts = DecodeConsts::new(self.q);
        gemm::gemm_driver(
            self.rows,
            self.cols,
            xt,
            yt,
            threads,
            kern,
            scratch,
            |r, ebuf, bscale| {
                self.decode_row(kern, r, consts, ebuf, bscale);
                self.row_scale[r]
            },
        );
    }

    /// Allocating convenience wrapper over [`Self::gemm_into`].
    pub fn gemm(&self, xt: &Mat, threads: usize) -> Mat {
        let mut yt = Mat::zeros(xt.rows, self.rows);
        self.gemm_into(xt, &mut yt, threads, &mut GemmScratch::new());
        yt
    }

    /// Payload bytes actually touched per GEMV (the memory-bound metric).
    pub fn payload_bytes(&self) -> usize {
        self.codes.len() + self.beta_idx.len() + self.row_scale.len() * 4
    }

    /// Bits per entry of the packed representation.
    pub fn bits_per_entry(&self) -> f64 {
        self.payload_bytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }
}

/// Integer-path inner product of two quantized vectors (Algorithm 4 with
/// i32 accumulation): both decodes stay integer, the per-block product is
/// exact in i64, and β/scales are applied at the end. Requires both
/// vectors quantized with the same (M-variant) quantizer.
pub fn qdot_int(nq: &NestedLatticeQuantizer, a: &QuantizedVector, b: &QuantizedVector) -> f32 {
    assert_eq!(a.n, b.n);
    if a.scale == 0.0 || b.scale == 0.0 {
        return 0.0;
    }
    let q = nq.q() as i32;
    let mut acc = 0f64;
    let mut ca = [0u8; D];
    let mut cb = [0u8; D];
    for j in 0..a.n / D {
        ca.copy_from_slice(&a.codes[j * D..(j + 1) * D]);
        cb.copy_from_slice(&b.codes[j * D..(j + 1) * D]);
        let ea = decode_block_i32(&ca, q);
        let eb = decode_block_i32(&cb, q);
        let mut d = 0i64;
        for i in 0..D {
            d += ea[i] as i64 * eb[i] as i64;
        }
        acc += d as f64
            * 0.25
            * nq.betas[a.beta_idx[j] as usize] as f64
            * nq.betas[b.beta_idx[j] as usize] as f64;
    }
    (acc * a.scale as f64 * b.scale as f64 / a.n as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::voronoi::VoronoiCodec;
    use crate::util::{propcheck, Rng};

    #[test]
    fn gmul_matches_generator_matrix() {
        use crate::lattice::voronoi::G2E8;
        let mut rng = Rng::new(1101);
        for _ in 0..200 {
            let mut c = [0u8; D];
            for v in c.iter_mut() {
                *v = rng.below(16) as u8;
            }
            let fast = gmul(&c);
            for i in 0..D {
                let mut acc = 0i32;
                for j in 0..D {
                    acc += G2E8[i][j] as i32 * c[j] as i32;
                }
                assert_eq!(fast[i], acc, "gmul mismatch at {i} for {c:?}");
            }
        }
    }

    #[test]
    fn integer_decode_matches_float_m_decode() {
        propcheck::check("int-decode-vs-float", 500, 1102, |rng| {
            for &q in &[3u32, 8, 14, 16] {
                let codec = VoronoiCodec::new_m(q);
                let mut c = [0u8; D];
                for v in c.iter_mut() {
                    *v = rng.below(q as usize) as u8;
                }
                let slow = codec.decode(&c);
                let fast = decode_block_i32(&c, q as i32);
                for i in 0..D {
                    if (fast[i] as f32) * 0.5 != slow[i] {
                        return Err(format!(
                            "q={q} code {c:?}: fast {:?} (half-units) vs slow {:?}",
                            fast, slow
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn packed_gemv_matches_dequantized_reference() {
        propcheck::check("packed-gemv", 10, 1103, |rng| {
            let nq =
                NestedLatticeQuantizer::new_m(14, vec![0.25, 0.32, 0.45, 1.0]);
            let m = Mat::from_vec(8, 64, rng.gauss_vec(512));
            let x = rng.gauss_vec(64);
            let packed = PackedNestMatrix::quantize(&m, &nq);
            let fast = packed.gemv(&x);
            // reference: unpacked QuantizedMatrix qgemv (float decode path)
            let qm = super::super::matrix::QuantizedMatrix::quantize(&m, &nq);
            let slow = qm.qgemv(&nq, &x);
            propcheck::assert_close(&fast, &slow, 1e-4, 1e-3)
        });
    }

    #[test]
    fn qdot_int_matches_float_dot() {
        propcheck::check("qdot-int", 30, 1104, |rng| {
            let nq =
                NestedLatticeQuantizer::new_m(14, vec![0.25, 0.32, 0.45, 1.0]);
            let a = rng.gauss_vec(64);
            let b = rng.gauss_vec(64);
            let qa = nq.quantize(&a);
            let qb = nq.quantize(&b);
            let int = qdot_int(&nq, &qa, &qb);
            let float = nq.dot(&qa, &qb);
            if (int - float).abs() < 1e-3 * (1.0 + float.abs()) {
                Ok(())
            } else {
                Err(format!("int {int} vs float {float}"))
            }
        });
    }

    #[test]
    fn packed_bits_per_entry_about_4_25() {
        let mut rng = Rng::new(1105);
        let nq = NestedLatticeQuantizer::new_m(14, vec![0.25, 0.32, 0.45, 1.0]);
        let m = Mat::from_vec(64, 256, rng.gauss_vec(64 * 256));
        let packed = PackedNestMatrix::quantize(&m, &nq);
        let bits = packed.bits_per_entry();
        // 4 (codes) + 0.25 (β) + 32/256 (scale) = 4.375
        assert!(bits > 4.2 && bits < 4.5, "bits/entry {bits}");
    }

    #[test]
    fn magic_division_exact() {
        // floor(t/m) via the magic multiply must be exact over the full t
        // range (t = G·c < 256 for codes < 16; we verify far beyond),
        // asserted through the actual hot-path entry point.
        for q in 2..=16i32 {
            let c = DecodeConsts::new(q);
            for t in 0..4096i32 {
                assert_eq!(c.div_m(t), t / (2 * q), "q={q} t={t}");
            }
        }
    }

    #[test]
    fn fast_decode_matches_reference() {
        let mut rng = Rng::new(1107);
        for &q in &[3i32, 8, 14, 16] {
            let consts = DecodeConsts::new(q);
            let mut out = [0i32; D];
            for _ in 0..2000 {
                let mut c = [0u8; D];
                for v in c.iter_mut() {
                    *v = rng.below(q as usize) as u8;
                }
                consts.decode(&c, &mut out);
                assert_eq!(out, decode_block_i32(&c, q), "q={q} c={c:?}");
            }
        }
    }

    #[test]
    fn gemm_matches_per_column_gemv_bitexact() {
        // The decode-amortized GEMM must be a pure reassociation-free
        // batching of the scalar GEMV: identical f32 operation sequence
        // per output element, hence bit-for-bit equal results across
        // shapes (incl. rows not divisible by the worker count), batch
        // sizes (incl. non-multiples of the panel width), and threads.
        propcheck::check("gemm-vs-gemv-bitexact", 5, 1108, |rng| {
            let nq = NestedLatticeQuantizer::new_m(14, vec![0.25, 0.32, 0.45, 1.0]);
            for &(rows, cols) in &[(3usize, 16usize), (8, 64), (17, 40)] {
                let m = Mat::from_vec(rows, cols, rng.gauss_vec(rows * cols));
                let packed = PackedNestMatrix::quantize(&m, &nq);
                for &batch in &[1usize, 5, 16, 33] {
                    let xt = Mat::from_vec(batch, cols, rng.gauss_vec(batch * cols));
                    for &threads in &[1usize, 3] {
                        let yt = packed.gemm(&xt, threads);
                        let mut y = vec![0f32; rows];
                        for c in 0..batch {
                            packed.gemv_into(xt.row(c), &mut y);
                            for r in 0..rows {
                                if yt[(c, r)].to_bits() != y[r].to_bits() {
                                    return Err(format!(
                                        "({rows}x{cols}) batch={batch} threads={threads} \
                                         col {c} row {r}: gemm {} vs gemv {}",
                                        yt[(c, r)],
                                        y[r]
                                    ));
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gemm_kernel_tiers_bitexact_vs_scalar_gemv() {
        // every host-supported dispatch tier must produce the same bits
        // as the forced-scalar GEMV — the end-to-end form of the
        // per-kernel parity propchecks in quant::kernels.
        let mut rng = Rng::new(1112);
        let nq = NestedLatticeQuantizer::new_m(14, vec![0.25, 0.32, 0.45, 1.0]);
        let (rows, cols, batch) = (9usize, 40usize, 19usize);
        let m = Mat::from_vec(rows, cols, rng.gauss_vec(rows * cols));
        let packed = PackedNestMatrix::quantize(&m, &nq);
        let xt = Mat::from_vec(batch, cols, rng.gauss_vec(batch * cols));
        let mut y = vec![0f32; rows];
        for k in kernels::available() {
            let mut yt = Mat::zeros(batch, rows);
            packed.gemm_into_with(k, &xt, &mut yt, 2, &mut GemmScratch::new());
            for c in 0..batch {
                packed.gemv_into_with(Kernel::Scalar, xt.row(c), &mut y);
                for r in 0..rows {
                    assert_eq!(
                        yt[(c, r)].to_bits(),
                        y[r].to_bits(),
                        "tier {} c={c} r={r}",
                        k.name()
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_scratch_reuse_across_shapes() {
        // scratch buffers are resized per call; stale contents from a
        // larger previous shape must not leak into smaller results.
        let mut rng = Rng::new(1109);
        let nq = NestedLatticeQuantizer::new_m(14, vec![0.25, 0.32, 0.45, 1.0]);
        let mut scratch = GemmScratch::new();
        for &(rows, cols, batch) in &[(12usize, 64usize, 40usize), (5, 24, 3), (9, 48, 17)] {
            let m = Mat::from_vec(rows, cols, rng.gauss_vec(rows * cols));
            let packed = PackedNestMatrix::quantize(&m, &nq);
            let xt = Mat::from_vec(batch, cols, rng.gauss_vec(batch * cols));
            let mut yt = Mat::zeros(batch, rows);
            packed.gemm_into(&xt, &mut yt, 2, &mut scratch);
            let mut y = vec![0f32; rows];
            for c in 0..batch {
                packed.gemv_into(xt.row(c), &mut y);
                for r in 0..rows {
                    assert_eq!(
                        yt[(c, r)].to_bits(),
                        y[r].to_bits(),
                        "({rows}x{cols}) b={batch} c={c} r={r}"
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_empty_batch_is_noop() {
        let mut rng = Rng::new(1110);
        let nq = NestedLatticeQuantizer::new_m(14, vec![0.25, 0.32, 0.45, 1.0]);
        let m = Mat::from_vec(8, 16, rng.gauss_vec(128));
        let packed = PackedNestMatrix::quantize(&m, &nq);
        let xt = Mat::zeros(0, 16);
        let yt = packed.gemm(&xt, 4);
        assert_eq!(yt.rows, 0);
        assert!(yt.data.is_empty());
    }

    #[test]
    fn from_quantized_preserves_ldlq_codes() {
        // the engine path: LDLQ picks the codes, packing must not
        // re-quantize — the packed GEMV must match the dequantized
        // LDLQ matrix, not Algorithm 3 re-applied to it.
        let mut rng = Rng::new(1111);
        let w = Mat::from_vec(16, 32, rng.gauss_vec(512));
        let acts = Mat::from_vec(64, 32, rng.gauss_vec(64 * 32));
        let h = crate::quant::ldlq::hessian_from_activations(&acts, 0.01);
        let (qm, nq) =
            crate::quant::ldlq::ldlq_quantize_adaptive(&w, &h, 14, 4, 3.0 / 14.0, true);
        let packed = PackedNestMatrix::from_quantized(&qm, &nq);
        let deq = qm.dequantize(&nq);
        let x = rng.gauss_vec(32);
        let fast = packed.gemv(&x);
        let slow = deq.matvec(&x);
        propcheck::assert_close(&fast, &slow, 1e-4, 1e-3).unwrap();
    }

    #[test]
    fn decoded_values_fit_i16() {
        // |e| ≤ m·(1 + covering radius slack); verify empirically over all
        // q=16 random codes: needed for a future i16 SIMD path.
        let mut rng = Rng::new(1106);
        for _ in 0..2000 {
            let mut c = [0u8; D];
            for v in c.iter_mut() {
                *v = rng.below(16) as u8;
            }
            let e = decode_block_i32(&c, 16);
            for &v in &e {
                assert!(v.abs() <= 3 * 32, "|e|={v} too large");
                assert!(i16::try_from(v).is_ok());
            }
        }
    }
}
