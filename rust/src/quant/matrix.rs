//! NestQuant matrix quantization (paper §4.2): each row is L2-normalized
//! and quantized blockwise with the multi-β nested-lattice codebook
//! (Algorithm 3). Storage keeps the coset codes + β indices + per-row
//! scales, supporting both full dequantization and quantized dot products.

use crate::lattice::e8::D;
use crate::lattice::nested::{NestedLatticeQuantizer, QuantizedVector};
use crate::util::linalg::Mat;

/// A matrix quantized row-wise with NestQuant.
#[derive(Clone, Debug)]
pub struct QuantizedMatrix {
    pub rows: usize,
    pub cols: usize,
    /// nesting ratio the codes were produced at. Recorded at quantize
    /// time so byte accounting can never be called with a different rate
    /// than the payload actually uses.
    pub q: u32,
    /// hierarchical levels M (1 = the flat single-level code). M-level
    /// matrices (`lattice::hierarchical`) store M digit groups per
    /// 8-block — `codes.len() == rows·cols·levels`, laid out
    /// `[row][block][level][coord]` — so payload accounting counts
    /// M·⌈log2 q⌉ bits per logical entry automatically.
    pub levels: u32,
    /// coset codes, row-major, one byte per entry (values < q);
    /// `rows·cols·levels` entries total
    pub codes: Vec<u8>,
    /// β indices, one per 8-block, row-major (rows × cols/8)
    pub beta_idx: Vec<u8>,
    /// per-row L2 norms s_r
    pub scales: Vec<f32>,
}

impl QuantizedMatrix {
    /// Quantize a dense matrix (cols must be divisible by 8).
    pub fn quantize(m: &Mat, nq: &NestedLatticeQuantizer) -> Self {
        assert_eq!(m.cols % D, 0, "cols must be divisible by 8");
        let mut codes = vec![0u8; m.rows * m.cols];
        let mut beta_idx = vec![0u8; m.rows * m.cols / D];
        let mut scales = vec![0f32; m.rows];
        let bpr = m.cols / D;
        for r in 0..m.rows {
            let qv = nq.quantize(m.row(r));
            codes[r * m.cols..(r + 1) * m.cols].copy_from_slice(&qv.codes);
            beta_idx[r * bpr..(r + 1) * bpr].copy_from_slice(&qv.beta_idx);
            scales[r] = qv.scale;
        }
        QuantizedMatrix {
            rows: m.rows,
            cols: m.cols,
            q: nq.q(),
            levels: 1,
            codes,
            beta_idx,
            scales,
        }
    }

    /// View row r as a `QuantizedVector` (clones the row's storage).
    pub fn row_qv(&self, r: usize) -> QuantizedVector {
        debug_assert_eq!(self.levels, 1, "flat-code view of an M-level matrix");
        let bpr = self.cols / D;
        QuantizedVector {
            codes: self.codes[r * self.cols..(r + 1) * self.cols].to_vec(),
            beta_idx: self.beta_idx[r * bpr..(r + 1) * bpr].to_vec(),
            scale: self.scales[r],
            n: self.cols,
        }
    }

    /// Full dequantization back to a dense matrix.
    pub fn dequantize(&self, nq: &NestedLatticeQuantizer) -> Mat {
        debug_assert_eq!(self.levels, 1, "use HierarchicalQuantizer::dequantize_matrix");
        let mut out = Mat::zeros(self.rows, self.cols);
        let bpr = self.cols / D;
        for r in 0..self.rows {
            if self.scales[r] == 0.0 {
                continue;
            }
            let denorm = self.scales[r] / (self.cols as f32).sqrt();
            let mut c = [0u8; D];
            for j in 0..bpr {
                let off = r * self.cols + j * D;
                c.copy_from_slice(&self.codes[off..off + D]);
                let rec = nq.decode_block(&c, self.beta_idx[r * bpr + j]);
                for i in 0..D {
                    out[(r, j * D + i)] = rec[i] * denorm;
                }
            }
        }
        out
    }

    /// y = W·x with decode-on-the-fly (x in fp32). The memory traffic is
    /// the quantized payload, not fp32 weights — the paper's memory-bound
    /// GEMV case.
    pub fn qgemv(&self, nq: &NestedLatticeQuantizer, x: &[f32]) -> Vec<f32> {
        debug_assert_eq!(self.levels, 1, "flat-code GEMV on an M-level matrix");
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0f32; self.rows];
        let bpr = self.cols / D;
        let mut c = [0u8; D];
        for r in 0..self.rows {
            if self.scales[r] == 0.0 {
                continue;
            }
            let denorm = self.scales[r] / (self.cols as f32).sqrt();
            let mut acc = 0f64;
            for j in 0..bpr {
                let off = r * self.cols + j * D;
                c.copy_from_slice(&self.codes[off..off + D]);
                let rec = nq.decode_block(&c, self.beta_idx[r * bpr + j]);
                let xb = &x[j * D..(j + 1) * D];
                let mut d = 0f32;
                for i in 0..D {
                    d += rec[i] * xb[i];
                }
                acc += d as f64;
            }
            y[r] = (acc * denorm as f64) as f32;
        }
        y
    }

    /// y = W·x̂ where x̂ is a quantized activation — Algorithm 4 per row
    /// (both operands stay in coded form; β products applied per block).
    pub fn qgemv_quantized(
        &self,
        nq: &NestedLatticeQuantizer,
        x: &QuantizedVector,
    ) -> Vec<f32> {
        debug_assert_eq!(self.levels, 1, "flat-code GEMV on an M-level matrix");
        assert_eq!(x.n, self.cols);
        let mut y = vec![0f32; self.rows];
        let bpr = self.cols / D;
        let mut cw = [0u8; D];
        let mut cx = [0u8; D];
        for r in 0..self.rows {
            if self.scales[r] == 0.0 || x.scale == 0.0 {
                continue;
            }
            let mut acc = 0f64;
            for j in 0..bpr {
                let off = r * self.cols + j * D;
                cw.copy_from_slice(&self.codes[off..off + D]);
                cx.copy_from_slice(&x.codes[j * D..(j + 1) * D]);
                let pw = nq.codec.decode(&cw);
                let px = nq.codec.decode(&cx);
                let mut d = 0f32;
                for i in 0..D {
                    d += pw[i] * px[i];
                }
                acc += (d
                    * nq.betas[self.beta_idx[r * bpr + j] as usize]
                    * nq.betas[x.beta_idx[j] as usize]) as f64;
            }
            y[r] = (acc * self.scales[r] as f64 * x.scale as f64 / self.cols as f64) as f32;
        }
        y
    }

    /// Relative Frobenius reconstruction error vs the original matrix.
    pub fn rel_error(&self, nq: &NestedLatticeQuantizer, original: &Mat) -> f64 {
        let deq = self.dequantize(nq);
        let mut num = 0f64;
        let mut den = 0f64;
        for (a, b) in original.data.iter().zip(&deq.data) {
            num += ((a - b) as f64).powi(2);
            den += (*a as f64).powi(2);
        }
        (num / den.max(1e-30)).sqrt()
    }

    /// Stored payload in bytes with 2-bit β packing and ⌈log2 q⌉-bit
    /// codes, at the rate the codes were quantized with (recorded in
    /// `self.q` — callers can no longer pass a mismatched rate and get
    /// silently wrong byte accounting). Hierarchical matrices are counted
    /// exactly as well: `codes` holds `rows·cols·levels` digit entries,
    /// so this is M·⌈log2 q⌉ bits per logical weight plus the unchanged
    /// β/scale side info.
    pub fn payload_bytes(&self) -> usize {
        debug_assert_eq!(self.codes.len(), self.rows * self.cols * self.levels as usize);
        let code_bits = (self.q as f64).log2().ceil() as usize;
        (self.codes.len() * code_bits).div_ceil(8)
            + (self.beta_idx.len() * 2).div_ceil(8)
            + self.scales.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::nested::NestedLatticeQuantizer;
    use crate::util::{propcheck, stats, Rng};

    fn nq() -> NestedLatticeQuantizer {
        NestedLatticeQuantizer::new(14, vec![0.25, 0.32, 0.45, 1.0])
    }

    fn random_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_vec(rows, cols, rng.gauss_vec(rows * cols))
    }

    #[test]
    fn roundtrip_is_fakequant() {
        // dequantize(quantize(W)) row r == nq.roundtrip(row r): matrix
        // quantization is exactly per-row Algorithm 3 (DESIGN.md §5.2).
        let nq = nq();
        let w = random_mat(6, 64, 901);
        let qm = QuantizedMatrix::quantize(&w, &nq);
        let deq = qm.dequantize(&nq);
        for r in 0..w.rows {
            let row_rt = nq.roundtrip(w.row(r));
            propcheck::assert_close(deq.row(r), &row_rt, 1e-6, 1e-5).unwrap();
        }
    }

    #[test]
    fn quantization_error_small_for_gaussian() {
        let nq = nq();
        let w = random_mat(16, 128, 902);
        let qm = QuantizedMatrix::quantize(&w, &nq);
        let rel = qm.rel_error(&nq, &w);
        assert!(rel < 0.1, "relative error {rel}");
    }

    #[test]
    fn qgemv_matches_dequantized_gemv() {
        propcheck::check("qgemv-consistency", 20, 903, |rng| {
            let nq = nq();
            let w = Mat::from_vec(8, 64, rng.gauss_vec(8 * 64));
            let x = rng.gauss_vec(64);
            let qm = QuantizedMatrix::quantize(&w, &nq);
            let fast = qm.qgemv(&nq, &x);
            let slow = qm.dequantize(&nq).matvec(&x);
            propcheck::assert_close(&fast, &slow, 1e-4, 1e-4)
        });
    }

    #[test]
    fn qgemv_quantized_matches_alg4() {
        propcheck::check("qgemv-quantized", 15, 904, |rng| {
            let nq = nq();
            let w = Mat::from_vec(8, 64, rng.gauss_vec(8 * 64));
            let x = rng.gauss_vec(64);
            let qm = QuantizedMatrix::quantize(&w, &nq);
            let qx = nq.quantize(&x);
            let y = qm.qgemv_quantized(&nq, &qx);
            for r in 0..8 {
                let expect = nq.dot(&qm.row_qv(r), &qx);
                if (y[r] - expect).abs() > 1e-4 * (1.0 + expect.abs()) {
                    return Err(format!("row {r}: {} vs {}", y[r], expect));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn qgemv_approximates_true_gemv() {
        let nq = nq();
        let w = random_mat(32, 256, 905);
        let mut rng = Rng::new(906);
        let x = rng.gauss_vec(256);
        let qm = QuantizedMatrix::quantize(&w, &nq);
        let approx = qm.qgemv(&nq, &x);
        let exact = w.matvec(&x);
        let rel = stats::rmse(&approx, &exact) / (stats::norm2(&exact) / (32f64).sqrt());
        assert!(rel < 0.12, "relative gemv error {rel}");
    }

    #[test]
    fn payload_is_about_4_bits_per_entry() {
        let nq = nq();
        let w = random_mat(16, 128, 907);
        let qm = QuantizedMatrix::quantize(&w, &nq);
        // the rate is recorded at quantize time — byte accounting can't
        // be fed a different q than the codes were produced with
        assert_eq!(qm.q, nq.q());
        let bits_per_entry = qm.payload_bytes() as f64 * 8.0 / (16.0 * 128.0);
        // log2(14) ≈ 3.81 stored as 4 bits + 0.25 β + scales
        assert!(bits_per_entry < 4.6, "bits/entry {bits_per_entry}");
    }

    #[test]
    fn payload_bytes_tracks_the_recorded_rate() {
        // q=7 codes pack at 3 bits/entry, q=14 at 4: same matrix, ~25%
        // smaller payload — the accounting follows the stored rate
        let w = random_mat(8, 64, 909);
        let q14 = QuantizedMatrix::quantize(&w, &NestedLatticeQuantizer::new(14, vec![0.3, 1.0]));
        let q7 = QuantizedMatrix::quantize(&w, &NestedLatticeQuantizer::new(7, vec![0.3, 1.0]));
        assert_eq!(q7.q, 7);
        assert!(q7.payload_bytes() < q14.payload_bytes());
    }

    #[test]
    fn zero_rows_handled() {
        let nq = nq();
        let mut w = random_mat(4, 32, 908);
        w.row_mut(2).fill(0.0);
        let qm = QuantizedMatrix::quantize(&w, &nq);
        let deq = qm.dequantize(&nq);
        assert!(deq.row(2).iter().all(|&v| v == 0.0));
        let y = qm.qgemv(&nq, &vec![1.0; 32]);
        assert_eq!(y[2], 0.0);
    }
}
