//! Decode-amortized quantized GEMM kernel core (the Table 4 claim made
//! real at batch > 1): packed weight formats decode each 8-block **once**
//! into an integer row buffer and multiply it against a *panel* of
//! activation columns, so the decode cost — the dominant term of the
//! per-column GEMV (see EXPERIMENTS.md §Perf) — is amortized over the
//! batch, exactly the trick the QuIP#/LUT decoding line of work uses on
//! GPUs.
//!
//! Layout and loop structure:
//!
//! * Activations arrive as `xt` (batch, cols) row-major — one activation
//!   vector per row, matching the engine's (seq, d) matrices. They are
//!   repacked once into `[panel][block][lane][col]` order so the 8×NC
//!   microkernel reads contiguous NC-wide lanes (autovectorizable
//!   fused-multiply loops with no gather).
//! * Each weight row is decoded to an `i16` entry buffer plus per-block
//!   scale multipliers by a format-specific `decode_row` callback, then
//!   swept across every panel by the runtime-dispatched 8×NC microkernel
//!   (`quant::kernels::row_times_panels` — scalar/AVX2/NEON tiers, all
//!   bitwise identical).
//! * Weight rows are partitioned across `std::thread::scope` workers
//!   (no thread pool, no dependencies); workers write disjoint chunks of
//!   a (rows, batch) staging buffer which is transposed into the caller's
//!   (batch, rows) output at the end.
//!
//! Bit-exactness: for one output element the kernel performs the *same
//! sequence* of f32 operations as the scalar GEMV (per block: an 8-term
//! sequential dot, then one multiply-accumulate by the block scale; per
//! row: one final multiply by the row scale), so `gemm_into` results are
//! bit-for-bit identical to calling `gemv_into` per batch row — the
//! property `quant::qgemm` tests enforce.

use crate::lattice::e8::D;
use crate::util::linalg::Mat;

/// Panel width NC of the 8×NC microkernel: 16 f32 columns = four 128-bit
/// (or two 256-bit) vector lanes, small enough that the d/acc tiles stay
/// in registers.
pub const PANEL: usize = 16;

/// Reusable buffers for [`gemm_driver`]: the packed activation panels,
/// the (rows, batch) staging output, and the single-threaded decode-row
/// buffers. Hold one per call site to make the steady state
/// allocation-free — the fused decode loop relies on this (worker
/// threads still use their own per-scope decode buffers).
#[derive(Default)]
pub struct GemmScratch {
    pub(crate) xp: Vec<f32>,
    pub(crate) ytmp: Vec<f32>,
    pub(crate) ebuf: Vec<i16>,
    pub(crate) bscale: Vec<f32>,
}

impl GemmScratch {
    pub fn new() -> Self {
        GemmScratch::default()
    }
}

/// Gather scattered per-session rows into one contiguous (n, cols)
/// activation panel — the fused-decode entry point that turns N live
/// sessions' current activations into a single GEMM batch. `dst` is
/// resized without reallocating once warm; `rows` yields one `cols`-long
/// slice per session.
pub fn gather_panel<'a, I>(rows: I, cols: usize, dst: &mut Mat)
where
    I: ExactSizeIterator<Item = &'a [f32]>,
{
    dst.rows = rows.len();
    dst.cols = cols;
    dst.data.clear();
    for row in rows {
        assert_eq!(row.len(), cols, "panel row width mismatch");
        dst.data.extend_from_slice(row);
    }
}

/// Scatter a (n, cols) result panel back to per-session buffers — the
/// inverse of [`gather_panel`], used to hand each live session its own
/// logits row after the fused step. Destination slices must already have
/// the panel width.
pub fn scatter_panel<'a, I>(src: &Mat, dsts: I)
where
    I: Iterator<Item = &'a mut [f32]>,
{
    let mut n = 0usize;
    for (r, dst) in dsts.enumerate() {
        assert_eq!(dst.len(), src.cols, "scatter row width mismatch");
        dst.copy_from_slice(src.row(r));
        n = r + 1;
    }
    assert_eq!(n, src.rows, "scatter row count mismatch");
}

/// Repack `xt` (batch, cols) into `[panel][block j][lane i][col c]` order
/// with zero padding up to a multiple of [`PANEL`] columns. Returns the
/// panel count. Padded lanes produce garbage accumulators that are never
/// written to the output.
pub(crate) fn pack_panels(xt: &Mat, xp: &mut Vec<f32>) -> usize {
    let batch = xt.rows;
    let cols = xt.cols;
    debug_assert_eq!(cols % D, 0);
    let bpr = cols / D;
    let n_panels = batch.div_ceil(PANEL);
    xp.clear();
    xp.resize(n_panels * bpr * D * PANEL, 0.0);
    for p in 0..n_panels {
        let c_lim = (batch - p * PANEL).min(PANEL);
        for c in 0..c_lim {
            let row = xt.row(p * PANEL + c);
            for j in 0..bpr {
                let base = (p * bpr + j) * D * PANEL;
                for i in 0..D {
                    xp[base + i * PANEL + c] = row[j * D + i];
                }
            }
        }
    }
    n_panels
}

/// Resolve a caller thread count: `0` means all available cores.
pub(crate) fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map_or(1, |n| n.get())
    } else {
        threads
    }
}

/// Split `rows` into at most `threads` contiguous, balanced ranges.
pub(crate) fn row_ranges(rows: usize, threads: usize) -> Vec<std::ops::Range<usize>> {
    let t = threads.max(1).min(rows.max(1));
    let base = rows / t;
    let extra = rows % t;
    let mut out = Vec::with_capacity(t);
    let mut start = 0;
    for w in 0..t {
        let len = base + usize::from(w < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Shared row-partitioned thread driver: run `run(range, chunk)` for
/// balanced contiguous weight-row ranges, each writing its disjoint
/// `range.len()·batch` chunk of the (rows, batch) staging buffer. One
/// range runs inline (no spawn); more fan out across `std::thread::scope`
/// workers. This is the single threading shape behind all three packed
/// GEMM backends (`qgemm`, `uniform`, `lut`), so the SIMD kernels are
/// wired into one driver, not three copies of it.
pub(crate) fn drive_rows<F>(rows: usize, batch: usize, threads: usize, ytmp: &mut [f32], run: F)
where
    F: Fn(std::ops::Range<usize>, &mut [f32]) + Sync,
{
    debug_assert_eq!(ytmp.len(), rows * batch);
    let ranges = row_ranges(rows, threads);
    if ranges.len() == 1 {
        run(ranges[0].clone(), ytmp);
        return;
    }
    let run = &run;
    std::thread::scope(|s| {
        let mut rest: &mut [f32] = ytmp;
        for range in ranges {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(range.len() * batch);
            rest = tail;
            s.spawn(move || run(range, chunk));
        }
    });
}

/// Transpose the (rows, batch) staging buffer into the caller's
/// (batch, rows) output.
pub(crate) fn transpose_into(src: &[f32], rows: usize, batch: usize, dst: &mut Mat) {
    debug_assert_eq!(src.len(), rows * batch);
    for c in 0..batch {
        let drow = dst.row_mut(c);
        for (r, out) in drow.iter_mut().enumerate() {
            *out = src[r * batch + c];
        }
    }
}

/// Shared GEMM driver for the packed weight formats. `decode_row(r, ebuf,
/// bscale)` fills the decoded integer entries and per-block multipliers
/// for weight row `r` and returns the row scale; `kernel` picks the
/// [`row_times_panels`] dispatch tier (callers pass `kernels::active()`
/// unless a test/bench forces one). `threads == 0` uses all available
/// cores; weight rows are partitioned across scoped workers.
///
/// [`row_times_panels`]: super::kernels::row_times_panels
pub(crate) fn gemm_driver<F>(
    rows: usize,
    cols: usize,
    xt: &Mat,
    yt: &mut Mat,
    threads: usize,
    kernel: super::kernels::Kernel,
    scratch: &mut GemmScratch,
    decode_row: F,
) where
    F: Fn(usize, &mut [i16], &mut [f32]) -> f32 + Sync,
{
    assert_eq!(cols % D, 0, "cols must be divisible by 8");
    assert_eq!(xt.cols, cols, "activation panel width mismatch");
    assert_eq!(yt.rows, xt.rows, "output batch mismatch");
    assert_eq!(yt.cols, rows, "output width mismatch");
    let batch = xt.rows;
    if batch == 0 || rows == 0 {
        return;
    }
    let threads = resolve_threads(threads);
    pack_panels(xt, &mut scratch.xp);
    scratch.ytmp.clear();
    scratch.ytmp.resize(rows * batch, 0.0);
    let GemmScratch { xp, ytmp, ebuf, bscale } = scratch;
    let xp: &[f32] = xp.as_slice();
    let bpr = cols / D;

    if threads == 1 {
        // Allocation-free fast path (after warmup): the decode-row
        // buffers live in the scratch and no range vector is built —
        // this is the fused decode scheduler's hot loop.
        ebuf.clear();
        ebuf.resize(cols, 0);
        bscale.clear();
        bscale.resize(bpr, 0.0);
        for r in 0..rows {
            let row_scale = decode_row(r, ebuf, bscale);
            super::kernels::row_times_panels(
                kernel,
                ebuf,
                bscale,
                xp,
                batch,
                row_scale,
                &mut ytmp[r * batch..(r + 1) * batch],
            );
        }
        transpose_into(ytmp, rows, batch, yt);
        return;
    }

    drive_rows(rows, batch, threads, ytmp, |range, out| {
        let mut ebuf = vec![0i16; cols];
        let mut bscale = vec![0f32; bpr];
        for (k, r) in range.enumerate() {
            let row_scale = decode_row(r, &mut ebuf, &mut bscale);
            super::kernels::row_times_panels(
                kernel,
                &ebuf,
                &bscale,
                xp,
                batch,
                row_scale,
                &mut out[k * batch..(k + 1) * batch],
            );
        }
    });
    transpose_into(ytmp, rows, batch, yt);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn row_ranges_cover_exactly() {
        for rows in [0usize, 1, 7, 16, 17, 2048] {
            for threads in [1usize, 2, 3, 8, 64] {
                let ranges = row_ranges(rows, threads);
                assert!(ranges.len() <= threads.max(1));
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next, "rows={rows} threads={threads}");
                    next = r.end;
                }
                assert_eq!(next, rows, "rows={rows} threads={threads}");
                // balanced within one row
                if !ranges.is_empty() {
                    let min = ranges.iter().map(|r| r.len()).min().unwrap();
                    let max = ranges.iter().map(|r| r.len()).max().unwrap();
                    assert!(max - min <= 1);
                }
            }
        }
    }

    #[test]
    fn drive_rows_writes_disjoint_chunks() {
        // every (row, col) staging slot is written exactly once whatever
        // the worker count — the invariant all three backends lean on.
        for &(rows, batch, threads) in &[(7usize, 3usize, 1usize), (8, 2, 3), (5, 4, 8)] {
            let mut ytmp = vec![f32::NAN; rows * batch];
            drive_rows(rows, batch, threads, &mut ytmp, |range, out| {
                assert_eq!(out.len(), range.len() * batch);
                for (k, r) in range.enumerate() {
                    for c in 0..batch {
                        out[k * batch + c] = (r * batch + c) as f32;
                    }
                }
            });
            for (i, &v) in ytmp.iter().enumerate() {
                assert_eq!(v, i as f32, "rows={rows} batch={batch} threads={threads}");
            }
        }
    }

    #[test]
    fn pack_panels_layout_and_padding() {
        let mut rng = Rng::new(2201);
        let batch = PANEL + 3; // forces one padded panel
        let cols = 2 * D;
        let xt = Mat::from_vec(batch, cols, rng.gauss_vec(batch * cols));
        let mut xp = Vec::new();
        let n_panels = pack_panels(&xt, &mut xp);
        assert_eq!(n_panels, 2);
        assert_eq!(xp.len(), n_panels * (cols / D) * D * PANEL);
        for c in 0..batch {
            let (p, lane_c) = (c / PANEL, c % PANEL);
            for j in 0..cols / D {
                for i in 0..D {
                    let got = xp[(p * (cols / D) + j) * D * PANEL + i * PANEL + lane_c];
                    assert_eq!(got, xt[(c, j * D + i)], "c={c} j={j} i={i}");
                }
            }
        }
        // padded lanes are zero
        for lane_c in batch % PANEL..PANEL {
            for j in 0..cols / D {
                for i in 0..D {
                    assert_eq!(xp[((cols / D) + j) * D * PANEL + i * PANEL + lane_c], 0.0);
                }
            }
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(2202);
        let (rows, batch) = (5, 3);
        let src = rng.gauss_vec(rows * batch);
        let mut dst = Mat::zeros(batch, rows);
        transpose_into(&src, rows, batch, &mut dst);
        for r in 0..rows {
            for c in 0..batch {
                assert_eq!(dst[(c, r)], src[r * batch + c]);
            }
        }
    }

    #[test]
    fn gather_scatter_panel_roundtrip() {
        let mut rng = Rng::new(2204);
        let cols = 6;
        let srcs: Vec<Vec<f32>> = (0..3).map(|_| rng.gauss_vec(cols)).collect();
        let mut panel = Mat::zeros(0, 0);
        gather_panel(srcs.iter().map(|v| v.as_slice()), cols, &mut panel);
        assert_eq!((panel.rows, panel.cols), (3, cols));
        for (r, src) in srcs.iter().enumerate() {
            assert_eq!(panel.row(r), src.as_slice());
        }
        let mut outs: Vec<Vec<f32>> = (0..3).map(|_| vec![0f32; cols]).collect();
        scatter_panel(&panel, outs.iter_mut().map(|v| v.as_mut_slice()));
        assert_eq!(outs, srcs);
        // re-gathering a smaller batch shrinks the panel without stale rows
        gather_panel(srcs[..1].iter().map(|v| v.as_slice()), cols, &mut panel);
        assert_eq!((panel.rows, panel.cols), (1, cols));
        assert_eq!(panel.data.len(), cols);
    }

    #[test]
    fn driver_matches_dense_reference() {
        // a trivial "format": identity decode of an i16 weight matrix with
        // unit block scales — the driver must reproduce the dense product.
        let mut rng = Rng::new(2203);
        let (rows, cols, batch) = (9, 2 * D, 21);
        let wq: Vec<i16> = (0..rows * cols).map(|_| rng.below(31) as i16 - 15).collect();
        let xt = Mat::from_vec(batch, cols, rng.gauss_vec(batch * cols));
        for threads in [1usize, 4] {
            let mut yt = Mat::zeros(batch, rows);
            let mut scratch = GemmScratch::new();
            let kernel = crate::quant::kernels::active();
            gemm_driver(rows, cols, &xt, &mut yt, threads, kernel, &mut scratch, |r, ebuf, bscale| {
                ebuf.copy_from_slice(&wq[r * cols..(r + 1) * cols]);
                bscale.fill(1.0);
                0.5
            });
            for c in 0..batch {
                for r in 0..rows {
                    let mut expect = 0f64;
                    for i in 0..cols {
                        expect += wq[r * cols + i] as f64 * xt[(c, i)] as f64;
                    }
                    let got = yt[(c, r)] as f64;
                    assert!(
                        (got - 0.5 * expect).abs() < 1e-3 * (1.0 + expect.abs()),
                        "threads={threads} c={c} r={r}: {got} vs {}",
                        0.5 * expect
                    );
                }
            }
        }
    }
}
