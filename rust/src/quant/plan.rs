//! Per-site quantization policy — the `QuantPlan` API.
//!
//! The paper's Tables 1–3 treat method/rate/regime as one global knob,
//! and the engine used to mirror that: one `EngineOptions` applied
//! identically to every linear, every layer and the KV cache. Production
//! mixed-precision deployments need *per-site* decisions (QuIP#- and
//! QuantEase-style layer-by-layer policies): sensitive `down`/`o`
//! projections at a higher rate, an fp `lm_head`, per-layer KV rates.
//!
//! This module names every quantized tensor in the stack with a
//! [`SiteId`] (layer × [`SiteKind`] × [`SiteRole`]), carries the
//! per-tensor knobs in a [`SitePolicy`], and resolves `SiteId →
//! SitePolicy` through a [`QuantPlan`]: a global default plus an ordered
//! list of `(selector, patch)` override rules (global default →
//! layer-range overrides → per-site overrides; later rules win).
//! Plans are built fluently with [`EngineBuilder`] or loaded from a
//! hand-rolled `*.qplan` text format (`key = value` sections, no new
//! dependencies) via [`QuantPlan::parse`] / [`QuantPlan::render`].
//!
//! [`QuantPlan::uniform`] lowers a legacy `EngineOptions` to an
//! equivalent plan (the regime becomes three per-role quantize gates),
//! so `Engine::build(w, opts)` remains a thin compat shim that
//! constructs bit-identical engines.
//!
//! Layering note: this module and `model::engine` reference each other
//! (`QuantPlan::uniform` consumes `EngineOptions`; the engine resolves
//! plans). The intra-crate cycle is deliberate — the compat contract
//! puts the lowering on `QuantPlan`, and `Method`/`RotKind` stay in
//! `model::engine` where every caller already imports them. If `quant`
//! ever needs to stand alone, the lowering and [`EngineBuilder::build`]
//! are the two seams to hoist into `model`.

use crate::lattice::hierarchical::lut_supported;
use crate::model::engine::{Engine, EngineOptions, Method, RotKind};
use crate::model::weights::ModelWeights;
use std::path::{Path, PathBuf};

/// What a site stores: weight entries, the activations flowing into a
/// linear, or KV-cache entries.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SiteRole {
    Weights,
    Acts,
    Kv,
}

impl SiteRole {
    pub const ALL: [SiteRole; 3] = [SiteRole::Weights, SiteRole::Acts, SiteRole::Kv];

    pub fn name(self) -> &'static str {
        match self {
            SiteRole::Weights => "weights",
            SiteRole::Acts => "acts",
            SiteRole::Kv => "kv",
        }
    }

    pub fn parse(s: &str) -> Option<SiteRole> {
        Self::ALL.into_iter().find(|r| r.name() == s)
    }
}

/// The kind of quantized tensor site within a transformer block.
///
/// `Gate` is reserved for gated-MLP architectures (this repo's char-LMs
/// use a plain up/GELU/down MLP) and `Activations` names the residual
/// activation stream as a site of its own; both are part of the total
/// `SiteId` space so plans written for larger models resolve cleanly.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SiteKind {
    Q,
    K,
    V,
    O,
    Gate,
    Up,
    Down,
    LmHead,
    KvCache,
    Activations,
}

impl SiteKind {
    pub const ALL: [SiteKind; 10] = [
        SiteKind::Q,
        SiteKind::K,
        SiteKind::V,
        SiteKind::O,
        SiteKind::Gate,
        SiteKind::Up,
        SiteKind::Down,
        SiteKind::LmHead,
        SiteKind::KvCache,
        SiteKind::Activations,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SiteKind::Q => "q",
            SiteKind::K => "k",
            SiteKind::V => "v",
            SiteKind::O => "o",
            SiteKind::Gate => "gate",
            SiteKind::Up => "up",
            SiteKind::Down => "down",
            SiteKind::LmHead => "lm_head",
            SiteKind::KvCache => "kv_cache",
            SiteKind::Activations => "activations",
        }
    }

    pub fn parse(s: &str) -> Option<SiteKind> {
        Self::ALL.into_iter().find(|k| k.name() == s)
    }
}

/// How a quantized weight site serves its GEMM.
///
/// `Decode` is the classic path: 4-bit nested codes decoded on the fly
/// (packed integer GEMM when eligible, dequantize-then-matmul
/// otherwise). `Lut` stores M-level hierarchical codes
/// (`lattice::hierarchical`) and computes inner products by pair-LUT
/// lookups without ever materializing decoded rows (`quant::lut`); it
/// requires a nested method and an i32-safe `(q, m_levels)` combination
/// (see `lattice::hierarchical::lut_supported`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GemmBackend {
    Decode,
    Lut,
}

impl GemmBackend {
    pub const ALL: [GemmBackend; 2] = [GemmBackend::Decode, GemmBackend::Lut];

    pub fn cli_name(self) -> &'static str {
        match self {
            GemmBackend::Decode => "decode",
            GemmBackend::Lut => "lut",
        }
    }

    pub fn parse(s: &str) -> Option<GemmBackend> {
        Self::ALL.into_iter().find(|b| b.cli_name() == s)
    }
}

/// Names one quantized tensor in the stack. The `lm_head` site sits
/// outside the block stack, so its `layer` is `None` — select it by
/// kind, not by layer range.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SiteId {
    pub layer: Option<usize>,
    pub kind: SiteKind,
    pub role: SiteRole,
}

impl SiteId {
    pub fn weights(layer: usize, kind: SiteKind) -> Self {
        SiteId {
            layer: Some(layer),
            kind,
            role: SiteRole::Weights,
        }
    }

    pub fn acts(layer: usize, kind: SiteKind) -> Self {
        SiteId {
            layer: Some(layer),
            kind,
            role: SiteRole::Acts,
        }
    }

    pub fn kv(layer: usize) -> Self {
        SiteId {
            layer: Some(layer),
            kind: SiteKind::KvCache,
            role: SiteRole::Kv,
        }
    }

    pub fn lm_head(role: SiteRole) -> Self {
        SiteId {
            layer: None,
            kind: SiteKind::LmHead,
            role,
        }
    }

    /// Human/metrics label, e.g. `L3.down.weights` or `lm_head.weights`.
    pub fn label(&self) -> String {
        match self.layer {
            Some(l) => format!("L{l}.{}.{}", self.kind.name(), self.role.name()),
            None => format!("{}.{}", self.kind.name(), self.role.name()),
        }
    }
}

/// Every `SiteId` of an `n_layer`-block stack — the domain the
/// resolution propcheck quantifies over.
pub fn enumerate_sites(n_layer: usize) -> Vec<SiteId> {
    let mut out = Vec::new();
    for layer in 0..n_layer {
        for kind in SiteKind::ALL {
            if kind == SiteKind::LmHead {
                continue;
            }
            for role in SiteRole::ALL {
                out.push(SiteId {
                    layer: Some(layer),
                    kind,
                    role,
                });
            }
        }
    }
    for role in SiteRole::ALL {
        out.push(SiteId::lm_head(role));
    }
    out
}

/// The per-tensor quantization knobs — what `EngineOptions` used to
/// carry crate-wide, resolved per site. `quantize = false` keeps the
/// site in fp32 (the per-site analog of the legacy `Regime` gates).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SitePolicy {
    pub quantize: bool,
    pub method: Method,
    /// nesting ratio (rate = log2 q bits/entry) for nested methods
    pub q: u32,
    /// number of scaling coefficients β
    pub k: usize,
    /// bits for the uniform baselines
    pub uniform_bits: u32,
    /// LDLQ feedback on weights
    pub ldlq: bool,
    /// QA-LDLQ correction when this site's activations are quantized
    pub qa_ldlq: bool,
    /// isotropic activation-noise variance ε² for QA-LDLQ
    pub eps2: f32,
    /// measure ε² from the site's calibrated activation quantizer
    pub auto_eps2: bool,
    /// serve M-variant nested linears through the packed integer GEMM
    pub int_gemm: bool,
    /// how a quantized weight site serves its GEMM: decode-on-the-fly
    /// or the hierarchical LUT inner-product backend
    pub backend: GemmBackend,
    /// hierarchical levels M for `backend = lut` (rate = M·log2 q
    /// bits/entry); ignored on the decode backend
    pub m_levels: u32,
}

impl SitePolicy {
    /// The per-tensor knobs of an `EngineOptions`, minus the regime
    /// (which lowers to per-role `quantize` rules — see
    /// [`QuantPlan::uniform`]).
    pub fn from_options(opts: &EngineOptions) -> Self {
        SitePolicy {
            quantize: true,
            method: opts.method,
            q: opts.q,
            k: opts.k,
            uniform_bits: opts.uniform_bits,
            ldlq: opts.ldlq,
            qa_ldlq: opts.qa_ldlq,
            eps2: opts.eps2,
            auto_eps2: opts.auto_eps2,
            int_gemm: opts.int_gemm,
            // EngineOptions predates the LUT backend and carries no
            // backend knobs — the legacy lowering always decodes.
            backend: GemmBackend::Decode,
            m_levels: 2,
        }
    }
}

impl Default for SitePolicy {
    /// Derived from `EngineOptions::default()` — one source of truth, so
    /// a `.qplan` file omitting a `[default]` key resolves exactly like
    /// the equivalent CLI invocation.
    fn default() -> Self {
        SitePolicy::from_options(&EngineOptions::default())
    }
}

/// A partial [`SitePolicy`]: only the set fields override the policy a
/// rule is applied on top of.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct PolicyPatch {
    pub quantize: Option<bool>,
    pub method: Option<Method>,
    pub q: Option<u32>,
    pub k: Option<usize>,
    pub uniform_bits: Option<u32>,
    pub ldlq: Option<bool>,
    pub qa_ldlq: Option<bool>,
    pub eps2: Option<f32>,
    pub auto_eps2: Option<bool>,
    pub int_gemm: Option<bool>,
    pub backend: Option<GemmBackend>,
    pub m_levels: Option<u32>,
}

/// Shared range checks — the `.qplan` parser, `QuantPlan::validate` and
/// the builder conveniences all enforce the same bounds (the codec
/// accepts q ∈ [2, 255], the uniform quantizer bits ∈ [2, 8]).
fn check_q(q: u32) -> Result<(), String> {
    if (2..=255).contains(&q) {
        Ok(())
    } else {
        Err(format!("q must be in [2, 255], got {q}"))
    }
}

fn check_k(k: usize) -> Result<(), String> {
    if k >= 1 {
        Ok(())
    } else {
        Err("k must be at least 1".into())
    }
}

fn check_uniform_bits(bits: u32) -> Result<(), String> {
    if (2..=8).contains(&bits) {
        Ok(())
    } else {
        Err(format!("uniform_bits must be in [2, 8], got {bits}"))
    }
}

fn check_m_levels(m: u32) -> Result<(), String> {
    if (2..=8).contains(&m) {
        Ok(())
    } else {
        Err(format!("m_levels must be in [2, 8], got {m}"))
    }
}

impl PolicyPatch {
    /// Convenience: a patch that only pins the nesting ratio.
    pub fn rate(q: u32) -> Self {
        check_q(q).unwrap();
        PolicyPatch {
            q: Some(q),
            ..Default::default()
        }
    }

    /// Convenience: a patch that keeps the site in fp32.
    pub fn fp() -> Self {
        PolicyPatch {
            quantize: Some(false),
            ..Default::default()
        }
    }

    pub fn apply(&self, p: &mut SitePolicy) {
        if let Some(v) = self.quantize {
            p.quantize = v;
        }
        if let Some(v) = self.method {
            p.method = v;
        }
        if let Some(v) = self.q {
            p.q = v;
        }
        if let Some(v) = self.k {
            p.k = v;
        }
        if let Some(v) = self.uniform_bits {
            p.uniform_bits = v;
        }
        if let Some(v) = self.ldlq {
            p.ldlq = v;
        }
        if let Some(v) = self.qa_ldlq {
            p.qa_ldlq = v;
        }
        if let Some(v) = self.eps2 {
            p.eps2 = v;
        }
        if let Some(v) = self.auto_eps2 {
            p.auto_eps2 = v;
        }
        if let Some(v) = self.int_gemm {
            p.int_gemm = v;
        }
        if let Some(v) = self.backend {
            p.backend = v;
        }
        if let Some(v) = self.m_levels {
            p.m_levels = v;
        }
    }

    /// Set one `key = value` pair from the `.qplan` text format.
    /// Returns `Ok(false)` when the key is not a policy key (so the rule
    /// parser can try selector keys next). Numeric knobs are range-
    /// checked here so a bad plan file fails at parse with a line
    /// number instead of an assert deep inside engine construction.
    fn set(&mut self, key: &str, val: &str) -> Result<bool, String> {
        match key {
            "quantize" => self.quantize = Some(parse_bool(key, val)?),
            "method" => {
                self.method = Some(
                    Method::parse(val).ok_or_else(|| format!("unknown method '{val}'"))?,
                )
            }
            "q" => {
                let q: u32 = parse_num(key, val)?;
                check_q(q)?;
                self.q = Some(q);
            }
            "k" => {
                let k: usize = parse_num(key, val)?;
                check_k(k)?;
                self.k = Some(k);
            }
            "uniform_bits" => {
                let bits: u32 = parse_num(key, val)?;
                check_uniform_bits(bits)?;
                self.uniform_bits = Some(bits);
            }
            "ldlq" => self.ldlq = Some(parse_bool(key, val)?),
            "qa_ldlq" => self.qa_ldlq = Some(parse_bool(key, val)?),
            "eps2" => self.eps2 = Some(parse_num(key, val)?),
            "auto_eps2" => self.auto_eps2 = Some(parse_bool(key, val)?),
            "int_gemm" => self.int_gemm = Some(parse_bool(key, val)?),
            "backend" => {
                self.backend = Some(GemmBackend::parse(val).ok_or_else(|| {
                    format!("unknown backend '{val}' (known: decode, lut)")
                })?)
            }
            "m_levels" => {
                let m: u32 = parse_num(key, val)?;
                check_m_levels(m)?;
                self.m_levels = Some(m);
            }
            _ => return Ok(false),
        }
        Ok(true)
    }

    /// Render only the set fields, in canonical key order.
    fn render_into(&self, s: &mut String) {
        if let Some(v) = self.quantize {
            s.push_str(&format!("quantize = {v}\n"));
        }
        if let Some(v) = self.method {
            s.push_str(&format!("method = {}\n", v.cli_name()));
        }
        if let Some(v) = self.q {
            s.push_str(&format!("q = {v}\n"));
        }
        if let Some(v) = self.k {
            s.push_str(&format!("k = {v}\n"));
        }
        if let Some(v) = self.uniform_bits {
            s.push_str(&format!("uniform_bits = {v}\n"));
        }
        if let Some(v) = self.ldlq {
            s.push_str(&format!("ldlq = {v}\n"));
        }
        if let Some(v) = self.qa_ldlq {
            s.push_str(&format!("qa_ldlq = {v}\n"));
        }
        if let Some(v) = self.eps2 {
            s.push_str(&format!("eps2 = {v:?}\n"));
        }
        if let Some(v) = self.auto_eps2 {
            s.push_str(&format!("auto_eps2 = {v}\n"));
        }
        if let Some(v) = self.int_gemm {
            s.push_str(&format!("int_gemm = {v}\n"));
        }
        if let Some(v) = self.backend {
            s.push_str(&format!("backend = {}\n", v.cli_name()));
        }
        if let Some(v) = self.m_levels {
            s.push_str(&format!("m_levels = {v}\n"));
        }
    }

    fn from_policy(p: &SitePolicy) -> Self {
        PolicyPatch {
            quantize: Some(p.quantize),
            method: Some(p.method),
            q: Some(p.q),
            k: Some(p.k),
            uniform_bits: Some(p.uniform_bits),
            ldlq: Some(p.ldlq),
            qa_ldlq: Some(p.qa_ldlq),
            eps2: Some(p.eps2),
            auto_eps2: Some(p.auto_eps2),
            int_gemm: Some(p.int_gemm),
            backend: Some(p.backend),
            m_levels: Some(p.m_levels),
        }
    }
}

fn parse_bool(key: &str, val: &str) -> Result<bool, String> {
    match val {
        "true" => Ok(true),
        "false" => Ok(false),
        _ => Err(format!("{key}: expected true/false, got '{val}'")),
    }
}

fn parse_num<T: std::str::FromStr>(key: &str, val: &str) -> Result<T, String> {
    val.parse()
        .map_err(|_| format!("{key}: invalid number '{val}'"))
}

/// Which sites a rule applies to; `None` fields match anything.
/// `layers` is an inclusive `(lo, hi)` range over block indices with
/// `lo <= hi` (the builder and the `.qplan` parser both enforce it; an
/// inverted range hand-built here matches nothing and renders to text
/// the parser rejects) — it never matches the layer-less `lm_head`
/// site (select that by kind).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct SiteSelector {
    pub layers: Option<(usize, usize)>,
    pub kind: Option<SiteKind>,
    pub role: Option<SiteRole>,
}

impl SiteSelector {
    pub fn matches(&self, site: SiteId) -> bool {
        if let Some((lo, hi)) = self.layers {
            match site.layer {
                Some(l) if l >= lo && l <= hi => {}
                _ => return false,
            }
        }
        if let Some(k) = self.kind {
            if site.kind != k {
                return false;
            }
        }
        if let Some(r) = self.role {
            if site.role != r {
                return false;
            }
        }
        true
    }
}

/// A per-site quantization plan: plan-global knobs (rotation flavor,
/// calibration budget, RNG seed) plus the layered policy rules.
/// Resolution is **total**: every `SiteId` resolves to the default
/// policy patched by each matching rule in order (later rules win).
#[derive(Clone, Debug, PartialEq)]
pub struct QuantPlan {
    pub rot_kind: RotKind,
    /// calibration windows used for Hessians / β DP
    pub calib_windows: usize,
    pub seed: u64,
    pub default: SitePolicy,
    pub rules: Vec<(SiteSelector, PolicyPatch)>,
}

impl Default for QuantPlan {
    fn default() -> Self {
        QuantPlan::uniform(EngineOptions::default())
    }
}

impl QuantPlan {
    /// Lower a legacy `EngineOptions` to the equivalent plan: the knobs
    /// become the default policy everywhere and the regime becomes three
    /// per-role quantize gates. `Engine::build_plan` on this plan is
    /// bit-identical to the pre-plan `Engine::build(w, opts)`.
    pub fn uniform(opts: EngineOptions) -> QuantPlan {
        let default = SitePolicy::from_options(&opts);
        let mut rules = Vec::new();
        for (role, on) in [
            (SiteRole::Weights, opts.regime.quantizes_weights()),
            (SiteRole::Acts, opts.regime.quantizes_acts()),
            (SiteRole::Kv, opts.regime.quantizes_kv()),
        ] {
            if !on {
                rules.push((
                    SiteSelector {
                        role: Some(role),
                        ..Default::default()
                    },
                    PolicyPatch::fp(),
                ));
            }
        }
        QuantPlan {
            rot_kind: opts.rot_kind,
            calib_windows: opts.calib_windows,
            seed: opts.seed,
            default,
            rules,
        }
    }

    /// Validate the plan's knobs against the same bounds the `.qplan`
    /// parser enforces — the choke point for plans built by hand or
    /// through the builder (fields are public, so construction can't be
    /// made unrepresentable). `Engine::build_plan` calls this, so an
    /// out-of-range plan fails fast with a named reason instead of an
    /// assert deep inside codec/quantizer construction.
    pub fn validate(&self) -> Result<(), String> {
        if self.calib_windows == 0 {
            return Err("calib_windows must be at least 1".into());
        }
        let check_patch = |ctx: &str, p: &PolicyPatch| -> Result<(), String> {
            let at = |e: String| format!("{ctx}: {e}");
            if let Some(q) = p.q {
                check_q(q).map_err(at)?;
            }
            if let Some(k) = p.k {
                check_k(k).map_err(at)?;
            }
            if let Some(b) = p.uniform_bits {
                check_uniform_bits(b).map_err(at)?;
            }
            if let Some(m) = p.m_levels {
                check_m_levels(m).map_err(at)?;
            }
            Ok(())
        };
        check_patch("[default]", &PolicyPatch::from_policy(&self.default))?;
        for (ri, (sel, patch)) in self.rules.iter().enumerate() {
            let ctx = format!("rule {ri}");
            if let Some((lo, hi)) = sel.layers {
                if lo > hi {
                    return Err(format!("{ctx}: inverted layer range {lo}..{hi}"));
                }
            }
            check_patch(&ctx, patch)?;
        }
        self.check_backend_support()
    }

    /// Reject plans that route a weight site to the LUT backend with a
    /// combination the backend cannot serve: a non-nested method, or a
    /// `(q, m_levels)` pair outside the i32-safe LUT window
    /// (`lattice::hierarchical::lut_supported` — q ∈ {2, 3} with M
    /// bounded so worst-case accumulation fits an i32). Per-field range
    /// checks can't see this because it is a property of the *resolved*
    /// policy, so we quantify over every site the rules can distinguish
    /// (layers beyond any rule's range all resolve identically — probing
    /// one past the deepest rule covers them).
    pub fn check_backend_support(&self) -> Result<(), String> {
        let deepest = self
            .rules
            .iter()
            .filter_map(|(sel, _)| sel.layers.map(|(_, hi)| hi))
            .max()
            .unwrap_or(0);
        for site in enumerate_sites(deepest + 2) {
            if site.role != SiteRole::Weights {
                continue;
            }
            let pol = self.resolve(site);
            if !pol.quantize || pol.backend != GemmBackend::Lut {
                continue;
            }
            if !pol.method.is_nested() {
                return Err(format!(
                    "{}: backend = lut requires a nested method, got '{}'",
                    site.label(),
                    pol.method.cli_name()
                ));
            }
            if pol.k > 4 {
                return Err(format!(
                    "{}: backend = lut packs β indices 2-bit, so k must be <= 4, got {}",
                    site.label(),
                    pol.k
                ));
            }
            if !lut_supported(pol.q, pol.m_levels) {
                return Err(format!(
                    "{}: backend = lut is unsupported at q = {}, m_levels = {} \
                     (LUT window: q = 2 with M in [2, 8], q = 3 with M in [2, 7] \
                     — the i32 accumulator bound)",
                    site.label(),
                    pol.q,
                    pol.m_levels
                ));
            }
        }
        Ok(())
    }

    /// Resolve the policy for one site. Total over every `SiteId`.
    pub fn resolve(&self, site: SiteId) -> SitePolicy {
        let mut pol = self.default;
        for (sel, patch) in &self.rules {
            if sel.matches(site) {
                patch.apply(&mut pol);
            }
        }
        pol
    }

    // ---- the `.qplan` text format ----

    /// Render as `.qplan` text. `parse(render(p)) == p` (property-tested).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str("# NestQuant per-site quantization plan (see quant::plan)\n");
        s.push_str("[plan]\n");
        s.push_str(&format!("rot_kind = {}\n", self.rot_kind.cli_name()));
        s.push_str(&format!("calib_windows = {}\n", self.calib_windows));
        s.push_str(&format!("seed = {}\n", self.seed));
        s.push_str("\n[default]\n");
        PolicyPatch::from_policy(&self.default).render_into(&mut s);
        for (sel, patch) in &self.rules {
            s.push_str("\n[rule]\n");
            if let Some((lo, hi)) = sel.layers {
                if lo == hi {
                    s.push_str(&format!("layers = {lo}\n"));
                } else {
                    s.push_str(&format!("layers = {lo}..{hi}\n"));
                }
            }
            if let Some(k) = sel.kind {
                s.push_str(&format!("kind = {}\n", k.name()));
            }
            if let Some(r) = sel.role {
                s.push_str(&format!("role = {}\n", r.name()));
            }
            patch.render_into(&mut s);
        }
        s
    }

    /// Parse the `.qplan` text format: `[plan]` / `[default]` /
    /// repeated `[rule]` sections of `key = value` lines; `#` starts a
    /// comment; `[default]` keys not given inherit `SitePolicy::default()`.
    pub fn parse(text: &str) -> Result<QuantPlan, String> {
        #[derive(PartialEq)]
        enum Sec {
            None,
            Plan,
            Default,
            Rule,
        }
        let defaults = EngineOptions::default();
        let mut plan = QuantPlan {
            rot_kind: defaults.rot_kind,
            calib_windows: defaults.calib_windows,
            seed: defaults.seed,
            default: SitePolicy::default(),
            rules: Vec::new(),
        };
        let mut sec = Sec::None;
        let mut cur: Option<(SiteSelector, PolicyPatch)> = None;
        for (i, raw) in text.lines().enumerate() {
            let n = i + 1;
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            match line {
                "[plan]" => {
                    plan.rules.extend(cur.take());
                    sec = Sec::Plan;
                    continue;
                }
                "[default]" => {
                    plan.rules.extend(cur.take());
                    sec = Sec::Default;
                    continue;
                }
                "[rule]" => {
                    plan.rules.extend(cur.take());
                    sec = Sec::Rule;
                    cur = Some(Default::default());
                    continue;
                }
                _ if line.starts_with('[') => {
                    return Err(format!("line {n}: unknown section '{line}'"));
                }
                _ => {}
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {n}: expected 'key = value', got '{line}'"))?;
            let (key, val) = (key.trim(), val.trim());
            let ctx = |e: String| format!("line {n}: {e}");
            match sec {
                Sec::None => {
                    return Err(format!("line {n}: '{key}' before any [section] header"));
                }
                Sec::Plan => match key {
                    "rot_kind" => {
                        plan.rot_kind = RotKind::parse(val)
                            .ok_or_else(|| format!("line {n}: unknown rot_kind '{val}'"))?;
                    }
                    "calib_windows" => {
                        let cw: usize = parse_num(key, val).map_err(ctx)?;
                        if cw == 0 {
                            return Err(format!("line {n}: calib_windows must be at least 1"));
                        }
                        plan.calib_windows = cw;
                    }
                    "seed" => plan.seed = parse_num(key, val).map_err(ctx)?,
                    _ => return Err(format!("line {n}: unknown [plan] key '{key}'")),
                },
                Sec::Default => {
                    let mut patch = PolicyPatch::default();
                    if !patch.set(key, val).map_err(ctx)? {
                        return Err(format!("line {n}: unknown [default] key '{key}'"));
                    }
                    patch.apply(&mut plan.default);
                }
                Sec::Rule => {
                    let (sel, patch) = cur.as_mut().expect("[rule] opened");
                    match key {
                        "layers" => sel.layers = Some(parse_layers(val).map_err(ctx)?),
                        "kind" => {
                            sel.kind = Some(SiteKind::parse(val).ok_or_else(|| {
                                format!("line {n}: unknown site kind '{val}'")
                            })?);
                        }
                        "role" => {
                            sel.role = Some(SiteRole::parse(val).ok_or_else(|| {
                                format!("line {n}: unknown site role '{val}'")
                            })?);
                        }
                        _ => {
                            if !patch.set(key, val).map_err(ctx)? {
                                return Err(format!("line {n}: unknown [rule] key '{key}'"));
                            }
                        }
                    }
                }
            }
        }
        plan.rules.extend(cur.take());
        Ok(plan)
    }

    /// Read, parse and validate a `.qplan` file — the one entry point
    /// the CLI uses, so every failure carries the offending path and a
    /// typed reason ([`PlanFileError`], same taxonomy as
    /// `io::tensorfile::TensorFileError`): I/O failures, parse errors
    /// with line numbers, out-of-range knobs, and LUT-backend
    /// combinations the engine cannot serve.
    pub fn load(path: &Path) -> Result<QuantPlan, PlanFileError> {
        let text = std::fs::read_to_string(path).map_err(|source| PlanFileError::Io {
            path: path.to_path_buf(),
            source,
        })?;
        let plan = QuantPlan::parse(&text).map_err(|reason| PlanFileError::Parse {
            path: path.to_path_buf(),
            reason,
        })?;
        // validate() subsumes check_backend_support(), but splitting the
        // two keeps the error typed: a syntactically fine plan asking
        // for an unserveable LUT site is `Unsupported`, not `Invalid`.
        plan.check_backend_support()
            .map_err(|reason| PlanFileError::Unsupported {
                path: path.to_path_buf(),
                reason,
            })?;
        plan.validate().map_err(|reason| PlanFileError::Invalid {
            path: path.to_path_buf(),
            reason,
        })?;
        Ok(plan)
    }
}

/// Why a `.qplan` file could not be loaded. Every variant names the
/// offending path so CLI errors are actionable without a backtrace
/// (mirrors `io::tensorfile::TensorFileError`).
#[derive(Debug)]
pub enum PlanFileError {
    /// The underlying filesystem read failed.
    Io {
        path: PathBuf,
        source: std::io::Error,
    },
    /// The text did not parse (reason carries the line number).
    Parse { path: PathBuf, reason: String },
    /// The plan parsed but a knob is out of range.
    Invalid { path: PathBuf, reason: String },
    /// The plan resolves a weight site to a LUT-backend configuration
    /// the engine cannot serve (reason names the site).
    Unsupported { path: PathBuf, reason: String },
}

impl std::fmt::Display for PlanFileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanFileError::Io { path, source } => {
                write!(f, "{}: read failed: {source}", path.display())
            }
            PlanFileError::Parse { path, reason } => {
                write!(f, "{}: {reason}", path.display())
            }
            PlanFileError::Invalid { path, reason } => {
                write!(f, "{}: invalid plan: {reason}", path.display())
            }
            PlanFileError::Unsupported { path, reason } => {
                write!(f, "{}: unsupported plan: {reason}", path.display())
            }
        }
    }
}

impl std::error::Error for PlanFileError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PlanFileError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// Inclusive layer range: `3` or `0..3`.
fn parse_layers(val: &str) -> Result<(usize, usize), String> {
    if let Some((lo, hi)) = val.split_once("..") {
        let lo: usize = parse_num("layers", lo.trim())?;
        let hi: usize = parse_num("layers", hi.trim())?;
        if lo > hi {
            return Err(format!("layers: empty range {lo}..{hi}"));
        }
        Ok((lo, hi))
    } else {
        let l: usize = parse_num("layers", val)?;
        Ok((l, l))
    }
}

/// Fluent constructor for [`QuantPlan`]s (and the engines built from
/// them). Rules are appended in call order; later rules win.
///
/// ```ignore
/// let eng = EngineBuilder::from_options(opts)      // uniform baseline
///     .layers(0, 3, PolicyPatch::rate(16))         // early blocks finer
///     .site(SiteKind::Down, PolicyPatch::rate(16)) // sensitive proj
///     .site(SiteKind::LmHead, PolicyPatch::fp())   // fp head
///     .build(&weights);
/// ```
#[derive(Clone, Debug)]
pub struct EngineBuilder {
    plan: QuantPlan,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl EngineBuilder {
    pub fn new() -> Self {
        Self::from_options(EngineOptions::default())
    }

    /// Start from the uniform lowering of a legacy `EngineOptions`.
    pub fn from_options(opts: EngineOptions) -> Self {
        EngineBuilder {
            plan: QuantPlan::uniform(opts),
        }
    }

    pub fn from_plan(plan: QuantPlan) -> Self {
        EngineBuilder { plan }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.plan.seed = seed;
        self
    }

    pub fn rot_kind(mut self, kind: RotKind) -> Self {
        self.plan.rot_kind = kind;
        self
    }

    pub fn calib_windows(mut self, n: usize) -> Self {
        assert!(n >= 1, "calib_windows must be at least 1");
        self.plan.calib_windows = n;
        self
    }

    pub fn default_policy(mut self, p: SitePolicy) -> Self {
        self.plan.default = p;
        self
    }

    /// Append a raw override rule.
    pub fn rule(mut self, sel: SiteSelector, patch: PolicyPatch) -> Self {
        self.plan.rules.push((sel, patch));
        self
    }

    /// Override every site in an inclusive layer range (`lo <= hi`; an
    /// inverted range would silently match nothing and render to a
    /// `.qplan` the parser rejects, so it is refused here).
    pub fn layers(self, lo: usize, hi: usize, patch: PolicyPatch) -> Self {
        assert!(lo <= hi, "inverted layer range {lo}..{hi}");
        self.rule(
            SiteSelector {
                layers: Some((lo, hi)),
                ..Default::default()
            },
            patch,
        )
    }

    /// Override every site of one kind (any layer, any role).
    pub fn site(self, kind: SiteKind, patch: PolicyPatch) -> Self {
        self.rule(
            SiteSelector {
                kind: Some(kind),
                ..Default::default()
            },
            patch,
        )
    }

    /// Override every site of one role (weights / acts / kv).
    pub fn role(self, role: SiteRole, patch: PolicyPatch) -> Self {
        self.rule(
            SiteSelector {
                role: Some(role),
                ..Default::default()
            },
            patch,
        )
    }

    pub fn plan(self) -> QuantPlan {
        self.plan
    }

    pub fn build(self, w: &ModelWeights) -> Engine {
        Engine::build_plan(w, self.plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::engine::Regime;
    use crate::util::{propcheck, Rng};

    fn rand_patch(rng: &mut Rng) -> PolicyPatch {
        let mut p = PolicyPatch::default();
        if rng.below(2) == 0 {
            p.quantize = Some(rng.below(2) == 0);
        }
        if rng.below(2) == 0 {
            p.method = Some(Method::ALL[rng.below(Method::ALL.len())]);
        }
        if rng.below(2) == 0 {
            p.q = Some(7 + rng.below(12) as u32);
        }
        if rng.below(2) == 0 {
            p.k = Some(2 + rng.below(6));
        }
        if rng.below(2) == 0 {
            p.uniform_bits = Some(2 + rng.below(6) as u32);
        }
        if rng.below(2) == 0 {
            p.ldlq = Some(rng.below(2) == 0);
        }
        if rng.below(2) == 0 {
            p.qa_ldlq = Some(rng.below(2) == 0);
        }
        if rng.below(2) == 0 {
            p.eps2 = Some(rng.f32());
        }
        if rng.below(2) == 0 {
            p.auto_eps2 = Some(rng.below(2) == 0);
        }
        if rng.below(2) == 0 {
            p.int_gemm = Some(rng.below(2) == 0);
        }
        if rng.below(2) == 0 {
            p.backend = Some(GemmBackend::ALL[rng.below(GemmBackend::ALL.len())]);
        }
        if rng.below(2) == 0 {
            p.m_levels = Some(2 + rng.below(7) as u32);
        }
        p
    }

    fn rand_selector(rng: &mut Rng) -> SiteSelector {
        let mut s = SiteSelector::default();
        if rng.below(2) == 0 {
            let lo = rng.below(6);
            s.layers = Some((lo, lo + rng.below(4)));
        }
        if rng.below(2) == 0 {
            s.kind = Some(SiteKind::ALL[rng.below(SiteKind::ALL.len())]);
        }
        if rng.below(2) == 0 {
            s.role = Some(SiteRole::ALL[rng.below(SiteRole::ALL.len())]);
        }
        s
    }

    fn rand_plan(rng: &mut Rng) -> QuantPlan {
        let mut default = SitePolicy::default();
        rand_patch(rng).apply(&mut default);
        let rules = (0..rng.below(5))
            .map(|_| (rand_selector(rng), rand_patch(rng)))
            .collect();
        QuantPlan {
            rot_kind: RotKind::ALL[rng.below(RotKind::ALL.len())],
            calib_windows: 1 + rng.below(4),
            seed: rng.next_u64(),
            default,
            rules,
        }
    }

    #[test]
    fn resolution_is_total_and_deterministic() {
        propcheck::check("plan-resolution-total", 40, 0x9_1A17, |rng| {
            let plan = rand_plan(rng);
            let n_layer = 1 + rng.below(5);
            for site in enumerate_sites(n_layer) {
                let a = plan.resolve(site);
                let b = plan.resolve(site);
                if a != b {
                    return Err(format!("non-deterministic resolve at {}", site.label()));
                }
                if plan.rules.iter().all(|(sel, _)| !sel.matches(site)) && a != plan.default {
                    return Err(format!("unmatched site {} left default", site.label()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn later_rules_win_in_order() {
        let plan = EngineBuilder::new()
            .role(SiteRole::Weights, PolicyPatch::rate(12))
            .site(SiteKind::Down, PolicyPatch::rate(16))
            .plan();
        assert_eq!(plan.resolve(SiteId::weights(2, SiteKind::Down)).q, 16);
        assert_eq!(plan.resolve(SiteId::weights(2, SiteKind::Up)).q, 12);
        // acts role untouched by the weights rule, but Down-kind rule has
        // no role filter, so Down acts pick up the 16 too
        assert_eq!(plan.resolve(SiteId::acts(2, SiteKind::Down)).q, 16);
        assert_eq!(plan.resolve(SiteId::acts(2, SiteKind::Up)).q, 14);
    }

    #[test]
    fn layer_ranges_are_inclusive_and_skip_lm_head() {
        let plan = EngineBuilder::new()
            .layers(1, 2, PolicyPatch::fp())
            .plan();
        assert!(plan.resolve(SiteId::weights(0, SiteKind::Q)).quantize);
        assert!(!plan.resolve(SiteId::weights(1, SiteKind::Q)).quantize);
        assert!(!plan.resolve(SiteId::weights(2, SiteKind::Q)).quantize);
        assert!(plan.resolve(SiteId::weights(3, SiteKind::Q)).quantize);
        // lm_head has no layer: layer-range rules never match it
        assert!(plan.resolve(SiteId::lm_head(SiteRole::Weights)).quantize);
        let plan = EngineBuilder::new()
            .site(SiteKind::LmHead, PolicyPatch::fp())
            .plan();
        assert!(!plan.resolve(SiteId::lm_head(SiteRole::Weights)).quantize);
    }

    #[test]
    fn uniform_lowering_gates_roles_like_the_regime() {
        for (regime, w_on, a_on, kv_on) in [
            (Regime::Fp, false, false, false),
            (Regime::W, true, false, false),
            (Regime::WKv, true, false, true),
            (Regime::WKvA, true, true, true),
        ] {
            let plan = QuantPlan::uniform(EngineOptions {
                regime,
                q: 10,
                ..Default::default()
            });
            assert_eq!(plan.resolve(SiteId::weights(0, SiteKind::Q)).quantize, w_on);
            assert_eq!(plan.resolve(SiteId::acts(0, SiteKind::Q)).quantize, a_on);
            assert_eq!(plan.resolve(SiteId::kv(0)).quantize, kv_on);
            assert_eq!(plan.resolve(SiteId::lm_head(SiteRole::Weights)).quantize, w_on);
            assert_eq!(plan.resolve(SiteId::kv(0)).q, 10);
        }
    }

    #[test]
    fn qplan_text_roundtrips() {
        propcheck::check("qplan-roundtrip", 60, 0xF0_97AD, |rng| {
            let plan = rand_plan(rng);
            let text = plan.render();
            let back = QuantPlan::parse(&text)
                .map_err(|e| format!("parse of rendered plan failed: {e}\n{text}"))?;
            if back != plan {
                return Err(format!("roundtrip drift:\n{plan:?}\nvs\n{back:?}\n{text}"));
            }
            Ok(())
        });
    }

    #[test]
    fn qplan_parse_accepts_handwritten_input() {
        let text = "
            # mixed-precision serving plan
            [plan]
            seed = 7   # deterministic rotations
            [default]
            method = nestquantm
            q = 12
            [rule]
            kind = down
            role = weights
            q = 16
            [rule]
            kind = lm_head
            quantize = false
        ";
        let plan = QuantPlan::parse(text).unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.default.method, Method::NestQuantM);
        assert_eq!(plan.resolve(SiteId::weights(0, SiteKind::Down)).q, 16);
        assert_eq!(plan.resolve(SiteId::acts(0, SiteKind::Down)).q, 12);
        assert!(!plan.resolve(SiteId::lm_head(SiteRole::Weights)).quantize);
        assert!(plan.resolve(SiteId::weights(0, SiteKind::Up)).quantize);
    }

    #[test]
    fn qplan_parse_rejects_malformed_input() {
        for (bad, why) in [
            ("q = 14", "key before section"),
            ("[plan]\nbogus = 1", "unknown plan key"),
            ("[default]\nmethod = float8", "unknown method"),
            ("[rule]\nkind = attention", "unknown kind"),
            ("[default]\nq 14", "missing ="),
            ("[wat]", "unknown section"),
            ("[rule]\nlayers = 5..2", "empty range"),
            ("[default]\nq = twelve", "bad number"),
            ("[default]\nq = 300", "q out of codec range"),
            ("[default]\nuniform_bits = 16", "uniform bits out of range"),
            ("[default]\nk = 0", "zero betas"),
            ("[plan]\ncalib_windows = 0", "no calibration windows"),
            ("[default]\nbackend = simd", "unknown backend"),
            ("[default]\nm_levels = 1", "m_levels below range"),
            ("[default]\nm_levels = 9", "m_levels above range"),
            ("[rule]\nm_levels = none", "non-numeric m_levels"),
        ] {
            assert!(QuantPlan::parse(bad).is_err(), "should reject: {why}");
        }
    }

    #[test]
    fn builder_is_fluent_and_ordered() {
        let plan = EngineBuilder::from_options(EngineOptions {
            q: 12,
            ..Default::default()
        })
        .seed(99)
        .calib_windows(2)
        .rot_kind(RotKind::Fourier)
        .layers(0, 1, PolicyPatch::rate(10))
        .site(SiteKind::Down, PolicyPatch::rate(16))
        .plan();
        assert_eq!(plan.seed, 99);
        assert_eq!(plan.calib_windows, 2);
        assert_eq!(plan.rot_kind, RotKind::Fourier);
        assert_eq!(plan.resolve(SiteId::weights(0, SiteKind::Up)).q, 10);
        assert_eq!(plan.resolve(SiteId::weights(0, SiteKind::Down)).q, 16);
        assert_eq!(plan.resolve(SiteId::weights(2, SiteKind::Up)).q, 12);
    }

    #[test]
    fn validate_catches_out_of_range_hand_built_plans() {
        // fields are public, so hand-built plans bypass the parser's
        // checks — validate() is the choke point Engine::build_plan uses
        assert!(QuantPlan::default().validate().is_ok());
        let mut plan = QuantPlan::default();
        plan.calib_windows = 0;
        assert!(plan.validate().unwrap_err().contains("calib_windows"));
        let mut plan = QuantPlan::default();
        plan.default.q = 1;
        assert!(plan.validate().unwrap_err().contains("q must be"));
        let mut plan = QuantPlan::default();
        plan.rules.push((
            SiteSelector {
                layers: Some((4, 2)),
                ..Default::default()
            },
            PolicyPatch {
                uniform_bits: Some(16),
                ..Default::default()
            },
        ));
        assert!(plan.validate().unwrap_err().contains("inverted layer range"));
    }

    #[test]
    fn backend_knob_parses_resolves_and_validates() {
        let text = "
            [default]
            method = nestquantm
            q = 2
            [rule]
            kind = up
            role = weights
            backend = lut
            m_levels = 4
        ";
        let plan = QuantPlan::parse(text).unwrap();
        let up = plan.resolve(SiteId::weights(0, SiteKind::Up));
        assert_eq!(up.backend, GemmBackend::Lut);
        assert_eq!(up.m_levels, 4);
        let q = plan.resolve(SiteId::weights(0, SiteKind::Q));
        assert_eq!(q.backend, GemmBackend::Decode);
        assert!(plan.validate().is_ok());
        // and the knobs survive a render → parse roundtrip
        let back = QuantPlan::parse(&plan.render()).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn validate_rejects_unserveable_lut_sites() {
        let mk = |method: Method, q: u32, m: u32| {
            let mut plan = QuantPlan::default();
            plan.default.method = method;
            plan.default.q = q;
            plan.default.m_levels = m;
            plan.default.backend = GemmBackend::Lut;
            plan
        };
        assert!(mk(Method::NestQuantM, 2, 4).validate().is_ok());
        assert!(mk(Method::NestQuant, 3, 7).validate().is_ok());
        // q = 3, M = 8 overflows the i32 LUT accumulator bound
        let e = mk(Method::NestQuantM, 3, 8).validate().unwrap_err();
        assert!(e.contains("backend = lut"), "{e}");
        // q = 4 is outside the LUT index window entirely
        let e = mk(Method::NestQuantM, 4, 2).validate().unwrap_err();
        assert!(e.contains("unsupported"), "{e}");
        // non-nested methods have no hierarchical codes to look up
        let e = mk(Method::Rtn, 2, 4).validate().unwrap_err();
        assert!(e.contains("nested method"), "{e}");
        // a later weights-role rule can rescue an unserveable default
        let mut plan = mk(Method::NestQuantM, 4, 2);
        plan.rules.push((
            SiteSelector {
                role: Some(SiteRole::Weights),
                ..Default::default()
            },
            PolicyPatch {
                q: Some(2),
                ..Default::default()
            },
        ));
        assert!(plan.validate().is_ok());
        // ...and a layer-bounded lut rule is checked inside its range
        let mut plan = QuantPlan::default();
        plan.default.method = Method::NestQuantM;
        plan.rules.push((
            SiteSelector {
                layers: Some((3, 5)),
                role: Some(SiteRole::Weights),
                ..Default::default()
            },
            PolicyPatch {
                backend: Some(GemmBackend::Lut),
                q: Some(3),
                m_levels: Some(8),
                ..Default::default()
            },
        ));
        assert!(plan.validate().unwrap_err().contains("L3."));
    }

    #[test]
    fn load_reports_typed_path_bearing_errors() {
        let dir = std::env::temp_dir().join("nqt_plan_test");
        std::fs::create_dir_all(&dir).unwrap();

        let missing = dir.join("missing.qplan");
        let _ = std::fs::remove_file(&missing);
        let err = QuantPlan::load(&missing).unwrap_err();
        assert!(matches!(err, PlanFileError::Io { .. }), "{err}");
        assert!(err.to_string().contains("missing.qplan"));

        let bad = dir.join("bad.qplan");
        std::fs::write(&bad, "[default]\nq = twelve\n").unwrap();
        let err = QuantPlan::load(&bad).unwrap_err();
        assert!(matches!(err, PlanFileError::Parse { .. }), "{err}");
        assert!(err.to_string().contains("bad.qplan"));
        assert!(err.to_string().contains("line 2"), "{err}");

        let unsup = dir.join("unsup.qplan");
        std::fs::write(
            &unsup,
            "[default]\nmethod = nestquantm\nbackend = lut\nq = 3\nm_levels = 8\n",
        )
        .unwrap();
        let err = QuantPlan::load(&unsup).unwrap_err();
        assert!(matches!(err, PlanFileError::Unsupported { .. }), "{err}");
        assert!(err.to_string().contains("unsup.qplan"));

        let good = dir.join("good.qplan");
        std::fs::write(
            &good,
            "[default]\nmethod = nestquantm\n[rule]\nkind = up\nrole = weights\nbackend = lut\nq = 2\nm_levels = 4\n",
        )
        .unwrap();
        let plan = QuantPlan::load(&good).unwrap();
        assert_eq!(
            plan.resolve(SiteId::weights(0, SiteKind::Up)).backend,
            GemmBackend::Lut
        );
    }

    #[test]
    #[should_panic(expected = "inverted layer range")]
    fn builder_refuses_inverted_layer_ranges() {
        // an inverted range would match nothing and render to a .qplan
        // the parser rejects — fail loudly at construction instead
        let _ = EngineBuilder::new().layers(3, 1, PolicyPatch::rate(16));
    }

    #[test]
    fn enumerate_sites_covers_every_combination() {
        let sites = enumerate_sites(2);
        // 2 layers × 9 in-stack kinds × 3 roles + 3 lm_head roles
        assert_eq!(sites.len(), 2 * 9 * 3 + 3);
        let mut seen = std::collections::HashSet::new();
        for s in &sites {
            assert!(seen.insert(s.label()), "duplicate site {}", s.label());
        }
        assert!(sites.contains(&SiteId::kv(1)));
        assert!(sites.contains(&SiteId::lm_head(SiteRole::Acts)));
    }
}
