//! LDLQ weight quantization (paper §4.5, Appendix B; following QuIP/GPTQ).
//!
//! Minimizes tr[(W−U)·H·(W−U)ᵀ] with H = E[XXᵀ] the activation Hessian.
//! With H = L·D·Lᵀ (L unit lower triangular), the loss separates along the
//! LDL coordinates; quantizing in-feature positions from last to first with
//! the feedback u_j = Q(w_j + Σ_{i>j} e_i·L_ij), e_i = w_i − u_i, leaves
//! only granular noise in each coordinate.
//!
//! NestQuant quantizes 8-blocks jointly, so the decomposition must be the
//! *block* LDL (8×8 identity diagonal blocks): the within-block coupling
//! lives in the block-diagonal D and the feedback L only spans distinct
//! blocks. (Using the scalar LDL and ignoring within-block terms is
//! unstable: under strongly correlated Hessians the uncompensated
//! coupling compounds block over block — empirically the error avalanches
//! exactly like the Appendix-B "∞ perplexity" pathology.)

use crate::lattice::e8::D;
use crate::lattice::nested::NestedLatticeQuantizer;
use crate::quant::matrix::QuantizedMatrix;
use crate::util::linalg::{block_ldl, Mat};

/// Estimate the calibration Hessian H = XᵀX/N (+ ridge) from activation
/// samples (rows of `x` are activation vectors).
pub fn hessian_from_activations(x: &Mat, ridge_frac: f64) -> Mat {
    let n = x.cols;
    let mut h = Mat::zeros(n, n);
    for r in 0..x.rows {
        let row = x.row(r);
        for i in 0..n {
            let xi = row[i];
            if xi == 0.0 {
                continue;
            }
            let hrow = &mut h.data[i * n..(i + 1) * n];
            for (hv, &xj) in hrow.iter_mut().zip(row) {
                *hv += xi * xj;
            }
        }
    }
    let scale = 1.0 / x.rows.max(1) as f32;
    h.scale(scale);
    // ridge: fraction of mean diagonal (GPTQ-style damping)
    let mean_diag: f64 =
        (0..n).map(|i| h[(i, i)] as f64).sum::<f64>() / n as f64;
    h.add_diag((ridge_frac * mean_diag.max(1e-12)) as f32);
    h
}

/// Quantize `w` (a×n) with LDLQ feedback against Hessian `h` (n×n),
/// using the nested-lattice quantizer for each 8-block. Row scales are
/// fixed from the *original* rows (the β codebook absorbs per-block
/// magnitude changes introduced by the feedback).
pub fn ldlq_quantize(w: &Mat, h: &Mat, nq: &NestedLatticeQuantizer) -> QuantizedMatrix {
    assert_eq!(w.cols, h.rows);
    assert_eq!(h.rows, h.cols);
    assert_eq!(w.cols % D, 0);
    let (l, _) = block_ldl(h, D);
    ldlq_quantize_with_l(w, &l, nq)
}

/// Paper Appendix G initial scaling coefficients β̂ = [3.5, 4.5, 6, 14.5,
/// 25]/q — "the β we get when optimizing them for weight quantization
/// without consideration of LDLQ". The large entries absorb the feedback-
/// inflated blocks LDLQ produces under strongly correlated Hessians.
pub fn initial_betas(q: u32) -> Vec<f32> {
    [3.5f32, 4.5, 6.0, 14.5, 25.0]
        .iter()
        .map(|v| v / q as f32)
        .collect()
}

/// The paper's full weight pipeline (§4.6 steps 2–5): simulate LDLQ with
/// the initial β̂ to collect the distribution of adjusted 8-blocks, run the
/// β-selection DP on them (+ overload margin, App. G), then requantize
/// with the chosen βs. Returns the quantized matrix and its quantizer.
pub fn ldlq_quantize_adaptive(
    w: &Mat,
    h: &Mat,
    q: u32,
    k: usize,
    margin: f32,
    m_variant: bool,
) -> (QuantizedMatrix, NestedLatticeQuantizer) {
    use crate::lattice::beta_dp::select_betas_for_data;
    use crate::lattice::voronoi::VoronoiCodec;
    let (l, _) = block_ldl(h, D);
    let codec = if m_variant {
        VoronoiCodec::new_m(q)
    } else {
        VoronoiCodec::new(q)
    };
    // pass 1: simulate with β̂, collecting the normalized adjusted blocks
    let nq0 = NestedLatticeQuantizer::with_codec(
        codec.clone(),
        initial_betas(q),
        crate::lattice::nested::Strategy::OptBeta,
    );
    let mut blocks = Vec::new();
    let _ = ldlq_core(w, &l, &nq0, Some(&mut blocks));
    // β-selection DP on the simulated blocks
    let betas = select_betas_for_data(&codec, &blocks, k, margin);
    let nq = NestedLatticeQuantizer::with_codec(
        codec,
        betas,
        crate::lattice::nested::Strategy::OptBeta,
    );
    (ldlq_core(w, &l, &nq, None), nq)
}

/// LDLQ with a precomputed unit-lower-triangular feedback matrix L.
pub fn ldlq_quantize_with_l(
    w: &Mat,
    l: &Mat,
    nq: &NestedLatticeQuantizer,
) -> QuantizedMatrix {
    ldlq_core(w, l, nq, None)
}

/// Core LDLQ loop; when `collect` is provided, also records every
/// normalized adjusted block (the pass-1 "simulation" of §4.6 step 2).
fn ldlq_core(
    w: &Mat,
    l: &Mat,
    nq: &NestedLatticeQuantizer,
    mut collect: Option<&mut Vec<[f32; D]>>,
) -> QuantizedMatrix {
    let n = w.cols;
    let bpr = n / D;
    let mut codes = vec![0u8; w.rows * n];
    let mut beta_idx = vec![0u8; w.rows * bpr];
    let mut scales = vec![0f32; w.rows];

    for r in 0..w.rows {
        let row = w.row(r);
        let s = crate::util::stats::norm2(row) as f32;
        scales[r] = s;
        if s == 0.0 {
            continue;
        }
        let t = s / (n as f32).sqrt(); // denorm factor
        let inv_t = 1.0 / t;
        let mut e = vec![0f32; n]; // e_i = w_i − u_i (original domain)
        // blocks from last to first
        for j in (0..bpr).rev() {
            let lo = j * D;
            // feedback from strictly-later columns
            let mut adj = [0f32; D];
            for (c, a) in adj.iter_mut().enumerate() {
                let col = lo + c;
                let mut f = 0f32;
                for i in (j + 1) * D..n {
                    // L is lower triangular: L[i][col] with i > col
                    f += e[i] * l[(i, col)];
                }
                *a = row[col] + f;
            }
            // quantize the adjusted block on the row's fixed grid
            let mut norm_block = [0f32; D];
            for i in 0..D {
                norm_block[i] = adj[i] * inv_t;
            }
            if let Some(sink) = collect.as_deref_mut() {
                sink.push(norm_block);
            }
            let (mut c, mut bi, mut recon, ov) = nq.quantize_block(&norm_block);
            if ov {
                // Overload safeguard: the feedback pushed this block
                // outside even the largest β's shaping region — the error-
                // avalanche regime of Appendix B ("∞ perplexity" under
                // original LDLQ). Dropping the feedback for this block
                // bounds the cascade: e stays at the direct-quantization
                // error instead of compounding.
                let mut plain = [0f32; D];
                for i in 0..D {
                    plain[i] = row[lo + i] * inv_t;
                }
                let (c2, bi2, recon2, _) = nq.quantize_block(&plain);
                c = c2;
                bi = bi2;
                recon = recon2;
            }
            codes[r * n + lo..r * n + lo + D].copy_from_slice(&c);
            beta_idx[r * bpr + j] = bi;
            for i in 0..D {
                let u = recon[i] * t;
                e[lo + i] = row[lo + i] - u;
            }
        }
    }
    QuantizedMatrix {
        rows: w.rows,
        cols: n,
        q: nq.q(),
        levels: 1,
        codes,
        beta_idx,
        scales,
    }
}

/// Proxy loss tr[(W−U)·H·(W−U)ᵀ] — what LDLQ minimizes.
pub fn hessian_loss(w: &Mat, u: &Mat, h: &Mat) -> f64 {
    assert_eq!(w.rows, u.rows);
    assert_eq!(w.cols, u.cols);
    let mut total = 0f64;
    let n = w.cols;
    let mut e = vec![0f32; n];
    for r in 0..w.rows {
        for i in 0..n {
            e[i] = w[(r, i)] - u[(r, i)];
        }
        let he = h.matvec(&e);
        total += crate::util::stats::dot(&e, &he);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn nq() -> NestedLatticeQuantizer {
        NestedLatticeQuantizer::new(14, vec![0.25, 0.32, 0.45, 1.0])
    }

    /// Correlated activation samples (AR(1)-ish) — makes H far from I so
    /// LDLQ has something to exploit.
    fn correlated_activations(n: usize, samples: usize, rho: f32, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut x = Mat::zeros(samples, n);
        for r in 0..samples {
            let mut prev = rng.gauss_f32();
            for c in 0..n {
                let z = rng.gauss_f32();
                prev = rho * prev + (1.0 - rho * rho).sqrt() * z;
                x[(r, c)] = prev;
            }
        }
        x
    }

    #[test]
    fn hessian_is_symmetric_psd() {
        let x = correlated_activations(32, 64, 0.8, 1201);
        let h = hessian_from_activations(&x, 0.01);
        for i in 0..32 {
            assert!(h[(i, i)] > 0.0);
            for j in 0..32 {
                assert!((h[(i, j)] - h[(j, i)]).abs() < 1e-5);
            }
        }
        // PD after ridge: LDL must succeed
        let _ = block_ldl(&h, D);
    }

    #[test]
    fn ldlq_beats_direct_quantization_on_correlated_hessian() {
        // The Table 6 ablation direction: LDLQ (with the paper's two-pass
        // β selection, §4.6 steps 2–3) reduces the Hessian-proxy loss
        // relative to direct (no-feedback) quantization at the same rate.
        let mut rng = Rng::new(1202);
        let w = Mat::from_vec(16, 64, rng.gauss_vec(16 * 64));
        let x = correlated_activations(64, 256, 0.9, 1203);
        let h = hessian_from_activations(&x, 0.01);

        let (qm, nq_adapted) = ldlq_quantize_adaptive(&w, &h, 14, 4, 3.0 / 14.0, false);
        let ldlq = qm.dequantize(&nq_adapted);
        // direct baseline at the same q/k (βs chosen for the raw rows)
        let blocks: Vec<[f32; crate::lattice::e8::D]> = {
            let mut v = Vec::new();
            for r in 0..w.rows {
                let row = w.row(r);
                let s = crate::util::stats::norm2(row) as f32;
                let norm = (w.cols as f32).sqrt() / s;
                for ch in row.chunks_exact(crate::lattice::e8::D) {
                    let mut b = [0f32; crate::lattice::e8::D];
                    for i in 0..crate::lattice::e8::D {
                        b[i] = ch[i] * norm;
                    }
                    v.push(b);
                }
            }
            v
        };
        let codec = crate::lattice::voronoi::VoronoiCodec::new(14);
        let betas =
            crate::lattice::beta_dp::select_betas_for_data(&codec, &blocks, 4, 3.0 / 14.0);
        let nq_direct = NestedLatticeQuantizer::new(14, betas);
        let direct = QuantizedMatrix::quantize(&w, &nq_direct).dequantize(&nq_direct);

        let loss_direct = hessian_loss(&w, &direct, &h);
        let loss_ldlq = hessian_loss(&w, &ldlq, &h);
        assert!(
            loss_ldlq < loss_direct,
            "LDLQ loss {loss_ldlq} not below direct {loss_direct}"
        );
    }

    #[test]
    fn adaptive_betas_prevent_feedback_avalanche() {
        // With fixed small βs and a strongly correlated Hessian, plain
        // LDLQ overloads and the error avalanches (the Llama-3-70B layer-0
        // pathology of Appendix B). The two-pass β selection absorbs the
        // feedback-inflated blocks: reconstruction must stay close to W.
        let mut rng = Rng::new(1207);
        let w = Mat::from_vec(8, 64, rng.gauss_vec(8 * 64));
        let x = correlated_activations(64, 256, 0.9, 1208);
        let h = hessian_from_activations(&x, 0.01);
        let (qm, nq_adapted) = ldlq_quantize_adaptive(&w, &h, 14, 4, 3.0 / 14.0, false);
        let u = qm.dequantize(&nq_adapted);
        let mut num = 0f64;
        let mut den = 0f64;
        for (a, b) in w.data.iter().zip(&u.data) {
            num += ((a - b) as f64).powi(2);
            den += (*a as f64).powi(2);
        }
        let rel = (num / den).sqrt();
        assert!(rel < 0.6, "avalanche not contained: rel err {rel}");
    }

    #[test]
    fn ldlq_with_identity_hessian_equals_direct() {
        // H = I ⇒ L = I ⇒ no feedback ⇒ identical to Algorithm 3 rows.
        let nq = nq();
        let mut rng = Rng::new(1204);
        let w = Mat::from_vec(4, 32, rng.gauss_vec(128));
        let h = Mat::eye(32);
        let a = ldlq_quantize(&w, &h, &nq);
        let b = QuantizedMatrix::quantize(&w, &nq);
        assert_eq!(a.codes, b.codes);
        assert_eq!(a.beta_idx, b.beta_idx);
        assert_eq!(a.scales, b.scales);
    }

    #[test]
    fn ldlq_reconstruction_still_close_to_w() {
        let nq = nq();
        let mut rng = Rng::new(1205);
        let w = Mat::from_vec(8, 64, rng.gauss_vec(512));
        let x = correlated_activations(64, 128, 0.7, 1206);
        let h = hessian_from_activations(&x, 0.01);
        let u = ldlq_quantize(&w, &h, &nq).dequantize(&nq);
        let mut num = 0f64;
        let mut den = 0f64;
        for (a, b) in w.data.iter().zip(&u.data) {
            num += ((a - b) as f64).powi(2);
            den += (*a as f64).powi(2);
        }
        let rel = (num / den).sqrt();
        assert!(rel < 0.25, "LDLQ drifted too far from W: rel={rel}");
    }
}
