//! The quantized inference engine — NestQuant (and the uniform baselines)
//! applied to a trained model in the paper's three regimes:
//!
//! * `W`      — weights only (§5.2 "W")
//! * `W+KV`   — weights + KV cache
//! * `W+KV+A` — weights + KV cache + activations (full quantization)
//!
//! Construction mirrors §4.6: (1) calibration forward passes collect
//! per-site activation statistics (Hessians for LDLQ, 8-blocks for the
//! β-selection DP, per-head K/V blocks); (2) weights are quantized with
//! (QA-)LDLQ and DP-chosen βs; (3) activation/KV quantizers get their own
//! DP βs; (4) evaluation runs the quantized forward (fake-quant semantics,
//! bit-exact with coded storage — `quant::matrix` tests prove the
//! equivalence), while the serving path (`kvpool`, `coordinator`) keeps
//! KV entries in coded form — per layer, through the same
//! [`KvLaneCodec`] the eval roundtrips use, so mixed-KV plans are
//! eval-vs-serve consistent.
//!
//! Policy is **per site**: [`Engine::build_plan`] resolves every linear,
//! every layer's KV pair and every activation tap through a
//! [`QuantPlan`](crate::quant::plan::QuantPlan) (`SiteId → SitePolicy`),
//! so mixed-precision deployments (fp `lm_head`, higher-rate `down`/`o`,
//! per-layer KV rates) are first-class. The legacy [`EngineOptions`]
//! remains as a thin compat shim: [`Engine::build`] lowers it through
//! [`QuantPlan::uniform`](crate::quant::plan::QuantPlan::uniform) and
//! constructs bit-identical engines.

use crate::kvpool::{KvPool, PoolConfig, SessionKv};
use crate::lattice::beta_dp::select_betas_for_data;
use crate::lattice::hierarchical::HierarchicalQuantizer;
use crate::lattice::e8::D;
use crate::lattice::nested::{NestedLatticeQuantizer, QuantizedVector, Strategy};
use crate::lattice::voronoi::VoronoiCodec;
use crate::model::forward::{embed_into, gelu, rmsnorm, rmsnorm_rows, softmax_inplace, window_nll};
use crate::model::weights::ModelWeights;
use crate::obs::trace::{EventKind, GemmPath, SiteTag, Trace, TRACK_ENGINE};
use crate::quant::gemm::GemmScratch;
use crate::quant::ldlq::hessian_from_activations;
use crate::quant::lut::{LutScratch, PackedLutMatrix};
use crate::quant::matrix::QuantizedMatrix;
use crate::quant::plan::{GemmBackend, QuantPlan, SiteId, SiteKind, SitePolicy, SiteRole};
use crate::quant::qgemm::PackedNestMatrix;
use crate::quant::uniform::UniformQuantizer;
use crate::rotation::Rotation;
use crate::util::linalg::{matmul_into, Mat};
use crate::util::Rng;
use std::sync::Arc;

/// Quantization regime (paper Tables 1–3 columns). With the plan API the
/// regime is just a shorthand: `QuantPlan::uniform` lowers it to three
/// per-role quantize gates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    /// no quantization (fp32 reference)
    Fp,
    /// weights only
    W,
    /// weights + KV cache
    WKv,
    /// weights + KV cache + activations
    WKvA,
}

impl Regime {
    pub const ALL: [Regime; 4] = [Regime::Fp, Regime::W, Regime::WKv, Regime::WKvA];

    pub fn quantizes_weights(self) -> bool {
        !matches!(self, Regime::Fp)
    }
    pub fn quantizes_kv(self) -> bool {
        matches!(self, Regime::WKv | Regime::WKvA)
    }
    pub fn quantizes_acts(self) -> bool {
        matches!(self, Regime::WKvA)
    }
    pub fn label(self) -> &'static str {
        match self {
            Regime::Fp => "FP32",
            Regime::W => "W",
            Regime::WKv => "W+KV",
            Regime::WKvA => "W+KV+A",
        }
    }
    /// CLI / `.qplan` spelling.
    pub fn cli_name(self) -> &'static str {
        match self {
            Regime::Fp => "fp",
            Regime::W => "w",
            Regime::WKv => "wkv",
            Regime::WKvA => "wkva",
        }
    }
    pub fn parse(s: &str) -> Option<Regime> {
        Self::ALL.into_iter().find(|r| r.cli_name() == s)
    }
}

/// Quantization method (paper Table 2 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// round-to-nearest uniform, no rotation (LLM.int8-style)
    Rtn,
    /// randomized Hadamard rotations + uniform (QuaRot-style)
    UniformRot,
    /// Hadamard + uniform + LDLQ weights (SpinQuant/GPTQ-style)
    UniformRotLdlq,
    /// full NestQuant: rotations + nested-lattice + DP-β + (QA-)LDLQ
    NestQuant,
    /// NestQuantM: same, with the hardware-simple decode oracle (App. D)
    NestQuantM,
}

impl Method {
    /// Every method, in CLI/table order — the single source of truth the
    /// parse/label pairs (and experiment sweeps) are driven from.
    pub const ALL: [Method; 5] = [
        Method::Rtn,
        Method::UniformRot,
        Method::UniformRotLdlq,
        Method::NestQuant,
        Method::NestQuantM,
    ];

    /// Display label (paper tables).
    pub fn label(self) -> &'static str {
        match self {
            Method::Rtn => "RTN (uniform)",
            Method::UniformRot => "QuaRot-style (rot+uniform)",
            Method::UniformRotLdlq => "SpinQuant-style (rot+uniform+LDLQ)",
            Method::NestQuant => "NestQuant",
            Method::NestQuantM => "NestQuantM",
        }
    }
    /// CLI / `.qplan` spelling.
    pub fn cli_name(self) -> &'static str {
        match self {
            Method::Rtn => "rtn",
            Method::UniformRot => "uniform",
            Method::UniformRotLdlq => "uniform-ldlq",
            Method::NestQuant => "nestquant",
            Method::NestQuantM => "nestquantm",
        }
    }
    pub fn parse(s: &str) -> Option<Method> {
        Self::ALL.into_iter().find(|m| m.cli_name() == s)
    }
    pub fn rotates(self) -> bool {
        !matches!(self, Method::Rtn)
    }
    pub fn is_nested(self) -> bool {
        matches!(self, Method::NestQuant | Method::NestQuantM)
    }
    /// The Voronoi codec a nested method quantizes with at rate `q`
    /// (M-variant for `NestQuantM`). Panics on non-nested methods.
    pub fn codec(self, q: u32) -> VoronoiCodec {
        match self {
            Method::NestQuant => VoronoiCodec::new(q),
            Method::NestQuantM => VoronoiCodec::new_m(q),
            other => panic!("{other:?} has no nested codec"),
        }
    }
}

/// Rotation flavor for the Table 7 ablation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RotKind {
    Hadamard,
    Fourier,
    RandOrthKron,
}

impl RotKind {
    pub const ALL: [RotKind; 3] = [RotKind::Hadamard, RotKind::Fourier, RotKind::RandOrthKron];

    /// CLI / `.qplan` spelling.
    pub fn cli_name(self) -> &'static str {
        match self {
            RotKind::Hadamard => "hadamard",
            RotKind::Fourier => "fourier",
            RotKind::RandOrthKron => "rand-orth-kron",
        }
    }
    pub fn parse(s: &str) -> Option<RotKind> {
        Self::ALL.into_iter().find(|k| k.cli_name() == s)
    }
}

/// Legacy crate-wide options — one knob applied to every site. Kept as
/// the ergonomic entry point for uniform configurations; lowered to a
/// [`QuantPlan`] by [`Engine::build`] (see `QuantPlan::uniform`).
#[derive(Clone, Debug)]
pub struct EngineOptions {
    pub method: Method,
    pub regime: Regime,
    /// nesting ratio (rate = log2 q bits/entry) for nested methods
    pub q: u32,
    /// number of scaling coefficients β
    pub k: usize,
    /// bits for the uniform baselines
    pub uniform_bits: u32,
    /// LDLQ on weights (Table 6 ablation)
    pub ldlq: bool,
    /// QA-LDLQ correction when activations are quantized (§4.5)
    pub qa_ldlq: bool,
    /// isotropic activation-noise variance for QA-LDLQ (ε²); when
    /// `auto_eps2` is set this is overridden by the measured roundtrip
    /// MSE of the site's calibrated activation quantizer (App. B: "ε²
    /// depends on the quantization rate and the statistics of X")
    pub eps2: f32,
    pub auto_eps2: bool,
    pub rot_kind: RotKind,
    /// calibration windows used for Hessians / β DP
    pub calib_windows: usize,
    /// serve M-variant nested linears through the packed integer GEMM
    /// backend (`quant::qgemm::PackedNestMatrix::gemm_into`, decode
    /// amortized over the sequence) instead of dequantized fp32 matmul
    pub int_gemm: bool,
    pub seed: u64,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            method: Method::NestQuant,
            regime: Regime::WKvA,
            q: 14,
            k: 4,
            uniform_bits: 4,
            ldlq: true,
            qa_ldlq: true,
            eps2: 0.01,
            auto_eps2: true,
            rot_kind: RotKind::Hadamard,
            calib_windows: 3,
            int_gemm: true,
            seed: 0xC0FFEE,
        }
    }
}

/// A site's resolved activation treatment, baked into the `QLinear` at
/// build time so the forward path needs no global flags.
pub enum ActQuant {
    /// activations pass through in fp32
    None,
    /// calibrated nested-lattice activation quantizer (W+KV+A, nested)
    Nested(NestedLatticeQuantizer),
    /// uniform fake-quant at the given bit width (the baselines)
    Uniform(u32),
}

/// A layer's resolved KV-cache treatment is its pool lane codec —
/// re-exported here because the engine resolves it from the plan. One
/// enum serves both paths: `forward_window` fake-quants through
/// `roundtrip_key`/`roundtrip_value`, and [`Engine::kv_pool`] hands the
/// same codec to the paged pool, whose coded storage decodes
/// bitwise-identically to those roundtrips (tested in `kvpool`).
pub use crate::kvpool::KvLaneCodec;

/// Logical coded-payload accounting for one weight site (what the
/// serving tier would ship/keep resident for that tensor).
#[derive(Clone, Debug)]
pub struct SitePayload {
    pub site: SiteId,
    pub bytes: usize,
    pub bits_per_entry: f64,
    pub quantized: bool,
}

/// One quantized linear layer: either the packed integer-decode backend
/// (M-variant nested regimes) or a fake-quant dequantized weight
/// (transposed for row-major GEMM), plus the rotation applied to its
/// inputs at runtime, the site's resolved activation quantizer, and
/// storage accounting.
pub struct QLinear {
    /// which tensor in the stack this is (payload reporting)
    pub site: SiteId,
    /// the plan policy this site resolved to
    pub policy: SitePolicy,
    /// output features (rows of W)
    pub out_features: usize,
    /// input features (cols of W)
    pub in_features: usize,
    /// dequantized (fake-quant) Wᵀ, (in, out) — the fp fallback path.
    /// `None` when the packed integer backend serves this site: keeping
    /// the fp32 matrix resident alongside the ~4.25-bit codes would
    /// forfeit the weight-memory win on the serving path.
    pub wt_deq: Option<Mat>,
    /// packed integer-decode backend (M-variant nested regimes): serves
    /// `forward` through the decode-amortized GEMM instead of fp32
    /// matmul over the dequantized weight
    pub packed: Option<PackedNestMatrix>,
    /// LUT inner-product backend (`backend = lut` sites): M-level
    /// hierarchical codes served entirely by pair-LUT lookups — no
    /// decoded rows and no fp32 weights resident; activations are
    /// hierarchically encoded inside the GEMV, so `act` is `None` here
    pub lut: Option<PackedLutMatrix>,
    /// input rotation (already folded into the stored weight)
    pub rot: Option<Rotation>,
    /// this site's activation treatment
    pub act: ActQuant,
    /// coded storage for bits accounting + the serving path
    pub coded: Option<(QuantizedMatrix, NestedLatticeQuantizer)>,
    /// payload bits per entry (codes + β side info, zstd-compressed)
    pub bits_zstd: f64,
    pub bits_packed: f64,
}

/// Reusable buffers for [`QLinear::forward_into`]: the rotated /
/// fake-quantized input copy, the packed-GEMM panel scratch and the
/// activation-quantizer staging. One instance per thread (or one inside
/// a [`StepScratch`]) makes every linear allocation-free once warm.
pub struct LinScratch {
    /// working copy of the input (rotation + fake-quant applied in place)
    xbuf: Mat,
    /// panel/staging buffers for the packed integer GEMM
    gemm: GemmScratch,
    /// uniform activation codes
    act_codes: Vec<i8>,
    /// nested activation codes
    act_qv: QuantizedVector,
    /// encoded-activation indices + staging for the LUT backend
    lut: LutScratch,
}

impl LinScratch {
    pub fn new() -> Self {
        LinScratch {
            xbuf: Mat::zeros(0, 0),
            gemm: GemmScratch::new(),
            act_codes: Vec::new(),
            act_qv: QuantizedVector::default(),
            lut: LutScratch::new(),
        }
    }
}

impl Default for LinScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl QLinear {
    /// y = (x·R)·W̃ᵀ with the site's activation quantization applied
    /// after rotation. x (seq, in) → y (seq, out). When the packed
    /// integer backend is present the product runs on coset codes
    /// end-to-end: single rows (decode steps) through the integer GEMV,
    /// multi-row prefill windows through the decode-amortized
    /// multithreaded GEMM.
    pub fn forward(&self, x: &Mat) -> Mat {
        // spawning workers is only worth it for real prefill panels
        let threads = if x.rows >= 16 { 0 } else { 1 };
        // per-thread scratch: prefill reuses the panel/staging buffers
        // instead of reallocating them every linear
        thread_local! {
            static SCRATCH: std::cell::RefCell<LinScratch> =
                std::cell::RefCell::new(LinScratch::new());
        }
        let mut y = Mat::zeros(x.rows, self.out_features);
        SCRATCH.with(|s| self.forward_into(x, &mut y, &mut s.borrow_mut(), threads));
        y
    }

    /// [`Self::forward`] into a caller-owned output through caller-owned
    /// scratch — the fused decode loop calls every linear once per token
    /// batch and must not allocate. Bitwise-identical to `forward`: the
    /// rotation, the activation fake-quant and the fp fallback all work
    /// row by row, single rows take the integer GEMV, and the panel GEMM
    /// is decode-for-decode identical to the GEMV (`quant::gemm` pins
    /// this), so `threads` never changes the bits.
    pub fn forward_into(&self, x: &Mat, y: &mut Mat, s: &mut LinScratch, threads: usize) {
        s.xbuf.rows = x.rows;
        s.xbuf.cols = x.cols;
        s.xbuf.data.clear();
        s.xbuf.data.extend_from_slice(&x.data);
        if let Some(rot) = &self.rot {
            rot.apply_rows(&mut s.xbuf.data);
        }
        match &self.act {
            ActQuant::None => {}
            ActQuant::Nested(nq) => {
                for t in 0..s.xbuf.rows {
                    nq.quantize_into(s.xbuf.row(t), &mut s.act_qv);
                    nq.dequantize_into(&s.act_qv, s.xbuf.row_mut(t));
                }
            }
            ActQuant::Uniform(bits) => {
                let uq = UniformQuantizer::new(*bits);
                for t in 0..s.xbuf.rows {
                    let delta = uq.quantize_into(s.xbuf.row(t), &mut s.act_codes);
                    for (v, &c) in s.xbuf.row_mut(t).iter_mut().zip(s.act_codes.iter()) {
                        *v = c as f32 * delta;
                    }
                }
            }
        }
        y.rows = s.xbuf.rows;
        y.cols = self.out_features;
        y.data.clear();
        y.data.resize(s.xbuf.rows * self.out_features, 0.0);
        if let Some(lut) = &self.lut {
            // LUT sites: activations are hierarchically encoded inside
            // the GEMV/GEMM and the product is pure table lookups —
            // gemm_into is bit-for-bit the per-row gemv (`quant::lut`
            // pins this), so `threads` never changes the bits here
            // either.
            if s.xbuf.rows == 1 {
                lut.gemv_into(s.xbuf.row(0), y.row_mut(0), &mut s.lut);
            } else {
                lut.gemm_into(&s.xbuf, y, threads, &mut s.lut);
            }
        } else if let Some(packed) = &self.packed {
            if s.xbuf.rows == 1 {
                packed.gemv_into(s.xbuf.row(0), y.row_mut(0));
            } else {
                packed.gemm_into(&s.xbuf, y, threads, &mut s.gemm);
            }
        } else {
            let wt = self
                .wt_deq
                .as_ref()
                .expect("QLinear without the integer backend must keep wt_deq");
            matmul_into(
                &s.xbuf.data,
                &wt.data,
                &mut y.data,
                s.xbuf.rows,
                s.xbuf.cols,
                wt.cols,
            );
        }
    }

    /// Logical payload this site ships: the coded bytes for nested
    /// methods, `uniform_bits`/entry (+ per-row scale) for the uniform
    /// baselines, 4 bytes/entry for fp sites.
    pub fn payload(&self) -> SitePayload {
        let entries = self.in_features * self.out_features;
        let bytes = if let Some(lut) = &self.lut {
            // M levels × ⌈log2 q⌉ bits per weight + β/scale side info —
            // identical to the carrier matrix formula (`quant::lut`
            // pins the equality), counted here from the packed form
            // because LUT sites drop the carrier after packing
            lut.payload_bytes()
        } else if let Some((qm, _)) = &self.coded {
            qm.payload_bytes()
        } else if self.policy.quantize {
            (entries * self.policy.uniform_bits as usize).div_ceil(8) + self.out_features * 4
        } else {
            entries * 4
        };
        SitePayload {
            site: self.site,
            bytes,
            bits_per_entry: bytes as f64 * 8.0 / entries.max(1) as f64,
            quantized: self.policy.quantize,
        }
    }

    /// Which execution backend serves this site's GEMM — the label the
    /// `site_gemm` trace spans attribute time to.
    pub fn gemm_path(&self) -> GemmPath {
        if self.lut.is_some() {
            GemmPath::Lut
        } else if self.packed.is_some() {
            GemmPath::Packed
        } else {
            GemmPath::Fp
        }
    }
}

/// Per-layer quantized weights + KV treatment.
pub struct QLayer {
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
    pub wq: QLinear,
    pub wk: QLinear,
    pub wv: QLinear,
    pub wo: QLinear,
    pub w_up: QLinear,
    pub w_down: QLinear,
    /// per-head rotation applied to k and q (scores invariant) and to v
    pub head_rot: Option<Rotation>,
    /// KV-cache lane codec for this layer (per-site policy) — shared by
    /// the eval roundtrips and the paged pool's coded storage
    pub kv: KvLaneCodec,
}

/// Reusable panels and staging buffers for
/// [`Engine::forward_step_fused`] — sized lazily on first use,
/// allocation-free on every later step whose batch is no larger than
/// the high-water mark.
pub struct StepScratch {
    /// (n, d) residual stream
    x: Mat,
    /// (n, d) rmsnorm output
    normed: Mat,
    q: Mat,
    k: Mat,
    v: Mat,
    /// (n, d) per-head attention output (rotated basis)
    att: Mat,
    /// (n, d) wo / w_down projection output
    proj: Mat,
    /// (n, d_ff) MLP mid panel
    hmid: Mat,
    /// per-head staging for the KV append (rotated basis)
    kh: Vec<f32>,
    vh: Vec<f32>,
    qh: Vec<f32>,
    /// attention scores (capacity pinned to ctx on first use)
    scores: Vec<f32>,
    /// shared scratch for every linear in the step
    lin: LinScratch,
}

impl StepScratch {
    pub fn new() -> Self {
        StepScratch {
            x: Mat::zeros(0, 0),
            normed: Mat::zeros(0, 0),
            q: Mat::zeros(0, 0),
            k: Mat::zeros(0, 0),
            v: Mat::zeros(0, 0),
            att: Mat::zeros(0, 0),
            proj: Mat::zeros(0, 0),
            hmid: Mat::zeros(0, 0),
            kh: Vec::new(),
            vh: Vec::new(),
            qh: Vec::new(),
            scores: Vec::new(),
            lin: LinScratch::new(),
        }
    }
}

impl Default for StepScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Resize a scratch `Mat` to (rows, cols) of zeros, reusing capacity.
fn reshape(m: &mut Mat, rows: usize, cols: usize) {
    m.rows = rows;
    m.cols = cols;
    m.data.clear();
    m.data.resize(rows * cols, 0.0);
}

/// The quantized model + evaluation entry points.
pub struct Engine {
    pub cfg: crate::model::ModelConfig,
    /// the resolved per-site plan this engine was built from
    pub plan: QuantPlan,
    pub tok_emb: Mat,
    pub pos_emb: Mat,
    pub final_norm: Vec<f32>,
    pub head: QLinear,
    pub layers: Vec<QLayer>,
    /// mean weight-payload bits/entry (zstd β stream), across linears
    pub weight_bits_zstd: f64,
    /// same with raw 2-bit β packing
    pub weight_bits_packed: f64,
}

/// Calibration record for one linear input site. Activations are stored
/// in the **raw** (unrotated) basis; the build loop rotates each tap
/// once per input-site rotation and hands rotating consumers the shared
/// rotated copy (non-rotating consumers read the raw tap), so mixed
/// plans where consumers of one input site disagree on rotation keep
/// every Hessian in the right basis without per-linear re-rotation.
struct SiteStats {
    /// activation samples (rows)
    acts: Mat,
}

struct CalibData {
    /// per layer: [attn_in, attn_out, mlp_in, mlp_down]
    sites: Vec<Vec<SiteStats>>,
    head_in: SiteStats,
    /// per layer: rotated per-head K / V 8-blocks
    k_blocks: Vec<Vec<[f32; D]>>,
    v_blocks: Vec<Vec<[f32; D]>>,
}

fn make_rotation(n: usize, kind: RotKind, rng: &mut Rng) -> Rotation {
    match kind {
        RotKind::Hadamard => {
            if n.is_power_of_two() {
                Rotation::random_hadamard(n, rng)
            } else {
                // n = 2^k·m with a Paley factor (12 covers 48/24/96/192…)
                let m = if n % 12 == 0 { 12 } else { 20 };
                Rotation::kron_hadamard(n, m, rng)
            }
        }
        RotKind::Fourier => Rotation::fourier(n),
        RotKind::RandOrthKron => {
            let m = if n % 12 == 0 {
                12
            } else if n % 16 == 0 {
                16
            } else {
                20
            };
            Rotation::random_orth_kron(n, m, rng)
        }
    }
}

impl Engine {
    /// Build a quantized engine from fp weights with one crate-wide
    /// option set — the legacy API, now a thin shim over
    /// [`Engine::build_plan`] via `QuantPlan::uniform` (bit-identical to
    /// the pre-plan construction).
    pub fn build(w: &ModelWeights, opts: EngineOptions) -> Self {
        Self::build_plan(w, QuantPlan::uniform(opts))
    }

    /// Build a quantized engine from fp weights per §4.6, resolving
    /// method/rate/regime **per site** through the plan. Panics with a
    /// named reason on out-of-range plan knobs (`QuantPlan::validate`)
    /// rather than asserting deep inside codec construction.
    pub fn build_plan(w: &ModelWeights, plan: QuantPlan) -> Self {
        if let Err(e) = plan.validate() {
            panic!("invalid QuantPlan: {e}");
        }
        let cfg = w.cfg;
        let mut rng = Rng::new(plan.seed);

        // ---- resolve policies for every site up front ----
        let lin_kinds = [
            SiteKind::Q,
            SiteKind::K,
            SiteKind::V,
            SiteKind::O,
            SiteKind::Up,
            SiteKind::Down,
        ];
        let wpols: Vec<[SitePolicy; 6]> = (0..cfg.n_layer)
            .map(|i| lin_kinds.map(|kind| plan.resolve(SiteId::weights(i, kind))))
            .collect();
        let head_wpol = plan.resolve(SiteId::lm_head(SiteRole::Weights));
        let kvpols: Vec<SitePolicy> =
            (0..cfg.n_layer).map(|i| plan.resolve(SiteId::kv(i))).collect();

        // ---- rotations ----
        // One rotation per *input site*, shared by its consumer linears
        // (wq/wk/wv share attn_in), drawn iff any consumer both
        // quantizes and uses a rotating method. Draw order is fixed
        // (layer-major: attn_in, attn_out, mlp_in, mlp_down; then the
        // head input; then per-layer head rotations) so uniform plans
        // replay the exact pre-plan RNG stream.
        let wants_rot = |p: &SitePolicy| p.quantize && p.method.rotates();
        let site_rot = |on: bool, n: usize, rng: &mut Rng| -> Option<Rotation> {
            on.then(|| make_rotation(n, plan.rot_kind, rng))
        };
        let rots: Vec<[Option<Rotation>; 4]> = (0..cfg.n_layer)
            .map(|i| {
                let p = &wpols[i];
                [
                    site_rot(
                        wants_rot(&p[0]) || wants_rot(&p[1]) || wants_rot(&p[2]),
                        cfg.d_model,
                        &mut rng,
                    ),
                    site_rot(wants_rot(&p[3]), cfg.d_model, &mut rng),
                    site_rot(wants_rot(&p[4]), cfg.d_model, &mut rng),
                    site_rot(wants_rot(&p[5]), cfg.d_ff, &mut rng),
                ]
            })
            .collect();
        let head_rot_site = site_rot(wants_rot(&head_wpol), cfg.d_model, &mut rng);
        let head_rots: Vec<Option<Rotation>> = (0..cfg.n_layer)
            .map(|i| {
                (kvpols[i].quantize && kvpols[i].method.rotates())
                    .then(|| make_rotation(cfg.d_head(), plan.rot_kind, &mut rng))
            })
            .collect();

        // ---- calibration pass (fp forward, raw activation taps) ----
        let kv_tap: Vec<bool> = kvpols
            .iter()
            .map(|p| p.quantize && p.method.is_nested())
            .collect();
        let calib = Self::calibrate(w, &head_rots, &kv_tap, plan.calib_windows);

        // ---- quantize weights per resolved site policy ----
        let mut layers = Vec::with_capacity(cfg.n_layer);
        for (i, lw) in w.layers.iter().enumerate() {
            let s = &calib.sites[i];
            let p = &wpols[i];
            // rotate each input site's calibration tap once; every
            // rotating consumer shares it (wq/wk/wv share attn_in), and
            // non-rotating consumers read the raw tap directly — no
            // per-linear stats clone.
            let rot_stats: Vec<Option<SiteStats>> = (0..4)
                .map(|j| {
                    rots[i][j].as_ref().map(|r| {
                        let mut acts = s[j].acts.clone();
                        r.apply_rows(&mut acts.data);
                        SiteStats { acts }
                    })
                })
                .collect();
            let mk = |kind: SiteKind, wpol: &SitePolicy, wm: &Mat, j: usize| -> QLinear {
                let rotated =
                    wpol.quantize && wpol.method.rotates() && rots[i][j].is_some();
                let (rot, stats) = if rotated {
                    (
                        rots[i][j].clone(),
                        rot_stats[j].as_ref().expect("rotated stats exist"),
                    )
                } else {
                    (None, &s[j])
                };
                Self::quantize_linear(
                    SiteId::weights(i, kind),
                    wm,
                    rot,
                    stats,
                    wpol,
                    &plan.resolve(SiteId::acts(i, kind)),
                    plan.seed,
                )
            };
            let layer = QLayer {
                ln1: lw.ln1.clone(),
                ln2: lw.ln2.clone(),
                wq: mk(SiteKind::Q, &p[0], &lw.wq, 0),
                wk: mk(SiteKind::K, &p[1], &lw.wk, 0),
                wv: mk(SiteKind::V, &p[2], &lw.wv, 0),
                wo: mk(SiteKind::O, &p[3], &lw.wo, 1),
                w_up: mk(SiteKind::Up, &p[4], &lw.w_up, 2),
                w_down: mk(SiteKind::Down, &p[5], &lw.w_down, 3),
                head_rot: head_rots[i].clone(),
                kv: Self::kv_lane(&kvpols[i], &calib.k_blocks[i], &calib.v_blocks[i]),
            };
            layers.push(layer);
        }
        // head_rot_site exists iff the head policy rotates (single
        // consumer), so it is already the head's effective rotation
        let head_stats = head_rot_site.as_ref().map(|r| {
            let mut acts = calib.head_in.acts.clone();
            r.apply_rows(&mut acts.data);
            SiteStats { acts }
        });
        let head = Self::quantize_linear(
            SiteId::lm_head(SiteRole::Weights),
            &w.head,
            head_rot_site.clone(),
            head_stats.as_ref().unwrap_or(&calib.head_in),
            &head_wpol,
            &plan.resolve(SiteId::lm_head(SiteRole::Acts)),
            plan.seed,
        );

        // aggregate bits accounting over all quantized linears
        let mut bits_z = 0f64;
        let mut bits_p = 0f64;
        let mut n_lin = 0f64;
        let mut visit = |l: &QLinear| {
            if l.bits_zstd > 0.0 {
                bits_z += l.bits_zstd;
                bits_p += l.bits_packed;
                n_lin += 1.0;
            }
        };
        for l in &layers {
            visit(&l.wq);
            visit(&l.wk);
            visit(&l.wv);
            visit(&l.wo);
            visit(&l.w_up);
            visit(&l.w_down);
        }
        visit(&head);

        Engine {
            cfg,
            plan,
            tok_emb: w.tok_emb.clone(),
            pos_emb: w.pos_emb.clone(),
            final_norm: w.final_norm.clone(),
            head,
            layers,
            weight_bits_zstd: if n_lin > 0.0 { bits_z / n_lin } else { 32.0 },
            weight_bits_packed: if n_lin > 0.0 { bits_p / n_lin } else { 32.0 },
        }
    }

    /// Logical payload accounting per weight site (layer linears in
    /// order, then the head) — what `coordinator::Metrics` exports.
    pub fn site_payloads(&self) -> Vec<SitePayload> {
        let mut out = Vec::new();
        for l in &self.layers {
            for lin in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w_up, &l.w_down] {
                out.push(lin.payload());
            }
        }
        out.push(self.head.payload());
        out
    }

    /// Build the paged KV pool — the **sole** KV backend, total over
    /// plans: every layer contributes its own [`KvLaneCodec`] (raw fp32
    /// lanes for unquantized KV, branch-free uniform lanes for the
    /// baselines, calibrated nested pairs per §4.6 step 4, each at that
    /// layer's own plan-resolved rate). Because the lane codec is the
    /// same object `forward_window` roundtrips through, generation
    /// serves exactly the per-layer KV treatment that batch eval
    /// applies — mixed fp/uniform/nested plans included, with the pool's
    /// decoded values bitwise equal to the eval roundtrips.
    pub fn kv_pool(&self, cfg: PoolConfig) -> Arc<KvPool> {
        let lanes = self.layers.iter().map(|l| l.kv.clone()).collect();
        Arc::new(KvPool::new(self.cfg.n_layer, self.cfg.n_head, lanes, cfg))
    }

    /// Resolve a layer's KV lane codec from its policy + calibration
    /// blocks. Empty calibration blocks fall back to the uniform
    /// roundtrip, like the pre-plan engine's missing-quantizer path.
    fn kv_lane(pol: &SitePolicy, k_blocks: &[[f32; D]], v_blocks: &[[f32; D]]) -> KvLaneCodec {
        if !pol.quantize {
            return KvLaneCodec::Fp32;
        }
        if !pol.method.is_nested() {
            return KvLaneCodec::Uniform(pol.uniform_bits);
        }
        match (
            Self::kv_quantizer(k_blocks, pol),
            Self::kv_quantizer(v_blocks, pol),
        ) {
            (Some(k), Some(v)) => KvLaneCodec::Nested { k, v },
            _ => {
                // pre-plan behavior, but with the plan API this can
                // contradict an *explicit* nested KV request — say so
                // instead of substituting silently
                eprintln!(
                    "warning: no K/V calibration blocks for a nested KV policy \
                     (q={}); falling back to uniform {}-bit KV fake-quant",
                    pol.q, pol.uniform_bits
                );
                KvLaneCodec::Uniform(pol.uniform_bits)
            }
        }
    }

    fn kv_quantizer(blocks: &[[f32; D]], pol: &SitePolicy) -> Option<NestedLatticeQuantizer> {
        if blocks.is_empty() {
            return None;
        }
        let codec = pol.method.codec(pol.q);
        let betas = select_betas_for_data(&codec, blocks, pol.k, 4.0 / pol.q as f32);
        Some(NestedLatticeQuantizer::with_codec(
            codec,
            betas,
            Strategy::OptBeta,
        ))
    }

    /// `rot` is this linear's *effective* rotation (the shared
    /// input-site rotation when this site's method rotates, `None`
    /// otherwise), and `stats` must already be expressed in that basis —
    /// the caller rotates each input site's tap once and shares it
    /// across consumers.
    fn quantize_linear(
        site: SiteId,
        wm: &Mat,
        rot: Option<Rotation>,
        stats: &SiteStats,
        wpol: &SitePolicy,
        apol: &SitePolicy,
        seed: u64,
    ) -> QLinear {
        if !wpol.quantize {
            // fp site: exact weights, no rotation folded (identity is
            // exact), no coded payload; the activation policy is still
            // honored (in the raw basis).
            return QLinear {
                site,
                policy: *wpol,
                out_features: wm.rows,
                in_features: wm.cols,
                wt_deq: Some(wm.transpose()),
                packed: None,
                lut: None,
                rot: None,
                act: Self::act_quant(stats, apol),
                coded: None,
                bits_zstd: 0.0,
                bits_packed: 0.0,
            };
        }

        // fold the rotation into the weight: y = W x = (W Rᵀ)(R x) —
        // rows of W are functionals on x: replace each row w by R·w
        // (then (R w)·(R x) = w·x).
        let mut wrot = wm.clone();
        if let Some(r) = &rot {
            r.apply_rows(&mut wrot.data);
        }

        let act = Self::act_quant(stats, apol);

        match wpol.method {
            Method::Rtn | Method::UniformRot => {
                let uq = UniformQuantizer::new(wpol.uniform_bits);
                let deq = uq.roundtrip_rows(&wrot);
                QLinear {
                    site,
                    policy: *wpol,
                    out_features: deq.rows,
                    in_features: wm.cols,
                    wt_deq: Some(deq.transpose()),
                    packed: None,
                    lut: None,
                    rot,
                    act,
                    coded: None,
                    bits_zstd: wpol.uniform_bits as f64,
                    bits_packed: wpol.uniform_bits as f64,
                }
            }
            Method::UniformRotLdlq => {
                // GPTQ-style: uniform grid with scalar LDLQ feedback
                let h = hessian_from_activations(&stats.acts, 0.01);
                let deq = Self::uniform_ldlq(&wrot, &h, wpol.uniform_bits);
                QLinear {
                    site,
                    policy: *wpol,
                    out_features: deq.rows,
                    in_features: wm.cols,
                    wt_deq: Some(deq.transpose()),
                    packed: None,
                    lut: None,
                    rot,
                    act,
                    coded: None,
                    bits_zstd: wpol.uniform_bits as f64,
                    bits_packed: wpol.uniform_bits as f64,
                }
            }
            // The LUT backend: M-level hierarchical codes at base q
            // (rate M·log2 q bits/entry) served by pair-LUT inner
            // products (`quant::lut`) — decoded rows never exist, and
            // no fp32 copy stays resident. LDLQ is skipped here: the
            // hierarchical encoder is a fixed lattice map (digit-exact
            // for Q_Λ(x)), so codes come from direct Algorithm-3-style
            // quantization. βs are DP-selected against the equal-rate
            // flat M-variant codec (the M-level encoder reproduces flat
            // rate-q^M reconstructions exactly when not overloaded;
            // q^M is clamped to the flat codec's 255 ceiling for the DP
            // only). The hierarchical digit decode always uses the
            // hardware-simple M-variant oracle, whichever nested method
            // the site names.
            Method::NestQuant | Method::NestQuantM if wpol.backend == GemmBackend::Lut => {
                let m = wpol.m_levels;
                let flat_q = (wpol.q as u64).pow(m).min(255) as u32;
                let flat = VoronoiCodec::new_m(flat_q);
                let blocks = Self::row_blocks(&wrot);
                let wbetas =
                    select_betas_for_data(&flat, &blocks, wpol.k, 3.0 / flat_q as f32);
                let wq = HierarchicalQuantizer::new(wpol.q, m as usize, wbetas);
                // activation-side quantizer: the LUT product consumes
                // *coded* inputs, so the site's ActQuant is not applied
                // on top (encoding happens inside the GEMV) — it is
                // calibrated here from the same taps the nested
                // ActQuant would use, with the wider activation margin
                let act_blocks = Self::norm_act_blocks(stats);
                let abetas = if act_blocks.is_empty() {
                    wq.betas.clone()
                } else {
                    select_betas_for_data(
                        &flat,
                        &act_blocks,
                        apol.k.min(4),
                        4.0 / flat_q as f32,
                    )
                };
                let aq = HierarchicalQuantizer::new(wpol.q, m as usize, abetas);
                let qm = wq.quantize_matrix(&wrot);
                assert!(
                    PackedLutMatrix::supports(&wq, qm.cols),
                    "{}: plan validation admitted an unserveable LUT site",
                    site.label()
                );
                let lut = PackedLutMatrix::from_quantized(&qm, &wq, aq);
                let bits = lut.bits_per_entry();
                QLinear {
                    site,
                    policy: *wpol,
                    out_features: qm.rows,
                    in_features: wm.cols,
                    wt_deq: None,
                    packed: None,
                    lut: Some(lut),
                    rot,
                    act: ActQuant::None,
                    coded: None,
                    bits_zstd: bits,
                    bits_packed: bits,
                }
            }
            Method::NestQuant | Method::NestQuantM => {
                let m_variant = wpol.method == Method::NestQuantM;
                let codec = wpol.method.codec(wpol.q);
                let h = hessian_from_activations(&stats.acts, 0.01);
                let margin = 3.0 / wpol.q as f32;
                // Appendix B: QA-LDLQ exists to fix *pathological* layers
                // (amplification ratio ≫ 1, e.g. ≈157 for Llama-3-70B
                // block-0 v_proj). On benign layers the W̃ bias costs more
                // than the robustness buys, so apply it selectively.
                let needs_qa = wpol.qa_ldlq
                    && apol.quantize
                    && crate::quant::qaldlq::amplification_ratio(&wrot, &stats.acts, seed)
                        > 5.0;
                let (qm, nq) = if wpol.ldlq {
                    if needs_qa {
                        // QA-LDLQ with DP βs: modify W then run adaptive LDLQ.
                        // ε² = measured per-coordinate MSE of this site's
                        // activation quantizer (auto) or the fixed option.
                        let eps2 = if wpol.auto_eps2 {
                            Self::estimate_act_noise(stats, &act, wpol.eps2, apol.uniform_bits)
                        } else {
                            wpol.eps2
                        };
                        let wt = crate::quant::qaldlq::modified_weight(&wrot, &h, eps2);
                        let mut hj = h.clone();
                        hj.add_diag(eps2);
                        crate::quant::ldlq::ldlq_quantize_adaptive(
                            &wt, &hj, wpol.q, wpol.k, margin, m_variant,
                        )
                    } else {
                        crate::quant::ldlq::ldlq_quantize_adaptive(
                            &wrot, &h, wpol.q, wpol.k, margin, m_variant,
                        )
                    }
                } else {
                    // direct Algorithm-3 quantization with DP βs on raw rows
                    let blocks = Self::row_blocks(&wrot);
                    let betas = select_betas_for_data(&codec, &blocks, wpol.k, margin);
                    let nq = NestedLatticeQuantizer::with_codec(
                        codec.clone(),
                        betas,
                        Strategy::OptBeta,
                    );
                    (QuantizedMatrix::quantize(&wrot, &nq), nq)
                };
                // integer GEMM backend: pack the LDLQ-chosen codes as-is
                // (no re-quantization) whenever the M-variant decode
                // oracle applies — forward then never touches fp32
                // weights (the Table 4 runtime claim, wired end-to-end)
                let packed = (wpol.int_gemm && PackedNestMatrix::supports(&nq, qm.cols))
                    .then(|| PackedNestMatrix::from_quantized(&qm, &nq));
                // fp32 fallback only materialized when the integer
                // backend doesn't serve this site
                let wt_deq = packed
                    .is_none()
                    .then(|| qm.dequantize(&nq).transpose());
                // bits accounting (Tables 1/3 columns) — at the rate the
                // codes were actually produced with (recorded in `qm`)
                let n_entries = qm.rows * qm.cols;
                let bz = crate::io::sideinfo::bits_per_entry(
                    qm.q,
                    n_entries,
                    crate::io::sideinfo::beta_bits_zstd(&qm.beta_idx),
                    qm.scales.len(),
                );
                let bp = crate::io::sideinfo::bits_per_entry(
                    qm.q,
                    n_entries,
                    crate::io::sideinfo::beta_bits_packed(&qm.beta_idx, nq.k()),
                    qm.scales.len(),
                );
                QLinear {
                    site,
                    policy: *wpol,
                    out_features: qm.rows,
                    in_features: wm.cols,
                    wt_deq,
                    packed,
                    lut: None,
                    rot,
                    act,
                    coded: Some((qm, nq)),
                    bits_zstd: bz,
                    bits_packed: bp,
                }
            }
        }
    }

    /// The site's resolved activation quantizer: nested (calibrated over
    /// the site's rotated activation blocks), uniform fake-quant for the
    /// baseline methods, or none.
    fn act_quant(stats: &SiteStats, apol: &SitePolicy) -> ActQuant {
        if !apol.quantize {
            return ActQuant::None;
        }
        if !apol.method.is_nested() {
            return ActQuant::Uniform(apol.uniform_bits);
        }
        // normalize activation rows like Algorithm 3 will, then DP-select β
        let blocks = Self::norm_act_blocks(stats);
        if blocks.is_empty() {
            return ActQuant::None;
        }
        let codec = apol.method.codec(apol.q);
        let betas = select_betas_for_data(&codec, &blocks, apol.k, 4.0 / apol.q as f32);
        ActQuant::Nested(NestedLatticeQuantizer::with_codec(
            codec,
            betas,
            Strategy::OptBeta,
        ))
    }

    /// Normalized 8-blocks of a site's calibration activations (rows
    /// normalized ×√n/‖·‖₂ like Algorithm 3 will at runtime) — the β-DP
    /// input shared by the nested `ActQuant` and the LUT backend's
    /// activation-side quantizer.
    fn norm_act_blocks(stats: &SiteStats) -> Vec<[f32; D]> {
        let mut blocks: Vec<[f32; D]> = Vec::new();
        for t in 0..stats.acts.rows.min(64) {
            let row = stats.acts.row(t);
            let s = crate::util::stats::norm2(row) as f32;
            if s == 0.0 {
                continue;
            }
            let norm = (row.len() as f32).sqrt() / s;
            for ch in row.chunks_exact(D) {
                let mut b = [0f32; D];
                for i in 0..D {
                    b[i] = ch[i] * norm;
                }
                blocks.push(b);
            }
        }
        blocks
    }

    /// Measured activation-quantizer noise: mean per-coordinate roundtrip
    /// MSE over calibration rows (the ε² of Lemma 4.2's J = ε²I).
    fn estimate_act_noise(
        stats: &SiteStats,
        act: &ActQuant,
        fallback_eps2: f32,
        uniform_bits: u32,
    ) -> f32 {
        let rows = stats.acts.rows.min(32);
        if rows == 0 {
            return fallback_eps2;
        }
        let mut acc = 0f64;
        let mut n = 0usize;
        for t in 0..rows {
            let row = stats.acts.row(t);
            let rt = match act {
                ActQuant::Nested(nq) => nq.roundtrip(row),
                ActQuant::Uniform(bits) => UniformQuantizer::new(*bits).roundtrip(row),
                ActQuant::None => UniformQuantizer::new(uniform_bits).roundtrip(row),
            };
            acc += crate::util::stats::mse(row, &rt) * row.len() as f64;
            n += row.len();
        }
        ((acc / n.max(1) as f64) as f32).max(1e-8)
    }

    /// Uniform-grid LDLQ (the GPTQ baseline): scalar feedback, per-row Δ.
    fn uniform_ldlq(w: &Mat, h: &Mat, bits: u32) -> Mat {
        let (l, _) = crate::util::linalg::ldl(h);
        let lvl = 1i32 << (bits - 1);
        let n = w.cols;
        let mut out = Mat::zeros(w.rows, n);
        for r in 0..w.rows {
            let row = w.row(r);
            let maxabs = row.iter().fold(0f32, |m, &v| m.max(v.abs()));
            if maxabs == 0.0 {
                continue;
            }
            let delta = maxabs / lvl as f32;
            let mut e = vec![0f32; n];
            for j in (0..n).rev() {
                let mut f = 0f32;
                for i in j + 1..n {
                    f += e[i] * l[(i, j)];
                }
                let adj = row[j] + f;
                let qv = ((adj / delta).round() as i32).clamp(-lvl, lvl - 1) as f32 * delta;
                out[(r, j)] = qv;
                e[j] = row[j] - qv;
            }
        }
        out
    }

    fn row_blocks(w: &Mat) -> Vec<[f32; D]> {
        let mut out = Vec::with_capacity(w.rows * w.cols / D);
        for r in 0..w.rows {
            let row = w.row(r);
            let s = crate::util::stats::norm2(row) as f32;
            if s == 0.0 {
                continue;
            }
            let norm = (w.cols as f32).sqrt() / s;
            for ch in row.chunks_exact(D) {
                let mut b = [0f32; D];
                for i in 0..D {
                    b[i] = ch[i] * norm;
                }
                out.push(b);
            }
        }
        out
    }

    /// Calibration: fp forward over calib windows, tapping each input
    /// site's raw activations and (for layers whose KV policy wants a
    /// nested quantizer) the per-head rotated K/V blocks.
    fn calibrate(
        w: &ModelWeights,
        head_rots: &[Option<Rotation>],
        kv_tap: &[bool],
        calib_windows: usize,
    ) -> CalibData {
        let cfg = w.cfg;
        let win = cfg.ctx;
        let windows: Vec<&[i32]> = w
            .calib_tokens
            .chunks_exact(win + 1)
            .take(calib_windows)
            .collect();
        let n_samples = windows.len() * win;
        let mut sites: Vec<Vec<SiteStats>> = (0..cfg.n_layer)
            .map(|_| {
                vec![
                    SiteStats { acts: Mat::zeros(n_samples, cfg.d_model) },
                    SiteStats { acts: Mat::zeros(n_samples, cfg.d_model) },
                    SiteStats { acts: Mat::zeros(n_samples, cfg.d_model) },
                    SiteStats { acts: Mat::zeros(n_samples, cfg.d_ff) },
                ]
            })
            .collect();
        let mut head_in = SiteStats {
            acts: Mat::zeros(n_samples, cfg.d_model),
        };
        let mut k_blocks: Vec<Vec<[f32; D]>> = vec![Vec::new(); cfg.n_layer];
        let mut v_blocks: Vec<Vec<[f32; D]>> = vec![Vec::new(); cfg.n_layer];

        let dh = cfg.d_head();
        for (wi, window) in windows.iter().enumerate() {
            let toks = &window[..win];
            let mut x = Mat::zeros(win, cfg.d_model);
            for (t, &tok) in toks.iter().enumerate() {
                let emb = w.tok_emb.row(tok as usize);
                let pos = w.pos_emb.row(t);
                for i in 0..cfg.d_model {
                    x[(t, i)] = emb[i] + pos[i];
                }
            }
            for (li, lw) in w.layers.iter().enumerate() {
                // attn_in site
                let mut normed = Mat::zeros(win, cfg.d_model);
                for t in 0..win {
                    rmsnorm(x.row(t), &lw.ln1, normed.row_mut(t));
                }
                Self::tap(&mut sites[li][0], &normed, wi * win);
                let att_in = normed.clone();
                // tap rotated per-head K/V blocks (normalized per
                // vector) — the projections are only needed here, so
                // layers without a nested KV policy skip both GEMMs
                if kv_tap[li] {
                    let k = crate::model::forward::linear(&att_in, &lw.wk);
                    let v = crate::model::forward::linear(&att_in, &lw.wv);
                    for t in 0..win {
                        for h in 0..cfg.n_head {
                            let mut kv = k.row(t)[h * dh..(h + 1) * dh].to_vec();
                            let mut vv = v.row(t)[h * dh..(h + 1) * dh].to_vec();
                            if let Some(r) = &head_rots[li] {
                                r.apply(&mut kv);
                                r.apply(&mut vv);
                            }
                            Self::push_norm_blocks(&mut k_blocks[li], &kv);
                            Self::push_norm_blocks(&mut v_blocks[li], &vv);
                        }
                    }
                }
                // fp attention to continue the forward
                let att = crate::model::forward::attention(&att_in, lw, cfg.n_head);
                for i in 0..x.data.len() {
                    x.data[i] += att.data[i];
                }
                // attn_out site taps the wo input (the concat head
                // outputs, recomputed without the wo projection)
                let wo_in = Self::attention_heads_only(&att_in, lw, cfg.n_head);
                Self::tap(&mut sites[li][1], &wo_in, wi * win);

                // MLP
                let mut normed2 = Mat::zeros(win, cfg.d_model);
                for t in 0..win {
                    rmsnorm(x.row(t), &lw.ln2, normed2.row_mut(t));
                }
                Self::tap(&mut sites[li][2], &normed2, wi * win);
                let mut hmid = crate::model::forward::linear(&normed2, &lw.w_up);
                for vv in hmid.data.iter_mut() {
                    *vv = gelu(*vv);
                }
                Self::tap(&mut sites[li][3], &hmid, wi * win);
                let down = crate::model::forward::linear(&hmid, &lw.w_down);
                for i in 0..x.data.len() {
                    x.data[i] += down.data[i];
                }
            }
            let mut fin = Mat::zeros(win, cfg.d_model);
            for t in 0..win {
                rmsnorm(x.row(t), &w.final_norm, fin.row_mut(t));
            }
            Self::tap(&mut head_in, &fin, wi * win);
        }
        CalibData {
            sites,
            head_in,
            k_blocks,
            v_blocks,
        }
    }

    /// Multi-head attention *without* the wo projection (per-head outputs
    /// concatenated) — the wo-input tap for calibration.
    fn attention_heads_only(x: &Mat, l: &crate::model::weights::LayerWeights, n_head: usize) -> Mat {
        let seq = x.rows;
        let d = x.cols;
        let dh = d / n_head;
        let q = crate::model::forward::linear(x, &l.wq);
        let k = crate::model::forward::linear(x, &l.wk);
        let v = crate::model::forward::linear(x, &l.wv);
        let mut out = Mat::zeros(seq, d);
        let scale = 1.0 / (dh as f32).sqrt();
        let mut scores = vec![0f32; seq];
        for h in 0..n_head {
            let off = h * dh;
            for t in 0..seq {
                let qrow = &q.row(t)[off..off + dh];
                for (s, sc) in scores.iter_mut().enumerate().take(t + 1) {
                    let krow = &k.row(s)[off..off + dh];
                    let mut acc = 0f32;
                    for i in 0..dh {
                        acc += qrow[i] * krow[i];
                    }
                    *sc = acc * scale;
                }
                softmax_inplace(&mut scores[..t + 1]);
                let orow = &mut out.row_mut(t)[off..off + dh];
                for s in 0..=t {
                    let p = scores[s];
                    let vrow = &v.row(s)[off..off + dh];
                    for i in 0..dh {
                        orow[i] += p * vrow[i];
                    }
                }
            }
        }
        out
    }

    fn tap(site: &mut SiteStats, acts: &Mat, row_off: usize) {
        for t in 0..acts.rows {
            site.acts
                .row_mut(row_off + t)
                .copy_from_slice(acts.row(t));
        }
    }

    fn push_norm_blocks(sink: &mut Vec<[f32; D]>, v: &[f32]) {
        let s = crate::util::stats::norm2(v) as f32;
        if s == 0.0 {
            return;
        }
        let norm = (v.len() as f32).sqrt() / s;
        for ch in v.chunks_exact(D) {
            let mut b = [0f32; D];
            for i in 0..D {
                b[i] = ch[i] * norm;
            }
            sink.push(b);
        }
    }

    // ---- quantized forward & evaluation ----

    /// Quantized attention over a full window.
    fn attention_q(&self, x: &Mat, l: &QLayer) -> Mat {
        let cfg = &self.cfg;
        let seq = x.rows;
        let d = cfg.d_model;
        let dh = cfg.d_head();
        let q = l.wq.forward(x);
        let mut k = l.wk.forward(x);
        let mut v = l.wv.forward(x);

        // KV-cache quantization (per position, per head, rotated basis)
        if !l.kv.is_fp() {
            for t in 0..seq {
                for h in 0..cfg.n_head {
                    let kr = &mut k.row_mut(t)[h * dh..(h + 1) * dh];
                    if let Some(r) = &l.head_rot {
                        r.apply(kr);
                    }
                    l.kv.roundtrip_key(kr);
                    let vr = &mut v.row_mut(t)[h * dh..(h + 1) * dh];
                    if let Some(r) = &l.head_rot {
                        r.apply(vr);
                    }
                    l.kv.roundtrip_value(vr);
                }
            }
        }
        // rotate queries to match keys (scores invariant)
        let mut qrot = q;
        if let Some(r) = &l.head_rot {
            for t in 0..seq {
                for h in 0..cfg.n_head {
                    r.apply(&mut qrot.row_mut(t)[h * dh..(h + 1) * dh]);
                }
            }
        }

        let mut out = Mat::zeros(seq, d);
        let scale = 1.0 / (dh as f32).sqrt();
        let mut scores = vec![0f32; seq];
        for h in 0..cfg.n_head {
            let off = h * dh;
            for t in 0..seq {
                let qrow = &qrot.row(t)[off..off + dh];
                for (s, sc) in scores.iter_mut().enumerate().take(t + 1) {
                    let krow = &k.row(s)[off..off + dh];
                    let mut acc = 0f32;
                    for i in 0..dh {
                        acc += qrow[i] * krow[i];
                    }
                    *sc = acc * scale;
                }
                softmax_inplace(&mut scores[..t + 1]);
                let orow = &mut out.row_mut(t)[off..off + dh];
                for s in 0..=t {
                    let p = scores[s];
                    let vrow = &v.row(s)[off..off + dh];
                    for i in 0..dh {
                        orow[i] += p * vrow[i];
                    }
                }
            }
        }
        // un-rotate attention output per head (values were rotated)
        if let Some(r) = &l.head_rot {
            for t in 0..seq {
                for h in 0..cfg.n_head {
                    r.apply_t(&mut out.row_mut(t)[h * dh..(h + 1) * dh]);
                }
            }
        }
        l.wo.forward(&out)
    }

    /// Quantized full-window forward → logits (seq, vocab).
    pub fn forward_window(&self, tokens: &[i32]) -> Mat {
        let cfg = &self.cfg;
        let seq = tokens.len();
        let d = cfg.d_model;
        let mut x = Mat::zeros(seq, d);
        for (t, &tok) in tokens.iter().enumerate() {
            let emb = self.tok_emb.row(tok as usize);
            let pos = self.pos_emb.row(t);
            for i in 0..d {
                x[(t, i)] = emb[i] + pos[i];
            }
        }
        let mut normed = Mat::zeros(seq, d);
        for l in &self.layers {
            for t in 0..seq {
                rmsnorm(x.row(t), &l.ln1, normed.row_mut(t));
            }
            let att = self.attention_q(&normed, l);
            for i in 0..x.data.len() {
                x.data[i] += att.data[i];
            }
            for t in 0..seq {
                rmsnorm(x.row(t), &l.ln2, normed.row_mut(t));
            }
            let mut h = l.w_up.forward(&normed);
            for v in h.data.iter_mut() {
                *v = gelu(*v);
            }
            let down = l.w_down.forward(&h);
            for i in 0..x.data.len() {
                x.data[i] += down.data[i];
            }
        }
        for t in 0..seq {
            rmsnorm(x.row(t), &self.final_norm, normed.row_mut(t));
        }
        self.head.forward(&normed)
    }

    /// One fused decode step over `n` live sessions: gather every
    /// session's current token into one (n, d) activation panel, run
    /// each linear once over the whole panel (the packed integer GEMM at
    /// n>1, the GEMV at n=1), score attention per session against its
    /// own coded cache, and leave the next-token logits for session `s`
    /// in `logits.row(s)`.
    ///
    /// Bitwise-identical to stepping each session alone (the propcheck
    /// harness in `coordinator::generator` pins this): every fused op is
    /// row-independent — `gemm_into` is decode-for-decode identical to
    /// `gemv_into` (proven in `quant::gemm`), the fp fallback matmul,
    /// rotations, rmsnorm and the activation fake-quant all work row by
    /// row, and attention touches only the session's own cache.
    ///
    /// Allocation-free after warmup away from page boundaries: all
    /// staging lives in `scratch`/`logits` and the caches code each
    /// append through their own reusable buffers (`kvpool`). Page
    /// boundary events (fresh page claims, prefix-index publication)
    /// still allocate.
    pub fn forward_step_fused(
        &self,
        tokens: &[i32],
        positions: &[usize],
        caches: &mut [&mut SessionKv],
        scratch: &mut StepScratch,
        logits: &mut Mat,
    ) {
        self.forward_step_fused_traced(tokens, positions, caches, scratch, logits, None)
    }

    /// [`Self::forward_step_fused`] with optional per-site GEMM timing:
    /// `Some(trace)` records one `SiteGemm` span per (layer, linear) —
    /// wq/wk/wv/wo/w_up/w_down per layer plus the lm_head (reported with
    /// `layer = n_layer`) — on the engine track. The timing reads are
    /// two clock calls per span and the ring push never allocates, so
    /// the traced step stays allocation-free; callers that sample (the
    /// serving loop) pass `None` on unsampled steps, which compiles down
    /// to the untraced path.
    pub fn forward_step_fused_traced(
        &self,
        tokens: &[i32],
        positions: &[usize],
        caches: &mut [&mut SessionKv],
        scratch: &mut StepScratch,
        logits: &mut Mat,
        trace: Option<&Trace>,
    ) {
        #[inline]
        fn gemm_span(
            trace: Option<&Trace>,
            layer: u16,
            site: SiteTag,
            backend: GemmPath,
            start: Option<u64>,
        ) {
            if let (Some(tr), Some(t0)) = (trace, start) {
                tr.span(
                    TRACK_ENGINE,
                    EventKind::SiteGemm {
                        layer,
                        site,
                        backend,
                        kernel: crate::quant::kernels::active(),
                    },
                    t0,
                );
            }
        }
        let n = tokens.len();
        assert_eq!(positions.len(), n, "one position per token");
        assert_eq!(caches.len(), n, "one cache per token");
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let dh = cfg.d_head();
        logits.rows = n;
        logits.cols = cfg.vocab;
        logits.data.clear();
        logits.data.resize(n * cfg.vocab, 0.0);
        if n == 0 {
            return;
        }
        for &p in positions {
            assert!(p < cfg.ctx, "context overflow");
        }
        // before any cache mutation: a contained fault here leaves every
        // session's KV state exactly as it was before the step
        crate::fail_point!("engine/step_fused");
        scratch.kh.clear();
        scratch.kh.resize(dh, 0.0);
        scratch.vh.clear();
        scratch.vh.resize(dh, 0.0);
        scratch.qh.clear();
        scratch.qh.resize(dh, 0.0);
        // pin score capacity to the context length once so the per-head
        // score fills never reallocate mid-decode
        scratch.scores.clear();
        scratch.scores.reserve(cfg.ctx);

        embed_into(&self.tok_emb, &self.pos_emb, tokens, positions, &mut scratch.x);
        for (li, l) in self.layers.iter().enumerate() {
            let lt = li as u16;
            rmsnorm_rows(&scratch.x, &l.ln1, &mut scratch.normed);
            let t0 = trace.map(Trace::now);
            l.wq.forward_into(&scratch.normed, &mut scratch.q, &mut scratch.lin, 1);
            gemm_span(trace, lt, SiteTag::Q, l.wq.gemm_path(), t0);
            let t0 = trace.map(Trace::now);
            l.wk.forward_into(&scratch.normed, &mut scratch.k, &mut scratch.lin, 1);
            gemm_span(trace, lt, SiteTag::K, l.wk.gemm_path(), t0);
            let t0 = trace.map(Trace::now);
            l.wv.forward_into(&scratch.normed, &mut scratch.v, &mut scratch.lin, 1);
            gemm_span(trace, lt, SiteTag::V, l.wv.gemm_path(), t0);
            reshape(&mut scratch.att, n, d);
            for (s, cache) in caches.iter_mut().enumerate() {
                for h in 0..cfg.n_head {
                    scratch
                        .kh
                        .copy_from_slice(&scratch.k.row(s)[h * dh..(h + 1) * dh]);
                    scratch
                        .vh
                        .copy_from_slice(&scratch.v.row(s)[h * dh..(h + 1) * dh]);
                    scratch
                        .qh
                        .copy_from_slice(&scratch.q.row(s)[h * dh..(h + 1) * dh]);
                    if let Some(r) = &l.head_rot {
                        r.apply(&mut scratch.kh);
                        r.apply(&mut scratch.vh);
                        r.apply(&mut scratch.qh);
                    }
                    cache.append(li, h, &scratch.kh, &scratch.vh);
                    cache.scores(li, h, &scratch.qh, &mut scratch.scores);
                    let scale = 1.0 / (dh as f32).sqrt();
                    for v in scratch.scores.iter_mut() {
                        *v *= scale;
                    }
                    softmax_inplace(&mut scratch.scores);
                    let oh = &mut scratch.att.row_mut(s)[h * dh..(h + 1) * dh];
                    cache.weighted_value_sum(li, h, &scratch.scores, oh);
                    if let Some(r) = &l.head_rot {
                        r.apply_t(oh);
                    }
                }
            }
            let t0 = trace.map(Trace::now);
            l.wo.forward_into(&scratch.att, &mut scratch.proj, &mut scratch.lin, 1);
            gemm_span(trace, lt, SiteTag::O, l.wo.gemm_path(), t0);
            for (xv, &pv) in scratch.x.data.iter_mut().zip(scratch.proj.data.iter()) {
                *xv += pv;
            }
            rmsnorm_rows(&scratch.x, &l.ln2, &mut scratch.normed);
            let t0 = trace.map(Trace::now);
            l.w_up
                .forward_into(&scratch.normed, &mut scratch.hmid, &mut scratch.lin, 1);
            gemm_span(trace, lt, SiteTag::Up, l.w_up.gemm_path(), t0);
            for v in scratch.hmid.data.iter_mut() {
                *v = gelu(*v);
            }
            let t0 = trace.map(Trace::now);
            l.w_down
                .forward_into(&scratch.hmid, &mut scratch.proj, &mut scratch.lin, 1);
            gemm_span(trace, lt, SiteTag::Down, l.w_down.gemm_path(), t0);
            for (xv, &pv) in scratch.x.data.iter_mut().zip(scratch.proj.data.iter()) {
                *xv += pv;
            }
        }
        // positions are complete on every (layer, head) lane: publish
        // them (freezes + registers pages at page boundaries)
        for (cache, &t) in caches.iter_mut().zip(tokens.iter()) {
            cache.note_token(t);
        }
        rmsnorm_rows(&scratch.x, &self.final_norm, &mut scratch.normed);
        let t0 = trace.map(Trace::now);
        self.head.forward_into(&scratch.normed, logits, &mut scratch.lin, 1);
        gemm_span(
            trace,
            self.layers.len() as u16,
            SiteTag::Head,
            self.head.gemm_path(),
            t0,
        );
    }

    /// Perplexity over non-overlapping windows.
    pub fn eval_ppl(&self, tokens: &[i32], max_windows: usize) -> f64 {
        let win = self.cfg.ctx;
        let mut total = 0f64;
        let mut count = 0usize;
        for chunk in tokens.chunks_exact(win + 1).take(max_windows) {
            let logits = self.forward_window(&chunk[..win]);
            total += window_nll(&logits, &chunk[1..]);
            count += 1;
        }
        (total / count.max(1) as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::{artifact_path, ModelWeights};
    use crate::quant::plan::{EngineBuilder, PolicyPatch, SiteSelector};

    fn load_tiny() -> Option<ModelWeights> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let p = artifact_path(&dir, "tiny");
        p.exists().then(|| ModelWeights::load(&p).unwrap())
    }

    #[test]
    fn cli_names_roundtrip_through_parse() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.cli_name()), Some(m));
        }
        for r in Regime::ALL {
            assert_eq!(Regime::parse(r.cli_name()), Some(r));
        }
        for k in RotKind::ALL {
            assert_eq!(RotKind::parse(k.cli_name()), Some(k));
        }
        assert_eq!(Method::parse("float8"), None);
        assert_eq!(Regime::parse("all"), None);
    }

    #[test]
    fn fp_regime_matches_native_forward() {
        let Some(w) = load_tiny() else { return };
        let eng = Engine::build(
            &w,
            EngineOptions {
                regime: Regime::Fp,
                ..Default::default()
            },
        );
        let toks: Vec<i32> = w.val_tokens[..32].to_vec();
        let a = eng.forward_window(&toks);
        let b = crate::model::forward::forward_window(&w, &toks);
        for i in 0..a.data.len() {
            assert!(
                (a.data[i] - b.data[i]).abs() < 1e-3,
                "engine fp path diverges at {i}: {} vs {}",
                a.data[i],
                b.data[i]
            );
        }
    }

    #[test]
    fn forward_into_matches_forward_with_dirty_scratch() {
        // one LinScratch serving sites of different widths back to back
        // (the fused-step usage) must reproduce `forward` bit for bit on
        // the GEMV (rows=1), small-GEMM and threaded-GEMM paths, for
        // packed, fp and act-quantized sites alike
        let cfg = crate::model::ModelConfig {
            vocab: 48,
            ctx: 96,
            d_model: 32,
            n_layer: 2,
            n_head: 2,
            d_ff: 64,
        };
        let w = ModelWeights::synthetic(cfg, 0x51EF);
        for (method, regime) in [
            (Method::NestQuantM, Regime::WKvA),
            (Method::Rtn, Regime::WKvA),
            (Method::NestQuantM, Regime::W),
        ] {
            let eng = Engine::build(
                &w,
                EngineOptions {
                    method,
                    regime,
                    calib_windows: 1,
                    ..Default::default()
                },
            );
            let mut rng = Rng::new(0xF00D);
            let mut s = LinScratch::new();
            for rows in [1usize, 3, 17] {
                for lin in [&eng.layers[0].wq, &eng.layers[1].w_down, &eng.head] {
                    let x = Mat {
                        rows,
                        cols: lin.in_features,
                        data: (0..rows * lin.in_features).map(|_| rng.f32() - 0.5).collect(),
                    };
                    let y_ref = lin.forward(&x);
                    let mut y = Mat::zeros(0, 0);
                    let threads = if rows >= 16 { 0 } else { 1 };
                    lin.forward_into(&x, &mut y, &mut s, threads);
                    assert_eq!((y.rows, y.cols), (rows, lin.out_features));
                    for (i, (a, b)) in y.data.iter().zip(y_ref.data.iter()).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{:?} {regime:?} rows={rows} out {i}: {a} vs {b}",
                            lin.site
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn quantized_ppl_close_to_fp() {
        let Some(w) = load_tiny() else { return };
        let fp_ppl = crate::model::forward::eval_ppl(&w, &w.val_tokens, 6);
        let eng = Engine::build(
            &w,
            EngineOptions {
                method: Method::NestQuant,
                regime: Regime::W,
                calib_windows: 2,
                ..Default::default()
            },
        );
        let qppl = eng.eval_ppl(&w.val_tokens, 6);
        assert!(
            qppl < fp_ppl * 1.25,
            "W-only NestQuant ppl {qppl} too far above fp {fp_ppl}"
        );
        assert!(qppl > fp_ppl * 0.8, "suspiciously better than fp: {qppl} vs {fp_ppl}");
    }

    #[test]
    fn full_quant_ranks_methods_correctly() {
        let Some(w) = load_tiny() else { return };
        let mut ppls = std::collections::HashMap::new();
        for method in [Method::Rtn, Method::NestQuant] {
            let eng = Engine::build(
                &w,
                EngineOptions {
                    method,
                    regime: Regime::WKvA,
                    calib_windows: 2,
                    ..Default::default()
                },
            );
            ppls.insert(method.label(), eng.eval_ppl(&w.val_tokens, 4));
        }
        let nest = ppls["NestQuant"];
        let rtn = ppls["RTN (uniform)"];
        assert!(
            nest < rtn,
            "NestQuant {nest} should beat plain RTN {rtn} at 4 bits"
        );
    }

    /// A synthetic random tiny model, so the integer-backend tests run
    /// without the trained artifact (which the `load_tiny` tests skip on).
    fn synth_weights() -> ModelWeights {
        ModelWeights::synthetic(
            crate::model::ModelConfig {
                vocab: 48,
                ctx: 16,
                d_model: 32,
                n_layer: 1,
                n_head: 2,
                d_ff: 64,
            },
            0xBEEF,
        )
    }

    fn synth_weights_2l() -> ModelWeights {
        ModelWeights::synthetic(
            crate::model::ModelConfig {
                vocab: 48,
                ctx: 16,
                d_model: 32,
                n_layer: 2,
                n_head: 2,
                d_ff: 64,
            },
            0xBEE2,
        )
    }

    #[test]
    fn m_variant_engine_runs_integer_gemm_path() {
        // end-to-end: a NestQuantM engine must carry the packed integer
        // backend on every nested linear, and its prefill forward (which
        // routes through PackedNestMatrix::gemm_into) must agree with the
        // fake-quant fp32 path on the identical codes.
        let w = synth_weights();
        let base = EngineOptions {
            method: Method::NestQuantM,
            regime: Regime::W,
            calib_windows: 1,
            ..Default::default()
        };
        let int_eng = Engine::build(&w, base.clone());
        for l in &int_eng.layers {
            for lin in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w_up, &l.w_down] {
                assert!(lin.packed.is_some(), "integer backend missing on a linear");
            }
        }
        assert!(int_eng.head.packed.is_some(), "integer backend missing on head");
        let fake_eng = Engine::build(
            &w,
            EngineOptions {
                int_gemm: false,
                ..base
            },
        );
        assert!(fake_eng.layers[0].wq.packed.is_none());
        let toks: Vec<i32> = w.val_tokens[..12].to_vec();
        let a = int_eng.forward_window(&toks);
        let b = fake_eng.forward_window(&toks);
        assert_eq!(a.data.len(), b.data.len());
        for i in 0..a.data.len() {
            assert!(
                (a.data[i] - b.data[i]).abs() < 1e-2 * (1.0 + b.data[i].abs()),
                "integer vs fake-quant logits diverge at {i}: {} vs {}",
                a.data[i],
                b.data[i]
            );
        }
    }

    #[test]
    fn non_m_methods_do_not_get_integer_backend() {
        // the packed decode oracle is NestQuantM-specific; plain NestQuant
        // and the uniform baselines must stay on the fp32 path.
        let w = synth_weights();
        for method in [Method::NestQuant, Method::Rtn] {
            let eng = Engine::build(
                &w,
                EngineOptions {
                    method,
                    regime: Regime::W,
                    calib_windows: 1,
                    ..Default::default()
                },
            );
            assert!(
                eng.layers[0].wq.packed.is_none(),
                "{:?} must not use the M-variant integer backend",
                method
            );
        }
    }

    #[test]
    fn uniform_plan_is_bitwise_equal_to_options_path() {
        // the compat contract: Engine::build(w, opts) and
        // Engine::build_plan(w, QuantPlan::uniform(opts)) construct the
        // same engine, logit-bitwise, across methods and regimes.
        //
        // Scope honestly stated: Engine::build IS the shim today, so
        // this guards the entry points staying in lock-step (e.g. a
        // future fast path re-added to build), NOT equality with the
        // deleted pre-redesign code — that argument is the reviewed
        // construction trace (rotation draw order, raw-tap-then-rotate
        // basis, per-role regime lowering; EXPERIMENTS §Mixed-precision)
        // plus the behavior tests written against the old engine that
        // still run on this path (fp_regime_matches_native_forward
        // cross-checks an independent forward, quantized_ppl_close_to_fp,
        // bits_accounting_close_to_4, the m_variant suite).
        let w = synth_weights();
        for opts in [
            EngineOptions {
                method: Method::NestQuantM,
                regime: Regime::W,
                calib_windows: 1,
                ..Default::default()
            },
            EngineOptions {
                method: Method::Rtn,
                regime: Regime::WKvA,
                calib_windows: 1,
                ..Default::default()
            },
            EngineOptions {
                method: Method::NestQuant,
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
            EngineOptions {
                regime: Regime::Fp,
                ..Default::default()
            },
        ] {
            let a = Engine::build(&w, opts.clone());
            let b = Engine::build_plan(&w, QuantPlan::uniform(opts.clone()));
            let toks: Vec<i32> = w.val_tokens[..12].to_vec();
            let la = a.forward_window(&toks);
            let lb = b.forward_window(&toks);
            assert_eq!(la.data.len(), lb.data.len());
            for i in 0..la.data.len() {
                assert_eq!(
                    la.data[i].to_bits(),
                    lb.data[i].to_bits(),
                    "{:?}/{:?}: uniform plan diverges from options path at {i}",
                    opts.method,
                    opts.regime
                );
            }
            assert_eq!(a.weight_bits_packed, b.weight_bits_packed);
        }
    }

    #[test]
    fn mixed_plan_fp_head_and_per_site_rates() {
        // the acceptance plan: fp lm_head, q=16 down, q=12 elsewhere —
        // must build, generate, and report per-site payloads.
        let w = synth_weights();
        let eng = EngineBuilder::from_options(EngineOptions {
            method: Method::NestQuantM,
            regime: Regime::W,
            q: 12,
            calib_windows: 1,
            ..Default::default()
        })
        .site(SiteKind::Down, PolicyPatch::rate(16))
        .site(SiteKind::LmHead, PolicyPatch::fp())
        .build(&w);

        // head is exactly fp: untouched weights, no coded payload
        assert!(eng.head.coded.is_none() && eng.head.packed.is_none());
        assert_eq!(eng.head.bits_zstd, 0.0);
        let wt = eng.head.wt_deq.as_ref().expect("fp head keeps wt_deq");
        assert_eq!(wt.data, w.head.transpose().data, "fp head must be exact");
        // per-site rates recorded in the coded payloads
        assert_eq!(eng.layers[0].w_down.coded.as_ref().unwrap().0.q, 16);
        assert_eq!(eng.layers[0].wq.coded.as_ref().unwrap().0.q, 12);
        assert_eq!(eng.layers[0].w_down.policy.q, 16);
        // generates through the incremental path
        let mut sess = crate::coordinator::generator::GenSession::new(&eng);
        let out = sess.generate(&w.val_tokens[..4].to_vec(), 8);
        assert_eq!(out.len(), 8);
        // per-site payload accounting
        let sp = eng.site_payloads();
        assert_eq!(sp.len(), 6 * w.cfg.n_layer + 1);
        let head = sp.last().unwrap();
        assert!(!head.quantized);
        assert!((head.bits_per_entry - 32.0).abs() < 1e-9, "fp head is 32 b/entry");
        let down = sp.iter().find(|s| s.site.kind == SiteKind::Down).unwrap();
        assert!(down.quantized && down.bits_per_entry < 8.0, "{down:?}");
        // q=12 and q=16 both pack codes at ⌈log2 q⌉ = 4 bits: every
        // layer site of the split costs exactly the bytes of its
        // uniform-q14 counterpart (the equal-payload rate-split claim)
        let uniform = EngineBuilder::from_options(EngineOptions {
            method: Method::NestQuantM,
            regime: Regime::W,
            q: 14,
            calib_windows: 1,
            ..Default::default()
        })
        .build(&w);
        let usp = uniform.site_payloads();
        for (a, b) in sp.iter().zip(&usp) {
            if a.site.kind != SiteKind::LmHead {
                assert_eq!(a.bytes, b.bytes, "split {} differs from uniform", a.site.label());
            }
        }
    }

    #[test]
    fn per_layer_kv_rates_flow_into_pool() {
        let w = synth_weights_2l();
        let eng = EngineBuilder::from_options(EngineOptions {
            method: Method::NestQuantM,
            regime: Regime::WKv,
            calib_windows: 1,
            ..Default::default()
        })
        .rule(
            SiteSelector {
                layers: Some((0, 0)),
                role: Some(SiteRole::Kv),
                ..Default::default()
            },
            PolicyPatch::rate(16),
        )
        .build(&w);
        match &eng.layers[0].kv {
            KvLaneCodec::Nested { k, .. } => assert_eq!(k.q(), 16),
            _ => panic!("layer 0 must carry a nested KV pair"),
        }
        let pool = eng.kv_pool(PoolConfig::default());
        match pool.lane(0) {
            KvLaneCodec::Nested { k, v } => {
                assert_eq!(k.q(), 16);
                assert_eq!(v.q(), 16);
            }
            other => panic!("layer 0 lane must be nested, got {other:?}"),
        }
        match pool.lane(1) {
            KvLaneCodec::Nested { k, .. } => assert_eq!(k.q(), 14),
            other => panic!("layer 1 lane must be nested, got {other:?}"),
        }
    }

    #[test]
    fn mixed_kv_plan_builds_heterogeneous_pool() {
        // a layer with fp KV becomes an fp32 lane in the shared pool —
        // the pool is total over plans, no per-session fp fallback
        let w = synth_weights_2l();
        let eng = EngineBuilder::from_options(EngineOptions {
            method: Method::NestQuantM,
            regime: Regime::WKv,
            calib_windows: 1,
            ..Default::default()
        })
        .rule(
            SiteSelector {
                layers: Some((1, 1)),
                role: Some(SiteRole::Kv),
                ..Default::default()
            },
            PolicyPatch::fp(),
        )
        .build(&w);
        assert!(!eng.layers[0].kv.is_fp());
        assert!(eng.layers[1].kv.is_fp());
        let pool = eng.kv_pool(PoolConfig::default());
        assert!(matches!(pool.lane(0), KvLaneCodec::Nested { .. }));
        assert!(pool.lane(1).is_fp());
        // and the mixed pool generates end-to-end
        let mut sess = crate::coordinator::generator::GenSession::new_in_pool(&eng, &pool);
        let out = sess.generate(&w.val_tokens[..4].to_vec(), 6);
        assert_eq!(out.len(), 6);
        let st = pool.stats();
        assert!(st.page_bytes_fp > 0 && st.page_bytes_nested > 0, "{st:?}");
    }

    #[test]
    fn integer_backend_ppl_matches_fake_quant_on_tiny() {
        // same codes, two execution backends: perplexities must agree to
        // float-accumulation tolerance on the trained tiny artifact.
        let Some(w) = load_tiny() else { return };
        let base = EngineOptions {
            method: Method::NestQuantM,
            regime: Regime::W,
            calib_windows: 2,
            ..Default::default()
        };
        let int_ppl = Engine::build(&w, base.clone()).eval_ppl(&w.val_tokens, 4);
        let fake_ppl = Engine::build(
            &w,
            EngineOptions {
                int_gemm: false,
                ..base
            },
        )
        .eval_ppl(&w.val_tokens, 4);
        assert!(
            (int_ppl / fake_ppl - 1.0).abs() < 0.02,
            "integer-backend ppl {int_ppl} vs fake-quant ppl {fake_ppl}"
        );
    }

    #[test]
    fn lut_backend_engine_serves_weight_sites_end_to_end() {
        // the LUT acceptance path: every weight site carries the LUT
        // backend and nothing else (never-materialize: no packed, no
        // wt_deq, no carrier codes), forward/forward_into agree bitwise
        // across the GEMV / GEMM / threaded shapes, logits track an
        // equal-rate decode-backend engine, payload accounting reports
        // the M·log2 q hierarchical rate, and the plan round-trips
        // through the .qplan text format.
        let w = synth_weights();
        let base = EngineOptions {
            method: Method::NestQuantM,
            regime: Regime::WKvA,
            q: 16,
            ldlq: false,
            qa_ldlq: false,
            calib_windows: 1,
            ..Default::default()
        };
        let lut_patch = PolicyPatch {
            backend: Some(GemmBackend::Lut),
            q: Some(2),
            m_levels: Some(4),
            ..Default::default()
        };
        let builder = EngineBuilder::from_options(base.clone()).rule(
            SiteSelector {
                role: Some(SiteRole::Weights),
                ..Default::default()
            },
            lut_patch,
        );
        let plan = builder.plan();
        // backend + m_levels survive the .qplan text format
        let back = QuantPlan::parse(&plan.render()).unwrap();
        assert_eq!(back, plan);
        let eng = Engine::build_plan(&w, plan);
        for l in &eng.layers {
            for lin in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w_up, &l.w_down] {
                assert!(lin.lut.is_some(), "LUT backend missing on {}", lin.site.label());
                assert!(
                    lin.packed.is_none() && lin.wt_deq.is_none() && lin.coded.is_none(),
                    "{} materialized a non-LUT representation",
                    lin.site.label()
                );
                assert_eq!(lin.gemm_path(), GemmPath::Lut);
                assert!(matches!(lin.act, ActQuant::None), "LUT sites encode inside the GEMV");
            }
        }
        assert!(eng.head.lut.is_some(), "LUT backend missing on head");

        // forward vs forward_into, bitwise, with one shared dirty
        // scratch — GEMV (rows=1), small GEMM, threaded GEMM
        let mut rng = Rng::new(0x117);
        let mut s = LinScratch::new();
        for rows in [1usize, 3, 17] {
            for lin in [&eng.layers[0].wq, &eng.layers[0].w_down, &eng.head] {
                let x = Mat {
                    rows,
                    cols: lin.in_features,
                    data: (0..rows * lin.in_features).map(|_| rng.f32() - 0.5).collect(),
                };
                let y_ref = lin.forward(&x);
                let mut y = Mat::zeros(0, 0);
                let threads = if rows >= 16 { 0 } else { 1 };
                lin.forward_into(&x, &mut y, &mut s, threads);
                assert_eq!((y.rows, y.cols), (rows, lin.out_features));
                for (i, (a, b)) in y.data.iter().zip(y_ref.data.iter()).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{} rows={rows} out {i}: {a} vs {b}",
                        lin.site.label()
                    );
                }
            }
        }

        // equal-rate cross-check: q=2, M=4 hierarchical codes reproduce
        // flat q=16 reconstructions except on (rare, DP-margin-guarded)
        // overloaded blocks, and the LUT activation encoder matches the
        // decode engine's nested ActQuant at the same rate — so logits
        // must track the decode-backend engine closely in aggregate
        let dec = Engine::build(&w, base);
        let toks: Vec<i32> = w.val_tokens[..12].to_vec();
        let a = eng.forward_window(&toks);
        let b = dec.forward_window(&toks);
        assert_eq!(a.data.len(), b.data.len());
        // near-exact elementwise (both paths reconstruct the same flat
        // codewords), with slack for isolated blocks where the flat and
        // telescoped overload regions disagree
        let (mut close, mut d2, mut n2) = (0usize, 0f64, 0f64);
        for i in 0..a.data.len() {
            let (av, bv) = (a.data[i] as f64, b.data[i] as f64);
            if (av - bv).abs() <= 1e-2 * (1.0 + bv.abs()) {
                close += 1;
            }
            d2 += (av - bv).powi(2);
            n2 += bv.powi(2);
        }
        let rel = (d2 / n2.max(1e-12)).sqrt();
        assert!(rel < 0.1, "LUT vs decode logits diverge: rel L2 {rel}");
        assert!(
            close * 20 >= a.data.len() * 19,
            "only {close}/{} logits match the decode backend",
            a.data.len()
        );

        // payload accounting: 4 bits/entry codes (M·log2 q) + β + scales
        for sp in eng.site_payloads() {
            assert!(sp.quantized, "{:?}", sp.site.label());
            assert!(
                sp.bits_per_entry > 4.0 && sp.bits_per_entry < 5.5,
                "{}: {} bits/entry",
                sp.site.label(),
                sp.bits_per_entry
            );
        }

        // generates through the fused incremental path (the same
        // forward_into both the solo and fused steps share)
        let mut sess = crate::coordinator::generator::GenSession::new(&eng);
        let out = sess.generate(&w.val_tokens[..4].to_vec(), 8);
        assert_eq!(out.len(), 8);
    }

    #[test]
    fn bits_accounting_close_to_4() {
        let Some(w) = load_tiny() else { return };
        let eng = Engine::build(
            &w,
            EngineOptions {
                method: Method::NestQuant,
                regime: Regime::W,
                calib_windows: 1,
                ..Default::default()
            },
        );
        assert!(
            eng.weight_bits_packed > 3.8 && eng.weight_bits_packed < 4.6,
            "packed bits {}",
            eng.weight_bits_packed
        );
        assert!(eng.weight_bits_zstd <= eng.weight_bits_packed + 1e-9);
    }
}
