//! The quantized inference engine — NestQuant (and the uniform baselines)
//! applied to a trained model in the paper's three regimes:
//!
//! * `W`      — weights only (§5.2 "W")
//! * `W+KV`   — weights + KV cache
//! * `W+KV+A` — weights + KV cache + activations (full quantization)
//!
//! Construction mirrors §4.6: (1) calibration forward passes collect
//! per-site activation statistics (Hessians for LDLQ, 8-blocks for the
//! β-selection DP, per-head K/V blocks); (2) weights are quantized with
//! (QA-)LDLQ and DP-chosen βs; (3) activation/KV quantizers get their own
//! DP βs; (4) evaluation runs the quantized forward (fake-quant semantics,
//! bit-exact with coded storage — `quant::matrix` tests prove the
//! equivalence), while the serving path (`kvcache`, `coordinator`) keeps
//! KV entries in coded form.

use crate::kvpool::{KvLayerQuant, KvPool, PoolConfig};
use crate::lattice::beta_dp::select_betas_for_data;
use crate::lattice::e8::D;
use crate::lattice::nested::{NestedLatticeQuantizer, Strategy};
use crate::lattice::voronoi::VoronoiCodec;
use crate::model::forward::{gelu, rmsnorm, softmax_inplace, window_nll};
use crate::model::weights::ModelWeights;
use crate::quant::gemm::GemmScratch;
use crate::quant::ldlq::hessian_from_activations;
use crate::quant::matrix::QuantizedMatrix;
use crate::quant::qgemm::PackedNestMatrix;
use crate::quant::uniform::UniformQuantizer;
use crate::rotation::Rotation;
use crate::util::linalg::{matmul_into, Mat};
use crate::util::Rng;
use std::sync::Arc;

/// Quantization regime (paper Tables 1–3 columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    /// no quantization (fp32 reference)
    Fp,
    /// weights only
    W,
    /// weights + KV cache
    WKv,
    /// weights + KV cache + activations
    WKvA,
}

impl Regime {
    pub fn quantizes_weights(self) -> bool {
        !matches!(self, Regime::Fp)
    }
    pub fn quantizes_kv(self) -> bool {
        matches!(self, Regime::WKv | Regime::WKvA)
    }
    pub fn quantizes_acts(self) -> bool {
        matches!(self, Regime::WKvA)
    }
    pub fn label(self) -> &'static str {
        match self {
            Regime::Fp => "FP32",
            Regime::W => "W",
            Regime::WKv => "W+KV",
            Regime::WKvA => "W+KV+A",
        }
    }
}

/// Quantization method (paper Table 2 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// round-to-nearest uniform, no rotation (LLM.int8-style)
    Rtn,
    /// randomized Hadamard rotations + uniform (QuaRot-style)
    UniformRot,
    /// Hadamard + uniform + LDLQ weights (SpinQuant/GPTQ-style)
    UniformRotLdlq,
    /// full NestQuant: rotations + nested-lattice + DP-β + (QA-)LDLQ
    NestQuant,
    /// NestQuantM: same, with the hardware-simple decode oracle (App. D)
    NestQuantM,
}

impl Method {
    pub fn label(self) -> &'static str {
        match self {
            Method::Rtn => "RTN (uniform)",
            Method::UniformRot => "QuaRot-style (rot+uniform)",
            Method::UniformRotLdlq => "SpinQuant-style (rot+uniform+LDLQ)",
            Method::NestQuant => "NestQuant",
            Method::NestQuantM => "NestQuantM",
        }
    }
    pub fn rotates(self) -> bool {
        !matches!(self, Method::Rtn)
    }
    pub fn is_nested(self) -> bool {
        matches!(self, Method::NestQuant | Method::NestQuantM)
    }
}

/// Rotation flavor for the Table 7 ablation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RotKind {
    Hadamard,
    Fourier,
    RandOrthKron,
}

#[derive(Clone, Debug)]
pub struct EngineOptions {
    pub method: Method,
    pub regime: Regime,
    /// nesting ratio (rate = log2 q bits/entry) for nested methods
    pub q: u32,
    /// number of scaling coefficients β
    pub k: usize,
    /// bits for the uniform baselines
    pub uniform_bits: u32,
    /// LDLQ on weights (Table 6 ablation)
    pub ldlq: bool,
    /// QA-LDLQ correction when activations are quantized (§4.5)
    pub qa_ldlq: bool,
    /// isotropic activation-noise variance for QA-LDLQ (ε²); when
    /// `auto_eps2` is set this is overridden by the measured roundtrip
    /// MSE of the site's calibrated activation quantizer (App. B: "ε²
    /// depends on the quantization rate and the statistics of X")
    pub eps2: f32,
    pub auto_eps2: bool,
    pub rot_kind: RotKind,
    /// calibration windows used for Hessians / β DP
    pub calib_windows: usize,
    /// serve M-variant nested linears through the packed integer GEMM
    /// backend (`quant::qgemm::PackedNestMatrix::gemm_into`, decode
    /// amortized over the sequence) instead of dequantized fp32 matmul
    pub int_gemm: bool,
    pub seed: u64,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            method: Method::NestQuant,
            regime: Regime::WKvA,
            q: 14,
            k: 4,
            uniform_bits: 4,
            ldlq: true,
            qa_ldlq: true,
            eps2: 0.01,
            auto_eps2: true,
            rot_kind: RotKind::Hadamard,
            calib_windows: 3,
            int_gemm: true,
            seed: 0xC0FFEE,
        }
    }
}

/// One quantized linear layer: either the packed integer-decode backend
/// (M-variant nested regimes) or a fake-quant dequantized weight
/// (transposed for row-major GEMM), plus the rotation applied to its
/// inputs at runtime, an optional activation quantizer, and storage
/// accounting.
pub struct QLinear {
    /// output features (rows of W)
    pub out_features: usize,
    /// dequantized (fake-quant) Wᵀ, (in, out) — the fp fallback path.
    /// `None` when the packed integer backend serves this site: keeping
    /// the fp32 matrix resident alongside the ~4.25-bit codes would
    /// forfeit the weight-memory win on the serving path.
    pub wt_deq: Option<Mat>,
    /// packed integer-decode backend (M-variant nested regimes): serves
    /// `forward` through the decode-amortized GEMM instead of fp32
    /// matmul over the dequantized weight
    pub packed: Option<PackedNestMatrix>,
    /// input rotation (already folded into the stored weight)
    pub rot: Option<Rotation>,
    /// activation quantizer for this site (W+KV+A regime)
    pub act_nq: Option<NestedLatticeQuantizer>,
    /// coded storage for bits accounting + the serving path
    pub coded: Option<(QuantizedMatrix, NestedLatticeQuantizer)>,
    /// payload bits per entry (codes + β side info, zstd-compressed)
    pub bits_zstd: f64,
    pub bits_packed: f64,
}

impl QLinear {
    /// y = (x·R)·W̃ᵀ with optional activation quantization after rotation.
    /// x (seq, in) → y (seq, out). When the packed integer backend is
    /// present the product runs on coset codes end-to-end: single rows
    /// (decode steps) through the integer GEMV, multi-row prefill
    /// windows through the decode-amortized multithreaded GEMM.
    pub fn forward(&self, x: &Mat, quantize_acts: bool, uniform_act: Option<u32>) -> Mat {
        let mut xr = x.clone();
        if let Some(rot) = &self.rot {
            rot.apply_rows(&mut xr.data);
        }
        if quantize_acts {
            if let Some(nq) = &self.act_nq {
                for t in 0..xr.rows {
                    let rt = nq.roundtrip(xr.row(t));
                    xr.row_mut(t).copy_from_slice(&rt);
                }
            } else if let Some(bits) = uniform_act {
                let uq = UniformQuantizer::new(bits);
                for t in 0..xr.rows {
                    let rt = uq.roundtrip(xr.row(t));
                    xr.row_mut(t).copy_from_slice(&rt);
                }
            }
        }
        let mut y = Mat::zeros(xr.rows, self.out_features);
        if let Some(packed) = &self.packed {
            if xr.rows == 1 {
                packed.gemv_into(xr.row(0), y.row_mut(0));
            } else {
                // spawning workers is only worth it for real prefill panels
                let threads = if xr.rows >= 16 { 0 } else { 1 };
                // per-thread scratch: prefill reuses the panel/staging
                // buffers instead of reallocating them every linear
                thread_local! {
                    static SCRATCH: std::cell::RefCell<GemmScratch> =
                        std::cell::RefCell::new(GemmScratch::new());
                }
                SCRATCH.with(|s| {
                    packed.gemm_into(&xr, &mut y, threads, &mut s.borrow_mut())
                });
            }
        } else {
            let wt = self
                .wt_deq
                .as_ref()
                .expect("QLinear without the integer backend must keep wt_deq");
            matmul_into(&xr.data, &wt.data, &mut y.data, xr.rows, xr.cols, wt.cols);
        }
        y
    }
}

/// Per-layer quantized weights + KV quantizers.
pub struct QLayer {
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
    pub wq: QLinear,
    pub wk: QLinear,
    pub wv: QLinear,
    pub wo: QLinear,
    pub w_up: QLinear,
    pub w_down: QLinear,
    /// per-head rotation applied to k and q (scores invariant) and to v
    pub head_rot: Option<Rotation>,
    /// KV-cache quantizers (key / value), per layer
    pub k_nq: Option<NestedLatticeQuantizer>,
    pub v_nq: Option<NestedLatticeQuantizer>,
}

/// The quantized model + evaluation entry points.
pub struct Engine {
    pub cfg: crate::model::ModelConfig,
    pub opts: EngineOptions,
    pub tok_emb: Mat,
    pub pos_emb: Mat,
    pub final_norm: Vec<f32>,
    pub head: QLinear,
    pub layers: Vec<QLayer>,
    /// mean weight-payload bits/entry (zstd β stream), across linears
    pub weight_bits_zstd: f64,
    /// same with raw 2-bit β packing
    pub weight_bits_packed: f64,
}

/// Calibration record for one linear site.
struct SiteStats {
    /// post-rotation activation samples (rows)
    acts: Mat,
}

struct CalibData {
    /// per layer: [attn_in, attn_out, mlp_in, mlp_down]
    sites: Vec<Vec<SiteStats>>,
    head_in: SiteStats,
    /// per layer: rotated per-head K / V 8-blocks
    k_blocks: Vec<Vec<[f32; D]>>,
    v_blocks: Vec<Vec<[f32; D]>>,
}

fn make_rotation(n: usize, kind: RotKind, rng: &mut Rng) -> Rotation {
    match kind {
        RotKind::Hadamard => {
            if n.is_power_of_two() {
                Rotation::random_hadamard(n, rng)
            } else {
                // n = 2^k·m with a Paley factor (12 covers 48/24/96/192…)
                let m = if n % 12 == 0 { 12 } else { 20 };
                Rotation::kron_hadamard(n, m, rng)
            }
        }
        RotKind::Fourier => Rotation::fourier(n),
        RotKind::RandOrthKron => {
            let m = if n % 12 == 0 {
                12
            } else if n % 16 == 0 {
                16
            } else {
                20
            };
            Rotation::random_orth_kron(n, m, rng)
        }
    }
}

impl Engine {
    /// Build a quantized engine from fp weights per §4.6.
    pub fn build(w: &ModelWeights, opts: EngineOptions) -> Self {
        let cfg = w.cfg;
        let mut rng = Rng::new(opts.seed);
        let rotate = opts.method.rotates() && opts.regime.quantizes_weights();

        // one rotation per input site (shared by wq/wk/wv at attn_in)
        let site_rot = |n: usize, rng: &mut Rng| -> Option<Rotation> {
            rotate.then(|| make_rotation(n, opts.rot_kind, rng))
        };
        let rots: Vec<[Option<Rotation>; 4]> = (0..cfg.n_layer)
            .map(|_| {
                [
                    site_rot(cfg.d_model, &mut rng), // attn_in
                    site_rot(cfg.d_model, &mut rng), // attn_out
                    site_rot(cfg.d_model, &mut rng), // mlp_in
                    site_rot(cfg.d_ff, &mut rng),    // mlp_down
                ]
            })
            .collect();
        let head_rot_site = site_rot(cfg.d_model, &mut rng);
        let head_rots: Vec<Option<Rotation>> = (0..cfg.n_layer)
            .map(|_| {
                (rotate && opts.regime.quantizes_kv())
                    .then(|| make_rotation(cfg.d_head(), opts.rot_kind, &mut rng))
            })
            .collect();

        // ---- calibration pass (fp forward with rotation taps) ----
        let calib = Self::calibrate(w, &rots, head_rot_site.as_ref(), &head_rots, &opts);

        // ---- quantize weights ----
        let quantize_linear = |wm: &Mat, rot: &Option<Rotation>, stats: &SiteStats| -> QLinear {
            Self::quantize_linear(wm, rot, stats, &opts)
        };

        let mut layers = Vec::with_capacity(cfg.n_layer);
        for (i, lw) in w.layers.iter().enumerate() {
            let s = &calib.sites[i];
            let layer = QLayer {
                ln1: lw.ln1.clone(),
                ln2: lw.ln2.clone(),
                wq: quantize_linear(&lw.wq, &rots[i][0], &s[0]),
                wk: quantize_linear(&lw.wk, &rots[i][0], &s[0]),
                wv: quantize_linear(&lw.wv, &rots[i][0], &s[0]),
                wo: quantize_linear(&lw.wo, &rots[i][1], &s[1]),
                w_up: quantize_linear(&lw.w_up, &rots[i][2], &s[2]),
                w_down: quantize_linear(&lw.w_down, &rots[i][3], &s[3]),
                head_rot: head_rots[i].clone(),
                k_nq: Self::kv_quantizer(&calib.k_blocks[i], &opts),
                v_nq: Self::kv_quantizer(&calib.v_blocks[i], &opts),
            };
            layers.push(layer);
        }
        let head = quantize_linear(&w.head, &head_rot_site, &calib.head_in);

        // aggregate bits accounting over all quantized linears
        let mut bits_z = 0f64;
        let mut bits_p = 0f64;
        let mut n_lin = 0f64;
        let mut visit = |l: &QLinear| {
            if l.bits_zstd > 0.0 {
                bits_z += l.bits_zstd;
                bits_p += l.bits_packed;
                n_lin += 1.0;
            }
        };
        for l in &layers {
            visit(&l.wq);
            visit(&l.wk);
            visit(&l.wv);
            visit(&l.wo);
            visit(&l.w_up);
            visit(&l.w_down);
        }
        visit(&head);

        Engine {
            cfg,
            opts,
            tok_emb: w.tok_emb.clone(),
            pos_emb: w.pos_emb.clone(),
            final_norm: w.final_norm.clone(),
            head,
            layers,
            weight_bits_zstd: if n_lin > 0.0 { bits_z / n_lin } else { 32.0 },
            weight_bits_packed: if n_lin > 0.0 { bits_p / n_lin } else { 32.0 },
        }
    }

    /// Build a paged KV pool carrying each layer's own calibrated
    /// key/value quantizer pair (§4.6 step 4 — per-layer dictionaries).
    /// `None` when this engine doesn't keep a coded KV cache (fp regime,
    /// or uniform-baseline KV which stays on the fp32 per-session path).
    pub fn kv_pool(&self, cfg: PoolConfig) -> Option<Arc<KvPool>> {
        if !self.opts.regime.quantizes_kv() {
            return None;
        }
        let mut layers = Vec::with_capacity(self.layers.len());
        for l in &self.layers {
            match (&l.k_nq, &l.v_nq) {
                (Some(k), Some(v)) => layers.push(KvLayerQuant {
                    k: k.clone(),
                    v: v.clone(),
                }),
                _ => return None,
            }
        }
        Some(Arc::new(KvPool::new(
            self.cfg.n_layer,
            self.cfg.n_head,
            layers,
            cfg,
        )))
    }

    fn kv_quantizer(
        blocks: &[[f32; D]],
        opts: &EngineOptions,
    ) -> Option<NestedLatticeQuantizer> {
        if !opts.regime.quantizes_kv() || !opts.method.is_nested() || blocks.is_empty() {
            return None;
        }
        let codec = if opts.method == Method::NestQuantM {
            VoronoiCodec::new_m(opts.q)
        } else {
            VoronoiCodec::new(opts.q)
        };
        let betas = select_betas_for_data(&codec, blocks, opts.k, 4.0 / opts.q as f32);
        Some(NestedLatticeQuantizer::with_codec(
            codec,
            betas,
            Strategy::OptBeta,
        ))
    }

    fn quantize_linear(
        wm: &Mat,
        rot: &Option<Rotation>,
        stats: &SiteStats,
        opts: &EngineOptions,
    ) -> QLinear {
        // fold the rotation into the weight: y = W x = (W Rᵀ)(R x)
        let mut wrot = wm.clone();
        if let Some(r) = rot {
            // rows of W are functionals on x: replace each row w by R·w
            // (then (R w)·(R x) = w·x).
            r.apply_rows(&mut wrot.data);
        }

        if !opts.regime.quantizes_weights() {
            return QLinear {
                out_features: wrot.rows,
                wt_deq: Some(wrot.transpose()),
                packed: None,
                rot: rot.clone(),
                act_nq: None,
                coded: None,
                bits_zstd: 0.0,
                bits_packed: 0.0,
            };
        }

        let act_nq = Self::act_quantizer(stats, opts);

        match opts.method {
            Method::Rtn | Method::UniformRot => {
                let uq = UniformQuantizer::new(opts.uniform_bits);
                let deq = uq.roundtrip_rows(&wrot);
                QLinear {
                    out_features: deq.rows,
                    wt_deq: Some(deq.transpose()),
                    packed: None,
                    rot: rot.clone(),
                    act_nq,
                    coded: None,
                    bits_zstd: opts.uniform_bits as f64,
                    bits_packed: opts.uniform_bits as f64,
                }
            }
            Method::UniformRotLdlq => {
                // GPTQ-style: uniform grid with scalar LDLQ feedback
                let h = hessian_from_activations(&stats.acts, 0.01);
                let deq = Self::uniform_ldlq(&wrot, &h, opts.uniform_bits);
                QLinear {
                    out_features: deq.rows,
                    wt_deq: Some(deq.transpose()),
                    packed: None,
                    rot: rot.clone(),
                    act_nq,
                    coded: None,
                    bits_zstd: opts.uniform_bits as f64,
                    bits_packed: opts.uniform_bits as f64,
                }
            }
            Method::NestQuant | Method::NestQuantM => {
                let m_variant = opts.method == Method::NestQuantM;
                let codec = if m_variant {
                    VoronoiCodec::new_m(opts.q)
                } else {
                    VoronoiCodec::new(opts.q)
                };
                let h = hessian_from_activations(&stats.acts, 0.01);
                let margin = 3.0 / opts.q as f32;
                // Appendix B: QA-LDLQ exists to fix *pathological* layers
                // (amplification ratio ≫ 1, e.g. ≈157 for Llama-3-70B
                // block-0 v_proj). On benign layers the W̃ bias costs more
                // than the robustness buys, so apply it selectively.
                let needs_qa = opts.qa_ldlq
                    && opts.regime.quantizes_acts()
                    && crate::quant::qaldlq::amplification_ratio(&wrot, &stats.acts, opts.seed)
                        > 5.0;
                let (qm, nq) = if opts.ldlq {
                    if needs_qa {
                        // QA-LDLQ with DP βs: modify W then run adaptive LDLQ.
                        // ε² = measured per-coordinate MSE of this site's
                        // activation quantizer (auto) or the fixed option.
                        let eps2 = if opts.auto_eps2 {
                            Self::estimate_act_noise(stats, act_nq.as_ref(), opts)
                        } else {
                            opts.eps2
                        };
                        let wt = crate::quant::qaldlq::modified_weight(&wrot, &h, eps2);
                        let mut hj = h.clone();
                        hj.add_diag(eps2);
                        crate::quant::ldlq::ldlq_quantize_adaptive(
                            &wt, &hj, opts.q, opts.k, margin, m_variant,
                        )
                    } else {
                        crate::quant::ldlq::ldlq_quantize_adaptive(
                            &wrot, &h, opts.q, opts.k, margin, m_variant,
                        )
                    }
                } else {
                    // direct Algorithm-3 quantization with DP βs on raw rows
                    let blocks = Self::row_blocks(&wrot);
                    let betas = select_betas_for_data(&codec, &blocks, opts.k, margin);
                    let nq = NestedLatticeQuantizer::with_codec(
                        codec.clone(),
                        betas,
                        Strategy::OptBeta,
                    );
                    (QuantizedMatrix::quantize(&wrot, &nq), nq)
                };
                // integer GEMM backend: pack the LDLQ-chosen codes as-is
                // (no re-quantization) whenever the M-variant decode
                // oracle applies — forward then never touches fp32
                // weights (the Table 4 runtime claim, wired end-to-end)
                let packed = (opts.int_gemm && PackedNestMatrix::supports(&nq, qm.cols))
                    .then(|| PackedNestMatrix::from_quantized(&qm, &nq));
                // fp32 fallback only materialized when the integer
                // backend doesn't serve this site
                let wt_deq = packed
                    .is_none()
                    .then(|| qm.dequantize(&nq).transpose());
                // bits accounting (Tables 1/3 columns)
                let n_entries = qm.rows * qm.cols;
                let bz = crate::io::sideinfo::bits_per_entry(
                    opts.q,
                    n_entries,
                    crate::io::sideinfo::beta_bits_zstd(&qm.beta_idx),
                    qm.scales.len(),
                );
                let bp = crate::io::sideinfo::bits_per_entry(
                    opts.q,
                    n_entries,
                    crate::io::sideinfo::beta_bits_packed(&qm.beta_idx, nq.k()),
                    qm.scales.len(),
                );
                QLinear {
                    out_features: qm.rows,
                    wt_deq,
                    packed,
                    rot: rot.clone(),
                    act_nq,
                    coded: Some((qm, nq)),
                    bits_zstd: bz,
                    bits_packed: bp,
                }
            }
        }
    }

    /// Measured activation-quantizer noise: mean per-coordinate roundtrip
    /// MSE over calibration rows (the ε² of Lemma 4.2's J = ε²I).
    fn estimate_act_noise(
        stats: &SiteStats,
        act_nq: Option<&NestedLatticeQuantizer>,
        opts: &EngineOptions,
    ) -> f32 {
        let rows = stats.acts.rows.min(32);
        if rows == 0 {
            return opts.eps2;
        }
        let mut acc = 0f64;
        let mut n = 0usize;
        for t in 0..rows {
            let row = stats.acts.row(t);
            let rt = if let Some(nq) = act_nq {
                nq.roundtrip(row)
            } else {
                UniformQuantizer::new(opts.uniform_bits).roundtrip(row)
            };
            acc += crate::util::stats::mse(row, &rt) * row.len() as f64;
            n += row.len();
        }
        ((acc / n.max(1) as f64) as f32).max(1e-8)
    }

    /// Uniform-grid LDLQ (the GPTQ baseline): scalar feedback, per-row Δ.
    fn uniform_ldlq(w: &Mat, h: &Mat, bits: u32) -> Mat {
        let (l, _) = crate::util::linalg::ldl(h);
        let lvl = 1i32 << (bits - 1);
        let n = w.cols;
        let mut out = Mat::zeros(w.rows, n);
        for r in 0..w.rows {
            let row = w.row(r);
            let maxabs = row.iter().fold(0f32, |m, &v| m.max(v.abs()));
            if maxabs == 0.0 {
                continue;
            }
            let delta = maxabs / lvl as f32;
            let mut e = vec![0f32; n];
            for j in (0..n).rev() {
                let mut f = 0f32;
                for i in j + 1..n {
                    f += e[i] * l[(i, j)];
                }
                let adj = row[j] + f;
                let qv = ((adj / delta).round() as i32).clamp(-lvl, lvl - 1) as f32 * delta;
                out[(r, j)] = qv;
                e[j] = row[j] - qv;
            }
        }
        out
    }

    fn act_quantizer(stats: &SiteStats, opts: &EngineOptions) -> Option<NestedLatticeQuantizer> {
        if !opts.regime.quantizes_acts() || !opts.method.is_nested() {
            return None;
        }
        // normalize activation rows like Algorithm 3 will, then DP-select β
        let mut blocks: Vec<[f32; D]> = Vec::new();
        for t in 0..stats.acts.rows.min(64) {
            let row = stats.acts.row(t);
            let s = crate::util::stats::norm2(row) as f32;
            if s == 0.0 {
                continue;
            }
            let norm = (row.len() as f32).sqrt() / s;
            for ch in row.chunks_exact(D) {
                let mut b = [0f32; D];
                for i in 0..D {
                    b[i] = ch[i] * norm;
                }
                blocks.push(b);
            }
        }
        if blocks.is_empty() {
            return None;
        }
        let codec = if opts.method == Method::NestQuantM {
            VoronoiCodec::new_m(opts.q)
        } else {
            VoronoiCodec::new(opts.q)
        };
        let betas = select_betas_for_data(&codec, &blocks, opts.k, 4.0 / opts.q as f32);
        Some(NestedLatticeQuantizer::with_codec(
            codec,
            betas,
            Strategy::OptBeta,
        ))
    }

    fn row_blocks(w: &Mat) -> Vec<[f32; D]> {
        let mut out = Vec::with_capacity(w.rows * w.cols / D);
        for r in 0..w.rows {
            let row = w.row(r);
            let s = crate::util::stats::norm2(row) as f32;
            if s == 0.0 {
                continue;
            }
            let norm = (w.cols as f32).sqrt() / s;
            for ch in row.chunks_exact(D) {
                let mut b = [0f32; D];
                for i in 0..D {
                    b[i] = ch[i] * norm;
                }
                out.push(b);
            }
        }
        out
    }

    /// Calibration: fp forward over calib windows, tapping each site's
    /// post-rotation activations and the per-head rotated K/V blocks.
    fn calibrate(
        w: &ModelWeights,
        rots: &[[Option<Rotation>; 4]],
        head_rot_site: Option<&Rotation>,
        head_rots: &[Option<Rotation>],
        opts: &EngineOptions,
    ) -> CalibData {
        let cfg = w.cfg;
        let win = cfg.ctx;
        let windows: Vec<&[i32]> = w
            .calib_tokens
            .chunks_exact(win + 1)
            .take(opts.calib_windows)
            .collect();
        let n_samples = windows.len() * win;
        let mut sites: Vec<Vec<SiteStats>> = (0..cfg.n_layer)
            .map(|_| {
                vec![
                    SiteStats { acts: Mat::zeros(n_samples, cfg.d_model) },
                    SiteStats { acts: Mat::zeros(n_samples, cfg.d_model) },
                    SiteStats { acts: Mat::zeros(n_samples, cfg.d_model) },
                    SiteStats { acts: Mat::zeros(n_samples, cfg.d_ff) },
                ]
            })
            .collect();
        let mut head_in = SiteStats {
            acts: Mat::zeros(n_samples, cfg.d_model),
        };
        let mut k_blocks: Vec<Vec<[f32; D]>> = vec![Vec::new(); cfg.n_layer];
        let mut v_blocks: Vec<Vec<[f32; D]>> = vec![Vec::new(); cfg.n_layer];

        let dh = cfg.d_head();
        for (wi, window) in windows.iter().enumerate() {
            let toks = &window[..win];
            let mut x = Mat::zeros(win, cfg.d_model);
            for (t, &tok) in toks.iter().enumerate() {
                let emb = w.tok_emb.row(tok as usize);
                let pos = w.pos_emb.row(t);
                for i in 0..cfg.d_model {
                    x[(t, i)] = emb[i] + pos[i];
                }
            }
            for (li, lw) in w.layers.iter().enumerate() {
                // attn_in site
                let mut normed = Mat::zeros(win, cfg.d_model);
                for t in 0..win {
                    rmsnorm(x.row(t), &lw.ln1, normed.row_mut(t));
                }
                Self::tap(&mut sites[li][0], &normed, &rots[li][0], wi * win);
                let att_in = normed.clone();
                let q = crate::model::forward::linear(&att_in, &lw.wq);
                let k = crate::model::forward::linear(&att_in, &lw.wk);
                let v = crate::model::forward::linear(&att_in, &lw.wv);
                // tap rotated per-head K/V blocks (normalized per vector)
                if opts.regime.quantizes_kv() {
                    for t in 0..win {
                        for h in 0..cfg.n_head {
                            let mut kv = k.row(t)[h * dh..(h + 1) * dh].to_vec();
                            let mut vv = v.row(t)[h * dh..(h + 1) * dh].to_vec();
                            if let Some(r) = &head_rots[li] {
                                r.apply(&mut kv);
                                r.apply(&mut vv);
                            }
                            Self::push_norm_blocks(&mut k_blocks[li], &kv);
                            Self::push_norm_blocks(&mut v_blocks[li], &vv);
                        }
                    }
                }
                // fp attention to continue the forward
                let att = crate::model::forward::attention(&att_in, lw, cfg.n_head);
                let _ = q;
                for i in 0..x.data.len() {
                    x.data[i] += att.data[i];
                }
                // attn_out site taps the wo input, which lives inside
                // attention(); approximate with the post-attention normed
                // input statistics of the *next* op instead:
                // (we tap wo via its own input during quantized eval, so
                // for calibration reuse the attention output pre-wo)
                // — recompute the concat head outputs:
                let wo_in = Self::attention_heads_only(&att_in, lw, cfg.n_head);
                Self::tap(&mut sites[li][1], &wo_in, &rots[li][1], wi * win);

                // MLP
                let mut normed2 = Mat::zeros(win, cfg.d_model);
                for t in 0..win {
                    rmsnorm(x.row(t), &lw.ln2, normed2.row_mut(t));
                }
                Self::tap(&mut sites[li][2], &normed2, &rots[li][2], wi * win);
                let mut hmid = crate::model::forward::linear(&normed2, &lw.w_up);
                for vv in hmid.data.iter_mut() {
                    *vv = gelu(*vv);
                }
                Self::tap(&mut sites[li][3], &hmid, &rots[li][3], wi * win);
                let down = crate::model::forward::linear(&hmid, &lw.w_down);
                for i in 0..x.data.len() {
                    x.data[i] += down.data[i];
                }
            }
            let mut fin = Mat::zeros(win, cfg.d_model);
            for t in 0..win {
                rmsnorm(x.row(t), &w.final_norm, fin.row_mut(t));
            }
            Self::tap(
                &mut head_in,
                &fin,
                &head_rot_site.cloned().map(Some).unwrap_or(None),
                wi * win,
            );
        }
        CalibData {
            sites,
            head_in,
            k_blocks,
            v_blocks,
        }
    }

    /// Multi-head attention *without* the wo projection (per-head outputs
    /// concatenated) — the wo-input tap for calibration.
    fn attention_heads_only(x: &Mat, l: &crate::model::weights::LayerWeights, n_head: usize) -> Mat {
        let seq = x.rows;
        let d = x.cols;
        let dh = d / n_head;
        let q = crate::model::forward::linear(x, &l.wq);
        let k = crate::model::forward::linear(x, &l.wk);
        let v = crate::model::forward::linear(x, &l.wv);
        let mut out = Mat::zeros(seq, d);
        let scale = 1.0 / (dh as f32).sqrt();
        let mut scores = vec![0f32; seq];
        for h in 0..n_head {
            let off = h * dh;
            for t in 0..seq {
                let qrow = &q.row(t)[off..off + dh];
                for (s, sc) in scores.iter_mut().enumerate().take(t + 1) {
                    let krow = &k.row(s)[off..off + dh];
                    let mut acc = 0f32;
                    for i in 0..dh {
                        acc += qrow[i] * krow[i];
                    }
                    *sc = acc * scale;
                }
                softmax_inplace(&mut scores[..t + 1]);
                let orow = &mut out.row_mut(t)[off..off + dh];
                for s in 0..=t {
                    let p = scores[s];
                    let vrow = &v.row(s)[off..off + dh];
                    for i in 0..dh {
                        orow[i] += p * vrow[i];
                    }
                }
            }
        }
        out
    }

    fn tap(site: &mut SiteStats, acts: &Mat, rot: &Option<Rotation>, row_off: usize) {
        for t in 0..acts.rows {
            let mut row = acts.row(t).to_vec();
            if let Some(r) = rot {
                r.apply(&mut row);
            }
            site.acts.row_mut(row_off + t).copy_from_slice(&row);
        }
    }

    fn push_norm_blocks(sink: &mut Vec<[f32; D]>, v: &[f32]) {
        let s = crate::util::stats::norm2(v) as f32;
        if s == 0.0 {
            return;
        }
        let norm = (v.len() as f32).sqrt() / s;
        for ch in v.chunks_exact(D) {
            let mut b = [0f32; D];
            for i in 0..D {
                b[i] = ch[i] * norm;
            }
            sink.push(b);
        }
    }

    // ---- quantized forward & evaluation ----

    /// Fake-quant a per-head vector with a KV quantizer (or uniform for
    /// the baseline methods).
    fn kv_roundtrip(&self, nq: &Option<NestedLatticeQuantizer>, v: &mut [f32]) {
        if !self.opts.regime.quantizes_kv() {
            return;
        }
        if let Some(nq) = nq {
            let rt = nq.roundtrip(v);
            v.copy_from_slice(&rt);
        } else {
            let uq = UniformQuantizer::new(self.opts.uniform_bits);
            let rt = uq.roundtrip(v);
            v.copy_from_slice(&rt);
        }
    }

    /// Quantized attention over a full window.
    fn attention_q(&self, x: &Mat, l: &QLayer) -> Mat {
        let cfg = &self.cfg;
        let seq = x.rows;
        let d = cfg.d_model;
        let dh = cfg.d_head();
        let qa = self.opts.regime.quantizes_acts();
        let ub = (!self.opts.method.is_nested()).then_some(self.opts.uniform_bits);
        let q = l.wq.forward(x, qa, ub);
        let mut k = l.wk.forward(x, qa, ub);
        let mut v = l.wv.forward(x, qa, ub);

        // KV-cache quantization (per position, per head, rotated basis)
        if self.opts.regime.quantizes_kv() {
            for t in 0..seq {
                for h in 0..cfg.n_head {
                    let kr = &mut k.row_mut(t)[h * dh..(h + 1) * dh];
                    if let Some(r) = &l.head_rot {
                        r.apply(kr);
                    }
                    self.kv_roundtrip(&l.k_nq, kr);
                    let vr = &mut v.row_mut(t)[h * dh..(h + 1) * dh];
                    if let Some(r) = &l.head_rot {
                        r.apply(vr);
                    }
                    self.kv_roundtrip(&l.v_nq, vr);
                }
            }
        }
        // rotate queries to match keys (scores invariant)
        let mut qrot = q;
        if let Some(r) = &l.head_rot {
            for t in 0..seq {
                for h in 0..cfg.n_head {
                    r.apply(&mut qrot.row_mut(t)[h * dh..(h + 1) * dh]);
                }
            }
        }

        let mut out = Mat::zeros(seq, d);
        let scale = 1.0 / (dh as f32).sqrt();
        let mut scores = vec![0f32; seq];
        for h in 0..cfg.n_head {
            let off = h * dh;
            for t in 0..seq {
                let qrow = &qrot.row(t)[off..off + dh];
                for (s, sc) in scores.iter_mut().enumerate().take(t + 1) {
                    let krow = &k.row(s)[off..off + dh];
                    let mut acc = 0f32;
                    for i in 0..dh {
                        acc += qrow[i] * krow[i];
                    }
                    *sc = acc * scale;
                }
                softmax_inplace(&mut scores[..t + 1]);
                let orow = &mut out.row_mut(t)[off..off + dh];
                for s in 0..=t {
                    let p = scores[s];
                    let vrow = &v.row(s)[off..off + dh];
                    for i in 0..dh {
                        orow[i] += p * vrow[i];
                    }
                }
            }
        }
        // un-rotate attention output per head (values were rotated)
        if let Some(r) = &l.head_rot {
            for t in 0..seq {
                for h in 0..cfg.n_head {
                    r.apply_t(&mut out.row_mut(t)[h * dh..(h + 1) * dh]);
                }
            }
        }
        l.wo.forward(&out, qa, ub)
    }

    /// Quantized full-window forward → logits (seq, vocab).
    pub fn forward_window(&self, tokens: &[i32]) -> Mat {
        let cfg = &self.cfg;
        let seq = tokens.len();
        let d = cfg.d_model;
        let qa = self.opts.regime.quantizes_acts();
        let ub = (!self.opts.method.is_nested()).then_some(self.opts.uniform_bits);
        let mut x = Mat::zeros(seq, d);
        for (t, &tok) in tokens.iter().enumerate() {
            let emb = self.tok_emb.row(tok as usize);
            let pos = self.pos_emb.row(t);
            for i in 0..d {
                x[(t, i)] = emb[i] + pos[i];
            }
        }
        let mut normed = Mat::zeros(seq, d);
        for l in &self.layers {
            for t in 0..seq {
                rmsnorm(x.row(t), &l.ln1, normed.row_mut(t));
            }
            let att = self.attention_q(&normed, l);
            for i in 0..x.data.len() {
                x.data[i] += att.data[i];
            }
            for t in 0..seq {
                rmsnorm(x.row(t), &l.ln2, normed.row_mut(t));
            }
            let mut h = l.w_up.forward(&normed, qa, ub);
            for v in h.data.iter_mut() {
                *v = gelu(*v);
            }
            let down = l.w_down.forward(&h, qa, ub);
            for i in 0..x.data.len() {
                x.data[i] += down.data[i];
            }
        }
        for t in 0..seq {
            rmsnorm(x.row(t), &self.final_norm, normed.row_mut(t));
        }
        self.head.forward(&normed, qa, ub)
    }

    /// Perplexity over non-overlapping windows.
    pub fn eval_ppl(&self, tokens: &[i32], max_windows: usize) -> f64 {
        let win = self.cfg.ctx;
        let mut total = 0f64;
        let mut count = 0usize;
        for chunk in tokens.chunks_exact(win + 1).take(max_windows) {
            let logits = self.forward_window(&chunk[..win]);
            total += window_nll(&logits, &chunk[1..]);
            count += 1;
        }
        (total / count.max(1) as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::{artifact_path, ModelWeights};

    fn load_tiny() -> Option<ModelWeights> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let p = artifact_path(&dir, "tiny");
        p.exists().then(|| ModelWeights::load(&p).unwrap())
    }

    #[test]
    fn fp_regime_matches_native_forward() {
        let Some(w) = load_tiny() else { return };
        let eng = Engine::build(
            &w,
            EngineOptions {
                regime: Regime::Fp,
                ..Default::default()
            },
        );
        let toks: Vec<i32> = w.val_tokens[..32].to_vec();
        let a = eng.forward_window(&toks);
        let b = crate::model::forward::forward_window(&w, &toks);
        for i in 0..a.data.len() {
            assert!(
                (a.data[i] - b.data[i]).abs() < 1e-3,
                "engine fp path diverges at {i}: {} vs {}",
                a.data[i],
                b.data[i]
            );
        }
    }

    #[test]
    fn quantized_ppl_close_to_fp() {
        let Some(w) = load_tiny() else { return };
        let fp_ppl = crate::model::forward::eval_ppl(&w, &w.val_tokens, 6);
        let eng = Engine::build(
            &w,
            EngineOptions {
                method: Method::NestQuant,
                regime: Regime::W,
                calib_windows: 2,
                ..Default::default()
            },
        );
        let qppl = eng.eval_ppl(&w.val_tokens, 6);
        assert!(
            qppl < fp_ppl * 1.25,
            "W-only NestQuant ppl {qppl} too far above fp {fp_ppl}"
        );
        assert!(qppl > fp_ppl * 0.8, "suspiciously better than fp: {qppl} vs {fp_ppl}");
    }

    #[test]
    fn full_quant_ranks_methods_correctly() {
        let Some(w) = load_tiny() else { return };
        let mut ppls = std::collections::HashMap::new();
        for method in [Method::Rtn, Method::NestQuant] {
            let eng = Engine::build(
                &w,
                EngineOptions {
                    method,
                    regime: Regime::WKvA,
                    calib_windows: 2,
                    ..Default::default()
                },
            );
            ppls.insert(method.label(), eng.eval_ppl(&w.val_tokens, 4));
        }
        let nest = ppls["NestQuant"];
        let rtn = ppls["RTN (uniform)"];
        assert!(
            nest < rtn,
            "NestQuant {nest} should beat plain RTN {rtn} at 4 bits"
        );
    }

    /// A synthetic random tiny model, so the integer-backend tests run
    /// without the trained artifact (which the `load_tiny` tests skip on).
    fn synth_weights() -> ModelWeights {
        ModelWeights::synthetic(
            crate::model::ModelConfig {
                vocab: 48,
                ctx: 16,
                d_model: 32,
                n_layer: 1,
                n_head: 2,
                d_ff: 64,
            },
            0xBEEF,
        )
    }

    #[test]
    fn m_variant_engine_runs_integer_gemm_path() {
        // end-to-end: a NestQuantM engine must carry the packed integer
        // backend on every nested linear, and its prefill forward (which
        // routes through PackedNestMatrix::gemm_into) must agree with the
        // fake-quant fp32 path on the identical codes.
        let w = synth_weights();
        let base = EngineOptions {
            method: Method::NestQuantM,
            regime: Regime::W,
            calib_windows: 1,
            ..Default::default()
        };
        let int_eng = Engine::build(&w, base.clone());
        for l in &int_eng.layers {
            for lin in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w_up, &l.w_down] {
                assert!(lin.packed.is_some(), "integer backend missing on a linear");
            }
        }
        assert!(int_eng.head.packed.is_some(), "integer backend missing on head");
        let fake_eng = Engine::build(
            &w,
            EngineOptions {
                int_gemm: false,
                ..base
            },
        );
        assert!(fake_eng.layers[0].wq.packed.is_none());
        let toks: Vec<i32> = w.val_tokens[..12].to_vec();
        let a = int_eng.forward_window(&toks);
        let b = fake_eng.forward_window(&toks);
        assert_eq!(a.data.len(), b.data.len());
        for i in 0..a.data.len() {
            assert!(
                (a.data[i] - b.data[i]).abs() < 1e-2 * (1.0 + b.data[i].abs()),
                "integer vs fake-quant logits diverge at {i}: {} vs {}",
                a.data[i],
                b.data[i]
            );
        }
    }

    #[test]
    fn non_m_methods_do_not_get_integer_backend() {
        // the packed decode oracle is NestQuantM-specific; plain NestQuant
        // and the uniform baselines must stay on the fp32 path.
        let w = synth_weights();
        for method in [Method::NestQuant, Method::Rtn] {
            let eng = Engine::build(
                &w,
                EngineOptions {
                    method,
                    regime: Regime::W,
                    calib_windows: 1,
                    ..Default::default()
                },
            );
            assert!(
                eng.layers[0].wq.packed.is_none(),
                "{:?} must not use the M-variant integer backend",
                method
            );
        }
    }

    #[test]
    fn integer_backend_ppl_matches_fake_quant_on_tiny() {
        // same codes, two execution backends: perplexities must agree to
        // float-accumulation tolerance on the trained tiny artifact.
        let Some(w) = load_tiny() else { return };
        let base = EngineOptions {
            method: Method::NestQuantM,
            regime: Regime::W,
            calib_windows: 2,
            ..Default::default()
        };
        let int_ppl = Engine::build(&w, base.clone()).eval_ppl(&w.val_tokens, 4);
        let fake_ppl = Engine::build(
            &w,
            EngineOptions {
                int_gemm: false,
                ..base
            },
        )
        .eval_ppl(&w.val_tokens, 4);
        assert!(
            (int_ppl / fake_ppl - 1.0).abs() < 0.02,
            "integer-backend ppl {int_ppl} vs fake-quant ppl {fake_ppl}"
        );
    }

    #[test]
    fn bits_accounting_close_to_4() {
        let Some(w) = load_tiny() else { return };
        let eng = Engine::build(
            &w,
            EngineOptions {
                method: Method::NestQuant,
                regime: Regime::W,
                calib_windows: 1,
                ..Default::default()
            },
        );
        assert!(
            eng.weight_bits_packed > 3.8 && eng.weight_bits_packed < 4.6,
            "packed bits {}",
            eng.weight_bits_packed
        );
        assert!(eng.weight_bits_zstd <= eng.weight_bits_packed + 1e-9);
    }
}
