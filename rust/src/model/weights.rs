//! fp32 weight store: loads `artifacts/model_<name>.nqt` (written by the
//! python training layer) plus the token splits used for evaluation and
//! calibration.

use crate::io::tensorfile::{find, read_tensors, Tensor};
use crate::model::config::ModelConfig;
use crate::util::linalg::Mat;
use anyhow::Result;
use std::path::Path;

/// One transformer block's weights (all matrices (out, in) row-major).
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
    pub wq: Mat,
    pub wk: Mat,
    pub wv: Mat,
    pub wo: Mat,
    pub w_up: Mat,
    pub w_down: Mat,
}

#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub cfg: ModelConfig,
    pub tok_emb: Mat,
    pub pos_emb: Mat,
    pub head: Mat,
    pub final_norm: Vec<f32>,
    pub layers: Vec<LayerWeights>,
    /// held-out validation tokens
    pub val_tokens: Vec<i32>,
    /// calibration tokens (train-split slice)
    pub calib_tokens: Vec<i32>,
}

fn mat_of(tensors: &[Tensor], name: &str) -> Result<Mat> {
    let t = find(tensors, name)?;
    let data = t.as_f32()?.to_vec();
    let (rows, cols) = match t.dims.len() {
        2 => (t.dims[0], t.dims[1]),
        1 => (1, t.dims[0]),
        _ => anyhow::bail!("{name}: expected 1- or 2-D tensor"),
    };
    Ok(Mat::from_vec(rows, cols, data))
}

fn vec_of(tensors: &[Tensor], name: &str) -> Result<Vec<f32>> {
    Ok(find(tensors, name)?.as_f32()?.to_vec())
}

impl ModelWeights {
    pub fn load(path: &Path) -> Result<Self> {
        let tensors = read_tensors(path)?;
        let cfg_t = find(&tensors, "config")?;
        let cfg_i32: Vec<i32> = match &cfg_t.data {
            crate::io::tensorfile::TensorData::I32(v) => v.clone(),
            _ => anyhow::bail!("config tensor must be i32"),
        };
        let cfg = ModelConfig::from_tensor(&cfg_i32)?;

        let grab_i32 = |name: &str| -> Result<Vec<i32>> {
            match &find(&tensors, name)?.data {
                crate::io::tensorfile::TensorData::I32(v) => Ok(v.clone()),
                _ => anyhow::bail!("{name} must be i32"),
            }
        };

        let mut layers = Vec::with_capacity(cfg.n_layer);
        for i in 0..cfg.n_layer {
            layers.push(LayerWeights {
                ln1: vec_of(&tensors, &format!("w/layers.{i}.ln1"))?,
                ln2: vec_of(&tensors, &format!("w/layers.{i}.ln2"))?,
                wq: mat_of(&tensors, &format!("w/layers.{i}.wq"))?,
                wk: mat_of(&tensors, &format!("w/layers.{i}.wk"))?,
                wv: mat_of(&tensors, &format!("w/layers.{i}.wv"))?,
                wo: mat_of(&tensors, &format!("w/layers.{i}.wo"))?,
                w_up: mat_of(&tensors, &format!("w/layers.{i}.w_up"))?,
                w_down: mat_of(&tensors, &format!("w/layers.{i}.w_down"))?,
            });
        }
        Ok(ModelWeights {
            cfg,
            tok_emb: mat_of(&tensors, "w/tok_emb")?,
            pos_emb: mat_of(&tensors, "w/pos_emb")?,
            head: mat_of(&tensors, "w/head")?,
            final_norm: vec_of(&tensors, "w/final_norm")?,
            layers,
            val_tokens: grab_i32("tokens/val")?,
            calib_tokens: grab_i32("tokens/calib")?,
        })
    }

    /// A deterministic random model (no trained artifact needed): used
    /// by the integer-backend / KV-pool tests and the serving bench,
    /// where end-to-end structure matters but logit quality doesn't.
    pub fn synthetic(cfg: ModelConfig, seed: u64) -> Self {
        use crate::util::Rng;
        let mut rng = Rng::new(seed);
        fn mat(rng: &mut Rng, r: usize, c: usize, s: f32) -> Mat {
            let mut m = Mat::from_vec(r, c, rng.gauss_vec(r * c));
            m.scale(s);
            m
        }
        let layers = (0..cfg.n_layer)
            .map(|_| LayerWeights {
                ln1: vec![1.0; cfg.d_model],
                ln2: vec![1.0; cfg.d_model],
                wq: mat(&mut rng, cfg.d_model, cfg.d_model, 0.25),
                wk: mat(&mut rng, cfg.d_model, cfg.d_model, 0.25),
                wv: mat(&mut rng, cfg.d_model, cfg.d_model, 0.25),
                wo: mat(&mut rng, cfg.d_model, cfg.d_model, 0.25),
                w_up: mat(&mut rng, cfg.d_ff, cfg.d_model, 0.25),
                w_down: mat(&mut rng, cfg.d_model, cfg.d_ff, 0.25),
            })
            .collect();
        let tok_emb = mat(&mut rng, cfg.vocab, cfg.d_model, 0.5);
        let pos_emb = mat(&mut rng, cfg.ctx, cfg.d_model, 0.1);
        let head = mat(&mut rng, cfg.vocab, cfg.d_model, 0.25);
        let mut toks = |n: usize| -> Vec<i32> {
            (0..n).map(|_| rng.below(cfg.vocab) as i32).collect()
        };
        let val_tokens = toks(3 * (cfg.ctx + 1));
        let calib_tokens = toks(3 * (cfg.ctx + 1));
        ModelWeights {
            cfg,
            tok_emb,
            pos_emb,
            head,
            final_norm: vec![1.0; cfg.d_model],
            layers,
            val_tokens,
            calib_tokens,
        }
    }

    /// The deterministic flat parameter order of the AOT artifact
    /// (python `flatten_names`): tok_emb, pos_emb, head, final_norm, then
    /// per layer ln1, ln2, wq, wk, wv, wo, w_up, w_down.
    pub fn flat_params(&self) -> Vec<(&'static str, Vec<usize>, Vec<f32>)> {
        let d = self.cfg.d_model;
        let mut out: Vec<(&'static str, Vec<usize>, Vec<f32>)> = vec![
            (
                "tok_emb",
                vec![self.cfg.vocab, d],
                self.tok_emb.data.clone(),
            ),
            ("pos_emb", vec![self.cfg.ctx, d], self.pos_emb.data.clone()),
            ("head", vec![self.cfg.vocab, d], self.head.data.clone()),
            ("final_norm", vec![d], self.final_norm.clone()),
        ];
        for l in &self.layers {
            out.push(("ln1", vec![d], l.ln1.clone()));
            out.push(("ln2", vec![d], l.ln2.clone()));
            out.push(("wq", vec![d, d], l.wq.data.clone()));
            out.push(("wk", vec![d, d], l.wk.data.clone()));
            out.push(("wv", vec![d, d], l.wv.data.clone()));
            out.push(("wo", vec![d, d], l.wo.data.clone()));
            out.push(("w_up", vec![self.cfg.d_ff, d], l.w_up.data.clone()));
            out.push(("w_down", vec![d, self.cfg.d_ff], l.w_down.data.clone()));
        }
        out
    }
}

/// Default artifact path for a model size name.
pub fn artifact_path(dir: &Path, name: &str) -> std::path::PathBuf {
    dir.join(format!("model_{name}.nqt"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_trained_model() {
        let path = artifact_path(&artifacts_dir(), "tiny");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let w = ModelWeights::load(&path).unwrap();
        assert_eq!(w.cfg.vocab, 52);
        assert_eq!(w.layers.len(), w.cfg.n_layer);
        assert_eq!(w.tok_emb.rows, w.cfg.vocab);
        assert!(!w.val_tokens.is_empty());
        assert!(w.val_tokens.iter().all(|&t| (t as usize) < w.cfg.vocab));
        // flat params arity matches the AOT manifest: 4 + 8·n_layer
        assert_eq!(w.flat_params().len(), 4 + 8 * w.cfg.n_layer);
    }
}
