//! Native fp32 forward pass — bit-compatible with the JAX model
//! (`python/compile/model.py`): RMSNorm, tanh-approximate GELU, causal
//! multi-head attention, no biases, untied head. Shared primitives are
//! reused by the quantized engine.

use crate::model::weights::{LayerWeights, ModelWeights};
use crate::util::linalg::{matmul_into, Mat};

/// RMSNorm with gain g (eps matches the JAX side).
pub fn rmsnorm(x: &[f32], g: &[f32], out: &mut [f32]) {
    let n = x.len() as f64;
    let ms: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / n;
    let r = (1.0 / (ms + 1e-5)).sqrt() as f32;
    for i in 0..x.len() {
        out[i] = x[i] * r * g[i];
    }
}

/// Row-wise [`rmsnorm`] into a caller-owned output panel (resized in
/// place — allocation-free once capacity is warm).
pub fn rmsnorm_rows(x: &Mat, g: &[f32], out: &mut Mat) {
    out.rows = x.rows;
    out.cols = x.cols;
    out.data.clear();
    out.data.resize(x.rows * x.cols, 0.0);
    for t in 0..x.rows {
        rmsnorm(x.row(t), g, out.row_mut(t));
    }
}

/// Gather one embedding row per (token, position) pair into an
/// activation panel: `x[s] = tok_emb[tokens[s]] + pos_emb[positions[s]]`.
/// Built on the GEMM panel gather so the fused decode step shares one
/// panel-assembly entry point; allocation-free once `x` has capacity.
pub fn embed_into(
    tok_emb: &Mat,
    pos_emb: &Mat,
    tokens: &[i32],
    positions: &[usize],
    x: &mut Mat,
) {
    assert_eq!(tokens.len(), positions.len());
    crate::quant::gemm::gather_panel(
        tokens.iter().map(|&t| tok_emb.row(t as usize)),
        tok_emb.cols,
        x,
    );
    for (s, &p) in positions.iter().enumerate() {
        for (xv, &pv) in x.row_mut(s).iter_mut().zip(pos_emb.row(p).iter()) {
            *xv += pv;
        }
    }
}

/// GELU, tanh approximation (identical constants to the JAX side).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (0.797_884_560_802_865_4 * (x + 0.044_715 * x * x * x)).tanh())
}

/// In-place softmax over a slice.
pub fn softmax_inplace(row: &mut [f32]) {
    let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// y = x · Wᵀ for row-major W (out, in); x (seq, in) → y (seq, out).
pub fn linear(x: &Mat, w: &Mat) -> Mat {
    // materialize Wᵀ once per call; callers on hot paths pre-transpose
    let wt = w.transpose();
    let mut y = Mat::zeros(x.rows, w.rows);
    matmul_into(&x.data, &wt.data, &mut y.data, x.rows, x.cols, w.rows);
    y
}

/// Causal multi-head attention over a full window; x (seq, d_model).
pub fn attention(x: &Mat, l: &LayerWeights, n_head: usize) -> Mat {
    let seq = x.rows;
    let d = x.cols;
    let dh = d / n_head;
    let q = linear(x, &l.wq);
    let k = linear(x, &l.wk);
    let v = linear(x, &l.wv);
    let mut out = Mat::zeros(seq, d);
    let scale = 1.0 / (dh as f32).sqrt();
    let mut scores = vec![0f32; seq];
    for h in 0..n_head {
        let off = h * dh;
        for t in 0..seq {
            let qrow = &q.row(t)[off..off + dh];
            for (s, score) in scores.iter_mut().enumerate().take(t + 1) {
                let krow = &k.row(s)[off..off + dh];
                let mut acc = 0f32;
                for i in 0..dh {
                    acc += qrow[i] * krow[i];
                }
                *score = acc * scale;
            }
            softmax_inplace(&mut scores[..t + 1]);
            let orow = &mut out.row_mut(t)[off..off + dh];
            for s in 0..=t {
                let p = scores[s];
                let vrow = &v.row(s)[off..off + dh];
                for i in 0..dh {
                    orow[i] += p * vrow[i];
                }
            }
        }
    }
    linear(&out, &l.wo)
}

/// One transformer block.
pub fn block(x: &mut Mat, l: &LayerWeights, n_head: usize) {
    let seq = x.rows;
    let d = x.cols;
    // attention sublayer
    let mut normed = Mat::zeros(seq, d);
    for t in 0..seq {
        rmsnorm(x.row(t), &l.ln1, normed.row_mut(t));
    }
    let att = attention(&normed, l, n_head);
    for i in 0..x.data.len() {
        x.data[i] += att.data[i];
    }
    // MLP sublayer
    for t in 0..seq {
        rmsnorm(x.row(t), &l.ln2, normed.row_mut(t));
    }
    let mut h = linear(&normed, &l.w_up);
    for v in h.data.iter_mut() {
        *v = gelu(*v);
    }
    let down = linear(&h, &l.w_down);
    for i in 0..x.data.len() {
        x.data[i] += down.data[i];
    }
}

/// Full-window forward: tokens (seq) → logits (seq, vocab).
pub fn forward_window(w: &ModelWeights, tokens: &[i32]) -> Mat {
    let seq = tokens.len();
    assert!(seq <= w.cfg.ctx);
    let d = w.cfg.d_model;
    let mut x = Mat::zeros(seq, d);
    for (t, &tok) in tokens.iter().enumerate() {
        let emb = w.tok_emb.row(tok as usize);
        let pos = w.pos_emb.row(t);
        for i in 0..d {
            x[(t, i)] = emb[i] + pos[i];
        }
    }
    for l in &w.layers {
        block(&mut x, l, w.cfg.n_head);
    }
    let mut normed = Mat::zeros(seq, d);
    for t in 0..seq {
        rmsnorm(x.row(t), &w.final_norm, normed.row_mut(t));
    }
    linear(&normed, &w.head)
}

/// Mean next-token NLL of a (seq+1)-token window given its logits.
pub fn window_nll(logits: &Mat, targets: &[i32]) -> f64 {
    assert_eq!(logits.rows, targets.len());
    let mut total = 0f64;
    for t in 0..targets.len() {
        let row = logits.row(t);
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let logsum: f64 =
            (row.iter().map(|&v| ((v - max) as f64).exp()).sum::<f64>()).ln() + max as f64;
        total += logsum - row[targets[t] as usize] as f64;
    }
    total / targets.len() as f64
}

/// Perplexity of the fp32 model over non-overlapping windows of `val`
/// tokens (up to `max_windows`).
pub fn eval_ppl(w: &ModelWeights, tokens: &[i32], max_windows: usize) -> f64 {
    let win = w.cfg.ctx;
    let mut total = 0f64;
    let mut count = 0usize;
    for chunk in tokens.chunks_exact(win + 1).take(max_windows) {
        let logits = forward_window(w, &chunk[..win]);
        total += window_nll(&logits, &chunk[1..]);
        count += 1;
    }
    (total / count as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::artifact_path;
    use crate::util::Rng;

    fn load(name: &str) -> Option<ModelWeights> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let p = artifact_path(&dir, name);
        if p.exists() {
            Some(ModelWeights::load(&p).unwrap())
        } else {
            eprintln!("skipping: artifacts missing");
            None
        }
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut v = vec![1.0f32, 2.0, 3.0, -1.0];
        softmax_inplace(&mut v);
        let s: f32 = v.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(v[2] > v[1] && v[1] > v[0] && v[0] > v[3]);
    }

    #[test]
    fn gelu_known_values() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(1.0) - 0.841_192).abs() < 1e-4);
        assert!(gelu(-5.0).abs() < 1e-3);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0f32, -4.0];
        let g = vec![1.0f32, 1.0];
        let mut out = vec![0.0; 2];
        rmsnorm(&x, &g, &mut out);
        // rms = √(25/2/2)... ms = 12.5, x/√ms
        let r = (1.0 / 12.5f64).sqrt() as f32;
        assert!((out[0] - 3.0 * r).abs() < 1e-5);
        assert!((out[1] + 4.0 * r).abs() < 1e-5);
    }

    #[test]
    fn causality_native() {
        let Some(w) = load("tiny") else { return };
        let mut rng = Rng::new(1601);
        let toks: Vec<i32> = (0..32).map(|_| rng.below(w.cfg.vocab) as i32).collect();
        let l1 = forward_window(&w, &toks);
        let mut toks2 = toks.clone();
        toks2[20] = (toks2[20] + 5) % w.cfg.vocab as i32;
        let l2 = forward_window(&w, &toks2);
        for t in 0..20 {
            for v in 0..w.cfg.vocab {
                assert!((l1[(t, v)] - l2[(t, v)]).abs() < 1e-4, "t={t}");
            }
        }
        let mut any_diff = false;
        for v in 0..w.cfg.vocab {
            if (l1[(20, v)] - l2[(20, v)]).abs() > 1e-4 {
                any_diff = true;
            }
        }
        assert!(any_diff);
    }

    #[test]
    fn trained_model_beats_uniform_ppl() {
        let Some(w) = load("tiny") else { return };
        let ppl = eval_ppl(&w, &w.val_tokens, 12);
        // python reported val ppl ≈ 3.96 for tiny; uniform would be 52.
        assert!(ppl < 6.0, "native ppl {ppl} too high — forward mismatch?");
        assert!(ppl > 1.5);
    }
}
