//! Model hyperparameters, serialized as the `config` i32 tensor in the
//! `.nqt` container (order fixed by python/compile/train.py).

use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub ctx: usize,
    pub d_model: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_ff: usize,
}

impl ModelConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_head
    }

    pub fn from_tensor(cfg: &[i32]) -> Result<Self> {
        if cfg.len() != 6 {
            bail!("config tensor must have 6 entries, got {}", cfg.len());
        }
        let c = ModelConfig {
            vocab: cfg[0] as usize,
            ctx: cfg[1] as usize,
            d_model: cfg[2] as usize,
            n_layer: cfg[3] as usize,
            n_head: cfg[4] as usize,
            d_ff: cfg[5] as usize,
        };
        if c.d_model % c.n_head != 0 {
            bail!("d_model {} not divisible by n_head {}", c.d_model, c.n_head);
        }
        if c.d_model % 8 != 0 || c.d_ff % 8 != 0 {
            bail!("dimensions must be divisible by the lattice dimension 8");
        }
        Ok(c)
    }

    /// Total parameter count (matches python `count_params`).
    pub fn n_params(&self) -> usize {
        let emb = self.vocab * self.d_model * 2 + self.ctx * self.d_model + self.d_model;
        let per_layer =
            2 * self.d_model + 4 * self.d_model * self.d_model + 2 * self.d_model * self.d_ff;
        emb + self.n_layer * per_layer
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_counts() {
        let c = ModelConfig::from_tensor(&[52, 128, 192, 4, 4, 512]).unwrap();
        assert_eq!(c.d_head(), 48);
        // python reported 1,422,528 for base
        assert_eq!(c.n_params(), 1_422_528);
    }

    #[test]
    fn rejects_bad_config() {
        assert!(ModelConfig::from_tensor(&[52, 128]).is_err());
        assert!(ModelConfig::from_tensor(&[52, 128, 190, 4, 4, 512]).is_err());
        assert!(ModelConfig::from_tensor(&[52, 128, 192, 4, 5, 512]).is_err());
    }
}
