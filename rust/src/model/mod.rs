//! The evaluation model: a GPT-style causal char-LM whose forward pass is
//! implemented natively in rust (bit-compatible with the JAX Layer-2
//! definition in `python/compile/model.py` — parity is asserted against
//! the PJRT-executed HLO artifact in `rust/tests/`).
//!
//! * [`config`]  — model hyperparameters (read from the `.nqt` container)
//! * [`weights`] — fp32 weight store loaded from `artifacts/model_*.nqt`
//! * [`forward`] — native forward pass (full-window scoring + incremental
//!   generation with a pluggable KV cache)
//! * [`engine`]  — the quantized inference engine: applies NestQuant /
//!   uniform / rotated baselines to weights, activations and KV cache in
//!   the paper's three regimes (W, W+KV, W+KV+A), with calibration-driven
//!   β selection and (QA-)LDLQ weight quantization

pub mod config;
pub mod engine;
pub mod forward;
pub mod weights;

pub use config::ModelConfig;
pub use engine::{
    ActQuant, Engine, EngineOptions, KvLaneCodec, Method, Regime, RotKind, SitePayload,
};
pub use weights::ModelWeights;
