//! Utilities shared across the crate: deterministic RNG, Gaussian sampling,
//! streaming statistics, a micro-benchmark harness and a small seeded
//! property-testing helper (criterion / proptest are unavailable in the
//! offline vendor set — see DESIGN.md §2).

pub mod bench;
pub mod linalg;
pub mod propcheck;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Welford;
