//! Utilities shared across the crate: deterministic RNG, Gaussian sampling,
//! streaming statistics, a micro-benchmark harness, a small seeded
//! property-testing helper (criterion / proptest are unavailable in the
//! offline vendor set — see DESIGN.md §2), and the deterministic
//! fail-point registry behind `fail_point!`.

pub mod bench;
pub mod failpoint;
pub mod linalg;
pub mod propcheck;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Welford;
