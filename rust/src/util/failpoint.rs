//! Deterministic fail-point injection (fail-rs style).
//!
//! Named sites are spread through the hot paths of the serving stack
//! (`kvpool/alloc`, `kvpool/decode`, `engine/prefill`, `engine/step_fused`,
//! `io/read`, `coordinator/worker`). A test arms a [`Scenario`], attaches a
//! [`FailSpec`] trigger schedule to one or more sites, and the instrumented
//! code panics (or runs a site-specific recovery expression) exactly when the
//! schedule says so — the same seed always fires the same hits, so fault-soak
//! tests are reproducible bit for bit.
//!
//! In release builds without the `failpoints` feature the whole subsystem
//! compiles down to a constant-false branch: [`armed`] is
//! `cfg!(any(debug_assertions, feature = "failpoints")) && ...`, so the
//! optimizer removes every site.

use std::cell::Cell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

/// When a site should fire, as a function of its 1-based hit count.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailSpec {
    /// Fire exactly on the n-th hit (1-based), then never again.
    Nth(u64),
    /// Fire on every n-th hit (n, 2n, 3n, ...).
    Every(u64),
    /// Fire on every hit with index >= n (1-based).
    From(u64),
    /// Fire pseudo-randomly on `percent`% of hits, deterministically
    /// derived from `seed`, the hit index, and the site name.
    Seeded { seed: u64, percent: u64 },
}

impl FailSpec {
    fn fires(&self, site: &str, hit: u64) -> bool {
        match *self {
            FailSpec::Nth(n) => hit == n,
            FailSpec::Every(n) => n > 0 && hit % n == 0,
            FailSpec::From(n) => hit >= n,
            FailSpec::Seeded { seed, percent } => {
                // FNV-1a over (seed, hit, site bytes), then splitmix finish.
                let mut h: u64 = 0xcbf2_9ce4_8422_2325;
                for b in seed
                    .to_le_bytes()
                    .iter()
                    .chain(hit.to_le_bytes().iter())
                    .chain(site.as_bytes())
                {
                    h ^= u64::from(*b);
                    h = h.wrapping_mul(0x0000_0100_0000_01b3);
                }
                h = (h ^ (h >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
                h = (h ^ (h >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
                h ^= h >> 31;
                h % 100 < percent
            }
        }
    }
}

#[derive(Clone, Debug, Default)]
struct SiteState {
    spec: Option<FailSpec>,
    hits: u64,
    fired: u64,
}

static ARMED: AtomicBool = AtomicBool::new(false);

thread_local! {
    // Only threads that belong to the active scenario see armed sites:
    // `cargo test` runs tests concurrently in one process, and a
    // globally-armed "engine/prefill" would panic an innocent test that
    // happens to prefill while a fault scenario runs elsewhere. The
    // scenario's own thread participates automatically; threads it
    // spawns opt in via [`join_scenario`] (the server worker does this
    // with the spawner's flag).
    static PARTICIPANT: Cell<bool> = const { Cell::new(false) };
}

/// Whether the current thread is inside the active fault scenario.
pub fn participating() -> bool {
    PARTICIPANT.with(|c| c.get())
}

/// Propagate scenario membership into a spawned thread: capture
/// [`participating`] on the spawning thread and pass it here from the
/// new thread before any fail-point site runs.
pub fn join_scenario(member: bool) {
    PARTICIPANT.with(|c| c.set(member));
}

fn registry() -> &'static Mutex<HashMap<String, SiteState>> {
    static REG: OnceLock<Mutex<HashMap<String, SiteState>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

fn reg_lock() -> MutexGuard<'static, HashMap<String, SiteState>> {
    registry().lock().unwrap_or_else(|e| e.into_inner())
}

/// True when fail points are compiled in AND a scenario is active.
///
/// The `cfg!` operand is a compile-time constant, so in release builds
/// without the `failpoints` feature this function is `false` and every
/// `fail_point!` site folds away.
#[inline]
pub fn armed() -> bool {
    cfg!(any(debug_assertions, feature = "failpoints"))
        && ARMED.load(Ordering::Relaxed)
        && participating()
}

/// Record a hit on `site`; return true when its schedule says to fire.
pub fn should_fail(site: &str) -> bool {
    let mut reg = reg_lock();
    let st = reg.entry(site.to_string()).or_default();
    st.hits += 1;
    let fire = st.spec.map(|s| s.fires(site, st.hits)).unwrap_or(false);
    if fire {
        st.fired += 1;
    }
    fire
}

/// Default fire action: panic with the site name. The containment layers in
/// `coordinator` are expected to catch this and tear down only the faulted
/// session.
pub fn trigger(site: &str) {
    if should_fail(site) {
        panic!("failpoint '{site}' fired");
    }
}

fn scenario_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

/// Serializes fault-injection tests (the registry is process-global) and
/// arms the sites for the duration of the guard. Dropping the scenario
/// disarms and clears every site.
pub struct Scenario {
    _serial: MutexGuard<'static, ()>,
}

/// Start a fault-injection scenario. Blocks until any other scenario in the
/// process has finished.
pub fn scenario() -> Scenario {
    let serial = scenario_lock().lock().unwrap_or_else(|e| e.into_inner());
    reg_lock().clear();
    join_scenario(true);
    ARMED.store(true, Ordering::SeqCst);
    Scenario { _serial: serial }
}

impl Scenario {
    /// Attach (or replace) the trigger schedule for `site`.
    pub fn fail(&self, site: &str, spec: FailSpec) {
        let mut reg = reg_lock();
        let st = reg.entry(site.to_string()).or_default();
        st.spec = Some(spec);
    }

    /// Total hits recorded on `site` so far (fired or not).
    pub fn hits(&self, site: &str) -> u64 {
        reg_lock().get(site).map(|s| s.hits).unwrap_or(0)
    }

    /// Number of times `site` actually fired.
    pub fn fired(&self, site: &str) -> u64 {
        reg_lock().get(site).map(|s| s.fired).unwrap_or(0)
    }
}

impl Drop for Scenario {
    fn drop(&mut self) {
        ARMED.store(false, Ordering::SeqCst);
        join_scenario(false);
        reg_lock().clear();
    }
}

/// Mark a potential fault site.
///
/// * `fail_point!("site")` — panics with the site name when the active
///   scenario's schedule fires (contained by `catch_unwind` at the
///   coordinator boundaries).
/// * `fail_point!("site", expr)` — runs `expr` instead of panicking; used
///   where the natural fault is an error return (e.g. an injected I/O error).
#[macro_export]
macro_rules! fail_point {
    ($site:expr) => {
        if $crate::util::failpoint::armed() {
            $crate::util::failpoint::trigger($site);
        }
    };
    ($site:expr, $on_fire:expr) => {
        if $crate::util::failpoint::armed() && $crate::util::failpoint::should_fail($site) {
            $on_fire
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_sites_never_fire() {
        // No scenario active: armed() is false, macro is a no-op.
        assert!(!armed());
        fail_point!("test/disarmed");
        // And should_fail without a spec never fires even when polled.
        assert!(!should_fail("test/disarmed-polled"));
    }

    #[test]
    fn nth_fires_exactly_once() {
        let sc = scenario();
        sc.fail("test/nth", FailSpec::Nth(3));
        let fired: Vec<bool> = (0..6).map(|_| should_fail("test/nth")).collect();
        assert_eq!(fired, vec![false, false, true, false, false, false]);
        assert_eq!(sc.hits("test/nth"), 6);
        assert_eq!(sc.fired("test/nth"), 1);
    }

    #[test]
    fn every_fires_periodically() {
        let sc = scenario();
        sc.fail("test/every", FailSpec::Every(2));
        let fired: Vec<bool> = (0..5).map(|_| should_fail("test/every")).collect();
        assert_eq!(fired, vec![false, true, false, true, false]);
        drop(sc);
    }

    #[test]
    fn from_fires_for_every_later_hit() {
        let sc = scenario();
        sc.fail("test/from", FailSpec::From(3));
        let fired: Vec<bool> = (0..5).map(|_| should_fail("test/from")).collect();
        assert_eq!(fired, vec![false, false, true, true, true]);
        drop(sc);
    }

    #[test]
    fn seeded_is_deterministic_and_roughly_calibrated() {
        let pattern = |seed: u64| -> Vec<bool> {
            let sc = scenario();
            sc.fail("test/seeded", FailSpec::Seeded { seed, percent: 30 });
            let v = (0..200).map(|_| should_fail("test/seeded")).collect();
            drop(sc);
            v
        };
        let a = pattern(42);
        let b = pattern(42);
        assert_eq!(a, b, "same seed must reproduce the same schedule");
        let c = pattern(43);
        assert_ne!(a, c, "different seeds should differ");
        let rate = a.iter().filter(|&&f| f).count();
        assert!((20..=100).contains(&rate), "30% of 200 hits, got {rate}");
    }

    #[test]
    fn macro_panics_when_fired_and_scenario_drop_disarms() {
        let sc = scenario();
        sc.fail("test/macro", FailSpec::Nth(1));
        let err = std::panic::catch_unwind(|| {
            fail_point!("test/macro");
        });
        assert!(err.is_err());
        assert_eq!(sc.fired("test/macro"), 1);
        drop(sc);
        assert!(!armed());
        // After the scenario ends the same site is inert again.
        fail_point!("test/macro");
    }

    #[test]
    fn macro_error_arm_runs_expression_instead_of_panicking() {
        let sc = scenario();
        sc.fail("test/errarm", FailSpec::Nth(1));
        let run = || -> Result<u32, String> {
            fail_point!("test/errarm", return Err("injected".to_string()));
            Ok(7)
        };
        assert_eq!(run(), Err("injected".to_string()));
        assert_eq!(run(), Ok(7));
        drop(sc);
    }
}
