//! Streaming statistics (Welford), quantiles, and small linear-algebra
//! helpers used by experiments and the quantization pipeline.

/// Streaming mean/variance accumulator (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Merge another accumulator (parallel Welford).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
    }
}

/// Quantile of a sample (linear interpolation); `q` in [0,1].
pub fn quantile(xs: &mut [f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (xs.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        xs[lo]
    } else {
        xs[lo] + (pos - lo as f64) * (xs[hi] - xs[lo])
    }
}

/// Mean squared error between two slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let mut s = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let d = (x - y) as f64;
        s += d * d;
    }
    s / a.len() as f64
}

/// Root mean squared error.
pub fn rmse(a: &[f32], b: &[f32]) -> f64 {
    mse(a, b).sqrt()
}

/// Squared L2 norm.
#[inline]
pub fn norm2_sq(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// L2 norm.
#[inline]
pub fn norm2(x: &[f32]) -> f64 {
    norm2_sq(x).sqrt()
}

/// Dot product in f64 accumulation.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Shannon entropy (bits) of a discrete histogram of counts.
pub fn entropy_bits(counts: &[u64]) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &c in counts {
        if c > 0 {
            let p = c as f64 / total as f64;
            h -= p * p.log2();
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean: f64 = xs.iter().sum::<f64>() / xs.len() as f64;
        let var: f64 =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut all = Welford::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.var() - all.var()).abs() < 1e-10);
    }

    #[test]
    fn quantile_endpoints() {
        let mut xs = vec![3.0, 1.0, 2.0];
        assert_eq!(quantile(&mut xs, 0.0), 1.0);
        assert_eq!(quantile(&mut xs, 1.0), 3.0);
        assert_eq!(quantile(&mut xs, 0.5), 2.0);
    }

    #[test]
    fn entropy_uniform() {
        assert!((entropy_bits(&[1, 1, 1, 1]) - 2.0).abs() < 1e-12);
        assert_eq!(entropy_bits(&[5, 0, 0]), 0.0);
    }

    #[test]
    fn mse_zero_for_equal() {
        let a = [1.0f32, -2.0, 3.5];
        assert_eq!(mse(&a, &a), 0.0);
    }
}
