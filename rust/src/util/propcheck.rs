//! Minimal seeded property-testing helper (proptest is unavailable in the
//! offline vendor set). Runs a property over `n` randomized cases derived
//! from a base seed; on failure reports the failing case seed so the case
//! can be replayed deterministically.

use super::rng::Rng;

/// Run `prop` over `n` randomized cases. `prop` receives a per-case RNG and
/// returns `Err(msg)` on property violation. Panics with the case seed on
/// the first failure.
pub fn check<F>(name: &str, n: usize, base_seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..n {
        let case_seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{n} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("sum-commutes", 50, 1, |rng| {
            let a = rng.f64();
            let b = rng.f64();
            if (a + b - (b + a)).abs() < 1e-15 {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failures() {
        check("always-fails", 5, 2, |_rng| Err("nope".into()));
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0], &[1.0001], 1e-3, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3, 0.0).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3, 0.0).is_err());
    }
}
