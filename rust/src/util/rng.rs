//! Deterministic, fast pseudo-random generation: xoshiro256++ seeded via
//! SplitMix64, plus Gaussian and other samplers used by the experiment
//! harness. All experiments in `results/` are reproducible from seeds.

/// xoshiro256++ PRNG (Blackman & Vigna). Deterministic, 2^256-1 period,
/// passes BigCrush; plenty for Monte-Carlo experiment workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Gaussian from Box-Muller
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free enough for non-crypto use.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard Gaussian via Box–Muller (with spare caching).
    #[inline]
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u in (0,1] to avoid ln(0)
        let u = 1.0 - self.f64();
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let (s, c) = (std::f64::consts::TAU * v).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Standard Gaussian as f32.
    #[inline]
    pub fn gauss_f32(&mut self) -> f32 {
        self.gauss() as f32
    }

    /// Fill a slice with iid N(0,1) f32s.
    pub fn fill_gauss(&mut self, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.gauss_f32();
        }
    }

    /// A fresh vector of n iid N(0,1) f32s.
    pub fn gauss_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0f32; n];
        self.fill_gauss(&mut v);
        v
    }

    /// Random ±1 signs.
    pub fn sign_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
            .collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        const N: usize = 100_000;
        for _ in 0..N {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(3);
        const N: usize = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..N {
            let z = r.gauss();
            m1 += z;
            m2 += z * z;
        }
        m1 /= N as f64;
        m2 /= N as f64;
        assert!(m1.abs() < 0.02, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.02, "var={m2}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for n in [1usize, 2, 7, 100] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
