//! Tiny micro-benchmark harness (criterion is unavailable in the offline
//! vendor set). Provides warmup, repeated timing, and median/MAD reporting,
//! which is what the paper-table benchmarks need.

use std::time::{Duration, Instant};

/// Result of a timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// median wall time per iteration
    pub median: Duration,
    /// median absolute deviation
    pub mad: Duration,
    /// number of timed iterations
    pub iters: usize,
}

impl BenchResult {
    pub fn median_us(&self) -> f64 {
        self.median.as_secs_f64() * 1e6
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>12.2} µs/iter (±{:.2}, n={})",
            self.name,
            self.median_us(),
            self.mad.as_secs_f64() * 1e6,
            self.iters
        )
    }
}

/// Benchmark `f`, autoscaling iteration count to fill ~`budget`.
/// `f` should perform one unit of work and return something observable
/// (returned value is black-boxed to prevent dead-code elimination).
pub fn bench<T, F: FnMut() -> T>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup + calibration: run until 10% of the budget is consumed.
    let calib_start = Instant::now();
    let mut calib_iters = 0usize;
    while calib_start.elapsed() < budget / 10 {
        black_box(f());
        calib_iters += 1;
        if calib_iters > 1_000_000 {
            break;
        }
    }
    let per_iter = calib_start.elapsed() / calib_iters.max(1) as u32;

    // Aim for ~30 samples of batched iterations within the budget.
    let samples = 30usize;
    let batch = ((budget.as_secs_f64() / samples as f64 / per_iter.as_secs_f64().max(1e-9))
        .ceil() as usize)
        .max(1);

    let mut times: Vec<f64> = Vec::with_capacity(samples);
    let mut total_iters = 0usize;
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..batch {
            black_box(f());
        }
        times.push(t0.elapsed().as_secs_f64() / batch as f64);
        total_iters += batch;
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];

    BenchResult {
        name: name.to_string(),
        median: Duration::from_secs_f64(median),
        mad: Duration::from_secs_f64(mad),
        iters: total_iters,
    }
}

/// Prevent the optimizer from eliding a computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let data: Vec<f64> = (0..20_000).map(|i| i as f64).collect();
        let r = bench("sum-20k", Duration::from_millis(50), || {
            data.iter().map(|&x| x.sqrt()).sum::<f64>()
        });
        assert!(r.iters > 0);
        assert!(r.median > Duration::ZERO);
    }

    #[test]
    fn report_formats() {
        let r = BenchResult {
            name: "x".into(),
            median: Duration::from_micros(12),
            mad: Duration::from_micros(1),
            iters: 10,
        };
        assert!(r.report().contains("µs/iter"));
    }
}
