//! Tiny micro-benchmark harness (criterion is unavailable in the offline
//! vendor set). Provides warmup, repeated timing, and median/MAD reporting,
//! which is what the paper-table benchmarks need.

use std::time::{Duration, Instant};

/// Result of a timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// median wall time per iteration
    pub median: Duration,
    /// median absolute deviation
    pub mad: Duration,
    /// number of timed iterations
    pub iters: usize,
}

impl BenchResult {
    pub fn median_us(&self) -> f64 {
        self.median.as_secs_f64() * 1e6
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>12.2} µs/iter (±{:.2}, n={})",
            self.name,
            self.median_us(),
            self.mad.as_secs_f64() * 1e6,
            self.iters
        )
    }
}

/// Benchmark `f`, autoscaling iteration count to fill ~`budget`.
/// `f` should perform one unit of work and return something observable
/// (returned value is black-boxed to prevent dead-code elimination).
pub fn bench<T, F: FnMut() -> T>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup + calibration: run until 10% of the budget is consumed.
    let calib_start = Instant::now();
    let mut calib_iters = 0usize;
    while calib_start.elapsed() < budget / 10 {
        black_box(f());
        calib_iters += 1;
        if calib_iters > 1_000_000 {
            break;
        }
    }
    let per_iter = calib_start.elapsed() / calib_iters.max(1) as u32;

    // Aim for ~30 samples of batched iterations within the budget.
    let samples = 30usize;
    let batch = ((budget.as_secs_f64() / samples as f64 / per_iter.as_secs_f64().max(1e-9))
        .ceil() as usize)
        .max(1);

    let mut times: Vec<f64> = Vec::with_capacity(samples);
    let mut total_iters = 0usize;
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..batch {
            black_box(f());
        }
        times.push(t0.elapsed().as_secs_f64() / batch as f64);
        total_iters += batch;
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];

    BenchResult {
        name: name.to_string(),
        median: Duration::from_secs_f64(median),
        mad: Duration::from_secs_f64(mad),
        iters: total_iters,
    }
}

/// Prevent the optimizer from eliding a computation.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Machine-readable bench output: a named suite of [`BenchResult`]s with
/// numeric tags (batch size, thread count, derived per-column costs…),
/// serialized as JSON so the perf trajectory is trackable across PRs
/// (`bench_main` writes the GEMV/GEMM suite to `BENCH_gemm.json`).
/// Hand-rolled writer — serde is unavailable in the offline vendor set.
pub struct BenchSuite {
    pub name: String,
    records: Vec<(BenchResult, Vec<(String, f64)>)>,
}

impl BenchSuite {
    pub fn new(name: &str) -> Self {
        BenchSuite {
            name: name.to_string(),
            records: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Record one result with numeric tags attached.
    pub fn push(&mut self, r: &BenchResult, tags: &[(&str, f64)]) {
        self.records.push((
            r.clone(),
            tags.iter().map(|&(k, v)| (k.to_string(), v)).collect(),
        ));
    }

    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"suite\": \"{}\",\n", json_escape(&self.name)));
        s.push_str("  \"results\": [\n");
        for (i, (r, tags)) in self.records.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"median_us\": {}, \"mad_us\": {}, \"iters\": {}",
                json_escape(&r.name),
                json_num(r.median_us()),
                json_num(r.mad.as_secs_f64() * 1e6),
                r.iters
            ));
            for (k, v) in tags {
                s.push_str(&format!(", \"{}\": {}", json_escape(k), json_num(*v)));
            }
            s.push('}');
            if i + 1 < self.records.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("  ]\n}\n");
        s
    }

    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Combined multi-suite document `{"suites": [...]}`. `bench_main`
/// sections each build their own [`BenchSuite`] and the binary writes
/// them to `BENCH_gemm.json` in ONE call — previously each section
/// clobbered the file with its own single-suite object.
pub fn suites_json(suites: &[&BenchSuite]) -> String {
    let mut s = String::from("{\n\"suites\": [\n");
    for (i, su) in suites.iter().enumerate() {
        s.push_str(su.to_json().trim_end());
        if i + 1 < suites.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("]\n}\n");
    s
}

/// Write the combined `{"suites": [...]}` document to `path`.
pub fn write_suites_json(path: &std::path::Path, suites: &[&BenchSuite]) -> std::io::Result<()> {
    std::fs::write(path, suites_json(suites))
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".to_string()
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let data: Vec<f64> = (0..20_000).map(|i| i as f64).collect();
        let r = bench("sum-20k", Duration::from_millis(50), || {
            data.iter().map(|&x| x.sqrt()).sum::<f64>()
        });
        assert!(r.iters > 0);
        assert!(r.median > Duration::ZERO);
    }

    #[test]
    fn report_formats() {
        let r = BenchResult {
            name: "x".into(),
            median: Duration::from_micros(12),
            mad: Duration::from_micros(1),
            iters: 10,
        };
        assert!(r.report().contains("µs/iter"));
    }

    #[test]
    fn suite_serializes_json() {
        let r = BenchResult {
            name: "gemm \"fast\"".into(),
            median: Duration::from_micros(100),
            mad: Duration::from_micros(2),
            iters: 30,
        };
        let mut suite = BenchSuite::new("gemm");
        suite.push(&r, &[("batch", 32.0), ("threads", 2.0)]);
        suite.push(&r, &[("batch", 1.0)]);
        assert_eq!(suite.len(), 2);
        let j = suite.to_json();
        assert!(j.contains("\"suite\": \"gemm\""));
        assert!(j.contains("\\\"fast\\\""), "quotes must be escaped: {j}");
        assert!(j.contains("\"median_us\": 100.000000"));
        assert!(j.contains("\"batch\": 32.000000"));
        assert!(j.contains("\"threads\": 2.000000"));
        // balanced braces/brackets as a cheap well-formedness proxy
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        // round-trip to disk
        let p = std::env::temp_dir().join("nqt_bench_suite_test.json");
        suite.write_json(&p).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), j);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn multi_suite_document_serializes_every_suite() {
        let r = BenchResult {
            name: "x".into(),
            median: Duration::from_micros(5),
            mad: Duration::ZERO,
            iters: 3,
        };
        let mut a = BenchSuite::new("core");
        a.push(&r, &[("batch", 1.0)]);
        let mut b = BenchSuite::new("lut");
        b.push(&r, &[("q", 2.0), ("m_levels", 4.0)]);
        let j = suites_json(&[&a, &b]);
        assert!(j.starts_with("{\n\"suites\": ["));
        assert!(j.contains("\"suite\": \"core\""));
        assert!(j.contains("\"suite\": \"lut\""));
        assert!(j.contains("\"m_levels\": 4.000000"));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        let p = std::env::temp_dir().join("nqt_bench_suites_test.json");
        write_suites_json(&p, &[&a, &b]).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), j);
        std::fs::remove_file(&p).ok();
    }
}
