//! Dense linear algebra used by the quantization pipeline and the native
//! model forward: row-major f32 matrices with f64 accumulation where
//! numerical robustness matters (Cholesky/LDL for LDLQ Hessians).

/// Row-major matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// self · other, blocked over k for cache friendliness.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        matmul_into(
            &self.data,
            &other.data,
            &mut out.data,
            self.rows,
            self.cols,
            other.cols,
        );
        out
    }

    /// self · v for a vector v.
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, v.len());
        let mut out = vec![0f32; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0f32;
            for (a, b) in row.iter().zip(v) {
                acc += a * b;
            }
            out[i] = acc;
        }
        out
    }

    pub fn scale(&mut self, c: f32) {
        for x in self.data.iter_mut() {
            *x *= c;
        }
    }

    pub fn add_diag(&mut self, c: f32) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self[(i, i)] += c;
        }
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

/// C (m×n) = A (m×k) · B (k×n), row-major, ikj loop order (streams B rows).
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// LDL^T decomposition of a symmetric positive-definite matrix (f64
/// accumulation). Returns (L unit-lower-triangular, d diagonal).
pub fn ldl(h: &Mat) -> (Mat, Vec<f64>) {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    let mut l = Mat::eye(n);
    let mut d = vec![0f64; n];
    // working copy in f64
    let mut lw = vec![0f64; n * n];
    for j in 0..n {
        let mut dj = h[(j, j)] as f64;
        for k in 0..j {
            dj -= lw[j * n + k] * lw[j * n + k] * d[k];
        }
        d[j] = dj;
        assert!(dj > 0.0, "matrix not positive definite at {j} (d={dj})");
        for i in j + 1..n {
            let mut v = h[(i, j)] as f64;
            for k in 0..j {
                v -= lw[i * n + k] * lw[j * n + k] * d[k];
            }
            lw[i * n + j] = v / dj;
        }
    }
    for i in 0..n {
        for j in 0..i {
            l[(i, j)] = lw[i * n + j] as f32;
        }
    }
    (l, d)
}

/// Block LDLᵀ decomposition with block size `b`: H = L·D·Lᵀ where L is
/// block-unit-lower-triangular (identity b×b diagonal blocks) and D is
/// block diagonal (SPD b×b blocks). With b = 1 this reduces to scalar
/// [`ldl`]. Used by block-LDLQ: quantizing b-blocks jointly requires the
/// within-block coupling to live in D, not in the feedback L — otherwise
/// the error recursion diverges under strongly correlated Hessians.
/// Returns (L, D-blocks in block-row order).
pub fn block_ldl(h: &Mat, b: usize) -> (Mat, Vec<Vec<f64>>) {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    assert_eq!(n % b, 0);
    let nb = n / b;
    // working Schur complement in f64
    let mut s: Vec<f64> = h.data.iter().map(|&x| x as f64).collect();
    let mut l = Mat::eye(n);
    let mut d_blocks: Vec<Vec<f64>> = Vec::with_capacity(nb);

    // invert an SPD b×b block (Gauss-Jordan, f64)
    let inv_block = |m: &[f64]| -> Vec<f64> {
        let mut a = m.to_vec();
        let mut inv = vec![0f64; b * b];
        for i in 0..b {
            inv[i * b + i] = 1.0;
        }
        for col in 0..b {
            // partial pivot within SPD block (diagonal is positive)
            let piv = a[col * b + col];
            assert!(piv.abs() > 1e-12, "singular diagonal block");
            let inv_piv = 1.0 / piv;
            for j in 0..b {
                a[col * b + j] *= inv_piv;
                inv[col * b + j] *= inv_piv;
            }
            for row in 0..b {
                if row == col {
                    continue;
                }
                let f = a[row * b + col];
                if f == 0.0 {
                    continue;
                }
                for j in 0..b {
                    a[row * b + j] -= f * a[col * b + j];
                    inv[row * b + j] -= f * inv[col * b + j];
                }
            }
        }
        inv
    };

    for jb in 0..nb {
        let j0 = jb * b;
        // D_J = current Schur diagonal block
        let mut dj = vec![0f64; b * b];
        for r in 0..b {
            for c in 0..b {
                dj[r * b + c] = s[(j0 + r) * n + (j0 + c)];
            }
        }
        let dj_inv = inv_block(&dj);
        d_blocks.push(dj.clone());
        // L_{I,J} = S_{I,J} · D_J⁻¹ for I > J, then update Schur complement
        for ib in jb + 1..nb {
            let i0 = ib * b;
            let mut lij = vec![0f64; b * b];
            for r in 0..b {
                for c in 0..b {
                    let mut acc = 0f64;
                    for k in 0..b {
                        acc += s[(i0 + r) * n + (j0 + k)] * dj_inv[k * b + c];
                    }
                    lij[r * b + c] = acc;
                }
            }
            for r in 0..b {
                for c in 0..b {
                    l[(i0 + r, j0 + c)] = lij[r * b + c] as f32;
                }
            }
        }
        // S_{I,K} -= L_{I,J} · S_{J,K} for I,K > J (row update form)
        for ib in jb + 1..nb {
            let i0 = ib * b;
            for r in 0..b {
                for k in j0 + b..n {
                    let mut acc = 0f64;
                    for c in 0..b {
                        acc += l[(i0 + r, j0 + c)] as f64 * s[(j0 + c) * n + k];
                    }
                    s[(i0 + r) * n + k] -= acc;
                }
            }
        }
    }
    (l, d_blocks)
}

/// Cholesky factor (lower) of an SPD matrix, f64 accumulation.
pub fn cholesky(h: &Mat) -> Mat {
    let (l, d) = ldl(h);
    let n = h.rows;
    let mut c = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            c[(i, j)] = l[(i, j)] * (d[j].sqrt() as f32);
        }
    }
    c
}

/// Solve L x = b with L lower triangular (diagonal non-unit).
pub fn solve_lower(l: &Mat, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut x = vec![0f32; n];
    for i in 0..n {
        let mut acc = b[i] as f64;
        for j in 0..i {
            acc -= l[(i, j)] as f64 * x[j] as f64;
        }
        x[i] = (acc / l[(i, i)] as f64) as f32;
    }
    x
}

/// Solve Lᵀ x = b with L lower triangular.
pub fn solve_lower_t(l: &Mat, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut x = vec![0f32; n];
    for i in (0..n).rev() {
        let mut acc = b[i] as f64;
        for j in i + 1..n {
            acc -= l[(j, i)] as f64 * x[j] as f64;
        }
        x[i] = (acc / l[(i, i)] as f64) as f32;
    }
    x
}

/// Inverse of an SPD matrix via Cholesky (column-by-column solves).
pub fn invert_spd(h: &Mat) -> Mat {
    let n = h.rows;
    let l = cholesky(h);
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0f32; n];
    for c in 0..n {
        e.fill(0.0);
        e[c] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_t(&l, &y);
        for r in 0..n {
            inv[(r, c)] = x[r];
        }
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut a = Mat::zeros(n, n);
        for x in a.data.iter_mut() {
            *x = rng.gauss_f32();
        }
        let mut h = a.transpose().matmul(&a);
        h.add_diag(0.5 + n as f32 * 0.01);
        h
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(601);
        let a = Mat::from_vec(3, 5, rng.gauss_vec(15));
        let i5 = Mat::eye(5);
        assert_eq!(a.matmul(&i5).data, a.data);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.matmul(&b).data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn ldl_reconstructs() {
        let h = random_spd(12, 602);
        let (l, d) = ldl(&h);
        // L D Lᵀ = H
        let n = h.rows;
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0f64;
                for k in 0..n {
                    acc += l[(i, k)] as f64 * d[k] * l[(j, k)] as f64;
                }
                assert!(
                    (acc - h[(i, j)] as f64).abs() < 1e-3,
                    "LDL mismatch at ({i},{j}): {acc} vs {}",
                    h[(i, j)]
                );
            }
        }
        // L unit lower triangular
        for i in 0..n {
            assert_eq!(l[(i, i)], 1.0);
            for j in i + 1..n {
                assert_eq!(l[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn block_ldl_b1_equals_scalar_ldl() {
        let h = random_spd(12, 606);
        let (l1, d1) = ldl(&h);
        let (lb, db) = block_ldl(&h, 1);
        for i in 0..12 {
            assert!((db[i][0] - d1[i]).abs() < 1e-6);
            for j in 0..12 {
                assert!((l1[(i, j)] - lb[(i, j)]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn block_ldl_reconstructs() {
        let n = 16;
        let b = 4;
        let h = random_spd(n, 607);
        let (l, d) = block_ldl(&h, b);
        // assemble D as a dense matrix
        let mut dm = Mat::zeros(n, n);
        for (jb, blk) in d.iter().enumerate() {
            for r in 0..b {
                for c in 0..b {
                    dm[(jb * b + r, jb * b + c)] = blk[r * b + c] as f32;
                }
            }
        }
        let rec = l.matmul(&dm).matmul(&l.transpose());
        for i in 0..n {
            for j in 0..n {
                assert!(
                    (rec[(i, j)] - h[(i, j)]).abs() < 1e-2,
                    "block LDL mismatch at ({i},{j}): {} vs {}",
                    rec[(i, j)],
                    h[(i, j)]
                );
            }
        }
        // diagonal blocks of L are identity; upper blocks zero
        for i in 0..n {
            for j in 0..n {
                let (ib, jb) = (i / b, j / b);
                if ib == jb {
                    let expect = if i == j { 1.0 } else { 0.0 };
                    assert_eq!(l[(i, j)], expect);
                } else if jb > ib {
                    assert_eq!(l[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn invert_spd_is_inverse() {
        let h = random_spd(16, 603);
        let inv = invert_spd(&h);
        let prod = h.matmul(&inv);
        for i in 0..16 {
            for j in 0..16 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (prod[(i, j)] - expect).abs() < 1e-2,
                    "H·H⁻¹ at ({i},{j}) = {}",
                    prod[(i, j)]
                );
            }
        }
    }

    #[test]
    fn triangular_solves() {
        let h = random_spd(10, 604);
        let l = cholesky(&h);
        let mut rng = Rng::new(605);
        let b = rng.gauss_vec(10);
        let y = solve_lower(&l, &b);
        // L y = b
        let ly = l.matvec(&y);
        for (u, v) in ly.iter().zip(&b) {
            assert!((u - v).abs() < 1e-4);
        }
        let x = solve_lower_t(&l, &b);
        let ltx = l.transpose().matvec(&x);
        for (u, v) in ltx.iter().zip(&b) {
            assert!((u - v).abs() < 1e-4);
        }
    }
}
