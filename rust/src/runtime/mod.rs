//! PJRT runtime: loads AOT-compiled HLO-text artifacts (produced by the
//! Layer-2 `python/compile/aot.py`) and executes them on the CPU PJRT
//! client. Python is never on this path — the artifacts are compiled once
//! at load time and the executables are reused per request.
//!
//! Arguments are passed as cached `Literal`s: the xla-0.1.6
//! `buffer_from_host_literal` + `execute_b` path trips a fatal
//! `literal.size_bytes() == b->size()` check for non-register-aligned
//! shapes on the CPU plugin, while the Literal execute path round-trips
//! cleanly (see /opt/xla-example/load_hlo).

use crate::model::weights::ModelWeights;
use anyhow::{Context, Result};
use std::path::Path;

/// A compiled HLO artifact.
pub struct HloExecutable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one client, many executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<HloExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {path:?}"))?;
        Ok(HloExecutable {
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            exe,
        })
    }

    /// Build an f32 literal of the given shape.
    pub fn lit_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
    }

    /// Build an i32 literal of the given shape.
    pub fn lit_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
    }
}

impl HloExecutable {
    /// Execute with literal arguments; returns the first tuple output's
    /// f32 data (artifacts are lowered with return_tuple=True).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(&self, args: &[L]) -> Result<Vec<f32>> {
        let outs = self.exe.execute::<L>(args)?;
        let lit = outs[0][0].to_literal_sync()?;
        let out = lit.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// A model forward executable with cached weight literals: the serving
/// scoring path (tokens → logits) with zero python on the request path.
pub struct ModelRunner {
    pub batch: usize,
    pub ctx: usize,
    pub vocab: usize,
    exe: HloExecutable,
    weight_lits: Vec<xla::Literal>,
    rt: Runtime,
}

impl ModelRunner {
    /// Load `model_fwd_<name>_b<batch>.hlo.txt` and cache `weights`
    /// (fp32 or fake-quantized — the artifact takes weights as arguments,
    /// so any quantization regime can be served through the same HLO).
    pub fn load(
        artifacts_dir: &Path,
        name: &str,
        batch: usize,
        weights: &ModelWeights,
    ) -> Result<Self> {
        let rt = Runtime::cpu()?;
        let exe =
            rt.load_hlo(&artifacts_dir.join(format!("model_fwd_{name}_b{batch}.hlo.txt")))?;
        let mut weight_lits = Vec::new();
        for (_nm, dims, data) in weights.flat_params() {
            weight_lits.push(rt.lit_f32(&data, &dims)?);
        }
        Ok(ModelRunner {
            batch,
            ctx: weights.cfg.ctx,
            vocab: weights.cfg.vocab,
            exe,
            weight_lits,
            rt,
        })
    }

    /// Score a token batch: tokens (batch·ctx) → flat logits
    /// (batch·ctx·vocab).
    pub fn forward(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == self.batch * self.ctx, "bad token shape");
        let tok_lit = self.rt.lit_i32(tokens, &[self.batch, self.ctx])?;
        let mut refs: Vec<&xla::Literal> = Vec::with_capacity(1 + self.weight_lits.len());
        refs.push(&tok_lit);
        for l in &self.weight_lits {
            refs.push(l);
        }
        self.exe.run(&refs)
    }

    /// Mean next-token NLL per window of a scored batch.
    pub fn batch_nll(&self, tokens_in: &[i32], targets: &[i32], logits: &[f32]) -> Vec<f64> {
        let v = self.vocab;
        let s = self.ctx;
        let mut out = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            let mut nll = 0f64;
            for t in 0..s {
                let row = &logits[(b * s + t) * v..(b * s + t + 1) * v];
                let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
                let logsum: f64 = (row.iter().map(|&x| ((x - max) as f64).exp()).sum::<f64>())
                    .ln()
                    + max as f64;
                nll += logsum - row[targets[b * s + t] as usize] as f64;
            }
            out.push(nll / s as f64);
        }
        let _ = tokens_in;
        out
    }
}
