//! `nestquant` — CLI for the NestQuant reproduction.
//!
//! ```text
//! nestquant exp <id|all> [--artifacts DIR] [--results DIR]
//!     regenerate paper tables/figures (see DESIGN.md §4)
//! nestquant ppl <model> [--regime fp|w|wkv|wkva] [--q Q] [--method M]
//!     evaluate perplexity of a quantized model
//! nestquant serve <model> [--requests N] [--batch B]
//!     run the serving coordinator demo (quantized KV cache)
//! nestquant generate <model> <prompt> [--tokens N]
//!     generate text with the quantized engine
//! ```
//!
//! (clap is unavailable offline; arguments are parsed by hand.)

use anyhow::{bail, Context, Result};
use nestquant::coordinator::generator::GenSession;
use nestquant::model::engine::{Engine, EngineOptions, Method, Regime};
use nestquant::model::weights::{artifact_path, ModelWeights};
use std::path::PathBuf;

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn parse_method(s: &str) -> Result<Method> {
    Ok(match s {
        "rtn" => Method::Rtn,
        "uniform" => Method::UniformRot,
        "uniform-ldlq" => Method::UniformRotLdlq,
        "nestquant" => Method::NestQuant,
        "nestquantm" => Method::NestQuantM,
        other => bail!("unknown method '{other}'"),
    })
}

fn parse_regime(s: &str) -> Result<Regime> {
    Ok(match s {
        "fp" => Regime::Fp,
        "w" => Regime::W,
        "wkv" => Regime::WKv,
        "wkva" => Regime::WKvA,
        other => bail!("unknown regime '{other}'"),
    })
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let artifacts = PathBuf::from(
        flag(&args, "--artifacts").unwrap_or_else(|| "artifacts".into()),
    );
    let results = PathBuf::from(flag(&args, "--results").unwrap_or_else(|| "results".into()));

    match cmd {
        "exp" => {
            let id = args.get(1).context("usage: nestquant exp <id|all>")?;
            nestquant::experiments::run(id, &artifacts, &results)?;
        }
        "ppl" => {
            let model = args.get(1).context("usage: nestquant ppl <model>")?;
            let w = ModelWeights::load(&artifact_path(&artifacts, model))?;
            let regime = parse_regime(&flag(&args, "--regime").unwrap_or_else(|| "wkva".into()))?;
            let method =
                parse_method(&flag(&args, "--method").unwrap_or_else(|| "nestquant".into()))?;
            let q: u32 = flag(&args, "--q").unwrap_or_else(|| "14".into()).parse()?;
            let windows: usize = flag(&args, "--windows")
                .unwrap_or_else(|| "8".into())
                .parse()?;
            if regime == Regime::Fp {
                let ppl = nestquant::model::forward::eval_ppl(&w, &w.val_tokens, windows);
                println!("fp32 ppl = {ppl:.4}");
            } else {
                let eng = Engine::build(
                    &w,
                    EngineOptions {
                        method,
                        regime,
                        q,
                        ..Default::default()
                    },
                );
                let ppl = eng.eval_ppl(&w.val_tokens, windows);
                println!(
                    "{} {} q={q}: ppl = {ppl:.4} (bits {:.2} zstd / {:.2} packed)",
                    method.label(),
                    regime.label(),
                    eng.weight_bits_zstd,
                    eng.weight_bits_packed
                );
            }
        }
        "serve" => {
            let model = args.get(1).context("usage: nestquant serve <model>")?;
            let n_req: usize = flag(&args, "--requests")
                .unwrap_or_else(|| "8".into())
                .parse()?;
            let batch: usize = flag(&args, "--batch").unwrap_or_else(|| "4".into()).parse()?;
            let w = ModelWeights::load(&artifact_path(&artifacts, model))?;
            let eng = std::sync::Arc::new(Engine::build(
                &w,
                EngineOptions {
                    regime: Regime::WKv,
                    calib_windows: 2,
                    ..Default::default()
                },
            ));
            let (srv, rx) = nestquant::coordinator::Server::start(
                eng,
                nestquant::coordinator::ServerConfig {
                    policy: nestquant::coordinator::BatchPolicy {
                        max_batch: batch,
                        ..Default::default()
                    },
                    ..Default::default()
                },
            );
            let t0 = std::time::Instant::now();
            for i in 0..n_req {
                let start = (i * 37) % (w.val_tokens.len() - 32);
                srv.submit(nestquant::coordinator::Request::Generate {
                    id: i as u64,
                    prompt: w.val_tokens[start..start + 16].to_vec(),
                    n_new: 32,
                });
            }
            for _ in 0..n_req {
                let r = rx.recv()?;
                println!(
                    "request {} done: {} tokens, {:.1} ms",
                    r.id,
                    r.tokens.len(),
                    r.latency_ms
                );
            }
            println!("wall: {:.2}s", t0.elapsed().as_secs_f64());
            println!("{}", srv.metrics.report());
            srv.shutdown();
        }
        "generate" => {
            let model = args
                .get(1)
                .context("usage: nestquant generate <model> <prompt>")?;
            let prompt_str = args.get(2).context("missing prompt")?;
            let n: usize = flag(&args, "--tokens")
                .unwrap_or_else(|| "64".into())
                .parse()?;
            let w = ModelWeights::load(&artifact_path(&artifacts, model))?;
            let eng = Engine::build(
                &w,
                EngineOptions {
                    regime: Regime::WKv,
                    calib_windows: 2,
                    ..Default::default()
                },
            );
            const VOCAB: &str = "abcdefghijklmnopqrstuvwxyz0123456789 .,;=+-()[]{}<>\n";
            let prompt: Vec<i32> = prompt_str
                .chars()
                .filter_map(|c| VOCAB.find(c).map(|i| i as i32))
                .collect();
            let mut sess = GenSession::new(&eng);
            let out = sess.generate(&prompt, n);
            let text: String = out
                .iter()
                .map(|&t| VOCAB.chars().nth(t as usize).unwrap_or('?'))
                .collect();
            println!("{prompt_str}{text}");
            println!(
                "\n[kv cache: {} bytes for {} positions]",
                sess.kv_bytes(),
                sess.position()
            );
        }
        _ => {
            println!(
                "nestquant — NestQuant (ICML 2025) reproduction\n\
                 usage:\n  nestquant exp <id|all>\n  nestquant ppl <model> \
                 [--regime fp|w|wkv|wkva] [--method rtn|uniform|uniform-ldlq|nestquant|nestquantm] [--q Q]\n  \
                 nestquant serve <model> [--requests N] [--batch B]\n  \
                 nestquant generate <model> <prompt> [--tokens N]"
            );
        }
    }
    Ok(())
}
