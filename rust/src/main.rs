//! `nestquant` — CLI for the NestQuant reproduction.
//!
//! ```text
//! nestquant exp <id|all> [--artifacts DIR] [--results DIR]
//!     regenerate paper tables/figures (see DESIGN.md §4)
//! nestquant ppl <model> [--regime fp|w|wkv|wkva] [--method M] [--q Q]
//!               [--k K] [--uniform-bits B] [--windows N] [--plan FILE]
//!     evaluate perplexity of a quantized model. Flag defaults follow
//!     `EngineOptions::default()`.
//! nestquant serve <model> [--requests N] [--batch B] [quant flags]
//!               [--trace-out FILE] [--metrics-out FILE] [--metrics-listen ADDR]
//!     run the serving coordinator demo (pooled, coded KV cache).
//!     `--trace-out` writes a Chrome trace-event JSON of the run (open
//!     in ui.perfetto.dev), `--metrics-out` a Prometheus text snapshot,
//!     and `--metrics-listen 127.0.0.1:PORT` serves live Prometheus
//!     scrapes while the demo runs.
//! nestquant generate <model> <prompt> [--tokens N] [quant flags]
//!     generate text with the quantized engine
//! ```
//!
//! `ppl`, `serve` and `generate` all accept the same quantization
//! flags: `--plan FILE` loads a per-site `.qplan` policy file (mixed
//! precision; overrides the uniform flags below and is validated through
//! one shared load path), while `--regime/--method/--q/--k/
//! --uniform-bits` tweak the uniform configuration. Mixed-KV plans
//! serve end-to-end: the paged pool carries one lane codec per layer.
//!
//! (clap is unavailable offline; arguments are parsed by hand. Method
//! names come from `Method::ALL` — one parse/label pair shared with the
//! experiment harness and the `.qplan` parser.)

use anyhow::{Context, Result};
use nestquant::coordinator::generator::GenSession;
use nestquant::model::engine::{Engine, EngineOptions, Method, Regime};
use nestquant::model::weights::{artifact_path, ModelWeights};
use nestquant::quant::plan::QuantPlan;
use std::path::PathBuf;

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn method_names() -> String {
    Method::ALL
        .iter()
        .map(|m| m.cli_name())
        .collect::<Vec<_>>()
        .join("|")
}

fn regime_names() -> String {
    Regime::ALL
        .iter()
        .map(|r| r.cli_name())
        .collect::<Vec<_>>()
        .join("|")
}

fn parse_method(s: &str) -> Result<Method> {
    Method::parse(s)
        .with_context(|| format!("unknown method '{s}' (available: {})", method_names()))
}

fn parse_regime(s: &str) -> Result<Regime> {
    Regime::parse(s)
        .with_context(|| format!("unknown regime '{s}' (available: {})", regime_names()))
}

/// Apply the shared uniform quantization flags on top of a command's
/// base options.
fn apply_quant_flags(args: &[String], mut opts: EngineOptions) -> Result<EngineOptions> {
    if let Some(s) = flag(args, "--regime") {
        opts.regime = parse_regime(&s)?;
    }
    if let Some(s) = flag(args, "--method") {
        opts.method = parse_method(&s)?;
    }
    if let Some(s) = flag(args, "--q") {
        opts.q = s.parse().context("--q")?;
    }
    if let Some(s) = flag(args, "--k") {
        opts.k = s.parse().context("--k")?;
    }
    if let Some(s) = flag(args, "--uniform-bits") {
        opts.uniform_bits = s.parse().context("--uniform-bits")?;
    }
    Ok(opts)
}

/// The shared `--plan` load/validate path (`ppl`/`serve`/`generate`):
/// a `.qplan` file carries the full per-site policy and overrides the
/// uniform knob flags; without one, the flags lower through
/// `QuantPlan::uniform`. Returns the plan and the plan path when one
/// was loaded.
fn resolve_plan(args: &[String], base: EngineOptions) -> Result<(QuantPlan, Option<String>)> {
    if let Some(path) = flag(args, "--plan") {
        // `QuantPlan::load` is the one typed load path (same taxonomy
        // as `io::TensorFileError`): Io / Parse / Unsupported / Invalid,
        // each naming the file — a bad or unserveable plan is a CLI
        // error here, not a panic inside Engine::build_plan
        let plan = QuantPlan::load(std::path::Path::new(&path))?;
        Ok((plan, Some(path)))
    } else {
        Ok((QuantPlan::uniform(apply_quant_flags(args, base)?), None))
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let artifacts = PathBuf::from(
        flag(&args, "--artifacts").unwrap_or_else(|| "artifacts".into()),
    );
    let results = PathBuf::from(flag(&args, "--results").unwrap_or_else(|| "results".into()));

    match cmd {
        "exp" => {
            let id = args.get(1).context("usage: nestquant exp <id|all>")?;
            nestquant::experiments::run(id, &artifacts, &results)?;
        }
        "ppl" => {
            let model = args.get(1).context("usage: nestquant ppl <model>")?;
            let w = ModelWeights::load(&artifact_path(&artifacts, model))?;
            let windows: usize = flag(&args, "--windows")
                .unwrap_or_else(|| "8".into())
                .parse()?;
            // a .qplan file carries the full per-site policy — it
            // overrides the uniform knob flags below
            if flag(&args, "--plan").is_some() {
                let (plan, path) = resolve_plan(&args, EngineOptions::default())?;
                let path = path.expect("--plan present");
                let eng = Engine::build_plan(&w, plan);
                let ppl = eng.eval_ppl(&w.val_tokens, windows);
                let payload: usize = eng.site_payloads().iter().map(|s| s.bytes).sum();
                println!(
                    "plan {path}: ppl = {ppl:.4} (bits {:.2} zstd / {:.2} packed, \
                     weights {:.1} KiB)",
                    eng.weight_bits_zstd,
                    eng.weight_bits_packed,
                    payload as f64 / 1024.0
                );
                return Ok(());
            }
            // uniform path: every knob defaults to EngineOptions::default()
            let opts = apply_quant_flags(&args, EngineOptions::default())?;
            if opts.regime == Regime::Fp {
                let ppl = nestquant::model::forward::eval_ppl(&w, &w.val_tokens, windows);
                println!("fp32 ppl = {ppl:.4}");
            } else {
                let (method, regime, q) = (opts.method, opts.regime, opts.q);
                let eng = Engine::build(&w, opts);
                let ppl = eng.eval_ppl(&w.val_tokens, windows);
                println!(
                    "{} {} q={q}: ppl = {ppl:.4} (bits {:.2} zstd / {:.2} packed)",
                    method.label(),
                    regime.label(),
                    eng.weight_bits_zstd,
                    eng.weight_bits_packed
                );
            }
        }
        "serve" => {
            let model = args.get(1).context("usage: nestquant serve <model>")?;
            let n_req: usize = flag(&args, "--requests")
                .unwrap_or_else(|| "8".into())
                .parse()?;
            let batch: usize = flag(&args, "--batch").unwrap_or_else(|| "4".into()).parse()?;
            let w = ModelWeights::load(&artifact_path(&artifacts, model))?;
            // same plan resolution as `ppl`: a `.qplan` file (mixed
            // precision, heterogeneous KV lanes) or the uniform flags
            let (plan, plan_path) = resolve_plan(
                &args,
                EngineOptions {
                    regime: Regime::WKv,
                    calib_windows: 2,
                    ..Default::default()
                },
            )?;
            if let Some(p) = &plan_path {
                println!("serving with plan {p}");
            }
            let eng = std::sync::Arc::new(Engine::build_plan(&w, plan));
            let (srv, rx) = nestquant::coordinator::Server::start(
                eng,
                nestquant::coordinator::ServerConfig {
                    policy: nestquant::coordinator::BatchPolicy {
                        max_batch: batch,
                        ..Default::default()
                    },
                    ..Default::default()
                },
            );
            // live Prometheus scrape endpoint, served while the demo runs
            let listener = match flag(&args, "--metrics-listen") {
                Some(addr) => {
                    let m = srv.metrics.clone();
                    let l = nestquant::obs::MetricsServer::serve_text(&addr, move || {
                        m.prometheus_text()
                    })
                    .with_context(|| format!("bind metrics listener on '{addr}'"))?;
                    println!("metrics: http://{}/metrics", l.local_addr());
                    Some(l)
                }
                None => None,
            };
            let t0 = std::time::Instant::now();
            for i in 0..n_req {
                let start = (i * 37) % (w.val_tokens.len() - 32);
                srv.submit(nestquant::coordinator::Request::Generate {
                    id: i as u64,
                    prompt: w.val_tokens[start..start + 16].to_vec(),
                    n_new: 32,
                })?;
            }
            for _ in 0..n_req {
                let r = rx.recv()?;
                match &r.error {
                    None => println!(
                        "request {} done: {} tokens, {:.1} ms",
                        r.id,
                        r.tokens.len(),
                        r.latency_ms
                    ),
                    Some(e) => println!(
                        "request {} failed after {} tokens: {e}",
                        r.id,
                        r.tokens.len()
                    ),
                }
            }
            println!("wall: {:.2}s", t0.elapsed().as_secs_f64());
            println!("{}", srv.metrics.report());
            let trace = srv.trace.clone();
            let metrics = srv.metrics.clone();
            let report = srv.shutdown();
            if !report.drained {
                println!("shutdown timed out: {} request(s) undrained", report.undrained);
            }
            // export after shutdown so the journal includes the drain
            // and the snapshot carries the final pool-idle audit
            if let Some(path) = flag(&args, "--metrics-out") {
                std::fs::write(&path, metrics.prometheus_text())
                    .with_context(|| format!("write metrics snapshot '{path}'"))?;
                println!("metrics snapshot written to {path}");
            }
            if let Some(path) = flag(&args, "--trace-out") {
                let json = nestquant::obs::chrome_trace_json(&trace.snapshot());
                std::fs::write(&path, json)
                    .with_context(|| format!("write trace '{path}'"))?;
                println!(
                    "trace written to {path} ({} events, {} dropped; open in ui.perfetto.dev)",
                    trace.len(),
                    trace.dropped()
                );
            }
            drop(listener);
        }
        "generate" => {
            let model = args
                .get(1)
                .context("usage: nestquant generate <model> <prompt>")?;
            let prompt_str = args.get(2).context("missing prompt")?;
            let n: usize = flag(&args, "--tokens")
                .unwrap_or_else(|| "64".into())
                .parse()?;
            let w = ModelWeights::load(&artifact_path(&artifacts, model))?;
            let (plan, plan_path) = resolve_plan(
                &args,
                EngineOptions {
                    regime: Regime::WKv,
                    calib_windows: 2,
                    ..Default::default()
                },
            )?;
            if let Some(p) = &plan_path {
                println!("generating with plan {p}");
            }
            let eng = Engine::build_plan(&w, plan);
            const VOCAB: &str = "abcdefghijklmnopqrstuvwxyz0123456789 .,;=+-()[]{}<>\n";
            let prompt: Vec<i32> = prompt_str
                .chars()
                .filter_map(|c| VOCAB.find(c).map(|i| i as i32))
                .collect();
            let mut sess = GenSession::new(&eng);
            let out = sess.generate(&prompt, n);
            let text: String = out
                .iter()
                .map(|&t| VOCAB.chars().nth(t as usize).unwrap_or('?'))
                .collect();
            println!("{prompt_str}{text}");
            println!(
                "\n[kv cache: {} bytes for {} positions]",
                sess.kv_bytes(),
                sess.position()
            );
        }
        _ => {
            println!(
                "nestquant — NestQuant (ICML 2025) reproduction\n\
                 usage:\n  nestquant exp <id|all>\n  nestquant ppl <model> \
                 [--regime {}] [--method {}]\n      [--q Q] [--k K] [--uniform-bits B] \
                 [--windows N] [--plan FILE]\n  \
                 nestquant serve <model> [--requests N] [--batch B] [quant flags]\n      \
                 [--trace-out FILE] [--metrics-out FILE] [--metrics-listen ADDR]\n  \
                 nestquant generate <model> <prompt> [--tokens N] [quant flags]\n\
                 `serve` and `generate` take the same quant flags as `ppl`, \
                 including --plan FILE",
                regime_names(),
                method_names()
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cli_parsers_share_the_canonical_name_tables() {
        assert_eq!(parse_method("nestquantm").unwrap(), Method::NestQuantM);
        assert_eq!(parse_method("uniform-ldlq").unwrap(), Method::UniformRotLdlq);
        assert!(parse_method("gptq").is_err());
        assert_eq!(parse_regime("wkva").unwrap(), Regime::WKvA);
        assert!(parse_regime("full").is_err());
        assert!(method_names().contains("rtn|uniform|uniform-ldlq"));
    }
}
