//! Token-ID prefix trie over frozen pages (RadixAttention-style, at page
//! granularity with token-granular tails).
//!
//! Each node below the root owns one frozen page and is keyed by the
//! exact `page_size`-token chunk that produced it; a path from the root
//! spells out a token prefix at page granularity. A new session walks the
//! trie against its prompt: every full-chunk match maps the node's page
//! (refcount bump — zero quantization work), and a final *partial* match
//! against one child's chunk maps that page as a copy-on-write tail.
//! Exact token keys (not hashes) make false sharing impossible.
//!
//! The index holds one reference on every registered page, which is what
//! keeps a finished session's prefix alive for later sessions; LRU
//! eviction walks leaf nodes (deepest-first by construction — a child's
//! page is useless without its ancestors) whose page nobody else
//! references and releases them until the pool is back under budget.

use super::block::PageId;

const ROOT: usize = 0;

struct TrieNode {
    /// the page_size-token chunk keying this node under its parent
    /// (empty for the root)
    chunk: Box<[i32]>,
    page: PageId,
    parent: usize,
    children: Vec<usize>,
    /// logical LRU timestamp (index clock at last lookup/registration)
    last_use: u64,
    /// free-list marker
    dead: bool,
    /// bumped every time the node slot is freed, so stale cursors held
    /// by long-lived sessions can be detected instead of silently
    /// registering chunks under a recycled node
    gen: u32,
}

/// The prefix index: a trie of frozen-page chunks.
pub struct PrefixIndex {
    nodes: Vec<TrieNode>,
    free: Vec<usize>,
    clock: u64,
}

impl Default for PrefixIndex {
    fn default() -> Self {
        Self::new()
    }
}

impl PrefixIndex {
    pub fn new() -> Self {
        PrefixIndex {
            nodes: vec![TrieNode {
                chunk: Box::new([]),
                page: 0,
                parent: ROOT,
                children: Vec::new(),
                last_use: 0,
                dead: false,
                gen: 0,
            }],
            free: Vec::new(),
            clock: 0,
        }
    }

    pub fn root(&self) -> usize {
        ROOT
    }

    pub fn page(&self, node: usize) -> PageId {
        debug_assert!(node != ROOT && !self.nodes[node].dead);
        self.nodes[node].page
    }

    /// Registered (non-root, live) node count.
    pub fn len(&self) -> usize {
        self.nodes.len() - 1 - self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Generation of a node slot — capture alongside the node id to form
    /// a cursor that survives (detectably) across evictions.
    pub fn gen(&self, node: usize) -> u32 {
        self.nodes[node].gen
    }

    /// Is a (node, gen) cursor still pointing at the node it named? The
    /// root is always valid.
    pub fn valid(&self, node: usize, gen: u32) -> bool {
        node == ROOT || (!self.nodes[node].dead && self.nodes[node].gen == gen)
    }

    /// Exact full-chunk child lookup; touches the LRU clock on hit.
    pub fn lookup_child(&mut self, node: usize, chunk: &[i32]) -> Option<usize> {
        let hit = self.nodes[node]
            .children
            .iter()
            .copied()
            .find(|&c| &*self.nodes[c].chunk == chunk);
        if let Some(c) = hit {
            let t = self.tick();
            self.nodes[c].last_use = t;
        }
        hit
    }

    /// Longest proper-prefix match of `toks` against one child's chunk:
    /// the copy-on-write tail candidate. Returns (child, matched tokens)
    /// with 1 ≤ matched < chunk length. `toks` shorter than a chunk is
    /// the common case (prompt tail); a full-length mismatching chunk can
    /// still share its head.
    pub fn partial_child(&mut self, node: usize, toks: &[i32]) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize)> = None;
        for &c in &self.nodes[node].children {
            let chunk = &self.nodes[c].chunk;
            let mut m = 0usize;
            while m < toks.len() && m < chunk.len() && toks[m] == chunk[m] {
                m += 1;
            }
            if m >= 1 && m < chunk.len() && best.map_or(true, |(_, bm)| m > bm) {
                best = Some((c, m));
            }
        }
        if let Some((c, _)) = best {
            let t = self.tick();
            self.nodes[c].last_use = t;
        }
        best
    }

    /// Register a frozen page under `node`. The caller must have checked
    /// `lookup_child` first (duplicate chunks are a logic error) and owns
    /// the index's reference on `page`.
    pub fn insert(&mut self, node: usize, chunk: &[i32], page: PageId) -> usize {
        debug_assert!(self
            .nodes[node]
            .children
            .iter()
            .all(|&c| &*self.nodes[c].chunk != chunk));
        let t = self.tick();
        let fresh = TrieNode {
            chunk: chunk.into(),
            page,
            parent: node,
            children: Vec::new(),
            last_use: t,
            dead: false,
            gen: 0,
        };
        let id = if let Some(id) = self.free.pop() {
            let gen = self.nodes[id].gen;
            self.nodes[id] = fresh;
            self.nodes[id].gen = gen;
            id
        } else {
            self.nodes.push(fresh);
            self.nodes.len() - 1
        };
        self.nodes[node].children.push(id);
        id
    }

    /// Count live registered pages whose id satisfies `pred` — used by
    /// the pool to measure evictable headroom (pages only the index
    /// references) without touching LRU state. Any such page is
    /// eventually reclaimable by repeated [`Self::evict_lru`] calls:
    /// a mapping session always holds the whole root path, so an
    /// index-only node can't have a pinned descendant blocking the
    /// bottom-up peel.
    pub fn count_pages<F: Fn(PageId) -> bool>(&self, pred: F) -> usize {
        self.nodes
            .iter()
            .skip(1)
            .filter(|n| !n.dead && pred(n.page))
            .count()
    }

    /// Evict the least-recently-used *leaf* whose page satisfies
    /// `reclaimable` (i.e. only the index references it). Returns the
    /// evicted page so the caller can drop the index's reference. Leaves
    /// first means runs are released bottom-up: a parent becomes a leaf
    /// once its children are gone, so repeated calls peel whole runs.
    ///
    /// Linear scan over the node slab per evicted page: fine at the
    /// current cached-chunk counts (hundreds) and single serving worker;
    /// a leaf min-heap on `last_use` is the upgrade path if budgeted
    /// pools grow to many thousands of cached chunks.
    pub fn evict_lru<F: Fn(PageId) -> bool>(&mut self, reclaimable: F) -> Option<PageId> {
        let mut victim: Option<usize> = None;
        for id in 1..self.nodes.len() {
            let n = &self.nodes[id];
            if n.dead || !n.children.is_empty() || !reclaimable(n.page) {
                continue;
            }
            if victim.map_or(true, |v| n.last_use < self.nodes[v].last_use) {
                victim = Some(id);
            }
        }
        let id = victim?;
        let parent = self.nodes[id].parent;
        self.nodes[parent].children.retain(|&c| c != id);
        self.nodes[id].dead = true;
        self.nodes[id].gen = self.nodes[id].gen.wrapping_add(1);
        self.nodes[id].children = Vec::new();
        self.nodes[id].chunk = Box::new([]);
        self.free.push(id);
        Some(self.nodes[id].page)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn chunk(base: i32, n: usize) -> Vec<i32> {
        (0..n as i32).map(|i| base + i).collect()
    }

    #[test]
    fn insert_lookup_roundtrip() {
        let mut idx = PrefixIndex::new();
        let r = idx.root();
        let c0 = chunk(0, 4);
        let n0 = idx.insert(r, &c0, 7);
        assert_eq!(idx.lookup_child(r, &c0), Some(n0));
        assert_eq!(idx.page(n0), 7);
        assert_eq!(idx.lookup_child(r, &chunk(1, 4)), None);
        // chain a second level
        let c1 = chunk(100, 4);
        let n1 = idx.insert(n0, &c1, 9);
        assert_eq!(idx.lookup_child(n0, &c1), Some(n1));
        assert_eq!(idx.lookup_child(r, &c1), None, "levels are separate");
        assert_eq!(idx.len(), 2);
    }

    #[test]
    fn partial_match_picks_longest_shared_head() {
        let mut idx = PrefixIndex::new();
        let r = idx.root();
        idx.insert(r, &[1, 2, 3, 4], 1);
        let nb = idx.insert(r, &[1, 2, 9, 9], 2);
        // toks share 2 tokens with both children; tie resolves to the
        // first-found longest (both length 2 — either page is valid)
        let (_, m) = idx.partial_child(r, &[1, 2]).unwrap();
        assert_eq!(m, 2);
        // 3-token overlap with child b only
        let (c, m) = idx.partial_child(r, &[1, 2, 9, 7]).unwrap();
        assert_eq!((c, m), (nb, 3));
        // no shared head at all
        assert!(idx.partial_child(r, &[5, 5]).is_none());
        // a full-chunk match is lookup_child's job, never a partial
        // (m < chunk len): with no sibling sharing a head, none is found
        let mut solo = PrefixIndex::new();
        let r2 = solo.root();
        solo.insert(r2, &[1, 2, 3, 4], 1);
        assert!(solo.partial_child(r2, &[1, 2, 3, 4]).is_none());
        assert!(solo.partial_child(r2, &[1, 2]).is_some());
    }

    #[test]
    fn lru_evicts_leaves_bottom_up() {
        let mut idx = PrefixIndex::new();
        let r = idx.root();
        let a = idx.insert(r, &chunk(0, 4), 10);
        let _b = idx.insert(a, &chunk(10, 4), 11);
        let c = idx.insert(r, &chunk(20, 4), 12);
        // touch the deep leaf (page 11) so the shallow leaf (12) is LRU
        idx.lookup_child(a, &chunk(10, 4));
        assert_eq!(idx.evict_lru(|_| true), Some(12));
        assert_eq!(idx.lookup_child(r, &chunk(20, 4)), None);
        // page 10 is an inner node: next eviction must take leaf 11 first
        assert_eq!(idx.evict_lru(|_| true), Some(11));
        assert_eq!(idx.evict_lru(|_| true), Some(10));
        assert_eq!(idx.evict_lru(|_| true), None);
        assert!(idx.is_empty());
        let _ = c;
    }

    #[test]
    fn count_pages_tracks_live_nodes() {
        let mut idx = PrefixIndex::new();
        let r = idx.root();
        idx.insert(r, &chunk(0, 4), 1);
        let a = idx.insert(r, &chunk(10, 4), 2);
        idx.insert(a, &chunk(20, 4), 3);
        assert_eq!(idx.count_pages(|_| true), 3);
        assert_eq!(idx.count_pages(|p| p != 2), 2);
        idx.evict_lru(|p| p == 3);
        assert_eq!(idx.count_pages(|_| true), 2, "evicted node drops out");
    }

    #[test]
    fn eviction_respects_reclaimable_filter() {
        let mut idx = PrefixIndex::new();
        let r = idx.root();
        idx.insert(r, &chunk(0, 4), 1);
        idx.insert(r, &chunk(10, 4), 2);
        // page 1 pinned (e.g. a live session still maps it)
        assert_eq!(idx.evict_lru(|p| p != 1), Some(2));
        assert_eq!(idx.evict_lru(|p| p != 1), None);
        assert_eq!(idx.len(), 1);
    }

    #[test]
    fn generation_guard_detects_recycled_cursor() {
        let mut idx = PrefixIndex::new();
        let r = idx.root();
        let n = idx.insert(r, &chunk(0, 4), 1);
        let gen = idx.gen(n);
        assert!(idx.valid(n, gen));
        idx.evict_lru(|_| true);
        assert!(!idx.valid(n, gen), "evicted node must invalidate cursors");
        let n2 = idx.insert(r, &chunk(10, 4), 2);
        assert_eq!(n, n2, "slot recycled");
        assert!(!idx.valid(n, gen), "recycled slot has a new generation");
        assert!(idx.valid(n2, idx.gen(n2)));
        assert!(idx.valid(r, 0), "root is always valid");
    }

    #[test]
    fn freed_nodes_are_recycled() {
        let mut idx = PrefixIndex::new();
        let r = idx.root();
        idx.insert(r, &chunk(0, 4), 1);
        idx.evict_lru(|_| true);
        let n = idx.insert(r, &chunk(10, 4), 2);
        assert_eq!(idx.lookup_child(r, &chunk(10, 4)), Some(n));
        assert_eq!(idx.nodes.len(), 2, "node slab must recycle");
    }
}
