//! Fixed-size page slab for coded KV payloads.
//!
//! A [`Page`] holds `page_size` consecutive positions × every
//! (layer, head) lane × the K and V coded payloads (coset codes, β
//! indices, per-vector scale) — the paged-attention block, but over
//! nested-lattice codes instead of fp16, so one page carries ~8× the
//! tokens of an fp32 page of equal byte cost. [`BlockPool`] is the slab
//! allocator underneath the pool: freed pages go on a free list and are
//! recycled buffer-and-all (no per-page reallocation on the serving
//! path), refcounts track sharers (sessions + the prefix index), and a
//! byte budget bounds the slab.

use crate::lattice::e8::D;

/// Physical page handle.
pub type PageId = u32;

/// Geometry of every page in a pool: (layer, head) lane count and
/// positions per page. The head dimension is fixed lazily by the first
/// append (the adapter construction paths don't know it up front).
#[derive(Clone, Copy, Debug)]
pub struct PageShape {
    pub n_layer: usize,
    pub n_head: usize,
    pub page_size: usize,
    /// per-head vector length; 0 until the first append fixes it
    pub d_head: usize,
}

impl PageShape {
    pub fn lanes(&self) -> usize {
        self.n_layer * self.n_head
    }

    pub fn lane(&self, layer: usize, head: usize) -> usize {
        debug_assert!(layer < self.n_layer && head < self.n_head);
        layer * self.n_head + head
    }

    /// Flat slot index of (lane, local position): lane-major so that one
    /// (layer, head)'s positions are contiguous — the layout the
    /// streaming scores / value kernels walk.
    pub fn slot(&self, lane: usize, local: usize) -> usize {
        debug_assert!(local < self.page_size);
        lane * self.page_size + local
    }

    pub fn slots(&self) -> usize {
        self.lanes() * self.page_size
    }

    /// β indices per vector (one per 8-block).
    pub fn blocks_per_vec(&self) -> usize {
        self.d_head / D
    }
}

/// One physical page: coded K and V payloads for `slots()` vectors.
/// Buffers are allocated once and recycled through the free list; stale
/// contents are never read because readers are gated by per-session fill
/// counts.
pub struct Page {
    pub codes_k: Box<[u8]>,
    pub beta_k: Box<[u8]>,
    pub scale_k: Box<[f32]>,
    pub codes_v: Box<[u8]>,
    pub beta_v: Box<[u8]>,
    pub scale_v: Box<[f32]>,
    /// sharers: one per mapping session + one if held by the prefix index
    refcount: u32,
    /// full pages are immutable (copy-on-write targets, never appended)
    pub frozen: bool,
}

impl Page {
    fn new(shape: &PageShape) -> Self {
        let slots = shape.slots();
        let dh = shape.d_head;
        let bpv = shape.blocks_per_vec();
        Page {
            codes_k: vec![0u8; slots * dh].into_boxed_slice(),
            beta_k: vec![0u8; slots * bpv].into_boxed_slice(),
            scale_k: vec![0f32; slots].into_boxed_slice(),
            codes_v: vec![0u8; slots * dh].into_boxed_slice(),
            beta_v: vec![0u8; slots * bpv].into_boxed_slice(),
            scale_v: vec![0f32; slots].into_boxed_slice(),
            refcount: 1,
            frozen: false,
        }
    }
}

/// Slab allocator of [`Page`]s with free-list recycling, refcounts and a
/// global byte budget (logical coded-payload bytes, the same accounting
/// as `QuantizedVector::payload_bits`).
pub struct BlockPool {
    shape: PageShape,
    pages: Vec<Page>,
    free: Vec<PageId>,
    /// logical payload bytes per page (0 until d_head is fixed)
    bytes_per_page: usize,
    budget_bytes: Option<usize>,
    in_use: usize,
    pub evicted_pages: u64,
    pub budget_overruns: u64,
}

impl BlockPool {
    pub fn new(shape: PageShape, budget_bytes: Option<usize>) -> Self {
        BlockPool {
            shape,
            pages: Vec::new(),
            free: Vec::new(),
            bytes_per_page: 0,
            budget_bytes,
            in_use: 0,
            evicted_pages: 0,
            budget_overruns: 0,
        }
    }

    pub fn shape(&self) -> &PageShape {
        &self.shape
    }

    /// Fix the head dimension (first append) and derive the per-page
    /// logical byte cost from the per-layer code rates.
    pub fn set_d_head(&mut self, d_head: usize, layer_qs: &[(u32, u32)]) {
        assert_eq!(d_head % D, 0, "d_head must be divisible by 8");
        if self.shape.d_head != 0 {
            assert_eq!(self.shape.d_head, d_head, "pool d_head is fixed at first append");
            return;
        }
        assert!(self.pages.is_empty());
        self.shape.d_head = d_head;
        // logical payload per coded vector — the same accounting as
        // QuantizedVector::payload_bits, via the shared helper
        let vec_bits = |q: u32| -> usize { crate::lattice::nested::payload_bits_for(d_head, q) };
        let mut page_bits = 0usize;
        for &(qk, qv) in layer_qs {
            page_bits += self.shape.n_head * self.shape.page_size * (vec_bits(qk) + vec_bits(qv));
        }
        self.bytes_per_page = page_bits.div_ceil(8);
    }

    pub fn d_head(&self) -> usize {
        self.shape.d_head
    }

    pub fn bytes_per_page(&self) -> usize {
        self.bytes_per_page
    }

    pub fn budget_bytes(&self) -> Option<usize> {
        self.budget_bytes
    }

    pub fn bytes_in_use(&self) -> usize {
        self.in_use * self.bytes_per_page
    }

    pub fn pages_in_use(&self) -> usize {
        self.in_use
    }

    pub fn pages_free(&self) -> usize {
        self.free.len()
    }

    /// True iff allocating one more page would exceed the byte budget.
    pub fn at_budget(&self) -> bool {
        match self.budget_bytes {
            Some(b) => self.bytes_in_use() + self.bytes_per_page > b,
            None => false,
        }
    }

    /// True iff the slab already exceeds the byte budget (post-release
    /// trim predicate).
    pub fn over_budget(&self) -> bool {
        match self.budget_bytes {
            Some(b) => self.bytes_in_use() > b,
            None => false,
        }
    }

    /// Allocate a page (refcount 1), recycling from the free list when
    /// possible. Budget-driven eviction is the caller's job (it owns the
    /// prefix index that knows which pages are reclaimable).
    pub fn alloc(&mut self) -> PageId {
        assert!(self.shape.d_head != 0, "set_d_head before alloc");
        self.in_use += 1;
        if let Some(id) = self.free.pop() {
            let p = &mut self.pages[id as usize];
            p.refcount = 1;
            p.frozen = false;
            id
        } else {
            self.pages.push(Page::new(&self.shape));
            (self.pages.len() - 1) as PageId
        }
    }

    pub fn page(&self, id: PageId) -> &Page {
        &self.pages[id as usize]
    }

    pub fn page_mut(&mut self, id: PageId) -> &mut Page {
        &mut self.pages[id as usize]
    }

    /// Two distinct pages mutably (copy-on-write source/destination).
    pub fn page_pair_mut(&mut self, a: PageId, b: PageId) -> (&Page, &mut Page) {
        assert_ne!(a, b);
        let (a, b) = (a as usize, b as usize);
        if a < b {
            let (lo, hi) = self.pages.split_at_mut(b);
            (&lo[a], &mut hi[0])
        } else {
            let (lo, hi) = self.pages.split_at_mut(a);
            (&hi[0], &mut lo[b])
        }
    }

    pub fn refcount(&self, id: PageId) -> u32 {
        self.pages[id as usize].refcount
    }

    pub fn incref(&mut self, id: PageId) {
        let p = &mut self.pages[id as usize];
        assert!(p.refcount > 0, "incref on freed page {id}");
        p.refcount += 1;
    }

    /// Drop one reference; the page returns to the free list when the
    /// count hits zero. Returns true iff the page was freed.
    pub fn decref(&mut self, id: PageId) -> bool {
        let p = &mut self.pages[id as usize];
        assert!(p.refcount > 0, "double free of page {id}");
        p.refcount -= 1;
        if p.refcount == 0 {
            self.free.push(id);
            self.in_use -= 1;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    fn shape() -> PageShape {
        PageShape {
            n_layer: 2,
            n_head: 2,
            page_size: 4,
            d_head: 0,
        }
    }

    #[test]
    fn lane_slot_layout_is_lane_major() {
        let mut s = shape();
        s.d_head = 16;
        assert_eq!(s.lanes(), 4);
        assert_eq!(s.slot(s.lane(1, 0), 3), 2 * 4 + 3);
        // positions of a fixed lane are contiguous
        assert_eq!(s.slot(2, 1), s.slot(2, 0) + 1);
    }

    #[test]
    fn bytes_per_page_accounting() {
        let mut bp = BlockPool::new(shape(), None);
        bp.set_d_head(16, &[(14, 14), (14, 14)]);
        // per vector: ceil(16·log2 14) + 2·2 + 32 = 61 + 36 = 97 bits
        let vec_bits = crate::lattice::nested::payload_bits_for(16, 14);
        assert_eq!(vec_bits, 97);
        let page_bits = 2 * 2 * 4 * 2 * vec_bits;
        assert_eq!(bp.bytes_per_page(), page_bits.div_ceil(8));
        let id = bp.alloc();
        assert_eq!(bp.bytes_in_use(), bp.bytes_per_page());
        bp.decref(id);
        assert_eq!(bp.bytes_in_use(), 0);
    }

    #[test]
    fn alloc_free_refcount_invariants() {
        // propcheck the slab: random alloc / incref / decref traffic must
        // never leak a page, never double-free, and keep
        // in_use + free == slab length at every step.
        propcheck::check("blockpool-invariants", 30, 0xB10C, |rng| {
            let mut bp = BlockPool::new(shape(), None);
            bp.set_d_head(8, &[(14, 14), (14, 14)]);
            let mut live: Vec<(PageId, u32)> = Vec::new(); // model refcounts
            let mut peak = 0usize;
            for _ in 0..200 {
                match rng.below(3) {
                    0 => {
                        let id = bp.alloc();
                        if live.iter().any(|&(l, _)| l == id) {
                            return Err(format!("alloc returned live page {id}"));
                        }
                        live.push((id, 1));
                    }
                    1 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        bp.incref(live[i].0);
                        live[i].1 += 1;
                    }
                    2 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        live[i].1 -= 1;
                        let freed = bp.decref(live[i].0);
                        if freed != (live[i].1 == 0) {
                            return Err("free / model refcount disagree".into());
                        }
                        if live[i].1 == 0 {
                            live.swap_remove(i);
                        }
                    }
                    _ => {}
                }
                peak = peak.max(bp.pages_in_use() + bp.pages_free());
                if bp.pages_in_use() != live.len() {
                    return Err(format!(
                        "in_use {} != model {}",
                        bp.pages_in_use(),
                        live.len()
                    ));
                }
                for &(id, rc) in &live {
                    if bp.refcount(id) != rc {
                        return Err(format!("page {id}: rc {} != model {rc}", bp.refcount(id)));
                    }
                }
                if bp.bytes_in_use() != live.len() * bp.bytes_per_page() {
                    return Err("byte accounting drifted".into());
                }
            }
            // drain and verify full recycling
            for (id, rc) in live.drain(..) {
                for _ in 0..rc {
                    bp.decref(id);
                }
            }
            if bp.pages_in_use() != 0 || bp.pages_free() != peak {
                return Err("pages leaked after drain".into());
            }
            Ok(())
        });
    }

    #[test]
    fn recycled_pages_reset_state() {
        let mut bp = BlockPool::new(shape(), None);
        bp.set_d_head(8, &[(14, 14), (14, 14)]);
        let a = bp.alloc();
        bp.page_mut(a).frozen = true;
        bp.incref(a);
        bp.decref(a);
        bp.decref(a);
        let b = bp.alloc();
        assert_eq!(a, b, "free list must recycle");
        assert!(!bp.page(b).frozen);
        assert_eq!(bp.refcount(b), 1);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut bp = BlockPool::new(shape(), None);
        bp.set_d_head(8, &[(14, 14), (14, 14)]);
        let id = bp.alloc();
        bp.decref(id);
        bp.decref(id);
    }

    #[test]
    fn at_budget_tracks_capacity() {
        let mut bp = BlockPool::new(shape(), Some(1));
        bp.set_d_head(8, &[(14, 14), (14, 14)]);
        assert!(bp.at_budget(), "1-byte budget can't fit a page");
        let mut bp2 = BlockPool::new(shape(), None);
        bp2.set_d_head(8, &[(14, 14), (14, 14)]);
        assert!(!bp2.at_budget());
    }
}
