//! Fixed-size page slab for coded KV payloads with **heterogeneous
//! per-layer lanes**.
//!
//! A [`Page`] holds `page_size` consecutive positions × every
//! (layer, head) lane × the K and V payloads — the paged-attention
//! block, except that each *layer* carries its own lane codec: nested
//! lattice codes (coset codes + β indices + scale), branch-free uniform
//! codes (one byte per entry + per-vector Δ), or raw fp32 bytes. The
//! page arena is a single byte slab addressed through per-layer byte
//! strides ([`PageLayout`]), so one page mixes lane codecs freely while
//! the byte budget stays exact. [`BlockPool`] is the slab allocator
//! underneath the pool: freed pages go on a free list and are recycled
//! buffer-and-all (no per-page reallocation on the serving path),
//! refcounts track sharers (sessions + the prefix index), and a byte
//! budget bounds the slab.

use crate::lattice::e8::D;
use std::ops::Range;

/// Physical page handle.
pub type PageId = u32;

/// Codec class of a lane — the buckets [`super::PoolStats`] splits page
/// bytes into.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneClass {
    Fp,
    Uniform,
    Nested,
}

impl LaneClass {
    /// Bucket index into per-class accounting arrays (`[fp, uniform,
    /// nested]`).
    pub fn idx(self) -> usize {
        match self {
            LaneClass::Fp => 0,
            LaneClass::Uniform => 1,
            LaneClass::Nested => 2,
        }
    }
}

/// Physical and logical per-vector cost of one layer's K (or V) lane.
#[derive(Clone, Copy, Debug)]
pub struct LaneSpec {
    pub class: LaneClass,
    /// physical bytes per coded vector in the page arena
    pub stride: usize,
    /// logical payload bits per vector (budget accounting — the same
    /// scheme as `QuantizedVector::payload_bits` for nested lanes)
    pub bits: usize,
}

/// Geometry of every page in a pool: (layer, head) lane count and
/// positions per page. The head dimension is fixed lazily by the first
/// append (the adapter construction paths don't know it up front).
#[derive(Clone, Copy, Debug)]
pub struct PageShape {
    pub n_layer: usize,
    pub n_head: usize,
    pub page_size: usize,
    /// per-head vector length; 0 until the first append fixes it
    pub d_head: usize,
}

impl PageShape {
    pub fn lanes(&self) -> usize {
        self.n_layer * self.n_head
    }

    pub fn lane(&self, layer: usize, head: usize) -> usize {
        debug_assert!(layer < self.n_layer && head < self.n_head);
        layer * self.n_head + head
    }

    /// Flat slot index of (lane, local position): lane-major so that one
    /// (layer, head)'s positions are contiguous — the layout the
    /// streaming scores / value kernels walk.
    pub fn slot(&self, lane: usize, local: usize) -> usize {
        debug_assert!(local < self.page_size);
        lane * self.page_size + local
    }

    pub fn slots(&self) -> usize {
        self.lanes() * self.page_size
    }

    /// β indices per vector (one per 8-block) — nested lanes only.
    pub fn blocks_per_vec(&self) -> usize {
        self.d_head / D
    }
}

/// Byte geometry of the heterogeneous page arena: each layer's K and V
/// lanes occupy their own region, addressed by a per-layer byte stride.
/// Within a region, one (head)'s positions are contiguous (the order the
/// streaming kernels walk), i.e. a vector lives at
/// `off[layer] + (head · page_size + local) · stride[layer]`.
pub struct PageLayout {
    shape: PageShape,
    /// per layer: (K lane spec, V lane spec)
    specs: Box<[(LaneSpec, LaneSpec)]>,
    /// per layer: byte offset of the layer's K / V region in the arena
    k_off: Box<[usize]>,
    v_off: Box<[usize]>,
    arena_bytes: usize,
    /// logical payload bytes per page (exact: bits summed, then one ⌈/8⌉)
    bytes_per_page: usize,
    /// logical payload bytes per page per lane class `[fp, uniform,
    /// nested]` — each bucket rounded up independently, so the split can
    /// exceed `bytes_per_page` by at most 2 bytes
    class_bytes: [usize; 3],
}

impl PageLayout {
    fn new(shape: PageShape, specs: &[(LaneSpec, LaneSpec)]) -> Self {
        assert_eq!(specs.len(), shape.n_layer, "one lane spec pair per layer");
        let vecs = shape.n_head * shape.page_size;
        let mut k_off = Vec::with_capacity(shape.n_layer);
        let mut v_off = Vec::with_capacity(shape.n_layer);
        let mut off = 0usize;
        let mut bits = 0usize;
        let mut class_bits = [0usize; 3];
        for &(k, v) in specs {
            k_off.push(off);
            off += vecs * k.stride;
            v_off.push(off);
            off += vecs * v.stride;
            bits += vecs * (k.bits + v.bits);
            class_bits[k.class.idx()] += vecs * k.bits;
            class_bits[v.class.idx()] += vecs * v.bits;
        }
        PageLayout {
            shape,
            specs: specs.to_vec().into_boxed_slice(),
            k_off: k_off.into_boxed_slice(),
            v_off: v_off.into_boxed_slice(),
            arena_bytes: off,
            bytes_per_page: bits.div_ceil(8),
            class_bytes: class_bits.map(|b| b.div_ceil(8)),
        }
    }

    pub fn shape(&self) -> &PageShape {
        &self.shape
    }

    pub fn spec(&self, layer: usize) -> (LaneSpec, LaneSpec) {
        self.specs[layer]
    }

    /// Byte range of (layer, head, local)'s coded K vector in the arena.
    #[inline]
    pub fn k_range(&self, layer: usize, head: usize, local: usize) -> Range<usize> {
        let stride = self.specs[layer].0.stride;
        let start =
            self.k_off[layer] + (head * self.shape.page_size + local) * stride;
        start..start + stride
    }

    /// Byte range of (layer, head, local)'s coded V vector in the arena.
    #[inline]
    pub fn v_range(&self, layer: usize, head: usize, local: usize) -> Range<usize> {
        let stride = self.specs[layer].1.stride;
        let start =
            self.v_off[layer] + (head * self.shape.page_size + local) * stride;
        start..start + stride
    }

    /// Contiguous byte run of positions `[0, cnt)` of (layer, head)'s K
    /// region — the copy-on-write unit.
    pub fn k_run(&self, layer: usize, head: usize, cnt: usize) -> Range<usize> {
        let stride = self.specs[layer].0.stride;
        let start = self.k_off[layer] + head * self.shape.page_size * stride;
        start..start + cnt * stride
    }

    /// Contiguous byte run of positions `[0, cnt)` of (layer, head)'s V
    /// region.
    pub fn v_run(&self, layer: usize, head: usize, cnt: usize) -> Range<usize> {
        let stride = self.specs[layer].1.stride;
        let start = self.v_off[layer] + head * self.shape.page_size * stride;
        start..start + cnt * stride
    }

    pub fn arena_bytes(&self) -> usize {
        self.arena_bytes
    }

    pub fn bytes_per_page(&self) -> usize {
        self.bytes_per_page
    }

    /// Logical page bytes split per lane class `[fp, uniform, nested]`.
    pub fn class_bytes(&self) -> [usize; 3] {
        self.class_bytes
    }
}

/// One physical page: the heterogeneous byte arena (all layers' coded K
/// and V payloads at their own strides) plus per-slot scales (nested: s,
/// uniform: Δ; unused for fp32 lanes). Buffers are allocated once and
/// recycled through the free list; stale contents are never read because
/// readers are gated by per-session fill counts.
pub struct Page {
    pub data: Box<[u8]>,
    pub scale_k: Box<[f32]>,
    pub scale_v: Box<[f32]>,
    /// sharers: one per mapping session + one if held by the prefix index
    refcount: u32,
    /// full pages are immutable (copy-on-write targets, never appended)
    pub frozen: bool,
}

impl Page {
    fn new(layout: &PageLayout) -> Self {
        let slots = layout.shape.slots();
        Page {
            data: vec![0u8; layout.arena_bytes].into_boxed_slice(),
            scale_k: vec![0f32; slots].into_boxed_slice(),
            scale_v: vec![0f32; slots].into_boxed_slice(),
            refcount: 1,
            frozen: false,
        }
    }
}

/// Slab allocator of [`Page`]s with free-list recycling, refcounts and a
/// global byte budget (logical coded-payload bytes — fp32 lanes cost
/// their full 32 bits/entry, uniform lanes `bits`/entry + Δ, nested
/// lanes the same accounting as `QuantizedVector::payload_bits`).
pub struct BlockPool {
    shape: PageShape,
    /// built by the first append ([`BlockPool::set_d_head`])
    layout: Option<PageLayout>,
    pages: Vec<Page>,
    free: Vec<PageId>,
    budget_bytes: Option<usize>,
    in_use: usize,
    pub evicted_pages: u64,
    pub budget_overruns: u64,
}

impl BlockPool {
    pub fn new(shape: PageShape, budget_bytes: Option<usize>) -> Self {
        BlockPool {
            shape,
            layout: None,
            pages: Vec::new(),
            free: Vec::new(),
            budget_bytes,
            in_use: 0,
            evicted_pages: 0,
            budget_overruns: 0,
        }
    }

    pub fn shape(&self) -> &PageShape {
        &self.shape
    }

    /// Fix the head dimension (first append) and derive the page byte
    /// geometry from the per-layer lane specs. Only nested lanes carry
    /// the 8-block geometry; fp32/uniform-only pools accept any head
    /// dimension.
    pub fn set_d_head(&mut self, d_head: usize, specs: &[(LaneSpec, LaneSpec)]) {
        let has_nested = specs
            .iter()
            .any(|&(k, v)| k.class == LaneClass::Nested || v.class == LaneClass::Nested);
        assert!(
            !has_nested || d_head % D == 0,
            "d_head must be divisible by 8 for nested lanes"
        );
        if self.shape.d_head != 0 {
            assert_eq!(self.shape.d_head, d_head, "pool d_head is fixed at first append");
            return;
        }
        assert!(self.pages.is_empty());
        self.shape.d_head = d_head;
        self.layout = Some(PageLayout::new(self.shape, specs));
    }

    pub fn d_head(&self) -> usize {
        self.shape.d_head
    }

    /// The page byte geometry; panics before the first append fixes it.
    pub fn layout(&self) -> &PageLayout {
        layout_of(&self.layout)
    }

    pub fn bytes_per_page(&self) -> usize {
        self.layout.as_ref().map_or(0, |l| l.bytes_per_page)
    }

    /// Logical page bytes split per lane class `[fp, uniform, nested]`.
    pub fn class_bytes(&self) -> [usize; 3] {
        self.layout.as_ref().map_or([0; 3], |l| l.class_bytes)
    }

    pub fn budget_bytes(&self) -> Option<usize> {
        self.budget_bytes
    }

    pub fn bytes_in_use(&self) -> usize {
        self.in_use * self.bytes_per_page()
    }

    pub fn pages_in_use(&self) -> usize {
        self.in_use
    }

    pub fn pages_free(&self) -> usize {
        self.free.len()
    }

    /// True iff allocating one more page would exceed the byte budget.
    pub fn at_budget(&self) -> bool {
        match self.budget_bytes {
            Some(b) => self.bytes_in_use() + self.bytes_per_page() > b,
            None => false,
        }
    }

    /// True iff the slab already exceeds the byte budget (post-release
    /// trim predicate).
    pub fn over_budget(&self) -> bool {
        match self.budget_bytes {
            Some(b) => self.bytes_in_use() > b,
            None => false,
        }
    }

    /// Allocate a page (refcount 1), recycling from the free list when
    /// possible. Budget-driven eviction is the caller's job (it owns the
    /// prefix index that knows which pages are reclaimable).
    pub fn alloc(&mut self) -> PageId {
        let layout = layout_of(&self.layout);
        self.in_use += 1;
        if let Some(id) = self.free.pop() {
            let p = &mut self.pages[id as usize];
            p.refcount = 1;
            p.frozen = false;
            id
        } else {
            self.pages.push(Page::new(layout));
            (self.pages.len() - 1) as PageId
        }
    }

    pub fn page(&self, id: PageId) -> &Page {
        &self.pages[id as usize]
    }

    pub fn page_mut(&mut self, id: PageId) -> &mut Page {
        &mut self.pages[id as usize]
    }

    /// A page mutably, together with the layout (the append path needs
    /// both and the borrows must split).
    pub fn page_mut_with_layout(&mut self, id: PageId) -> (&PageLayout, &mut Page) {
        (layout_of(&self.layout), &mut self.pages[id as usize])
    }

    /// Two distinct pages (copy-on-write source/destination) plus the
    /// layout that addresses them.
    pub fn page_pair_mut(&mut self, a: PageId, b: PageId) -> (&PageLayout, &Page, &mut Page) {
        assert_ne!(a, b);
        let layout = layout_of(&self.layout);
        let (a, b) = (a as usize, b as usize);
        if a < b {
            let (lo, hi) = self.pages.split_at_mut(b);
            (layout, &lo[a], &mut hi[0])
        } else {
            let (lo, hi) = self.pages.split_at_mut(a);
            (layout, &hi[0], &mut lo[b])
        }
    }

    pub fn refcount(&self, id: PageId) -> u32 {
        self.pages[id as usize].refcount
    }

    pub fn incref(&mut self, id: PageId) {
        let p = &mut self.pages[id as usize];
        assert!(p.refcount > 0, "incref on freed page {id}");
        p.refcount += 1;
    }

    /// Drop one reference; the page returns to the free list when the
    /// count hits zero. Returns true iff the page was freed.
    pub fn decref(&mut self, id: PageId) -> bool {
        let p = &mut self.pages[id as usize];
        assert!(p.refcount > 0, "double free of page {id}");
        p.refcount -= 1;
        if p.refcount == 0 {
            self.free.push(id);
            self.in_use -= 1;
            true
        } else {
            false
        }
    }
}

/// The fixed page geometry, or a diagnostic panic when nothing has been
/// appended yet. A free function over the field (not a method) so call
/// sites keep their disjoint borrows of `pages` / `in_use`.
fn layout_of(layout: &Option<PageLayout>) -> &PageLayout {
    match layout {
        Some(l) => l,
        None => panic!("BlockPool: set_d_head must run before the page layout is used"),
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    fn shape() -> PageShape {
        PageShape {
            n_layer: 2,
            n_head: 2,
            page_size: 4,
            d_head: 0,
        }
    }

    /// The nested-lane spec at rate q — mirrors
    /// `KvLaneCodec::lane_specs`, hand-rolled so the slab tests stay
    /// independent of the pool layer.
    fn nested_spec(d_head: usize, q: u32) -> LaneSpec {
        LaneSpec {
            class: LaneClass::Nested,
            stride: d_head + d_head / D,
            bits: crate::lattice::nested::payload_bits_for(d_head, q),
        }
    }

    fn nested_specs(d_head: usize, qs: &[(u32, u32)]) -> Vec<(LaneSpec, LaneSpec)> {
        qs.iter()
            .map(|&(qk, qv)| (nested_spec(d_head, qk), nested_spec(d_head, qv)))
            .collect()
    }

    fn fp_spec(d_head: usize) -> LaneSpec {
        LaneSpec {
            class: LaneClass::Fp,
            stride: 4 * d_head,
            bits: 32 * d_head,
        }
    }

    fn uniform_spec(d_head: usize, bits: u32) -> LaneSpec {
        LaneSpec {
            class: LaneClass::Uniform,
            stride: d_head,
            bits: bits as usize * d_head + 32,
        }
    }

    #[test]
    fn lane_slot_layout_is_lane_major() {
        let mut s = shape();
        s.d_head = 16;
        assert_eq!(s.lanes(), 4);
        assert_eq!(s.slot(s.lane(1, 0), 3), 2 * 4 + 3);
        // positions of a fixed lane are contiguous
        assert_eq!(s.slot(2, 1), s.slot(2, 0) + 1);
    }

    #[test]
    fn bytes_per_page_accounting() {
        let mut bp = BlockPool::new(shape(), None);
        bp.set_d_head(16, &nested_specs(16, &[(14, 14), (14, 14)]));
        // per vector: ceil(16·log2 14) + 2·2 + 32 = 61 + 36 = 97 bits
        let vec_bits = crate::lattice::nested::payload_bits_for(16, 14);
        assert_eq!(vec_bits, 97);
        let page_bits = 2 * 2 * 4 * 2 * vec_bits;
        assert_eq!(bp.bytes_per_page(), page_bits.div_ceil(8));
        // all-nested: the class split puts everything in one bucket
        assert_eq!(bp.class_bytes(), [0, 0, page_bits.div_ceil(8)]);
        let id = bp.alloc();
        assert_eq!(bp.bytes_in_use(), bp.bytes_per_page());
        bp.decref(id);
        assert_eq!(bp.bytes_in_use(), 0);
    }

    #[test]
    fn heterogeneous_layout_strides_do_not_overlap() {
        // layer 0 fp32, layer 1 nested: every vector byte range must be
        // disjoint and inside the arena, and the per-class byte split
        // must account each layer to its own bucket.
        let mut bp = BlockPool::new(shape(), None);
        let dh = 16;
        let specs = vec![
            (fp_spec(dh), uniform_spec(dh, 4)),
            (nested_spec(dh, 14), nested_spec(dh, 14)),
        ];
        bp.set_d_head(dh, &specs);
        let layout = bp.layout();
        let mut seen = vec![false; layout.arena_bytes()];
        for layer in 0..2 {
            for head in 0..2 {
                for local in 0..4 {
                    for r in [
                        layout.k_range(layer, head, local),
                        layout.v_range(layer, head, local),
                    ] {
                        assert!(r.end <= layout.arena_bytes());
                        for i in r {
                            assert!(!seen[i], "byte {i} claimed twice");
                            seen[i] = true;
                        }
                    }
                }
            }
        }
        assert!(seen.iter().all(|&b| b), "arena has unaddressed bytes");
        // run ranges prefix the per-(layer, head) regions
        assert_eq!(layout.k_run(1, 1, 4).end, layout.k_range(1, 1, 3).end);
        assert_eq!(layout.k_run(1, 1, 0).len(), 0);
        // class split: fp = layer-0 K, uniform = layer-0 V, nested = layer 1
        let vecs = 2 * 4;
        let [fp, uni, nest] = layout.class_bytes();
        assert_eq!(fp, (vecs * 32 * dh).div_ceil(8));
        assert_eq!(uni, (vecs * (4 * dh + 32)).div_ceil(8));
        assert_eq!(
            nest,
            (2 * vecs * crate::lattice::nested::payload_bits_for(dh, 14)).div_ceil(8)
        );
        let total = layout.bytes_per_page();
        assert!(fp + uni + nest >= total && fp + uni + nest <= total + 2);
    }

    #[test]
    fn alloc_free_refcount_invariants() {
        // propcheck the slab: random alloc / incref / decref traffic must
        // never leak a page, never double-free, and keep
        // in_use + free == slab length at every step.
        propcheck::check("blockpool-invariants", 30, 0xB10C, |rng| {
            let mut bp = BlockPool::new(shape(), None);
            bp.set_d_head(8, &nested_specs(8, &[(14, 14), (14, 14)]));
            let mut live: Vec<(PageId, u32)> = Vec::new(); // model refcounts
            let mut peak = 0usize;
            for _ in 0..200 {
                match rng.below(3) {
                    0 => {
                        let id = bp.alloc();
                        if live.iter().any(|&(l, _)| l == id) {
                            return Err(format!("alloc returned live page {id}"));
                        }
                        live.push((id, 1));
                    }
                    1 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        bp.incref(live[i].0);
                        live[i].1 += 1;
                    }
                    2 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        live[i].1 -= 1;
                        let freed = bp.decref(live[i].0);
                        if freed != (live[i].1 == 0) {
                            return Err("free / model refcount disagree".into());
                        }
                        if live[i].1 == 0 {
                            live.swap_remove(i);
                        }
                    }
                    _ => {}
                }
                peak = peak.max(bp.pages_in_use() + bp.pages_free());
                if bp.pages_in_use() != live.len() {
                    return Err(format!(
                        "in_use {} != model {}",
                        bp.pages_in_use(),
                        live.len()
                    ));
                }
                for &(id, rc) in &live {
                    if bp.refcount(id) != rc {
                        return Err(format!("page {id}: rc {} != model {rc}", bp.refcount(id)));
                    }
                }
                if bp.bytes_in_use() != live.len() * bp.bytes_per_page() {
                    return Err("byte accounting drifted".into());
                }
            }
            // drain and verify full recycling
            for (id, rc) in live.drain(..) {
                for _ in 0..rc {
                    bp.decref(id);
                }
            }
            if bp.pages_in_use() != 0 || bp.pages_free() != peak {
                return Err("pages leaked after drain".into());
            }
            Ok(())
        });
    }

    #[test]
    fn recycled_pages_reset_state() {
        let mut bp = BlockPool::new(shape(), None);
        bp.set_d_head(8, &nested_specs(8, &[(14, 14), (14, 14)]));
        let a = bp.alloc();
        bp.page_mut(a).frozen = true;
        bp.incref(a);
        bp.decref(a);
        bp.decref(a);
        let b = bp.alloc();
        assert_eq!(a, b, "free list must recycle");
        assert!(!bp.page(b).frozen);
        assert_eq!(bp.refcount(b), 1);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut bp = BlockPool::new(shape(), None);
        bp.set_d_head(8, &nested_specs(8, &[(14, 14), (14, 14)]));
        let id = bp.alloc();
        bp.decref(id);
        bp.decref(id);
    }

    #[test]
    fn at_budget_tracks_capacity() {
        let mut bp = BlockPool::new(shape(), Some(1));
        bp.set_d_head(8, &nested_specs(8, &[(14, 14), (14, 14)]));
        assert!(bp.at_budget(), "1-byte budget can't fit a page");
        let mut bp2 = BlockPool::new(shape(), None);
        bp2.set_d_head(8, &nested_specs(8, &[(14, 14), (14, 14)]));
        assert!(!bp2.at_budget());
    }
}
