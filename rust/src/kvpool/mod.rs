//! Paged quantized KV pool — a shared, budgeted store for *coded* KV
//! payloads serving many generation sessions at once (the paper's §1/§4.6
//! serving motivation compounded with vLLM-style paging).
//!
//! Keeping the KV cache in nested-lattice coded form means a page of
//! fixed byte size holds ~8× the tokens of fp32, so every serving-systems
//! trick over pages pays ~8× more: more sessions per byte budget, more
//! prefix reuse per cached page. The pool is built from:
//!
//! * [`block::BlockPool`] — slab allocator of fixed-size pages
//!   (`page_size` positions × every (layer, head) lane × coded K/V) with
//!   free-list recycling, refcounts and a global byte budget;
//! * [`page_table::PageTable`] — per-session logical→physical mapping
//!   with copy-on-write on shared / partial tail pages;
//! * [`prefix::PrefixIndex`] — a token-ID trie over frozen pages: a new
//!   session whose prompt shares a prefix with a live or recently
//!   finished session maps the shared pages (refcount bump, **zero
//!   quantization work**) instead of re-quantizing them;
//! * LRU eviction of index-held page runs when the budget is exceeded.
//!
//! [`SessionKv`] is the per-session view; its `scores` /
//! `weighted_value_sum` kernels stream page-by-page straight off the
//! coded payloads through [`crate::quant::qgemm::DecodeConsts`] (the
//! same all-integer decoder as the packed GEMM) with fixed stack
//! scratch — no per-position `Vec<f32>` is ever materialized on the
//! decode hot path. Quantizers are **per layer** (each layer decodes
//! with its own calibrated K/V pair — §4.6 step 4).

pub mod block;
pub mod page_table;
pub mod prefix;

pub use block::{BlockPool, PageId, PageShape};
pub use page_table::PageTable;
pub use prefix::PrefixIndex;

use crate::lattice::e8::D;
use crate::lattice::nested::{NestedLatticeQuantizer, QuantizedVector};
use crate::quant::qgemm::DecodeConsts;
use std::sync::{Arc, Mutex};

/// Calibrated key/value quantizer pair for one layer.
#[derive(Clone)]
pub struct KvLayerQuant {
    pub k: NestedLatticeQuantizer,
    pub v: NestedLatticeQuantizer,
}

/// Pool sizing knobs.
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    /// positions per page (16 ≈ the vLLM default block size)
    pub page_size: usize,
    /// global logical-payload byte budget; `None` = unbounded
    pub budget_bytes: Option<usize>,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            page_size: 16,
            budget_bytes: None,
        }
    }
}

/// Point-in-time pool gauges (exported through `coordinator::Metrics`).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    pub pages_in_use: usize,
    pub pages_free: usize,
    pub bytes_in_use: usize,
    pub bytes_per_page: usize,
    pub budget_bytes: Option<usize>,
    /// trie nodes currently caching a frozen page
    pub cached_pages: usize,
    pub prefix_hit_tokens: u64,
    pub prefix_miss_tokens: u64,
    pub evicted_pages: u64,
    /// allocations that had to proceed over budget because every cached
    /// page was pinned by a live session
    pub budget_overruns: u64,
}

impl PoolStats {
    /// Fraction of prefill tokens served from shared pages.
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hit_tokens + self.prefix_miss_tokens;
        if total == 0 {
            0.0
        } else {
            self.prefix_hit_tokens as f64 / total as f64
        }
    }
}

struct PoolInner {
    blocks: BlockPool,
    index: PrefixIndex,
    prefix_hit_tokens: u64,
    prefix_miss_tokens: u64,
}

/// Evict LRU index-only pages until the budget constraint holds.
/// `need_headroom` asks for room for one more page (allocation site);
/// otherwise the predicate is plain `bytes ≤ budget` (release site).
/// Live sessions are never evicted: if everything cached is pinned, an
/// allocating caller proceeds over budget and the overrun is counted.
fn trim_to_budget(blocks: &mut BlockPool, index: &mut PrefixIndex, need_headroom: bool) {
    loop {
        let over = if need_headroom {
            blocks.at_budget()
        } else {
            blocks.over_budget()
        };
        if !over {
            return;
        }
        match index.evict_lru(|p| blocks.refcount(p) == 1) {
            Some(p) => {
                blocks.decref(p);
                blocks.evicted_pages += 1;
            }
            None => {
                if need_headroom {
                    blocks.budget_overruns += 1;
                }
                return;
            }
        }
    }
}

/// The shared paged store. Cheap to clone an `Arc<KvPool>` per session;
/// all mutable state sits behind one mutex (the serving worker holds it
/// for one page-walk or one append at a time).
pub struct KvPool {
    page_size: usize,
    n_layer: usize,
    n_head: usize,
    layers: Vec<KvLayerQuant>,
    /// (q_k, q_v) per layer, cached for page byte accounting
    layer_qs: Vec<(u32, u32)>,
    inner: Mutex<PoolInner>,
}

impl KvPool {
    pub fn new(n_layer: usize, n_head: usize, layers: Vec<KvLayerQuant>, cfg: PoolConfig) -> Self {
        assert_eq!(layers.len(), n_layer, "one quantizer pair per layer");
        assert!(cfg.page_size >= 1);
        let layer_qs = layers.iter().map(|l| (l.k.q(), l.v.q())).collect();
        KvPool {
            page_size: cfg.page_size,
            n_layer,
            n_head,
            layers,
            layer_qs,
            inner: Mutex::new(PoolInner {
                blocks: BlockPool::new(
                    PageShape {
                        n_layer,
                        n_head,
                        page_size: cfg.page_size,
                        d_head: 0,
                    },
                    cfg.budget_bytes,
                ),
                index: PrefixIndex::new(),
                prefix_hit_tokens: 0,
                prefix_miss_tokens: 0,
            }),
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn n_layer(&self) -> usize {
        self.n_layer
    }

    pub fn n_head(&self) -> usize {
        self.n_head
    }

    /// The calibrated quantizer pair a given layer decodes with.
    pub fn layer_quant(&self, layer: usize) -> &KvLayerQuant {
        &self.layers[layer]
    }

    pub fn stats(&self) -> PoolStats {
        let g = self.inner.lock().unwrap();
        PoolStats {
            pages_in_use: g.blocks.pages_in_use(),
            pages_free: g.blocks.pages_free(),
            bytes_in_use: g.blocks.bytes_in_use(),
            bytes_per_page: g.blocks.bytes_per_page(),
            budget_bytes: g.blocks.budget_bytes(),
            cached_pages: g.index.len(),
            prefix_hit_tokens: g.prefix_hit_tokens,
            prefix_miss_tokens: g.prefix_miss_tokens,
            evicted_pages: g.blocks.evicted_pages,
            budget_overruns: g.blocks.budget_overruns,
        }
    }
}

/// Per-session view over a shared [`KvPool`]: owns a [`PageTable`], the
/// session's token history (for prefix registration) and a trie cursor.
pub struct SessionKv {
    pool: Arc<KvPool>,
    table: PageTable,
    tokens: Vec<i32>,
    /// (node, generation) registration cursor into the prefix trie
    cursor: (usize, u32),
}

impl SessionKv {
    pub fn new(pool: Arc<KvPool>) -> Self {
        let lanes = pool.n_layer * pool.n_head;
        SessionKv {
            pool,
            table: PageTable::new(lanes),
            tokens: Vec::new(),
            cursor: (0, 0),
        }
    }

    pub fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    fn lane(&self, layer: usize, head: usize) -> usize {
        debug_assert!(layer < self.pool.n_layer && head < self.pool.n_head);
        layer * self.pool.n_head + head
    }

    /// Cached positions for (layer, head).
    pub fn seq_len(&self, layer: usize, head: usize) -> usize {
        self.table.fill(self.lane(layer, head))
    }

    pub fn n_pages(&self) -> usize {
        self.table.n_pages()
    }

    /// Logical coded-payload bytes of this session's mapped pages
    /// (capacity-based: a page costs its full size once mapped).
    pub fn payload_bytes(&self) -> usize {
        let g = self.pool.inner.lock().unwrap();
        self.table.n_pages() * g.blocks.bytes_per_page()
    }

    /// Quantize and append one position's K and V for (layer, head).
    /// Copy-on-write and budget eviction are applied by the page claim.
    pub fn append(&mut self, layer: usize, head: usize, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), v.len());
        let lq = &self.pool.layers[layer];
        // quantization (the expensive part) runs outside the pool lock
        let qk = lq.k.quantize(k);
        let qv = lq.v.quantize(v);
        let lane = self.lane(layer, head);
        let mut g = self.pool.inner.lock().unwrap();
        let inner = &mut *g;
        if inner.blocks.d_head() == 0 {
            inner.blocks.set_d_head(k.len(), &self.pool.layer_qs);
        }
        assert_eq!(k.len(), inner.blocks.d_head(), "d_head fixed by first append");
        let index = &mut inner.index;
        let (pid, local) = self
            .table
            .claim_slot(lane, &mut inner.blocks, |b| trim_to_budget(b, index, true));
        let shape = *inner.blocks.shape();
        let (dh, bpv) = (shape.d_head, shape.blocks_per_vec());
        let s = shape.slot(lane, local);
        let page = inner.blocks.page_mut(pid);
        page.codes_k[s * dh..(s + 1) * dh].copy_from_slice(&qk.codes);
        page.beta_k[s * bpv..(s + 1) * bpv].copy_from_slice(&qk.beta_idx);
        page.scale_k[s] = qk.scale;
        page.codes_v[s * dh..(s + 1) * dh].copy_from_slice(&qv.codes);
        page.beta_v[s * bpv..(s + 1) * bpv].copy_from_slice(&qv.beta_idx);
        page.scale_v[s] = qv.scale;
    }

    /// Record the token behind the position just appended (all lanes).
    /// When this completes a page on every lane, the page freezes and is
    /// registered in the prefix index so later sessions can map it.
    pub fn note_token(&mut self, token: i32) {
        self.tokens.push(token);
        let ps = self.pool.page_size;
        let n = self.tokens.len();
        if n % ps != 0 {
            return;
        }
        if (0..self.pool.n_layer * self.pool.n_head).any(|l| self.table.fill(l) != n) {
            // ragged lanes (adapter usage) — nothing shareable
            return;
        }
        let mut g = self.pool.inner.lock().unwrap();
        let inner = &mut *g;
        let pid = self.table.pages()[n / ps - 1];
        inner.blocks.page_mut(pid).frozen = true;
        if !inner.index.valid(self.cursor.0, self.cursor.1) {
            // our registration point was evicted under us; stop
            // registering rather than grafting onto a recycled node
            return;
        }
        let chunk = &self.tokens[n - ps..n];
        if let Some(child) = inner.index.lookup_child(self.cursor.0, chunk) {
            // an identical chunk is already cached (computed earlier by
            // another session); keep ours private, descend the cursor
            self.cursor = (child, inner.index.gen(child));
        } else {
            inner.blocks.incref(pid); // the index's reference
            let node = inner.index.insert(self.cursor.0, chunk, pid);
            self.cursor = (node, inner.index.gen(node));
        }
    }

    /// Map the longest cached prefix of `prompt` (full pages, then at
    /// most one copy-on-write partial tail), capped at `prompt.len()-1`
    /// so the final prompt token is always recomputed for its logits.
    /// Returns the number of positions served from shared pages.
    pub fn match_prefix(&mut self, prompt: &[i32]) -> usize {
        assert!(
            self.tokens.is_empty() && self.table.n_pages() == 0,
            "match_prefix requires a fresh session"
        );
        let ps = self.pool.page_size;
        let cap = prompt.len().saturating_sub(1);
        let mut g = self.pool.inner.lock().unwrap();
        let inner = &mut *g;
        let mut node = inner.index.root();
        let mut matched = 0usize;
        if inner.blocks.d_head() != 0 {
            while matched + ps <= cap {
                let chunk = &prompt[matched..matched + ps];
                match inner.index.lookup_child(node, chunk) {
                    Some(child) => {
                        let pid = inner.index.page(child);
                        inner.blocks.incref(pid);
                        self.table.map_shared(pid, ps, ps);
                        node = child;
                        matched += ps;
                    }
                    None => break,
                }
            }
            if matched < cap {
                if let Some((child, m)) = inner.index.partial_child(node, &prompt[matched..cap]) {
                    let pid = inner.index.page(child);
                    inner.blocks.incref(pid);
                    self.table.map_shared(pid, m, ps);
                    matched += m;
                    // cursor stays at `node`: the partial page is not on
                    // our registration path (our tail diverges from it)
                }
            }
        }
        self.tokens.extend_from_slice(&prompt[..matched]);
        self.cursor = (node, inner.index.gen(node));
        inner.prefix_hit_tokens += matched as u64;
        inner.prefix_miss_tokens += (prompt.len() - matched) as u64;
        matched
    }

    /// Attention scores q·k_t for every cached position of (layer, head)
    /// (pre-softmax, unscaled), streamed page-by-page off the coded
    /// payload: all-integer block decode via [`DecodeConsts`] for
    /// M-variant codecs at q ≤ 16, float decode otherwise. Fixed stack
    /// scratch — no per-position allocation (`out` is reused across
    /// calls and only grows).
    pub fn scores(&self, layer: usize, head: usize, qvec: &[f32], out: &mut Vec<f32>) {
        out.clear();
        let lane = self.lane(layer, head);
        let total = self.table.fill(lane);
        if total == 0 {
            return;
        }
        let nq = &self.pool.layers[layer].k;
        let q = nq.q() as i32;
        let use_int = nq.codec.m_variant && q <= 16;
        let consts = DecodeConsts::new(q);
        let g = self.pool.inner.lock().unwrap();
        let shape = *g.blocks.shape();
        let (dh, bpv, ps) = (shape.d_head, shape.blocks_per_vec(), shape.page_size);
        debug_assert_eq!(qvec.len(), dh);
        let sqrt_dh = (dh as f32).sqrt();
        let mut c = [0u8; D];
        let mut e = [0i32; D];
        for (pi, &pid) in self.table.pages().iter().enumerate() {
            if pi * ps >= total {
                break;
            }
            let cnt = (total - pi * ps).min(ps);
            let page = g.blocks.page(pid);
            let s0 = shape.slot(lane, 0);
            for t in 0..cnt {
                let s = s0 + t;
                let scale = page.scale_k[s];
                if scale == 0.0 {
                    out.push(0.0);
                    continue;
                }
                let denorm = (scale / sqrt_dh) as f64;
                let codes = &page.codes_k[s * dh..(s + 1) * dh];
                let bidx = &page.beta_k[s * bpv..(s + 1) * bpv];
                let mut acc = 0f64;
                for j in 0..bpv {
                    c.copy_from_slice(&codes[j * D..(j + 1) * D]);
                    let xb = &qvec[j * D..(j + 1) * D];
                    if use_int {
                        consts.decode(&c, &mut e);
                        let mut d = 0f32;
                        for i in 0..D {
                            d += e[i] as f32 * xb[i];
                        }
                        acc += (d * 0.5 * nq.betas[bidx[j] as usize]) as f64;
                    } else {
                        let rec = nq.decode_block(&c, bidx[j]);
                        let mut d = 0f32;
                        for i in 0..D {
                            d += rec[i] * xb[i];
                        }
                        acc += d as f64;
                    }
                }
                out.push((acc * denorm) as f32);
            }
        }
    }

    /// out = Σ_t probs[t]·v_t for (layer, head): the decode-step value
    /// path, streamed page-by-page with the same integer decoder as
    /// [`Self::scores`] — replaces the per-position dequantize-into-Vec
    /// loop. `out` must be the head dimension; it is overwritten.
    pub fn weighted_value_sum(&self, layer: usize, head: usize, probs: &[f32], out: &mut [f32]) {
        out.fill(0.0);
        let lane = self.lane(layer, head);
        let total = self.table.fill(lane).min(probs.len());
        assert!(
            probs.len() <= self.table.fill(lane),
            "probs longer than cached positions"
        );
        if total == 0 {
            return;
        }
        let nq = &self.pool.layers[layer].v;
        let q = nq.q() as i32;
        let use_int = nq.codec.m_variant && q <= 16;
        let consts = DecodeConsts::new(q);
        let g = self.pool.inner.lock().unwrap();
        let shape = *g.blocks.shape();
        let (dh, bpv, ps) = (shape.d_head, shape.blocks_per_vec(), shape.page_size);
        assert_eq!(out.len(), dh);
        let sqrt_dh = (dh as f32).sqrt();
        let mut c = [0u8; D];
        let mut e = [0i32; D];
        for (pi, &pid) in self.table.pages().iter().enumerate() {
            if pi * ps >= total {
                break;
            }
            let cnt = (total - pi * ps).min(ps);
            let page = g.blocks.page(pid);
            let s0 = shape.slot(lane, 0);
            for t in 0..cnt {
                let p = probs[pi * ps + t];
                let s = s0 + t;
                let scale = page.scale_v[s];
                if scale == 0.0 {
                    continue;
                }
                let denorm = scale / sqrt_dh;
                let codes = &page.codes_v[s * dh..(s + 1) * dh];
                let bidx = &page.beta_v[s * bpv..(s + 1) * bpv];
                for j in 0..bpv {
                    c.copy_from_slice(&codes[j * D..(j + 1) * D]);
                    let ob = &mut out[j * D..(j + 1) * D];
                    if use_int {
                        consts.decode(&c, &mut e);
                        let beta = nq.betas[bidx[j] as usize];
                        for i in 0..D {
                            // (e·0.5)·β·denorm mirrors dequantize's
                            // (dec·β)·denorm bit-for-bit: e·0.5 is exact
                            ob[i] += p * (((e[i] as f32 * 0.5) * beta) * denorm);
                        }
                    } else {
                        let rec = nq.decode_block(&c, bidx[j]);
                        for i in 0..D {
                            ob[i] += p * (rec[i] * denorm);
                        }
                    }
                }
            }
        }
    }

    fn fetch(&self, layer: usize, head: usize, pos: usize, key: bool) -> Vec<f32> {
        let lane = self.lane(layer, head);
        assert!(pos < self.table.fill(lane), "position {pos} not cached");
        let g = self.pool.inner.lock().unwrap();
        let shape = *g.blocks.shape();
        let (dh, bpv, ps) = (shape.d_head, shape.blocks_per_vec(), shape.page_size);
        let page = g.blocks.page(self.table.pages()[pos / ps]);
        let s = shape.slot(lane, pos % ps);
        let (codes, beta, scale) = if key {
            (&page.codes_k, &page.beta_k, page.scale_k[s])
        } else {
            (&page.codes_v, &page.beta_v, page.scale_v[s])
        };
        let qv = QuantizedVector {
            codes: codes[s * dh..(s + 1) * dh].to_vec(),
            beta_idx: beta[s * bpv..(s + 1) * bpv].to_vec(),
            scale,
            n: dh,
        };
        let lq = &self.pool.layers[layer];
        if key {
            lq.k.dequantize(&qv)
        } else {
            lq.v.dequantize(&qv)
        }
    }

    /// Decode the key at a position (allocating; tests and diagnostics).
    pub fn key(&self, layer: usize, head: usize, pos: usize) -> Vec<f32> {
        self.fetch(layer, head, pos, true)
    }

    /// Decode the value at a position (allocating; tests and diagnostics).
    pub fn value(&self, layer: usize, head: usize, pos: usize) -> Vec<f32> {
        self.fetch(layer, head, pos, false)
    }
}

impl Drop for SessionKv {
    fn drop(&mut self) {
        let mut g = self.pool.inner.lock().unwrap();
        let inner = &mut *g;
        self.table.release(&mut inner.blocks);
        // freshly unpinned cached pages may now exceed the budget
        trim_to_budget(&mut inner.blocks, &mut inner.index, false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{propcheck, stats, Rng};

    fn pool(n_layer: usize, n_head: usize, cfg: PoolConfig) -> Arc<KvPool> {
        let nq = NestedLatticeQuantizer::new_m(14, vec![0.25, 0.32, 0.45, 1.0]);
        let layers = (0..n_layer)
            .map(|_| KvLayerQuant {
                k: nq.clone(),
                v: nq.clone(),
            })
            .collect();
        Arc::new(KvPool::new(n_layer, n_head, layers, cfg))
    }

    /// Append `n` positions with deterministic per-token vectors to every
    /// lane and note the token, emulating a generation session.
    fn run_session(sess: &mut SessionKv, tokens: &[i32], dh: usize) {
        let p = sess.pool().clone();
        for (t, &tok) in tokens.iter().enumerate() {
            for l in 0..p.n_layer() {
                for h in 0..p.n_head() {
                    let mut rng = Rng::new(0x5EED ^ tok as u64 ^ ((t as u64) << 32));
                    let k = rng.gauss_vec(dh);
                    let v = rng.gauss_vec(dh);
                    sess.append(l, h, &k, &v);
                }
            }
            sess.note_token(tok);
        }
    }

    #[test]
    fn prefix_hit_shares_pages_and_decodes_identically() {
        let p = pool(2, 2, PoolConfig { page_size: 4, budget_bytes: None });
        let dh = 16;
        let toks: Vec<i32> = (0..17).collect();
        let mut a = SessionKv::new(p.clone());
        run_session(&mut a, &toks, dh);
        let a_pages = a.n_pages();
        let a_bytes = a.payload_bytes();
        assert_eq!(a_pages, 5); // 17 positions / 4 per page

        let mut b = SessionKv::new(p.clone());
        let matched = b.match_prefix(&toks);
        // cap = 16 → 4 full pages; no partial child of the last node
        assert_eq!(matched, 16);
        assert_eq!(b.n_pages(), 4);
        // shared pages decode bit-identically for both sessions
        for pos in [0usize, 3, 7, 15] {
            assert_eq!(a.key(1, 0, pos), b.key(1, 0, pos));
            assert_eq!(a.value(0, 1, pos), b.value(0, 1, pos));
        }
        // pool-wide: the second session added zero pages
        assert_eq!(p.stats().pages_in_use, 5);
        assert!(p.stats().bytes_in_use < a_bytes * 2);
        assert_eq!(p.stats().prefix_hit_tokens, 16);
        assert_eq!(p.stats().prefix_miss_tokens, 1);
    }

    #[test]
    fn partial_tail_match_is_copy_on_write() {
        let p = pool(1, 1, PoolConfig { page_size: 4, budget_bytes: None });
        let dh = 16;
        let toks: Vec<i32> = (0..8).collect();
        let mut a = SessionKv::new(p.clone());
        run_session(&mut a, &toks, dh);

        // B shares 6 of A's 8 tokens then diverges
        let b_toks = vec![0, 1, 2, 3, 4, 5, 99, 98];
        let mut b = SessionKv::new(p.clone());
        let matched = b.match_prefix(&b_toks);
        assert_eq!(matched, 6, "1 full page + 2-token partial tail");
        let shared_tail = b.table.pages()[1];
        assert_eq!(shared_tail, a.table.pages()[1]);
        // diverging append must COW the tail, leaving A's data intact
        let a_key_before = a.key(0, 0, 6);
        run_session(&mut b, &b_toks[6..], dh);
        assert_ne!(b.table.pages()[1], shared_tail, "tail not copied on write");
        assert_eq!(a.key(0, 0, 6), a_key_before);
        // shared positions still decode identically; diverged ones differ
        assert_eq!(a.key(0, 0, 5), b.key(0, 0, 5));
        assert_ne!(a.key(0, 0, 6), b.key(0, 0, 6));
    }

    #[test]
    fn streaming_kernels_match_dequantized_reference() {
        for m_variant in [false, true] {
            let betas = vec![0.25, 0.32, 0.45, 1.0];
            let nq = if m_variant {
                NestedLatticeQuantizer::new_m(14, betas)
            } else {
                NestedLatticeQuantizer::new(14, betas)
            };
            let layers = vec![KvLayerQuant { k: nq.clone(), v: nq.clone() }];
            let cfg = PoolConfig { page_size: 4, budget_bytes: None };
            let p = Arc::new(KvPool::new(1, 1, layers, cfg));
            let mut sess = SessionKv::new(p);
            let dh = 16;
            let mut rng = Rng::new(1704);
            for _ in 0..11 {
                let k = rng.gauss_vec(dh);
                let v = rng.gauss_vec(dh);
                sess.append(0, 0, &k, &v);
            }
            let qv = rng.gauss_vec(dh);
            let mut scores = Vec::new();
            sess.scores(0, 0, &qv, &mut scores);
            assert_eq!(scores.len(), 11);
            let probs: Vec<f32> = (0..11).map(|i| 0.05 + 0.01 * i as f32).collect();
            let mut wsum = vec![0f32; dh];
            sess.weighted_value_sum(0, 0, &probs, &mut wsum);
            let mut expect_w = vec![0f32; dh];
            for t in 0..11 {
                let kd = sess.key(0, 0, t);
                let s = stats::dot(&qv, &kd) as f32;
                assert!(
                    (scores[t] - s).abs() < 1e-4 * (1.0 + s.abs()),
                    "m={m_variant} t={t}: streaming {} vs reference {s}",
                    scores[t]
                );
                let vd = sess.value(0, 0, t);
                for i in 0..dh {
                    expect_w[i] += probs[t] * vd[i];
                }
            }
            for i in 0..dh {
                assert!(
                    (wsum[i] - expect_w[i]).abs() < 1e-5 * (1.0 + expect_w[i].abs()),
                    "m={m_variant} value sum diverges at {i}: {} vs {}",
                    wsum[i],
                    expect_w[i]
                );
            }
        }
    }

    #[test]
    fn eviction_reclaims_cached_runs_and_respects_live_sessions() {
        let dh = 16;
        // budget: 6 pages exactly
        let probe = pool(1, 1, PoolConfig { page_size: 4, budget_bytes: None });
        let bpp = {
            let mut s = SessionKv::new(probe.clone());
            s.append(0, 0, &vec![0.5; dh], &vec![0.5; dh]);
            probe.stats().bytes_per_page
        };
        let p = pool(1, 1, PoolConfig { page_size: 4, budget_bytes: Some(6 * bpp) });

        let toks_a: Vec<i32> = (0..16).collect();
        let mut a = SessionKv::new(p.clone());
        run_session(&mut a, &toks_a, dh);
        assert_eq!(p.stats().pages_in_use, 4);
        // A finishes: its 4 frozen pages stay cached in the index
        drop(a);
        assert_eq!(p.stats().pages_in_use, 4);
        assert_eq!(p.stats().cached_pages, 4);

        // B (live, disjoint tokens) needs 4 pages; budget 6 forces LRU
        // eviction of A's cached run
        let toks_b: Vec<i32> = (100..116).collect();
        let mut b = SessionKv::new(p.clone());
        assert_eq!(b.match_prefix(&toks_b), 0);
        run_session(&mut b, &toks_b, dh);
        let st = p.stats();
        assert!(st.evicted_pages >= 2, "expected LRU evictions, got {st:?}");
        assert!(st.bytes_in_use <= 6 * bpp, "budget exceeded: {st:?}");
        assert_eq!(st.budget_overruns, 0);

        // a live session under eviction pressure still scores
        // bit-identically to an unconstrained pool
        let unbounded = pool(1, 1, PoolConfig { page_size: 4, budget_bytes: None });
        let mut c = SessionKv::new(unbounded);
        run_session(&mut c, &toks_b, dh);
        let mut b_scores = Vec::new();
        let mut c_scores = Vec::new();
        b.scores(0, 0, &vec![0.3; dh], &mut b_scores);
        c.scores(0, 0, &vec![0.3; dh], &mut c_scores);
        for (x, y) in b_scores.iter().zip(&c_scores) {
            assert_eq!(x.to_bits(), y.to_bits(), "eviction changed live scores");
        }
        // A's run was evicted bottom-up: the tail is gone, so a rematch
        // can recover at most the surviving head of the run
        let mut d = SessionKv::new(p.clone());
        assert!(
            d.match_prefix(&toks_a) <= 8,
            "evicted tail pages must not be matchable"
        );
    }

    #[test]
    fn budget_overrun_counted_when_all_pages_pinned() {
        let dh = 16;
        let probe = pool(1, 1, PoolConfig { page_size: 4, budget_bytes: None });
        let bpp = {
            let mut s = SessionKv::new(probe.clone());
            s.append(0, 0, &vec![0.5; dh], &vec![0.5; dh]);
            probe.stats().bytes_per_page
        };
        let p = pool(1, 1, PoolConfig { page_size: 4, budget_bytes: Some(2 * bpp) });
        let mut a = SessionKv::new(p.clone());
        run_session(&mut a, &(0..16).collect::<Vec<_>>(), dh);
        let st = p.stats();
        assert_eq!(st.pages_in_use, 4, "live traffic is never refused");
        assert!(st.budget_overruns > 0);
        drop(a);
        // once the session ends, the trim brings the cache under budget
        assert!(p.stats().bytes_in_use <= 2 * bpp);
    }

    #[test]
    fn pool_sessions_propcheck_no_leaks_budget_respected() {
        // random session traffic: spawn / extend / drop sessions against
        // a budgeted pool; invariants checked at every step: page
        // accounting consistent, and whenever no session is live the
        // cached footprint is within budget.
        propcheck::check("kvpool-session-traffic", 8, 0xF00D_0011, |rng| {
            let dh = 8;
            let probe = pool(1, 1, PoolConfig { page_size: 2, budget_bytes: None });
            let bpp = {
                let mut s = SessionKv::new(probe.clone());
                s.append(0, 0, &vec![0.5; dh], &vec![0.5; dh]);
                probe.stats().bytes_per_page
            };
            let p = pool(1, 1, PoolConfig { page_size: 2, budget_bytes: Some(5 * bpp) });
            let mut live: Vec<SessionKv> = Vec::new();
            for step in 0..60 {
                match rng.below(4) {
                    0 => {
                        let mut s = SessionKv::new(p.clone());
                        let start = rng.below(4) as i32;
                        let toks: Vec<i32> = (start..start + 4).collect();
                        s.match_prefix(&toks);
                        let done = s.tokens.len();
                        let rest: Vec<i32> = toks[done..].to_vec();
                        run_session(&mut s, &rest, dh);
                        live.push(s);
                    }
                    1 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        let tok = rng.below(50) as i32;
                        run_session(&mut live[i], &[tok], dh);
                    }
                    2 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        live.swap_remove(i);
                    }
                    _ => {}
                }
                let st = p.stats();
                if live.is_empty() && st.bytes_in_use > 5 * bpp {
                    return Err(format!("idle pool over budget at step {step}: {st:?}"));
                }
                let mapped: usize = live.iter().map(|s| s.n_pages()).sum();
                if st.pages_in_use > mapped + st.cached_pages {
                    return Err(format!(
                        "accounting drift at step {step}: in_use {} > mapped {mapped} + cached {}",
                        st.pages_in_use, st.cached_pages
                    ));
                }
            }
            drop(live);
            let st = p.stats();
            if st.bytes_in_use > 5 * bpp {
                return Err(format!("final pool over budget: {st:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn per_layer_quantizers_decode_with_their_own_pair() {
        // layer 0: fine quantizer (q=14); layer 1: coarse (q=3). The same
        // vector stored in both layers must come back through the
        // layer's own codec — coarse decode ≠ fine decode.
        let fine = NestedLatticeQuantizer::new_m(14, vec![0.25, 0.32, 0.45, 1.0]);
        let coarse = NestedLatticeQuantizer::new_m(3, vec![0.5, 1.0]);
        let layers = vec![
            KvLayerQuant { k: fine.clone(), v: fine.clone() },
            KvLayerQuant { k: coarse.clone(), v: coarse.clone() },
        ];
        let p = Arc::new(KvPool::new(2, 1, layers, PoolConfig::default()));
        let mut sess = SessionKv::new(p);
        let mut rng = Rng::new(9);
        let x = rng.gauss_vec(16);
        sess.append(0, 0, &x, &x);
        sess.append(1, 0, &x, &x);
        let d0 = sess.key(0, 0, 0);
        let d1 = sess.key(1, 0, 0);
        assert_eq!(d0, fine.roundtrip(&x), "layer 0 must use its own quantizer");
        assert_eq!(d1, coarse.roundtrip(&x), "layer 1 must use its own quantizer");
        assert!(
            stats::rmse(&x, &d0) < stats::rmse(&x, &d1),
            "fine layer should reconstruct better"
        );
    }
}
