//! Paged KV pool — the **sole** KV backend: a shared, budgeted store for
//! per-layer-coded KV payloads serving many generation sessions at once
//! (the paper's §1/§4.6 serving motivation compounded with vLLM-style
//! paging).
//!
//! Every layer carries its own [`KvLaneCodec`]: raw fp32 lanes
//! (unquantized layers — including entire all-fp models), branch-free
//! uniform lanes (the scalar baselines), or calibrated nested-lattice
//! pairs (§4.6 step 4 — per-layer dictionaries). Pages are heterogeneous
//! within: the byte arena is addressed through per-layer strides
//! ([`block::PageLayout`]), so a plan mixing fp, uniform and nested KV
//! layers runs end-to-end through one pool, and the bytes each lane
//! stores are exactly what the batch-eval fake-quant path reconstructs —
//! eval and serve consume bitwise-identical KV values. The pool is built
//! from:
//!
//! * [`block::BlockPool`] — slab allocator of fixed-size pages
//!   (`page_size` positions × every (layer, head) lane × coded K/V) with
//!   free-list recycling, refcounts and a global byte budget;
//! * [`page_table::PageTable`] — per-session logical→physical mapping
//!   with copy-on-write on shared / partial tail pages;
//! * [`prefix::PrefixIndex`] — an exact-token-chunk trie over frozen
//!   pages: a new session whose prompt shares a prefix with a live or
//!   recently finished session maps the shared pages (refcount bump,
//!   **zero quantization work**) instead of re-coding them;
//! * LRU eviction of index-held page runs when the budget is exceeded.
//!
//! [`SessionKv`] is the per-session view; its `scores` /
//! `weighted_value_sum` kernels dispatch **once per call** on the lane's
//! codec and then stream page-by-page straight off the coded payloads —
//! fp32 copy, branch-free uniform decode, or the
//! [`crate::quant::qgemm::DecodeConsts`] all-integer nested decoder (the
//! same as the packed GEMM) — with fixed stack scratch: no per-position
//! `Vec<f32>` is ever materialized on any decode hot path.

#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod block;
pub mod page_table;
pub mod prefix;

pub use block::{BlockPool, LaneClass, LaneSpec, PageId, PageShape};
pub use page_table::{ClaimKind, PageTable};
pub use prefix::PrefixIndex;

use crate::lattice::e8::D;
use crate::lattice::nested::{payload_bits_for, NestedLatticeQuantizer, QuantizedVector};
use crate::obs::trace::{EventKind, Trace, TRACK_POOL};
use crate::quant::kernels;
use crate::quant::qgemm::DecodeConsts;
use crate::quant::uniform::UniformQuantizer;
use std::sync::{Arc, Mutex, OnceLock};

/// How one layer's KV lane stores (and fake-quants) its vectors — the
/// single source of truth shared by the batch-eval roundtrip
/// (`Engine::forward_window`) and the pool's coded serving path, which
/// is what makes mixed-precision plans eval-vs-serve consistent.
#[derive(Clone, Debug)]
pub enum KvLaneCodec {
    /// exact fp32 lane (raw little-endian bytes in the page arena)
    Fp32,
    /// symmetric uniform fake-quant at `bits` (one code byte per entry
    /// plus a per-vector Δ in the scale slot)
    Uniform(u32),
    /// calibrated nested-lattice pair (coset codes + β indices + scale)
    Nested {
        k: NestedLatticeQuantizer,
        v: NestedLatticeQuantizer,
    },
}

impl KvLaneCodec {
    /// True for the exact fp32 lane (the per-site analog of the legacy
    /// `KvQuant::None`).
    pub fn is_fp(&self) -> bool {
        matches!(self, KvLaneCodec::Fp32)
    }

    /// Accounting/metrics bucket of this codec.
    pub fn class(&self) -> LaneClass {
        match self {
            KvLaneCodec::Fp32 => LaneClass::Fp,
            KvLaneCodec::Uniform(_) => LaneClass::Uniform,
            KvLaneCodec::Nested { .. } => LaneClass::Nested,
        }
    }

    /// Physical/logical per-vector lane costs at head dimension
    /// `d_head`, for K and V.
    pub fn lane_specs(&self, d_head: usize) -> (LaneSpec, LaneSpec) {
        match self {
            KvLaneCodec::Fp32 => {
                let s = LaneSpec {
                    class: LaneClass::Fp,
                    stride: 4 * d_head,
                    bits: 32 * d_head,
                };
                (s, s)
            }
            KvLaneCodec::Uniform(bits) => {
                let s = LaneSpec {
                    class: LaneClass::Uniform,
                    stride: d_head,
                    bits: *bits as usize * d_head + 32, // + f32 Δ
                };
                (s, s)
            }
            KvLaneCodec::Nested { k, v } => {
                let stride = d_head + d_head / D; // codes + β indices
                let spec = |q: u32| LaneSpec {
                    class: LaneClass::Nested,
                    stride,
                    bits: payload_bits_for(d_head, q),
                };
                (spec(k.q()), spec(v.q()))
            }
        }
    }

    fn roundtrip(&self, key: bool, x: &mut [f32]) {
        match self {
            KvLaneCodec::Fp32 => {}
            KvLaneCodec::Uniform(bits) => {
                let uq = UniformQuantizer::new(*bits);
                let rt = uq.roundtrip(x);
                x.copy_from_slice(&rt);
            }
            KvLaneCodec::Nested { k, v } => {
                let nq = if key { k } else { v };
                let rt = nq.roundtrip(x);
                x.copy_from_slice(&rt);
            }
        }
    }

    /// Fake-quant a per-head key vector — the batch-eval path. The
    /// pool's coded storage decodes bitwise-identically to this.
    pub fn roundtrip_key(&self, x: &mut [f32]) {
        self.roundtrip(true, x);
    }

    /// Fake-quant a per-head value vector.
    pub fn roundtrip_value(&self, x: &mut [f32]) {
        self.roundtrip(false, x);
    }
}

/// Pool sizing knobs.
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    /// positions per page (16 ≈ the vLLM default block size)
    pub page_size: usize,
    /// global logical-payload byte budget; `None` = unbounded
    pub budget_bytes: Option<usize>,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            page_size: 16,
            budget_bytes: None,
        }
    }
}

/// Point-in-time pool gauges (exported through `coordinator::Metrics`).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    pub pages_in_use: usize,
    pub pages_free: usize,
    pub bytes_in_use: usize,
    /// exact logical bytes per page (the budget accounting unit)
    pub bytes_per_page: usize,
    /// per-page logical bytes stored in fp32 lanes (each class bucket
    /// rounds its own bit total up, so the three buckets can exceed
    /// `bytes_per_page` by at most 2 bytes)
    pub page_bytes_fp: usize,
    /// per-page logical bytes stored in uniform lanes
    pub page_bytes_uniform: usize,
    /// per-page logical bytes stored in nested-lattice lanes
    pub page_bytes_nested: usize,
    pub budget_bytes: Option<usize>,
    /// trie nodes currently caching a frozen page
    pub cached_pages: usize,
    pub prefix_hit_tokens: u64,
    pub prefix_miss_tokens: u64,
    pub evicted_pages: u64,
    /// allocations that had to proceed over budget because every cached
    /// page was pinned by a live session
    pub budget_overruns: u64,
}

impl PoolStats {
    /// Fraction of prefill tokens served from shared pages.
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hit_tokens + self.prefix_miss_tokens;
        if total == 0 {
            0.0
        } else {
            self.prefix_hit_tokens as f64 / total as f64
        }
    }

    /// Bytes in use split per lane-codec class `[fp, uniform, nested]`.
    pub fn bytes_in_use_split(&self) -> [usize; 3] {
        [
            self.pages_in_use * self.page_bytes_fp,
            self.pages_in_use * self.page_bytes_uniform,
            self.pages_in_use * self.page_bytes_nested,
        ]
    }
}

struct PoolInner {
    blocks: BlockPool,
    index: PrefixIndex,
    prefix_hit_tokens: u64,
    prefix_miss_tokens: u64,
}

/// Evict LRU index-only pages until the budget constraint holds.
/// `need_headroom` asks for room for one more page (allocation site);
/// otherwise the predicate is plain `bytes ≤ budget` (release site).
/// Live sessions are never evicted: if everything cached is pinned, an
/// allocating caller proceeds over budget and the overrun is counted.
/// With a trace attached, every eviction and overrun lands in the
/// journal as a kvpool event.
fn trim_to_budget(
    blocks: &mut BlockPool,
    index: &mut PrefixIndex,
    need_headroom: bool,
    trace: Option<&Trace>,
) {
    loop {
        let over = if need_headroom {
            blocks.at_budget()
        } else {
            blocks.over_budget()
        };
        if !over {
            return;
        }
        match index.evict_lru(|p| blocks.refcount(p) == 1) {
            Some(p) => {
                blocks.decref(p);
                blocks.evicted_pages += 1;
                if let Some(t) = trace {
                    t.instant(TRACK_POOL, EventKind::PageEvict);
                }
            }
            None => {
                if need_headroom {
                    blocks.budget_overruns += 1;
                    if let Some(t) = trace {
                        t.instant(TRACK_POOL, EventKind::BudgetOverrun);
                    }
                }
                return;
            }
        }
    }
}

/// The shared paged store. Cheap to clone an `Arc<KvPool>` per session;
/// all mutable state sits behind one mutex (the serving worker holds it
/// for one page-walk or one append at a time).
pub struct KvPool {
    page_size: usize,
    n_layer: usize,
    n_head: usize,
    /// one lane codec per layer
    lanes: Vec<KvLaneCodec>,
    inner: Mutex<PoolInner>,
    /// attached observability journal (pools are built by the engine
    /// before the server's trace exists, so the hookup is late-bound;
    /// `OnceLock::get` on the hot path is one relaxed atomic load)
    trace: OnceLock<Arc<Trace>>,
}

impl KvPool {
    /// All pool state sits behind this mutex. Injected faults and
    /// contained panics deliberately fire *before* the lock is taken
    /// (see the `fail_point!` sites), but a panic elsewhere while the
    /// guard was held must not cascade: every invariant the pool relies
    /// on is restored before the holding call can panic, so a poisoned
    /// lock is recovered rather than propagated.
    fn guard(&self) -> std::sync::MutexGuard<'_, PoolInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn new(n_layer: usize, n_head: usize, lanes: Vec<KvLaneCodec>, cfg: PoolConfig) -> Self {
        assert_eq!(lanes.len(), n_layer, "one lane codec per layer");
        assert!(cfg.page_size >= 1);
        KvPool {
            page_size: cfg.page_size,
            n_layer,
            n_head,
            lanes,
            inner: Mutex::new(PoolInner {
                blocks: BlockPool::new(
                    PageShape {
                        n_layer,
                        n_head,
                        page_size: cfg.page_size,
                        d_head: 0,
                    },
                    cfg.budget_bytes,
                ),
                index: PrefixIndex::new(),
                prefix_hit_tokens: 0,
                prefix_miss_tokens: 0,
            }),
            trace: OnceLock::new(),
        }
    }

    /// Attach an observability journal: page alloc / copy-on-write /
    /// eviction / budget-overrun events flow to it from every session.
    /// First attachment wins; later calls are ignored.
    pub fn set_trace(&self, trace: Arc<Trace>) {
        let _ = self.trace.set(trace);
    }

    fn trace(&self) -> Option<&Trace> {
        self.trace.get().map(|t| t.as_ref())
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn n_layer(&self) -> usize {
        self.n_layer
    }

    pub fn n_head(&self) -> usize {
        self.n_head
    }

    /// The codec a given layer's KV lane stores with.
    pub fn lane(&self, layer: usize) -> &KvLaneCodec {
        &self.lanes[layer]
    }

    fn lane_specs(&self, d_head: usize) -> Vec<(LaneSpec, LaneSpec)> {
        self.lanes.iter().map(|c| c.lane_specs(d_head)).collect()
    }

    pub fn stats(&self) -> PoolStats {
        let g = self.guard();
        let [fp, uni, nest] = g.blocks.class_bytes();
        PoolStats {
            pages_in_use: g.blocks.pages_in_use(),
            pages_free: g.blocks.pages_free(),
            bytes_in_use: g.blocks.bytes_in_use(),
            bytes_per_page: g.blocks.bytes_per_page(),
            page_bytes_fp: fp,
            page_bytes_uniform: uni,
            page_bytes_nested: nest,
            budget_bytes: g.blocks.budget_bytes(),
            cached_pages: g.index.len(),
            prefix_hit_tokens: g.prefix_hit_tokens,
            prefix_miss_tokens: g.prefix_miss_tokens,
            evicted_pages: g.blocks.evicted_pages,
            budget_overruns: g.blocks.budget_overruns,
        }
    }

    /// Would allocating `new_pages` fresh pages exceed the byte budget
    /// even after evicting every reclaimable (index-only) cached page?
    /// The fused decode scheduler preempts sessions while this holds —
    /// *before* the allocations happen — which keeps `budget_overruns`
    /// at zero whenever shrinking the live set can restore headroom.
    pub fn would_overrun(&self, new_pages: usize) -> bool {
        let g = self.guard();
        let Some(budget) = g.blocks.budget_bytes() else {
            return false;
        };
        let bpp = g.blocks.bytes_per_page();
        if bpp == 0 {
            // layout not fixed yet: nothing allocated, nothing to predict
            return false;
        }
        let evictable = g.index.count_pages(|p| g.blocks.refcount(p) == 1);
        let pages = (g.blocks.pages_in_use() + new_pages).saturating_sub(evictable);
        pages * bpp > budget
    }

    /// Leak audit for an idle pool (no live sessions): every in-use page
    /// must be a prefix-cache page holding exactly its one index
    /// reference. A faulted session teardown that leaked a page or a
    /// refcount shows up here as `Err` — the serving worker records the
    /// verdict in `Metrics` when it drains.
    pub fn verify_idle(&self) -> Result<(), String> {
        let g = self.guard();
        let in_use = g.blocks.pages_in_use();
        let cached = g.index.len();
        let singly = g.index.count_pages(|p| g.blocks.refcount(p) == 1);
        if in_use != cached {
            return Err(format!(
                "{in_use} pages in use but {cached} cached in the prefix index \
                 ({} page(s) unaccounted)",
                in_use.abs_diff(cached)
            ));
        }
        if singly != cached {
            return Err(format!(
                "{} cached page(s) hold refcounts beyond the index's own",
                cached - singly
            ));
        }
        Ok(())
    }
}

/// Reusable per-session coding buffers: `append` quantizes into these
/// (outside the pool lock) instead of allocating per token — the fused
/// decode hot loop is allocation-free once they are warm.
#[derive(Default)]
struct CodeScratch {
    ck: Vec<i8>,
    cv: Vec<i8>,
    qk: QuantizedVector,
    qv: QuantizedVector,
}

/// Per-session view over a shared [`KvPool`]: owns a [`PageTable`], the
/// session's token history (for prefix registration) and a trie cursor.
pub struct SessionKv {
    pool: Arc<KvPool>,
    table: PageTable,
    tokens: Vec<i32>,
    /// (node, generation) registration cursor into the prefix trie
    cursor: (usize, u32),
    code: CodeScratch,
}

impl SessionKv {
    pub fn new(pool: Arc<KvPool>) -> Self {
        let lanes = pool.n_layer * pool.n_head;
        SessionKv {
            pool,
            table: PageTable::new(lanes),
            tokens: Vec::new(),
            cursor: (0, 0),
            code: CodeScratch::default(),
        }
    }

    /// Single-owner adapter: a private, unbudgeted pool with the given
    /// lane codec replicated across layers — the old `KvCache::new_nest`
    /// (and, with [`KvLaneCodec::Fp32`], `KvCache::new_fp`) behaviour,
    /// for tests/benches that need no pool plumbing.
    pub fn solo(n_layer: usize, n_head: usize, lane: KvLaneCodec) -> Self {
        let lanes = (0..n_layer).map(|_| lane.clone()).collect();
        SessionKv::new(Arc::new(KvPool::new(
            n_layer,
            n_head,
            lanes,
            PoolConfig::default(),
        )))
    }

    pub fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    fn lane(&self, layer: usize, head: usize) -> usize {
        debug_assert!(layer < self.pool.n_layer && head < self.pool.n_head);
        layer * self.pool.n_head + head
    }

    /// Cached positions for (layer, head).
    pub fn seq_len(&self, layer: usize, head: usize) -> usize {
        self.table.fill(self.lane(layer, head))
    }

    pub fn n_pages(&self) -> usize {
        self.table.n_pages()
    }

    /// Logical coded-payload bytes of this session's mapped pages
    /// (capacity-based: a page costs its full size once mapped).
    pub fn payload_bytes(&self) -> usize {
        let g = self.pool.guard();
        self.table.n_pages() * g.blocks.bytes_per_page()
    }

    /// Code and append one position's K and V for (layer, head) through
    /// the layer's lane codec. Copy-on-write and budget eviction are
    /// applied by the page claim.
    pub fn append(&mut self, layer: usize, head: usize, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), v.len());
        if self.table.fill(self.lane(layer, head)) % self.pool.page_size == 0 {
            // this append claims a fresh (or copy-on-write) page; the
            // site fires before any coding or locking so a contained
            // panic leaves the pool's accounting untouched
            crate::fail_point!("kvpool/alloc");
        }
        // coding (the expensive part) runs outside the pool lock, into
        // the session-owned scratch buffers
        enum Kind {
            Fp,
            Uniform { dk: f32, dv: f32 },
            Nested,
        }
        let kind = match &self.pool.lanes[layer] {
            KvLaneCodec::Fp32 => Kind::Fp,
            KvLaneCodec::Uniform(bits) => {
                let uq = UniformQuantizer::new(*bits);
                let dk = uq.quantize_into(k, &mut self.code.ck);
                let dv = uq.quantize_into(v, &mut self.code.cv);
                Kind::Uniform { dk, dv }
            }
            KvLaneCodec::Nested { k: knq, v: vnq } => {
                knq.quantize_into(k, &mut self.code.qk);
                vnq.quantize_into(v, &mut self.code.qv);
                Kind::Nested
            }
        };
        let lane = self.lane(layer, head);
        let mut g = self.pool.guard();
        let inner = &mut *g;
        if inner.blocks.d_head() == 0 {
            // once per pool lifetime, so the spec Vec is not a per-append
            // allocation
            let specs = self.pool.lane_specs(k.len());
            inner.blocks.set_d_head(k.len(), &specs);
        }
        assert_eq!(k.len(), inner.blocks.d_head(), "d_head fixed by first append");
        let index = &mut inner.index;
        let trace = self.pool.trace();
        let (pid, local, claim) = self
            .table
            .claim_slot(lane, &mut inner.blocks, |b| {
                trim_to_budget(b, index, true, trace)
            });
        if let Some(t) = trace {
            match claim {
                ClaimKind::Fresh => t.instant(TRACK_POOL, EventKind::PageAlloc),
                ClaimKind::Cow => t.instant(TRACK_POOL, EventKind::PageCow),
                ClaimKind::Existing => {}
            }
        }
        let (layout, page) = inner.blocks.page_mut_with_layout(pid);
        let s = layout.shape().slot(lane, local);
        let kr = layout.k_range(layer, head, local);
        let vr = layout.v_range(layer, head, local);
        let dh = k.len();
        match kind {
            Kind::Fp => {
                for (dst, &x) in page.data[kr].chunks_exact_mut(4).zip(k) {
                    dst.copy_from_slice(&x.to_le_bytes());
                }
                for (dst, &x) in page.data[vr].chunks_exact_mut(4).zip(v) {
                    dst.copy_from_slice(&x.to_le_bytes());
                }
            }
            Kind::Uniform { dk, dv } => {
                for (dst, &c) in page.data[kr].iter_mut().zip(&self.code.ck) {
                    *dst = c as u8;
                }
                page.scale_k[s] = dk;
                for (dst, &c) in page.data[vr].iter_mut().zip(&self.code.cv) {
                    *dst = c as u8;
                }
                page.scale_v[s] = dv;
            }
            Kind::Nested => {
                let (qk, qv) = (&self.code.qk, &self.code.qv);
                let dst = &mut page.data[kr];
                dst[..dh].copy_from_slice(&qk.codes);
                dst[dh..].copy_from_slice(&qk.beta_idx);
                page.scale_k[s] = qk.scale;
                let dst = &mut page.data[vr];
                dst[..dh].copy_from_slice(&qv.codes);
                dst[dh..].copy_from_slice(&qv.beta_idx);
                page.scale_v[s] = qv.scale;
            }
        }
    }

    /// Pre-reserve the token-history buffer (e.g. to the model context
    /// length) so per-token [`Self::note_token`] pushes never reallocate
    /// on the fused decode hot loop.
    pub fn reserve_tokens(&mut self, n: usize) {
        self.tokens.reserve(n);
    }

    /// Swap this session out under pool pressure: unmap every page and
    /// reset to the fresh-session state [`Self::match_prefix`] requires.
    /// Frozen pages registered in the prefix index stay cached, so a
    /// requeued session re-maps its shared prefix (bitwise-identical
    /// bytes) instead of re-coding it; only the partial tail is
    /// recomputed. Returns the number of pages released.
    pub fn preempt(&mut self) -> usize {
        let released = self.table.n_pages();
        let mut g = self.pool.guard();
        let inner = &mut *g;
        self.table.release(&mut inner.blocks);
        // freshly unpinned cached pages may now exceed the budget
        trim_to_budget(&mut inner.blocks, &mut inner.index, false, self.pool.trace());
        self.tokens.clear();
        self.cursor = (inner.index.root(), 0);
        released
    }

    /// Record the token behind the position just appended (all lanes).
    /// When this completes a page on every lane, the page freezes and is
    /// registered in the prefix index so later sessions can map it.
    pub fn note_token(&mut self, token: i32) {
        self.tokens.push(token);
        let ps = self.pool.page_size;
        let n = self.tokens.len();
        if n % ps != 0 {
            return;
        }
        let lanes = self.pool.n_layer * self.pool.n_head;
        if lanes == 0 || (0..lanes).any(|l| self.table.fill(l) != n) {
            // ragged (adapter usage) or degenerate lanes — nothing
            // shareable
            return;
        }
        let mut g = self.pool.guard();
        let inner = &mut *g;
        let pid = self.table.pages()[n / ps - 1];
        inner.blocks.page_mut(pid).frozen = true;
        if !inner.index.valid(self.cursor.0, self.cursor.1) {
            // our registration point was evicted under us; stop
            // registering rather than grafting onto a recycled node
            return;
        }
        let chunk = &self.tokens[n - ps..n];
        if let Some(child) = inner.index.lookup_child(self.cursor.0, chunk) {
            // an identical chunk is already cached (computed earlier by
            // another session); keep ours private, descend the cursor
            self.cursor = (child, inner.index.gen(child));
        } else {
            inner.blocks.incref(pid); // the index's reference
            let node = inner.index.insert(self.cursor.0, chunk, pid);
            self.cursor = (node, inner.index.gen(node));
        }
    }

    /// Map the longest cached prefix of `prompt` (full pages, then at
    /// most one copy-on-write partial tail), capped at `prompt.len()-1`
    /// so the final prompt token is always recomputed for its logits.
    /// Returns the number of positions served from shared pages.
    pub fn match_prefix(&mut self, prompt: &[i32]) -> usize {
        assert!(
            self.tokens.is_empty() && self.table.n_pages() == 0,
            "match_prefix requires a fresh session"
        );
        let ps = self.pool.page_size;
        let cap = prompt.len().saturating_sub(1);
        let mut g = self.pool.guard();
        let inner = &mut *g;
        let mut node = inner.index.root();
        let mut matched = 0usize;
        if inner.blocks.d_head() != 0 {
            while matched + ps <= cap {
                let chunk = &prompt[matched..matched + ps];
                match inner.index.lookup_child(node, chunk) {
                    Some(child) => {
                        let pid = inner.index.page(child);
                        inner.blocks.incref(pid);
                        self.table.map_shared(pid, ps, ps);
                        node = child;
                        matched += ps;
                    }
                    None => break,
                }
            }
            if matched < cap {
                if let Some((child, m)) = inner.index.partial_child(node, &prompt[matched..cap]) {
                    let pid = inner.index.page(child);
                    inner.blocks.incref(pid);
                    self.table.map_shared(pid, m, ps);
                    matched += m;
                    // cursor stays at `node`: the partial page is not on
                    // our registration path (our tail diverges from it)
                }
            }
        }
        self.tokens.extend_from_slice(&prompt[..matched]);
        self.cursor = (node, inner.index.gen(node));
        inner.prefix_hit_tokens += matched as u64;
        inner.prefix_miss_tokens += (prompt.len() - matched) as u64;
        matched
    }

    /// Attention scores q·k_t for every cached position of (layer, head)
    /// (pre-softmax, unscaled), streamed page-by-page off the coded
    /// payload. Dispatch is per lane, once per call: fp32 lanes read raw
    /// bytes, uniform lanes run the branch-free scalar decode, nested
    /// lanes the all-integer block decode via [`DecodeConsts`]
    /// (M-variant codecs at q ≤ 16; float decode otherwise). Fixed stack
    /// scratch — no per-position allocation (`out` is reused across
    /// calls and only grows).
    pub fn scores(&self, layer: usize, head: usize, qvec: &[f32], out: &mut Vec<f32>) {
        crate::fail_point!("kvpool/decode");
        out.clear();
        let lane = self.lane(layer, head);
        let total = self.table.fill(lane);
        if total == 0 {
            return;
        }
        let g = self.pool.guard();
        let layout = g.blocks.layout();
        let shape = *layout.shape();
        let (dh, ps) = (shape.d_head, shape.page_size);
        debug_assert_eq!(qvec.len(), dh);
        match &self.pool.lanes[layer] {
            KvLaneCodec::Fp32 => {
                self.stream(&g.blocks, total, ps, |page, local, _| {
                    let bytes = &page.data[layout.k_range(layer, head, local)];
                    let mut acc = 0f64;
                    for (xb, &qi) in bytes.chunks_exact(4).zip(qvec) {
                        let x = f32::from_le_bytes([xb[0], xb[1], xb[2], xb[3]]);
                        acc += x as f64 * qi as f64;
                    }
                    out.push(acc as f32);
                });
            }
            KvLaneCodec::Uniform(_) => {
                self.stream(&g.blocks, total, ps, |page, local, _| {
                    let delta = page.scale_k[shape.slot(lane, local)];
                    let codes = &page.data[layout.k_range(layer, head, local)];
                    let mut acc = 0f32;
                    for (&c, &qi) in codes.iter().zip(qvec) {
                        acc += (c as i8 as f32) * qi;
                    }
                    out.push(acc * delta);
                });
            }
            KvLaneCodec::Nested { k: nq, .. } => {
                let q = nq.q() as i32;
                let use_int = nq.codec.m_variant && q <= 16;
                let consts = DecodeConsts::new(q);
                // dispatch tier resolved once per call, shared with the
                // GEMM backends — KV attention rides the SIMD decode
                let kern = kernels::active();
                let bpv = shape.blocks_per_vec();
                let sqrt_dh = (dh as f32).sqrt();
                let mut c = [0u8; D];
                let mut e = [0i32; D];
                self.stream(&g.blocks, total, ps, |page, local, _| {
                    let scale = page.scale_k[shape.slot(lane, local)];
                    if scale == 0.0 {
                        out.push(0.0);
                        return;
                    }
                    let denorm = (scale / sqrt_dh) as f64;
                    let payload = &page.data[layout.k_range(layer, head, local)];
                    let (codes, bidx) = payload.split_at(dh);
                    let mut acc = 0f64;
                    for j in 0..bpv {
                        c.copy_from_slice(&codes[j * D..(j + 1) * D]);
                        let xb = &qvec[j * D..(j + 1) * D];
                        if use_int {
                            kernels::decode_block(kern, consts, &c, &mut e);
                            let mut d = 0f32;
                            for i in 0..D {
                                d += e[i] as f32 * xb[i];
                            }
                            acc += (d * 0.5 * nq.betas[bidx[j] as usize]) as f64;
                        } else {
                            let rec = nq.decode_block(&c, bidx[j]);
                            let mut d = 0f32;
                            for i in 0..D {
                                d += rec[i] * xb[i];
                            }
                            acc += d as f64;
                        }
                    }
                    out.push((acc * denorm) as f32);
                });
            }
        }
    }

    /// out = Σ_t probs[t]·v_t for (layer, head): the decode-step value
    /// path, streamed page-by-page with the same per-lane dispatch as
    /// [`Self::scores`] — no per-position dequantize buffer. Each lane's
    /// per-entry reconstruction mirrors its eval-path roundtrip
    /// bit-for-bit. `out` must be the head dimension; it is overwritten.
    pub fn weighted_value_sum(&self, layer: usize, head: usize, probs: &[f32], out: &mut [f32]) {
        out.fill(0.0);
        let lane = self.lane(layer, head);
        let total = self.table.fill(lane).min(probs.len());
        assert!(
            probs.len() <= self.table.fill(lane),
            "probs longer than cached positions"
        );
        if total == 0 {
            return;
        }
        let g = self.pool.guard();
        let layout = g.blocks.layout();
        let shape = *layout.shape();
        let (dh, ps) = (shape.d_head, shape.page_size);
        assert_eq!(out.len(), dh);
        match &self.pool.lanes[layer] {
            KvLaneCodec::Fp32 => {
                self.stream(&g.blocks, total, ps, |page, local, t| {
                    let p = probs[t];
                    let bytes = &page.data[layout.v_range(layer, head, local)];
                    for (i, xb) in bytes.chunks_exact(4).enumerate() {
                        let x = f32::from_le_bytes([xb[0], xb[1], xb[2], xb[3]]);
                        out[i] += p * x;
                    }
                });
            }
            KvLaneCodec::Uniform(_) => {
                self.stream(&g.blocks, total, ps, |page, local, t| {
                    let p = probs[t];
                    let delta = page.scale_v[shape.slot(lane, local)];
                    let codes = &page.data[layout.v_range(layer, head, local)];
                    for (i, &c) in codes.iter().enumerate() {
                        // (c·Δ) mirrors the uniform dequantize bit-for-bit
                        out[i] += p * ((c as i8 as f32) * delta);
                    }
                });
            }
            KvLaneCodec::Nested { v: nq, .. } => {
                let q = nq.q() as i32;
                let use_int = nq.codec.m_variant && q <= 16;
                let consts = DecodeConsts::new(q);
                let kern = kernels::active();
                let bpv = shape.blocks_per_vec();
                let sqrt_dh = (dh as f32).sqrt();
                let mut c = [0u8; D];
                let mut e = [0i32; D];
                self.stream(&g.blocks, total, ps, |page, local, t| {
                    let p = probs[t];
                    let scale = page.scale_v[shape.slot(lane, local)];
                    if scale == 0.0 {
                        return;
                    }
                    let denorm = scale / sqrt_dh;
                    let payload = &page.data[layout.v_range(layer, head, local)];
                    let (codes, bidx) = payload.split_at(dh);
                    for j in 0..bpv {
                        c.copy_from_slice(&codes[j * D..(j + 1) * D]);
                        let ob = &mut out[j * D..(j + 1) * D];
                        if use_int {
                            kernels::decode_block(kern, consts, &c, &mut e);
                            let beta = nq.betas[bidx[j] as usize];
                            for i in 0..D {
                                // (e·0.5)·β·denorm mirrors dequantize's
                                // (dec·β)·denorm bit-for-bit: e·0.5 is exact
                                ob[i] += p * (((e[i] as f32 * 0.5) * beta) * denorm);
                            }
                        } else {
                            let rec = nq.decode_block(&c, bidx[j]);
                            for i in 0..D {
                                ob[i] += p * (rec[i] * denorm);
                            }
                        }
                    }
                });
            }
        }
    }

    /// Walk this session's cached positions `[0, total)` page-by-page,
    /// calling `f(page, local, t)` for each — the shared streaming
    /// skeleton of the decode kernels (no allocation).
    #[inline]
    fn stream<F: FnMut(&block::Page, usize, usize)>(
        &self,
        blocks: &BlockPool,
        total: usize,
        ps: usize,
        mut f: F,
    ) {
        for (pi, &pid) in self.table.pages().iter().enumerate() {
            if pi * ps >= total {
                break;
            }
            let cnt = (total - pi * ps).min(ps);
            let page = blocks.page(pid);
            for local in 0..cnt {
                f(page, local, pi * ps + local);
            }
        }
    }

    fn fetch(&self, layer: usize, head: usize, pos: usize, key: bool) -> Vec<f32> {
        let lane = self.lane(layer, head);
        assert!(pos < self.table.fill(lane), "position {pos} not cached");
        let g = self.pool.guard();
        let layout = g.blocks.layout();
        let shape = *layout.shape();
        let (dh, ps) = (shape.d_head, shape.page_size);
        let page = g.blocks.page(self.table.pages()[pos / ps]);
        let local = pos % ps;
        let s = shape.slot(lane, local);
        let range = if key {
            layout.k_range(layer, head, local)
        } else {
            layout.v_range(layer, head, local)
        };
        let payload = &page.data[range];
        match &self.pool.lanes[layer] {
            KvLaneCodec::Fp32 => payload
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect(),
            KvLaneCodec::Uniform(_) => {
                let delta = if key { page.scale_k[s] } else { page.scale_v[s] };
                payload.iter().map(|&c| (c as i8 as f32) * delta).collect()
            }
            KvLaneCodec::Nested { k, v } => {
                let qv = QuantizedVector {
                    codes: payload[..dh].to_vec(),
                    beta_idx: payload[dh..].to_vec(),
                    scale: if key { page.scale_k[s] } else { page.scale_v[s] },
                    n: dh,
                };
                let nq = if key { k } else { v };
                nq.dequantize(&qv)
            }
        }
    }

    /// Decode the key at a position (allocating; tests and diagnostics).
    pub fn key(&self, layer: usize, head: usize, pos: usize) -> Vec<f32> {
        self.fetch(layer, head, pos, true)
    }

    /// Decode the value at a position (allocating; tests and diagnostics).
    pub fn value(&self, layer: usize, head: usize, pos: usize) -> Vec<f32> {
        self.fetch(layer, head, pos, false)
    }
}

impl Drop for SessionKv {
    fn drop(&mut self) {
        let mut g = self.pool.guard();
        let inner = &mut *g;
        self.table.release(&mut inner.blocks);
        // freshly unpinned cached pages may now exceed the budget
        trim_to_budget(&mut inner.blocks, &mut inner.index, false, self.pool.trace());
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::{propcheck, stats, Rng};

    fn nested(q: u32) -> KvLaneCodec {
        let betas = if q <= 4 {
            vec![0.5, 1.0]
        } else {
            vec![0.25, 0.32, 0.45, 1.0]
        };
        let nq = NestedLatticeQuantizer::new_m(q, betas);
        KvLaneCodec::Nested {
            k: nq.clone(),
            v: nq,
        }
    }

    fn pool(n_layer: usize, n_head: usize, cfg: PoolConfig) -> Arc<KvPool> {
        let lanes = (0..n_layer).map(|_| nested(14)).collect();
        Arc::new(KvPool::new(n_layer, n_head, lanes, cfg))
    }

    /// A 3-layer pool exercising every lane codec at once.
    fn mixed_pool(n_head: usize, cfg: PoolConfig) -> Arc<KvPool> {
        let lanes = vec![KvLaneCodec::Fp32, KvLaneCodec::Uniform(4), nested(14)];
        Arc::new(KvPool::new(3, n_head, lanes, cfg))
    }

    /// Append `n` positions with deterministic per-token vectors to every
    /// lane and note the token, emulating a generation session.
    fn run_session(sess: &mut SessionKv, tokens: &[i32], dh: usize) {
        let p = sess.pool().clone();
        for (t, &tok) in tokens.iter().enumerate() {
            for l in 0..p.n_layer() {
                for h in 0..p.n_head() {
                    let mut rng = Rng::new(0x5EED ^ tok as u64 ^ ((t as u64) << 32));
                    let k = rng.gauss_vec(dh);
                    let v = rng.gauss_vec(dh);
                    sess.append(l, h, &k, &v);
                }
            }
            sess.note_token(tok);
        }
    }

    #[test]
    fn lanes_decode_bitwise_equal_to_eval_roundtrip() {
        // The eval-vs-serve parity contract: what the pool stores and
        // decodes for each lane codec is bitwise what the batch-eval
        // fake-quant path (`KvLaneCodec::roundtrip_*`) computes.
        let p = mixed_pool(2, PoolConfig::default());
        let mut sess = SessionKv::new(p.clone());
        let mut rng = Rng::new(0xBEA7);
        let dh = 16;
        for pos in 0..3 {
            let k = rng.gauss_vec(dh);
            let v = rng.gauss_vec(dh);
            for l in 0..3 {
                for h in 0..2 {
                    sess.append(l, h, &k, &v);
                }
            }
            for l in 0..3 {
                let mut rt_k = k.clone();
                p.lane(l).roundtrip_key(&mut rt_k);
                assert_eq!(sess.key(l, 1, pos), rt_k, "layer {l} key parity");
                let mut rt_v = v.clone();
                p.lane(l).roundtrip_value(&mut rt_v);
                assert_eq!(sess.value(l, 0, pos), rt_v, "layer {l} value parity");
            }
            // the fp lane is exact
            assert_eq!(sess.key(0, 0, pos), k);
            assert_eq!(sess.value(0, 0, pos), v);
        }
    }

    #[test]
    fn prefix_hit_shares_pages_and_decodes_identically() {
        // mixed lanes: prefix sharing must hand back bitwise-identical
        // payloads on fp32, uniform and nested layers alike.
        let p = mixed_pool(2, PoolConfig { page_size: 4, budget_bytes: None });
        let dh = 16;
        let toks: Vec<i32> = (0..17).collect();
        let mut a = SessionKv::new(p.clone());
        run_session(&mut a, &toks, dh);
        let a_pages = a.n_pages();
        let a_bytes = a.payload_bytes();
        assert_eq!(a_pages, 5); // 17 positions / 4 per page

        let mut b = SessionKv::new(p.clone());
        let matched = b.match_prefix(&toks);
        // cap = 16 → 4 full pages; no partial child of the last node
        assert_eq!(matched, 16);
        assert_eq!(b.n_pages(), 4);
        // shared pages decode bit-identically for both sessions, on
        // every lane codec
        for layer in 0..3 {
            for pos in [0usize, 3, 7, 15] {
                assert_eq!(a.key(layer, 0, pos), b.key(layer, 0, pos), "L{layer} key");
                assert_eq!(a.value(layer, 1, pos), b.value(layer, 1, pos), "L{layer} val");
            }
        }
        // pool-wide: the second session added zero pages
        assert_eq!(p.stats().pages_in_use, 5);
        assert!(p.stats().bytes_in_use < a_bytes * 2);
        assert_eq!(p.stats().prefix_hit_tokens, 16);
        assert_eq!(p.stats().prefix_miss_tokens, 1);
    }

    #[test]
    fn partial_tail_match_is_copy_on_write() {
        // COW over the heterogeneous byte arena: the diverging session
        // must copy the tail page without disturbing any lane of the
        // source session.
        let p = mixed_pool(1, PoolConfig { page_size: 4, budget_bytes: None });
        let dh = 16;
        let toks: Vec<i32> = (0..8).collect();
        let mut a = SessionKv::new(p.clone());
        run_session(&mut a, &toks, dh);

        // B shares 6 of A's 8 tokens then diverges
        let b_toks = vec![0, 1, 2, 3, 4, 5, 99, 98];
        let mut b = SessionKv::new(p.clone());
        let matched = b.match_prefix(&b_toks);
        assert_eq!(matched, 6, "1 full page + 2-token partial tail");
        let shared_tail = b.table.pages()[1];
        assert_eq!(shared_tail, a.table.pages()[1]);
        // diverging append must COW the tail, leaving A's data intact
        let before: Vec<Vec<f32>> = (0..3).map(|l| a.key(l, 0, 6)).collect();
        run_session(&mut b, &b_toks[6..], dh);
        assert_ne!(b.table.pages()[1], shared_tail, "tail not copied on write");
        for l in 0..3 {
            assert_eq!(a.key(l, 0, 6), before[l], "L{l} disturbed by COW");
            // shared positions still decode identically; diverged differ
            assert_eq!(a.key(l, 0, 5), b.key(l, 0, 5), "L{l} shared pos");
            assert_ne!(a.key(l, 0, 6), b.key(l, 0, 6), "L{l} diverged pos");
        }
    }

    #[test]
    fn preempt_releases_pages_and_requeue_rebuilds_bitwise() {
        let p = mixed_pool(2, PoolConfig { page_size: 4, budget_bytes: None });
        let dh = 16;
        let toks: Vec<i32> = (0..11).collect();
        let mut a = SessionKv::new(p.clone());
        run_session(&mut a, &toks, dh);
        let before: Vec<Vec<f32>> = (0..3).map(|l| a.key(l, 0, 9)).collect();
        let released = a.preempt();
        assert_eq!(released, 3, "11 positions / 4 per page");
        assert_eq!(a.n_pages(), 0);
        assert_eq!(p.stats().cached_pages, 2, "frozen prefix pages stay cached");
        // requeue: the frozen prefix re-maps, only the tail recomputes
        let matched = a.match_prefix(&toks);
        assert_eq!(matched, 8, "two frozen pages re-mapped");
        for (t, &tok) in toks.iter().enumerate().skip(matched) {
            for l in 0..3 {
                for h in 0..2 {
                    let mut rng = Rng::new(0x5EED ^ tok as u64 ^ ((t as u64) << 32));
                    let k = rng.gauss_vec(dh);
                    let v = rng.gauss_vec(dh);
                    a.append(l, h, &k, &v);
                }
            }
            a.note_token(tok);
        }
        for (l, b) in before.iter().enumerate() {
            assert_eq!(&a.key(l, 0, 9), b, "L{l} rebuild not bitwise");
        }
    }

    #[test]
    fn would_overrun_predicts_allocation_pressure() {
        // learn the page byte cost from an unbudgeted probe pool
        let probe = mixed_pool(1, PoolConfig { page_size: 4, budget_bytes: None });
        let mut s = SessionKv::new(probe.clone());
        run_session(&mut s, &[1], 16);
        let bpp = probe.stats().bytes_per_page;

        let p = mixed_pool(1, PoolConfig { page_size: 4, budget_bytes: Some(3 * bpp) });
        assert!(!p.would_overrun(100), "no layout fixed yet → nothing to predict");
        let mut a = SessionKv::new(p.clone());
        let a_toks: Vec<i32> = (0..8).collect();
        run_session(&mut a, &a_toks, 16); // 2 pages, pinned + cached
        assert!(!p.would_overrun(1), "third page still fits");
        assert!(p.would_overrun(2), "two fresh pages would blow the 3-page budget");
        let mut b = SessionKv::new(p.clone());
        let b_toks: Vec<i32> = (100..104).collect();
        run_session(&mut b, &b_toks, 16); // third page
        assert!(p.would_overrun(1), "every page pinned by a live session");
        drop(a);
        // a's pages are now index-only → evictable headroom is back
        assert!(!p.would_overrun(2));
        assert_eq!(p.stats().budget_overruns, 0);
    }

    #[test]
    fn streaming_kernels_match_dequantized_reference() {
        // every lane codec (and both nested decode variants): the
        // page-streaming score / value kernels must agree with
        // decode-then-dot over the same coded entries.
        let lanes: Vec<KvLaneCodec> = vec![
            KvLaneCodec::Fp32,
            KvLaneCodec::Uniform(4),
            KvLaneCodec::Uniform(8),
            {
                let betas = vec![0.25, 0.32, 0.45, 1.0];
                let nq = NestedLatticeQuantizer::new(14, betas);
                KvLaneCodec::Nested { k: nq.clone(), v: nq }
            },
            nested(14),
        ];
        for lane in lanes {
            let label = format!("{lane:?}");
            let cfg = PoolConfig { page_size: 4, budget_bytes: None };
            let mut sess = SessionKv::new(Arc::new(KvPool::new(1, 1, vec![lane], cfg)));
            let dh = 16;
            let mut rng = Rng::new(1704);
            for _ in 0..11 {
                let k = rng.gauss_vec(dh);
                let v = rng.gauss_vec(dh);
                sess.append(0, 0, &k, &v);
            }
            let qv = rng.gauss_vec(dh);
            let mut scores = Vec::new();
            sess.scores(0, 0, &qv, &mut scores);
            assert_eq!(scores.len(), 11);
            let probs: Vec<f32> = (0..11).map(|i| 0.05 + 0.01 * i as f32).collect();
            let mut wsum = vec![0f32; dh];
            sess.weighted_value_sum(0, 0, &probs, &mut wsum);
            let mut expect_w = vec![0f32; dh];
            for t in 0..11 {
                let kd = sess.key(0, 0, t);
                let s = stats::dot(&qv, &kd) as f32;
                assert!(
                    (scores[t] - s).abs() < 1e-4 * (1.0 + s.abs()),
                    "{label} t={t}: streaming {} vs reference {s}",
                    scores[t]
                );
                let vd = sess.value(0, 0, t);
                for i in 0..dh {
                    expect_w[i] += probs[t] * vd[i];
                }
            }
            for i in 0..dh {
                assert!(
                    (wsum[i] - expect_w[i]).abs() < 1e-5 * (1.0 + expect_w[i].abs()),
                    "{label} value sum diverges at {i}: {} vs {}",
                    wsum[i],
                    expect_w[i]
                );
            }
        }
    }

    #[test]
    fn fp_lane_pool_is_exact() {
        let mut sess = SessionKv::solo(1, 1, KvLaneCodec::Fp32);
        let mut rng = Rng::new(1703);
        let k = rng.gauss_vec(16);
        let v = rng.gauss_vec(16);
        sess.append(0, 0, &k, &v);
        assert_eq!(sess.key(0, 0, 0), k);
        assert_eq!(sess.value(0, 0, 0), v);
        let qv = rng.gauss_vec(16);
        let mut scores = Vec::new();
        sess.scores(0, 0, &qv, &mut scores);
        assert_eq!(scores[0], stats::dot(&qv, &k) as f32);
    }

    #[test]
    fn fp_and_uniform_lanes_accept_non_8_divisible_d_head() {
        // only nested lanes carry the 8-block geometry: an fp32/uniform
        // pool must serve head dims the old fp cache path accepted
        // (e.g. d_head = 12), through append, kernels and decode.
        let lanes = vec![KvLaneCodec::Fp32, KvLaneCodec::Uniform(4)];
        let p = Arc::new(KvPool::new(2, 1, lanes, PoolConfig::default()));
        let mut sess = SessionKv::new(p);
        let mut rng = Rng::new(12);
        let dh = 12;
        let k = rng.gauss_vec(dh);
        let v = rng.gauss_vec(dh);
        sess.append(0, 0, &k, &v);
        sess.append(1, 0, &k, &v);
        assert_eq!(sess.key(0, 0, 0), k);
        let mut scores = Vec::new();
        sess.scores(1, 0, &k, &mut scores);
        assert_eq!(scores.len(), 1);
        let mut wsum = vec![0f32; dh];
        sess.weighted_value_sum(0, 0, &[1.0], &mut wsum);
        assert_eq!(wsum, v);
    }

    #[test]
    fn nested_lane_pages_smaller_than_fp_lane_pages() {
        // the memory claim at the page level: an all-nested pool's page
        // byte cost is > 4× below an all-fp32 pool of the same geometry,
        // and the stats split attributes each pool's bytes to its class.
        let dh = 48;
        let mut fp = SessionKv::solo(2, 2, KvLaneCodec::Fp32);
        let mut nest = SessionKv::solo(2, 2, nested(14));
        let mut rng = Rng::new(1702);
        for _ in 0..50 {
            let k = rng.gauss_vec(dh);
            let v = rng.gauss_vec(dh);
            for l in 0..2 {
                for h in 0..2 {
                    fp.append(l, h, &k, &v);
                    nest.append(l, h, &k, &v);
                }
            }
        }
        let fp_bytes = fp.payload_bytes();
        let nest_bytes = nest.payload_bytes();
        assert!(
            (nest_bytes as f64) < fp_bytes as f64 / 4.0,
            "cache compression too weak: {nest_bytes} vs {fp_bytes}"
        );
        let fp_stats = fp.pool().stats();
        assert_eq!(fp_stats.page_bytes_uniform + fp_stats.page_bytes_nested, 0);
        assert_eq!(fp_stats.page_bytes_fp, fp_stats.bytes_per_page);
        let nest_stats = nest.pool().stats();
        assert_eq!(nest_stats.page_bytes_fp + nest_stats.page_bytes_uniform, 0);
        assert_eq!(nest_stats.page_bytes_nested, nest_stats.bytes_per_page);
    }

    #[test]
    fn mixed_pool_stats_split_bytes_by_class() {
        let p = mixed_pool(2, PoolConfig { page_size: 4, budget_bytes: None });
        let mut sess = SessionKv::new(p.clone());
        run_session(&mut sess, &[1, 2, 3, 4, 5], 16);
        let st = p.stats();
        assert!(st.page_bytes_fp > 0 && st.page_bytes_uniform > 0 && st.page_bytes_nested > 0);
        let sum = st.page_bytes_fp + st.page_bytes_uniform + st.page_bytes_nested;
        assert!(
            sum >= st.bytes_per_page && sum <= st.bytes_per_page + 2,
            "split {sum} vs exact {}",
            st.bytes_per_page
        );
        let split = st.bytes_in_use_split();
        assert_eq!(split[0], st.pages_in_use * st.page_bytes_fp);
        // fp32 lanes dominate the byte cost of a mixed page
        assert!(st.page_bytes_fp > st.page_bytes_nested);
    }

    #[test]
    fn eviction_reclaims_cached_runs_and_respects_live_sessions() {
        let dh = 16;
        // budget: 6 pages exactly
        let probe = pool(1, 1, PoolConfig { page_size: 4, budget_bytes: None });
        let bpp = {
            let mut s = SessionKv::new(probe.clone());
            s.append(0, 0, &vec![0.5; dh], &vec![0.5; dh]);
            probe.stats().bytes_per_page
        };
        let p = pool(1, 1, PoolConfig { page_size: 4, budget_bytes: Some(6 * bpp) });

        let toks_a: Vec<i32> = (0..16).collect();
        let mut a = SessionKv::new(p.clone());
        run_session(&mut a, &toks_a, dh);
        assert_eq!(p.stats().pages_in_use, 4);
        // A finishes: its 4 frozen pages stay cached in the index
        drop(a);
        assert_eq!(p.stats().pages_in_use, 4);
        assert_eq!(p.stats().cached_pages, 4);

        // B (live, disjoint tokens) needs 4 pages; budget 6 forces LRU
        // eviction of A's cached run
        let toks_b: Vec<i32> = (100..116).collect();
        let mut b = SessionKv::new(p.clone());
        assert_eq!(b.match_prefix(&toks_b), 0);
        run_session(&mut b, &toks_b, dh);
        let st = p.stats();
        assert!(st.evicted_pages >= 2, "expected LRU evictions, got {st:?}");
        assert!(st.bytes_in_use <= 6 * bpp, "budget exceeded: {st:?}");
        assert_eq!(st.budget_overruns, 0);

        // a live session under eviction pressure still scores
        // bit-identically to an unconstrained pool
        let unbounded = pool(1, 1, PoolConfig { page_size: 4, budget_bytes: None });
        let mut c = SessionKv::new(unbounded);
        run_session(&mut c, &toks_b, dh);
        let mut b_scores = Vec::new();
        let mut c_scores = Vec::new();
        b.scores(0, 0, &vec![0.3; dh], &mut b_scores);
        c.scores(0, 0, &vec![0.3; dh], &mut c_scores);
        for (x, y) in b_scores.iter().zip(&c_scores) {
            assert_eq!(x.to_bits(), y.to_bits(), "eviction changed live scores");
        }
        // A's run was evicted bottom-up: the tail is gone, so a rematch
        // can recover at most the surviving head of the run
        let mut d = SessionKv::new(p.clone());
        assert!(
            d.match_prefix(&toks_a) <= 8,
            "evicted tail pages must not be matchable"
        );
    }

    #[test]
    fn budget_overrun_counted_when_all_pages_pinned() {
        let dh = 16;
        let probe = pool(1, 1, PoolConfig { page_size: 4, budget_bytes: None });
        let bpp = {
            let mut s = SessionKv::new(probe.clone());
            s.append(0, 0, &vec![0.5; dh], &vec![0.5; dh]);
            probe.stats().bytes_per_page
        };
        let p = pool(1, 1, PoolConfig { page_size: 4, budget_bytes: Some(2 * bpp) });
        let mut a = SessionKv::new(p.clone());
        run_session(&mut a, &(0..16).collect::<Vec<_>>(), dh);
        let st = p.stats();
        assert_eq!(st.pages_in_use, 4, "live traffic is never refused");
        assert!(st.budget_overruns > 0);
        drop(a);
        // once the session ends, the trim brings the cache under budget
        assert!(p.stats().bytes_in_use <= 2 * bpp);
    }

    #[test]
    fn pool_emits_bounded_trace_events_for_alloc_and_eviction() {
        let dh = 16;
        let probe = pool(1, 1, PoolConfig { page_size: 4, budget_bytes: None });
        let bpp = {
            let mut s = SessionKv::new(probe.clone());
            s.append(0, 0, &vec![0.5; dh], &vec![0.5; dh]);
            probe.stats().bytes_per_page
        };
        let p = pool(1, 1, PoolConfig { page_size: 4, budget_bytes: Some(6 * bpp) });
        let tr = Arc::new(Trace::manual(64));
        p.set_trace(tr.clone());
        // a second attach is a no-op: the first trace stays wired
        p.set_trace(Arc::new(Trace::manual(1)));

        let mut a = SessionKv::new(p.clone());
        run_session(&mut a, &(0..16).collect::<Vec<_>>(), dh);
        drop(a); // 4 frozen pages stay cached
        let mut b = SessionKv::new(p.clone());
        run_session(&mut b, &(100..116).collect::<Vec<_>>(), dh);

        let events = tr.snapshot();
        let allocs = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::PageAlloc))
            .count();
        let evicts = events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::PageEvict))
            .count() as u64;
        assert_eq!(allocs, 8, "4 fresh pages per 16-token session");
        let st = p.stats();
        assert!(st.evicted_pages >= 2, "budget must force evictions: {st:?}");
        assert_eq!(evicts, st.evicted_pages, "one event per evicted page");
        assert!(events.iter().all(|e| e.track == TRACK_POOL));
        assert_eq!(tr.dropped(), 0, "ring sized for this run");
    }

    #[test]
    fn mixed_pool_sessions_propcheck_no_leaks_budget_respected() {
        // random session traffic against a budgeted **mixed-lane** pool
        // (fp32 + uniform + nested layers): spawn sessions that
        // prefix-match (sharing), extend them (COW on shared tails), and
        // drop them (index caching + LRU eviction). Invariants at every
        // step: page accounting consistent, and whenever no session is
        // live the cached footprint is within budget.
        propcheck::check("kvpool-mixed-session-traffic", 8, 0xF00D_0011, |rng| {
            let dh = 8;
            let probe = mixed_pool(1, PoolConfig { page_size: 2, budget_bytes: None });
            let bpp = {
                let mut s = SessionKv::new(probe.clone());
                s.append(0, 0, &vec![0.5; dh], &vec![0.5; dh]);
                probe.stats().bytes_per_page
            };
            let p = mixed_pool(1, PoolConfig { page_size: 2, budget_bytes: Some(5 * bpp) });
            let mut live: Vec<SessionKv> = Vec::new();
            for step in 0..60 {
                match rng.below(4) {
                    0 => {
                        let mut s = SessionKv::new(p.clone());
                        let start = rng.below(4) as i32;
                        let toks: Vec<i32> = (start..start + 4).collect();
                        s.match_prefix(&toks);
                        let done = s.tokens.len();
                        let rest: Vec<i32> = toks[done..].to_vec();
                        run_session(&mut s, &rest, dh);
                        // a prefix-served position must decode bitwise
                        // like the session that produced it — checked on
                        // every lane codec via the deterministic
                        // per-token vectors run_session derives
                        if done > 0 {
                            for l in 0..3 {
                                let mut gen = Rng::new(0x5EED ^ toks[0] as u64);
                                let kexp = gen.gauss_vec(dh);
                                let mut rt = kexp.clone();
                                p.lane(l).roundtrip_key(&mut rt);
                                if s.key(l, 0, 0) != rt {
                                    return Err(format!(
                                        "step {step}: shared pos decodes wrong on layer {l}"
                                    ));
                                }
                            }
                        }
                        live.push(s);
                    }
                    1 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        let tok = rng.below(50) as i32;
                        run_session(&mut live[i], &[tok], dh);
                    }
                    2 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        live.swap_remove(i);
                    }
                    _ => {}
                }
                let st = p.stats();
                if live.is_empty() && st.bytes_in_use > 5 * bpp {
                    return Err(format!("idle pool over budget at step {step}: {st:?}"));
                }
                let mapped: usize = live.iter().map(|s| s.n_pages()).sum();
                if st.pages_in_use > mapped + st.cached_pages {
                    return Err(format!(
                        "accounting drift at step {step}: in_use {} > mapped {mapped} + cached {}",
                        st.pages_in_use, st.cached_pages
                    ));
                }
            }
            drop(live);
            let st = p.stats();
            if st.bytes_in_use > 5 * bpp {
                return Err(format!("final pool over budget: {st:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn per_layer_quantizers_decode_with_their_own_pair() {
        // layer 0: fine quantizer (q=14); layer 1: coarse (q=3). The same
        // vector stored in both layers must come back through the
        // layer's own codec — coarse decode ≠ fine decode.
        let fine = NestedLatticeQuantizer::new_m(14, vec![0.25, 0.32, 0.45, 1.0]);
        let coarse = NestedLatticeQuantizer::new_m(3, vec![0.5, 1.0]);
        let lanes = vec![
            KvLaneCodec::Nested { k: fine.clone(), v: fine.clone() },
            KvLaneCodec::Nested { k: coarse.clone(), v: coarse.clone() },
        ];
        let p = Arc::new(KvPool::new(2, 1, lanes, PoolConfig::default()));
        let mut sess = SessionKv::new(p);
        let mut rng = Rng::new(9);
        let x = rng.gauss_vec(16);
        sess.append(0, 0, &x, &x);
        sess.append(1, 0, &x, &x);
        let d0 = sess.key(0, 0, 0);
        let d1 = sess.key(1, 0, 0);
        assert_eq!(d0, fine.roundtrip(&x), "layer 0 must use its own quantizer");
        assert_eq!(d1, coarse.roundtrip(&x), "layer 1 must use its own quantizer");
        assert!(
            stats::rmse(&x, &d0) < stats::rmse(&x, &d1),
            "fine layer should reconstruct better"
        );
    }

    #[test]
    fn poisoned_lock_recovers_and_accounting_survives() {
        // A panic while the pool guard is held must not brick the pool:
        // subsequent sessions recover the lock and the accounting they
        // see is consistent.
        let p = pool(1, 1, PoolConfig { page_size: 4, budget_bytes: None });
        let mut sess = SessionKv::new(p.clone());
        run_session(&mut sess, &[1, 2, 3, 4, 5], 16);
        let before = p.stats();
        let poisoner = p.clone();
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let _g = poisoner.guard();
            panic!("injected panic while holding the pool lock");
        }));
        // every entry point still works through the recovered lock
        let after = p.stats();
        assert_eq!(after.pages_in_use, before.pages_in_use);
        assert!(!p.would_overrun(1));
        assert_eq!(sess.key(0, 0, 2).len(), 16);
        drop(sess);
        assert_eq!(p.verify_idle(), Ok(()));
    }

    #[test]
    fn alloc_failpoint_teardown_releases_every_page() {
        use crate::util::failpoint::{scenario, FailSpec};
        // An injected allocation fault mid-session, then teardown: the
        // pool must return to idle (frozen prefix pages only, each with
        // exactly the index reference) with zero leaked refcounts.
        let p = pool(2, 2, PoolConfig { page_size: 4, budget_bytes: None });
        let toks: Vec<i32> = (0..11).collect();
        let mut keeper = SessionKv::new(p.clone());
        run_session(&mut keeper, &toks, 16);
        let sc = scenario();
        sc.fail("kvpool/alloc", FailSpec::Nth(2));
        let mut victim = SessionKv::new(p.clone());
        let crashed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // diverging tokens -> no prefix hit -> fresh page claims
            run_session(&mut victim, &[40, 41, 42, 43, 44, 45, 46, 47, 48], 16);
        }));
        assert!(crashed.is_err(), "the armed alloc site must fire");
        assert_eq!(sc.fired("kvpool/alloc"), 1);
        drop(sc);
        drop(victim); // faulted teardown: releases whatever was claimed
        let full_pages_kept = toks.len() / 4;
        drop(keeper);
        // idle: only the keeper's frozen pages remain, index-owned
        assert_eq!(p.verify_idle(), Ok(()));
        assert_eq!(p.stats().pages_in_use, full_pages_kept);
        // and the pool still serves new sessions bitwise-identically
        let mut again = SessionKv::new(p.clone());
        assert_eq!(again.match_prefix(&toks), 8);
        run_session(&mut again, &toks[8..], 16);
    }

    #[test]
    fn decode_failpoint_is_contained_to_the_calling_session() {
        use crate::util::failpoint::{scenario, FailSpec};
        let p = pool(1, 1, PoolConfig::default());
        let mut sess = SessionKv::new(p.clone());
        run_session(&mut sess, &[7, 8, 9], 16);
        let mut out = Vec::new();
        let sc = scenario();
        sc.fail("kvpool/decode", FailSpec::Nth(1));
        let q = vec![0.5f32; 16];
        let crashed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            sess.scores(0, 0, &q, &mut out);
        }));
        assert!(crashed.is_err());
        drop(sc);
        // the fault fired before the lock: the pool is not poisoned and
        // the same call now succeeds
        sess.scores(0, 0, &q, &mut out);
        assert_eq!(out.len(), 3);
        drop(sess);
        assert_eq!(p.verify_idle(), Ok(()));
    }

    #[test]
    fn verify_idle_reports_leaked_refcounts() {
        let p = pool(1, 1, PoolConfig { page_size: 4, budget_bytes: None });
        let mut sess = SessionKv::new(p.clone());
        run_session(&mut sess, &(0..8).collect::<Vec<i32>>(), 16);
        {
            // simulate a teardown bug: an extra refcount on a frozen page
            let mut g = p.guard();
            let inner = &mut *g;
            assert!(inner.index.count_pages(|_| true) > 0);
            let first = std::cell::Cell::new(None);
            inner.index.count_pages(|pg| {
                if first.get().is_none() {
                    first.set(Some(pg));
                }
                true
            });
            if let Some(pg) = first.get() {
                inner.blocks.incref(pg);
            }
        }
        drop(sess);
        let verdict = p.verify_idle();
        assert!(verdict.is_err(), "leaked refcount must be detected: {verdict:?}");
        assert!(verdict.unwrap_err().contains("refcount"));
    }
}
