//! Per-session logical→physical page mapping.
//!
//! A [`PageTable`] maps a session's logical page index (position /
//! page_size) to a physical [`PageId`] and tracks per-(layer, head) fill
//! counts — lanes may be ragged (single-owner `SessionKv::solo` usage
//! appends per head), but pooled serving sessions fill all lanes
//! uniformly, one position per decode step.
//!
//! Writes go through [`PageTable::writable_page`], which enforces
//! copy-on-write: appending into a page that is shared (mapped by
//! another session or held by the prefix index) or frozen first copies
//! the session-visible filled prefix of every lane into a fresh page and
//! remaps. Shared full pages are therefore immutable, and a partial tail
//! mapped from the prefix index diverges privately at the first write.

use super::block::{BlockPool, PageId};

/// How [`PageTable::claim_slot`] resolved the physical page behind an
/// append — surfaced so the pool can emit the matching trace event
/// (page alloc vs copy-on-write) without re-deriving the decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClaimKind {
    /// the slot landed on an already-private page — no allocation
    Existing,
    /// a fresh page was allocated for the lane's next logical page
    Fresh,
    /// a shared or frozen page was copied on write
    Cow,
}

pub struct PageTable {
    /// logical page index → physical page
    pages: Vec<PageId>,
    /// per-lane appended-position count (lane = layer·n_head + head)
    fill: Box<[u32]>,
}

impl PageTable {
    pub fn new(lanes: usize) -> Self {
        PageTable {
            pages: Vec::new(),
            fill: vec![0u32; lanes].into_boxed_slice(),
        }
    }

    pub fn pages(&self) -> &[PageId] {
        &self.pages
    }

    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    pub fn fill(&self, lane: usize) -> usize {
        self.fill[lane] as usize
    }

    /// Map an externally owned page (prefix hit) as the next logical
    /// page, advancing every lane by `positions` (≤ page_size). The
    /// caller has already bumped the page's refcount.
    pub fn map_shared(&mut self, page: PageId, positions: usize, page_size: usize) {
        debug_assert!(positions >= 1 && positions <= page_size);
        let full = self.pages.len() * page_size;
        for f in self.fill.iter_mut() {
            debug_assert_eq!(*f as usize, full, "prefix mapping requires uniform lanes");
            *f += positions as u32;
        }
        self.pages.push(page);
    }

    /// Number of positions of logical page `pi` visible to this session
    /// on `lane`.
    pub fn filled_on(&self, lane: usize, pi: usize, page_size: usize) -> usize {
        (self.fill(lane)).saturating_sub(pi * page_size).min(page_size)
    }

    /// Resolve (and if needed allocate or copy-on-write) the physical
    /// page behind `lane`'s next append slot, advancing the lane's fill.
    /// Returns (page id, local slot, how the page was obtained).
    /// `on_alloc` runs before every fresh allocation so the pool owner
    /// can apply budget eviction.
    pub fn claim_slot<F: FnMut(&mut BlockPool)>(
        &mut self,
        lane: usize,
        blocks: &mut BlockPool,
        mut on_alloc: F,
    ) -> (PageId, usize, ClaimKind) {
        let page_size = blocks.shape().page_size;
        let slot = self.fill(lane);
        let pi = slot / page_size;
        let local = slot % page_size;
        let mut kind = ClaimKind::Existing;
        if pi == self.pages.len() {
            on_alloc(blocks);
            self.pages.push(blocks.alloc());
            kind = ClaimKind::Fresh;
        } else {
            debug_assert!(pi < self.pages.len(), "lane fill ahead of page table");
            let cur = self.pages[pi];
            if blocks.refcount(cur) > 1 || blocks.page(cur).frozen {
                on_alloc(blocks);
                let fresh = self.cow(pi, blocks);
                self.pages[pi] = fresh;
                blocks.decref(cur);
                kind = ClaimKind::Cow;
            }
        }
        self.fill[lane] = (slot + 1) as u32;
        (self.pages[pi], local, kind)
    }

    /// Copy the session-visible filled prefix of every lane of logical
    /// page `pi` into a freshly allocated page. Lane payloads are opaque
    /// byte runs at the layer's own stride, so the copy is
    /// codec-agnostic (fp32 / uniform / nested lanes all move as raw
    /// bytes — bitwise-preserving by construction).
    fn cow(&self, pi: usize, blocks: &mut BlockPool) -> PageId {
        let fresh = blocks.alloc();
        let (layout, src, dst) = blocks.page_pair_mut(self.pages[pi], fresh);
        let shape = *layout.shape();
        let ps = shape.page_size;
        for layer in 0..shape.n_layer {
            for head in 0..shape.n_head {
                let lane = shape.lane(layer, head);
                let cnt = (self.fill(lane)).saturating_sub(pi * ps).min(ps);
                if cnt == 0 {
                    continue;
                }
                let kr = layout.k_run(layer, head, cnt);
                dst.data[kr.clone()].copy_from_slice(&src.data[kr]);
                let vr = layout.v_run(layer, head, cnt);
                dst.data[vr.clone()].copy_from_slice(&src.data[vr]);
                let s0 = shape.slot(lane, 0);
                dst.scale_k[s0..s0 + cnt].copy_from_slice(&src.scale_k[s0..s0 + cnt]);
                dst.scale_v[s0..s0 + cnt].copy_from_slice(&src.scale_v[s0..s0 + cnt]);
            }
        }
        fresh
    }

    /// Release every mapped page back to `blocks`.
    pub fn release(&mut self, blocks: &mut BlockPool) {
        for &p in &self.pages {
            blocks.decref(p);
        }
        self.pages.clear();
        for f in self.fill.iter_mut() {
            *f = 0;
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::kvpool::block::{LaneClass, LaneSpec, PageShape};

    fn pool() -> BlockPool {
        let mut bp = BlockPool::new(
            PageShape {
                n_layer: 1,
                n_head: 2,
                page_size: 4,
                d_head: 0,
            },
            None,
        );
        let spec = LaneSpec {
            class: LaneClass::Nested,
            stride: 8 + 1,
            bits: crate::lattice::nested::payload_bits_for(8, 14),
        };
        bp.set_d_head(8, &[(spec, spec)]);
        bp
    }

    #[test]
    fn claim_allocates_page_per_page_size_positions() {
        let mut bp = pool();
        let mut t = PageTable::new(2);
        for i in 0..9 {
            let (_, local, kind) = t.claim_slot(0, &mut bp, |_| {});
            assert_eq!(local, i % 4);
            let expect = if i % 4 == 0 {
                ClaimKind::Fresh
            } else {
                ClaimKind::Existing
            };
            assert_eq!(kind, expect, "claim {i}");
        }
        assert_eq!(t.n_pages(), 3);
        assert_eq!(t.fill(0), 9);
        assert_eq!(t.fill(1), 0, "lanes are independent");
        // second lane rides the already-mapped pages
        let before = bp.pages_in_use();
        t.claim_slot(1, &mut bp, |_| {});
        assert_eq!(bp.pages_in_use(), before);
        t.release(&mut bp);
        assert_eq!(bp.pages_in_use(), 0);
    }

    #[test]
    fn cow_triggers_on_shared_page_and_preserves_content() {
        let mut bp = pool();
        let mut t = PageTable::new(2);
        let (p0, s0, k0) = t.claim_slot(0, &mut bp, |_| {});
        assert_eq!(s0, 0);
        assert_eq!(k0, ClaimKind::Fresh);
        let kb = bp.layout().k_range(0, 0, 0).start;
        bp.page_mut(p0).data[kb] = 42;
        bp.page_mut(p0).scale_k[0] = 1.5;
        // simulate the prefix index holding a reference
        bp.incref(p0);
        let (p1, s1, k1) = t.claim_slot(0, &mut bp, |_| {});
        assert_ne!(p0, p1, "shared page must be copied on write");
        assert_eq!(s1, 1);
        assert_eq!(k1, ClaimKind::Cow);
        assert_eq!(bp.page(p1).data[kb], 42, "filled prefix copied");
        assert_eq!(bp.page(p1).scale_k[0], 1.5);
        assert_eq!(bp.refcount(p0), 1, "session ref moved off the old page");
        // subsequent appends stay on the private copy
        let (p2, _, k2) = t.claim_slot(0, &mut bp, |_| {});
        assert_eq!(p1, p2);
        assert_eq!(k2, ClaimKind::Existing);
        t.release(&mut bp);
        bp.decref(p0);
        assert_eq!(bp.pages_in_use(), 0);
    }

    #[test]
    fn frozen_private_page_also_copies() {
        let mut bp = pool();
        let mut t = PageTable::new(2);
        let (p0, _, _) = t.claim_slot(0, &mut bp, |_| {});
        bp.page_mut(p0).frozen = true;
        let (p1, _, k1) = t.claim_slot(0, &mut bp, |_| {});
        assert_ne!(p0, p1);
        assert_eq!(k1, ClaimKind::Cow);
        assert_eq!(bp.pages_in_use(), 1, "old private page freed by COW");
        t.release(&mut bp);
    }

    #[test]
    fn map_shared_advances_all_lanes() {
        let mut bp = pool();
        let mut t = PageTable::new(2);
        let ext = bp.alloc();
        bp.incref(ext); // table's reference
        t.map_shared(ext, 3, 4);
        assert_eq!(t.fill(0), 3);
        assert_eq!(t.fill(1), 3);
        assert_eq!(t.filled_on(0, 0, 4), 3);
        // next claim lands on slot 3 of the shared page → COW
        let (p, local, kind) = t.claim_slot(0, &mut bp, |_| {});
        assert_eq!(local, 3);
        assert_ne!(p, ext);
        assert_eq!(kind, ClaimKind::Cow);
        t.release(&mut bp);
        bp.decref(ext);
        assert_eq!(bp.pages_in_use(), 0);
    }
}
