//! Serving metrics: request latency quantiles, token throughput, batch
//! occupancy, KV-cache memory, the paged-pool gauges (pages/bytes in
//! use, prefix hit rate, evictions), and the engine's per-site weight
//! payload accounting — the numbers the serve_demo example reports.
//!
//! All latency-shaped series live in bounded [`LogHistogram`]s
//! (`obs::histogram`): memory is a fixed bucket array per series no
//! matter how many requests are served. (The previous implementation
//! kept an unbounded `Vec<f64>` of per-request latencies — a slow leak
//! under sustained traffic.) Besides the human-readable [`Metrics::report`]
//! line, the whole sink renders as a Prometheus text-exposition
//! snapshot via [`Metrics::prometheus_text`].

use crate::kvpool::PoolStats;
use crate::model::engine::SitePayload;
use crate::obs::histogram::{HistSummary, LogHistogram};
use crate::obs::PromWriter;
use std::sync::Mutex;
use std::time::Duration;

#[derive(Default)]
struct Inner {
    /// end-to-end request latency (admission to completion)
    latency: LogHistogram,
    /// time from submit to admission into the live set
    queue_wait: LogHistogram,
    /// time from submit to the first generated token
    ttft: LogHistogram,
    /// gap between consecutive generated tokens of one request
    inter_token: LogHistogram,
    /// prefill span over the prompt (incl. preemption replays)
    prefill: LogHistogram,
    /// one fused decode step across all live sessions
    fused_step: LogHistogram,
    tokens_out: u64,
    requests: u64,
    batches: u64,
    batch_slots: u64,
    /// capacity of those batches (the real occupancy denominator)
    batch_capacity_slots: u64,
    wall_ms: f64,
    kv_bytes: usize,
    /// every token the engine processed (prefill + decode + scoring)
    tokens_processed: u64,
    /// fused decode steps and the tokens they produced
    decode_steps: u64,
    decode_tokens: u64,
    /// sessions swapped out under pool-byte pressure (and requeued)
    preemptions: u64,
    /// latest paged-pool snapshot (None until a pooled engine serves)
    pool: Option<PoolStats>,
    /// per-site weight payload (label, bytes), recorded once per engine
    weight_sites: Vec<(String, usize)>,
    /// how many of those sites carry a quantized payload
    weight_sites_quantized: usize,
    /// requests rejected at admission (invalid or over capacity)
    rejected: u64,
    /// requests shed or expired past their deadline
    expired: u64,
    /// panics caught at a session boundary (score/prefill/step/probe)
    session_panics: u64,
    /// uncontained worker faults the supervisor respawned from
    respawns: u64,
    /// result of the pool's idle leak audit at worker exit
    pool_idle: Option<Result<(), String>>,
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Metrics survive panics elsewhere: a recorder that unwound while
    /// holding the lock cannot tear the counters (each is a plain
    /// scalar write), so poisoned locks are recovered rather than
    /// propagated.
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn record_request(&self, latency: Duration, tokens: usize) {
        let mut g = self.lock();
        g.latency.record_duration(latency);
        g.tokens_out += tokens as u64;
        g.requests += 1;
    }

    /// One scheduled batch of `size` filled slots out of `capacity`
    /// available — occupancy is reported against the real denominator.
    pub fn record_batch(&self, size: usize, capacity: usize) {
        let mut g = self.lock();
        g.batches += 1;
        g.batch_slots += size as u64;
        g.batch_capacity_slots += capacity.max(size) as u64;
    }

    /// Count tokens the engine actually processed (prefill, decode and
    /// scoring alike) — the counter the fused scheduler feeds instead of
    /// dropping its tally on the floor.
    pub fn record_tokens(&self, n: usize) {
        self.lock().tokens_processed += n as u64;
    }

    pub fn tokens_processed(&self) -> u64 {
        self.lock().tokens_processed
    }

    /// One fused decode step over `batch` live sessions out of
    /// `capacity` decode slots (each step emits one token per session,
    /// so the step also counts as a batch for occupancy).
    pub fn record_decode_step(&self, batch: usize, capacity: usize) {
        let mut g = self.lock();
        g.batches += 1;
        g.batch_slots += batch as u64;
        g.batch_capacity_slots += capacity.max(batch) as u64;
        g.decode_steps += 1;
        g.decode_tokens += batch as u64;
    }

    /// (fused decode steps, tokens they produced) — occupancy of the
    /// fused loop is their ratio.
    pub fn decode_stats(&self) -> (u64, u64) {
        let g = self.lock();
        (g.decode_steps, g.decode_tokens)
    }

    /// Filled vs available batch slots over every recorded batch.
    pub fn batch_utilization(&self) -> f64 {
        let g = self.lock();
        if g.batch_capacity_slots > 0 {
            g.batch_slots as f64 / g.batch_capacity_slots as f64
        } else {
            0.0
        }
    }

    // -- latency histograms -------------------------------------------

    /// Queue wait: submit → admission into the live set.
    pub fn record_queue_wait(&self, d: Duration) {
        self.lock().queue_wait.record_duration(d);
    }

    /// Time to first token: submit → first generated token streamed.
    pub fn record_ttft(&self, d: Duration) {
        self.lock().ttft.record_duration(d);
    }

    /// Gap between consecutive generated tokens of one request.
    pub fn record_inter_token(&self, d: Duration) {
        self.lock().inter_token.record_duration(d);
    }

    /// One prefill span (including replays after preemption).
    pub fn record_prefill(&self, d: Duration) {
        self.lock().prefill.record_duration(d);
    }

    /// One fused decode step across all live sessions.
    pub fn record_fused_step(&self, d: Duration) {
        self.lock().fused_step.record_duration(d);
    }

    pub fn latency_summary(&self) -> HistSummary {
        self.lock().latency.summary_ms()
    }

    pub fn queue_wait_summary(&self) -> HistSummary {
        self.lock().queue_wait.summary_ms()
    }

    pub fn ttft_summary(&self) -> HistSummary {
        self.lock().ttft.summary_ms()
    }

    pub fn inter_token_summary(&self) -> HistSummary {
        self.lock().inter_token.summary_ms()
    }

    pub fn prefill_summary(&self) -> HistSummary {
        self.lock().prefill.summary_ms()
    }

    pub fn fused_step_summary(&self) -> HistSummary {
        self.lock().fused_step.summary_ms()
    }

    // -- fault & lifecycle counters -----------------------------------

    /// A session was swapped out under pool-byte pressure (its pages
    /// released, its request requeued).
    pub fn record_preemption(&self) {
        self.lock().preemptions += 1;
    }

    pub fn preemptions(&self) -> u64 {
        self.lock().preemptions
    }

    /// A request was rejected at admission (invalid, or over capacity).
    pub fn record_rejected(&self) {
        self.lock().rejected += 1;
    }

    pub fn rejected(&self) -> u64 {
        self.lock().rejected
    }

    /// A request passed its deadline (shed while queued, or expired
    /// mid-generation with partial output).
    pub fn record_expired(&self) {
        self.lock().expired += 1;
    }

    pub fn expired(&self) -> u64 {
        self.lock().expired
    }

    /// A panic was caught at a session boundary (scoring, prefill, the
    /// fused step, or a recovery probe).
    pub fn record_session_panic(&self) {
        self.lock().session_panics += 1;
    }

    pub fn session_panics(&self) -> u64 {
        self.lock().session_panics
    }

    /// The supervision loop respawned the worker after an uncontained
    /// fault.
    pub fn record_respawn(&self) {
        self.lock().respawns += 1;
    }

    pub fn respawns(&self) -> u64 {
        self.lock().respawns
    }

    /// Store the pool's idle leak audit (`KvPool::verify_idle`),
    /// recorded when a worker drains cleanly.
    pub fn record_pool_idle(&self, r: Result<(), String>) {
        self.lock().pool_idle = Some(r);
    }

    /// `Some(Ok(()))` once a drained worker verified the pool returned
    /// to idle (only prefix-cache pages, each holding exactly its index
    /// reference); `Some(Err(msg))` describes a leak.
    pub fn pool_idle(&self) -> Option<Result<(), String>> {
        self.lock().pool_idle.clone()
    }

    pub fn record_wall(&self, wall: Duration) {
        self.lock().wall_ms += wall.as_secs_f64() * 1e3;
    }

    pub fn record_kv_bytes(&self, bytes: usize) {
        let mut g = self.lock();
        g.kv_bytes = g.kv_bytes.max(bytes);
    }

    /// Store the latest pool snapshot (pages/bytes in use, prefix
    /// hits/misses, evictions). Counters inside the snapshot are
    /// cumulative pool-side; the gauge is replaced, not accumulated.
    pub fn record_pool(&self, stats: PoolStats) {
        self.lock().pool = Some(stats);
    }

    /// Latest paged-pool snapshot, if a pooled engine is serving.
    pub fn pool_stats(&self) -> Option<PoolStats> {
        self.lock().pool
    }

    /// Record the serving engine's per-site weight payload accounting
    /// (`Engine::site_payloads`): one (site label, bytes) gauge per
    /// quantized tensor. Replaced, not accumulated.
    pub fn record_weight_sites(&self, sites: &[SitePayload]) {
        let mut g = self.lock();
        g.weight_sites = sites.iter().map(|s| (s.site.label(), s.bytes)).collect();
        g.weight_sites_quantized = sites.iter().filter(|s| s.quantized).count();
    }

    /// Per-site weight payload gauges (label, bytes); empty until an
    /// engine has been recorded.
    pub fn weight_sites(&self) -> Vec<(String, usize)> {
        self.lock().weight_sites.clone()
    }

    pub fn report(&self) -> String {
        let g = self.lock();
        let p50 = g.latency.quantile_us(0.50) as f64 / 1e3;
        let p95 = g.latency.quantile_us(0.95) as f64 / 1e3;
        let tput = if g.wall_ms > 0.0 {
            g.tokens_out as f64 / (g.wall_ms / 1e3)
        } else {
            0.0
        };
        let occupancy = if g.batches > 0 {
            g.batch_slots as f64 / g.batches as f64
        } else {
            0.0
        };
        let batch_util = if g.batch_capacity_slots > 0 {
            g.batch_slots as f64 / g.batch_capacity_slots as f64
        } else {
            0.0
        };
        let mut s = format!(
            "requests={} tokens={} throughput={:.1} tok/s p50={:.1}ms p95={:.1}ms \
             mean_batch={:.2} batch_util={:.2} kv_peak={:.1} KiB",
            g.requests,
            g.tokens_out,
            tput,
            p50,
            p95,
            occupancy,
            batch_util,
            g.kv_bytes as f64 / 1024.0
        );
        let faults = g.rejected + g.expired + g.session_panics + g.respawns;
        if g.tokens_processed > 0 || g.decode_steps > 0 || g.preemptions > 0 || faults > 0 {
            let mean_decode = if g.decode_steps > 0 {
                g.decode_tokens as f64 / g.decode_steps as f64
            } else {
                0.0
            };
            s.push_str(&format!(
                " | sched: processed={} decode_steps={} mean_decode_batch={:.2} preemptions={} \
                 rejected={} expired={} panics={} respawns={}",
                g.tokens_processed,
                g.decode_steps,
                mean_decode,
                g.preemptions,
                g.rejected,
                g.expired,
                g.session_panics,
                g.respawns
            ));
        }
        if g.queue_wait.count() > 0 || g.ttft.count() > 0 || g.fused_step.count() > 0 {
            s.push_str(&format!(
                " | lat: queue[{}] ttft[{}] itl[{}] prefill[{}] step[{}]",
                g.queue_wait.summary_ms().render(),
                g.ttft.summary_ms().render(),
                g.inter_token.summary_ms().render(),
                g.prefill.summary_ms().render(),
                g.fused_step.summary_ms().render()
            ));
        }
        if let Some(p) = &g.pool {
            let [fp, uni, nest] = p.bytes_in_use_split();
            s.push_str(&format!(
                " | pool: pages={} cached={} bytes={:.1} KiB \
                 (fp {:.1} / uni {:.1} / nest {:.1}) hit_rate={:.2} \
                 evictions={} overruns={}",
                p.pages_in_use,
                p.cached_pages,
                p.bytes_in_use as f64 / 1024.0,
                fp as f64 / 1024.0,
                uni as f64 / 1024.0,
                nest as f64 / 1024.0,
                p.prefix_hit_rate(),
                p.evicted_pages,
                p.budget_overruns
            ));
        }
        if let Some(Err(msg)) = &g.pool_idle {
            s.push_str(&format!(" | pool_leak: {msg}"));
        }
        if !g.weight_sites.is_empty() {
            let total: usize = g.weight_sites.iter().map(|(_, b)| b).sum();
            s.push_str(&format!(
                " | weights: sites={} quantized={} payload={:.1} KiB",
                g.weight_sites.len(),
                g.weight_sites_quantized,
                total as f64 / 1024.0
            ));
        }
        s
    }

    /// Render the whole sink as a Prometheus text-exposition snapshot
    /// (format 0.0.4): lifecycle counters, pool and weight gauges, and
    /// every latency histogram as a `_bucket`/`_sum`/`_count` family in
    /// seconds.
    pub fn prometheus_text(&self) -> String {
        let g = self.lock();
        let mut w = PromWriter::new();
        w.counter(
            "nestquant_requests_total",
            "requests completed",
            g.requests,
        );
        w.counter(
            "nestquant_tokens_out_total",
            "tokens returned to clients",
            g.tokens_out,
        );
        w.counter(
            "nestquant_tokens_processed_total",
            "tokens the engine processed (prefill + decode + scoring)",
            g.tokens_processed,
        );
        w.counter(
            "nestquant_decode_steps_total",
            "fused decode steps",
            g.decode_steps,
        );
        w.counter(
            "nestquant_decode_tokens_total",
            "tokens produced by fused decode steps",
            g.decode_tokens,
        );
        w.counter(
            "nestquant_batch_slots_total",
            "filled batch slots",
            g.batch_slots,
        );
        w.counter(
            "nestquant_batch_capacity_slots_total",
            "available batch slots",
            g.batch_capacity_slots,
        );
        w.counter(
            "nestquant_preemptions_total",
            "sessions preempted under pool pressure",
            g.preemptions,
        );
        w.counter(
            "nestquant_rejected_total",
            "requests rejected at admission",
            g.rejected,
        );
        w.counter(
            "nestquant_expired_total",
            "requests shed or expired past deadline",
            g.expired,
        );
        w.counter(
            "nestquant_session_panics_total",
            "panics contained at a session boundary",
            g.session_panics,
        );
        w.counter(
            "nestquant_respawns_total",
            "worker respawns after uncontained faults",
            g.respawns,
        );
        w.gauge(
            "nestquant_kv_peak_bytes",
            "peak per-session KV bytes observed",
            g.kv_bytes as f64,
        );
        if let Some(p) = &g.pool {
            w.gauge(
                "nestquant_pool_pages_in_use",
                "pool pages currently referenced",
                p.pages_in_use as f64,
            );
            w.gauge(
                "nestquant_pool_cached_pages",
                "prefix-cache pages resident",
                p.cached_pages as f64,
            );
            w.gauge(
                "nestquant_pool_bytes_in_use",
                "pool bytes currently in use",
                p.bytes_in_use as f64,
            );
            let [fp, uni, nest] = p.bytes_in_use_split();
            w.gauge_labeled(
                "nestquant_pool_lane_bytes",
                "pool bytes in use per lane codec",
                "lane",
                &[
                    ("fp32", fp as f64),
                    ("uniform", uni as f64),
                    ("nested", nest as f64),
                ],
            );
            w.gauge(
                "nestquant_pool_prefix_hit_rate",
                "fraction of prompt tokens served from cached pages",
                p.prefix_hit_rate(),
            );
            w.counter(
                "nestquant_pool_evicted_pages_total",
                "index-only pages evicted for headroom",
                p.evicted_pages,
            );
            w.counter(
                "nestquant_pool_budget_overruns_total",
                "allocations past the pool byte budget",
                p.budget_overruns,
            );
        }
        if !g.weight_sites.is_empty() {
            let total: usize = g.weight_sites.iter().map(|(_, b)| b).sum();
            w.gauge(
                "nestquant_weight_payload_bytes",
                "total quantized weight payload",
                total as f64,
            );
            w.gauge(
                "nestquant_weight_sites",
                "weight sites served (quantized or passthrough)",
                g.weight_sites.len() as f64,
            );
        }
        w.histogram(
            "nestquant_request_latency_seconds",
            "end-to-end request latency",
            &g.latency,
        );
        w.histogram(
            "nestquant_queue_wait_seconds",
            "submit to admission into the live set",
            &g.queue_wait,
        );
        w.histogram(
            "nestquant_ttft_seconds",
            "submit to first generated token",
            &g.ttft,
        );
        w.histogram(
            "nestquant_inter_token_seconds",
            "gap between consecutive generated tokens",
            &g.inter_token,
        );
        w.histogram(
            "nestquant_prefill_seconds",
            "prefill span over the prompt",
            &g.prefill,
        );
        w.histogram(
            "nestquant_fused_step_seconds",
            "one fused decode step across live sessions",
            &g.fused_step,
        );
        w.finish()
    }

    pub fn throughput_tok_s(&self) -> f64 {
        let g = self.lock();
        if g.wall_ms > 0.0 {
            g.tokens_out as f64 / (g.wall_ms / 1e3)
        } else {
            0.0
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::obs::export::validate_prometheus;

    #[test]
    fn aggregates() {
        let m = Metrics::new();
        m.record_request(Duration::from_millis(10), 5);
        m.record_request(Duration::from_millis(30), 7);
        m.record_batch(3, 4);
        m.record_wall(Duration::from_millis(100));
        m.record_kv_bytes(2048);
        let r = m.report();
        assert!(r.contains("requests=2"));
        assert!(r.contains("tokens=12"));
        assert!(r.contains("kv_peak=2.0 KiB"));
        assert!(!r.contains("pool:"), "no pool gauges before a snapshot");
        assert!(m.throughput_tok_s() > 0.0);
    }

    #[test]
    fn batch_occupancy_uses_the_real_capacity_denominator() {
        let m = Metrics::new();
        assert_eq!(m.batch_utilization(), 0.0);
        m.record_batch(3, 4);
        assert!((m.batch_utilization() - 0.75).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("batch_util=0.75"), "{r}");
        // decode steps feed the same denominator
        m.record_decode_step(1, 4);
        assert!((m.batch_utilization() - 0.5).abs() < 1e-12);
        // capacity can never be reported smaller than the filled slots
        m.record_batch(6, 2);
        assert!(m.batch_utilization() <= 1.0);
    }

    #[test]
    fn latency_memory_is_bounded_and_quantiles_survive() {
        let m = Metrics::new();
        for i in 0..50_000u64 {
            m.record_request(Duration::from_micros(100 + i % 900), 1);
        }
        let s = m.latency_summary();
        assert_eq!(s.count, 50_000);
        // all samples in [100 µs, 1 ms): the histogram quantiles must be
        // in range (bounded error), and no per-request storage exists
        assert!(s.p50_ms >= 0.1 && s.p50_ms < 1.1, "{:?}", s);
        assert!(s.max_ms < 1.1, "{:?}", s);
        let r = m.report();
        assert!(r.contains("requests=50000"), "{r}");
    }

    #[test]
    fn latency_histograms_surface_in_report_and_prometheus() {
        let m = Metrics::new();
        assert!(!m.report().contains("lat:"), "no segment before a record");
        m.record_queue_wait(Duration::from_micros(300));
        m.record_ttft(Duration::from_millis(2));
        m.record_inter_token(Duration::from_micros(700));
        m.record_prefill(Duration::from_millis(1));
        m.record_fused_step(Duration::from_micros(650));
        assert_eq!(m.ttft_summary().count, 1);
        assert_eq!(m.inter_token_summary().count, 1);
        assert_eq!(m.queue_wait_summary().count, 1);
        assert_eq!(m.prefill_summary().count, 1);
        assert_eq!(m.fused_step_summary().count, 1);
        let r = m.report();
        assert!(r.contains("lat: queue["), "{r}");
        assert!(r.contains("ttft["), "{r}");
        let text = m.prometheus_text();
        validate_prometheus(&text).unwrap();
        assert!(text.contains("nestquant_ttft_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("nestquant_inter_token_seconds_count 1"));
    }

    #[test]
    fn prometheus_snapshot_validates_with_pool_and_weights() {
        use crate::quant::plan::{SiteId, SiteKind};
        let m = Metrics::new();
        m.record_request(Duration::from_millis(5), 3);
        m.record_pool(PoolStats {
            pages_in_use: 2,
            bytes_in_use: 1024,
            page_bytes_fp: 128,
            ..Default::default()
        });
        m.record_weight_sites(&[SitePayload {
            site: SiteId::weights(0, SiteKind::Up),
            bytes: 512,
            bits_per_entry: 4.25,
            quantized: true,
        }]);
        let text = m.prometheus_text();
        validate_prometheus(&text).unwrap();
        assert!(text.contains("nestquant_requests_total 1"));
        assert!(text.contains("nestquant_pool_bytes_in_use 1024"));
        assert!(text.contains("lane=\"fp32\""));
        assert!(text.contains("nestquant_weight_payload_bytes 512"));
    }

    #[test]
    fn scheduler_counters_surface_in_report() {
        let m = Metrics::new();
        assert!(!m.report().contains("sched:"), "no gauges before a record");
        m.record_tokens(40);
        m.record_decode_step(3, 4);
        m.record_decode_step(1, 4);
        m.record_tokens(4);
        m.record_preemption();
        assert_eq!(m.tokens_processed(), 44);
        assert_eq!(m.decode_stats(), (2, 4));
        assert_eq!(m.preemptions(), 1);
        let r = m.report();
        assert!(
            r.contains("sched: processed=44 decode_steps=2 mean_decode_batch=2.00 preemptions=1"),
            "{r}"
        );
        // decode steps also feed batch occupancy
        assert!(r.contains("mean_batch=2.00"), "{r}");
        assert!(r.contains("batch_util=0.50"), "{r}");
    }

    #[test]
    fn fault_counters_surface_in_report() {
        let m = Metrics::new();
        assert!(!m.report().contains("sched:"), "no gauges before a record");
        m.record_rejected();
        m.record_rejected();
        m.record_expired();
        m.record_session_panic();
        m.record_respawn();
        assert_eq!(m.rejected(), 2);
        assert_eq!(m.expired(), 1);
        assert_eq!(m.session_panics(), 1);
        assert_eq!(m.respawns(), 1);
        let r = m.report();
        assert!(
            r.contains("rejected=2 expired=1 panics=1 respawns=1"),
            "{r}"
        );
        // the idle audit only surfaces on failure
        assert_eq!(m.pool_idle(), None);
        m.record_pool_idle(Ok(()));
        assert!(!m.report().contains("pool_leak:"));
        m.record_pool_idle(Err("2 pages unaccounted".into()));
        assert_eq!(m.pool_idle(), Some(Err("2 pages unaccounted".into())));
        assert!(m.report().contains("pool_leak: 2 pages unaccounted"), "{}", m.report());
    }

    #[test]
    fn pool_gauges_surface_in_report() {
        let m = Metrics::new();
        assert!(m.pool_stats().is_none());
        m.record_pool(PoolStats {
            pages_in_use: 7,
            cached_pages: 3,
            bytes_in_use: 4096,
            // heterogeneous page: 512 B of fp32 lanes, 64 B uniform,
            // 16 B nested — the report must split by lane codec
            page_bytes_fp: 512,
            page_bytes_uniform: 64,
            page_bytes_nested: 16,
            prefix_hit_tokens: 90,
            prefix_miss_tokens: 10,
            evicted_pages: 2,
            budget_overruns: 0,
            ..Default::default()
        });
        let r = m.report();
        assert!(r.contains("pages=7"), "{r}");
        assert!(r.contains("cached=3"), "{r}");
        // per-class split: 7 pages × the per-page class bytes
        assert!(r.contains("(fp 3.5 / uni 0.4 / nest 0.1)"), "{r}");
        assert!(r.contains("hit_rate=0.90"), "{r}");
        assert!(r.contains("evictions=2"), "{r}");
        assert_eq!(m.pool_stats().unwrap().pages_in_use, 7);
        assert_eq!(m.pool_stats().unwrap().bytes_in_use_split(), [3584, 448, 112]);
    }

    #[test]
    fn weight_site_gauges_surface_in_report() {
        use crate::quant::plan::{SiteId, SiteKind, SiteRole};
        let m = Metrics::new();
        assert!(m.weight_sites().is_empty());
        assert!(!m.report().contains("weights:"), "no gauges before a record");
        m.record_weight_sites(&[
            SitePayload {
                site: SiteId::weights(0, SiteKind::Down),
                bytes: 2048,
                bits_per_entry: 4.25,
                quantized: true,
            },
            SitePayload {
                site: SiteId::lm_head(SiteRole::Weights),
                bytes: 4096,
                bits_per_entry: 32.0,
                quantized: false,
            },
        ]);
        let r = m.report();
        assert!(r.contains("weights: sites=2 quantized=1 payload=6.0 KiB"), "{r}");
        let sites = m.weight_sites();
        assert_eq!(sites[0], ("L0.down.weights".to_string(), 2048));
        assert_eq!(sites[1].0, "lm_head.weights");
    }
}
