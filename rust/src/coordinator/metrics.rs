//! Serving metrics: request latency quantiles, token throughput, batch
//! occupancy, KV-cache memory, the paged-pool gauges (pages/bytes in
//! use, prefix hit rate, evictions), and the engine's per-site weight
//! payload accounting — the numbers the serve_demo example reports.

use crate::kvpool::PoolStats;
use crate::model::engine::SitePayload;
use std::sync::Mutex;
use std::time::Duration;

#[derive(Default)]
struct Inner {
    latencies_ms: Vec<f64>,
    tokens_out: u64,
    requests: u64,
    batches: u64,
    batch_slots: u64,
    wall_ms: f64,
    kv_bytes: usize,
    /// every token the engine processed (prefill + decode + scoring)
    tokens_processed: u64,
    /// fused decode steps and the tokens they produced
    decode_steps: u64,
    decode_tokens: u64,
    /// sessions swapped out under pool-byte pressure (and requeued)
    preemptions: u64,
    /// latest paged-pool snapshot (None until a pooled engine serves)
    pool: Option<PoolStats>,
    /// per-site weight payload (label, bytes), recorded once per engine
    weight_sites: Vec<(String, usize)>,
    /// how many of those sites carry a quantized payload
    weight_sites_quantized: usize,
    /// requests rejected at admission (invalid or over capacity)
    rejected: u64,
    /// requests shed or expired past their deadline
    expired: u64,
    /// panics caught at a session boundary (score/prefill/step/probe)
    session_panics: u64,
    /// uncontained worker faults the supervisor respawned from
    respawns: u64,
    /// result of the pool's idle leak audit at worker exit
    pool_idle: Option<Result<(), String>>,
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Metrics survive panics elsewhere: a recorder that unwound while
    /// holding the lock cannot tear the counters (each is a plain
    /// scalar write), so poisoned locks are recovered rather than
    /// propagated.
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn record_request(&self, latency: Duration, tokens: usize) {
        let mut g = self.lock();
        g.latencies_ms.push(latency.as_secs_f64() * 1e3);
        g.tokens_out += tokens as u64;
        g.requests += 1;
    }

    pub fn record_batch(&self, size: usize, capacity: usize) {
        let mut g = self.lock();
        g.batches += 1;
        g.batch_slots += size as u64;
        let _ = capacity;
    }

    /// Count tokens the engine actually processed (prefill, decode and
    /// scoring alike) — the counter the fused scheduler feeds instead of
    /// dropping its tally on the floor.
    pub fn record_tokens(&self, n: usize) {
        self.lock().tokens_processed += n as u64;
    }

    pub fn tokens_processed(&self) -> u64 {
        self.lock().tokens_processed
    }

    /// One fused decode step over `batch` live sessions (each step
    /// emits one token per session, so the step also counts as a batch
    /// for occupancy).
    pub fn record_decode_step(&self, batch: usize) {
        let mut g = self.lock();
        g.batches += 1;
        g.batch_slots += batch as u64;
        g.decode_steps += 1;
        g.decode_tokens += batch as u64;
    }

    /// (fused decode steps, tokens they produced) — occupancy of the
    /// fused loop is their ratio.
    pub fn decode_stats(&self) -> (u64, u64) {
        let g = self.lock();
        (g.decode_steps, g.decode_tokens)
    }

    /// A session was swapped out under pool-byte pressure (its pages
    /// released, its request requeued).
    pub fn record_preemption(&self) {
        self.lock().preemptions += 1;
    }

    pub fn preemptions(&self) -> u64 {
        self.lock().preemptions
    }

    /// A request was rejected at admission (invalid, or over capacity).
    pub fn record_rejected(&self) {
        self.lock().rejected += 1;
    }

    pub fn rejected(&self) -> u64 {
        self.lock().rejected
    }

    /// A request passed its deadline (shed while queued, or expired
    /// mid-generation with partial output).
    pub fn record_expired(&self) {
        self.lock().expired += 1;
    }

    pub fn expired(&self) -> u64 {
        self.lock().expired
    }

    /// A panic was caught at a session boundary (scoring, prefill, the
    /// fused step, or a recovery probe).
    pub fn record_session_panic(&self) {
        self.lock().session_panics += 1;
    }

    pub fn session_panics(&self) -> u64 {
        self.lock().session_panics
    }

    /// The supervision loop respawned the worker after an uncontained
    /// fault.
    pub fn record_respawn(&self) {
        self.lock().respawns += 1;
    }

    pub fn respawns(&self) -> u64 {
        self.lock().respawns
    }

    /// Store the pool's idle leak audit (`KvPool::verify_idle`),
    /// recorded when a worker drains cleanly.
    pub fn record_pool_idle(&self, r: Result<(), String>) {
        self.lock().pool_idle = Some(r);
    }

    /// `Some(Ok(()))` once a drained worker verified the pool returned
    /// to idle (only prefix-cache pages, each holding exactly its index
    /// reference); `Some(Err(msg))` describes a leak.
    pub fn pool_idle(&self) -> Option<Result<(), String>> {
        self.lock().pool_idle.clone()
    }

    pub fn record_wall(&self, wall: Duration) {
        self.lock().wall_ms += wall.as_secs_f64() * 1e3;
    }

    pub fn record_kv_bytes(&self, bytes: usize) {
        let mut g = self.lock();
        g.kv_bytes = g.kv_bytes.max(bytes);
    }

    /// Store the latest pool snapshot (pages/bytes in use, prefix
    /// hits/misses, evictions). Counters inside the snapshot are
    /// cumulative pool-side; the gauge is replaced, not accumulated.
    pub fn record_pool(&self, stats: PoolStats) {
        self.lock().pool = Some(stats);
    }

    /// Latest paged-pool snapshot, if a pooled engine is serving.
    pub fn pool_stats(&self) -> Option<PoolStats> {
        self.lock().pool
    }

    /// Record the serving engine's per-site weight payload accounting
    /// (`Engine::site_payloads`): one (site label, bytes) gauge per
    /// quantized tensor. Replaced, not accumulated.
    pub fn record_weight_sites(&self, sites: &[SitePayload]) {
        let mut g = self.lock();
        g.weight_sites = sites.iter().map(|s| (s.site.label(), s.bytes)).collect();
        g.weight_sites_quantized = sites.iter().filter(|s| s.quantized).count();
    }

    /// Per-site weight payload gauges (label, bytes); empty until an
    /// engine has been recorded.
    pub fn weight_sites(&self) -> Vec<(String, usize)> {
        self.lock().weight_sites.clone()
    }

    pub fn report(&self) -> String {
        let g = self.lock();
        let mut lat = g.latencies_ms.clone();
        let (p50, p95) = if lat.is_empty() {
            (0.0, 0.0)
        } else {
            (
                crate::util::stats::quantile(&mut lat, 0.5),
                crate::util::stats::quantile(&mut lat, 0.95),
            )
        };
        let tput = if g.wall_ms > 0.0 {
            g.tokens_out as f64 / (g.wall_ms / 1e3)
        } else {
            0.0
        };
        let occupancy = if g.batches > 0 {
            g.batch_slots as f64 / g.batches as f64
        } else {
            0.0
        };
        let mut s = format!(
            "requests={} tokens={} throughput={:.1} tok/s p50={:.1}ms p95={:.1}ms \
             mean_batch={:.2} kv_peak={:.1} KiB",
            g.requests,
            g.tokens_out,
            tput,
            p50,
            p95,
            occupancy,
            g.kv_bytes as f64 / 1024.0
        );
        let faults = g.rejected + g.expired + g.session_panics + g.respawns;
        if g.tokens_processed > 0 || g.decode_steps > 0 || g.preemptions > 0 || faults > 0 {
            let mean_decode = if g.decode_steps > 0 {
                g.decode_tokens as f64 / g.decode_steps as f64
            } else {
                0.0
            };
            s.push_str(&format!(
                " | sched: processed={} decode_steps={} mean_decode_batch={:.2} preemptions={} \
                 rejected={} expired={} panics={} respawns={}",
                g.tokens_processed,
                g.decode_steps,
                mean_decode,
                g.preemptions,
                g.rejected,
                g.expired,
                g.session_panics,
                g.respawns
            ));
        }
        if let Some(p) = &g.pool {
            let [fp, uni, nest] = p.bytes_in_use_split();
            s.push_str(&format!(
                " | pool: pages={} cached={} bytes={:.1} KiB \
                 (fp {:.1} / uni {:.1} / nest {:.1}) hit_rate={:.2} \
                 evictions={} overruns={}",
                p.pages_in_use,
                p.cached_pages,
                p.bytes_in_use as f64 / 1024.0,
                fp as f64 / 1024.0,
                uni as f64 / 1024.0,
                nest as f64 / 1024.0,
                p.prefix_hit_rate(),
                p.evicted_pages,
                p.budget_overruns
            ));
        }
        if let Some(Err(msg)) = &g.pool_idle {
            s.push_str(&format!(" | pool_leak: {msg}"));
        }
        if !g.weight_sites.is_empty() {
            let total: usize = g.weight_sites.iter().map(|(_, b)| b).sum();
            s.push_str(&format!(
                " | weights: sites={} quantized={} payload={:.1} KiB",
                g.weight_sites.len(),
                g.weight_sites_quantized,
                total as f64 / 1024.0
            ));
        }
        s
    }

    pub fn throughput_tok_s(&self) -> f64 {
        let g = self.lock();
        if g.wall_ms > 0.0 {
            g.tokens_out as f64 / (g.wall_ms / 1e3)
        } else {
            0.0
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let m = Metrics::new();
        m.record_request(Duration::from_millis(10), 5);
        m.record_request(Duration::from_millis(30), 7);
        m.record_batch(3, 4);
        m.record_wall(Duration::from_millis(100));
        m.record_kv_bytes(2048);
        let r = m.report();
        assert!(r.contains("requests=2"));
        assert!(r.contains("tokens=12"));
        assert!(r.contains("kv_peak=2.0 KiB"));
        assert!(!r.contains("pool:"), "no pool gauges before a snapshot");
        assert!(m.throughput_tok_s() > 0.0);
    }

    #[test]
    fn scheduler_counters_surface_in_report() {
        let m = Metrics::new();
        assert!(!m.report().contains("sched:"), "no gauges before a record");
        m.record_tokens(40);
        m.record_decode_step(3);
        m.record_decode_step(1);
        m.record_tokens(4);
        m.record_preemption();
        assert_eq!(m.tokens_processed(), 44);
        assert_eq!(m.decode_stats(), (2, 4));
        assert_eq!(m.preemptions(), 1);
        let r = m.report();
        assert!(
            r.contains("sched: processed=44 decode_steps=2 mean_decode_batch=2.00 preemptions=1"),
            "{r}"
        );
        // decode steps also feed batch occupancy
        assert!(r.contains("mean_batch=2.00"), "{r}");
    }

    #[test]
    fn fault_counters_surface_in_report() {
        let m = Metrics::new();
        assert!(!m.report().contains("sched:"), "no gauges before a record");
        m.record_rejected();
        m.record_rejected();
        m.record_expired();
        m.record_session_panic();
        m.record_respawn();
        assert_eq!(m.rejected(), 2);
        assert_eq!(m.expired(), 1);
        assert_eq!(m.session_panics(), 1);
        assert_eq!(m.respawns(), 1);
        let r = m.report();
        assert!(
            r.contains("rejected=2 expired=1 panics=1 respawns=1"),
            "{r}"
        );
        // the idle audit only surfaces on failure
        assert_eq!(m.pool_idle(), None);
        m.record_pool_idle(Ok(()));
        assert!(!m.report().contains("pool_leak:"));
        m.record_pool_idle(Err("2 pages unaccounted".into()));
        assert_eq!(m.pool_idle(), Some(Err("2 pages unaccounted".into())));
        assert!(m.report().contains("pool_leak: 2 pages unaccounted"), "{}", m.report());
    }

    #[test]
    fn pool_gauges_surface_in_report() {
        let m = Metrics::new();
        assert!(m.pool_stats().is_none());
        m.record_pool(PoolStats {
            pages_in_use: 7,
            cached_pages: 3,
            bytes_in_use: 4096,
            // heterogeneous page: 512 B of fp32 lanes, 64 B uniform,
            // 16 B nested — the report must split by lane codec
            page_bytes_fp: 512,
            page_bytes_uniform: 64,
            page_bytes_nested: 16,
            prefix_hit_tokens: 90,
            prefix_miss_tokens: 10,
            evicted_pages: 2,
            budget_overruns: 0,
            ..Default::default()
        });
        let r = m.report();
        assert!(r.contains("pages=7"), "{r}");
        assert!(r.contains("cached=3"), "{r}");
        // per-class split: 7 pages × the per-page class bytes
        assert!(r.contains("(fp 3.5 / uni 0.4 / nest 0.1)"), "{r}");
        assert!(r.contains("hit_rate=0.90"), "{r}");
        assert!(r.contains("evictions=2"), "{r}");
        assert_eq!(m.pool_stats().unwrap().pages_in_use, 7);
        assert_eq!(m.pool_stats().unwrap().bytes_in_use_split(), [3584, 448, 112]);
    }

    #[test]
    fn weight_site_gauges_surface_in_report() {
        use crate::quant::plan::{SiteId, SiteKind, SiteRole};
        let m = Metrics::new();
        assert!(m.weight_sites().is_empty());
        assert!(!m.report().contains("weights:"), "no gauges before a record");
        m.record_weight_sites(&[
            SitePayload {
                site: SiteId::weights(0, SiteKind::Down),
                bytes: 2048,
                bits_per_entry: 4.25,
                quantized: true,
            },
            SitePayload {
                site: SiteId::lm_head(SiteRole::Weights),
                bytes: 4096,
                bits_per_entry: 32.0,
                quantized: false,
            },
        ]);
        let r = m.report();
        assert!(r.contains("weights: sites=2 quantized=1 payload=6.0 KiB"), "{r}");
        let sites = m.weight_sites();
        assert_eq!(sites[0], ("L0.down.weights".to_string(), 2048));
        assert_eq!(sites[1].0, "lm_head.weights");
    }
}
