//! Dynamic batcher: accumulates requests until the batch is full or a
//! deadline expires — the standard continuous-batching admission policy
//! (vLLM-style), sized to the AOT artifact's static batch dimension.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// maximum requests per batch (the artifact's batch dim)
    pub max_batch: usize,
    /// max time the first request may wait for the batch to fill
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(5),
        }
    }
}

/// Pulls from a channel, groups into batches under the policy.
pub struct Batcher<T> {
    rx: Receiver<T>,
    pub policy: BatchPolicy,
}

impl<T> Batcher<T> {
    pub fn new(rx: Receiver<T>, policy: BatchPolicy) -> Self {
        Batcher { rx, policy }
    }

    /// Block for the next batch. Returns `None` when the channel closed
    /// and no items remain.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        // block for the first item
        let first = self.rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.policy.max_wait;
        while batch.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(item) => batch.push(item),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }

    /// Block for one item — token-level admission pulls requests one at
    /// a time between decode steps instead of waiting out a batch
    /// deadline. `None` when the channel closed.
    pub fn recv(&self) -> Option<T> {
        self.rx.recv().ok()
    }

    /// Drain everything currently queued without blocking. Returns
    /// `false` once the channel has disconnected (nothing more will
    /// ever arrive), `true` while senders remain.
    pub fn try_drain(&self, into: &mut Vec<T>) -> bool {
        loop {
            match self.rx.try_recv() {
                Ok(item) => into.push(item),
                Err(TryRecvError::Empty) => return true,
                Err(TryRecvError::Disconnected) => return false,
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(
            rx,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
        );
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4, 5, 6, 7]);
        assert_eq!(b.next_batch().unwrap(), vec![8, 9]);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let b = Batcher::new(
            rx,
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(10),
            },
        );
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn closed_channel_returns_none() {
        let (tx, rx) = channel::<i32>();
        drop(tx);
        let b = Batcher::new(rx, BatchPolicy::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn try_drain_takes_queued_items_without_blocking() {
        let (tx, rx) = channel();
        for i in 0..3 {
            tx.send(i).unwrap();
        }
        let b = Batcher::new(rx, BatchPolicy::default());
        let mut inbox = Vec::new();
        assert!(b.try_drain(&mut inbox), "sender still alive");
        assert_eq!(inbox, vec![0, 1, 2]);
        assert!(b.try_drain(&mut inbox), "empty but open");
        assert_eq!(inbox.len(), 3);
        tx.send(9).unwrap();
        drop(tx);
        assert!(!b.try_drain(&mut inbox), "disconnected after draining");
        assert_eq!(inbox, vec![0, 1, 2, 9]);
        assert!(b.recv().is_none());
    }
}
