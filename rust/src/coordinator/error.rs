//! Typed serving errors carried on `Response` and returned by
//! `Server::submit` — failures become per-request answers instead of
//! silent channel drops or worker panics.

use std::fmt;

/// Why a request was rejected, expired, or failed mid-flight.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The request could never be served (bad window, out-of-vocab token,
    /// prompt + n_new past the model context, ...). Rejected at admission.
    InvalidRequest(String),
    /// The request's deadline passed — either while queued (shed before
    /// admission, no tokens) or mid-generation (partial tokens attached).
    DeadlineExceeded,
    /// The server's admission queue is full; retry later.
    Capacity(String),
    /// A fault inside the serving stack poisoned this request's session.
    /// Other sessions are unaffected; partial tokens are attached when any
    /// were generated before the fault.
    Internal(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::InvalidRequest(msg) => write!(f, "invalid request: {msg}"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded"),
            ServeError::Capacity(msg) => write!(f, "over capacity: {msg}"),
            ServeError::Internal(msg) => write!(f, "internal serving fault: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_friendly_and_error_trait_object_safe() {
        let e: Box<dyn std::error::Error> = Box::new(ServeError::InvalidRequest("empty prompt".into()));
        assert_eq!(e.to_string(), "invalid request: empty prompt");
        assert_eq!(ServeError::DeadlineExceeded.to_string(), "deadline exceeded");
        assert_eq!(
            ServeError::Capacity("queue full (4)".into()).to_string(),
            "over capacity: queue full (4)"
        );
        assert_eq!(
            ServeError::Internal("worker restarted".into()).to_string(),
            "internal serving fault: worker restarted"
        );
    }
}
