//! Serving coordinator: request router → dynamic batcher → prefill/decode
//! scheduler → engine workers. std-thread + mpsc based (tokio is not in
//! the offline vendor set; the concurrency pattern is identical).
//!
//! The coordinator demonstrates NestQuant's motivating serving wins:
//! generation keeps the KV cache in coded form (`kvcache`), and batched
//! scoring goes through the PJRT HLO artifact (`runtime::ModelRunner`) —
//! python never appears on the request path.

pub mod batcher;
pub mod generator;
pub mod metrics;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use generator::GenSession;
pub use metrics::Metrics;
pub use server::{Request, Response, Server, ServerConfig};
