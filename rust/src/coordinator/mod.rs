//! Serving coordinator: request router → dynamic batcher → prefill/decode
//! scheduler → engine workers. std-thread + mpsc based (tokio is not in
//! the offline vendor set; the concurrency pattern is identical).
//!
//! The coordinator demonstrates NestQuant's motivating serving wins:
//! generation keeps the KV cache in coded form, with every worker
//! session drawing pages from one shared `kvpool::KvPool` — common
//! prompt prefixes are served from cached coded pages (refcount bump,
//! no re-quantization), total KV memory is capped by the pool's byte
//! budget with LRU eviction, and the pool gauges (pages, bytes, prefix
//! hit rate, evictions) flow through [`Metrics`]. Batched scoring goes
//! through the PJRT HLO artifact (`runtime::ModelRunner`) — python
//! never appears on the request path.
//!
//! Faults are contained per request: admission validates every
//! [`Request`] against the model config, deadlines shed stale work, and
//! panics inside prefill or the fused step tear down only the faulted
//! session (pages verifiably released) while survivors continue
//! bitwise-identical. Callers see a typed [`ServeError`] on the
//! [`Response`], never a worker panic.

#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod batcher;
pub mod error;
pub mod generator;
pub mod metrics;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use error::ServeError;
pub use generator::GenSession;
pub use metrics::Metrics;
pub use server::{Request, Response, Server, ServerConfig, ShutdownReport};
