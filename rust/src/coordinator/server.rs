//! The serving front-end: a router thread fans requests out to a
//! generation worker (continuous batching over `GenSession`s, all
//! drawing quantized KV pages from one shared
//! [`KvPool`](crate::kvpool::KvPool)) and a scoring
//! worker (batched full-window forward through the AOT HLO artifact when
//! available, native engine otherwise). Sessions with common prompt
//! prefixes — within a batch or across batches — share coded pages
//! through the pool's prefix index instead of re-quantizing them, and
//! the pool's byte budget caps total KV memory under load.

use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::generator::GenSession;
use crate::coordinator::metrics::Metrics;
use crate::kvpool::PoolConfig;
use crate::model::engine::Engine;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// A serving request.
pub enum Request {
    /// prompt tokens → generated tokens
    Generate {
        id: u64,
        prompt: Vec<i32>,
        n_new: usize,
    },
    /// full-window scoring: mean NLL of the window
    Score { id: u64, window: Vec<i32> },
}

impl Request {
    pub fn id(&self) -> u64 {
        match self {
            Request::Generate { id, .. } | Request::Score { id, .. } => *id,
        }
    }
}

#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub nll: Option<f64>,
    pub latency_ms: f64,
}

#[derive(Clone, Copy)]
pub struct ServerConfig {
    pub policy: BatchPolicy,
    /// shared KV-pool sizing (page size, byte budget) for pooled engines.
    /// The server's pool outlives every session, so unlike the
    /// per-session default it ships with a byte budget: without one, the
    /// prefix index would retain every finished session's frozen pages
    /// forever and sustained traffic would grow memory without bound.
    pub pool: PoolConfig,
}

impl ServerConfig {
    /// Default KV-pool byte budget (logical coded payload): 64 MiB ≈
    /// 128M fp32-equivalent KV entries at the ~8× coded density.
    pub const DEFAULT_POOL_BUDGET: usize = 64 << 20;
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            policy: BatchPolicy::default(),
            pool: PoolConfig {
                budget_bytes: Some(Self::DEFAULT_POOL_BUDGET),
                ..PoolConfig::default()
            },
        }
    }
}

/// Handle to a running server.
pub struct Server {
    tx: Option<Sender<(Request, Instant)>>,
    worker: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl Server {
    /// Start the coordinator over a quantized engine. Responses are
    /// delivered on the returned channel (out of order across batches).
    pub fn start(
        engine: Arc<Engine>,
        cfg: ServerConfig,
    ) -> (Self, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel::<(Request, Instant)>();
        let (resp_tx, resp_rx) = channel::<Response>();
        let metrics = Arc::new(Metrics::new());
        let m = metrics.clone();

        let worker = std::thread::spawn(move || {
            // one shared paged pool for every session this worker runs:
            // prefix reuse and the byte budget span the server's
            // lifetime. The pool is total over plans — fp/uniform KV
            // layers ride their own lanes — so every engine pools.
            let pool = engine.kv_pool(cfg.pool);
            // per-site weight payload gauges (mixed-precision plans show
            // their per-tensor byte split here)
            m.record_weight_sites(&engine.site_payloads());
            let batcher = Batcher::new(rx, cfg.policy);
            while let Some(batch) = batcher.next_batch() {
                m.record_batch(batch.len(), cfg.policy.max_batch);
                let t_batch = Instant::now();
                let mut total_tokens = 0usize;

                // continuous-batching lite: round-robin one decode step
                // per active session until all sessions finish.
                struct Active<'a> {
                    id: u64,
                    t0: Instant,
                    sess: GenSession<'a>,
                    pending_prompt: Vec<i32>,
                    remaining: usize,
                    logits: Vec<f32>,
                    out: Vec<i32>,
                }
                let mut gen_sessions: Vec<Active> = Vec::new();
                for (req, t0) in batch {
                    match req {
                        Request::Generate { id, prompt, n_new } => {
                            let sess = GenSession::new_in_pool(&engine, &pool);
                            gen_sessions.push(Active {
                                id,
                                t0,
                                sess,
                                pending_prompt: prompt,
                                remaining: n_new,
                                logits: Vec::new(),
                                out: Vec::new(),
                            });
                        }
                        Request::Score { id, window } => {
                            // native scoring (the HLO path is exercised by
                            // runtime::ModelRunner in examples/tests; the
                            // in-process worker stays self-contained)
                            let logits = engine.forward_window(&window[..window.len() - 1]);
                            let nll =
                                crate::model::forward::window_nll(&logits, &window[1..]);
                            total_tokens += window.len();
                            let _ = resp_tx.send(Response {
                                id,
                                tokens: Vec::new(),
                                nll: Some(nll),
                                latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                            });
                            m.record_request(t0.elapsed(), window.len());
                        }
                    }
                }
                // prefill phase: pool-cached prefixes are mapped (zero
                // quantization work), the remainder steps through the cache
                for a in gen_sessions.iter_mut() {
                    a.logits = a.sess.prefill(&a.pending_prompt);
                    total_tokens += a.pending_prompt.len();
                }
                // decode phase, round-robin
                let mut done = false;
                while !done {
                    done = true;
                    for a in gen_sessions.iter_mut() {
                        if a.remaining == 0 || a.sess.position() >= engine.cfg.ctx {
                            continue;
                        }
                        done = false;
                        let next = GenSession::greedy(&a.logits);
                        a.out.push(next);
                        a.logits = a.sess.step(next);
                        a.remaining -= 1;
                        total_tokens += 1;
                    }
                }
                for a in gen_sessions {
                    m.record_kv_bytes(a.sess.kv_bytes());
                    m.record_request(a.t0.elapsed(), a.out.len());
                    let _ = resp_tx.send(Response {
                        id: a.id,
                        tokens: a.out,
                        nll: None,
                        latency_ms: a.t0.elapsed().as_secs_f64() * 1e3,
                    });
                }
                m.record_pool(pool.stats());
                m.record_wall(t_batch.elapsed());
                let _ = total_tokens;
            }
        });

        (
            Server {
                tx: Some(tx),
                worker: Some(worker),
                metrics,
            },
            resp_rx,
        )
    }

    pub fn submit(&self, req: Request) {
        self.tx
            .as_ref()
            .expect("server closed")
            .send((req, Instant::now()))
            .expect("worker died");
    }

    /// Close the queue and wait for the worker to drain.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::engine::{EngineOptions, Regime};
    use crate::model::weights::{artifact_path, ModelWeights};

    fn engine() -> Option<Arc<Engine>> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let p = artifact_path(&dir, "tiny");
        if !p.exists() {
            return None;
        }
        let w = ModelWeights::load(&p).unwrap();
        Some(Arc::new(Engine::build(
            &w,
            EngineOptions {
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        )))
    }

    #[test]
    fn serves_generate_and_score() {
        let Some(eng) = engine() else { return };
        let prompt: Vec<i32> = (0..8).collect();
        let window: Vec<i32> = (0..33).map(|i| i % 40).collect();
        let (srv, rx) = Server::start(eng, ServerConfig::default());
        srv.submit(Request::Generate {
            id: 1,
            prompt: prompt.clone(),
            n_new: 4,
        });
        srv.submit(Request::Score { id: 2, window });
        srv.submit(Request::Generate {
            id: 3,
            prompt,
            n_new: 2,
        });
        let mut got = std::collections::HashMap::new();
        for _ in 0..3 {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            got.insert(r.id, r);
        }
        assert_eq!(got[&1].tokens.len(), 4);
        assert_eq!(got[&3].tokens.len(), 2);
        assert!(got[&2].nll.unwrap() > 0.0);
        srv.shutdown();
    }

    #[test]
    fn pooled_serving_shares_prefixes_and_exports_gauges() {
        // no artifact needed: a synthetic NestQuantM W+KV engine. Three
        // generate requests with a 32-token common prefix must hit the
        // shared pool, and the pool gauges must surface in Metrics.
        let w = crate::model::weights::ModelWeights::synthetic(
            crate::model::ModelConfig {
                vocab: 48,
                ctx: 64,
                d_model: 32,
                n_layer: 1,
                n_head: 2,
                d_ff: 64,
            },
            0x5E11,
        );
        let eng = Arc::new(Engine::build(
            &w,
            crate::model::engine::EngineOptions {
                method: crate::model::engine::Method::NestQuantM,
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        ));
        let (srv, rx) = Server::start(eng, ServerConfig::default());
        let common: Vec<i32> = (0..32).map(|i| i % 48).collect();
        for id in 0..3u64 {
            let mut prompt = common.clone();
            prompt.push(40 + id as i32);
            srv.submit(Request::Generate { id, prompt, n_new: 3 });
        }
        for _ in 0..3 {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            assert_eq!(r.tokens.len(), 3);
        }
        let stats = srv.metrics.pool_stats().expect("pooled engine must export gauges");
        assert!(
            stats.prefix_hit_tokens >= 32,
            "later sessions should map the shared prefix: {stats:?}"
        );
        assert!(stats.pages_in_use > 0);
        assert!(srv.metrics.report().contains("pool:"));
        // per-site weight payloads flow through Metrics: 6 linears per
        // layer + the head
        let sites = srv.metrics.weight_sites();
        assert_eq!(sites.len(), 7);
        assert!(sites.iter().all(|(_, b)| *b > 0));
        assert!(srv.metrics.report().contains("weights: sites=7"));
        srv.shutdown();
    }
}
