//! The serving front-end: a router thread fans requests out to a
//! generation worker (continuous batching over `GenSession`s, quantized
//! KV cache) and a scoring worker (batched full-window forward through
//! the AOT HLO artifact when available, native engine otherwise).

use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::generator::GenSession;
use crate::coordinator::metrics::Metrics;
use crate::model::engine::Engine;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// A serving request.
pub enum Request {
    /// prompt tokens → generated tokens
    Generate {
        id: u64,
        prompt: Vec<i32>,
        n_new: usize,
    },
    /// full-window scoring: mean NLL of the window
    Score { id: u64, window: Vec<i32> },
}

impl Request {
    pub fn id(&self) -> u64 {
        match self {
            Request::Generate { id, .. } | Request::Score { id, .. } => *id,
        }
    }
}

#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub nll: Option<f64>,
    pub latency_ms: f64,
}

#[derive(Clone, Copy)]
pub struct ServerConfig {
    pub policy: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            policy: BatchPolicy::default(),
        }
    }
}

/// Handle to a running server.
pub struct Server {
    tx: Option<Sender<(Request, Instant)>>,
    worker: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl Server {
    /// Start the coordinator over a quantized engine. Responses are
    /// delivered on the returned channel (out of order across batches).
    pub fn start(
        engine: Arc<Engine>,
        cfg: ServerConfig,
    ) -> (Self, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel::<(Request, Instant)>();
        let (resp_tx, resp_rx) = channel::<Response>();
        let metrics = Arc::new(Metrics::new());
        let m = metrics.clone();

        let worker = std::thread::spawn(move || {
            let batcher = Batcher::new(rx, cfg.policy);
            while let Some(batch) = batcher.next_batch() {
                m.record_batch(batch.len(), cfg.policy.max_batch);
                let t_batch = Instant::now();
                let mut total_tokens = 0usize;

                // continuous-batching lite: round-robin one decode step
                // per active session until all sessions finish.
                struct Active<'a> {
                    id: u64,
                    t0: Instant,
                    sess: GenSession<'a>,
                    pending_prompt: Vec<i32>,
                    remaining: usize,
                    logits: Vec<f32>,
                    out: Vec<i32>,
                }
                let mut gen_sessions: Vec<Active> = Vec::new();
                for (req, t0) in batch {
                    match req {
                        Request::Generate { id, prompt, n_new } => {
                            gen_sessions.push(Active {
                                id,
                                t0,
                                sess: GenSession::new(&engine),
                                pending_prompt: prompt,
                                remaining: n_new,
                                logits: Vec::new(),
                                out: Vec::new(),
                            });
                        }
                        Request::Score { id, window } => {
                            // native scoring (the HLO path is exercised by
                            // runtime::ModelRunner in examples/tests; the
                            // in-process worker stays self-contained)
                            let logits = engine.forward_window(&window[..window.len() - 1]);
                            let nll =
                                crate::model::forward::window_nll(&logits, &window[1..]);
                            total_tokens += window.len();
                            let _ = resp_tx.send(Response {
                                id,
                                tokens: Vec::new(),
                                nll: Some(nll),
                                latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                            });
                            m.record_request(t0.elapsed(), window.len());
                        }
                    }
                }
                // prefill phase (token-by-token through the cache)
                for a in gen_sessions.iter_mut() {
                    for &t in &a.pending_prompt.clone() {
                        a.logits = a.sess.step(t);
                    }
                    total_tokens += a.pending_prompt.len();
                }
                // decode phase, round-robin
                let mut done = false;
                while !done {
                    done = true;
                    for a in gen_sessions.iter_mut() {
                        if a.remaining == 0 || a.sess.position() >= engine.cfg.ctx {
                            continue;
                        }
                        done = false;
                        let next = GenSession::greedy(&a.logits);
                        a.out.push(next);
                        a.logits = a.sess.step(next);
                        a.remaining -= 1;
                        total_tokens += 1;
                    }
                }
                for a in gen_sessions {
                    m.record_kv_bytes(a.sess.kv_bytes());
                    m.record_request(a.t0.elapsed(), a.out.len());
                    let _ = resp_tx.send(Response {
                        id: a.id,
                        tokens: a.out,
                        nll: None,
                        latency_ms: a.t0.elapsed().as_secs_f64() * 1e3,
                    });
                }
                m.record_wall(t_batch.elapsed());
                let _ = total_tokens;
            }
        });

        (
            Server {
                tx: Some(tx),
                worker: Some(worker),
                metrics,
            },
            resp_rx,
        )
    }

    pub fn submit(&self, req: Request) {
        self.tx
            .as_ref()
            .expect("server closed")
            .send((req, Instant::now()))
            .expect("worker died");
    }

    /// Close the queue and wait for the worker to drain.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::engine::{EngineOptions, Regime};
    use crate::model::weights::{artifact_path, ModelWeights};

    fn engine() -> Option<Arc<Engine>> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let p = artifact_path(&dir, "tiny");
        if !p.exists() {
            return None;
        }
        let w = ModelWeights::load(&p).unwrap();
        Some(Arc::new(Engine::build(
            &w,
            EngineOptions {
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        )))
    }

    #[test]
    fn serves_generate_and_score() {
        let Some(eng) = engine() else { return };
        let prompt: Vec<i32> = (0..8).collect();
        let window: Vec<i32> = (0..33).map(|i| i % 40).collect();
        let (srv, rx) = Server::start(eng, ServerConfig::default());
        srv.submit(Request::Generate {
            id: 1,
            prompt: prompt.clone(),
            n_new: 4,
        });
        srv.submit(Request::Score { id: 2, window });
        srv.submit(Request::Generate {
            id: 3,
            prompt,
            n_new: 2,
        });
        let mut got = std::collections::HashMap::new();
        for _ in 0..3 {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            got.insert(r.id, r);
        }
        assert_eq!(got[&1].tokens.len(), 4);
        assert_eq!(got[&3].tokens.len(), 2);
        assert!(got[&2].nll.unwrap() > 0.0);
        srv.shutdown();
    }
}
