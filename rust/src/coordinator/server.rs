//! The serving front-end: a single fused decode loop (vLLM-style
//! token-level continuous batching). Every live session's current token
//! is gathered into one activation panel per layer and served through
//! the packed integer GEMM
//! ([`step_fused`](crate::coordinator::generator::step_fused));
//! per-session attention runs
//! against each session's own coded pages in the shared
//! [`KvPool`](crate::kvpool::KvPool). Admission happens between decode
//! steps (a request joins the running loop as soon as a slot and pool
//! headroom exist — no batch barrier), and pool-byte pressure preempts
//! the youngest session (pages released, request requeued and replayed)
//! instead of overrunning the budget. Sessions with common prompt
//! prefixes share coded pages through the pool's prefix index instead
//! of re-quantizing them.
//!
//! Fault containment: requests are validated at admission and answered
//! with a typed [`ServeError`] instead of panicking the worker;
//! per-request deadlines shed queued work and expire mid-generation
//! runs with partial output; panics inside scoring, prefill, or the
//! fused step are caught at the session boundary — the poisoned
//! session is torn down (its pages verifiably released), survivors are
//! replayed bitwise-identically, and a supervision loop respawns the
//! worker state after any uncontained fault so [`Server::submit`]
//! never panics.

use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::error::ServeError;
use crate::coordinator::generator::{step_fused_traced, GenSession};
use crate::coordinator::metrics::Metrics;
use crate::kvpool::PoolConfig;
use crate::model::engine::{Engine, StepScratch};
use crate::obs::clock::Clock;
use crate::obs::trace::{req_track, EventKind, Trace, TraceConfig, TRACK_WORKER};
use crate::model::ModelConfig;
use crate::quant::gemm::scatter_panel;
use crate::util::linalg::Mat;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A serving request.
pub enum Request {
    /// prompt tokens → generated tokens
    Generate {
        id: u64,
        prompt: Vec<i32>,
        n_new: usize,
    },
    /// full-window scoring: mean NLL of the window
    Score { id: u64, window: Vec<i32> },
}

impl Request {
    pub fn id(&self) -> u64 {
        match self {
            Request::Generate { id, .. } | Request::Score { id, .. } => *id,
        }
    }

    /// Admission-time validation against the model shape. Anything that
    /// could never be served — and in particular anything that would
    /// previously have panicked the worker (an empty score window
    /// underflowed `window[..len - 1]`) — is answered with
    /// [`ServeError::InvalidRequest`] instead of entering the loop.
    pub fn validate(&self, cfg: &ModelConfig) -> Result<(), ServeError> {
        let bad_token = |toks: &[i32]| {
            toks.iter()
                .find(|&&t| t < 0 || t as usize >= cfg.vocab)
                .copied()
        };
        match self {
            Request::Generate { prompt, n_new, .. } => {
                if prompt.is_empty() {
                    return Err(ServeError::InvalidRequest("empty prompt".into()));
                }
                if prompt.len() + n_new > cfg.ctx {
                    return Err(ServeError::InvalidRequest(format!(
                        "prompt ({}) + n_new ({}) exceeds model context ({})",
                        prompt.len(),
                        n_new,
                        cfg.ctx
                    )));
                }
                if let Some(t) = bad_token(prompt) {
                    return Err(ServeError::InvalidRequest(format!(
                        "prompt token {t} outside vocab (0..{})",
                        cfg.vocab
                    )));
                }
            }
            Request::Score { window, .. } => {
                if window.len() < 2 {
                    return Err(ServeError::InvalidRequest(format!(
                        "score window needs at least 2 tokens, got {}",
                        window.len()
                    )));
                }
                if window.len() - 1 > cfg.ctx {
                    return Err(ServeError::InvalidRequest(format!(
                        "score window ({} tokens) exceeds model context ({})",
                        window.len(),
                        cfg.ctx
                    )));
                }
                if let Some(t) = bad_token(window) {
                    return Err(ServeError::InvalidRequest(format!(
                        "window token {t} outside vocab (0..{})",
                        cfg.vocab
                    )));
                }
            }
        }
        Ok(())
    }
}

#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub nll: Option<f64>,
    pub latency_ms: f64,
    /// `true` on the final response for a request (the full token
    /// stream / score); `false` on per-token streaming updates (sent
    /// only when [`ServerConfig::stream`] is on, one generated token
    /// each)
    pub done: bool,
    /// `None` on success. On failure this is the final answer for the
    /// request: `tokens` carries whatever was generated before the
    /// deadline/fault (possibly empty).
    pub error: Option<ServeError>,
}

impl Response {
    fn finished(id: u64, t0: Instant, tokens: Vec<i32>) -> Self {
        Response {
            id,
            tokens,
            nll: None,
            latency_ms: t0.elapsed().as_secs_f64() * 1e3,
            done: true,
            error: None,
        }
    }

    fn scored(id: u64, t0: Instant, nll: f64) -> Self {
        Response {
            id,
            tokens: Vec::new(),
            nll: Some(nll),
            latency_ms: t0.elapsed().as_secs_f64() * 1e3,
            done: true,
            error: None,
        }
    }

    fn token(id: u64, t0: Instant, t: i32) -> Self {
        Response {
            id,
            tokens: vec![t],
            nll: None,
            latency_ms: t0.elapsed().as_secs_f64() * 1e3,
            done: false,
            error: None,
        }
    }

    fn failed(id: u64, t0: Instant, tokens: Vec<i32>, error: ServeError) -> Self {
        Response {
            id,
            tokens,
            nll: None,
            latency_ms: t0.elapsed().as_secs_f64() * 1e3,
            done: true,
            error: Some(error),
        }
    }
}

#[derive(Clone, Copy)]
pub struct ServerConfig {
    pub policy: BatchPolicy,
    /// shared KV-pool sizing (page size, byte budget) for pooled engines.
    /// The server's pool outlives every session, so unlike the
    /// per-session default it ships with a byte budget: without one, the
    /// prefix index would retain every finished session's frozen pages
    /// forever and sustained traffic would grow memory without bound.
    pub pool: PoolConfig,
    /// also send a `done: false` response per generated token as the
    /// fused loop produces it (the final `done: true` response still
    /// carries the full stream)
    pub stream: bool,
    /// default per-request deadline, applied by [`Server::submit`]
    /// (override per request via [`Server::submit_with_deadline`]).
    /// A request past its deadline is shed from the queue or expired
    /// mid-generation with partial output + `DeadlineExceeded`.
    pub deadline: Option<Duration>,
    /// admission-queue bound: requests arriving while this many
    /// `Generate`s wait are answered `ServeError::Capacity` immediately
    /// instead of queueing without bound.
    pub max_queue: Option<usize>,
    /// trace-journal sizing: ring capacity and fused-step sampling
    /// period (see [`Server::trace`] for reading it back out)
    pub trace: TraceConfig,
}

impl ServerConfig {
    /// Default KV-pool byte budget (logical coded payload): 64 MiB ≈
    /// 128M fp32-equivalent KV entries at the ~8× coded density.
    pub const DEFAULT_POOL_BUDGET: usize = 64 << 20;
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            policy: BatchPolicy::default(),
            pool: PoolConfig {
                budget_bytes: Some(Self::DEFAULT_POOL_BUDGET),
                ..PoolConfig::default()
            },
            stream: false,
            deadline: None,
            max_queue: None,
            trace: TraceConfig::default(),
        }
    }
}

/// What a bounded [`Server::shutdown`] observed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShutdownReport {
    /// worker exited cleanly within the deadline
    pub drained: bool,
    /// requests admitted but still unanswered when the deadline hit
    /// (0 when `drained`)
    pub undrained: usize,
}

/// A submitted request travelling to the worker.
struct Inbound {
    req: Request,
    t0: Instant,
    deadline: Option<Instant>,
}

type Inflight = Arc<Mutex<HashMap<u64, Instant>>>;

/// Response sender + the shared admitted-but-unanswered map. The map is
/// what makes worker respawn lossless: after an uncontained fault the
/// supervisor answers every orphaned request with a typed error instead
/// of letting it hang on a dead channel.
struct Responder {
    tx: Sender<Response>,
    inflight: Inflight,
}

impl Responder {
    fn lock(&self) -> MutexGuard<'_, HashMap<u64, Instant>> {
        // a panic while the map was held is already contained elsewhere;
        // the map itself (u64 -> Instant) cannot be torn
        self.inflight.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn admit(&self, id: u64, t0: Instant) {
        self.lock().insert(id, t0);
    }

    fn finish(&self, r: Response) {
        self.lock().remove(&r.id);
        let _ = self.tx.send(r);
    }

    fn stream(&self, r: Response) {
        let _ = self.tx.send(r);
    }

    fn fail_all_inflight(&self, msg: &str) {
        let orphans: Vec<(u64, Instant)> = self.lock().drain().collect();
        for (id, t0) in orphans {
            let _ = self.tx.send(Response::failed(
                id,
                t0,
                Vec::new(),
                ServeError::Internal(msg.to_string()),
            ));
        }
    }
}

/// Handle to a running server.
pub struct Server {
    tx: Option<Sender<Inbound>>,
    worker: Option<JoinHandle<()>>,
    default_deadline: Option<Duration>,
    inflight: Inflight,
    pub metrics: Arc<Metrics>,
    /// bounded request-lifecycle trace journal (export with
    /// [`crate::obs::chrome_trace_json`])
    pub trace: Arc<Trace>,
}

impl Server {
    /// Start the coordinator over a quantized engine. Responses are
    /// delivered on the returned channel (out of order across batches).
    pub fn start(
        engine: Arc<Engine>,
        cfg: ServerConfig,
    ) -> (Self, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel::<Inbound>();
        let (resp_tx, resp_rx) = channel::<Response>();
        let metrics = Arc::new(Metrics::new());
        let m = metrics.clone();
        let trace = Arc::new(cfg.trace.build(Clock::wall()));
        let tr = trace.clone();
        let inflight: Inflight = Arc::new(Mutex::new(HashMap::new()));
        let inflight_w = inflight.clone();
        // fault-injection scope is per-thread (see util::failpoint); the
        // worker inherits the spawner's membership so a test scenario
        // reaches the serving loop but not unrelated concurrent tests
        let fault_scope = crate::util::failpoint::participating();

        let worker = std::thread::spawn(move || {
            crate::util::failpoint::join_scenario(fault_scope);
            // the batcher (and its receiver) outlives worker respawns, so
            // requests still queued in the channel survive a fault and are
            // served by the respawned loop
            let batcher = Batcher::new(rx, cfg.policy);
            let out = Responder {
                tx: resp_tx,
                inflight: inflight_w,
            };
            // supervision: an uncontained panic anywhere in the loop tears
            // down all worker state; orphaned requests get a typed error
            // and the loop restarts with a fresh pool
            loop {
                let run = catch_unwind(AssertUnwindSafe(|| {
                    worker_loop(&engine, cfg, &batcher, &out, &m, &tr)
                }));
                match run {
                    Ok(()) => break,
                    Err(_) => {
                        m.record_respawn();
                        tr.instant(TRACK_WORKER, EventKind::WorkerRespawn);
                        out.fail_all_inflight("serving worker restarted after a fault");
                    }
                }
            }
        });

        (
            Server {
                tx: Some(tx),
                worker: Some(worker),
                default_deadline: cfg.deadline,
                inflight,
                metrics,
                trace,
            },
            resp_rx,
        )
    }

    /// Enqueue a request under the server's default deadline. Never
    /// panics: a dead or shut-down worker is a typed error.
    pub fn submit(&self, req: Request) -> Result<(), ServeError> {
        self.submit_with_deadline(req, self.default_deadline)
    }

    /// Enqueue a request with an explicit deadline override (`None` =
    /// no deadline, regardless of the server default).
    pub fn submit_with_deadline(
        &self,
        req: Request,
        deadline: Option<Duration>,
    ) -> Result<(), ServeError> {
        let t0 = Instant::now();
        // an unrepresentable (astronomically far) deadline is no deadline
        let abs = deadline.and_then(|d| t0.checked_add(d));
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| ServeError::Internal("server is shut down".into()))?;
        self.trace.instant(req_track(req.id()), EventKind::Queued);
        tx.send(Inbound {
            req,
            t0,
            deadline: abs,
        })
        .map_err(|_| ServeError::Internal("serving worker is gone".into()))
    }

    /// Close the queue and wait up to 10 minutes for the worker to
    /// drain (see [`Server::shutdown_within`]).
    pub fn shutdown(self) -> ShutdownReport {
        self.shutdown_within(Duration::from_secs(600))
    }

    /// Close the queue and wait for the worker to drain, but give up
    /// after `limit` and report how many admitted requests were still
    /// unanswered (the detached worker keeps draining in the
    /// background; its responses land on the receiver as usual).
    pub fn shutdown_within(mut self, limit: Duration) -> ShutdownReport {
        drop(self.tx.take());
        let Some(w) = self.worker.take() else {
            return ShutdownReport {
                drained: true,
                undrained: 0,
            };
        };
        let giveup = Instant::now().checked_add(limit);
        loop {
            if w.is_finished() {
                let _ = w.join();
                return ShutdownReport {
                    drained: true,
                    undrained: 0,
                };
            }
            if let Some(g) = giveup {
                if Instant::now() >= g {
                    let undrained = self
                        .inflight
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .len();
                    return ShutdownReport {
                        drained: false,
                        undrained,
                    };
                }
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// A `Generate` waiting for admission; `out` carries tokens already
/// produced before a preemption, replayed on re-admission.
struct Pending {
    id: u64,
    t0: Instant,
    deadline: Option<Instant>,
    prompt: Vec<i32>,
    n_new: usize,
    out: Vec<i32>,
}

/// A session inside the fused decode loop.
struct Live<'a> {
    id: u64,
    t0: Instant,
    deadline: Option<Instant>,
    // admission order — preemption swaps out the youngest
    seq: u64,
    sess: GenSession<'a>,
    prompt: Vec<i32>,
    n_new: usize,
    out: Vec<i32>,
    /// when the previous token landed — feeds the inter-token latency
    /// histogram; `None` until this incarnation's first token, so gaps
    /// spanning a preemption/replay are not counted
    last_tok: Option<Instant>,
    logits: Vec<f32>,
}

/// One incarnation of the worker. Returns when the submit channel is
/// closed and all work is drained; panics only on uncontained faults
/// (the supervisor in [`Server::start`] respawns it).
fn worker_loop(
    engine: &Arc<Engine>,
    cfg: ServerConfig,
    batcher: &Batcher<Inbound>,
    out: &Responder,
    m: &Metrics,
    tr: &Arc<Trace>,
) {
    // one shared paged pool for every session this worker runs: prefix
    // reuse and the byte budget span the incarnation's lifetime. The
    // pool is total over plans — fp/uniform KV layers ride their own
    // lanes — so every engine pools. A respawn starts a fresh pool; the
    // old one's pages were released when its sessions unwound.
    let pool = engine.kv_pool(cfg.pool);
    pool.set_trace(tr.clone());
    // per-site weight payload gauges (mixed-precision plans show their
    // per-tensor byte split here)
    m.record_weight_sites(&engine.site_payloads());
    let page_size = cfg.pool.page_size.max(1);
    let max_live = cfg.policy.max_batch.max(1);

    let mut queue: VecDeque<Pending> = VecDeque::new();
    let mut live: Vec<Live> = Vec::new();
    let mut inbox: Vec<Inbound> = Vec::new();
    let mut open = true;
    let mut next_seq = 0u64;
    let mut scratch = StepScratch::new();
    let mut panel = Mat::zeros(0, 0);

    loop {
        // ingest: block only when idle, otherwise take whatever has
        // queued up since the last decode step
        if open && live.is_empty() && queue.is_empty() {
            match batcher.recv() {
                Some(item) => inbox.push(item),
                None => open = false,
            }
        }
        if open && !batcher.try_drain(&mut inbox) {
            open = false;
        }
        for Inbound { req, t0, deadline } in inbox.drain(..) {
            let id = req.id();
            out.admit(id, t0);
            if let Err(e) = req.validate(&engine.cfg) {
                m.record_rejected();
                tr.instant(req_track(id), EventKind::Rejected);
                out.finish(Response::failed(id, t0, Vec::new(), e));
                continue;
            }
            tr.instant(req_track(id), EventKind::Validated);
            if deadline.is_some_and(|dl| Instant::now() >= dl) {
                m.record_expired();
                tr.instant(req_track(id), EventKind::Expired);
                out.finish(Response::failed(
                    id,
                    t0,
                    Vec::new(),
                    ServeError::DeadlineExceeded,
                ));
                continue;
            }
            match req {
                Request::Generate { id, prompt, n_new } => {
                    if cfg.max_queue.is_some_and(|cap| queue.len() >= cap) {
                        m.record_rejected();
                        out.finish(Response::failed(
                            id,
                            t0,
                            Vec::new(),
                            ServeError::Capacity(format!(
                                "admission queue full ({} waiting)",
                                queue.len()
                            )),
                        ));
                        continue;
                    }
                    queue.push_back(Pending {
                        id,
                        t0,
                        deadline,
                        prompt,
                        n_new,
                        out: Vec::new(),
                    });
                }
                Request::Score { id, window } => {
                    // native scoring (the HLO path is exercised by
                    // runtime::ModelRunner in examples/tests; the
                    // in-process worker stays self-contained). A panic
                    // in the forward pass is this request's fault, not
                    // the worker's.
                    let t_score = Instant::now();
                    let scored = catch_unwind(AssertUnwindSafe(|| {
                        let logits = engine.forward_window(&window[..window.len() - 1]);
                        crate::model::forward::window_nll(&logits, &window[1..])
                    }));
                    match scored {
                        Ok(nll) => {
                            m.record_tokens(window.len());
                            m.record_request(t0.elapsed(), window.len());
                            m.record_wall(t_score.elapsed());
                            tr.instant(req_track(id), EventKind::Done { tokens: 0 });
                            out.finish(Response::scored(id, t0, nll));
                        }
                        Err(_) => {
                            m.record_session_panic();
                            tr.instant(req_track(id), EventKind::Fault);
                            out.finish(Response::failed(
                                id,
                                t0,
                                Vec::new(),
                                ServeError::Internal("score forward panicked".into()),
                            ));
                        }
                    }
                }
            }
        }
        // deliberately uncontained: exercises the supervision respawn
        // path (tests only — compiled out of release builds)
        crate::fail_point!("coordinator/worker");
        if !open && live.is_empty() && queue.is_empty() {
            break;
        }

        // age-based shedding: queued requests past their deadline are
        // answered now (with any pre-preemption partial output) instead
        // of burning pool pages on work nobody is waiting for
        let now = Instant::now();
        let mut qi = 0;
        while qi < queue.len() {
            if !queue[qi].deadline.is_some_and(|dl| now >= dl) {
                qi += 1;
                continue;
            }
            let Some(p) = queue.remove(qi) else { break };
            m.record_expired();
            tr.instant(req_track(p.id), EventKind::Expired);
            out.finish(Response::failed(
                p.id,
                p.t0,
                p.out,
                ServeError::DeadlineExceeded,
            ));
        }

        // token-level admission: a queued request joins the running
        // loop between decode steps as soon as a slot is free and its
        // pages fit (preemption keeps at least one session running, so
        // an empty loop always admits)
        while live.len() < max_live {
            let Some(front) = queue.front() else { break };
            let need = (front.prompt.len() + front.out.len()) / page_size + 1;
            if !live.is_empty() && pool.would_overrun(need) {
                break;
            }
            let Some(p) = queue.pop_front() else { break };
            let t_adm = Instant::now();
            let queue_wait = t_adm.duration_since(p.t0);
            m.record_queue_wait(queue_wait);
            tr.instant(
                req_track(p.id),
                EventKind::Admitted {
                    queue_wait_us: u64::try_from(queue_wait.as_micros()).unwrap_or(u64::MAX),
                    replayed: !p.out.is_empty(),
                },
            );
            let mut sess = GenSession::new_in_pool(engine, &pool);
            // requeued sessions replay prompt + prior output; the
            // prefix index serves whatever pages survived
            let replay: Vec<i32> = p.prompt.iter().chain(p.out.iter()).copied().collect();
            let n_replay = replay.len();
            // a prefill panic poisons only this session: the unwinding
            // closure drops `sess`, whose Drop releases every page it
            // had claimed back to the pool
            let t_prefill = tr.now();
            let prefilled = catch_unwind(AssertUnwindSafe(move || {
                let logits = sess.prefill(&replay);
                (sess, logits)
            }));
            match prefilled {
                Ok((sess, logits)) => {
                    m.record_tokens(n_replay);
                    m.record_prefill(t_adm.elapsed());
                    m.record_wall(t_adm.elapsed());
                    tr.span(
                        req_track(p.id),
                        EventKind::Prefill {
                            tokens: n_replay as u32,
                        },
                        t_prefill,
                    );
                    live.push(Live {
                        id: p.id,
                        t0: p.t0,
                        deadline: p.deadline,
                        seq: next_seq,
                        sess,
                        prompt: p.prompt,
                        n_new: p.n_new,
                        out: p.out,
                        last_tok: None,
                        logits,
                    });
                    next_seq += 1;
                }
                Err(_) => {
                    m.record_session_panic();
                    tr.instant(req_track(p.id), EventKind::Fault);
                    out.finish(Response::failed(
                        p.id,
                        p.t0,
                        p.out,
                        ServeError::Internal("prefill panicked; session torn down".into()),
                    ));
                }
            }
        }

        // completions and mid-generation expiry (before the step so a
        // request admitted with nothing left to generate answers
        // immediately, and an expired session stops burning steps)
        let mut i = 0;
        while i < live.len() {
            let a = &live[i];
            let done = a.out.len() >= a.n_new || a.sess.position() >= engine.cfg.ctx;
            let expired = !done && a.deadline.is_some_and(|dl| Instant::now() >= dl);
            if !done && !expired {
                i += 1;
                continue;
            }
            let a = live.swap_remove(i);
            m.record_kv_bytes(a.sess.kv_bytes());
            m.record_request(a.t0.elapsed(), a.out.len());
            if expired {
                m.record_expired();
                tr.instant(req_track(a.id), EventKind::Expired);
                out.finish(Response::failed(
                    a.id,
                    a.t0,
                    a.out,
                    ServeError::DeadlineExceeded,
                ));
            } else {
                tr.instant(
                    req_track(a.id),
                    EventKind::Done {
                        tokens: a.out.len() as u32,
                    },
                );
                out.finish(Response::finished(a.id, a.t0, a.out));
            }
        }
        if live.is_empty() {
            m.record_pool(pool.stats());
            continue;
        }

        // pool-pressure preemption: if the next step's page claims
        // could overrun the byte budget, swap out the youngest session
        // — release its pages, requeue its request at the front —
        // rather than fail. The oldest session is never preempted, so
        // every stream finishes.
        loop {
            let upcoming = live
                .iter()
                .filter(|a| a.sess.position() % page_size == 0)
                .count()
                .max(1);
            if live.len() <= 1 || !pool.would_overrun(upcoming) {
                break;
            }
            let Some(vi) = live
                .iter()
                .enumerate()
                .max_by_key(|(_, a)| a.seq)
                .map(|(i, _)| i)
            else {
                break;
            };
            let mut a = live.swap_remove(vi);
            a.sess.preempt();
            m.record_preemption();
            tr.instant(req_track(a.id), EventKind::Preempted);
            queue.push_front(Pending {
                id: a.id,
                t0: a.t0,
                deadline: a.deadline,
                prompt: a.prompt,
                n_new: a.n_new,
                out: a.out,
            });
        }

        // one fused decode step over every live session: greedy next
        // tokens in, one activation panel through the engine,
        // next-token logits scattered back per session. Per-step and
        // per-site GEMM spans are recorded on sampled steps only — the
        // unsampled path pays one relaxed atomic.
        let t_step = Instant::now();
        let sampled = tr.sample_step();
        let t_trace = tr.now();
        let tokens: Vec<i32> = live.iter().map(|a| GenSession::greedy(&a.logits)).collect();
        let stepped = {
            let mut sessions: Vec<&mut GenSession> =
                live.iter_mut().map(|a| &mut a.sess).collect();
            let step_trace: Option<&Trace> = if sampled { Some(tr) } else { None };
            catch_unwind(AssertUnwindSafe(|| {
                step_fused_traced(&mut sessions, &tokens, &mut scratch, &mut panel, step_trace);
            }))
        };
        match stepped {
            Ok(()) => {
                if sampled {
                    tr.span(
                        TRACK_WORKER,
                        EventKind::DecodeStep {
                            batch: live.len() as u32,
                        },
                        t_trace,
                    );
                }
                for a in live.iter_mut() {
                    a.logits.clear();
                    a.logits.resize(engine.cfg.vocab, 0.0);
                }
                scatter_panel(&panel, live.iter_mut().map(|a| a.logits.as_mut_slice()));
                for (a, &t) in live.iter_mut().zip(tokens.iter()) {
                    // TTFT fires on the request's genuinely first token;
                    // replayed sessions (out pre-filled) skip it, and the
                    // inter-token gauge skips gaps that span a preemption
                    // (last_tok resets to None on re-admission)
                    if a.out.is_empty() {
                        m.record_ttft(a.t0.elapsed());
                    } else if let Some(lt) = a.last_tok {
                        m.record_inter_token(lt.elapsed());
                    }
                    a.last_tok = Some(Instant::now());
                    a.out.push(t);
                    if cfg.stream {
                        out.stream(Response::token(a.id, a.t0, t));
                    }
                }
                m.record_decode_step(live.len(), max_live);
                m.record_tokens(live.len());
            }
            Err(_) => {
                m.record_session_panic();
                recover_fused_fault(engine, &cfg, out, m, tr, &mut live, &tokens);
            }
        }
        m.record_pool(pool.stats());
        m.record_fused_step(t_step.elapsed());
        m.record_wall(t_step.elapsed());
    }
    m.record_pool(pool.stats());
    // leak audit: with every session gone, only prefix-index pages may
    // remain and each must hold exactly its index reference
    m.record_pool_idle(pool.verify_idle());
    tr.instant(TRACK_WORKER, EventKind::ShutdownDrain { undrained: 0 });
}

/// A panic escaped `step_fused`: some sessions' caches may hold
/// partially-appended positions for the faulted token (never frozen or
/// prefix-registered — `note_token` only runs after all layers
/// complete). Recovery preempts every live session (releasing all its
/// pages, partial state included) and replays each solo under its own
/// `catch_unwind`: prefill(prompt + out) re-serves the clean prefix
/// from the pool, then the faulted token is stepped again. Survivors
/// continue bitwise-identically (the same preempt-requeue guarantee the
/// scheduler already relies on); a session that panics again is the
/// faulty one — it is torn down with its pages released and answered
/// with a typed error.
fn recover_fused_fault(
    engine: &Arc<Engine>,
    cfg: &ServerConfig,
    out: &Responder,
    m: &Metrics,
    tr: &Arc<Trace>,
    live: &mut Vec<Live<'_>>,
    tokens: &[i32],
) {
    for i in (0..live.len().min(tokens.len())).rev() {
        let t = tokens[i];
        let probed = {
            let a = &mut live[i];
            a.sess.preempt();
            let replay: Vec<i32> = a.prompt.iter().chain(a.out.iter()).copied().collect();
            catch_unwind(AssertUnwindSafe(|| {
                let _ = a.sess.prefill(&replay);
                a.sess.step(t)
            }))
        };
        match probed {
            Ok(logits) => {
                let a = &mut live[i];
                if a.out.is_empty() {
                    m.record_ttft(a.t0.elapsed());
                } else if let Some(lt) = a.last_tok {
                    m.record_inter_token(lt.elapsed());
                }
                a.last_tok = Some(Instant::now());
                a.out.push(t);
                a.logits = logits;
                m.record_tokens(1);
                if cfg.stream {
                    out.stream(Response::token(a.id, a.t0, t));
                }
            }
            Err(_) => {
                m.record_session_panic();
                tr.instant(req_track(live[i].id), EventKind::Fault);
                let mut a = live.remove(i);
                // release whatever the failed probe appended; if even
                // that panics the Drop impl is the backstop
                let _ = catch_unwind(AssertUnwindSafe(|| a.sess.preempt()));
                m.record_kv_bytes(a.sess.kv_bytes());
                m.record_request(a.t0.elapsed(), a.out.len());
                out.finish(Response::failed(
                    a.id,
                    a.t0,
                    a.out,
                    ServeError::Internal("session poisoned by a decode fault".into()),
                ));
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::model::engine::{EngineOptions, Regime};
    use crate::model::weights::{artifact_path, ModelWeights};
    use crate::util::failpoint::{scenario, FailSpec};

    fn engine() -> Option<Arc<Engine>> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let p = artifact_path(&dir, "tiny");
        if !p.exists() {
            return None;
        }
        let w = ModelWeights::load(&p).unwrap();
        Some(Arc::new(Engine::build(
            &w,
            EngineOptions {
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        )))
    }

    #[test]
    fn serves_generate_and_score() {
        let Some(eng) = engine() else { return };
        let prompt: Vec<i32> = (0..8).collect();
        let window: Vec<i32> = (0..33).map(|i| i % 40).collect();
        let (srv, rx) = Server::start(eng, ServerConfig::default());
        srv.submit(Request::Generate {
            id: 1,
            prompt: prompt.clone(),
            n_new: 4,
        })
        .unwrap();
        srv.submit(Request::Score { id: 2, window }).unwrap();
        srv.submit(Request::Generate {
            id: 3,
            prompt,
            n_new: 2,
        })
        .unwrap();
        let mut got = std::collections::HashMap::new();
        for _ in 0..3 {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            assert!(r.error.is_none(), "unexpected error: {:?}", r.error);
            got.insert(r.id, r);
        }
        assert_eq!(got[&1].tokens.len(), 4);
        assert_eq!(got[&3].tokens.len(), 2);
        assert!(got[&2].nll.unwrap() > 0.0);
        assert!(srv.shutdown().drained);
    }

    #[test]
    fn pooled_serving_shares_prefixes_and_exports_gauges() {
        // no artifact needed: a synthetic NestQuantM W+KV engine. Three
        // generate requests with a 32-token common prefix must hit the
        // shared pool, and the pool gauges must surface in Metrics.
        let w = crate::model::weights::ModelWeights::synthetic(
            crate::model::ModelConfig {
                vocab: 48,
                ctx: 64,
                d_model: 32,
                n_layer: 1,
                n_head: 2,
                d_ff: 64,
            },
            0x5E11,
        );
        let eng = Arc::new(Engine::build(
            &w,
            crate::model::engine::EngineOptions {
                method: crate::model::engine::Method::NestQuantM,
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        ));
        let (srv, rx) = Server::start(eng, ServerConfig::default());
        let common: Vec<i32> = (0..32).map(|i| i % 48).collect();
        for id in 0..3u64 {
            let mut prompt = common.clone();
            prompt.push(40 + id as i32);
            srv.submit(Request::Generate { id, prompt, n_new: 3 }).unwrap();
        }
        for _ in 0..3 {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            assert_eq!(r.tokens.len(), 3);
        }
        let stats = srv.metrics.pool_stats().expect("pooled engine must export gauges");
        assert!(
            stats.prefix_hit_tokens >= 32,
            "later sessions should map the shared prefix: {stats:?}"
        );
        assert!(stats.pages_in_use > 0);
        assert!(srv.metrics.report().contains("pool:"));
        // per-site weight payloads flow through Metrics: 6 linears per
        // layer + the head
        let sites = srv.metrics.weight_sites();
        assert_eq!(sites.len(), 7);
        assert!(sites.iter().all(|(_, b)| *b > 0));
        assert!(srv.metrics.report().contains("weights: sites=7"));
        // the throughput tally actually reaches Metrics now (it used to
        // be dropped on the floor): 3 × (33-token prefill + 3 decode)
        assert_eq!(srv.metrics.tokens_processed(), 3 * 36);
        let (steps, decode_tokens) = srv.metrics.decode_stats();
        assert_eq!(decode_tokens, 9, "3 sessions × 3 generated tokens");
        assert!(
            (3..=9).contains(&steps),
            "fused steps must batch up to 3 sessions, got {steps}"
        );
        assert!(srv.metrics.report().contains("sched: processed=108"));
        assert!(srv.metrics.throughput_tok_s() > 0.0);
        let m = srv.metrics.clone();
        assert!(srv.shutdown().drained);
        assert_eq!(m.pool_idle(), Some(Ok(())), "pool must be leak-free at exit");
    }

    fn soak_engine() -> Arc<Engine> {
        let w = crate::model::weights::ModelWeights::synthetic(
            crate::model::ModelConfig {
                vocab: 48,
                ctx: 64,
                d_model: 32,
                n_layer: 2,
                n_head: 2,
                d_ff: 64,
            },
            0x50AC,
        );
        Arc::new(Engine::build(
            &w,
            crate::model::engine::EngineOptions {
                method: crate::model::engine::Method::NestQuantM,
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        ))
    }

    #[test]
    fn soak_tight_budget_preempts_requeues_and_stays_bitwise() {
        // Stress the scheduler: 12 overlapping-prefix sessions against a
        // pool budget of 8 pages (each finished stream needs 3). The
        // loop must (a) never overrun the byte budget, (b) preempt and
        // requeue rather than fail, (c) finish every request with the
        // exact token stream an unconstrained solo run produces.
        let eng = soak_engine();
        let ps = 8usize;
        // learn this engine's page byte size from an unbounded probe pool
        let bpp = eng
            .kv_pool(PoolConfig {
                page_size: ps,
                budget_bytes: None,
            })
            .stats()
            .bytes_per_page;
        assert!(bpp > 0);

        let common: Vec<i32> = (0..8).map(|i| (i * 5 + 1) % 48).collect();
        let mut prompts = Vec::new();
        for s in 0..12i32 {
            let mut p = common.clone();
            for j in 0..4 {
                p.push((s * 7 + j * 3 + 2) % 48);
            }
            prompts.push(p);
        }
        let n_new = 6usize;
        // solo references on private, unbounded pools
        let expect: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| GenSession::new(&eng).generate(p, n_new))
            .collect();

        let (srv, rx) = Server::start(
            eng.clone(),
            ServerConfig {
                policy: BatchPolicy {
                    max_batch: 6,
                    max_wait: std::time::Duration::from_millis(1),
                },
                pool: PoolConfig {
                    page_size: ps,
                    budget_bytes: Some(8 * bpp),
                },
                ..ServerConfig::default()
            },
        );
        for (id, p) in prompts.iter().enumerate() {
            srv.submit(Request::Generate {
                id: id as u64,
                prompt: p.clone(),
                n_new,
            })
            .unwrap();
        }
        let mut got = std::collections::HashMap::new();
        for _ in 0..12 {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            assert!(r.done);
            got.insert(r.id, r.tokens);
        }
        assert_eq!(got.len(), 12, "every session must complete (no starvation)");
        for (id, exp) in expect.iter().enumerate() {
            assert_eq!(
                &got[&(id as u64)], exp,
                "session {id}: preemption/requeue changed the decoded stream"
            );
        }
        let stats = srv.metrics.pool_stats().unwrap();
        assert_eq!(
            stats.budget_overruns, 0,
            "scheduler must preempt before the pool overruns: {stats:?}"
        );
        assert!(
            srv.metrics.preemptions() > 0,
            "a 8-page budget cannot hold 6 × 3-page sessions without preemption"
        );
        assert!(stats.bytes_in_use <= 8 * bpp, "budget exceeded: {stats:?}");
        srv.shutdown();
    }

    #[test]
    fn streaming_emits_per_token_then_final() {
        let eng = soak_engine();
        let (srv, rx) = Server::start(
            eng,
            ServerConfig {
                stream: true,
                ..ServerConfig::default()
            },
        );
        let prompt: Vec<i32> = (0..6).map(|i| (i * 11 + 3) % 48).collect();
        srv.submit(Request::Generate {
            id: 7,
            prompt,
            n_new: 4,
        })
        .unwrap();
        let mut streamed = Vec::new();
        let fin = loop {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            assert_eq!(r.id, 7);
            if r.done {
                break r;
            }
            assert_eq!(r.tokens.len(), 1, "one token per streaming update");
            streamed.push(r.tokens[0]);
        };
        assert_eq!(fin.tokens.len(), 4);
        assert_eq!(
            streamed, fin.tokens,
            "streamed tokens must replay the final stream in order"
        );
        srv.shutdown();
    }

    #[test]
    fn server_trace_journal_covers_the_request_lifecycle() {
        use crate::obs::trace::TraceConfig;
        let eng = soak_engine();
        let (srv, rx) = Server::start(
            eng,
            ServerConfig {
                trace: TraceConfig {
                    capacity: 4096,
                    sample_every: 1, // trace every fused step
                },
                ..ServerConfig::default()
            },
        );
        let prompt: Vec<i32> = (0..6).map(|i| (i * 11 + 3) % 48).collect();
        srv.submit(Request::Generate {
            id: 9,
            prompt,
            n_new: 4,
        })
        .unwrap();
        let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
        assert!(r.error.is_none(), "unexpected error: {:?}", r.error);
        let tr = srv.trace.clone();
        assert!(srv.shutdown().drained);

        let events = tr.snapshot();
        let has = |k: &str| events.iter().any(|e| e.kind.name() == k);
        for k in [
            "queued",
            "validated",
            "admitted",
            "prefill",
            "decode_step",
            "site_gemm",
            "done",
            "page_alloc",
            "shutdown_drain",
        ] {
            assert!(has(k), "journal is missing a `{k}` event");
        }
        // the request rides its own track, with the generated-token
        // count on the terminal event
        assert!(events.iter().filter(|e| e.track == req_track(9)).count() >= 4);
        assert!(events
            .iter()
            .any(|e| matches!(e.kind, EventKind::Done { tokens: 4 })));
        // prefill covered all 6 prompt tokens (fresh session, no replay)
        assert!(events
            .iter()
            .any(|e| matches!(e.kind, EventKind::Prefill { tokens: 6 })));
        // and the whole journal exports as a loadable Chrome trace
        let json = crate::obs::chrome_trace_json(&events);
        crate::obs::validate_chrome_trace(&json).unwrap();
    }

    #[test]
    fn invalid_requests_are_rejected_with_typed_errors() {
        let eng = soak_engine(); // vocab 48, ctx 64
        let (srv, rx) = Server::start(eng, ServerConfig::default());
        // the underflow case from the old worker: a 1-token score window
        srv.submit(Request::Score { id: 1, window: vec![3] }).unwrap();
        // empty prompt
        srv.submit(Request::Generate { id: 2, prompt: vec![], n_new: 4 }).unwrap();
        // prompt + n_new past ctx
        srv.submit(Request::Generate {
            id: 3,
            prompt: (0..40).map(|i| i % 48).collect(),
            n_new: 40,
        })
        .unwrap();
        // out-of-vocab token
        srv.submit(Request::Generate { id: 4, prompt: vec![1, 99], n_new: 2 }).unwrap();
        // and one valid request to prove the worker survived all of the
        // above
        srv.submit(Request::Generate {
            id: 5,
            prompt: vec![1, 2, 3, 4],
            n_new: 2,
        })
        .unwrap();
        let mut got = std::collections::HashMap::new();
        for _ in 0..5 {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            got.insert(r.id, r);
        }
        for id in 1..=4u64 {
            match got[&id].error {
                Some(ServeError::InvalidRequest(_)) => {}
                ref other => panic!("request {id}: expected InvalidRequest, got {other:?}"),
            }
            assert!(got[&id].tokens.is_empty());
            assert!(got[&id].done);
        }
        assert!(got[&5].error.is_none());
        assert_eq!(got[&5].tokens.len(), 2);
        assert_eq!(srv.metrics.rejected(), 4);
        assert!(srv.metrics.report().contains("rejected=4"));
        assert!(srv.shutdown().drained);
    }

    #[test]
    fn deadline_zero_sheds_before_admission() {
        let eng = soak_engine();
        let (srv, rx) = Server::start(eng, ServerConfig::default());
        srv.submit_with_deadline(
            Request::Generate {
                id: 1,
                prompt: vec![1, 2, 3],
                n_new: 4,
            },
            Some(Duration::ZERO),
        )
        .unwrap();
        // no deadline: must still serve normally
        srv.submit(Request::Generate {
            id: 2,
            prompt: vec![1, 2, 3],
            n_new: 4,
        })
        .unwrap();
        let mut got = std::collections::HashMap::new();
        for _ in 0..2 {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            got.insert(r.id, r);
        }
        assert_eq!(got[&1].error, Some(ServeError::DeadlineExceeded));
        assert!(got[&1].tokens.is_empty(), "shed before any generation");
        assert!(got[&2].error.is_none());
        assert_eq!(got[&2].tokens.len(), 4);
        assert_eq!(srv.metrics.expired(), 1);
        assert!(srv.metrics.report().contains("expired=1"));
        assert!(srv.shutdown().drained);
    }

    #[test]
    fn prefill_fault_poisons_only_that_session() {
        let eng = soak_engine();
        let prompts: Vec<Vec<i32>> = (0..3)
            .map(|s: i32| (0..6).map(|j| (s * 13 + j * 7 + 1) % 48).collect())
            .collect();
        let n_new = 4;
        // solo refs BEFORE arming (reference runs must not hit sites)
        let expect: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| GenSession::new(&eng).generate(p, n_new))
            .collect();

        let sc = scenario();
        // the 2nd admission prefill panics (solo refs above are done)
        sc.fail("engine/prefill", FailSpec::Nth(2));
        let (srv, rx) = Server::start(
            eng.clone(),
            ServerConfig {
                policy: BatchPolicy {
                    max_batch: 1, // serialize admissions so Nth(2) = request id 1
                    max_wait: Duration::from_millis(1),
                },
                ..ServerConfig::default()
            },
        );
        for (id, p) in prompts.iter().enumerate() {
            srv.submit(Request::Generate {
                id: id as u64,
                prompt: p.clone(),
                n_new,
            })
            .unwrap();
        }
        let mut got = std::collections::HashMap::new();
        for _ in 0..3 {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            got.insert(r.id, r);
        }
        assert_eq!(sc.fired("engine/prefill"), 1);
        let faulted: Vec<u64> = got
            .values()
            .filter(|r| r.error.is_some())
            .map(|r| r.id)
            .collect();
        assert_eq!(faulted.len(), 1, "exactly one session faults: {got:?}");
        let fid = faulted[0];
        match got[&fid].error {
            Some(ServeError::Internal(_)) => {}
            ref e => panic!("expected Internal, got {e:?}"),
        }
        for (id, exp) in expect.iter().enumerate() {
            let id = id as u64;
            if id == fid {
                continue;
            }
            assert_eq!(
                &got[&id].tokens, exp,
                "survivor {id} must stream bitwise-identically to solo"
            );
        }
        assert!(srv.metrics.session_panics() >= 1);
        let m = srv.metrics.clone();
        assert!(srv.shutdown().drained);
        assert_eq!(m.pool_idle(), Some(Ok(())), "faulted teardown must not leak pages");
    }

    #[test]
    fn step_fault_recovers_survivors_bitwise() {
        let eng = soak_engine();
        let prompts: Vec<Vec<i32>> = (0..3)
            .map(|s: i32| (0..6).map(|j| (s * 17 + j * 5 + 2) % 48).collect())
            .collect();
        let n_new = 5;
        let expect: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| GenSession::new(&eng).generate(p, n_new))
            .collect();

        let sc = scenario();
        // one mid-flight fused step panics; solo recovery probes pass
        // (Nth fires once)
        sc.fail("engine/step_fused", FailSpec::Nth(2));
        let (srv, rx) = Server::start(eng.clone(), ServerConfig::default());
        for (id, p) in prompts.iter().enumerate() {
            srv.submit(Request::Generate {
                id: id as u64,
                prompt: p.clone(),
                n_new,
            })
            .unwrap();
        }
        let mut got = std::collections::HashMap::new();
        for _ in 0..3 {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            got.insert(r.id, r);
        }
        assert_eq!(sc.fired("engine/step_fused"), 1);
        // every session recovers: the faulted step is replayed solo from
        // the pool-served prefix, bitwise-identically
        for (id, exp) in expect.iter().enumerate() {
            let r = &got[&(id as u64)];
            assert!(r.error.is_none(), "session {id} should recover: {:?}", r.error);
            assert_eq!(&r.tokens, exp, "session {id}: recovery changed the stream");
        }
        assert!(srv.metrics.session_panics() >= 1, "the caught step fault must count");
        let m = srv.metrics.clone();
        assert!(srv.shutdown().drained);
        assert_eq!(m.pool_idle(), Some(Ok(())));
    }

    #[test]
    fn worker_respawn_after_uncontained_fault() {
        let eng = soak_engine();
        let sc = scenario();
        // fires after the first ingest block: request 1 is admitted
        // (inflight) when the worker dies uncontained
        sc.fail("coordinator/worker", FailSpec::Nth(1));
        let (srv, rx) = Server::start(eng, ServerConfig::default());
        srv.submit(Request::Generate {
            id: 1,
            prompt: vec![1, 2, 3],
            n_new: 3,
        })
        .unwrap();
        let r1 = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
        assert_eq!(r1.id, 1);
        match r1.error {
            Some(ServeError::Internal(ref msg)) => {
                assert!(msg.contains("restarted"), "got: {msg}")
            }
            ref e => panic!("expected Internal(restarted), got {e:?}"),
        }
        // the respawned worker serves as if nothing happened — submit
        // still returns Ok (never panics)
        srv.submit(Request::Generate {
            id: 2,
            prompt: vec![4, 5, 6],
            n_new: 3,
        })
        .unwrap();
        let r2 = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
        assert_eq!(r2.id, 2);
        assert!(r2.error.is_none());
        assert_eq!(r2.tokens.len(), 3);
        assert_eq!(srv.metrics.respawns(), 1);
        assert!(srv.metrics.report().contains("respawns=1"));
        assert!(srv.shutdown().drained);
    }

    #[test]
    fn fault_soak_contains_faults_and_leaks_nothing() {
        // The acceptance soak: seeded fail-point schedules firing in
        // pool alloc, codec decode, prefill, and the fused step. Every
        // faulted session gets a typed error with a prefix-of-solo
        // token stream; every non-faulted session is bitwise-identical
        // to its solo run; the pool's page/refcount accounting returns
        // to idle after every case.
        let eng = soak_engine();
        let sites = [
            "kvpool/alloc",
            "kvpool/decode",
            "engine/prefill",
            "engine/step_fused",
        ];
        crate::util::propcheck::check("fault-soak", 6, 0xFA17, |rng| {
            let n_sess = 4 + rng.below(3);
            let n_new = 3 + rng.below(4);
            let prompts: Vec<Vec<i32>> = (0..n_sess)
                .map(|s| {
                    let len = 4 + rng.below(6);
                    (0..len).map(|j| ((s * 19 + j * 7) % 48) as i32).collect()
                })
                .collect();
            // solo references BEFORE the scenario arms (they must not
            // consume fail-point hits)
            let expect: Vec<Vec<i32>> = prompts
                .iter()
                .map(|p| GenSession::new(&eng).generate(p, n_new))
                .collect();

            let sc = scenario();
            let site = sites[rng.below(sites.len())];
            let spec = if rng.below(4) == 0 {
                // a sticky fault: fires on every hit from n on, so the
                // faulted session cannot be saved by the solo re-probe
                FailSpec::From(10 + rng.below(60) as u64)
            } else {
                FailSpec::Nth(1 + rng.below(60) as u64)
            };
            sc.fail(site, spec);

            let (srv, rx) = Server::start(eng.clone(), ServerConfig::default());
            for (id, p) in prompts.iter().enumerate() {
                // submit must never panic, faults or not
                srv.submit(Request::Generate {
                    id: id as u64,
                    prompt: p.clone(),
                    n_new,
                })
                .map_err(|e| format!("submit failed: {e}"))?;
            }
            let mut got: HashMap<u64, Response> = HashMap::new();
            while got.len() < n_sess {
                let r = rx
                    .recv_timeout(std::time::Duration::from_secs(120))
                    .map_err(|e| format!("response channel: {e}"))?;
                if !r.done {
                    continue;
                }
                if got.insert(r.id, r).is_some() {
                    return Err("two done responses for one request".into());
                }
            }
            for (id, exp) in expect.iter().enumerate() {
                let r = &got[&(id as u64)];
                match &r.error {
                    None => {
                        if &r.tokens != exp {
                            return Err(format!(
                                "session {id} (site {site}): non-faulted stream diverged"
                            ));
                        }
                    }
                    Some(ServeError::Internal(_)) => {
                        if r.tokens.len() > exp.len() || r.tokens[..] != exp[..r.tokens.len()] {
                            return Err(format!(
                                "session {id} (site {site}): faulted partial output is not \
                                 a prefix of the solo stream"
                            ));
                        }
                    }
                    Some(e) => {
                        return Err(format!("session {id}: unexpected error class {e:?}"));
                    }
                }
            }
            let m = srv.metrics.clone();
            let rep = srv.shutdown();
            if !rep.drained {
                return Err(format!("shutdown did not drain: {rep:?}"));
            }
            match m.pool_idle() {
                Some(Ok(())) => {}
                other => {
                    return Err(format!(
                        "pool leaked after faults at {site}: {other:?}"
                    ))
                }
            }
            drop(sc);
            Ok(())
        });
    }

    #[test]
    fn shutdown_within_reports_undrained_then_drains() {
        let eng = soak_engine();
        let (srv, rx) = Server::start(eng, ServerConfig::default());
        srv.submit(Request::Generate {
            id: 1,
            prompt: vec![1, 2, 3, 4],
            n_new: 4,
        })
        .unwrap();
        // zero-deadline shutdown usually reports the request undrained
        // (the detached worker keeps going); either way the response
        // still arrives and accounting stays consistent
        let rep = srv.shutdown_within(Duration::ZERO);
        if !rep.drained {
            assert!(rep.undrained <= 1);
        }
        let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
        assert_eq!(r.id, 1);
        assert!(r.error.is_none());
        assert_eq!(r.tokens.len(), 4);
    }
}
