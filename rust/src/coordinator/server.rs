//! The serving front-end: a single fused decode loop (vLLM-style
//! token-level continuous batching). Every live session's current token
//! is gathered into one activation panel per layer and served through
//! the packed integer GEMM ([`step_fused`]); per-session attention runs
//! against each session's own coded pages in the shared
//! [`KvPool`](crate::kvpool::KvPool). Admission happens between decode
//! steps (a request joins the running loop as soon as a slot and pool
//! headroom exist — no batch barrier), and pool-byte pressure preempts
//! the youngest session (pages released, request requeued and replayed)
//! instead of overrunning the budget. Sessions with common prompt
//! prefixes share coded pages through the pool's prefix index instead
//! of re-quantizing them.

use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::generator::{step_fused, GenSession};
use crate::coordinator::metrics::Metrics;
use crate::kvpool::PoolConfig;
use crate::model::engine::{Engine, StepScratch};
use crate::quant::gemm::scatter_panel;
use crate::util::linalg::Mat;
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// A serving request.
pub enum Request {
    /// prompt tokens → generated tokens
    Generate {
        id: u64,
        prompt: Vec<i32>,
        n_new: usize,
    },
    /// full-window scoring: mean NLL of the window
    Score { id: u64, window: Vec<i32> },
}

impl Request {
    pub fn id(&self) -> u64 {
        match self {
            Request::Generate { id, .. } | Request::Score { id, .. } => *id,
        }
    }
}

#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub nll: Option<f64>,
    pub latency_ms: f64,
    /// `true` on the final response for a request (the full token
    /// stream / score); `false` on per-token streaming updates (sent
    /// only when [`ServerConfig::stream`] is on, one generated token
    /// each)
    pub done: bool,
}

#[derive(Clone, Copy)]
pub struct ServerConfig {
    pub policy: BatchPolicy,
    /// shared KV-pool sizing (page size, byte budget) for pooled engines.
    /// The server's pool outlives every session, so unlike the
    /// per-session default it ships with a byte budget: without one, the
    /// prefix index would retain every finished session's frozen pages
    /// forever and sustained traffic would grow memory without bound.
    pub pool: PoolConfig,
    /// also send a `done: false` response per generated token as the
    /// fused loop produces it (the final `done: true` response still
    /// carries the full stream)
    pub stream: bool,
}

impl ServerConfig {
    /// Default KV-pool byte budget (logical coded payload): 64 MiB ≈
    /// 128M fp32-equivalent KV entries at the ~8× coded density.
    pub const DEFAULT_POOL_BUDGET: usize = 64 << 20;
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            policy: BatchPolicy::default(),
            pool: PoolConfig {
                budget_bytes: Some(Self::DEFAULT_POOL_BUDGET),
                ..PoolConfig::default()
            },
            stream: false,
        }
    }
}

/// Handle to a running server.
pub struct Server {
    tx: Option<Sender<(Request, Instant)>>,
    worker: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl Server {
    /// Start the coordinator over a quantized engine. Responses are
    /// delivered on the returned channel (out of order across batches).
    pub fn start(
        engine: Arc<Engine>,
        cfg: ServerConfig,
    ) -> (Self, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel::<(Request, Instant)>();
        let (resp_tx, resp_rx) = channel::<Response>();
        let metrics = Arc::new(Metrics::new());
        let m = metrics.clone();

        let worker = std::thread::spawn(move || {
            // one shared paged pool for every session this worker runs:
            // prefix reuse and the byte budget span the server's
            // lifetime. The pool is total over plans — fp/uniform KV
            // layers ride their own lanes — so every engine pools.
            let pool = engine.kv_pool(cfg.pool);
            // per-site weight payload gauges (mixed-precision plans show
            // their per-tensor byte split here)
            m.record_weight_sites(&engine.site_payloads());
            let batcher = Batcher::new(rx, cfg.policy);
            let page_size = cfg.pool.page_size.max(1);
            let max_live = cfg.policy.max_batch.max(1);

            // a Generate request waiting for admission; `out` carries
            // tokens already produced before a preemption, replayed on
            // re-admission
            struct Pending {
                id: u64,
                t0: Instant,
                prompt: Vec<i32>,
                n_new: usize,
                out: Vec<i32>,
            }
            // a session inside the fused decode loop
            struct Live<'a> {
                id: u64,
                t0: Instant,
                // admission order — preemption swaps out the youngest
                seq: u64,
                sess: GenSession<'a>,
                prompt: Vec<i32>,
                n_new: usize,
                out: Vec<i32>,
                logits: Vec<f32>,
            }

            let mut queue: VecDeque<Pending> = VecDeque::new();
            let mut live: Vec<Live> = Vec::new();
            let mut inbox: Vec<(Request, Instant)> = Vec::new();
            let mut open = true;
            let mut next_seq = 0u64;
            let mut scratch = StepScratch::new();
            let mut panel = Mat::zeros(0, 0);

            loop {
                // ingest: block only when idle, otherwise take whatever
                // has queued up since the last decode step
                if open && live.is_empty() && queue.is_empty() {
                    match batcher.recv() {
                        Some(item) => inbox.push(item),
                        None => open = false,
                    }
                }
                if open && !batcher.try_drain(&mut inbox) {
                    open = false;
                }
                for (req, t0) in inbox.drain(..) {
                    match req {
                        Request::Generate { id, prompt, n_new } => {
                            queue.push_back(Pending {
                                id,
                                t0,
                                prompt,
                                n_new,
                                out: Vec::new(),
                            });
                        }
                        Request::Score { id, window } => {
                            // native scoring (the HLO path is exercised
                            // by runtime::ModelRunner in examples/tests;
                            // the in-process worker stays self-contained)
                            let t_score = Instant::now();
                            let logits = engine.forward_window(&window[..window.len() - 1]);
                            let nll =
                                crate::model::forward::window_nll(&logits, &window[1..]);
                            m.record_tokens(window.len());
                            m.record_request(t0.elapsed(), window.len());
                            m.record_wall(t_score.elapsed());
                            let _ = resp_tx.send(Response {
                                id,
                                tokens: Vec::new(),
                                nll: Some(nll),
                                latency_ms: t0.elapsed().as_secs_f64() * 1e3,
                                done: true,
                            });
                        }
                    }
                }
                if !open && live.is_empty() && queue.is_empty() {
                    break;
                }

                // token-level admission: a queued request joins the
                // running loop between decode steps as soon as a slot is
                // free and its pages fit (preemption keeps at least one
                // session running, so an empty loop always admits)
                while live.len() < max_live {
                    let Some(front) = queue.front() else { break };
                    let need = (front.prompt.len() + front.out.len()) / page_size + 1;
                    if !live.is_empty() && pool.would_overrun(need) {
                        break;
                    }
                    let p = queue.pop_front().unwrap();
                    let t_adm = Instant::now();
                    let mut sess = GenSession::new_in_pool(&engine, &pool);
                    // requeued sessions replay prompt + prior output;
                    // the prefix index serves whatever pages survived
                    let replay: Vec<i32> =
                        p.prompt.iter().chain(p.out.iter()).copied().collect();
                    let logits = sess.prefill(&replay);
                    m.record_tokens(replay.len());
                    m.record_wall(t_adm.elapsed());
                    live.push(Live {
                        id: p.id,
                        t0: p.t0,
                        seq: next_seq,
                        sess,
                        prompt: p.prompt,
                        n_new: p.n_new,
                        out: p.out,
                        logits,
                    });
                    next_seq += 1;
                }

                // completions (before the step so a request admitted
                // with nothing left to generate answers immediately)
                let mut i = 0;
                while i < live.len() {
                    let a = &live[i];
                    if a.out.len() >= a.n_new || a.sess.position() >= engine.cfg.ctx {
                        let a = live.swap_remove(i);
                        m.record_kv_bytes(a.sess.kv_bytes());
                        m.record_request(a.t0.elapsed(), a.out.len());
                        let _ = resp_tx.send(Response {
                            id: a.id,
                            tokens: a.out,
                            nll: None,
                            latency_ms: a.t0.elapsed().as_secs_f64() * 1e3,
                            done: true,
                        });
                    } else {
                        i += 1;
                    }
                }
                if live.is_empty() {
                    m.record_pool(pool.stats());
                    continue;
                }

                // pool-pressure preemption: if the next step's page
                // claims could overrun the byte budget, swap out the
                // youngest session — release its pages, requeue its
                // request at the front — rather than fail. The oldest
                // session is never preempted, so every stream finishes.
                loop {
                    let upcoming = live
                        .iter()
                        .filter(|a| a.sess.position() % page_size == 0)
                        .count()
                        .max(1);
                    if live.len() <= 1 || !pool.would_overrun(upcoming) {
                        break;
                    }
                    let vi = live
                        .iter()
                        .enumerate()
                        .max_by_key(|(_, a)| a.seq)
                        .map(|(i, _)| i)
                        .unwrap();
                    let mut a = live.swap_remove(vi);
                    a.sess.preempt();
                    m.record_preemption();
                    queue.push_front(Pending {
                        id: a.id,
                        t0: a.t0,
                        prompt: a.prompt,
                        n_new: a.n_new,
                        out: a.out,
                    });
                }

                // one fused decode step over every live session: greedy
                // next tokens in, one activation panel through the
                // engine, next-token logits scattered back per session
                let t_step = Instant::now();
                let tokens: Vec<i32> =
                    live.iter().map(|a| GenSession::greedy(&a.logits)).collect();
                {
                    let mut sessions: Vec<&mut GenSession> =
                        live.iter_mut().map(|a| &mut a.sess).collect();
                    step_fused(&mut sessions, &tokens, &mut scratch, &mut panel);
                }
                for a in live.iter_mut() {
                    a.logits.clear();
                    a.logits.resize(engine.cfg.vocab, 0.0);
                }
                scatter_panel(&panel, live.iter_mut().map(|a| a.logits.as_mut_slice()));
                for (a, &t) in live.iter_mut().zip(tokens.iter()) {
                    a.out.push(t);
                    if cfg.stream {
                        let _ = resp_tx.send(Response {
                            id: a.id,
                            tokens: vec![t],
                            nll: None,
                            latency_ms: a.t0.elapsed().as_secs_f64() * 1e3,
                            done: false,
                        });
                    }
                }
                m.record_decode_step(live.len());
                m.record_tokens(live.len());
                m.record_pool(pool.stats());
                m.record_wall(t_step.elapsed());
            }
            m.record_pool(pool.stats());
        });

        (
            Server {
                tx: Some(tx),
                worker: Some(worker),
                metrics,
            },
            resp_rx,
        )
    }

    pub fn submit(&self, req: Request) {
        self.tx
            .as_ref()
            .expect("server closed")
            .send((req, Instant::now()))
            .expect("worker died");
    }

    /// Close the queue and wait for the worker to drain.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::engine::{EngineOptions, Regime};
    use crate::model::weights::{artifact_path, ModelWeights};

    fn engine() -> Option<Arc<Engine>> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let p = artifact_path(&dir, "tiny");
        if !p.exists() {
            return None;
        }
        let w = ModelWeights::load(&p).unwrap();
        Some(Arc::new(Engine::build(
            &w,
            EngineOptions {
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        )))
    }

    #[test]
    fn serves_generate_and_score() {
        let Some(eng) = engine() else { return };
        let prompt: Vec<i32> = (0..8).collect();
        let window: Vec<i32> = (0..33).map(|i| i % 40).collect();
        let (srv, rx) = Server::start(eng, ServerConfig::default());
        srv.submit(Request::Generate {
            id: 1,
            prompt: prompt.clone(),
            n_new: 4,
        });
        srv.submit(Request::Score { id: 2, window });
        srv.submit(Request::Generate {
            id: 3,
            prompt,
            n_new: 2,
        });
        let mut got = std::collections::HashMap::new();
        for _ in 0..3 {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            got.insert(r.id, r);
        }
        assert_eq!(got[&1].tokens.len(), 4);
        assert_eq!(got[&3].tokens.len(), 2);
        assert!(got[&2].nll.unwrap() > 0.0);
        srv.shutdown();
    }

    #[test]
    fn pooled_serving_shares_prefixes_and_exports_gauges() {
        // no artifact needed: a synthetic NestQuantM W+KV engine. Three
        // generate requests with a 32-token common prefix must hit the
        // shared pool, and the pool gauges must surface in Metrics.
        let w = crate::model::weights::ModelWeights::synthetic(
            crate::model::ModelConfig {
                vocab: 48,
                ctx: 64,
                d_model: 32,
                n_layer: 1,
                n_head: 2,
                d_ff: 64,
            },
            0x5E11,
        );
        let eng = Arc::new(Engine::build(
            &w,
            crate::model::engine::EngineOptions {
                method: crate::model::engine::Method::NestQuantM,
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        ));
        let (srv, rx) = Server::start(eng, ServerConfig::default());
        let common: Vec<i32> = (0..32).map(|i| i % 48).collect();
        for id in 0..3u64 {
            let mut prompt = common.clone();
            prompt.push(40 + id as i32);
            srv.submit(Request::Generate { id, prompt, n_new: 3 });
        }
        for _ in 0..3 {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            assert_eq!(r.tokens.len(), 3);
        }
        let stats = srv.metrics.pool_stats().expect("pooled engine must export gauges");
        assert!(
            stats.prefix_hit_tokens >= 32,
            "later sessions should map the shared prefix: {stats:?}"
        );
        assert!(stats.pages_in_use > 0);
        assert!(srv.metrics.report().contains("pool:"));
        // per-site weight payloads flow through Metrics: 6 linears per
        // layer + the head
        let sites = srv.metrics.weight_sites();
        assert_eq!(sites.len(), 7);
        assert!(sites.iter().all(|(_, b)| *b > 0));
        assert!(srv.metrics.report().contains("weights: sites=7"));
        // the throughput tally actually reaches Metrics now (it used to
        // be dropped on the floor): 3 × (33-token prefill + 3 decode)
        assert_eq!(srv.metrics.tokens_processed(), 3 * 36);
        let (steps, decode_tokens) = srv.metrics.decode_stats();
        assert_eq!(decode_tokens, 9, "3 sessions × 3 generated tokens");
        assert!(
            (3..=9).contains(&steps),
            "fused steps must batch up to 3 sessions, got {steps}"
        );
        assert!(srv.metrics.report().contains("sched: processed=108"));
        assert!(srv.metrics.throughput_tok_s() > 0.0);
        srv.shutdown();
    }

    fn soak_engine() -> Arc<Engine> {
        let w = crate::model::weights::ModelWeights::synthetic(
            crate::model::ModelConfig {
                vocab: 48,
                ctx: 64,
                d_model: 32,
                n_layer: 2,
                n_head: 2,
                d_ff: 64,
            },
            0x50AC,
        );
        Arc::new(Engine::build(
            &w,
            crate::model::engine::EngineOptions {
                method: crate::model::engine::Method::NestQuantM,
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        ))
    }

    #[test]
    fn soak_tight_budget_preempts_requeues_and_stays_bitwise() {
        // Stress the scheduler: 12 overlapping-prefix sessions against a
        // pool budget of 8 pages (each finished stream needs 3). The
        // loop must (a) never overrun the byte budget, (b) preempt and
        // requeue rather than fail, (c) finish every request with the
        // exact token stream an unconstrained solo run produces.
        let eng = soak_engine();
        let ps = 8usize;
        // learn this engine's page byte size from an unbounded probe pool
        let bpp = eng
            .kv_pool(PoolConfig {
                page_size: ps,
                budget_bytes: None,
            })
            .stats()
            .bytes_per_page;
        assert!(bpp > 0);

        let common: Vec<i32> = (0..8).map(|i| (i * 5 + 1) % 48).collect();
        let mut prompts = Vec::new();
        for s in 0..12i32 {
            let mut p = common.clone();
            for j in 0..4 {
                p.push((s * 7 + j * 3 + 2) % 48);
            }
            prompts.push(p);
        }
        let n_new = 6usize;
        // solo references on private, unbounded pools
        let expect: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| GenSession::new(&eng).generate(p, n_new))
            .collect();

        let (srv, rx) = Server::start(
            eng.clone(),
            ServerConfig {
                policy: BatchPolicy {
                    max_batch: 6,
                    max_wait: std::time::Duration::from_millis(1),
                },
                pool: PoolConfig {
                    page_size: ps,
                    budget_bytes: Some(8 * bpp),
                },
                stream: false,
            },
        );
        for (id, p) in prompts.iter().enumerate() {
            srv.submit(Request::Generate {
                id: id as u64,
                prompt: p.clone(),
                n_new,
            });
        }
        let mut got = std::collections::HashMap::new();
        for _ in 0..12 {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            assert!(r.done);
            got.insert(r.id, r.tokens);
        }
        assert_eq!(got.len(), 12, "every session must complete (no starvation)");
        for (id, exp) in expect.iter().enumerate() {
            assert_eq!(
                &got[&(id as u64)], exp,
                "session {id}: preemption/requeue changed the decoded stream"
            );
        }
        let stats = srv.metrics.pool_stats().unwrap();
        assert_eq!(
            stats.budget_overruns, 0,
            "scheduler must preempt before the pool overruns: {stats:?}"
        );
        assert!(
            srv.metrics.preemptions() > 0,
            "a 8-page budget cannot hold 6 × 3-page sessions without preemption"
        );
        assert!(stats.bytes_in_use <= 8 * bpp, "budget exceeded: {stats:?}");
        srv.shutdown();
    }

    #[test]
    fn streaming_emits_per_token_then_final() {
        let eng = soak_engine();
        let (srv, rx) = Server::start(
            eng,
            ServerConfig {
                stream: true,
                ..ServerConfig::default()
            },
        );
        let prompt: Vec<i32> = (0..6).map(|i| (i * 11 + 3) % 48).collect();
        srv.submit(Request::Generate {
            id: 7,
            prompt,
            n_new: 4,
        });
        let mut streamed = Vec::new();
        let fin = loop {
            let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
            assert_eq!(r.id, 7);
            if r.done {
                break r;
            }
            assert_eq!(r.tokens.len(), 1, "one token per streaming update");
            streamed.push(r.tokens[0]);
        };
        assert_eq!(fin.tokens.len(), 4);
        assert_eq!(
            streamed, fin.tokens,
            "streamed tokens must replay the final stream in order"
        );
        srv.shutdown();
    }
}
