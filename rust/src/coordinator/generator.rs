//! Incremental generation session over a quantized [`Engine`]: one token
//! per step, KV entries coded on insertion into the paged pool through
//! each layer's own [`crate::kvpool::KvLaneCodec`] (fp32 / uniform /
//! nested lanes — the pool is the sole KV backend), attention scored
//! against the coded keys — the paper's memory-bound generation path.
//!
//! Sessions can share an `Arc<KvPool>` ([`GenSession::new_in_pool`]):
//! prefill then maps any cached token prefix straight from the pool
//! (zero forward/quantization work for matched positions) and decode
//! steps publish completed pages back to the pool's prefix index.

use crate::kvpool::{KvPool, PoolConfig, SessionKv};
use crate::model::engine::{Engine, StepScratch};
use crate::model::forward::{gelu, rmsnorm, softmax_inplace};
use crate::obs::trace::Trace;
use crate::util::linalg::Mat;
use crate::util::Rng;
use std::sync::Arc;

/// Advance every session one token in a single fused forward pass — the
/// multi-session decode loop. `sessions[i]` consumes `tokens[i]`; row
/// `i` of `logits` holds its next-token logits afterwards. All sessions
/// must share one engine (the panel runs through that engine's
/// weights). Bitwise-identical to calling [`GenSession::step`] per
/// session — [`Engine::forward_step_fused`] documents the argument and
/// `fused_decode_matches_solo_bitwise` pins it.
pub fn step_fused(
    sessions: &mut [&mut GenSession<'_>],
    tokens: &[i32],
    scratch: &mut StepScratch,
    logits: &mut Mat,
) {
    step_fused_traced(sessions, tokens, scratch, logits, None)
}

/// [`step_fused`] with optional per-site GEMM tracing: `Some(trace)`
/// records a `SiteGemm` span per (layer, site) of this step on the
/// engine track. The server passes `Some` on sampled steps only, so the
/// steady-state decode path is identical to the untraced one.
pub fn step_fused_traced(
    sessions: &mut [&mut GenSession<'_>],
    tokens: &[i32],
    scratch: &mut StepScratch,
    logits: &mut Mat,
    trace: Option<&Trace>,
) {
    assert_eq!(sessions.len(), tokens.len(), "one token per session");
    if sessions.is_empty() {
        logits.rows = 0;
        logits.data.clear();
        return;
    }
    let eng = sessions[0].eng;
    assert!(
        sessions.iter().all(|s| std::ptr::eq(s.eng, eng)),
        "fused step requires one shared engine"
    );
    let positions: Vec<usize> = sessions.iter().map(|s| s.pos).collect();
    let mut caches: Vec<&mut SessionKv> = sessions.iter_mut().map(|s| &mut s.cache).collect();
    eng.forward_step_fused_traced(tokens, &positions, &mut caches, scratch, logits, trace);
    for s in sessions.iter_mut() {
        s.pos += 1;
    }
}

/// A single-stream generation session.
pub struct GenSession<'a> {
    eng: &'a Engine,
    cache: SessionKv,
    pos: usize,
}

impl<'a> GenSession<'a> {
    /// A session with a private single-owner pool carrying the engine's
    /// per-layer lane codecs (an all-fp model gets an all-`Fp32`-lane
    /// pool — there is no separate fp cache path).
    pub fn new(eng: &'a Engine) -> Self {
        GenSession {
            eng,
            cache: SessionKv::new(eng.kv_pool(PoolConfig::default())),
            pos: 0,
        }
    }

    /// A session drawing its KV pages from a shared pool — the
    /// multi-session serving path (prefix sharing, byte budget, LRU
    /// eviction all happen in the pool).
    pub fn new_in_pool(eng: &'a Engine, pool: &Arc<KvPool>) -> Self {
        GenSession {
            eng,
            cache: SessionKv::new(pool.clone()),
            pos: 0,
        }
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    /// Release every KV page back to the pool (keeping whatever the
    /// prefix index already published) and rewind to position 0 — the
    /// scheduler's pressure valve under pool-byte pressure. The token
    /// stream lives with the caller (requeue + replay); a later
    /// [`Self::prefill`] re-maps whatever prefix the pool still caches
    /// and recomputes the rest, bitwise-identical to an uninterrupted
    /// run (`kvpool` pins the rebuild). Returns the pages released.
    pub fn preempt(&mut self) -> usize {
        self.pos = 0;
        self.cache.preempt()
    }

    pub fn kv_bytes(&self) -> usize {
        self.cache.payload_bytes()
    }

    /// Feed one token, get logits for the next.
    pub fn step(&mut self, token: i32) -> Vec<f32> {
        let eng = self.eng;
        let cfg = &eng.cfg;
        let d = cfg.d_model;
        let dh = cfg.d_head();
        assert!(self.pos < cfg.ctx, "context overflow");

        let mut x = vec![0f32; d];
        let emb = eng.tok_emb.row(token as usize);
        let pos_emb = eng.pos_emb.row(self.pos);
        for i in 0..d {
            x[i] = emb[i] + pos_emb[i];
        }

        let mut normed = vec![0f32; d];
        let mut scores: Vec<f32> = Vec::new();
        for (li, l) in eng.layers.iter().enumerate() {
            rmsnorm(&x, &l.ln1, &mut normed);
            let xm = Mat::from_vec(1, d, normed.clone());
            let q = l.wq.forward(&xm);
            let k = l.wk.forward(&xm);
            let v = l.wv.forward(&xm);
            let mut att_out = vec![0f32; d];
            for h in 0..cfg.n_head {
                let mut kh = k.row(0)[h * dh..(h + 1) * dh].to_vec();
                let mut vh = v.row(0)[h * dh..(h + 1) * dh].to_vec();
                let mut qh = q.row(0)[h * dh..(h + 1) * dh].to_vec();
                if let Some(r) = &l.head_rot {
                    r.apply(&mut kh);
                    r.apply(&mut vh);
                    r.apply(&mut qh);
                }
                self.cache.append(li, h, &kh, &vh);
                self.cache.scores(li, h, &qh, &mut scores);
                let scale = 1.0 / (dh as f32).sqrt();
                for s in scores.iter_mut() {
                    *s *= scale;
                }
                softmax_inplace(&mut scores);
                // streaming value-weighted sum off the coded values —
                // no per-position dequantize buffer on the decode path
                let oh = &mut att_out[h * dh..(h + 1) * dh];
                self.cache.weighted_value_sum(li, h, &scores, oh);
                if let Some(r) = &l.head_rot {
                    r.apply_t(oh);
                }
            }
            let att = l.wo.forward(&Mat::from_vec(1, d, att_out));
            for i in 0..d {
                x[i] += att.row(0)[i];
            }
            rmsnorm(&x, &l.ln2, &mut normed);
            let mut h_mid = l.w_up.forward(&Mat::from_vec(1, d, normed.clone()));
            for v in h_mid.data.iter_mut() {
                *v = gelu(*v);
            }
            let down = l.w_down.forward(&h_mid);
            for i in 0..d {
                x[i] += down.row(0)[i];
            }
        }
        // the position is complete on every (layer, head) lane: publish
        // it (freezes + registers pages at page boundaries)
        self.cache.note_token(token);
        rmsnorm(&x, &eng.final_norm, &mut normed);
        let logits = eng.head.forward(&Mat::from_vec(1, d, normed.clone()));
        self.pos += 1;
        logits.data
    }

    /// Prefill a prompt: map the longest pool-cached prefix (at most
    /// `prompt.len()-1` positions — the final token is always recomputed
    /// so its logits exist), then step the remainder. Returns the logits
    /// after the last prompt token (zeros for an empty prompt).
    pub fn prefill(&mut self, prompt: &[i32]) -> Vec<f32> {
        assert_eq!(self.pos, 0, "prefill on a fresh session only");
        // before any page is claimed: a contained fault at admission
        // tears down a session that owns nothing yet
        crate::fail_point!("engine/prefill");
        let matched = self.cache.match_prefix(prompt);
        self.pos = matched;
        let mut logits = vec![0f32; self.eng.cfg.vocab];
        for &t in &prompt[matched..] {
            logits = self.step(t);
        }
        logits
    }

    /// Greedy argmax sampling.
    pub fn greedy(logits: &[f32]) -> i32 {
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        best as i32
    }

    /// Temperature sampling.
    pub fn sample(logits: &[f32], temp: f32, rng: &mut Rng) -> i32 {
        if temp <= 0.0 {
            return Self::greedy(logits);
        }
        let mut probs: Vec<f32> = logits.iter().map(|&v| v / temp).collect();
        softmax_inplace(&mut probs);
        let r = rng.f32();
        let mut acc = 0f32;
        for (i, &p) in probs.iter().enumerate() {
            acc += p;
            if r < acc {
                return i as i32;
            }
        }
        probs.len() as i32 - 1
    }

    /// Prefill a prompt (prefix-served from the pool when shared), then
    /// generate `n_new` tokens greedily. Returns the generated tokens.
    ///
    /// On a session that has already consumed tokens, `prompt` extends
    /// the stream; with an empty `prompt` the first greedy pick seeds
    /// from zero logits (token 0) since the previous step's logits are
    /// owned by the caller — pass them through [`Self::step`] yourself
    /// for logits-continuous continuation.
    pub fn generate(&mut self, prompt: &[i32], n_new: usize) -> Vec<i32> {
        let mut logits = if self.pos == 0 {
            self.prefill(prompt)
        } else {
            // continuing an existing stream: prefix mapping only applies
            // to fresh sessions, so step any extra prompt tokens directly
            let mut logits = vec![0f32; self.eng.cfg.vocab];
            for &t in prompt {
                logits = self.step(t);
            }
            logits
        };
        let mut out = Vec::with_capacity(n_new);
        for _ in 0..n_new {
            if self.pos >= self.eng.cfg.ctx {
                break;
            }
            let next = Self::greedy(&logits);
            out.push(next);
            logits = self.step(next);
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::model::engine::{EngineOptions, Method, Regime};
    use crate::model::weights::{artifact_path, ModelWeights};

    fn load_tiny() -> Option<ModelWeights> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let p = artifact_path(&dir, "tiny");
        p.exists().then(|| ModelWeights::load(&p).unwrap())
    }

    #[test]
    fn incremental_matches_window_forward_fp() {
        // step-by-step logits must equal the full-window forward logits
        let Some(w) = load_tiny() else { return };
        let eng = Engine::build(
            &w,
            EngineOptions {
                regime: Regime::Fp,
                ..Default::default()
            },
        );
        let toks: Vec<i32> = w.val_tokens[..16].to_vec();
        let full = eng.forward_window(&toks);
        let mut sess = GenSession::new(&eng);
        for (t, &tok) in toks.iter().enumerate() {
            let logits = sess.step(tok);
            for v in 0..w.cfg.vocab {
                assert!(
                    (logits[v] - full[(t, v)]).abs() < 1e-3,
                    "t={t} v={v}: {} vs {}",
                    logits[v],
                    full[(t, v)]
                );
            }
        }
    }

    #[test]
    fn generates_plausible_text_quantized() {
        let Some(w) = load_tiny() else { return };
        let eng = Engine::build(
            &w,
            EngineOptions {
                regime: Regime::WKv,
                calib_windows: 2,
                ..Default::default()
            },
        );
        let mut sess = GenSession::new(&eng);
        let prompt: Vec<i32> = w.val_tokens[..8].to_vec();
        let out = sess.generate(&prompt, 24);
        assert_eq!(out.len(), 24);
        assert!(out.iter().all(|&t| (t as usize) < w.cfg.vocab));
        // quantized KV cache must actually be in coded form (small)
        let bytes = sess.kv_bytes();
        let fp_bytes = 2 * sess.position() * w.cfg.d_model * 4 * w.cfg.n_layer / w.cfg.n_head
            * w.cfg.n_head;
        assert!(bytes < fp_bytes / 3, "kv {bytes} vs fp {fp_bytes}");
    }

    #[test]
    fn pooled_prefill_matches_cold_session_bitwise() {
        // Two sessions sharing a ≥64-token prompt through one pool: the
        // second must (a) map shared pages instead of re-quantizing,
        // (b) produce bit-identical logits to the cold path, (c) use
        // strictly less than 2× one session's pool bytes.
        let cfg = crate::model::ModelConfig {
            vocab: 48,
            ctx: 96,
            d_model: 32,
            n_layer: 2,
            n_head: 2,
            d_ff: 64,
        };
        let w = ModelWeights::synthetic(cfg, 0xBEEF);
        let eng = Engine::build(
            &w,
            EngineOptions {
                method: Method::NestQuantM,
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        );
        let pool = eng.kv_pool(PoolConfig::default());
        let vocab = cfg.vocab as i32;
        let prompt: Vec<i32> = (0..64).map(|i| (i * 7 % vocab + i) % vocab).collect();

        let mut a = GenSession::new_in_pool(&eng, &pool);
        let la = a.prefill(&prompt);
        let bytes_one = pool.stats().bytes_in_use;
        assert!(pool.stats().prefix_hit_tokens == 0);

        let mut b = GenSession::new_in_pool(&eng, &pool);
        let lb = b.prefill(&prompt);
        assert_eq!(b.position(), prompt.len());
        let st = pool.stats();
        assert!(
            st.prefix_hit_tokens >= 48,
            "expected ≥3 shared pages, stats {st:?}"
        );
        assert!(
            st.bytes_in_use < 2 * bytes_one,
            "sharing saved nothing: {} vs 2×{}",
            st.bytes_in_use,
            bytes_one
        );
        assert_eq!(la.len(), lb.len());
        for (i, (x, y)) in la.iter().zip(&lb).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "logit {i} diverges between shared and cold prefill: {x} vs {y}"
            );
        }
        // and greedy decode stays bitwise-identical step by step (each
        // step reads the caches — shared pages vs privately quantized)
        let (mut ga, mut gb) = (la, lb);
        for s in 0..8 {
            let (ta, tb) = (GenSession::greedy(&ga), GenSession::greedy(&gb));
            assert_eq!(ta, tb, "greedy token diverges at step {s}");
            ga = a.step(ta);
            gb = b.step(tb);
            for (i, (x, y)) in ga.iter().zip(&gb).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "step {s} logit {i} diverges");
            }
        }
    }

    #[test]
    fn fused_decode_matches_solo_bitwise() {
        // The tentpole's parity harness: a fused multi-session decode in
        // one shared pool must be bitwise identical to stepping every
        // session alone on a private pool — across mixed plans (fp
        // lm_head, fp32/uniform/nested KV lanes), session counts
        // {1, 2, 8, 17} and staggered admission.
        use crate::quant::plan::{EngineBuilder, PolicyPatch, SiteKind, SiteSelector};
        use crate::util::propcheck;

        let cfg = crate::model::ModelConfig {
            vocab: 48,
            ctx: 96,
            d_model: 32,
            n_layer: 3,
            n_head: 2,
            d_ff: 64,
        };
        let w = ModelWeights::synthetic(cfg, 0xFA57);
        let nested = Engine::build(
            &w,
            EngineOptions {
                method: Method::NestQuantM,
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        );
        // mixed plan: fp32 lane in layer 0, uniform-4 lane in layer 1,
        // nested lane in layer 2, fp lm_head
        let mixed = EngineBuilder::from_options(EngineOptions {
            method: Method::NestQuantM,
            regime: Regime::WKv,
            calib_windows: 1,
            ..Default::default()
        })
        .rule(
            SiteSelector {
                layers: Some((0, 0)),
                kind: Some(SiteKind::KvCache),
                ..Default::default()
            },
            PolicyPatch::fp(),
        )
        .rule(
            SiteSelector {
                layers: Some((1, 1)),
                kind: Some(SiteKind::KvCache),
                ..Default::default()
            },
            PolicyPatch {
                method: Some(Method::Rtn),
                uniform_bits: Some(4),
                ..Default::default()
            },
        )
        .rule(
            SiteSelector {
                kind: Some(SiteKind::LmHead),
                ..Default::default()
            },
            PolicyPatch::fp(),
        )
        .build(&w);
        assert!(mixed.layers[0].kv.is_fp(), "plan must yield an fp32 lane");
        let engines = [&nested, &mixed];

        propcheck::check("fused_decode_matches_solo", 6, 0xD05EED, |rng| {
            let eng = engines[rng.below(engines.len())];
            let n = [1usize, 2, 8, 17][rng.below(4)];
            // session s: shared random prefix + private tail, admitted
            // into the fused loop at iteration joins[s]
            let shared_len = 1 + rng.below(8);
            let shared: Vec<i32> = (0..shared_len)
                .map(|_| rng.below(cfg.vocab) as i32)
                .collect();
            let mut prompts: Vec<Vec<i32>> = Vec::new();
            let mut joins = Vec::new();
            for _ in 0..n {
                let mut p = shared.clone();
                for _ in 0..1 + rng.below(4) {
                    p.push(rng.below(cfg.vocab) as i32);
                }
                prompts.push(p);
                joins.push(rng.below(4));
            }
            let n_new = 4 + rng.below(3);

            // solo references: each on a private pool, greedy decode,
            // logits recorded after prefill and after every step
            let mut solo: Vec<Vec<Vec<f32>>> = Vec::new();
            for p in &prompts {
                let mut sess = GenSession::new(eng);
                let mut log = vec![sess.prefill(p)];
                for _ in 0..n_new {
                    let t = GenSession::greedy(log.last().unwrap());
                    log.push(sess.step(t));
                }
                solo.push(log);
            }

            // fused run: one shared pool, token-level admission
            let pool = eng.kv_pool(PoolConfig::default());
            let mut fused: Vec<Option<GenSession>> = (0..n).map(|_| None).collect();
            let mut last: Vec<Vec<f32>> = vec![Vec::new(); n];
            let mut emitted = vec![0usize; n];
            let mut scratch = StepScratch::new();
            let mut logits = Mat::zeros(0, 0);
            let mut iter = 0usize;
            loop {
                assert!(iter < 64, "fused drive did not terminate");
                for s in 0..n {
                    if joins[s] == iter {
                        let mut sess = GenSession::new_in_pool(eng, &pool);
                        let l = sess.prefill(&prompts[s]);
                        for (i, (a, b)) in l.iter().zip(&solo[s][0]).enumerate() {
                            if a.to_bits() != b.to_bits() {
                                return Err(format!(
                                    "prefill logit {i} of session {s} diverges: {a} vs {b}"
                                ));
                            }
                        }
                        last[s] = l;
                        fused[s] = Some(sess);
                    }
                }
                let mut ids = Vec::new();
                let mut sessions: Vec<&mut GenSession> = Vec::new();
                for (s, slot) in fused.iter_mut().enumerate() {
                    if let Some(sess) = slot {
                        if emitted[s] < n_new {
                            ids.push(s);
                            sessions.push(sess);
                        }
                    }
                }
                if sessions.is_empty() {
                    if emitted.iter().all(|&e| e >= n_new) {
                        break;
                    }
                    iter += 1;
                    continue;
                }
                let tokens: Vec<i32> = ids.iter().map(|&s| GenSession::greedy(&last[s])).collect();
                step_fused(&mut sessions, &tokens, &mut scratch, &mut logits);
                for (r, &s) in ids.iter().enumerate() {
                    emitted[s] += 1;
                    let expect = &solo[s][emitted[s]];
                    let row = logits.row(r);
                    for (i, (a, b)) in row.iter().zip(expect).enumerate() {
                        if a.to_bits() != b.to_bits() {
                            return Err(format!(
                                "iter {iter} session {s} (batch {}) logit {i}: {a} vs {b}",
                                ids.len()
                            ));
                        }
                    }
                    last[s].clear();
                    last[s].extend_from_slice(row);
                }
                iter += 1;
            }
            Ok(())
        });
    }

    #[test]
    fn preempted_session_requeues_bitwise() {
        // preempt mid-decode, then replay the full stream on the same
        // pool: logits after replay must bitwise match an uninterrupted
        // solo run (the scheduler's requeue path)
        let cfg = crate::model::ModelConfig {
            vocab: 48,
            ctx: 96,
            d_model: 32,
            n_layer: 2,
            n_head: 2,
            d_ff: 64,
        };
        let w = ModelWeights::synthetic(cfg, 0xBEEF);
        let eng = Engine::build(
            &w,
            EngineOptions {
                method: Method::NestQuantM,
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        );
        let vocab = cfg.vocab as i32;
        let prompt: Vec<i32> = (0..24).map(|i| (i * 7 % vocab + i) % vocab).collect();

        // uninterrupted reference
        let mut solo = GenSession::new(&eng);
        let mut logits = solo.prefill(&prompt);
        let mut stream = prompt.clone();
        for _ in 0..6 {
            let t = GenSession::greedy(&logits);
            stream.push(t);
            logits = solo.step(t);
        }

        // interrupted run: 3 tokens in, preempt, requeue with the whole
        // stream-so-far as the replay prompt
        let pool = eng.kv_pool(PoolConfig::default());
        let mut sess = GenSession::new_in_pool(&eng, &pool);
        let mut l2 = sess.prefill(&prompt);
        let mut replay = prompt.clone();
        for _ in 0..3 {
            let t = GenSession::greedy(&l2);
            replay.push(t);
            l2 = sess.step(t);
        }
        let released = sess.preempt();
        assert!(released > 0, "preempt must hand pages back");
        assert_eq!(sess.position(), 0);
        let mut l3 = sess.prefill(&replay);
        for _ in 0..3 {
            let t = GenSession::greedy(&l3);
            replay.push(t);
            l3 = sess.step(t);
        }
        assert_eq!(replay, stream, "requeued decode took a different path");
        for (i, (a, b)) in l3.iter().zip(&logits).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "post-requeue logit {i} diverges: {a} vs {b}"
            );
        }
    }

    #[test]
    fn per_layer_kv_quantizers_are_used() {
        // the engine calibrates a quantizer pair per layer; the pool
        // must carry each layer's own pair, not layer 0's for all
        let cfg = crate::model::ModelConfig {
            vocab: 48,
            ctx: 32,
            d_model: 32,
            n_layer: 3,
            n_head: 2,
            d_ff: 64,
        };
        let w = ModelWeights::synthetic(cfg, 0xA11);
        let eng = Engine::build(
            &w,
            EngineOptions {
                method: Method::NestQuantM,
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        );
        let pool = eng.kv_pool(PoolConfig::default());
        for (li, l) in eng.layers.iter().enumerate() {
            let (k_nq, v_nq) = match &l.kv {
                crate::model::engine::KvLaneCodec::Nested { k, v } => (k, v),
                _ => panic!("layer {li} must carry a nested KV pair"),
            };
            match pool.lane(li) {
                crate::model::engine::KvLaneCodec::Nested { k, v } => {
                    assert_eq!(k.betas, k_nq.betas, "layer {li} key quantizer mismatch");
                    assert_eq!(v.betas, v_nq.betas, "layer {li} value quantizer mismatch");
                }
                other => panic!("layer {li} pool lane must be nested, got {other:?}"),
            }
        }
    }
}
